"""The failure-pattern subsystem: bounded hashed Δ store (insert/probe
lanes, counter-guided eviction, soundness under any capacity) and the
cross-query template cache (canonicalization, μ == 0 filtering,
warm-start end to end)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backtrack import backtrack_deadend
from repro.core.distributed import DistributedMatcher
from repro.core.engine_step import read_store_slot
from repro.core.vectorized import WaveScheduler, match_vectorized
from repro.data.graph_gen import (corridor_graph, er_labeled_graph,
                                  random_walk_query, trap_graph)
from repro.patterns.cache import PatternCache
from repro.patterns.store import (PROBE, PatternStoreBank, empty_entries,
                                  entries_to_store, hash_insert,
                                  hash_probe, store_to_entries)


def embset(embs):
    return set(frozenset(enumerate(e.tolist())) for e in embs)


def _insert(bank, entries, slot=0):
    """Insert a list of (pos, v, phi, mu) tuples one batch at a time."""
    n = len(entries)
    arr = np.asarray(entries, np.int32)
    return hash_insert(
        bank, jnp.full((n,), slot, jnp.int32),
        jnp.asarray(arr[:, 0]), jnp.asarray(arr[:, 1]),
        jnp.asarray(arr[:, 2]), jnp.asarray(arr[:, 3]),
        jnp.zeros((n, 2), jnp.uint32), jnp.ones((n,), bool))


# ------------------------------------------------------------ store unit
def test_hash_store_roundtrip():
    """Inserted patterns probe back exactly; absent keys miss."""
    bank = PatternStoreBank.empty(2, 64)
    pats = [(d, v, 100 * d + v, d % 3) for d in range(5)
            for v in range(7)]
    bank, counters = _insert(bank, pats, slot=1)
    assert int(counters.stored.sum()) == len(pats)
    assert int(counters.evictions.sum()) == 0
    kp = jnp.asarray([p[0] for p in pats], jnp.int32)
    kv = jnp.asarray([p[1] for p in pats], jnp.int32)
    sl = jnp.ones((len(pats),), jnp.int32)
    found, phi, mu, _, _ = hash_probe(bank, sl, kp, kv)
    assert bool(found.all())
    np.testing.assert_array_equal(np.asarray(phi),
                                  [p[2] for p in pats])
    np.testing.assert_array_equal(np.asarray(mu), [p[3] for p in pats])
    # the other slot must be empty (slot-private stores)
    found0, *_ = hash_probe(bank, jnp.zeros_like(sl), kp, kv)
    assert not bool(found0.any())
    # absent keys miss
    missing, *_ = hash_probe(bank, sl, kp + 40, kv)
    assert not bool(missing.any())


def test_hash_store_same_key_overwrites():
    bank = PatternStoreBank.empty(1, 32)
    bank, c1 = _insert(bank, [(2, 5, 11, 1)])
    bank, c2 = _insert(bank, [(2, 5, 99, 0)])
    assert int(c2.overwrites.sum()) == 1
    found, phi, mu, _, _ = hash_probe(
        bank, jnp.zeros((1,), jnp.int32),
        jnp.asarray([2], jnp.int32), jnp.asarray([5], jnp.int32))
    assert bool(found[0]) and int(phi[0]) == 99 and int(mu[0]) == 0
    assert int(np.asarray(bank.valid).sum()) == 1


def test_hash_store_same_key_within_one_batch():
    """Two same-key entries in ONE batch must collapse to a single
    stored entry with the LAST value (the dense scatter's last-write-
    wins) — the megastep in-loop store batches are not host-deduped, so
    the device insert must key its in-batch dedup by the pattern key."""
    bank = PatternStoreBank.empty(1, 32)
    bank, c = _insert(bank, [(2, 5, 111, 0), (3, 9, 7, 0), (2, 5, 222, 0)])
    assert int(np.asarray(bank.valid).sum()) == 2     # no duplicate key
    found, phi, _, _, _ = hash_probe(
        bank, jnp.zeros((1,), jnp.int32),
        jnp.asarray([2], jnp.int32), jnp.asarray([5], jnp.int32))
    assert bool(found[0]) and int(phi[0]) == 222      # last write won
    assert int(c.stored.sum()) == 2
    assert int(c.dropped.sum()) == 0


def test_hash_store_counter_guided_eviction():
    """capacity == PROBE makes the whole store one probe window: once
    full, the entry with the fewest hits is the one displaced."""
    bank = PatternStoreBank.empty(1, PROBE)
    pats = [(1, v, v, 0) for v in range(PROBE)]
    bank, _ = _insert(bank, pats)
    # bump hits of every entry except v == 3 (the designated victim)
    hot = [(1, v) for v in range(PROBE) if v != 3]
    kp = jnp.asarray([p for p, _ in hot], jnp.int32)
    kv = jnp.asarray([v for _, v in hot], jnp.int32)
    for _ in range(3):
        _, _, _, _, idx = hash_probe(bank, jnp.zeros_like(kp), kp, kv)
        bank = bank._replace(
            hits=bank.hits.at[jnp.zeros_like(idx), idx].add(1))
    bank, c = _insert(bank, [(2, 7, 42, 0)])
    assert int(c.evictions.sum()) == 1
    found3, *_ = hash_probe(bank, jnp.zeros((1,), jnp.int32),
                            jnp.asarray([1], jnp.int32),
                            jnp.asarray([3], jnp.int32))
    assert not bool(found3[0])          # cold entry evicted
    foundn, *_ = hash_probe(bank, jnp.zeros((1,), jnp.int32),
                            jnp.asarray([2], jnp.int32),
                            jnp.asarray([7], jnp.int32))
    assert bool(foundn[0])              # newcomer present
    # all hot entries survived
    fh, *_ = hash_probe(bank, jnp.zeros_like(kp), kp, kv)
    assert bool(fh.all())


def test_store_entries_roundtrip_any_capacity():
    """entries form is layout-independent: snapshot under one capacity,
    rebuild under another, contents identical."""
    bank = PatternStoreBank.empty(1, 256)
    pats = [(d, v, d * 31 + v, d % 2) for d in range(6) for v in range(5)]
    bank, _ = _insert(bank, pats)
    entries = store_to_entries(read_store_slot(bank, 0))
    assert len(entries["pos"]) == len(pats)
    rebuilt = entries_to_store(entries, 64)
    back = store_to_entries(rebuilt)
    for k in ("pos", "v", "phi", "mu", "mask"):
        np.testing.assert_array_equal(entries[k], back[k])
    with pytest.raises(ValueError):
        entries_to_store(entries, 48)       # not a power of two


def test_capacity_validation():
    with pytest.raises(ValueError):
        PatternStoreBank.empty(1, 100)
    with pytest.raises(ValueError):
        PatternStoreBank.empty(1, 4)        # < PROBE


# --------------------------------------------- soundness under eviction
@pytest.mark.parametrize("capacity", [8, 32])
def test_tiny_capacity_oracle_equality_trap(capacity):
    """Eviction changes prune counts, never the embedding set."""
    query, data = trap_graph(n_b=30, n_c=30, n_good=2, tail_len=2, seed=0)
    ref = backtrack_deadend(query, data, limit=None)
    small = match_vectorized(query, data, limit=None, wave_size=32,
                             kpr=4, pattern_capacity=capacity)
    big = match_vectorized(query, data, limit=None, wave_size=32,
                           kpr=4, pattern_capacity=4096)
    assert embset(small.embeddings) == embset(ref.embeddings)
    assert embset(big.embeddings) == embset(ref.embeddings)
    # the bounded store under pressure loses pruning, not correctness
    assert small.stats.deadend_prunes <= big.stats.deadend_prunes
    ts = small.stats.table_stats
    assert ts.capacity == capacity and ts.occupancy <= capacity


def test_tiny_capacity_oracle_equality_megastep():
    query, data = trap_graph(n_b=20, n_c=20, n_good=2, tail_len=2, seed=1)
    ref = backtrack_deadend(query, data, limit=None)
    sched = WaveScheduler(data, n_slots=2, wave_size=16, kpr=4,
                          megastep_depth=4, adaptive_prune_threshold=2.0,
                          pattern_capacity=16)
    qid = sched.submit(query, limit=None)
    sched.run()
    res = sched.finished.pop(qid)
    assert embset(res.embeddings) == embset(ref.embeddings)


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_tiny_capacity_oracle_equality_distributed(n_shards):
    query, data = trap_graph(n_b=25, n_c=25, n_good=2, tail_len=2, seed=0)
    ref = backtrack_deadend(query, data, limit=None)
    dm = DistributedMatcher(data, n_shards=n_shards, wave_size=32,
                            kpr=4, pattern_capacity=16)
    res = dm.match(query, limit=None)
    assert embset(res.embeddings) == embset(ref.embeddings)


def test_property_tiny_capacity_equals_oracle():
    """Hypothesis property (companion to tests/test_deadend.py): random
    graphs + queries stay oracle-equal under severe store pressure."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def check(seed):
        rng = np.random.default_rng(seed)
        n_d = int(rng.integers(10, 30))
        data = er_labeled_graph(n_d, int(rng.integers(n_d, 3 * n_d)),
                                int(rng.integers(1, 4)), seed=seed)
        try:
            query = random_walk_query(data, int(rng.integers(2, 6)),
                                      seed=seed + 1)
        except RuntimeError:
            return
        a = match_vectorized(query, data, limit=None, wave_size=16,
                             kpr=4, pattern_capacity=8)
        b = backtrack_deadend(query, data, limit=None)
        assert embset(a.embeddings) == embset(b.embeddings)

    check()


# ------------------------------------------------------- template cache
def test_cache_fingerprint_distinguishes_templates():
    cb = np.arange(12, dtype=np.uint32).reshape(3, 4)
    nm = np.zeros((3, 3), bool)
    fp = PatternCache.fingerprint(3, cb, nm)
    assert fp == PatternCache.fingerprint(3, cb.copy(), nm.copy())
    assert fp != PatternCache.fingerprint(4, cb, nm)
    cb2 = cb.copy()
    cb2[0, 0] += 1
    assert fp != PatternCache.fingerprint(3, cb2, nm)


def test_cache_keeps_transferable_entries_only():
    cache = PatternCache(max_templates=2, top_k=4)
    entries = empty_entries()
    entries["pos"] = np.asarray([1, 2, 3, 4, 5, 6], np.int32)
    entries["v"] = np.asarray([10, 20, 30, 40, 50, 60], np.int32)
    entries["phi"] = np.zeros(6, np.int32)
    entries["mu"] = np.asarray([0, 1, 0, 0, 0, 0], np.int32)
    entries["mask"] = np.zeros(6, np.uint64)
    entries["hits"] = np.asarray([5, 99, 1, 7, 2, 3], np.int64)
    n = cache.put(b"fp1", entries)
    assert n == 4                       # 5 transferable, capped at top_k=4
    got = cache.get(b"fp1")
    assert (got["mu"] == 0).all()
    assert 30 not in got["v"].tolist()  # hits=1 entry ranked out
    assert cache.get(b"missing") is None
    # LRU eviction at max_templates
    cache.put(b"fp2", entries)
    cache.put(b"fp3", entries)
    assert len(cache) == 2
    assert cache.get(b"fp1") is None    # oldest line evicted
    assert cache.stats.evictions == 1


def test_cache_merge_accumulates_hits():
    cache = PatternCache(top_k=8)
    e = empty_entries()
    e["pos"] = np.asarray([1], np.int32)
    e["v"] = np.asarray([10], np.int32)
    e["phi"] = np.zeros(1, np.int32)
    e["mu"] = np.zeros(1, np.int32)
    e["mask"] = np.zeros(1, np.uint64)
    e["hits"] = np.asarray([3], np.int64)
    cache.put(b"fp", e)
    cache.put(b"fp", e)
    got = cache.get(b"fp")
    assert int(got["hits"][0]) == 6


# -------------------------------------------------- warm start end to end
def test_warm_start_prunes_known_deadends():
    """Resubmitting a template must warm-start from the cache, prune the
    corridor baits it never pruned cold, and stay oracle-exact."""
    query, data = corridor_graph(n_bait=24)
    ref = backtrack_deadend(query, data, limit=None)
    sched = WaveScheduler(data, n_slots=2, wave_size=32, kpr=4)

    def run():
        qid = sched.submit(query, limit=None)
        sched.run()
        sched.poll()
        return sched.finished.pop(qid)

    cold, warm = run(), run()
    assert embset(cold.embeddings) == embset(ref.embeddings)
    assert embset(warm.embeddings) == embset(ref.embeddings)
    assert not cold.stats.cache_hit
    assert warm.stats.cache_hit and warm.stats.warm_patterns > 0
    assert cold.stats.deadend_prunes == 0       # single root: no reuse
    assert warm.stats.deadend_prunes >= 24      # every bait pruned
    assert warm.stats.rows_created < cold.stats.rows_created
    stats = sched.scheduler_stats()
    assert stats["warm_started"] == 1
    assert stats["pattern_cache"]["hits"] == 1


def test_warm_start_respects_no_pruning_ablation():
    """use_pruning=False queries must not be warm-started (their prune
    counts are pinned to zero by the ablation tests)."""
    query, data = corridor_graph(n_bait=12)
    sched = WaveScheduler(data, n_slots=2, wave_size=32, kpr=4)
    q1 = sched.submit(query, limit=None)
    sched.run()
    q2 = sched.submit(query, limit=None, use_pruning=False)
    sched.run()
    r1 = sched.finished.pop(q1)
    r2 = sched.finished.pop(q2)
    assert embset(r1.embeddings) == embset(r2.embeddings)
    assert not r2.stats.cache_hit
    assert r2.stats.deadend_prunes == 0


def test_cache_disabled_scheduler():
    query, data = corridor_graph(n_bait=12)
    sched = WaveScheduler(data, n_slots=2, wave_size=32, kpr=4,
                          pattern_cache=False)
    for _ in range(2):
        qid = sched.submit(query, limit=None)
        sched.run()
        res = sched.finished.pop(qid)
        assert not res.stats.cache_hit
    assert sched.scheduler_stats()["pattern_cache"] is None


def test_warm_start_under_tiny_capacity_stays_exact():
    """Seeding more cached patterns than the store can hold drops the
    coldest — still exact, still warm."""
    query, data = corridor_graph(n_bait=32)
    ref = backtrack_deadend(query, data, limit=None)
    sched = WaveScheduler(data, n_slots=2, wave_size=32, kpr=4,
                          pattern_capacity=16)
    for i in range(2):
        qid = sched.submit(query, limit=None)
        sched.run()
        sched.poll()
        res = sched.finished.pop(qid)
        assert embset(res.embeddings) == embset(ref.embeddings)
    assert res.stats.cache_hit


def test_hit_aging_under_pressure_stays_exact():
    """hit_decay_every=1 ages the device counters every scheduling step;
    combined with a tiny capacity (constant eviction churn) the search
    must still enumerate exactly the oracle set."""
    query, data = trap_graph(n_b=25, n_c=25, n_good=2, tail_len=2, seed=0)
    ref = backtrack_deadend(query, data, limit=None)
    sched = WaveScheduler(data, n_slots=2, wave_size=32, kpr=4,
                          pattern_capacity=16, hit_decay_every=1)
    qid = sched.submit(query, limit=None)
    sched.run()
    res = sched.finished.pop(qid)
    assert embset(res.embeddings) == embset(ref.embeddings)
    assert sched._last_aged_wave > 0            # aging actually ran
