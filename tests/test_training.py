"""Checkpointing, optimizer, data pipeline, and fault-tolerance tests."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.lm_data import LMStreamConfig, TokenStream
from repro.training import checkpoint
from repro.training.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                      global_norm, schedule)


def small_tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
            "b": [jnp.asarray(rng.standard_normal(3), jnp.bfloat16),
                  jnp.asarray(rng.integers(0, 5, 4), jnp.int32)]}


def test_checkpoint_roundtrip(tmp_path):
    tree = small_tree()
    checkpoint.save(tmp_path, 7, tree, extra={"foo": 1})
    out, step, extra = checkpoint.restore(tmp_path, tree)
    assert step == 7 and extra == {"foo": 1}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_keeps_latest_and_gc(tmp_path):
    tree = small_tree()
    for s in (1, 2, 3, 4, 5):
        checkpoint.save(tmp_path, s, tree, keep=2)
    assert checkpoint.latest_step(tmp_path) == 5
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [4, 5]


def test_checkpoint_atomicity_partial_tmp(tmp_path):
    tree = small_tree()
    checkpoint.save(tmp_path, 1, tree)
    # a crashed writer leaves a tmp dir; restore must ignore it
    (tmp_path / "step_000000002.tmp-dead").mkdir()
    assert checkpoint.latest_step(tmp_path) == 1
    out, step, _ = checkpoint.restore(tmp_path, tree)
    assert step == 1


def test_adamw_reduces_loss():
    rng = np.random.default_rng(0)
    w_true = jnp.asarray(rng.standard_normal((8, 1)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
    y = x @ w_true
    params = {"w": jnp.zeros((8, 1), jnp.float32)}
    cfg = AdamWConfig(lr=5e-2, warmup_steps=1, total_steps=100,
                      weight_decay=0.0)
    state = adamw_init(params, cfg)

    def loss_fn(p):
        return jnp.mean((x @ p["w"] - y) ** 2)

    l0 = float(loss_fn(params))
    for _ in range(60):
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, state = adamw_update(params, g, state, cfg)
    assert float(loss_fn(params)) < 0.05 * l0


def test_adamw_bf16_state_mode():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    # lr large enough that the delta survives bf16 rounding at 1.0
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, state_dtype=jnp.bfloat16)
    state = adamw_init(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((4, 4), 0.1, jnp.bfloat16)}
    p2, s2 = adamw_update(params, g, state, cfg)
    assert p2["w"].dtype == jnp.bfloat16
    assert not np.allclose(np.asarray(p2["w"], np.float32), 1.0)


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert float(schedule(cfg, jnp.asarray(100))) <= 0.11


def test_token_stream_deterministic_resume():
    cfg = LMStreamConfig(vocab=128, batch=2, seq_len=16)
    s1 = TokenStream(cfg)
    batches = [s1.next_batch() for _ in range(5)]
    # resume from step 3
    s2 = TokenStream.from_state(cfg, {"seed": 0, "step": 3})
    b3 = s2.next_batch()
    np.testing.assert_array_equal(batches[3]["tokens"], b3["tokens"])


def test_train_driver_end_to_end(tmp_path):
    """Loss goes down, an injected failure + resume continues exactly."""
    from repro.launch.train import main
    ck = str(tmp_path / "run")
    # crash at step 30
    with pytest.raises(RuntimeError):
        main(["--arch", "qwen3-0.6b", "--steps", "60", "--batch", "2",
              "--seq", "32", "--ckpt-dir", ck, "--ckpt-every", "10",
              "--fail-at-step", "30", "--log-every", "100"])
    assert checkpoint.latest_step(ck) == 30
    # resume and finish
    rc = main(["--arch", "qwen3-0.6b", "--steps", "60", "--batch", "2",
               "--seq", "32", "--ckpt-dir", ck, "--ckpt-every", "10",
               "--log-every", "100"])
    assert rc == 0
    assert checkpoint.latest_step(ck) == 60
