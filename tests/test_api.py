"""Request/handle serving API (DESIGN.md §4): non-blocking submit,
incremental streaming, cancellation, typed backpressure, and the single
MatchOptions knob surface shared by engine, distributed, and server."""
import json

import numpy as np
import pytest

from repro.api import (MatchHandle, MatchOptions, MatchSession,
                       QueryResult, QueueFull)
from repro.api.handle import STATUSES
from repro.core.backtrack import DEFAULT_LIMIT, backtrack_deadend
from repro.core.distributed import DistributedMatcher
from repro.core.vectorized import WaveScheduler
from repro.data.graph_gen import (corridor_graph, er_labeled_graph,
                                  query_set, trap_graph)
from repro.serving.query_server import QueryServer


def embset(embs):
    return set(tuple(np.asarray(e).tolist()) for e in embs)


def stream_union(handle: MatchHandle):
    rows = set()
    batches = 0
    for batch in handle.stream():
        assert batch.dtype == np.int32 and batch.ndim == 2
        rows.update(tuple(r) for r in batch.tolist())
        batches += 1
    return rows, batches


# one representative query per workload class of the acceptance
# criteria: uniform (random-walk over an ER graph), trap (the paper's
# Fig. 1 hard case), corridor (prefix-independent mu==0 dead ends)
def _workload(name):
    if name == "uniform":
        data = er_labeled_graph(35, 100, 3, seed=11)
        return query_set(data, 4, 1, seed=5)[0], data
    if name == "trap":
        return trap_graph(n_b=12, n_c=12, n_good=2, tail_len=2, seed=0)
    return corridor_graph(n_bait=10)


# ----------------------------------------------------------------------
# satellite: one knob surface, one set of defaults
# ----------------------------------------------------------------------
def test_options_are_the_single_default_surface():
    """limit / time_budget_s / max_recursions (and every engine knob)
    have exactly one definition: MatchOptions. Engine, scheduler and
    server resolve through it instead of carrying their own copies."""
    opts = MatchOptions()
    assert opts.limit == DEFAULT_LIMIT == 1000
    data = er_labeled_graph(20, 40, 2, seed=0)
    # the scheduler's options ARE the canonical defaults; the tunable
    # engine knobs (None = "tuning layer decides", DESIGN.md §9)
    # resolve through exactly one funnel: MatchOptions.resolved_engine
    sched = WaveScheduler(data)
    assert sched.options == opts
    knobs, _record = opts.resolved_engine(backend="jnp",
                                          n_vertices=data.n)
    assert (sched.max_queue, sched.wave_size, sched.n_slots) == \
        (opts.max_queue, knobs["wave_size"], knobs["n_slots"])
    # a no-override submit queues exactly the MatchOptions defaults
    qid = sched.submit(query_set(data, 3, 1, seed=1)[0])
    req = next(r for r in sched.queue if r.query_id == qid)
    assert (req.limit, req.time_budget_s, req.max_rows) == \
        (opts.limit, opts.time_budget_s, opts.max_recursions)
    # server and distributed matcher: same surface, no local defaults
    srv = QueryServer(data, backend="engine")
    assert srv.options == opts
    assert (srv.limit, srv.time_budget_s, srv.max_recursions) == \
        (opts.limit, opts.time_budget_s, opts.max_recursions)
    dm = DistributedMatcher(data, n_shards=2)
    assert dm.scheduler.options == opts.replace(n_slots=1)
    # the historical max_rows spelling folds into max_recursions
    assert MatchOptions.resolve(None, max_rows=7).max_recursions == 7


def test_options_validated_in_one_place():
    with pytest.raises(ValueError):
        MatchOptions(limit=-1).validate()
    with pytest.raises(ValueError):
        MatchOptions(parallelism=0).validate()
    with pytest.raises(ValueError):
        MatchOptions(pattern_capacity=48).validate()   # not a pow2
    with pytest.raises(TypeError):
        MatchOptions.resolve(None, not_a_knob=1)
    data = er_labeled_graph(20, 40, 2, seed=0)
    with pytest.raises(ValueError):
        QueryServer(data, backend="engine", time_budget_s=-1.0)


# ----------------------------------------------------------------------
# tentpole: streamed union == blocking embedding set, per backend
# ----------------------------------------------------------------------
@pytest.mark.parametrize("workload", ["uniform", "trap", "corridor"])
@pytest.mark.parametrize("backend", ["engine", "distributed",
                                     "sequential"])
def test_stream_equals_batch_equals_oracle(workload, backend):
    """MatchHandle.stream() must yield exactly the blocking API's
    embedding set — on every workload class and every backend
    (engine, distributed parallelism>1, sequential oracle)."""
    query, data = _workload(workload)
    ref = embset(backtrack_deadend(query, data, limit=None).embeddings)
    if backend == "distributed":
        dm = DistributedMatcher(data, n_shards=3, wave_size=32, kpr=4)
        h = dm.submit(query, limit=None)
    else:
        srv = QueryServer(data, backend=backend, limit=None, n_slots=2,
                          wave_size=32, kpr=4)
        h = srv.submit_async(query, limit=None)
    rows, _ = stream_union(h)
    res = h.result()
    assert res.status == "ok"
    assert rows == embset(res.embeddings) == ref


def test_stream_yields_before_completion():
    """Embeddings arrive while the query is still running: the first
    streamed batch lands before the handle completes, and TTFE is
    strictly below total wall time."""
    query, data = trap_graph(n_b=10, n_c=10, n_good=2, tail_len=2,
                             seed=0)
    srv = QueryServer(data, backend="engine", limit=None, n_slots=2,
                      wave_size=32, kpr=4)
    h = srv.submit_async(query, limit=None)
    it = h.stream()
    first = next(it)
    assert len(first) > 0
    assert not h.done()            # streamed mid-flight, not at retire
    rows = set(tuple(r) for r in first.tolist())
    for batch in it:
        rows.update(tuple(r) for r in batch.tolist())
    res = h.result()
    assert res.ttfe_s is not None
    assert res.ttfe_s < res.stats.wall_time_s
    assert rows == embset(res.embeddings)
    rep = srv.slo_report()
    assert rep["ttfe_n"] == 1 and rep["ttfe_p50_ms"] < rep["p50_ms"]


# ----------------------------------------------------------------------
# satellite: cancellation lifecycle
# ----------------------------------------------------------------------
def test_cancel_mid_flight_leaves_neighbors_bit_identical():
    """Cancelling one in-flight query must not perturb the embedding
    rows of the queries sharing its waves."""
    data = er_labeled_graph(35, 100, 3, seed=11)
    queries = query_set(data, 4, 6, seed=5)

    def run(cancel_victim):
        srv = QueryServer(data, backend="engine", limit=None, n_slots=4,
                          wave_size=32, kpr=4)
        handles = [srv.submit_async(q, query_id=i, limit=None)
                   for i, q in enumerate(queries)]
        if cancel_victim:
            for _ in range(3):          # let it get airborne first
                srv.step()
            assert handles[0].cancel()
        return [h.result() for h in handles]

    base = run(cancel_victim=False)
    got = run(cancel_victim=True)
    assert got[0].status == "cancelled"
    assert got[0].aborted and not got[0].timed_out
    assert got[0].stats.abort_reason == "cancelled"
    for b, g in zip(base[1:], got[1:]):
        assert g.status == "ok"
        # bit-identical rows: compare the exact int32 row bytes
        assert sorted(np.asarray(e, np.int32).tobytes()
                      for e in b.embeddings) == \
            sorted(np.asarray(e, np.int32).tobytes()
                   for e in g.embeddings)
    # the cancelled query's stream terminates with what it had
    srv_stats = got[0].stats
    assert srv_stats.found == len(got[0].embeddings)


def test_cancel_queued_request_never_takes_a_slot():
    data = er_labeled_graph(35, 100, 3, seed=11)
    queries = query_set(data, 4, 3, seed=5)
    srv = QueryServer(data, backend="engine", limit=None, n_slots=1,
                      wave_size=32, kpr=4)
    handles = [srv.submit_async(q, limit=None) for q in queries]
    assert handles[2].cancel()          # still queued: retires at once
    assert handles[2].done()
    r = handles[2].result()
    assert r.status == "cancelled" and r.n_found == 0
    assert [h.result().status for h in handles[:2]] == ["ok", "ok"]
    # cancelling a finished query is a no-op
    assert not handles[0].cancel()


def test_cancel_sequential_backend():
    data = er_labeled_graph(35, 100, 3, seed=11)
    queries = query_set(data, 4, 2, seed=5)
    srv = QueryServer(data, backend="sequential", limit=None)
    h1 = srv.submit_async(queries[0], limit=None)
    h2 = srv.submit_async(queries[1], limit=None)
    assert h2.cancel()
    assert h2.result().status == "cancelled"
    assert h1.result().status == "ok"
    assert srv.slo_report()["cancelled"] == 1


# ----------------------------------------------------------------------
# satellite: typed backpressure + priority admission
# ----------------------------------------------------------------------
def test_queue_full_backpressure_is_typed():
    data = er_labeled_graph(35, 100, 3, seed=11)
    queries = query_set(data, 4, 4, seed=5)
    srv = QueryServer(data, backend="engine", limit=None, n_slots=1,
                      wave_size=32, kpr=4, max_queue=2)
    assert issubclass(QueueFull, RuntimeError)
    srv.submit_async(queries[0], limit=None)
    srv.submit_async(queries[1], limit=None)
    with pytest.raises(QueueFull):
        srv.submit_async(queries[2], limit=None)
    # submit_batch absorbs the same signal as backpressure (drains the
    # queue by stepping instead of surfacing QueueFull to the caller)
    results = srv.submit_batch(queries)
    assert all(r.status == "ok" for r in results)


def test_priority_admission_order():
    """Higher-priority requests leave the bounded queue first (FIFO
    within a tie): with one slot, completion order shows admission
    order."""
    data = er_labeled_graph(35, 100, 3, seed=11)
    q = query_set(data, 4, 1, seed=5)[0]
    sched = WaveScheduler(data, n_slots=1, wave_size=32, kpr=4)
    # admission happens at step time, so all three compete in the queue
    tie_a = sched.submit(q, limit=None)            # priority 0, first
    tie_b = sched.submit(q, limit=None, priority=0)
    high = sched.submit(q, limit=None, priority=5)
    sched.run()
    order = sched.poll()
    assert order.index(high) < order.index(tie_a) < order.index(tie_b)


# ----------------------------------------------------------------------
# satellite: JSON-safe result payloads
# ----------------------------------------------------------------------
def test_query_result_to_dict_is_json_safe():
    query, data = trap_graph(n_b=10, n_c=10, n_good=2, tail_len=2,
                             seed=0)
    srv = QueryServer(data, backend="engine", limit=3, n_slots=2,
                      wave_size=32, kpr=4)
    r = srv.submit(7, query)
    d = r.to_dict(include_embeddings=True)
    payload = json.loads(json.dumps(d))            # round-trips cleanly
    assert payload["query_id"] == 7
    assert payload["status"] in STATUSES
    assert payload["status"] == "limit"
    assert isinstance(payload["n_found"], int)
    assert isinstance(payload["latency_ms"], float)
    assert payload["ttfe_ms"] is None or isinstance(
        payload["ttfe_ms"], float)
    assert payload["embeddings"] == [
        [int(v) for v in np.asarray(e).tolist()] for e in r.embeddings]
    assert not r.to_dict().get("embeddings")       # opt-in only
    # the cancelled leg of the taxonomy serializes too
    h = srv.submit_async(query, limit=None)
    h.cancel()
    assert h.result().to_dict()["status"] == "cancelled"


def test_handle_replays_stream_after_completion():
    """stream() on an already-finished handle replays the buffered
    batches — late consumers still see the full union."""
    query, data = trap_graph(n_b=10, n_c=10, n_good=2, tail_len=2,
                             seed=0)
    for backend in ("engine", "sequential"):
        srv = QueryServer(data, backend=backend, limit=None, n_slots=2,
                          wave_size=32, kpr=4)
        h = srv.submit_async(query, limit=None)
        res = h.result()                           # finish first
        rows, _ = stream_union(h)                  # then stream
        assert rows == embset(res.embeddings)


def test_result_mid_stream_and_double_stream():
    """result() while a stream is being consumed must not error (the
    sequential backend runs streams on a worker thread), and a second
    stream() over a finished handle replays the full set."""
    query, data = trap_graph(n_b=10, n_c=10, n_good=2, tail_len=2,
                             seed=0)
    for backend in ("engine", "sequential"):
        srv = QueryServer(data, backend=backend, limit=None, n_slots=2,
                          wave_size=32, kpr=4)
        h = srv.submit_async(query, limit=None)
        it = h.stream()
        next(it)                       # stream is live...
        res = h.result()               # ...result() joins, no error
        assert res.status == "ok"
        first, _ = stream_union(h)     # fresh iterator: full replay
        second, _ = stream_union(h)    # and again — non-destructive
        assert first == second == embset(res.embeddings)


def test_match_session_direct():
    """The api-level session works without the serving wrapper, and
    QueryResult re-exports stay importable from the serving module."""
    from repro.serving import QueryResult as ServingQueryResult
    assert ServingQueryResult is QueryResult
    query, data = trap_graph(n_b=10, n_c=10, n_good=2, tail_len=2,
                             seed=0)
    s = MatchSession(data, n_slots=2, wave_size=32, kpr=4)
    h = s.submit(query, limit=None, keep_table=True)
    res = h.result()
    assert res.status == "ok"
    assert s.scheduler.tables.pop(h.query_id, None) is not None
