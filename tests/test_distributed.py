"""Distributed matcher: partition/steal/share/restore must preserve the
exact result set (Theorem 1 extended to the distributed schedule)."""
import numpy as np
import pytest

from repro.core.backtrack import backtrack_deadend
from repro.core.distributed import DistributedMatcher
from repro.data.graph_gen import (er_labeled_graph, random_walk_query,
                                  trap_graph)


def embset(embs):
    return set(frozenset(enumerate(e.tolist())) for e in embs)


@pytest.mark.parametrize("n_shards", [1, 3, 4])
def test_distributed_matches_sequential(n_shards):
    data = er_labeled_graph(40, 130, 2, seed=2)
    query = random_walk_query(data, 4, seed=3)
    ref = backtrack_deadend(query, data, limit=None)
    dm = DistributedMatcher(data, n_shards=n_shards, wave_size=32, kpr=4)
    res = dm.match(query, limit=None)
    assert embset(res.embeddings) == embset(ref.embeddings)


def test_distributed_pattern_sharing_reduces_rows():
    query, data = trap_graph(n_b=40, n_c=40, n_good=2, tail_len=2, seed=0)
    shared = DistributedMatcher(data, n_shards=4, wave_size=32, kpr=4,
                                share_patterns=True)
    lone = DistributedMatcher(data, n_shards=4, wave_size=32, kpr=4,
                              share_patterns=False)
    r1 = shared.match(query, limit=None, rounds=16)
    r2 = lone.match(query, limit=None, rounds=16)
    assert embset(r1.embeddings) == embset(r2.embeddings)
    # transferable mu=0 patterns exist in the trap (bad c's die for any
    # prefix mapping u1 -> hub), so sharing must not hurt
    assert r1.stats.recursions <= r2.stats.recursions * 1.05


def test_distributed_checkpoint_and_elastic_restore(tmp_path):
    data = er_labeled_graph(36, 100, 2, seed=5)
    query = random_walk_query(data, 4, seed=6)
    dm = DistributedMatcher(data, n_shards=4, wave_size=32, kpr=4)
    # save a synthetic mid-run state and restore onto a DIFFERENT count
    from repro.core.distributed import ShardState
    shards = [ShardState(0, [(0, 3), (3, 7)], []),
              ShardState(1, [(7, 9)], [])]
    dm.save_state(str(tmp_path), query, shards)
    restored = dm.load_state(str(tmp_path), n_shards=3)
    assert len(restored) == 3
    all_ranges = sorted(r for s in restored for r in s.pending_ranges)
    assert all_ranges == [(0, 3), (3, 7), (7, 9)]
