"""Distributed matching as shard-as-segments on the shared-wave
scheduler: partition/steal/share/restore must preserve the exact result
set (Theorem 1 extended to the distributed schedule), and full Δ sharing
must be *observable* — the unified architecture's prune counts may never
fall below the old per-engine implementation's."""
import json
import pathlib

import numpy as np
import pytest

from repro.core.backtrack import backtrack_deadend
from repro.core.distributed import (DistributedMatcher,
                                    select_exchange_patterns)
from repro.core.vectorized import WaveEngine
from repro.data.graph_gen import (er_labeled_graph, random_walk_query,
                                  trap_graph)

# deadend_prunes of the deleted per-engine DistributedMatcher (isolated
# 1-slot WaveEngines + lossy mu==0-only exchange) on trap(40) with
# n_shards=4, wave_size=32, kpr=4 — measured at commit 6455815. The
# shard-as-segments rebuild shares the full Δ (mu > 0 included), so its
# prune count must never fall below this.
OLD_PER_ENGINE_TRAP40_PRUNES = 1320


def embset(embs):
    return set(frozenset(enumerate(e.tolist())) for e in embs)


@pytest.mark.parametrize("n_shards", [1, 3, 4])
def test_distributed_matches_sequential(n_shards):
    data = er_labeled_graph(40, 130, 2, seed=2)
    query = random_walk_query(data, 4, seed=3)
    ref = backtrack_deadend(query, data, limit=None)
    dm = DistributedMatcher(data, n_shards=n_shards, wave_size=32, kpr=4)
    res = dm.match(query, limit=None)
    assert embset(res.embeddings) == embset(ref.embeddings)


@pytest.mark.parametrize("n_shards", [2, 4])
def test_distributed_megastep_matches_sequential(n_shards):
    """Oracle equality with the fused K-deep megastep forced on
    (threshold > 1 keeps every fresh wave on the deep schedule), on both
    uniform and failure-heavy workloads."""
    data = er_labeled_graph(40, 130, 2, seed=2)
    query = random_walk_query(data, 4, seed=3)
    tq, tg = trap_graph(n_b=20, n_c=20, n_good=2, tail_len=2, seed=0)
    for q, g in ((query, data), (tq, tg)):
        ref = backtrack_deadend(q, g, limit=None)
        dm = DistributedMatcher(g, n_shards=n_shards, wave_size=32, kpr=4,
                                megastep_depth=4,
                                adaptive_prune_threshold=2.0)
        res = dm.match(q, limit=None)
        assert embset(res.embeddings) == embset(ref.embeddings)


def test_full_delta_sharing_observable_on_trap():
    """The acceptance pin: distributed match with n_shards > 1 (+ the
    megastep machinery) enumerates exactly the sequential oracle's set,
    and its prune count is >= the old per-engine implementation's —
    full Δ sharing must be observable, not just claimed. (On this trap
    every learned pattern has mu == 1, so the old mu==0-only collective
    shared *nothing*; the unified table is what closes the gap.)"""
    query, data = trap_graph(n_b=40, n_c=40, n_good=2, tail_len=2, seed=0)
    ref = backtrack_deadend(query, data, limit=None)
    dm = DistributedMatcher(data, n_shards=4, wave_size=32, kpr=4)
    res = dm.match(query, limit=None)
    assert embset(res.embeddings) == embset(ref.embeddings)
    assert res.stats.deadend_prunes >= OLD_PER_ENGINE_TRAP40_PRUNES
    # distributed prune rate matches a single-engine run of the same
    # wave shape (sharding is a schedule change, not a pruning change)
    eng = WaveEngine(data, wave_size=32, kpr=4)
    single = eng.match(query, limit=None)
    assert res.stats.deadend_prunes >= 0.95 * single.stats.deadend_prunes
    d_rate = res.stats.deadend_prunes / max(1, res.stats.rows_created)
    s_rate = single.stats.deadend_prunes / max(1, single.stats.rows_created)
    assert d_rate >= 0.9 * s_rate


def test_sharing_beats_isolated_shards():
    """share_patterns=False (the pre-unification ablation: isolated
    per-shard queries, private tables) must enumerate the same set but
    prune less / expand more than the shared-table architecture."""
    query, data = trap_graph(n_b=40, n_c=40, n_good=2, tail_len=2, seed=0)
    shared = DistributedMatcher(data, n_shards=4, wave_size=32, kpr=4,
                                share_patterns=True)
    lone = DistributedMatcher(data, n_shards=4, wave_size=32, kpr=4,
                              share_patterns=False)
    r1 = shared.match(query, limit=None)
    r2 = lone.match(query, limit=None)
    assert embset(r1.embeddings) == embset(r2.embeddings)
    assert r1.stats.deadend_prunes >= r2.stats.deadend_prunes
    assert r1.stats.rows_created <= r2.stats.rows_created


def test_work_stealing_mid_query():
    """Uneven root ranges: idle shards must steal work-item ranges from
    loaded shards (steal counter > 0) without perturbing the result."""
    query, data = trap_graph(n_b=40, n_c=40, n_good=2, tail_len=2, seed=0)
    ref = backtrack_deadend(query, data, limit=None)
    dm = DistributedMatcher(data, n_shards=8, wave_size=16, kpr=4)
    res = dm.match(query, limit=None)
    assert embset(res.embeddings) == embset(ref.embeddings)
    assert res.stats.steals > 0
    assert res.stats.shard_rows is not None
    assert len(res.stats.shard_rows) == 8
    assert sum(res.stats.shard_rows) == res.stats.rows_created


def test_checkpoint_npz_roundtrip(tmp_path):
    """A completed checkpointed run writes a v3 .npz snapshot with empty
    pending set, the full embedding set, and the learned Δ entries."""
    query, data = trap_graph(n_b=20, n_c=20, n_good=2, tail_len=2, seed=0)
    ref = backtrack_deadend(query, data, limit=None)
    dm = DistributedMatcher(data, n_shards=4, wave_size=32, kpr=4,
                            checkpoint_every_waves=2)
    res = dm.match(query, limit=None, checkpoint_dir=str(tmp_path))
    assert embset(res.embeddings) == embset(ref.embeddings)
    assert (tmp_path / "state.npz").exists()
    ck = DistributedMatcher.load_state(str(tmp_path))
    assert ck.version == 3
    assert len(ck.pending_roots) == 0
    assert embset(ck.embeddings) == embset(ref.embeddings)
    assert ck.entries is not None and len(ck.entries["pos"]) > 0
    assert ck.entries["hits"].sum() > 0
    assert ck.phi_floor > 1


def test_elastic_restore_onto_different_shard_count(tmp_path):
    """Abort a 4-shard run mid-flight (row budget), then resume the last
    snapshot on 3 shards: the resumed run must complete with exactly the
    oracle's embedding set, keeping its learned Δ (seeded table + raised
    phi floor)."""
    query, data = trap_graph(n_b=40, n_c=40, n_good=2, tail_len=2, seed=0)
    ref = backtrack_deadend(query, data, limit=None)
    dm = DistributedMatcher(data, n_shards=4, wave_size=32, kpr=4,
                            checkpoint_every_waves=2)
    partial = dm.match(query, limit=None, checkpoint_dir=str(tmp_path),
                       max_rows=120)
    assert partial.stats.aborted and partial.stats.abort_reason == "rows"
    ck = DistributedMatcher.load_state(str(tmp_path))
    assert len(ck.pending_roots) > 0      # genuinely mid-run
    dm2 = DistributedMatcher(data, n_shards=3, wave_size=32, kpr=4)
    res = dm2.match(query, limit=None, checkpoint_dir=str(tmp_path),
                    resume=True)
    assert embset(res.embeddings) == embset(ref.embeddings)
    # restore raised the phi floor above the writer's ceiling, so the
    # seeded mu > 0 patterns were sound to keep
    assert dm2.scheduler.pool.id_counter >= ck.phi_floor


def test_resume_with_limit_yields_full_quota(tmp_path):
    """A resumed run under a finite limit must deliver `limit` *unique*
    embeddings when that many exist: the raw per-run limit leaves room
    for duplicates of the checkpoint's prior embeddings (dedup happens
    on the merged union)."""
    query, data = trap_graph(n_b=40, n_c=40, n_good=2, tail_len=2, seed=0)
    ref = backtrack_deadend(query, data, limit=None)
    n_full = len(ref.embeddings)
    assert n_full > 20
    dm = DistributedMatcher(data, n_shards=4, wave_size=32, kpr=4,
                            checkpoint_every_waves=2)
    partial = dm.match(query, limit=None, checkpoint_dir=str(tmp_path),
                       max_rows=120)
    assert partial.stats.aborted
    dm2 = DistributedMatcher(data, n_shards=2, wave_size=32, kpr=4)
    res = dm2.match(query, limit=n_full - 5, checkpoint_dir=str(tmp_path),
                    resume=True)
    assert res.stats.found == n_full - 5
    assert embset(res.embeddings) <= embset(ref.embeddings)
    assert len(embset(res.embeddings)) == n_full - 5   # unique quota


def test_legacy_json_checkpoint_read_path(tmp_path):
    """One-release compatibility: a v1 state.json (root-candidate index
    ranges) still restores — pending ranges map onto the deterministic
    root order of the recomputed candidates."""
    from repro.core.backtrack import _prepare
    query, data = trap_graph(n_b=20, n_c=20, n_good=2, tail_len=2, seed=0)
    ref = backtrack_deadend(query, data, limit=None)
    cand_by_pos, _, _, _ = _prepare(query, data, None, None)
    n_roots = len(cand_by_pos[0])
    state = {"shards": [
        {"shard_id": 0, "pending": [[0, n_roots // 2]], "found": []},
        {"shard_id": 1, "pending": [[n_roots // 2, n_roots]], "found": []},
    ]}
    pathlib.Path(tmp_path, "state.json").write_text(json.dumps(state))
    dm = DistributedMatcher(data, n_shards=3, wave_size=32, kpr=4)
    res = dm.match(query, limit=None, checkpoint_dir=str(tmp_path),
                   resume=True)
    assert embset(res.embeddings) == embset(ref.embeddings)


def test_exchange_selection_deterministic_by_hits():
    """The cross-host pattern exchange ranks by Δ hit counters with a
    deterministic (pos, vertex) tie-break — two identical runs export
    the identical top-k, and no exported entry has fewer hits than an
    excluded one."""
    query, data = trap_graph(n_b=40, n_c=40, n_good=2, tail_len=2, seed=0)

    def run():
        dm = DistributedMatcher(data, n_shards=4, wave_size=32, kpr=4)
        dm.match(query, limit=None)
        return dm

    dm1, dm2 = run(), run()
    e1 = dm1.export_patterns(top_k=8, transferable_only=False)
    e2 = dm2.export_patterns(top_k=8, transferable_only=False)
    assert np.array_equal(e1["pos"], e2["pos"])
    assert np.array_equal(e1["v"], e2["v"])
    assert len(e1["pos"]) == 8
    full = dm1._entries
    exported = set(zip(e1["pos"].tolist(), e1["v"].tolist()))
    excluded_hits = [int(h) for p, v, h in zip(
        full["pos"].tolist(), full["v"].tolist(), full["hits"].tolist())
        if (p, v) not in exported]
    if excluded_hits:
        assert int(e1["hits"].min()) >= max(excluded_hits)


def test_exchange_transferable_only_filters_mu():
    """transferable_only export keeps mu == 0 entries only (sound
    without a phi floor); the full export keeps everything valid."""
    query, data = trap_graph(n_b=40, n_c=40, n_good=2, tail_len=2, seed=0)
    dm = DistributedMatcher(data, n_shards=4, wave_size=32, kpr=4)
    dm.match(query, limit=None)
    tab = dm.export_patterns(transferable_only=True)
    assert (np.asarray(tab["mu"]) == 0).all()
    full = dm.export_patterns(transferable_only=False)
    assert len(full["pos"]) >= len(tab["pos"])
    assert len(full["pos"]) == len(dm._entries["pos"])


def test_legacy_v2_dense_checkpoint_read_path(tmp_path):
    """One-release compatibility: a v2 .npz snapshot (dense [N_PAD, V]
    table + hit counters) converts to the entries form on read and
    restores — keeping the learned Δ and the phi floor."""
    import numpy as np
    from repro.core.engine_step import N_PAD
    from repro.patterns.store import words_from64

    query, data = trap_graph(n_b=40, n_c=40, n_good=2, tail_len=2, seed=0)
    ref = backtrack_deadend(query, data, limit=None)
    # abort a run mid-flight to get a genuine pending set + learned Δ
    dm = DistributedMatcher(data, n_shards=4, wave_size=32, kpr=4,
                            checkpoint_every_waves=2)
    partial = dm.match(query, limit=None, checkpoint_dir=str(tmp_path),
                       max_rows=120)
    assert partial.stats.aborted
    ck = DistributedMatcher.load_state(str(tmp_path))
    assert ck.entries is not None and len(ck.entries["pos"]) > 0
    # rewrite the snapshot in the legacy v2 dense format
    v = data.n
    dense = {k: np.zeros((N_PAD, v), d) for k, d in
             (("phi", np.int32), ("mu", np.int32), ("valid", bool))}
    dense["mask"] = np.zeros((N_PAD, v, 2), np.uint32)
    hits = np.zeros((N_PAD, v), np.int64)
    e = ck.entries
    dense["phi"][e["pos"], e["v"]] = e["phi"]
    dense["mu"][e["pos"], e["v"]] = e["mu"]
    dense["mask"][e["pos"], e["v"]] = words_from64(e["mask"])
    dense["valid"][e["pos"], e["v"]] = True
    hits[e["pos"], e["v"]] = e["hits"]
    payload = {"version": np.int64(2), "n_shards": np.int64(4),
               "phi_floor": np.int64(ck.phi_floor),
               "pending_roots": ck.pending_roots,
               "embeddings": (np.stack(ck.embeddings).astype(np.int32)
                              if ck.embeddings
                              else np.zeros((0, 0), np.int32)),
               "table_hits": hits}
    for k in ("phi", "mu", "mask", "valid"):
        payload[f"table_{k}"] = dense[k]
    with open(tmp_path / "state.npz", "wb") as f:
        np.savez_compressed(f, **payload)
    ck2 = DistributedMatcher.load_state(str(tmp_path))
    assert ck2.version == 2
    for k in ("pos", "v", "phi", "mu", "mask", "hits"):
        np.testing.assert_array_equal(ck2.entries[k], ck.entries[k])
    dm2 = DistributedMatcher(data, n_shards=3, wave_size=32, kpr=4)
    res = dm2.match(query, limit=None, checkpoint_dir=str(tmp_path),
                    resume=True)
    assert embset(res.embeddings) == embset(ref.embeddings)
    assert dm2.scheduler.pool.id_counter >= ck.phi_floor
