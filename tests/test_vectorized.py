"""The JAX wave engine must enumerate exactly the reference result sets."""
import numpy as np
import pytest

from repro.core.backtrack import backtrack_deadend
from repro.core.vectorized import WaveEngine, match_vectorized
from repro.data.graph_gen import (er_labeled_graph, random_walk_query,
                                  trap_graph)


def embset(res):
    return set(frozenset(enumerate(e.tolist())) for e in res.embeddings)


def random_case(seed):
    rng = np.random.default_rng(seed)
    data = er_labeled_graph(int(rng.integers(10, 40)),
                            int(rng.integers(20, 90)),
                            int(rng.integers(1, 4)), seed=seed)
    try:
        query = random_walk_query(data, int(rng.integers(2, 7)),
                                  seed=seed + 1)
    except RuntimeError:
        return None
    return query, data


@pytest.mark.parametrize("seed", range(20))
def test_engine_equals_sequential(seed):
    case = random_case(seed)
    if case is None:
        pytest.skip("no connected query")
    query, data = case
    a = match_vectorized(query, data, limit=None, wave_size=64, kpr=4)
    b = backtrack_deadend(query, data, limit=None)
    assert embset(a) == embset(b)


@pytest.mark.parametrize("wave_size,kpr", [(4, 2), (16, 4), (256, 16)])
def test_engine_wave_config_invariance(wave_size, kpr):
    """Result sets must not depend on the wave schedule."""
    query, data = trap_graph(n_b=20, n_c=20, n_good=2, tail_len=2, seed=0)
    a = match_vectorized(query, data, limit=None,
                         wave_size=wave_size, kpr=kpr)
    b = backtrack_deadend(query, data, limit=None)
    assert embset(a) == embset(b)


def test_engine_pruning_reduces_rows():
    query, data = trap_graph(n_b=50, n_c=50, n_good=2, tail_len=2, seed=0)
    a = match_vectorized(query, data, limit=None, wave_size=64, kpr=8)
    b = match_vectorized(query, data, limit=None, wave_size=64, kpr=8,
                         use_pruning=False)
    assert embset(a) == embset(b)
    assert a.stats.deadend_prunes > 0
    assert a.stats.rows_created < b.stats.rows_created / 2


def test_engine_limit():
    data = er_labeled_graph(30, 90, 2, seed=3)
    query = random_walk_query(data, 3, seed=4)
    full = match_vectorized(query, data, limit=None)
    if full.stats.found > 5:
        lim = match_vectorized(query, data, limit=5)
        assert lim.stats.found == 5
        assert lim.stats.aborted
        assert embset(lim) <= embset(full)


def test_engine_no_candidates():
    data = er_labeled_graph(20, 40, 2, seed=5)
    # a query label that does not exist in the data graph
    from repro.core.graph import Graph
    query = Graph.from_edges(2, [(0, 1)], [7, 7], n_labels=8)
    res = match_vectorized(query, data, limit=None)
    assert res.embeddings == []


def test_engine_reuse_across_queries():
    """One engine instance (one compiled program) serves many queries."""
    data = er_labeled_graph(40, 120, 3, seed=6)
    eng = WaveEngine(data, wave_size=64, kpr=8)
    for s in range(5):
        try:
            q = random_walk_query(data, 4, seed=s)
        except RuntimeError:
            continue
        a = eng.match(q, limit=None)
        b = backtrack_deadend(q, data, limit=None)
        assert embset(a) == embset(b)
