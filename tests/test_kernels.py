"""Per-kernel interpret-mode validation against the ref.py oracles,
swept across shapes and dtypes (the kernel testing contract)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.graph import build_hier_bitmap, pack_bitmap
from repro.kernels import ref
from repro.kernels.ops import (bitmap_spmm_op, flash_attention_op,
                               refine_bitmap_op, refine_bitmap_rows_op,
                               refine_bitmap_rows_hier_op)


# ---------------------------------------------------------------- refine
@pytest.mark.parametrize("v,f,np_,seed", [
    (33, 4, 5, 0), (128, 16, 8, 1), (300, 32, 12, 2), (64, 1, 3, 3),
])
def test_refine_bitmap_vs_ref(v, f, np_, seed):
    rng = np.random.default_rng(seed)
    dense = rng.random((v, v)) < 0.2
    dense |= dense.T
    adj = jnp.asarray(pack_bitmap(dense))
    cand = jnp.asarray(pack_bitmap(rng.random((1, v)) < 0.5)[0])
    frontier = jnp.asarray(
        rng.integers(-1, v, size=(f, np_)).astype(np.int32))
    active = jnp.asarray((rng.random(np_) < 0.6).astype(np.int32))
    got = refine_bitmap_op(adj, cand, frontier, active,
                           backend="pallas_interpret")
    want = ref.refine_bitmap_ref(adj, cand, frontier, active)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("v,f,np_,seed", [
    (48, 3, 6, 0),      # F < BLOCK_F: one padded row block
    (96, 8, 7, 1),      # F == BLOCK_F exactly
    (200, 21, 9, 2),    # F not a multiple of BLOCK_F
    (520, 40, 12, 3),   # W > 16: multi-word rows, padded lanes
])
def test_refine_bitmap_rows_vs_ref(v, f, np_, seed):
    """Multi-row (8, W_pad) block geometry with per-row candidate and
    active sets (the multi-query wave layout) against the rowwise
    oracle."""
    rng = np.random.default_rng(seed)
    dense = rng.random((v, v)) < 0.2
    dense |= dense.T
    adj = jnp.asarray(pack_bitmap(dense))
    cand_rows = jnp.asarray(pack_bitmap(rng.random((f, v)) < 0.5))
    frontier = jnp.asarray(
        rng.integers(-1, v, size=(f, np_)).astype(np.int32))
    active = jnp.asarray((rng.random((f, np_)) < 0.6).astype(np.int32))
    got = refine_bitmap_rows_op(adj, cand_rows, frontier, active,
                                backend="pallas_interpret")
    want = ref.refine_bitmap_rows_ref(adj, cand_rows, frontier, active)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_refine_bitmap_no_active_positions():
    v = 70
    rng = np.random.default_rng(0)
    adj = jnp.asarray(pack_bitmap(rng.random((v, v)) < 0.3))
    cand = jnp.asarray(pack_bitmap(rng.random((1, v)) < 0.5)[0])
    frontier = jnp.full((3, 4), -1, jnp.int32)
    active = jnp.zeros(4, jnp.int32)
    got = refine_bitmap_op(adj, cand, frontier, active,
                           backend="pallas_interpret")
    np.testing.assert_array_equal(
        np.asarray(got), np.broadcast_to(np.asarray(cand), got.shape))


# -------------------------------------------------------- hier refine
def _random_graph_csr(v, seed, density=0.2):
    """(dense_bool, indptr, indices) of a random symmetric graph."""
    rng = np.random.default_rng(seed)
    dense = rng.random((v, v)) < density
    dense |= dense.T
    indptr = np.concatenate(
        ([0], np.cumsum(dense.sum(axis=1)))).astype(np.int64)
    indices = np.nonzero(dense)[1].astype(np.int64)
    return dense, indptr, indices


@pytest.mark.parametrize("backend", ["jnp", "pallas_interpret"])
@pytest.mark.parametrize("v,f,np_,cw,seed", [
    (48, 6, 5, 1, 0),       # C=1: every chunk is a single word
    (300, 16, 8, 8, 1),     # default chunk width, W=10 > C
    (520, 24, 9, 4, 2),     # multi-word rows, F not a block multiple
    (64, 1, 3, 16, 3),      # C > W: one chunk spans the whole row
])
def test_refine_hier_vs_dense_oracle(backend, v, f, np_, cw, seed):
    """The two-level layout must be *bit-identical* to the dense rowwise
    oracle on the same graph, for both kernel variants (jnp reference
    and the HBM-paged Pallas kernel in interpret mode)."""
    dense, indptr, indices = _random_graph_csr(v, seed)
    hb = build_hier_bitmap(v, indptr, indices, chunk_words=cw)
    adj = jnp.asarray(pack_bitmap(dense))
    rng = np.random.default_rng(seed + 100)
    cand_rows = jnp.asarray(pack_bitmap(rng.random((f, v)) < 0.5))
    frontier = jnp.asarray(
        rng.integers(-1, v, size=(f, np_)).astype(np.int32))
    active = jnp.asarray((rng.random((f, np_)) < 0.6).astype(np.int32))
    got = refine_bitmap_rows_hier_op(
        jnp.asarray(hb.summary), jnp.asarray(hb.chunk_ptr),
        jnp.asarray(hb.chunk_id), jnp.asarray(hb.chunk_data), hb.kmax,
        cand_rows, frontier, active, backend=backend)
    want = ref.refine_bitmap_rows_ref(adj, cand_rows, frontier, active)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dma_depth", [1, 3])
def test_refine_hier_dma_depth_moves_time_not_bits(dma_depth):
    """The DMA pipeline depth is a schedule knob: any depth must return
    the same words as the dense oracle."""
    dense, indptr, indices = _random_graph_csr(200, 7)
    hb = build_hier_bitmap(200, indptr, indices, chunk_words=8)
    adj = jnp.asarray(pack_bitmap(dense))
    rng = np.random.default_rng(8)
    cand_rows = jnp.asarray(pack_bitmap(rng.random((12, 200)) < 0.5))
    frontier = jnp.asarray(
        rng.integers(-1, 200, size=(12, 6)).astype(np.int32))
    active = jnp.asarray((rng.random((12, 6)) < 0.6).astype(np.int32))
    got = refine_bitmap_rows_hier_op(
        jnp.asarray(hb.summary), jnp.asarray(hb.chunk_ptr),
        jnp.asarray(hb.chunk_id), jnp.asarray(hb.chunk_data), hb.kmax,
        cand_rows, frontier, active, backend="pallas_interpret",
        dma_depth=dma_depth)
    want = ref.refine_bitmap_rows_ref(adj, cand_rows, frontier, active)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("cw", [0, 3, 6, 256])
def test_build_hier_bitmap_rejects_bad_chunk_words(cw):
    """Non-power-of-two or out-of-range chunk widths must fail at build
    time (the same constraint tuning/space.py enforces pre-compile)."""
    _, indptr, indices = _random_graph_csr(64, 0)
    with pytest.raises(ValueError, match="power of two"):
        build_hier_bitmap(64, indptr, indices, chunk_words=cw)


# ---------------------------------------------------------------- spmm
@pytest.mark.parametrize("n,m,d,dtype", [
    (40, 64, 16, jnp.float32), (100, 96, 48, jnp.float32),
    (256, 256, 128, jnp.float32), (33, 32, 8, jnp.bfloat16),
])
def test_bitmap_spmm_vs_ref(n, m, d, dtype):
    rng = np.random.default_rng(n + m + d)
    dense = rng.random((n, m)) < 0.15
    words = jnp.asarray(pack_bitmap(dense))
    x = jnp.asarray(rng.standard_normal((m, d)), dtype=dtype)
    got = bitmap_spmm_op(words, x, backend="pallas_interpret",
                         block_i=32, block_j=32)
    want = ref.bitmap_spmm_ref(words, x)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5, atol=1e-5)


def test_bitmap_spmm_matches_dense_matmul():
    rng = np.random.default_rng(9)
    dense = rng.random((50, 64)) < 0.3
    x = rng.standard_normal((64, 20)).astype(np.float32)
    got = bitmap_spmm_op(jnp.asarray(pack_bitmap(dense)), jnp.asarray(x),
                         backend="pallas_interpret", block_i=32, block_j=32)
    np.testing.assert_allclose(np.asarray(got),
                               dense.astype(np.float32) @ x,
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- flash
@pytest.mark.parametrize("b,h,hkv,s,d,causal", [
    (1, 2, 2, 128, 32, True),
    (2, 4, 2, 128, 64, True),    # GQA
    (1, 2, 1, 256, 64, False),
    (1, 8, 2, 128, 128, True),
])
def test_flash_attention_vs_ref(b, h, hkv, s, d, causal):
    rng = np.random.default_rng(b * 100 + h)
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    got = flash_attention_op(q, k, v, causal=causal,
                             backend="pallas_interpret",
                             block_q=64, block_k=64)
    want = flash_attention_op(q, k, v, causal=causal, backend="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), dtype)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), dtype)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), dtype)
    got = flash_attention_op(q, k, v, backend="pallas_interpret",
                             block_q=64, block_k=64)
    want = flash_attention_op(q, k, v, backend="jnp")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_long_kv_decode_shape():
    """Decode regime: 1 query token against a long KV history."""
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((2, 4, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 4, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 4, 512, 64)), jnp.float32)
    got = flash_attention_op(q, k, v, causal=False,
                             backend="pallas_interpret",
                             block_q=128, block_k=128)
    want = flash_attention_op(q, k, v, causal=False, backend="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------- backend config
def test_kernel_backend_rejects_unknown_names():
    """kernels.config must fail fast on unknown backend names — both at
    runtime selection and for the REPRO_KERNEL_BACKEND env var at import
    time (no silent fall-through to a default)."""
    import os
    import subprocess
    import sys

    from repro.kernels import config

    with pytest.raises(ValueError, match="cuda"):
        config.set_backend("cuda")
    with pytest.raises(ValueError, match="tpu"):
        config.resolve("tpu")
    with pytest.raises(ValueError, match="warp"):
        with config.backend_scope("warp_drive"):
            pass                                      # never entered
    assert config.get_backend() in config.BACKENDS    # state unchanged
    # explicit None falls back to the process-wide setting
    assert config.resolve(None) == config.get_backend()
    for name in config.BACKENDS:
        assert config.resolve(name) == name

    # env-var validation happens at import time: exercise it in a
    # subprocess so this process's config module stays untouched
    env = dict(os.environ, REPRO_KERNEL_BACKEND="warp_drive",
               PYTHONPATH=os.pathsep.join(sys.path))
    proc = subprocess.run(
        [sys.executable, "-c", "import repro.kernels.config"],
        env=env, capture_output=True, text=True)
    assert proc.returncode != 0
    assert "warp_drive" in proc.stderr and "jnp" in proc.stderr


def test_backend_scope_saves_and_restores():
    """backend_scope must restore the process-global backend on normal
    exit, on exception, and when nested (the leak-free replacement for
    the importlib.reload cleanup the backend tests used to need)."""
    from repro.kernels import config

    before = config.get_backend()
    with config.backend_scope("pallas_interpret"):
        assert config.get_backend() == "pallas_interpret"
        with config.backend_scope("jnp"):
            assert config.get_backend() == "jnp"
        assert config.get_backend() == "pallas_interpret"
    assert config.get_backend() == before

    with pytest.raises(RuntimeError, match="boom"):
        with config.backend_scope("pallas_interpret"):
            raise RuntimeError("boom")
    assert config.get_backend() == before
