"""Serving layer: mixed multi-query waves must enumerate exactly what the
sequential oracle enumerates per query, budgets must evict cleanly, and
timeout/abort status must be consistent across backends."""
import numpy as np
import pytest

from repro.core.backtrack import backtrack_deadend
from repro.core.vectorized import WaveScheduler
from repro.data.graph_gen import er_labeled_graph, query_set, trap_graph
from repro.serving.query_server import QueryServer


def embset(embs):
    return set(frozenset(enumerate(e.tolist())) for e in embs)


@pytest.fixture(scope="module")
def workload():
    data = er_labeled_graph(35, 100, 3, seed=11)
    queries = query_set(data, 4, 12, seed=5)
    oracle = [backtrack_deadend(q, data, limit=None) for q in queries]
    return data, queries, oracle


def test_batch_matches_oracle_fewer_slots_than_queries(workload):
    """Continuous admission: 12 queries through 4 slots, results exact."""
    data, queries, oracle = workload
    srv = QueryServer(data, backend="engine", limit=None, n_slots=4,
                      wave_size=32, kpr=4)
    results = srv.submit_batch(queries)
    assert [r.query_id for r in results] == list(range(len(queries)))
    for r, ref in zip(results, oracle):
        assert embset(r.embeddings) == embset(ref.embeddings)
        assert r.status == "ok" and not r.timed_out


def test_64_concurrent_queries_share_one_wave_program():
    """≥64 queries resident at once, mixed into shared fixed-shape waves,
    each enumerating exactly the oracle's embedding set."""
    data = er_labeled_graph(30, 80, 2, seed=2)
    queries = query_set(data, 3, 64, seed=9)
    srv = QueryServer(data, backend="engine", limit=None, n_slots=64,
                      wave_size=128, kpr=4)
    results = srv.submit_batch(queries)
    for r, q in zip(results, queries):
        ref = backtrack_deadend(q, data, limit=None)
        assert embset(r.embeddings) == embset(ref.embeddings)
    rep = srv.slo_report()
    assert rep["peak_active"] == 64          # truly concurrent
    # mixed waves: far fewer waves than a per-query serial schedule
    assert rep["waves"] < sum(
        backtrack_deadend(q, data, limit=None).stats.recursions
        for q in queries)
    assert rep["mean_occupancy"] > 0.0


def test_batch_respects_limit(workload):
    data, queries, oracle = workload
    srv = QueryServer(data, backend="engine", limit=3, n_slots=4,
                      wave_size=32, kpr=4)
    results = srv.submit_batch(queries)
    for r, ref in zip(results, oracle):
        full = embset(ref.embeddings)
        assert r.n_found == min(3, len(full))
        assert embset(r.embeddings) <= full
        if len(full) > 3:
            assert r.status == "limit" and r.aborted and not r.timed_out


@pytest.mark.parametrize("backend", ["sequential", "engine"])
def test_timeout_status_consistent_across_backends(backend):
    """A query killed by its recursion budget reports timed_out on both
    backends; a limit-capped query does not."""
    query, data = trap_graph(n_b=30, n_c=30, n_good=2, tail_len=2, seed=0)
    srv = QueryServer(data, backend=backend, limit=1000,
                      max_recursions=20, n_slots=2, wave_size=16, kpr=4)
    r = srv.submit(0, query)
    assert r.timed_out and r.aborted and r.status == "timeout"

    srv2 = QueryServer(data, backend=backend, limit=1, n_slots=2,
                       wave_size=16, kpr=4)
    r2 = srv2.submit(0, query)
    assert r2.n_found == 1
    assert not r2.timed_out and r2.status == "limit"


def test_eviction_does_not_disturb_neighbors(workload):
    """One query aborted mid-flight (tiny recursion budget) must not
    corrupt the other queries sharing its waves."""
    data, queries, oracle = workload
    srv = QueryServer(data, backend="engine", limit=None, n_slots=4,
                      wave_size=32, kpr=4)
    # run the doomed query and the healthy ones in one shared batch
    sched = srv.scheduler
    doomed = sched.submit(queries[0], limit=None, max_rows=1)
    healthy = [sched.submit(q, limit=None) for q in queries]
    sched.run()
    d = sched.finished.pop(doomed)
    assert d.stats.aborted and d.stats.abort_reason == "rows"
    for sqid, ref in zip(healthy, oracle):
        res = sched.finished.pop(sqid)
        assert not res.stats.aborted
        assert embset(res.embeddings) == embset(ref.embeddings)


def test_time_budget_eviction():
    """A wall-clock budget of ~0 must abort with status "timeout" while
    keeping any partial results, on the engine backend."""
    query, data = trap_graph(n_b=40, n_c=40, n_good=2, tail_len=2, seed=1)
    srv = QueryServer(data, backend="engine", limit=None,
                      time_budget_s=0.0, n_slots=2, wave_size=16, kpr=4)
    r = srv.submit(0, query)
    assert r.timed_out and r.status == "timeout"


def test_scheduler_pruning_is_per_slot(workload):
    """Slot-private tables: a learning query next to a non-learning one
    must both stay exact, and only the learner stores patterns."""
    data, queries, oracle = workload
    sched = WaveScheduler(data, n_slots=2, wave_size=32, kpr=4)
    a = sched.submit(queries[0], limit=None, use_pruning=True)
    b = sched.submit(queries[1], limit=None, use_pruning=False)
    sched.run()
    ra, rb = sched.finished.pop(a), sched.finished.pop(b)
    assert embset(ra.embeddings) == embset(oracle[0].embeddings)
    assert embset(rb.embeddings) == embset(oracle[1].embeddings)
    assert rb.stats.patterns_stored == 0 and rb.stats.deadend_prunes == 0


def test_trivial_queries_in_batch(workload):
    """Single-vertex and no-candidate queries flow through the batched
    API without occupying scheduler slots."""
    from repro.core.graph import Graph
    data, queries, oracle = workload
    single = Graph.from_edges(1, [], [int(data.labels[0])], data.n_labels)
    impossible = Graph.from_edges(2, [(0, 1)], [7, 7], 8)
    srv = QueryServer(data, backend="engine", limit=None, n_slots=2,
                      wave_size=32, kpr=4)
    results = srv.submit_batch([single, impossible, queries[0]])
    assert results[0].n_found == int((data.labels == data.labels[0]).sum())
    assert results[1].n_found == 0 and results[1].status == "ok"
    assert embset(results[2].embeddings) == embset(oracle[0].embeddings)
    # limit-capped trivial queries report "limit", same as the oracle
    srv_cap = QueryServer(data, backend="engine", limit=1, n_slots=2,
                          wave_size=32, kpr=4)
    capped = srv_cap.submit(0, single)
    assert capped.n_found == 1 and capped.status == "limit"
    assert not capped.timed_out


def test_parallel_query_alongside_mixed_traffic(workload):
    """A heavy query submitted with parallelism=4 (shard-as-segments)
    next to plain traffic: everyone stays exact, and the heavy query
    reports per-shard rows/items that add up to its total."""
    data, queries, oracle = workload
    heavy, heavy_data = trap_graph(n_b=20, n_c=20, n_good=2, tail_len=2,
                                   seed=0)
    srv = QueryServer(data, backend="engine", limit=None, n_slots=4,
                      wave_size=32, kpr=4)
    results = srv.submit_batch(queries[:4] + [queries[4]],
                               parallelism=[1, 1, 1, 1, 4])
    for r, ref in zip(results, oracle[:5]):
        assert embset(r.embeddings) == embset(ref.embeddings)
    par = results[-1]
    assert par.stats.shard_rows is not None
    assert len(par.stats.shard_rows) == 4
    assert sum(par.stats.shard_rows) == par.stats.rows_created
    assert sum(par.stats.shard_items) > 0
    # scheduler-level steal/occupancy accounting is exposed for reports
    rep = srv.slo_report()
    assert "steals" in rep and "slot_rows_expanded" in rep
    # dedicated heavy-workload server: parallelism on a trap query stays
    # exact too (per-shard Δ sharing inside one slot)
    ref_heavy = backtrack_deadend(heavy, heavy_data, limit=None)
    srv2 = QueryServer(heavy_data, backend="engine", limit=None,
                       n_slots=2, wave_size=32, kpr=4)
    r_heavy = srv2.submit(0, heavy, parallelism=8)
    assert embset(r_heavy.embeddings) == embset(ref_heavy.embeddings)
    # a mis-sized per-query parallelism list must fail fast, not
    # silently drop queries (zip truncation)
    with pytest.raises(ValueError):
        srv.submit_batch(queries[:3], parallelism=[4])


def test_slo_report_has_occupancy(workload):
    data, queries, _ = workload
    srv = QueryServer(data, backend="engine", limit=None, n_slots=4,
                      wave_size=32, kpr=4)
    srv.submit_batch(queries[:6])
    rep = srv.slo_report()
    for key in ("p50_ms", "p99_ms", "mean_occupancy", "steady_occupancy",
                "waves", "peak_active"):
        assert key in rep
    assert 0.0 < rep["mean_occupancy"] <= 1.0


def test_device_stacks_alongside_sharded_parallelism(workload):
    """Shard-as-segments queries (host path, shards 1/2/4) must stay
    exact — with stealing accounted — while single-shard neighbors ride
    the device-resident stacks in the same waves."""
    data, queries, oracle = workload
    for shards in (1, 2, 4):
        srv = QueryServer(data, backend="engine", limit=None, n_slots=4,
                          wave_size=32, kpr=4)
        results = srv.submit_batch(
            queries[:4], parallelism=[1, 1, shards, shards])
        for res, ref in zip(results, oracle[:4]):
            assert embset(res.embeddings) == embset(ref.embeddings)
        rep = srv.slo_report()
        assert rep["steals"] >= 0
        if shards > 1:
            sharded = results[2]
            assert len(sharded.stats.shard_rows) == shards
            assert sum(sharded.stats.shard_rows) == \
                sharded.stats.rows_created
