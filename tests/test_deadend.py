"""Unit + property tests for dead-end mask extraction and the numeric
pattern representation (paper §4.3–4.4)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.backtrack import backtrack_deadend
from repro.core.deadend import (DeadEndStats, NumericDeadEndTable,
                                SetDeadEndTable)
from repro.core.graph import Graph, pack_bitmap, unpack_bitmap
from repro.data.graph_gen import er_labeled_graph, random_walk_query


def test_numeric_store_and_match_roundtrip():
    t = NumericDeadEndTable(6)
    phi = np.array([1, 2, 3, 4, 5, 6, 7], dtype=np.int64)
    mapping = [10, 20, 30, 40]
    # pattern over positions {0, 2, 3}, keyed by last mapping pos 3 -> v=40
    t.store(3, 40, mapping, frozenset({0, 2, 3}), phi)
    # same phi prefix -> match
    assert t.match(3, 40, mapping, phi) == frozenset({0, 2, 3})
    # different prefix id at mu=3 -> no match
    phi2 = phi.copy(); phi2[3] = 99
    assert t.match(3, 40, mapping, phi2) is None
    # changing phi beyond mu does not matter
    phi3 = phi.copy(); phi3[4] = 99
    assert t.match(3, 40, mapping, phi3) == frozenset({0, 2, 3})
    # different key vertex -> no entry
    assert t.match(3, 41, mapping, phi) is None


def test_numeric_mask_only_last_position():
    """mask == {key position} -> mu = 0 -> matches any embedding that maps
    this position to this vertex (prefix-independent pattern)."""
    t = NumericDeadEndTable(4)
    phi = np.array([1, 5, 9, 13, 17], dtype=np.int64)
    t.store(2, 7, [3, 4, 7], frozenset({2}), phi)
    other_phi = np.array([1, 100, 200, 300, 400], dtype=np.int64)
    assert t.match(2, 7, [8, 9], other_phi) == frozenset({2})


def test_set_table_subset_semantics():
    t = SetDeadEndTable(4)
    phi = np.zeros(5, dtype=np.int64)
    t.store(2, 30, [10, 20, 30], frozenset({0, 2}), phi)
    assert t.match(2, 30, [10, 99, 30], phi) == frozenset({0, 2})
    assert t.match(2, 30, [11, 99, 30], phi) is None  # position 0 differs


def test_numeric_never_matches_more_than_set_semantics():
    """Prefix-identity (numeric) implies subset containment (set)."""
    rng = np.random.default_rng(0)
    for _ in range(200):
        n = 6
        mapping_store = rng.integers(0, 50, size=n).tolist()
        phi_store = np.arange(1, n + 2, dtype=np.int64) * 7
        pos = int(rng.integers(1, n))
        mask = frozenset(int(x) for x in
                         rng.choice(pos + 1, size=rng.integers(1, pos + 2),
                                    replace=False))
        num = NumericDeadEndTable(n)
        st_ = SetDeadEndTable(n)
        num.store(pos, mapping_store[pos], mapping_store, mask, phi_store)
        st_.store(pos, mapping_store[pos], mapping_store, mask, phi_store)
        # numeric matches iff the phi prefix is identical; when it is, the
        # stored mapping prefix is also identical -> set table must match
        got = num.match(pos, mapping_store[pos], mapping_store, phi_store)
        if got is not None:
            assert st_.match(pos, mapping_store[pos], mapping_store,
                             phi_store) is not None


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_pruned_equals_unpruned(seed):
    """Property (Theorem 1): for random graphs+queries the pruned search
    reports exactly the unpruned result set."""
    rng = np.random.default_rng(seed)
    n_d = int(rng.integers(10, 32))
    data = er_labeled_graph(n_d, int(rng.integers(n_d, 3 * n_d)),
                            int(rng.integers(1, 4)), seed=seed)
    try:
        query = random_walk_query(data, int(rng.integers(2, 6)),
                                  seed=seed + 1)
    except RuntimeError:
        return
    a = backtrack_deadend(query, data, limit=None)
    b = backtrack_deadend(query, data, limit=None, use_pruning=False)
    ea = set(frozenset(enumerate(e.tolist())) for e in a.embeddings)
    eb = set(frozenset(enumerate(e.tolist())) for e in b.embeddings)
    assert ea == eb
    assert a.stats.recursions <= b.stats.recursions


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_set_vs_numeric_table(seed):
    rng = np.random.default_rng(seed)
    n_d = int(rng.integers(10, 32))
    data = er_labeled_graph(n_d, int(rng.integers(n_d, 3 * n_d)),
                            int(rng.integers(1, 4)), seed=seed)
    try:
        query = random_walk_query(data, int(rng.integers(2, 6)),
                                  seed=seed + 1)
    except RuntimeError:
        return
    a = backtrack_deadend(query, data, limit=None,
                          table_cls=NumericDeadEndTable)
    b = backtrack_deadend(query, data, limit=None,
                          table_cls=SetDeadEndTable)
    ea = set(frozenset(enumerate(e.tolist())) for e in a.embeddings)
    eb = set(frozenset(enumerate(e.tolist())) for e in b.embeddings)
    assert ea == eb
    # NOTE: set-containment matches >= numeric *per check*, but a global
    # recursion-count inequality does NOT hold: earlier pruning changes
    # which patterns get learned downstream (hypothesis found a
    # counterexample). Both must still beat no-pruning's trajectory
    # lower bound: never fewer results, never more recursions than it.
    c = backtrack_deadend(query, data, limit=None, use_pruning=False)
    assert a.stats.recursions <= c.stats.recursions
    assert b.stats.recursions <= c.stats.recursions


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_bitmap_pack_unpack_roundtrip(data):
    r = data.draw(st.integers(1, 8))
    v = data.draw(st.integers(1, 200))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    dense = rng.random((r, v)) < 0.3
    assert (unpack_bitmap(pack_bitmap(dense), v) == dense).all()
