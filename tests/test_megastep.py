"""The fused K-depth megastep must enumerate exactly what the
single-step wave path (and the sequential oracle) enumerates, under
uniform and trap workloads, including limit-aborts that land in the
middle of a megastep.

``adaptive_prune_threshold`` doubles as the test switch: > 1.0 forces
every fresh wave through the fused megastep (the EMA never exceeds 1),
< 0.0 forces the synchronous single-step schedule.
"""
import numpy as np
import pytest

from repro.core.backtrack import backtrack_deadend
from repro.core.vectorized import WaveScheduler
from repro.data.graph_gen import (corridor_graph, er_labeled_graph,
                                  query_set, random_walk_query,
                                  trap_graph)

ALWAYS_DEEP = 2.0
NEVER_DEEP = -1.0


def embset(embs):
    return set(frozenset(enumerate(e.tolist())) for e in embs)


def run_batch(data, queries, *, megastep_depth, threshold, limit=None,
              n_slots=4, wave_size=32, kpr=4):
    sched = WaveScheduler(data, n_slots=n_slots, wave_size=wave_size,
                          kpr=kpr, megastep_depth=megastep_depth,
                          adaptive_prune_threshold=threshold)
    qids = [sched.submit(q, limit=limit) for q in queries]
    sched.run()
    return [sched.finished.pop(qid) for qid in qids]


def test_megastep_matches_oracle_uniform():
    """Forced K=4 megastep vs the sequential oracle on mixed traffic."""
    data = er_labeled_graph(35, 100, 3, seed=11)
    queries = query_set(data, 4, 10, seed=5)
    got = run_batch(data, queries, megastep_depth=4,
                    threshold=ALWAYS_DEEP)
    for res, q in zip(got, queries):
        ref = backtrack_deadend(q, data, limit=None)
        assert embset(res.embeddings) == embset(ref.embeddings)
        assert not res.stats.aborted


def test_megastep_matches_single_step_path():
    """K>1 and K=1 must produce identical embedding sets per query —
    the megastep is a schedule change, never a result change."""
    data = er_labeled_graph(32, 90, 2, seed=3)
    queries = query_set(data, 4, 8, seed=9)
    deep = run_batch(data, queries, megastep_depth=5,
                     threshold=ALWAYS_DEEP)
    single = run_batch(data, queries, megastep_depth=1,
                       threshold=NEVER_DEEP)
    for a, b in zip(deep, single):
        assert embset(a.embeddings) == embset(b.embeddings)
        assert a.stats.found == b.stats.found


def test_megastep_trap_exact_with_inloop_stores():
    """Trap workload under forced deep mode: the in-loop Lemma-1 stores
    and the host Lemma-4 resolution must stay exact together."""
    query, data = trap_graph(n_b=20, n_c=20, n_good=2, tail_len=2, seed=0)
    got = run_batch(data, [query, query], megastep_depth=3,
                    threshold=ALWAYS_DEEP, wave_size=16)
    ref = backtrack_deadend(query, data, limit=None)
    for res in got:
        assert embset(res.embeddings) == embset(ref.embeddings)
        assert res.stats.deadend_prunes > 0      # learning still active
        assert res.stats.patterns_stored > 0


def test_megastep_limit_abort_mid_flight():
    """A limit hit by embeddings found *inside* a megastep must abort
    with exactly ``limit`` results, all of them valid embeddings."""
    data = er_labeled_graph(30, 90, 2, seed=3)
    query = random_walk_query(data, 3, seed=4)
    full = run_batch(data, [query], megastep_depth=4,
                     threshold=ALWAYS_DEEP)[0]
    if full.stats.found <= 5:
        pytest.skip("query too small to exercise the limit")
    lim = run_batch(data, [query], megastep_depth=4,
                    threshold=ALWAYS_DEEP, limit=5)[0]
    assert lim.stats.found == 5
    assert len(lim.embeddings) == 5
    assert lim.stats.aborted and lim.stats.abort_reason == "limit"
    assert embset(lim.embeddings) <= embset(full.embeddings)


def test_megastep_rows_budget_abort():
    """max_rows eviction still works when rows are created K levels at a
    time (the budget may overshoot by at most one megastep)."""
    query, data = trap_graph(n_b=20, n_c=20, n_good=2, tail_len=2, seed=1)
    sched = WaveScheduler(data, n_slots=2, wave_size=16, kpr=4,
                          megastep_depth=4,
                          adaptive_prune_threshold=ALWAYS_DEEP)
    doomed = sched.submit(query, limit=None, max_rows=10)
    sched.run()
    res = sched.finished.pop(doomed)
    assert res.stats.aborted and res.stats.abort_reason == "rows"


def test_megastep_neighbors_survive_eviction():
    """An aborted query mid-megastep must not corrupt queries sharing
    its waves (in-flight rows of the evicted slot are dropped)."""
    data = er_labeled_graph(35, 100, 3, seed=11)
    queries = query_set(data, 4, 6, seed=5)
    sched = WaveScheduler(data, n_slots=4, wave_size=32, kpr=4,
                          megastep_depth=4,
                          adaptive_prune_threshold=ALWAYS_DEEP)
    doomed = sched.submit(queries[0], limit=None, max_rows=1)
    healthy = [sched.submit(q, limit=None) for q in queries]
    sched.run()
    d = sched.finished.pop(doomed)
    assert d.stats.aborted and d.stats.abort_reason == "rows"
    for sqid, q in zip(healthy, queries):
        res = sched.finished.pop(sqid)
        ref = backtrack_deadend(q, data, limit=None)
        assert not res.stats.aborted
        assert embset(res.embeddings) == embset(ref.embeddings)


def test_adaptive_depth_falls_back_on_trap():
    """The prune-rate EMA must keep a failure-dominated workload on the
    tight single-step cadence (pruning effectiveness ~ the single-step
    schedule), while staying exact."""
    query, data = trap_graph(n_b=40, n_c=40, n_good=2, tail_len=2, seed=0)
    sched = WaveScheduler(data, n_slots=1, wave_size=64, kpr=8,
                          megastep_depth=6)     # default adaptivity
    qid = sched.submit(query, limit=None)
    sched.run()
    res = sched.finished.pop(qid)
    ref = backtrack_deadend(query, data, limit=None)
    assert embset(res.embeddings) == embset(ref.embeddings)
    assert sched._prune_ema > sched.adaptive_prune_threshold


# ------------------------------------------------------- device stacks
def test_device_stacks_match_host_path_across_depths():
    """Device-resident stacks vs the host SegmentPool path must
    enumerate identical sets at megastep_depth 1 and 6 (depth 1 routes
    through the single-step host schedule in both modes)."""
    data = er_labeled_graph(35, 100, 3, seed=11)
    queries = query_set(data, 4, 8, seed=5)
    for depth in (1, 6):
        per_mode = {}
        for use_dev in (True, False):
            sched = WaveScheduler(data, n_slots=4, wave_size=32, kpr=4,
                                  megastep_depth=depth,
                                  adaptive_prune_threshold=ALWAYS_DEEP,
                                  device_stacks=use_dev)
            qids = [sched.submit(q, limit=None) for q in queries]
            sched.run()
            per_mode[use_dev] = [sched.finished.pop(qid)
                                 for qid in qids]
        for a, b, q in zip(per_mode[True], per_mode[False], queries):
            ref = backtrack_deadend(q, data, limit=None)
            assert embset(a.embeddings) == embset(ref.embeddings)
            assert embset(b.embeddings) == embset(ref.embeddings)


def test_device_stacks_mid_run_eviction_and_rows_abort():
    """A rows-budget eviction of a device-resident query must clear its
    slot stack without disturbing device neighbors mid-megastep."""
    data = er_labeled_graph(35, 100, 3, seed=11)
    queries = query_set(data, 4, 6, seed=5)
    sched = WaveScheduler(data, n_slots=4, wave_size=32, kpr=4,
                          megastep_depth=4,
                          adaptive_prune_threshold=ALWAYS_DEEP)
    doomed = sched.submit(queries[0], limit=None, max_rows=1)
    healthy = [sched.submit(q, limit=None) for q in queries]
    sched.run()
    d = sched.finished.pop(doomed)
    assert d.stats.aborted and d.stats.abort_reason == "rows"
    for sqid, q in zip(healthy, queries):
        res = sched.finished.pop(sqid)
        ref = backtrack_deadend(q, data, limit=None)
        assert not res.stats.aborted
        assert embset(res.embeddings) == embset(ref.embeddings)


def test_device_stacks_cancellation_mid_run():
    """Cancelling a device-resident query drops its in-flight stack and
    digest rows; a neighbor sharing the waves stays exact."""
    query, data = trap_graph(n_b=30, n_c=30, n_good=2, tail_len=2, seed=0)
    sched = WaveScheduler(data, n_slots=2, wave_size=32, kpr=4,
                          megastep_depth=4,
                          adaptive_prune_threshold=ALWAYS_DEEP)
    victim = sched.submit(query, limit=None)
    keeper = sched.submit(query, limit=None)
    sched.step()
    sched.step()
    if not sched.cancel(victim):
        pytest.skip("query finished before the cancel landed")
    sched.run()
    v = sched.finished.pop(victim)
    assert v.stats.aborted and v.stats.abort_reason == "cancelled"
    k = sched.finished.pop(keeper)
    ref = backtrack_deadend(query, data, limit=None)
    assert embset(k.embeddings) == embset(ref.embeddings)


def test_device_stacks_tiny_capacity_stays_exact():
    """A stack far too small for the workload must throttle (fold-back /
    wedge export), never drop or duplicate rows."""
    data = er_labeled_graph(35, 100, 3, seed=11)
    queries = query_set(data, 4, 6, seed=5)
    sched = WaveScheduler(data, n_slots=2, wave_size=32, kpr=4,
                          megastep_depth=4, stack_capacity=32,
                          adaptive_prune_threshold=ALWAYS_DEEP)
    qids = [sched.submit(q, limit=None) for q in queries]
    sched.run()
    for qid, q in zip(qids, queries):
        res = sched.finished.pop(qid)
        ref = backtrack_deadend(q, data, limit=None)
        assert embset(res.embeddings) == embset(ref.embeddings)


# ------------------------------------------------- hierarchical layout
@pytest.mark.parametrize("depth", [1, 6])
@pytest.mark.parametrize("workload", ["uniform", "trap", "corridor"])
def test_hier_adjacency_matches_oracle(workload, depth):
    """The two-level HBM-paged adjacency layout, forced on via
    MatchOptions.hier_adjacency, must enumerate exactly the sequential
    oracle's embedding sets across all three workload archetypes and
    both megastep depths — the layout is a footprint change, never a
    result change."""
    if workload == "uniform":
        data = er_labeled_graph(35, 100, 3, seed=11)
        queries = query_set(data, 4, 6, seed=5)
    elif workload == "trap":
        query, data = trap_graph(n_b=20, n_c=20, n_good=2, tail_len=2,
                                 seed=0)
        queries = [query, query]
    else:
        query, data = corridor_graph(n_bait=16, n_spines=2)
        queries = [query]
    sched = WaveScheduler(data, n_slots=2, wave_size=32, kpr=4,
                          megastep_depth=depth,
                          adaptive_prune_threshold=ALWAYS_DEEP,
                          hier_adjacency=True)
    assert sched.scheduler_stats()["adjacency_variant"] == "hier-hbm"
    qids = [sched.submit(q, limit=None) for q in queries]
    sched.run()
    for qid, q in zip(qids, queries):
        res = sched.finished.pop(qid)
        want = backtrack_deadend(q, data, limit=None)
        assert embset(res.embeddings) == embset(want.embeddings)


def test_hier_adjacency_matches_dense_layout_bitwise():
    """Dense-VMEM and hier-HBM schedulers on the same traffic: identical
    embedding sets *and* identical per-query found counts (refinement is
    bit-exact, so the whole schedule evolves identically)."""
    data = er_labeled_graph(40, 120, 3, seed=2)
    queries = query_set(data, 4, 8, seed=3)
    legs = {}
    for hier in (False, True):
        sched = WaveScheduler(data, n_slots=4, wave_size=32, kpr=4,
                              megastep_depth=4,
                              adaptive_prune_threshold=ALWAYS_DEEP,
                              hier_adjacency=hier, chunk_words=4)
        qids = [sched.submit(q, limit=None) for q in queries]
        sched.run()
        legs[hier] = [sched.finished.pop(qid) for qid in qids]
    for a, b in zip(legs[False], legs[True]):
        assert embset(a.embeddings) == embset(b.embeddings)
        assert a.stats.found == b.stats.found
