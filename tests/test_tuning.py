"""Autotuner tests (DESIGN.md §9): search-space validity, cache
round-trip + staleness, the resolution precedence (explicit arg >
MatchOptions > tuning cache > built-in default), and the oracle-equality
pin under a deliberately weird tuned configuration."""
import json

import numpy as np
import pytest

from repro.api.options import ENGINE_TUNABLE_DEFAULTS, MatchOptions
from repro.core.backtrack import backtrack_deadend
from repro.core.vectorized import WaveScheduler
from repro.data.graph_gen import (corridor_graph, er_labeled_graph,
                                  random_walk_query, trap_graph)
from repro.kernels import config as kconfig
from repro.tuning import (CandidateConfig, TunableSpace, TuningCache,
                          WorkloadShape, cache_key, device_kind,
                          quantize_vertices, resolve_engine_options,
                          schema_hash)
from repro.tuning.space import PROBE, refine_vmem_bytes


def embset(embeddings):
    return set(frozenset(enumerate(np.asarray(e).tolist()))
               for e in embeddings)


# --------------------------------------------------------- search space
def test_probe_pin_matches_pattern_store():
    """space.PROBE is a literal copy of the store's probe window (kept
    so tuning/ imports without the patterns package) — they must agree
    or the capacity floor stops meaning 'one probe sequence fits'."""
    from repro.patterns.store import PROBE as STORE_PROBE
    assert PROBE == STORE_PROBE


def test_space_rejects_invalid_points_before_compile():
    """Every constraint fires as a reason string from pure shape
    arithmetic — an invalid point is never handed to the engine (the
    enumeration below never imports jax)."""
    shape = WorkloadShape.for_graph(128)
    space = TunableSpace("jnp", shape)
    assert space.validate(CandidateConfig()) is None

    r = space.validate(CandidateConfig(wave_size=48))
    assert r is not None and "power of two" in r
    r = space.validate(CandidateConfig(pattern_capacity=4))
    assert r is not None and "probe window" in r
    r = space.validate(CandidateConfig(stack_capacity=256, wave_size=512))
    assert r is not None and "stack_capacity" in r
    r = space.validate(CandidateConfig(megastep_depth=0))
    assert r is not None and ">= 1" in r

    # hierarchical layout knobs: C must be a power of two in [1, 128]
    # (C=1 is the degenerate-but-legal one-word-chunk layout)
    assert space.validate(CandidateConfig(chunk_words=1)) is None
    r = space.validate(CandidateConfig(chunk_words=3))
    assert r is not None and "chunk_words" in r and "power of two" in r
    r = space.validate(CandidateConfig(chunk_words=256))
    assert r is not None and "chunk_words" in r
    r = space.validate(CandidateConfig(dma_depth=0))
    assert r is not None and ">= 1" in r
    r = space.validate(CandidateConfig(hbm_adjacency=2))
    assert r is not None and "hbm_adjacency" in r

    # block_f tiling: only the compiled pallas backend demands the
    # sublane multiple — interpret and jnp accept odd heights
    odd = CandidateConfig(block_f=12)
    r = TunableSpace("pallas", shape).validate(odd)
    assert r is not None and "sublane" in r
    assert TunableSpace("pallas_interpret", shape).validate(odd) is None
    assert TunableSpace("jnp", shape).validate(odd) is None

    # VMEM budget: a graph whose padded adjacency bitmap alone exceeds
    # the budget rejects every block height with the byte arithmetic
    big = WorkloadShape.for_graph(200_000)
    assert refine_vmem_bytes(big, 8) > TunableSpace(
        "pallas", big).vmem_budget_bytes
    r = TunableSpace("pallas", big).validate(CandidateConfig())
    assert r is not None and "VMEM" in r
    # ... which is exactly the regime the hierarchical layout exists
    # for: the same shape passes when the adjacency stays in HBM and
    # only the paging scratch must fit
    assert TunableSpace("pallas", big).validate(
        CandidateConfig(hbm_adjacency=1)) is None


def test_space_enumeration_partitions_cross_product():
    space = TunableSpace("pallas", WorkloadShape.for_graph(128))
    domains = {"block_f": [4, 8], "megastep_depth": [2, 6],
               "wave_size": [64], "n_slots": [8],
               "stack_capacity": [1024], "pattern_capacity": [4, 1024],
               "store_flush_min": [16], "hbm_adjacency": [0],
               "chunk_words": [8], "dma_depth": [2]}
    valid = space.candidates(overrides=domains)
    assert len(valid) + len(space.rejected) == 2 * 2 * 2
    # block_f=4 (sublane) and pattern_capacity=4 (probe floor) are out
    assert len(valid) == 2
    assert all(c.block_f == 8 and c.pattern_capacity == 1024
               for c in valid)
    with pytest.raises(KeyError, match="warp_factor"):
        space.candidates(overrides={"warp_factor": [1]})


def test_smoke_domains_contain_default_point():
    """The smoke sweep must include the built-in-default point so the
    recorded best is structurally never worse than the defaults."""
    from repro.tuning.autotune import SMOKE_DOMAINS
    d = CandidateConfig(wave_size=64)        # smoke pins the packing
    for k in ("block_f", "megastep_depth", "stack_capacity",
              "pattern_capacity", "store_flush_min"):
        assert getattr(d, k) in SMOKE_DOMAINS[k]


# ---------------------------------------------------------------- cache
def test_cache_roundtrip(tmp_path):
    p = tmp_path / "cache.json"
    params = CandidateConfig(megastep_depth=4, wave_size=128).as_params()
    rec = TuningCache(p).put("jnp", "cpu", 100, params,
                             measured={"qps": 12.5})
    assert rec["name"] == "jnp/cpu/v128"          # |V| quantized up

    fresh = TuningCache(p)                        # re-read from disk
    hit = fresh.lookup("jnp", "cpu", 100)
    assert hit is not None and hit["params"] == params
    assert hit["measured"]["qps"] == 12.5
    assert quantize_vertices(100) == 128
    assert fresh.lookup("jnp", "cpu", 4000) is None      # other bucket
    assert fresh.lookup("pallas", "cpu", 100) is None    # other backend
    assert cache_key("jnp", "cpu", 100) == "jnp/cpu/v128"


def test_cache_schema_hash_invalidates_stale_records(tmp_path):
    p = tmp_path / "cache.json"
    TuningCache(p).put("jnp", "cpu", 128, CandidateConfig().as_params())
    data = json.loads(p.read_text())
    data["records"]["jnp/cpu/v128"]["schema_hash"] = "deadbeef0000"
    p.write_text(json.dumps(data))
    # the record parses fine but was tuned under a different knob
    # schema: the lookup must miss, not resolve moved-meaning knobs
    assert TuningCache(p).lookup("jnp", "cpu", 128) is None
    assert len(schema_hash()) == 12


def test_cache_resets_on_version_or_shape_mismatch(tmp_path):
    p = tmp_path / "cache.json"
    p.write_text(json.dumps({"version": 99, "records": {"x": {}}}))
    assert TuningCache(p).records() == {}
    p.write_text("not json at all")
    assert TuningCache(p).records() == {}


# ----------------------------------------------------------- resolution
def _seed_cache(monkeypatch, tmp_path, n_vertices=512, backend="jnp",
                **param_overrides):
    """Point the default cache at a tmp file holding one record for
    (backend, this process's device kind, n_vertices)."""
    p = tmp_path / "TUNING_CACHE.json"
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(p))
    params = CandidateConfig(**param_overrides).as_params()
    TuningCache(p).put(backend, device_kind(), n_vertices, params)
    return params


def test_resolution_cache_fills_only_unset_knobs(monkeypatch, tmp_path):
    params = _seed_cache(monkeypatch, tmp_path, megastep_depth=4,
                         wave_size=128, block_f=16)
    knobs, rec = resolve_engine_options(MatchOptions(), backend="jnp",
                                        n_vertices=512)
    assert rec["source"] == "tuning-cache"
    assert rec["record"] == cache_key("jnp", device_kind(), 512)
    assert knobs["megastep_depth"] == 4
    assert knobs["wave_size"] == 128
    assert knobs["block_f"] == 16
    assert set(rec["filled_from_cache"]) >= {"megastep_depth",
                                             "wave_size", "block_f"}
    assert rec["params"] == knobs
    del params


def test_resolution_explicit_options_beat_cache(monkeypatch, tmp_path):
    _seed_cache(monkeypatch, tmp_path, megastep_depth=4, wave_size=128)
    opts = MatchOptions(megastep_depth=12, wave_size=256)
    knobs, rec = resolve_engine_options(opts, backend="jnp",
                                        n_vertices=512)
    assert rec["source"] == "tuning-cache"       # record still consulted
    assert knobs["megastep_depth"] == 12         # ...but the user wins
    assert knobs["wave_size"] == 256
    assert "megastep_depth" not in rec["filled_from_cache"]
    assert "wave_size" not in rec["filled_from_cache"]


def test_resolution_scope_override_beats_cache(monkeypatch, tmp_path):
    _seed_cache(monkeypatch, tmp_path, block_f=16)
    with kconfig.kernel_param_scope(block_f=24):
        knobs, _ = resolve_engine_options(MatchOptions(), backend="jnp",
                                          n_vertices=512)
    assert knobs["block_f"] == 24
    assert kconfig.kernel_override("block_f") is None    # scope restored


def test_resolution_builtin_on_miss_or_disable(monkeypatch, tmp_path):
    _seed_cache(monkeypatch, tmp_path, megastep_depth=4, n_vertices=512)
    # different shape bucket: deterministic built-ins
    knobs, rec = resolve_engine_options(MatchOptions(), backend="jnp",
                                        n_vertices=33)
    assert rec["source"] == "builtin" and rec["record"] is None
    assert knobs["megastep_depth"] == \
        ENGINE_TUNABLE_DEFAULTS["megastep_depth"]
    assert knobs["block_f"] == kconfig.DEFAULT_BLOCK_F
    # kill switch: the record exists for this key but is skipped
    monkeypatch.setenv("REPRO_TUNING_DISABLE", "1")
    knobs, rec = resolve_engine_options(MatchOptions(), backend="jnp",
                                        n_vertices=512)
    assert rec["source"] == "builtin"
    assert knobs == {**{k: int(v) for k, v
                        in ENGINE_TUNABLE_DEFAULTS.items()},
                     "block_f": kconfig.DEFAULT_BLOCK_F}


def test_scheduler_consumes_and_surfaces_tuned_record(monkeypatch,
                                                      tmp_path):
    """WaveScheduler construction resolves through the cache and the
    consumed record is visible in scheduler_stats() — the 'tuned record
    visibly consumed' acceptance criterion at unit scale."""
    data = er_labeled_graph(40, 120, 3, seed=6)          # bucket v64
    _seed_cache(monkeypatch, tmp_path, n_vertices=data.n,
                megastep_depth=2, wave_size=32, n_slots=2,
                stack_capacity=256, pattern_capacity=64,
                store_flush_min=8)
    sched = WaveScheduler(data, options=MatchOptions(limit=None))
    assert sched.megastep_depth == 2
    assert sched.wave_size == 32 and sched.n_slots == 2
    assert sched.pattern_capacity == 64
    stats = sched.scheduler_stats()
    assert stats["tuning"]["source"] == "tuning-cache"
    assert stats["tuning"]["record"] == \
        cache_key("jnp", device_kind(), data.n)
    # ...and the tuned schedule still enumerates the oracle set
    q = random_walk_query(data, 4, seed=1)
    qid = sched.submit(q)
    finished = sched.run()
    assert embset(finished[qid].embeddings) == \
        embset(backtrack_deadend(q, data, limit=None).embeddings)


# ------------------------------------------------- weird-config oracle
@pytest.mark.parametrize("case", ["uniform", "trap", "corridor"])
def test_weird_config_matches_oracle(case, monkeypatch):
    """A deliberately awkward tuned point — odd refine block height on
    the interpreted Pallas kernel, shallow megastep, K=3, a pattern
    store squeezed to 16 slots (heavy eviction) — must move time only,
    never results."""
    monkeypatch.setenv("REPRO_TUNING_DISABLE", "1")
    if case == "uniform":
        data = er_labeled_graph(30, 80, 3, seed=2)
        query = random_walk_query(data, 4, seed=3)
    elif case == "trap":
        query, data = trap_graph(n_b=20, n_c=20, n_good=2, tail_len=2,
                                 seed=0)
    else:
        query, data = corridor_graph(n_bait=12, n_spines=2)
    opts = MatchOptions(limit=None, kpr=3, megastep_depth=3,
                        pattern_capacity=16, stack_capacity=256,
                        wave_size=32, n_slots=2, store_flush_min=1)
    with kconfig.backend_scope("pallas_interpret"), \
            kconfig.kernel_param_scope(block_f=5):
        sched = WaveScheduler(data, options=opts)
        assert sched._block_f == 5
        qid = sched.submit(query)
        finished = sched.run()
    want = backtrack_deadend(query, data, limit=None)
    assert embset(finished[qid].embeddings) == embset(want.embeddings)
