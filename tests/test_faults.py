"""Fault-tolerant matching runtime (DESIGN.md §8): deterministic fault
injection, watchdog + retry/backoff, digest validation + quarantine,
host-path fallback, shard-loss recovery, checkpoint validation, typed
timeouts, and overload shedding.

The standing soundness bar for every scenario: an injected fault may
cost work (retries, re-enumeration, host fallback) but never results —
the final embedding set equals the sequential oracle's, and co-resident
queries are bit-identical to a fault-free run.
"""
import pathlib

import numpy as np
import pytest

from repro.api import (MatchError, MatchSession, MatchTimeout,
                       QueueFull)
from repro.core.backtrack import backtrack_deadend
from repro.core.distributed import CheckpointCorrupt, DistributedMatcher
from repro.core.faults import FaultInjected, FaultPlan, FaultSpec
from repro.data.graph_gen import er_labeled_graph, query_set, trap_graph


def embset(embs):
    return set(tuple(np.asarray(e).tolist()) for e in embs)


def sorted_rows(embs):
    return sorted(tuple(np.asarray(e).tolist()) for e in embs)


@pytest.fixture(scope="module")
def workload():
    data = er_labeled_graph(35, 100, 3, seed=11)
    queries = query_set(data, 4, 6, seed=5)
    oracle = [embset(backtrack_deadend(q, data, limit=None).embeddings)
              for q in queries]
    return data, queries, oracle


def run_one(data, q, oracle_set, *, expect_status="ok", **knobs):
    """One query through a fresh engine session; asserts terminal status
    and oracle equality, returns (result, fault counters, session)."""
    s = MatchSession(data, wave_size=64, n_slots=4, **knobs)
    h = s.submit(q, limit=None)
    r = h.result()
    f = s.scheduler.scheduler_stats()["faults"]
    assert r.status == expect_status
    if expect_status == "ok":
        assert embset(r.embeddings) == oracle_set
    return r, f, s


# ----------------------------------------------------------------------
# the fault plan itself
# ----------------------------------------------------------------------
def test_fault_plan_is_deterministic():
    plan = FaultPlan([FaultSpec("dispatch", "exception", at=2, times=2),
                      FaultSpec("flush", "exception", at=1)])
    hits = [plan.poke("dispatch") is not None for _ in range(5)]
    assert hits == [False, True, True, False, False]
    assert plan.poke("flush") is not None
    assert [(s, k, n) for s, k, n, _ in plan.fired] == \
        [("dispatch", "exception", 2), ("dispatch", "exception", 3),
         ("flush", "exception", 1)]
    plan.reset()
    assert plan.peek("dispatch") == 0 and plan.fired == []
    # identical replay after reset: same crossings fire
    assert [plan.poke("dispatch") is not None for _ in range(5)] == hits


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec("nonsense", "exception")
    with pytest.raises(ValueError):
        FaultSpec("dispatch", "shard_loss")     # wrong kind for site
    with pytest.raises(ValueError):
        FaultSpec("dispatch", "exception", at=0)


# ----------------------------------------------------------------------
# tentpole: dispatch retry / watchdog / digest quarantine / fallback
# ----------------------------------------------------------------------
def test_dispatch_exception_is_retried(workload):
    """A failing dispatch re-runs with backoff and the query still
    completes on the device path — no fallback, no lost embeddings."""
    data, queries, oracle = workload
    plan = FaultPlan([FaultSpec("dispatch", "exception", at=2)])
    _, f, _ = run_one(data, queries[0], oracle[0], faults=plan)
    assert f["dispatch_retries"] >= 1
    assert f["fallbacks"] == 0 and f["errors"] == 0


def test_retry_exhaustion_demotes_to_host(workload):
    """times > dispatch_retries exhausts the retry budget; the query is
    quarantined and completes on the host fallback, oracle-equal."""
    data, queries, oracle = workload
    plan = FaultPlan([FaultSpec("dispatch", "exception", at=2, times=5)])
    r, f, _ = run_one(data, queries[0], oracle[0], faults=plan)
    assert f["dispatch_retries"] == 2          # budget fully spent
    assert f["quarantined"] >= 1 and f["fallbacks"] >= 1
    assert r.stats.fallback


def test_hang_fires_watchdog_then_fallback(workload):
    """A hung dispatch retires through the watchdog instead of blocking
    the pipeline; the affected query completes via fallback."""
    data, queries, oracle = workload
    plan = FaultPlan([FaultSpec("dispatch", "hang", at=2)])
    _, f, _ = run_one(data, queries[0], oracle[0], faults=plan)
    assert f["hangs"] >= 1 and f["fallbacks"] >= 1


def test_digest_corruption_is_caught_never_absorbed(workload):
    """A bit-corrupted digest (broken Lemma-4 conservation + negative
    counter) is rejected by the validator — the slot is quarantined and
    re-run, never silently folded into results."""
    data, queries, oracle = workload
    plan = FaultPlan([FaultSpec("digest", "corrupt", at=1)])
    _, f, _ = run_one(data, queries[0], oracle[0], faults=plan)
    assert f["digest_failures"] >= 1
    assert f["quarantined"] >= 1 and f["fallbacks"] >= 1


def test_digest_overflow_is_caught(workload):
    """A forged live count past stack_capacity trips the capacity
    invariant."""
    data, queries, oracle = workload
    plan = FaultPlan([FaultSpec("digest", "overflow", at=1)])
    _, f, _ = run_one(data, queries[0], oracle[0], faults=plan)
    assert f["digest_failures"] >= 1


def test_corrupt_digest_only_hits_target_slot(workload):
    """Quarantine blast radius: with the corruption aimed at slot 0,
    the co-resident query's embedding rows are bit-identical to a
    fault-free run's."""
    data, queries, oracle = workload
    qa, qb = queries[0], queries[1]

    def run(plan):
        s = MatchSession(data, wave_size=64, n_slots=4, faults=plan)
        ha = s.submit(qa, limit=None)
        hb = s.submit(qb, limit=None)
        return ha.result(), hb.result(), s

    ra0, rb0, _ = run(None)                        # fault-free baseline
    plan = FaultPlan([FaultSpec("digest", "corrupt", at=1, slot=0)])
    ra1, rb1, s = run(plan)
    assert s.scheduler.scheduler_stats()["faults"]["digest_failures"] >= 1
    assert ra1.status == "ok" and rb1.status == "ok"
    assert embset(ra1.embeddings) == oracle[0]
    assert sorted_rows(rb1.embeddings) == sorted_rows(rb0.embeddings)


def test_error_status_when_fallback_disabled(workload):
    """fallback_on_failure=False: a quarantined query terminates with
    status='error', a typed MatchError on the handle, and done() that
    never lies."""
    data, queries, oracle = workload
    plan = FaultPlan([FaultSpec("digest", "corrupt", at=1)])
    s = MatchSession(data, wave_size=64, n_slots=4, faults=plan,
                     fallback_on_failure=False)
    h = s.submit(queries[0], limit=None)
    r = h.result()
    assert r.status == "error" and r.aborted
    assert h.done()
    assert isinstance(h.error, MatchError)
    assert "digest validation failed" in str(h.error)
    assert s.scheduler.scheduler_stats()["faults"]["errors"] == 1


def test_admission_fault_errors_the_request(workload):
    data, queries, _ = workload
    plan = FaultPlan([FaultSpec("admission", "exception", at=1)])
    s = MatchSession(data, wave_size=64, n_slots=4, faults=plan)
    h = s.submit(queries[0], limit=None)
    assert h.result().status == "error"
    assert s.scheduler.scheduler_stats()["faults"][
        "admission_failures"] == 1


def test_flush_fault_drops_patterns_soundly():
    """A dropped Δ flush batch loses pruning power only — enumeration
    still matches the oracle exactly (patterns never add results)."""
    q, data = trap_graph(n_b=12, n_c=12, n_good=2, tail_len=2, seed=0)
    oracle = embset(backtrack_deadend(q, data, limit=None).embeddings)
    plan = FaultPlan([FaultSpec("flush", "exception", at=1)])
    s = MatchSession(data, wave_size=64, n_slots=4, megastep_depth=1,
                     device_stacks=False, faults=plan)
    r = s.submit(q, limit=None).result()
    assert r.status == "ok" and embset(r.embeddings) == oracle
    assert s.scheduler.scheduler_stats()["faults"]["flush_drops"] >= 1


def test_host_megastep_path_faults(workload):
    """The same dispatch boundary covers the host megastep pipeline
    (device_stacks=False): exception → retry, hang → watchdog."""
    data, queries, oracle = workload
    knobs = dict(device_stacks=False, adaptive_prune_threshold=1.0)
    plan = FaultPlan([FaultSpec("dispatch", "exception", at=1)])
    _, f, _ = run_one(data, queries[0], oracle[0], faults=plan, **knobs)
    assert f["dispatch_retries"] >= 1
    plan = FaultPlan([FaultSpec("dispatch", "hang", at=1)])
    _, f, _ = run_one(data, queries[0], oracle[0], faults=plan, **knobs)
    assert f["hangs"] >= 1


def test_fault_hooks_are_inert_when_disabled(workload):
    """No FaultPlan: every counter stays zero and results are exact —
    the hooks exist but never fire (zero-cost in the ab_gate sense)."""
    data, queries, oracle = workload
    _, f, _ = run_one(data, queries[0], oracle[0])
    assert all(v == 0 for v in f.values())


# ----------------------------------------------------------------------
# satellites: typed timeout, shedding, checkpoint validation, shard loss
# ----------------------------------------------------------------------
def test_result_timeout_raises_typed_not_blocks(workload):
    data, queries, oracle = workload
    s = MatchSession(data, wave_size=64, n_slots=4)
    h = s.submit(queries[0], limit=None)
    with pytest.raises(MatchTimeout):
        h.result(timeout=0.0)
    assert not h.done()                 # the query is unharmed, not done
    r = h.result()                      # and still completes normally
    assert r.status == "ok" and embset(r.embeddings) == oracle[0]
    assert h.result(timeout=0.0) is r   # completed: returns immediately


def test_overload_shedding_drops_lowest_priority(workload):
    """shed_policy='shed_lowest': a saturated queue sheds the lowest-
    priority requests with status='shed' instead of growing or raising;
    the served queries' results are untouched."""
    data, queries, oracle = workload
    s = MatchSession(data, wave_size=64, n_slots=1, max_queue=2,
                     shed_policy="shed_lowest")
    handles = [s.submit(q, limit=None, priority=i % 3)
               for i, q in enumerate(queries)]
    results = [h.result() for h in handles]
    statuses = [r.status for r in results]
    assert statuses.count("shed") >= 1
    shed_prio = [i % 3 for i, st in enumerate(statuses) if st == "shed"]
    ok_prio = [i % 3 for i, st in enumerate(statuses) if st == "ok"]
    # every shed request had priority <= every served one
    assert max(shed_prio) <= min(ok_prio)
    for i, r in enumerate(results):
        if r.status == "ok":
            assert embset(r.embeddings) == oracle[i]
    f = s.scheduler.scheduler_stats()["faults"]
    assert f["shed"] == statuses.count("shed")
    # the default policy still raises typed backpressure instead
    s2 = MatchSession(data, wave_size=64, n_slots=1, max_queue=1)
    with pytest.raises(QueueFull):
        for q in queries:
            s2.submit(q, limit=None)


def test_server_tallies_shed_and_errors(workload):
    from repro.serving.query_server import QueryServer
    data, queries, _ = workload
    plan = FaultPlan([FaultSpec("admission", "exception", at=1)])
    srv = QueryServer(data, backend="engine", wave_size=64, n_slots=4,
                      faults=plan, fallback_on_failure=False)
    srv.submit_batch(queries[:2])
    rep = srv.slo_report()
    assert rep["errors"] == 1 and rep["shed"] == 0


def test_checkpoint_corrupt_truncated_archive(tmp_path):
    (tmp_path / "state.npz").write_bytes(b"PK\x03\x04 not a real zip")
    with pytest.raises(CheckpointCorrupt, match="unreadable"):
        DistributedMatcher.load_state(str(tmp_path))


def test_checkpoint_corrupt_names_the_bad_field(tmp_path):
    # missing required field
    np.savez_compressed(tmp_path / "state.npz",
                        version=np.int64(3), n_shards=np.int64(2))
    with pytest.raises(CheckpointCorrupt, match="phi_floor"):
        DistributedMatcher.load_state(str(tmp_path))
    # unsupported version
    np.savez_compressed(
        tmp_path / "state.npz", version=np.int64(99),
        n_shards=np.int64(2), phi_floor=np.int64(1),
        pending_roots=np.zeros(0, np.int32),
        embeddings=np.zeros((0, 0), np.int32))
    with pytest.raises(CheckpointCorrupt, match="version"):
        DistributedMatcher.load_state(str(tmp_path))
    # wrong-shape array
    np.savez_compressed(
        tmp_path / "state.npz", version=np.int64(3),
        n_shards=np.int64(2), phi_floor=np.int64(1),
        pending_roots=np.zeros((2, 2), np.int32),
        embeddings=np.zeros((0, 0), np.int32))
    with pytest.raises(CheckpointCorrupt, match="pending_roots"):
        DistributedMatcher.load_state(str(tmp_path))
    # Δ entry arrays with mismatched lengths
    np.savez_compressed(
        tmp_path / "state.npz", version=np.int64(3),
        n_shards=np.int64(2), phi_floor=np.int64(1),
        pending_roots=np.zeros(0, np.int32),
        embeddings=np.zeros((0, 0), np.int32),
        delta_pos=np.zeros(3, np.int32), delta_v=np.zeros(3, np.int32),
        delta_phi=np.zeros(3, np.int32), delta_mu=np.zeros(3, np.int32),
        delta_mask=np.zeros(2, np.uint64),
        delta_hits=np.zeros(3, np.int64))
    with pytest.raises(CheckpointCorrupt, match="delta_mask"):
        DistributedMatcher.load_state(str(tmp_path))


def test_checkpoint_valid_roundtrip_still_loads(tmp_path, workload):
    """The validation pass accepts everything save_state writes."""
    data, queries, oracle = workload
    m = DistributedMatcher(data, n_shards=2, wave_size=64)
    out = m.match(queries[0], limit=None,
                  checkpoint_dir=str(tmp_path))
    assert embset(out.embeddings) == oracle[0]
    ck = DistributedMatcher.load_state(str(tmp_path))
    assert ck is not None and ck.version == 3
    assert len(ck.pending_roots) == 0


def test_shard_loss_recovers_on_survivors(tmp_path, workload):
    """A shard killed mid-run re-seeds its unresolved roots onto the
    3 survivors from the micro-checkpoints; the final embedding set is
    identical to the fault-free 4-shard run."""
    data, queries, oracle = workload
    ref = DistributedMatcher(data, n_shards=4, wave_size=64).match(
        queries[0], limit=None)
    plan = FaultPlan([FaultSpec("shard", "shard_loss", at=2)])
    m = DistributedMatcher(data, n_shards=4, wave_size=64,
                           micro_checkpoint_every=1, faults=plan)
    out = m.match(queries[0], limit=None, checkpoint_dir=str(tmp_path))
    assert m.n_shards == 3                       # one shard gone
    assert len(plan.fired) == 1
    assert embset(out.embeddings) == embset(ref.embeddings) == oracle[0]


def test_checkpoint_save_fault_keeps_previous_snapshot(tmp_path,
                                                       workload):
    """An injected checkpoint-save failure skips that snapshot; the
    match completes and the run is unharmed."""
    data, queries, oracle = workload
    plan = FaultPlan([FaultSpec("checkpoint", "exception", at=1,
                                times=100)])
    m = DistributedMatcher(data, n_shards=2, wave_size=64,
                           micro_checkpoint_every=1, faults=plan)
    out = m.match(queries[0], limit=None, checkpoint_dir=str(tmp_path))
    assert embset(out.embeddings) == oracle[0]
    assert plan.peek("checkpoint") >= 1
    assert not (tmp_path / "state.npz").exists()   # every save skipped
