"""Correctness of the sequential matching algorithms vs a networkx oracle
plus the paper's core claims (pruning soundness, recursion reduction)."""
import numpy as np
import pytest

import networkx as nx
from networkx.algorithms import isomorphism as nxiso

from repro.core.backtrack import backtrack_deadend, backtrack_naive
from repro.core.deadend import NumericDeadEndTable, SetDeadEndTable
from repro.core.graph import Graph
from repro.data.graph_gen import (er_labeled_graph, ba_labeled_graph,
                                  random_walk_query)


def paper_example():
    """Figure 1 of the paper: Q (4 vertices) and G (9 vertices)."""
    # labels: a=0, b=1, c=2
    q = Graph.from_edges(4, [(0, 1), (1, 2), (2, 3)], [0, 1, 2, 0])
    # G: v1..v9 -> 0..8; labels from the figure
    #   v1=a v2=b v3=b v4=b v5=c v6=c v7=c v8=a v9=a (one consistent reading)
    g = Graph.from_edges(
        9,
        [(0, 1), (0, 2), (0, 3),          # v1-b's
         (1, 4), (1, 5), (2, 5), (2, 6), (3, 6),  # b-c edges
         (4, 7), (5, 0), (6, 0),          # c-a edges: v5,v6,v7 adjacency
         (4, 8)],
        [0, 1, 1, 1, 2, 2, 2, 0, 0])
    return q, g


def nx_oracle_embeddings(query: Graph, data: Graph) -> set:
    """All monomorphic embeddings as frozensets of (query_v, data_v)."""
    gq, gd = query.to_networkx(), data.to_networkx()
    matcher = nxiso.GraphMatcher(
        gd, gq, node_match=lambda a, b: a["label"] == b["label"])
    out = set()
    for m in matcher.subgraph_monomorphisms_iter():
        # m maps data vertex -> query vertex
        out.add(frozenset((qv, dv) for dv, qv in m.items()))
    return out


def result_embeddings(res) -> set:
    return set(frozenset(enumerate(e.tolist())) for e in res.embeddings)


def random_case(seed):
    rng = np.random.default_rng(seed)
    n_d = int(rng.integers(8, 40))
    n_e = int(rng.integers(n_d, 4 * n_d))
    n_labels = int(rng.integers(1, 5))
    data = er_labeled_graph(n_d, n_e, n_labels, seed=seed)
    n_q = int(rng.integers(2, 6))
    try:
        query = random_walk_query(data, n_q, seed=seed + 1)
    except RuntimeError:
        return None
    return query, data


@pytest.mark.parametrize("seed", range(30))
def test_naive_matches_networkx(seed):
    case = random_case(seed)
    if case is None:
        pytest.skip("no connected query")
    query, data = case
    res = backtrack_naive(query, data, limit=None)
    assert result_embeddings(res) == nx_oracle_embeddings(query, data)


@pytest.mark.parametrize("seed", range(30))
@pytest.mark.parametrize("table_cls", [NumericDeadEndTable, SetDeadEndTable])
def test_deadend_matches_networkx(seed, table_cls):
    """Theorem 1: the pruned search reports exactly the same embeddings."""
    case = random_case(seed)
    if case is None:
        pytest.skip("no connected query")
    query, data = case
    res = backtrack_deadend(query, data, limit=None, table_cls=table_cls)
    assert result_embeddings(res) == nx_oracle_embeddings(query, data)


@pytest.mark.parametrize("seed", range(10))
def test_deadend_no_pruning_identical(seed):
    case = random_case(seed)
    if case is None:
        pytest.skip("no connected query")
    query, data = case
    a = backtrack_deadend(query, data, limit=None, use_pruning=True)
    b = backtrack_deadend(query, data, limit=None, use_pruning=False)
    assert result_embeddings(a) == result_embeddings(b)
    assert a.stats.recursions <= b.stats.recursions


def test_paper_example_embedding():
    q, g = paper_example()
    res = backtrack_deadend(q, g, limit=None)
    oracle = nx_oracle_embeddings(q, g)
    assert result_embeddings(res) == oracle
    assert res.stats.found == len(oracle)


def test_recursion_reduction_on_hard_instance():
    """The paper's headline effect: pruning turns the Theta(n_b*n_c)
    injectivity-failure blowup into Theta(n_b+n_c) (Fig. 2 mechanism)."""
    from repro.data.graph_gen import trap_graph
    query, data = trap_graph(n_b=60, n_c=60, n_good=2, tail_len=2, seed=0)
    pruned = backtrack_deadend(query, data, limit=None)
    unpruned = backtrack_deadend(query, data, limit=None, use_pruning=False)
    assert pruned.stats.found == unpruned.stats.found  # Theorem 1
    assert result_embeddings(pruned) == result_embeddings(unpruned)
    assert unpruned.stats.recursions > 5 * pruned.stats.recursions
    assert pruned.stats.deadend_prunes > 0


def test_trap_scaling_is_linear_vs_quadratic():
    from repro.data.graph_gen import trap_graph
    rec_p, rec_u = [], []
    for n in (25, 50, 100):
        query, data = trap_graph(n_b=n, n_c=n, n_good=2, tail_len=2, seed=0)
        p = backtrack_deadend(query, data, limit=None)
        u = backtrack_deadend(query, data, limit=None, use_pruning=False)
        rec_p.append(p.stats.recursions)
        rec_u.append(u.stats.recursions)
    # doubling n roughly doubles pruned recursions but ~4x unpruned ones
    assert rec_p[2] < 5 * rec_p[0]
    assert rec_u[2] > 10 * rec_u[0]


def test_limit_semantics():
    data = er_labeled_graph(30, 80, 2, seed=1)
    query = random_walk_query(data, 3, seed=2)
    res_all = backtrack_deadend(query, data, limit=None)
    if res_all.stats.found > 3:
        res3 = backtrack_deadend(query, data, limit=3)
        assert res3.stats.found == 3
        assert res3.stats.aborted
