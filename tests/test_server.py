"""Network serving tier (DESIGN.md §10): wire protocol round-trips,
multi-tenant admission (WFQ / token buckets / bounded-queue shedding),
and end-to-end subprocess tests — streamed embeddings over HTTP must be
bit-identical to the in-process oracle, and a client disconnect must
cancel its query through the eviction path without disturbing
co-resident queries."""
import json
import signal
import subprocess
import sys
import threading
import time
import types
from pathlib import Path

import numpy as np
import pytest

from repro.api.handle import STATUSES
from repro.core.backtrack import backtrack_deadend
from repro.core.graph import Graph
from repro.data.graph_gen import ba_labeled_graph, query_set
from repro.server.admission import (AdmissionController, TenantConfig,
                                    TokenBucket)
from repro.server.client import ServeClient
from repro.server.protocol import (MatchRequestWire, ProtocolError,
                                   decode_event, decode_query,
                                   done_event, encode_event,
                                   encode_query)

ROOT = Path(__file__).resolve().parent.parent


def embset(embs):
    return set(frozenset(enumerate(e.tolist())) for e in embs)


def rowset(rows):
    return set(frozenset(enumerate(r)) for r in rows)


# ======================================================================
# protocol: versioned JSON wire encoding
# ======================================================================
def _tiny_query() -> Graph:
    return Graph.from_edges(3, [(0, 1), (1, 2)], [0, 1, 0], n_labels=2)


def test_query_roundtrip():
    q = _tiny_query()
    d = encode_query(q)
    q2 = decode_query(d)
    assert encode_query(q2) == d


def test_request_roundtrip():
    wire = MatchRequestWire(query=_tiny_query(), tenant="alpha",
                            options={"limit": 10, "priority": 3},
                            request_id="req-7")
    back = MatchRequestWire.from_json(wire.to_json())
    assert back.tenant == "alpha"
    assert back.options == {"limit": 10, "priority": 3}
    assert back.request_id == "req-7"
    assert encode_query(back.query) == encode_query(wire.query)


def test_every_terminal_status_survives_the_wire():
    """``error`` and ``shed`` included: no outcome is expressible
    in-process but not on the wire."""
    assert set(STATUSES) == {"ok", "limit", "timeout", "cancelled",
                             "error", "shed"}
    for st in STATUSES:
        ev = done_event(7, {"status": st, "n_embeddings": 0})
        back = decode_event(encode_event(ev))
        assert back == ev
        assert back["result"]["status"] == st


def test_done_event_rejects_non_terminal_status():
    with pytest.raises(ProtocolError):
        done_event(7, {"status": "running"})


def _valid_request() -> dict:
    return MatchRequestWire(query=_tiny_query()).to_wire()


@pytest.mark.parametrize("mutate", [
    pytest.param(lambda p: p.pop("v"), id="missing-version"),
    pytest.param(lambda p: p.update(v=99), id="wrong-version"),
    pytest.param(lambda p: p.pop("query"), id="missing-query"),
    pytest.param(lambda p: p["query"].update(n=0), id="n-zero"),
    pytest.param(lambda p: p["query"].update(n=65), id="n-too-big"),
    pytest.param(lambda p: p["query"].update(n="3"), id="n-not-int"),
    pytest.param(lambda p: p["query"].update(labels=[0, 1]),
                 id="labels-wrong-length"),
    pytest.param(lambda p: p["query"].update(labels=[0, -1, 0]),
                 id="negative-label"),
    pytest.param(lambda p: p["query"]["edges"].append([2, 2]),
                 id="self-loop"),
    pytest.param(lambda p: p["query"]["edges"].append([0, 3]),
                 id="edge-out-of-range"),
    pytest.param(lambda p: p["query"]["edges"].append([0]),
                 id="edge-not-a-pair"),
    pytest.param(lambda p: p["query"].update(n_labels=1),
                 id="n_labels-below-max-label"),
    pytest.param(lambda p: p.update(options={"wave_size": 9}),
                 id="engine-knob-not-settable"),
    pytest.param(lambda p: p.update(options={"limit": [1]}),
                 id="option-not-a-scalar"),
    pytest.param(lambda p: p.update(tenant=""), id="empty-tenant"),
    pytest.param(lambda p: p.update(tenant=7), id="tenant-not-str"),
    pytest.param(lambda p: p.update(request_id={"a": 1}),
                 id="request_id-not-scalar"),
])
def test_malformed_request_rejected(mutate):
    payload = _valid_request()
    mutate(payload)
    with pytest.raises(ProtocolError):
        MatchRequestWire.from_json(json.dumps(payload))


def test_request_not_json_rejected():
    with pytest.raises(ProtocolError):
        MatchRequestWire.from_json(b"{nope")


@pytest.mark.parametrize("line", [
    pytest.param('{"v": 1, "event": "nope"}', id="unknown-kind"),
    pytest.param('{"event": "done"}', id="event-missing-version"),
    pytest.param('{"v": 1, "event": "chunk", "seq": -1, "rows": []}',
                 id="negative-seq"),
    pytest.param('{"v": 1, "event": "chunk", "seq": 0, "rows": [[1.5]]}',
                 id="non-int-rows"),
    pytest.param('{"v": 1, "event": "done", "result": '
                 '{"status": "running"}}', id="done-non-terminal"),
    pytest.param('{"v": 1, "event": "error", "message": "x"}',
                 id="error-missing-code"),
    pytest.param("{not json", id="not-json"),
])
def test_malformed_event_rejected(line):
    with pytest.raises(ProtocolError):
        decode_event(line)


# ======================================================================
# admission: WFQ, token buckets, bounded-queue shedding
# ======================================================================
def _item(priority=0, name=""):
    return types.SimpleNamespace(priority=priority, name=name)


def test_wfq_shares_interleave_by_weight():
    """Both tenants backlogged: weight-2 alpha gets exactly 2 of every
    3 admissions, and weight-1 beta is never starved — finish tags are
    frozen at enqueue, not re-priced per pop."""
    ctl = AdmissionController({
        "alpha": TenantConfig(weight=2.0),
        "beta": TenantConfig(weight=1.0)})
    for i in range(6):
        ctl.offer(_item(name=f"a{i}"), "alpha")
    for i in range(3):
        ctl.offer(_item(name=f"b{i}"), "beta")
    order = [ctl.next_ready().name[0] for _ in range(9)]
    assert order == ["a", "a", "b"] * 3
    assert ctl.next_ready() is None


def test_token_bucket_rate_and_burst():
    b = TokenBucket(rate=10.0, burst=2.0, now=0.0)
    assert b.take(0.0) and b.take(0.0)        # burst capacity
    assert not b.take(0.0)                    # empty
    assert not b.peek(0.05)                   # half a token refilled
    assert b.peek(0.1) and b.take(0.1)        # one token back at 10/s
    assert not b.take(0.1)
    unlimited = TokenBucket(rate=None, burst=1.0, now=0.0)
    assert all(unlimited.take(0.0) for _ in range(100))


def test_over_rate_tenant_waits_without_blocking_others():
    ctl = AdmissionController({
        "slow": TenantConfig(rate=0.001, burst=1.0),
        "fast": TenantConfig()})
    ctl.offer(_item(name="s0"), "slow")
    ctl.offer(_item(name="s1"), "slow")
    ctl.offer(_item(name="f0"), "fast")
    got = {ctl.next_ready().name, ctl.next_ready().name}
    assert got == {"s0", "f0"}        # slow spent its one token
    assert ctl.next_ready() is None   # s1 gated, not admissible
    assert ctl.snapshot()["slow"]["pending"] == 1


def test_bounded_queue_sheds_lowest_priority():
    shed = []
    ctl = AdmissionController(
        {"t": TenantConfig(max_pending=2)}, on_shed=shed.append)
    ctl.offer(_item(priority=1, name="p1"), "t")
    ctl.offer(_item(priority=2, name="p2"), "t")
    # new arrival is itself the lowest: shed on arrival, offer -> False
    assert ctl.offer(_item(priority=0, name="p0"), "t") is False
    assert [it.name for it in shed] == ["p0"]
    # higher-priority arrival displaces the current lowest
    assert ctl.offer(_item(priority=3, name="p3"), "t") is True
    assert [it.name for it in shed] == ["p0", "p1"]
    assert ctl.snapshot()["t"]["shed"] == 2
    kept = {ctl.next_ready().name, ctl.next_ready().name}
    assert kept == {"p2", "p3"}


def test_requeue_front_counts_backpressure_not_shed():
    ctl = AdmissionController({"t": TenantConfig()})
    ctl.offer(_item(name="x"), "t")
    ctl.offer(_item(name="y"), "t")
    it = ctl.next_ready()
    assert it.name == "x"
    ctl.requeue_front(it, "t")               # engine said QueueFull
    snap = ctl.snapshot()["t"]
    assert snap["backpressure"] == 1
    assert snap["admitted"] == 0
    assert snap["shed"] == 0
    assert ctl.next_ready().name == "x"      # head of the line again
    assert ctl.next_ready().name == "y"


# ======================================================================
# end to end: subprocess server over HTTP
# ======================================================================
GRAPH = dict(n=96, m=3, labels=3, extra=96, seed=3)
SERVER_ARGS = ["--graph", "ba", "--graph-n", "96", "--graph-m", "3",
               "--graph-labels", "3", "--graph-extra-edges", "96",
               "--graph-seed", "3", "--n-slots", "8",
               "--wave-size", "64", "--kpr", "8",
               "--warmup-queries", "2", "--quiet", "--port", "0",
               "--tenants",
               json.dumps({"alpha": {"weight": 2.0},
                           "beta": {"weight": 1.0}})]


@pytest.fixture(scope="module")
def served():
    """One server subprocess for the whole module + the identical graph
    rebuilt in-process for the oracle (build_graph is deterministic in
    (kind, n, seed))."""
    data = ba_labeled_graph(GRAPH["n"], GRAPH["m"], GRAPH["labels"],
                            extra_edges=GRAPH["extra"],
                            seed=GRAPH["seed"])
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.server.launch", *SERVER_ARGS],
        cwd=ROOT, stdout=subprocess.PIPE, stderr=subprocess.DEVNULL,
        text=True)
    info = None
    deadline = time.monotonic() + 600
    try:
        while info is None:
            assert proc.poll() is None, "server died during startup"
            assert time.monotonic() < deadline, "server never ready"
            line = proc.stdout.readline()
            if line.startswith("REPRO_SERVER_READY "):
                info = json.loads(line.split(" ", 1)[1])
        yield data, ServeClient(info["host"], info["port"])
    finally:
        if proc.poll() is None:
            proc.send_signal(signal.SIGTERM)   # graceful drain
            proc.wait(timeout=120)
        proc.stdout.close()
    assert proc.returncode == 0               # drain exits clean


def test_e2e_two_tenant_streams_match_oracle(served):
    """Six queries streamed concurrently across two tenants: every
    stream opens with ``accepted``, chunks carry increasing ``seq``,
    and the chunk-row union is bit-identical to the in-process
    oracle."""
    data, cli = served
    queries = query_set(data, 4, 6, seed=21)
    oracle = [embset(backtrack_deadend(q, data, limit=None).embeddings)
              for q in queries]
    out = [None] * len(queries)

    def drive(i):
        tenant = "alpha" if i % 2 == 0 else "beta"
        rows, seqs, status = [], [], None
        first = None
        for ev in cli.stream(queries[i], tenant=tenant,
                             options={"limit": None}, request_id=i):
            if first is None:
                first = ev["event"]
            if ev["event"] == "chunk":
                seqs.append(ev["seq"])
                rows.extend(ev["rows"])
            elif ev["event"] == "done":
                status = ev["result"]["status"]
                assert ev["result"]["request_id"] == i
                assert ev["result"]["tenant"] == tenant
        out[i] = (first, rows, seqs, status)

    threads = [threading.Thread(target=drive, args=(i,))
               for i in range(len(queries))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    for i, (first, rows, seqs, status) in enumerate(out):
        assert first == "accepted"
        assert status == "ok"
        assert seqs == sorted(seqs)
        assert rowset(rows) == oracle[i], f"query {i} diverged"


def test_e2e_blocking_client_matches_oracle(served):
    data, cli = served
    q = query_set(data, 4, 6, seed=21)[2]
    rows, res = cli.match(q, options={"limit": None})
    ref = backtrack_deadend(q, data, limit=None)
    assert res["status"] == "ok"
    assert embset(rows) == embset(ref.embeddings)


def test_e2e_disconnect_cancels_without_disturbing_residents(served):
    """Drop the connection mid-stream on a heavy query: the server
    must cancel it through the eviction path (client_disconnects and
    ``cancelled`` both observable), and a query running right through
    the eviction window still returns the exact oracle set."""
    data, cli = served
    heavy = query_set(data, 6, 4, seed=33)[0]   # ~0.5s at limit=None
    light = query_set(data, 4, 6, seed=21)[3]
    ref = backtrack_deadend(light, data, limit=None)

    before = cli.metrics()["wire"].get("client_disconnects", 0)
    it = cli.stream(heavy, tenant="alpha", options={"limit": None})
    for ev in it:
        if ev["event"] == "chunk" and ev["rows"]:
            break                    # heavy query is mid-enumeration
        assert ev["event"] != "done", "heavy query finished too fast"
    it.close()                       # drops the TCP connection

    # co-resident with the eviction: exactness must be unaffected
    rows, res = cli.match(light, tenant="beta",
                          options={"limit": None})
    assert res["status"] == "ok"
    assert embset(rows) == embset(ref.embeddings)

    deadline = time.monotonic() + 30
    while True:
        m = cli.metrics()
        slo = cli.slo()
        if (m["wire"].get("client_disconnects", 0) > before
                and slo.get("cancelled", 0) >= 1):
            break
        assert time.monotonic() < deadline, (
            f"no cancellation observed: wire={m['wire']} slo={slo}")
        time.sleep(0.2)


def test_e2e_slo_and_metrics_shape(served):
    _, cli = served
    assert cli.health()["ok"] is True
    slo = cli.slo()
    for k in ("queue_depth", "resident_queries",
              "backpressure_absorbed"):
        assert isinstance(slo[k], int) and slo[k] >= 0
    m = cli.metrics()
    assert set(m["tenants"]) >= {"alpha", "beta"}
    for t in m["tenants"].values():
        assert t["offered"] >= t["admitted"] >= 0
