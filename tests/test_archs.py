"""Per-architecture smoke tests: reduced configs, one forward/train step
on CPU, output shapes + finiteness. Plus physics sanity for the
equivariant family (rotation invariance/covariance)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, get_arch
from repro.models import gnn, recsys, transformer
from repro.models.equivariant import (equiv_energy, equiv_forces,
                                      equiv_init)


LM_ARCHS = [a for a, s in ARCHS.items() if s.family == "lm"]
GNN_ARCHS = [a for a, s in ARCHS.items() if s.family == "gnn"]
EQ_ARCHS = [a for a, s in ARCHS.items() if s.family == "equiv"]


def _lm_batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab, size=(b, s + 1))
    return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "targets": jnp.asarray(toks[:, 1:], jnp.int32)}


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_forward_and_grad(arch):
    cfg = get_arch(arch).smoke_config
    params = transformer.lm_init(jax.random.key(0), cfg)
    batch = _lm_batch(cfg)
    logits = transformer.lm_logits(params, cfg, batch["tokens"])
    assert logits.shape == (2, 16, cfg.vocab)
    loss, grads = jax.value_and_grad(
        lambda p: transformer.lm_loss(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode_matches_prefill(arch):
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = get_arch(arch).smoke_config
    params = transformer.lm_init(jax.random.key(1), cfg)
    toks = _lm_batch(cfg, b=2, s=8, seed=1)["tokens"]
    full = transformer.lm_logits(params, cfg, toks)
    state = transformer.init_decode_state(cfg, batch=2, s_max=16)
    outs = []
    for i in range(8):
        lg, state = transformer.lm_decode_step(
            params, cfg, toks[:, i:i + 1], state)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_full_batch(arch):
    cfg = get_arch(arch).smoke_config
    rng = np.random.default_rng(0)
    n, e = 40, 120
    x = jnp.asarray(rng.standard_normal((n, cfg.d_in)), jnp.float32)
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    ei = jnp.asarray(np.stack([np.concatenate([src, dst]),
                               np.concatenate([dst, src])]), jnp.int32)
    params = gnn.gnn_init(jax.random.key(0), cfg)
    out = gnn.gnn_forward_full(params, cfg, x, ei)
    assert out.shape == (n, cfg.n_classes)
    assert np.isfinite(np.asarray(out)).all()
    labels = jnp.asarray(rng.integers(0, cfg.n_classes, n), jnp.int32)
    loss, grads = jax.value_and_grad(
        lambda p: gnn.gnn_loss(p, cfg, x, ei, labels))(params)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_sampled(arch):
    cfg = get_arch(arch).smoke_config
    rng = np.random.default_rng(1)
    b, f0, f1 = 8, 5, 3
    n1, n2 = b * f0, b * f0 * f1
    feats = [jnp.asarray(rng.standard_normal((m, cfg.d_in)), jnp.float32)
             for m in (b, n1, n2)]
    nbr_idx = [jnp.asarray(rng.integers(0, n1, (b, f0)), jnp.int32),
               jnp.asarray(rng.integers(0, n2, (n1, f1)), jnp.int32)]
    nbr_valid = [jnp.asarray(rng.random((b, f0)) < 0.8),
                 jnp.asarray(rng.random((n1, f1)) < 0.8)]
    # sampled forward needs depth >= n_layers feats; clamp layers to 2
    import dataclasses
    cfg2 = dataclasses.replace(cfg, n_layers=2)
    params = gnn.gnn_init(jax.random.key(0), cfg2)
    out = gnn.gnn_forward_sampled(params, cfg2, feats, nbr_idx, nbr_valid)
    assert out.shape == (b, cfg2.n_classes)
    assert np.isfinite(np.asarray(out)).all()


def _mol_case(cfg, n=12, seed=0):
    rng = np.random.default_rng(seed)
    species = jnp.asarray(rng.integers(0, cfg.n_species, n), jnp.int32)
    pos = jnp.asarray(rng.standard_normal((n, 3)) * 2.0, jnp.float32)
    # all pairs within cutoff as directed edges
    d = np.linalg.norm(np.asarray(pos)[:, None] - np.asarray(pos)[None],
                       axis=-1)
    src, dst = np.nonzero((d < cfg.cutoff) & (d > 0))
    ei = jnp.asarray(np.stack([src, dst]), jnp.int32)
    return species, pos, ei


@pytest.mark.parametrize("arch", EQ_ARCHS)
def test_equiv_smoke_energy_forces(arch):
    cfg = get_arch(arch).smoke_config
    species, pos, ei = _mol_case(cfg)
    params = equiv_init(jax.random.key(0), cfg)
    e, f = equiv_forces(params, cfg, species, pos, ei)
    assert e.shape == ()
    assert f.shape == pos.shape
    assert np.isfinite(float(e)) and np.isfinite(np.asarray(f)).all()


def _rotation(seed=3):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((3, 3))
    q, _ = np.linalg.qr(a)
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    return jnp.asarray(q, jnp.float32)


@pytest.mark.parametrize("arch", EQ_ARCHS)
def test_equiv_rotation_invariance(arch):
    """E(3) property: energy invariant, forces covariant under rotation."""
    cfg = get_arch(arch).smoke_config
    species, pos, ei = _mol_case(cfg, seed=5)
    params = equiv_init(jax.random.key(2), cfg)
    rot = _rotation()
    e1, f1 = equiv_forces(params, cfg, species, pos, ei)
    e2, f2 = equiv_forces(params, cfg, species, pos @ rot.T, ei)
    np.testing.assert_allclose(float(e1), float(e2), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(f1 @ rot.T), np.asarray(f2),
                               rtol=1e-3, atol=1e-4)


def test_din_smoke_train_and_retrieval():
    cfg = get_arch("din").smoke_config
    rng = np.random.default_rng(0)
    b, L = 16, cfg.seq_len
    batch = {
        "target_item": jnp.asarray(rng.integers(0, cfg.n_items, b)),
        "target_cat": jnp.asarray(rng.integers(0, cfg.n_cats, b)),
        "hist_items": jnp.asarray(rng.integers(0, cfg.n_items, (b, L))),
        "hist_cats": jnp.asarray(rng.integers(0, cfg.n_cats, (b, L))),
        "hist_mask": jnp.asarray(rng.random((b, L)) < 0.7, jnp.float32),
        "dense_feats": jnp.asarray(rng.standard_normal(
            (b, cfg.n_dense_feats)), jnp.float32),
        "labels": jnp.asarray(rng.integers(0, 2, b)),
    }
    params = recsys.din_init(jax.random.key(0), cfg)
    logits = recsys.din_forward(params, cfg, batch)
    assert logits.shape == (b,)
    loss, grads = jax.value_and_grad(
        lambda p: recsys.din_loss(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    # retrieval mode: 1 user x N candidates
    user = {"hist_items": batch["hist_items"][0],
            "hist_cats": batch["hist_cats"][0],
            "hist_mask": batch["hist_mask"][0],
            "dense_feats": batch["dense_feats"][0]}
    n_cand = 64
    scores = recsys.din_score_candidates(
        params, cfg, user,
        jnp.asarray(rng.integers(0, cfg.n_items, n_cand)),
        jnp.asarray(rng.integers(0, cfg.n_cats, n_cand)))
    assert scores.shape == (n_cand,)
    # consistency: retrieval scoring == pointwise scoring
    b2 = {k: jnp.broadcast_to(v[None], (n_cand,) + v.shape)
          for k, v in user.items()}
    b2["target_item"] = jnp.asarray(rng.integers(0, cfg.n_items, n_cand))
    b2["target_cat"] = jnp.asarray(rng.integers(0, cfg.n_cats, n_cand))
    want = recsys.din_forward(params, cfg, {**b2,
                                            "hist_items": b2["hist_items"],
                                            "hist_cats": b2["hist_cats"]})
    got = recsys.din_score_candidates(params, cfg, user, b2["target_item"],
                                      b2["target_cat"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_embedding_bag_modes():
    from repro.models.recsys import embedding_bag
    table = jnp.asarray(np.arange(20, dtype=np.float32).reshape(10, 2))
    idx = jnp.asarray([0, 1, 2, 5, 5], jnp.int32)
    seg = jnp.asarray([0, 0, 1, 1, 2], jnp.int32)
    s = embedding_bag(table, idx, seg, 4, mode="sum")
    np.testing.assert_allclose(np.asarray(s[0]), [2.0, 4.0])
    m = embedding_bag(table, idx, seg, 4, mode="mean")
    np.testing.assert_allclose(np.asarray(m[0]), [1.0, 2.0])
    np.testing.assert_allclose(np.asarray(m[3]), [0.0, 0.0])


def test_moe_routing_balance_update():
    from repro.models.moe import (MoEConfig, moe_init, router_load,
                                  update_router_bias)
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, n_shared=1)
    p = moe_init(jax.random.key(0), 32, cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 8, 32)),
                    jnp.float32)
    load = router_load(p, cfg, x)
    assert abs(float(load.sum()) - 1.0) < 1e-5
    p2 = update_router_bias(p, cfg, load)
    assert not np.allclose(np.asarray(p2["router_bias"]),
                           np.asarray(p["router_bias"]))
