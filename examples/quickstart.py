"""Quickstart: match a query graph in a data graph with dead-end pruning.

    PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

from repro.core.backtrack import backtrack_deadend
from repro.core.graph import Graph
from repro.core.vectorized import match_vectorized
from repro.data.graph_gen import trap_graph, yeast_like_graph, random_walk_query


def main():
    # 1. The paper's Fig. 1 example ---------------------------------------
    #    labels: a=0, b=1, c=2; query path a-b-c-a
    query = Graph.from_edges(4, [(0, 1), (1, 2), (2, 3)], [0, 1, 2, 0])
    data = Graph.from_edges(
        7, [(0, 1), (1, 2), (2, 3), (3, 0), (0, 4), (4, 5), (5, 6)],
        [0, 1, 2, 0, 1, 2, 0])
    res = backtrack_deadend(query, data, limit=None)
    print(f"paper-style example: {res.stats.found} embeddings, "
          f"{res.stats.recursions} recursions")
    for e in res.embeddings:
        print("  embedding:", {f"u{i+1}": f"v{v+1}"
                               for i, v in enumerate(e.tolist())})

    # 2. Dead-end pruning at work (quadratic -> linear) --------------------
    q, g = trap_graph(n_b=100, n_c=100, n_good=2, tail_len=2)
    pruned = backtrack_deadend(q, g, limit=None)
    plain = backtrack_deadend(q, g, limit=None, use_pruning=False)
    print(f"\ntrap(100x100): pruned={pruned.stats.recursions} recursions "
          f"vs no-pruning={plain.stats.recursions} "
          f"({plain.stats.recursions / pruned.stats.recursions:.1f}x), "
          f"same {pruned.stats.found} embeddings")

    # 3. The TPU wave engine (same results, vectorized execution) ---------
    eng = match_vectorized(q, g, limit=None, wave_size=256, kpr=16)
    assert eng.stats.found == pruned.stats.found
    print(f"wave engine: {eng.stats.found} embeddings in "
          f"{eng.stats.waves} waves, {eng.stats.rows_created} rows, "
          f"{eng.stats.deadend_prunes} dead-end prunes")

    # 4. A protein-interaction-scale graph --------------------------------
    big = yeast_like_graph(0)
    qq = random_walk_query(big, 12, seed=5)
    r = backtrack_deadend(qq, big, limit=1000)
    print(f"\nyeast-like |V|={big.n}: 12-vertex query -> "
          f"{r.stats.found} embeddings in {r.stats.wall_time_s*1e3:.1f} ms")


if __name__ == "__main__":
    main()
