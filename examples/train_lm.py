"""Train a reduced qwen3-family LM for a few hundred steps with
checkpoint/restart (thin wrapper over the production driver).

    PYTHONPATH=src python examples/train_lm.py
"""
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

from repro.launch.train import main as train_main


def main():
    ckpt = tempfile.mkdtemp(prefix="repro_lm_")
    train_main(["--arch", "qwen3-0.6b", "--scale", "smoke",
                "--steps", "200", "--batch", "8", "--seq", "128",
                "--ckpt-dir", ckpt, "--ckpt-every", "50",
                "--log-every", "20"])
    print(f"checkpoints in {ckpt}")


if __name__ == "__main__":
    main()
