"""Where the paper's engine plugs into the model zoo: subgraph-motif
counting as structural features for a GCN node classifier.

For every vertex, count how many triangle / path-motif embeddings touch
it (computed exactly by the matcher), append these as node features, and
train the gcn-cora smoke config on a synthetic citation-like graph.

    PYTHONPATH=src python examples/motif_features_gnn.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.backtrack import backtrack_deadend
from repro.core.graph import Graph
from repro.data.graph_gen import ba_labeled_graph
from repro.models import gnn
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def motif_counts(data: Graph, motifs: list[Graph]) -> np.ndarray:
    counts = np.zeros((data.n, len(motifs)), np.float32)
    for mi, motif in enumerate(motifs):
        res = backtrack_deadend(motif, data, limit=20000)
        for emb in res.embeddings:
            for v in emb:
                counts[v, mi] += 1.0
    return counts / np.maximum(counts.max(axis=0, keepdims=True), 1.0)


def main():
    data = ba_labeled_graph(200, 3, 3, extra_edges=150, seed=1)
    # motifs over the same label alphabet: triangle and 3-path
    tri = Graph.from_edges(3, [(0, 1), (1, 2), (2, 0)], [0, 0, 0], 3)
    path = Graph.from_edges(3, [(0, 1), (1, 2)], [0, 1, 0], 3)
    feats = motif_counts(data, [tri, path])
    print(f"motif features: {feats.shape}, "
          f"triangles touch {int((feats[:, 0] > 0).sum())} vertices")

    # labels: whether the vertex participates in a triangle (learnable
    # from structure) — train GCN with and without motif features
    labels = jnp.asarray((feats[:, 0] > 0).astype(np.int32))
    deg = np.asarray(data.degrees, np.float32)[:, None]
    base_x = np.concatenate([deg / deg.max(),
                             np.eye(3, dtype=np.float32)[data.labels]], 1)
    ei = np.stack([np.concatenate([data.indices,
                                   np.repeat(np.arange(data.n),
                                             data.degrees)]),
                   np.concatenate([np.repeat(np.arange(data.n),
                                             data.degrees),
                                   data.indices])]).astype(np.int32)
    for name, x in (("plain", base_x),
                    ("plain+motif", np.concatenate([base_x, feats], 1))):
        import dataclasses
        cfg = gnn.GNNConfig(name="demo", kind="gcn", n_layers=2,
                            d_in=x.shape[1], d_hidden=16, n_classes=2)
        params = gnn.gnn_init(jax.random.key(0), cfg)
        ocfg = AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=100)
        opt = adamw_init(params, ocfg)
        xj, eij = jnp.asarray(x), jnp.asarray(ei)

        @jax.jit
        def step(params, opt):
            loss, g = jax.value_and_grad(
                lambda p: gnn.gnn_loss(p, cfg, xj, eij, labels))(params)
            params, opt = adamw_update(params, g, opt, ocfg)
            return params, opt, loss

        for _ in range(100):
            params, opt, loss = step(params, opt)
        pred = gnn.gnn_forward_full(params, cfg, xj, eij).argmax(1)
        acc = float((pred == labels).mean())
        print(f"{name:13s}: final loss {float(loss):.4f} acc {acc:.3f}")


if __name__ == "__main__":
    main()
