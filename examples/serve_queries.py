"""End-to-end driver (the paper's kind of system = a query engine):
serve a batched subgraph-matching workload through the request/handle
API (DESIGN.md §4) — many concurrent queries packed into each device
wave — with SLO + wave-occupancy + TTFE reporting. One heavy trap
query rides the same batch with ``parallelism=8`` (shard-as-segments,
DESIGN.md §3): its root space splits into 8 root segments that share
one slot-private Δ table and steal work from each other, and the run
prints per-shard row/item/steal stats. A streaming demo consumes a
trap query through ``MatchHandle.stream()`` (first embeddings long
before completion) and cancels a second submission mid-flight; a
distributed trap match with full Δ sharing closes the demo.

    PYTHONPATH=src python examples/serve_queries.py [--n-queries 50]

With ``--server host:port`` the same workload is driven through a live
serving-tier process (DESIGN.md §10) instead of an in-process
``QueryServer``: the client reads the resident graph's generator
recipe from ``/healthz``, rebuilds the identical graph locally to
craft valid queries, then streams them over the NDJSON wire:

    PYTHONPATH=src python -m repro.server.launch --port 8421 &
    PYTHONPATH=src python examples/serve_queries.py --server \\
        127.0.0.1:8421 --n-queries 20
"""
import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

_BENCH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_serving.json"


def _baseline_delta(rep: dict, n_served: int, wall_s: float) -> str:
    """One glance-able line comparing this run's qps and latency tails
    against the committed BENCH_serving.json trajectory baseline (the
    configs differ, so deltas are a smoke signal, not a benchmark)."""
    if not _BENCH.exists():
        return "baseline: BENCH_serving.json not found — no delta"
    base = json.loads(_BENCH.read_text())
    qps = n_served / wall_s if wall_s > 0 else 0.0
    dq = 100.0 * (qps / base["queries_per_sec"] - 1.0)
    dp50 = 100.0 * (rep["p50_ms"] / base["p50_ms"] - 1.0)
    dp99 = 100.0 * (rep["p99_ms"] / base["p99_ms"] - 1.0)
    return (f"vs BENCH_serving.json baseline "
            f"({base['queries_per_sec']:.1f} qps, "
            f"p50 {base['p50_ms']:.0f}ms, p99 {base['p99_ms']:.0f}ms): "
            f"qps {dq:+.0f}%  p50 {dp50:+.0f}%  p99 {dp99:+.0f}%")

from repro.core.distributed import DistributedMatcher
from repro.data.graph_gen import query_set, yeast_like_graph, trap_graph
from repro.serving import QueryServer


def run_against_server(target: str, n_queries: int,
                       query_size: int) -> None:
    """Drive the workload through a live serving-tier process over
    HTTP: rebuild the server's resident graph from the generator
    recipe on ``/healthz``, stream one query (TTFE vs completion),
    then run the rest through the blocking client and print the
    server-side SLO gauges."""
    import time

    from repro.server.client import ServeClient
    from repro.server.server_args import ServerArgs

    host, _, port = target.rpartition(":")
    cli = ServeClient(host or "127.0.0.1", int(port))
    health = cli.health()
    gi = health["graph"]
    print(f"server {target}: graph={gi['kind']} |V|={gi['n_vertices']} "
          f"|E|={gi['n_edges']} labels={gi['n_labels']} "
          f"draining={health['draining']}")
    data = ServerArgs(graph=gi["kind"], graph_n=gi["n"],
                      graph_m=gi["m"], graph_labels=gi["labels"],
                      graph_extra_edges=gi["extra_edges"],
                      graph_seed=gi["seed"]).build_graph()
    assert data.n == gi["n_vertices"], "graph recipe mismatch"
    queries = query_set(data, query_size, max(n_queries, 2), seed=42)

    # one streamed query: embeddings arrive while the search is still
    # backtracking, exactly like MatchHandle.stream() in-process
    n_rows = n_chunks = 0
    ttfe = None
    t0 = time.perf_counter()
    for ev in cli.stream(queries[0], tenant="example"):
        if ev["event"] == "chunk" and ev["rows"]:
            if n_chunks == 0:
                ttfe = time.perf_counter() - t0
            n_chunks += 1
            n_rows += len(ev["rows"])
        elif ev["event"] == "done":
            done = ev["result"]
    wall = time.perf_counter() - t0
    print(f"streamed query 0: {n_rows} embeddings over {n_chunks} "
          f"chunks; TTFE {ttfe * 1e3:.0f}ms vs completion "
          f"{wall * 1e3:.0f}ms ({done['status']})")

    t0 = time.perf_counter()
    statuses: dict[str, int] = {}
    found = 0
    for i, q in enumerate(queries[1:], start=1):
        rows, res = cli.match(q, tenant="example", request_id=i)
        statuses[res["status"]] = statuses.get(res["status"], 0) + 1
        found += len(rows)
    wall = time.perf_counter() - t0
    n = len(queries) - 1
    print(f"served {n} blocking queries over the wire: {found} "
          f"embeddings, statuses={statuses} ({n / wall:.1f} qps)")
    slo = cli.slo()
    print(f"server SLO: queue_depth={slo['queue_depth']} "
          f"resident={slo['resident_queries']} "
          f"backpressure_absorbed={slo['backpressure_absorbed']}"
          + (f" p50={slo['p50_ms']:.1f}ms p99={slo['p99_ms']:.1f}ms"
             if "p50_ms" in slo else ""))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-queries", type=int, default=50)
    ap.add_argument("--query-size", type=int, default=10)
    ap.add_argument("--backend", default="engine",
                    choices=["sequential", "engine"])
    ap.add_argument("--server", default=None, metavar="HOST:PORT",
                    help="drive a live repro.server.launch process "
                         "over HTTP instead of the in-process engine")
    # default None, NOT a number: an always-explicit argparse default
    # used to pin every run to n_slots=32/wave_size=256, so the server
    # never resolved the tuned configuration the committed
    # BENCH_serving.json was measured with — the printed baseline delta
    # compared unlike configs. Leave unset to let the server resolve
    # MatchOptions > tuning cache > built-in default (DESIGN.md §9).
    ap.add_argument("--n-slots", type=int, default=None,
                    help="concurrent queries resident per wave (engine); "
                         "default: tuned/built-in resolution")
    ap.add_argument("--wave-size", type=int, default=None,
                    help="rows per device wave; default: tuned/built-in "
                         "resolution")
    args = ap.parse_args()
    if args.server is not None:
        run_against_server(args.server, args.n_queries,
                           args.query_size)
        return
    knobs = {k: v for k, v in (("n_slots", args.n_slots),
                               ("wave_size", args.wave_size))
             if v is not None}

    data = yeast_like_graph(0)
    print(f"data graph: |V|={data.n} |E|={data.n_edges} "
          f"labels={data.n_labels}")
    queries = query_set(data, args.query_size, args.n_queries, seed=42)
    # one heavy query rides the mixed batch as 8 intra-query shards:
    # a short walk query with the widest root-candidate range (the
    # min-candidate matching order keeps typical roots narrow, so pick
    # the fattest search tree worth splitting across shards)
    from repro.core.backtrack import _prepare
    from repro.data.graph_gen import random_walk_query
    heavy = max((random_walk_query(data, 3, seed=s) for s in range(8)),
                key=lambda q: len(_prepare(q, data, None, None)[0][0]))
    heavy_i = len(queries)
    queries = queries + [heavy]
    par = [1] * len(queries)
    par[heavy_i] = 8

    # warm-up: compile the wave programs before taking timed traffic —
    # a cold megastep compile would eat the per-query time budgets
    warm = queries[:min(4, len(queries))] + [heavy]
    QueryServer(data, backend=args.backend, limit=100,
                time_budget_s=60.0, **knobs).submit_batch(
                    warm, parallelism=[1] * (len(warm) - 1) + [8])
    server = QueryServer(data, backend=args.backend, limit=1000,
                         time_budget_s=2.0, **knobs)
    if args.backend == "engine":
        tun = server.scheduler.tuning_record
        print(f"engine config: {tun['source']}"
              f"{' ' + tun['record'] if tun['record'] else ''} -> "
              f"n_slots={server.scheduler.n_slots} "
              f"wave_size={server.scheduler.wave_size} "
              f"megastep_depth={server.scheduler.megastep_depth} "
              f"pattern_capacity={server.scheduler.pattern_capacity}")
    import time
    t0 = time.perf_counter()
    results = server.submit_batch(queries, parallelism=par)
    wall = time.perf_counter() - t0
    found = sum(r.n_found for r in results)
    dnf = sum(r.timed_out for r in results)
    capped = sum(r.status == "limit" for r in results)
    print(f"served {len(results)} queries: {found} embeddings total, "
          f"{capped} hit the limit, {dnf} timed out "
          f"({len(results) / wall:.1f} qps)")
    rep = server.slo_report()
    line = (f"SLO: p50={rep['p50_ms']:.1f}ms p99={rep['p99_ms']:.1f}ms "
            f"mean={rep['mean_ms']:.1f}ms")
    if args.backend == "engine":
        line += (f" | waves={rep['waves']} "
                 f"megastep_depth={rep['megastep_depth']} "
                 f"occupancy={rep['mean_occupancy']:.2f} "
                 f"(steady {rep['steady_occupancy']:.2f}) "
                 f"peak_concurrent={rep['peak_active']} "
                 f"prune_rate={rep['prune_rate']:.2f}")
    print(line)
    if args.backend == "engine":
        hs = results[heavy_i].stats
        total = max(1, hs.rows_created)
        occ = [f"{r / total:.0%}" for r in (hs.shard_rows or [])]
        print(f"heavy query #{heavy_i} (parallelism=8): "
              f"{hs.rows_created} rows, {hs.steals} steals | per-shard "
              f"rows {hs.shard_rows} (occupancy {occ}) "
              f"items {hs.shard_items}")
    print(_baseline_delta(rep, len(results), wall))

    # streaming + cancellation (request/handle API, DESIGN.md §4): the
    # trap query keeps emitting embeddings while its dead-end subtrees
    # are still resolving, so the first streamed batch lands well
    # before retirement; a second submission is cancelled mid-flight
    # without touching its neighbors.
    tq, tg = trap_graph(n_b=60, n_c=60, n_good=2, tail_len=2)
    sserver = QueryServer(tg, backend="engine", limit=None, n_slots=4,
                          wave_size=128, kpr=8)
    handle = sserver.submit_async(tq, limit=None)
    n_rows = n_batches = 0
    for batch in handle.stream():           # [k, n_query] int32 batches
        n_rows += len(batch)
        n_batches += 1
    res = handle.result()
    print(f"\nstreamed trap query: {n_rows} embeddings over "
          f"{n_batches} batches; TTFE {res.ttfe_s * 1e3:.0f}ms vs "
          f"completion {res.latency_s * 1e3:.0f}ms ({res.status})")
    doomed = sserver.submit_async(tq, limit=None)
    for batch in doomed.stream():
        doomed.cancel()                     # evict after the first batch
    dres = doomed.result()
    print(f"cancelled mid-flight: status={dres.status}, kept "
          f"{dres.n_found} partial embeddings")

    # distributed matching of one hard query: shard-as-segments with
    # full Δ sharing (every mu learned by one shard prunes the others)
    q, g = trap_graph(n_b=120, n_c=120, n_good=2, tail_len=2)
    dm = DistributedMatcher(g, n_shards=4, wave_size=128, kpr=8)
    res = dm.match(q, limit=None)
    print(f"\ndistributed trap(120): {res.stats.found} embeddings, "
          f"{res.stats.recursions} rows across 4 shards, "
          f"{res.stats.deadend_prunes} prunes (full Δ shared), "
          f"{res.stats.steals} steals, per-shard rows "
          f"{res.stats.shard_rows}")


if __name__ == "__main__":
    main()
