"""Deterministic, resumable synthetic token pipeline.

Emits document-structured token streams (Zipf unigrams + per-document
'topic' shift + EOS boundaries) packed into fixed [batch, seq] blocks.
State = (seed, step) — resuming a restarted job at step k reproduces the
exact batch sequence (the property the fault-tolerance test asserts).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class LMStreamConfig:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    eos: int = 0
    mean_doc_len: int = 256


class TokenStream:
    def __init__(self, cfg: LMStreamConfig, step: int = 0):
        self.cfg = cfg
        self.step = step

    def state(self) -> dict:
        return {"seed": self.cfg.seed, "step": self.step}

    @staticmethod
    def from_state(cfg: LMStreamConfig, state: dict) -> "TokenStream":
        return TokenStream(cfg, step=int(state["step"]))

    def next_batch(self) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, self.step]))
        self.step += 1
        n = cfg.batch * (cfg.seq_len + 1)
        # zipf body with per-doc topic offsets
        toks = rng.zipf(1.3, size=2 * n).astype(np.int64)
        toks = toks[toks < cfg.vocab - 1][:n] + 1
        while len(toks) < n:
            extra = rng.zipf(1.3, size=n).astype(np.int64)
            extra = extra[extra < cfg.vocab - 1] + 1
            toks = np.concatenate([toks, extra])[:n]
        # sprinkle EOS at ~1/mean_doc_len rate
        eos_mask = rng.random(n) < 1.0 / cfg.mean_doc_len
        toks[eos_mask] = cfg.eos
        block = toks.reshape(cfg.batch, cfg.seq_len + 1).astype(np.int32)
        return {"tokens": block[:, :-1], "targets": block[:, 1:]}


def din_synthetic_batch(cfg, batch: int, seed: int = 0, step: int = 0):
    """Synthetic DIN batch with popularity-skewed items and correlated
    histories (items near the target id are more likely)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    L = cfg.seq_len
    target = (rng.pareto(1.2, batch) * 1000).astype(np.int64) % cfg.n_items
    drift = rng.integers(-5000, 5000, size=(batch, L))
    hist = (target[:, None] + drift) % cfg.n_items
    mask = (rng.random((batch, L)) < 0.8).astype(np.float32)
    labels = (rng.random(batch) < 0.35).astype(np.int32)
    return {
        "target_item": target.astype(np.int32),
        "target_cat": (target % cfg.n_cats).astype(np.int32),
        "hist_items": hist.astype(np.int32),
        "hist_cats": (hist % cfg.n_cats).astype(np.int32),
        "hist_mask": mask,
        "dense_feats": rng.standard_normal(
            (batch, cfg.n_dense_feats)).astype(np.float32),
        "labels": labels,
    }
