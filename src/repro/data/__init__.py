from .graph_gen import (ba_labeled_graph, er_labeled_graph,
                        human_like_graph, random_walk_query, yeast_like_graph)

__all__ = ["ba_labeled_graph", "er_labeled_graph", "human_like_graph",
           "random_walk_query", "yeast_like_graph"]
