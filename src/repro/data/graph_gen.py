"""Synthetic graph datasets and query workloads.

The paper evaluates on the yeast (3112 V / 12519 E / 71 labels) and human
(4674 V / 86282 E / 44 labels) protein-interaction graphs, with query
graphs extracted as random-walk connected subgraphs and query sets of many
queries per size. Those datasets are not redistributable offline, so we
generate synthetic graphs with matched vertex/edge/label statistics and a
heavy-tailed degree profile (preferential attachment + extra random
edges), plus the paper's exact query-extraction protocol.
"""
from __future__ import annotations

import numpy as np

from ..core.graph import Graph


def _zipf_labels(rng: np.random.Generator, n: int, n_labels: int,
                 s: float = 1.1) -> np.ndarray:
    """Zipf-ish label distribution — a few frequent labels, a long tail,
    which is what makes label filters weak and the paper's pruning shine."""
    w = 1.0 / np.arange(1, n_labels + 1) ** s
    w /= w.sum()
    labels = rng.choice(n_labels, size=n, p=w)
    # guarantee every label appears at least once (keeps |Sigma| honest)
    labels[:n_labels] = np.arange(n_labels)
    return labels.astype(np.int32)


def ba_labeled_graph(n: int, m_attach: int, n_labels: int,
                     extra_edges: int = 0, seed: int = 0) -> Graph:
    """Barabasi-Albert preferential attachment + optional random edges."""
    rng = np.random.default_rng(seed)
    edges: list[tuple[int, int]] = []
    targets = list(range(min(m_attach, n)))
    repeated: list[int] = list(targets)
    for v in range(m_attach, n):
        chosen = rng.choice(repeated, size=min(m_attach, len(repeated)),
                            replace=False)
        for t in set(int(c) for c in chosen):
            edges.append((v, t))
            repeated.append(t)
        repeated.extend([v] * m_attach)
    for _ in range(extra_edges):
        a, b = rng.integers(0, n, size=2)
        if a != b:
            edges.append((int(a), int(b)))
    labels = _zipf_labels(rng, n, n_labels)
    return Graph.from_edges(n, edges, labels, n_labels)


def powerlaw_graph(n: int, m_attach: int = 3, n_labels: int = 16,
                   seed: int = 0, degree_sorted: bool = True) -> Graph:
    """BA-style labeled power-law graph, vectorized for large ``n``.

    ``ba_labeled_graph`` keeps a growing Python list of repeated
    endpoints and draws with ``rng.choice`` over it per vertex — fine at
    512 vertices, minutes at 64K. Here the endpoint pool is a
    preallocated array (each vertex appends at most ``2 * m_attach``
    entries) and each step draws ``m_attach`` uniform *indices* into the
    filled prefix, which is exactly degree-proportional sampling; the
    per-vertex work is a handful of O(m) numpy ops, so 64K vertices
    build in seconds.

    ``degree_sorted=True`` relabels the result in degree-descending
    order — the locality transform the hierarchical adjacency layout
    (core.graph.HierBitmap) wants: hubs take the low vertex ids, so
    every row's neighbor bits concentrate in the low chunks, stored
    chunk counts stay small and the summary intersection kills more of
    the chunk walk.
    """
    rng = np.random.default_rng(seed)
    if n <= 1:
        return Graph.from_edges(n, [], _zipf_labels(rng, max(n, 1),
                                                    n_labels)[:n], n_labels)
    m = int(max(1, min(m_attach, n - 1)))
    if n <= m + 1:                     # degenerate tiny graph: clique
        edges = [(a, b) for a in range(n) for b in range(a)]
        return Graph.from_edges(n, edges, _zipf_labels(rng, n, n_labels),
                                n_labels)
    src = np.empty(m * n, np.int64)
    dst = np.empty(m * n, np.int64)
    pool = np.empty(2 * m * n, np.int64)
    ne = ps = 0
    # seed: vertex m attaches to every earlier vertex once
    src[:m] = m
    dst[:m] = np.arange(m)
    pool[:m] = m
    pool[m:2 * m] = np.arange(m)
    ne = m
    ps = 2 * m
    for v in range(m + 1, n):
        targets = np.unique(pool[rng.integers(0, ps, size=m)])
        k = targets.size
        src[ne:ne + k] = v
        dst[ne:ne + k] = targets
        ne += k
        pool[ps:ps + k] = targets
        pool[ps + k:ps + k + m] = v
        ps += k + m
    edges = list(zip(src[:ne].tolist(), dst[:ne].tolist()))
    labels = _zipf_labels(rng, n, n_labels)
    g = Graph.from_edges(n, edges, labels, n_labels)
    if degree_sorted:
        from ..core.graph import degree_descending_order
        g = g.relabel(degree_descending_order(g))
    return g


def er_labeled_graph(n: int, n_edges: int, n_labels: int,
                     seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    edges = set()
    while len(edges) < n_edges:
        a, b = rng.integers(0, n, size=2)
        if a != b:
            edges.add((min(int(a), int(b)), max(int(a), int(b))))
    labels = _zipf_labels(rng, n, n_labels)
    return Graph.from_edges(n, list(edges), labels, n_labels)


def yeast_like_graph(seed: int = 0) -> Graph:
    """|V|=3112, |E|~12519, 71 labels — matches the paper's yeast stats."""
    n, target_e, n_labels = 3112, 12519, 71
    g = ba_labeled_graph(n, 3, n_labels,
                         extra_edges=max(0, target_e - 3 * n), seed=seed)
    return g


def human_like_graph(seed: int = 0) -> Graph:
    """|V|=4674, |E|~86282, 44 labels — matches the paper's human stats.

    Much denser (avg degree ~37): the regime where structural filters are
    weak and search-failure learning matters most.
    """
    n, target_e, n_labels = 4674, 86282, 44
    m = 9  # ~ BA backbone
    g = ba_labeled_graph(n, m, n_labels,
                         extra_edges=max(0, target_e - m * n), seed=seed)
    return g


def random_walk_query(data: Graph, n_vertices: int,
                      seed: int = 0, max_tries: int = 200) -> Graph:
    """Extract a connected query subgraph by random walk (paper §5).

    Walks the data graph collecting vertices until ``n_vertices`` distinct
    ones are visited, then takes the *induced* subgraph on them (so the
    query always has at least ``n_vertices - 1`` edges and realistic label
    correlations). Vertex labels are inherited.
    """
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        start = int(rng.integers(0, data.n))
        visited: list[int] = [start]
        vset = {start}
        cur = start
        steps = 0
        while len(vset) < n_vertices and steps < 50 * n_vertices:
            nbrs = data.neighbors(cur)
            steps += 1
            if len(nbrs) == 0:
                break
            cur = int(nbrs[rng.integers(0, len(nbrs))])
            if cur not in vset:
                vset.add(cur)
                visited.append(cur)
        if len(vset) == n_vertices:
            verts = sorted(vset)
            remap = {v: i for i, v in enumerate(verts)}
            edges = [(remap[a], remap[int(b)]) for a in verts
                     for b in data.neighbors(a) if int(b) in vset and a < b]
            labels = [int(data.labels[v]) for v in verts]
            return Graph.from_edges(n_vertices, edges, labels, data.n_labels)
    raise RuntimeError("could not extract a connected query")


def query_set(data: Graph, n_vertices: int, n_queries: int,
              seed: int = 0) -> list[Graph]:
    return [random_walk_query(data, n_vertices, seed=seed * 100003 + i)
            for i in range(n_queries)]


def trap_graph(n_b: int = 30, n_c: int = 30, n_good: int = 2,
               tail_len: int = 2, seed: int = 0
               ) -> tuple[Graph, Graph]:
    """Scaled version of the paper's Fig. 1 hard case.

    Query: path  a - b - c - a - (tail of d's...), labels a,b,c,a,d,d,...
    Data:  one hub 'a' vertex v0 (which also carries a d-tail, so it stays
    arc-consistent as a candidate for the *second* 'a'); ``n_b`` 'b'
    vertices all adjacent to v0; each 'b' adjacent to all ``n_c`` 'c'
    vertices. Every 'c' has an 'a' neighbor: for the ``n_good`` good ones
    it is a fresh 'a' vertex with its own d-tail; for the bad ones it is
    *v0 itself* (the paper's v6/v7 situation).

    A partial embedding u1->v0, u2->b_i, u3->bad c_j then fails only at
    the injectivity check (u4 would reuse v0) — a failure invisible to
    label/degree/neighbor-label filters AND to arc-consistency, repeated
    ``n_b x n_c`` times by plain backtracking but learned once per c_j by
    dead-end pruning as the pattern {(u1,v0),(u3,c_j)} (exactly the
    paper's {(u1,v1),(u3,v6)} example). Expected recursions:
    Theta(n_b * n_c) without pruning vs Theta(n_b + n_c) with pruning.

    Returns (query, data).
    """
    # labels: a=0, b=1, c=2, d=3
    q_edges = [(0, 1), (1, 2), (2, 3)]
    q_labels = [0, 1, 2, 0]
    for t in range(tail_len):
        q_edges.append((3 + t, 4 + t))
        q_labels.append(3)
    query = Graph.from_edges(4 + tail_len, q_edges, q_labels, 4)

    rng = np.random.default_rng(seed)
    edges: list[tuple[int, int]] = []
    labels: list[int] = [0]                      # v0: the hub 'a'
    b_ids = list(range(1, 1 + n_b))
    labels += [1] * n_b
    c_ids = list(range(1 + n_b, 1 + n_b + n_c))
    labels += [2] * n_c
    nxt = 1 + n_b + n_c

    def add_tail(root: int) -> None:
        nonlocal nxt
        prev = root
        for _ in range(tail_len):
            d = nxt; nxt += 1
            labels.append(3)
            edges.append((prev, d))
            prev = d

    for b in b_ids:
        edges.append((0, b))
        for c in c_ids:
            edges.append((b, c))
    good = set(int(g) for g in rng.choice(n_c, size=n_good, replace=False))
    for ci, c in enumerate(c_ids):
        if ci in good:
            a2 = nxt; nxt += 1
            labels.append(0)
            edges.append((c, a2))
            add_tail(a2)
        else:
            edges.append((c, 0))      # bad c: its only 'a' neighbor is v0
    add_tail(0)                       # keep v0 arc-consistent for u4
    data = Graph.from_edges(nxt, edges, labels, 4)
    return query, data


def corridor_graph(n_bait: int = 64, n_spines: int = 2, seed: int = 0
                   ) -> tuple[Graph, Graph]:
    """Repeated-template workload: prefix-independent dead-end corridors.

    Query: a 7-vertex path with distinct labels 0-1-2-3-4-5-6.
    Data: one root r (label 0) on a real spine r-s1-...-s6 (labels 1..6),
    plus ``n_bait`` *bait corridors*: chains b1-b2-b3-b4-b5 (labels 1..5)
    with b1 attached to r and the chain cut before label 6. Every bait
    passes the label/degree/NLF filters and survives the bounded
    CFL-lite refinement (the emptiness needs 4 propagation hops, one
    more than its round budget), so the search must discover each
    corridor's death by descending into it — and the failure depends
    *only* on (position 1, b1): the learned Lemma-1 patterns all have
    μ == 0.

    That makes this the showcase for cross-query pattern reuse: within
    one run each bait is entered exactly once (learning can't help —
    there is a single root), so the cold prune rate is ~0, while a
    warm-started rerun of the same template prunes all ``n_bait`` baits
    at the first extraction. ``trap_graph`` is the opposite pin: all its
    patterns are μ == 1 and intra-query learning is what matters.
    ``n_spines`` (>= 2) real spines carry the true embeddings.

    Returns (query, data).
    """
    del seed                          # deterministic by construction
    n = 7
    q_edges = [(i, i + 1) for i in range(n - 1)]
    query = Graph.from_edges(n, q_edges, list(range(n)), n)

    edges: list[tuple[int, int]] = []
    labels: list[int] = [0]           # vertex 0: the root r
    nxt = 1
    # >= 2 real spines keep every non-root candidate set larger than
    # C[u0] = {r}, so the rarity-first ordering starts at the root and
    # walks the path — the schedule that actually enters the corridors
    for _ in range(max(2, n_spines)):     # real spines s1..s6
        spine_prev = 0
        for lab in range(1, 7):
            edges.append((spine_prev, nxt))
            labels.append(lab)
            spine_prev = nxt
            nxt += 1
    for _ in range(n_bait):           # bait corridors b1..b5
        prev = 0
        for lab in range(1, 6):
            edges.append((prev, nxt))
            labels.append(lab)
            prev = nxt
            nxt += 1
    data = Graph.from_edges(nxt, edges, labels, n)
    return query, data
