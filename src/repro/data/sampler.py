"""Fanout-bounded neighbor sampler (GraphSAGE-style) over CSR graphs.

Produces the fixed-shape block structure ``models.gnn.gnn_forward_sampled``
consumes: per hop, [N_k, fanout] neighbor indices into the next level's
feature rows plus a validity mask. Pure numpy — runs on the host input
pipeline, overlapped with device steps by the trainer.
"""
from __future__ import annotations

import numpy as np


class NeighborSampler:
    def __init__(self, indptr: np.ndarray, indices: np.ndarray,
                 fanouts: tuple[int, ...], seed: int = 0):
        self.indptr = indptr
        self.indices = indices
        self.fanouts = fanouts
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray):
        """Returns (node_levels, nbr_idx, nbr_valid):
        node_levels[k] — node ids at hop k (level 0 = seeds);
        nbr_idx[k]     — [len(level_k), fanout_k] indices into level k+1;
        nbr_valid[k]   — bool same shape.
        """
        levels = [np.asarray(seeds, np.int64)]
        nbr_idx, nbr_valid = [], []
        for fanout in self.fanouts:
            cur = levels[-1]
            deg = self.indptr[cur + 1] - self.indptr[cur]
            idx = np.zeros((len(cur), fanout), np.int64)
            valid = np.zeros((len(cur), fanout), bool)
            next_nodes = []
            for i, v in enumerate(cur):
                d = deg[i]
                if d == 0:
                    continue
                take = min(fanout, d)
                chosen = self.rng.choice(d, size=take, replace=d < fanout)
                nbrs = self.indices[self.indptr[v]:self.indptr[v + 1]][
                    chosen]
                idx[i, :take] = np.arange(len(next_nodes),
                                          len(next_nodes) + take)
                valid[i, :take] = True
                next_nodes.extend(nbrs.tolist())
            levels.append(np.asarray(next_nodes, np.int64))
            nbr_idx.append(idx.astype(np.int32))
            nbr_valid.append(valid)
        return levels, nbr_idx, nbr_valid

    def sample_padded(self, seeds: np.ndarray, feats: np.ndarray):
        """Fixed-shape variant: every level is padded to
        len(seeds) * prod(fanouts[:k]) rows (what the jitted step wants).
        Returns (feat_levels, nbr_idx, nbr_valid)."""
        levels, nbr_idx, nbr_valid = self.sample(seeds)
        out_feats = []
        sizes = [len(seeds)]
        for f in self.fanouts:
            sizes.append(sizes[-1] * f)
        for k, nodes in enumerate(levels):
            fl = np.zeros((sizes[k], feats.shape[1]), feats.dtype)
            fl[:len(nodes)] = feats[nodes]
            out_feats.append(fl)
        fixed_idx, fixed_valid = [], []
        for k, (idx, valid) in enumerate(zip(nbr_idx, nbr_valid)):
            fi = np.zeros((sizes[k], self.fanouts[k]), np.int32)
            fv = np.zeros((sizes[k], self.fanouts[k]), bool)
            fi[:len(idx)] = idx
            fv[:len(valid)] = valid
            fixed_idx.append(fi)
            fixed_valid.append(fv)
        return out_feats, fixed_idx, fixed_valid
