"""The single source of truth for every matching knob (DESIGN.md §4).

Before this module existed the engine/budget knobs (``limit``,
``time_budget_s``, ``max_recursions``, ``parallelism``, ``wave_size``,
``megastep_depth``, ``pattern_*``, …) were duplicated with drifting
defaults across four kwarg surfaces: ``QueryServer``,
``WaveScheduler.submit``, ``DistributedMatcher`` and ``WaveEngine``.
:class:`MatchOptions` collapses them into one dataclass, validated in
one place; every entry point resolves its keyword arguments through
:meth:`MatchOptions.resolve` so a default changed here changes
everywhere (asserted by ``tests/test_api.py``).

This module is deliberately leaf-level: it imports nothing from
``repro.core`` so the core scheduler can consume it without an import
cycle.

Two kinds of field share the dataclass because requests and engines
share a vocabulary:

* **per-query** fields travel on a :class:`MatchRequest` and may differ
  between concurrent queries (``limit``, ``time_budget_s``,
  ``max_recursions``, ``use_pruning``, ``parallelism``, ``priority``,
  ``seed_patterns``, ``keep_table``);
* **per-engine** fields are consumed once at scheduler construction
  (``n_slots``, ``wave_size``, ``kpr``, ``megastep_depth``,
  ``max_queue``, ``store_*``, ``adaptive_prune_threshold``,
  ``device_stacks``, ``stack_capacity``, ``pattern_*``,
  ``hit_decay_every``) and ignored on a request.

An engine built from a ``MatchOptions`` also uses it as the *default*
per-query options for requests that do not override them — so a server
constructed with ``limit=100`` serves every query with that cap unless
the request says otherwise.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any

if TYPE_CHECKING:                                    # pragma: no cover
    from ..core.graph import Graph

__all__ = ["MatchOptions", "MatchRequest", "ENGINE_TUNABLE_DEFAULTS"]

# accepted spellings of historical kwargs -> canonical field
_ALIASES = {"max_rows": "max_recursions"}

# Engine knobs the autotuner may fill (DESIGN.md §9). Their MatchOptions
# default is ``None`` = "let the tuning layer decide"; the values below
# are the built-in fallback when no tuning record matches. An explicit
# user value always wins over both (pinned by tests/test_tuning.py).
# ``pattern_capacity`` was right-sized from 4096 by measurement: the
# serving workloads peak near ~130 resident patterns per slot (corridor,
# 128 baits), so 4096 ran at load factor 0.004 on uniform traffic —
# capacity paid for but unused. 1024 keeps 8x headroom over the heaviest
# measured workload, and eviction is sound anyway (loses pruning, never
# results).
ENGINE_TUNABLE_DEFAULTS = {
    "n_slots": 8,
    "wave_size": 512,
    "megastep_depth": 6,
    "store_flush_min": 16,
    "stack_capacity": 1024,
    "pattern_capacity": 1024,
}


@dataclasses.dataclass(frozen=True)
class MatchOptions:
    """Every per-query and per-engine matching knob, with the one
    canonical default per knob. Frozen: derive variants with
    :meth:`replace` / :meth:`resolve`."""

    # ---- per-query ----------------------------------------------------
    limit: int | None = 1000          # result cap (None = enumerate all)
    time_budget_s: float | None = None   # wall-clock budget
    max_recursions: int | None = None    # recursion/row budget
    use_pruning: bool | None = None      # None = engine default (True)
    parallelism: int = 1              # intra-query shards (DESIGN.md §3)
    priority: int = 0                 # admission priority (higher first)
    keep_table: bool = False          # export the learned Δ on finish
    seed_patterns: dict | None = None  # entries dict to warm-start Δ

    # ---- per-engine (consumed at scheduler construction) --------------
    # ``None`` on a tunable knob means "resolve through the tuning layer"
    # (tuning cache record for this backend/shape, else the built-in
    # ENGINE_TUNABLE_DEFAULTS entry — DESIGN.md §9). Explicit values win.
    n_slots: int | None = None
    wave_size: int | None = None
    kpr: int = 16
    megastep_depth: int | None = None
    max_queue: int = 4096
    store_flush_min: int | None = None
    store_pad: int = 256
    adaptive_prune_threshold: float = 0.05
    # device-resident frontier stacks (DESIGN.md §2): per-slot DFS stack
    # depth held in device arrays. ``device_stacks=False`` forces every
    # query through the host SegmentPool path (debug / A-B testing).
    device_stacks: bool = True
    stack_capacity: int | None = None
    # hierarchical / HBM-resident adjacency (DESIGN.md §2): ``None`` on
    # every knob means "resolve through kernels.config" — the
    # ``use_hbm_adjacency`` size threshold (or a tuning record) picks
    # the layout, and ``chunk_words`` / ``dma_depth`` fill from the
    # tuned kernel parameters. Explicit values pin the variant — e.g.
    # ``hier_adjacency=True`` forces the two-level layout on a small
    # graph for A/B and bit-identity testing.
    hier_adjacency: bool | None = None
    chunk_words: int | None = None    # packed words per chunk (C, pow-2)
    dma_depth: int | None = None      # in-flight chunk copies (HBM kernel)
    pattern_capacity: int | None = None
    pattern_cache: bool = True
    pattern_cache_templates: int = 64
    pattern_cache_top_k: int = 512
    hit_decay_every: int = 256
    # ---- fault tolerance (DESIGN.md §8) -------------------------------
    # Watchdog deadline per device/megastep dispatch (None = off: a
    # first dispatch legitimately spends tens of seconds in jit
    # compilation). A dispatch past the deadline is treated as hung:
    # its digest is untrusted and the involved queries are demoted.
    dispatch_timeout_s: float | None = None
    dispatch_retries: int = 2         # re-dispatch attempts on failure
    retry_backoff_s: float = 0.05     # base of the exponential backoff
    validate_digests: bool = True     # check DeviceResult invariants
    fallback_on_failure: bool = True  # demote failing queries to host
    max_query_failures: int = 2       # failures before status="error"
    shed_policy: str = "reject"       # "reject" (QueueFull) | "shed_lowest"
    micro_checkpoint_every: int | None = None  # distributed waves/ckpt
    faults: Any = None                # core.faults.FaultPlan (tests/chaos)

    # ------------------------------------------------------------------
    def validate(self) -> "MatchOptions":
        """Raise ``ValueError`` on an inconsistent knob; returns self."""
        def _nonneg(name: str, v, allow_none: bool = True) -> None:
            if v is None:
                if not allow_none:
                    raise ValueError(f"{name} may not be None")
                return
            if v < 0:
                raise ValueError(f"{name} must be >= 0, got {v!r}")

        _nonneg("limit", self.limit)
        _nonneg("time_budget_s", self.time_budget_s)
        _nonneg("max_recursions", self.max_recursions)
        if self.parallelism < 1:
            raise ValueError(
                f"parallelism must be >= 1, got {self.parallelism!r}")
        for name in ("n_slots", "wave_size", "kpr", "megastep_depth",
                     "max_queue", "store_pad", "pattern_capacity",
                     "hit_decay_every", "stack_capacity",
                     "store_flush_min"):
            v = getattr(self, name)
            if v is None and name in ENGINE_TUNABLE_DEFAULTS:
                continue              # tunable: resolved at construction
            if v is None or v < 1:
                raise ValueError(
                    f"{name} must be >= 1, got {getattr(self, name)!r}")
        if (self.pattern_capacity is not None
                and self.pattern_capacity & (self.pattern_capacity - 1)):
            raise ValueError("pattern_capacity must be a power of two, "
                             f"got {self.pattern_capacity!r}")
        if self.chunk_words is not None and (
                self.chunk_words < 1 or self.chunk_words > 128
                or self.chunk_words & (self.chunk_words - 1)):
            raise ValueError("chunk_words must be a power of two in "
                             f"[1, 128], got {self.chunk_words!r}")
        if self.dma_depth is not None and self.dma_depth < 1:
            raise ValueError(
                f"dma_depth must be >= 1, got {self.dma_depth!r}")
        _nonneg("dispatch_timeout_s", self.dispatch_timeout_s)
        _nonneg("retry_backoff_s", self.retry_backoff_s, allow_none=False)
        _nonneg("dispatch_retries", self.dispatch_retries,
                allow_none=False)
        _nonneg("max_query_failures", self.max_query_failures,
                allow_none=False)
        if self.shed_policy not in ("reject", "shed_lowest"):
            raise ValueError("shed_policy must be 'reject' or "
                             f"'shed_lowest', got {self.shed_policy!r}")
        if (self.micro_checkpoint_every is not None
                and self.micro_checkpoint_every < 1):
            raise ValueError("micro_checkpoint_every must be >= 1, got "
                             f"{self.micro_checkpoint_every!r}")
        return self

    def replace(self, **overrides: Any) -> "MatchOptions":
        """``dataclasses.replace`` with alias normalization + validation."""
        return MatchOptions.resolve(self, **overrides)

    @staticmethod
    def resolve(base: "MatchOptions | None" = None,
                **overrides: Any) -> "MatchOptions":
        """The one resolution path every entry point funnels through.

        ``base`` supplies defaults (``None`` = the canonical
        ``MatchOptions()``); ``overrides`` are explicitly-passed kwargs
        — *presence* marks an override, so ``limit=None`` genuinely
        overrides a numeric default. Unknown keys raise ``TypeError``
        (the historical ``max_rows`` spelling is folded into
        ``max_recursions``)."""
        kw = {}
        for k, v in overrides.items():
            kw[_ALIASES.get(k, k)] = v
        opts = base if base is not None else MatchOptions()
        if kw:
            opts = dataclasses.replace(opts, **kw)
        return opts.validate()

    def resolved_engine(self, *, backend: str | None = None,
                        n_vertices: int | None = None
                        ) -> tuple[dict, dict]:
        """Concrete engine knobs + the tuning record that supplied them.

        Fills every tunable knob the caller left ``None`` from the
        persistent tuning cache (keyed by backend / device kind /
        quantized data-graph size — DESIGN.md §9), falling back to
        ``ENGINE_TUNABLE_DEFAULTS``. Explicit values on this options
        object always win over the cache. Returns ``(knobs, record)``
        where ``knobs`` maps every ENGINE_TUNABLE_DEFAULTS key (plus
        ``block_f``, the refine-kernel row-block height) to an int and
        ``record`` is a JSON-safe descriptor naming the consumed tuning
        record (``source`` = "tuning-cache" | "builtin")."""
        from ..tuning.resolve import resolve_engine_options
        return resolve_engine_options(self, backend=backend,
                                      n_vertices=n_vertices)


@dataclasses.dataclass
class MatchRequest:
    """One query plus its resolved options — the unit the request/handle
    API submits. ``request_id`` is the caller-visible id (defaults to
    the scheduler-assigned query id); ``cand``/``order`` optionally pin
    the candidate sets / matching order (oracle tests, shard restriction
    in ``core.distributed``)."""
    query: "Graph"
    options: MatchOptions
    request_id: int | None = None
    cand: list | None = None
    order: Any | None = None

    def __post_init__(self) -> None:
        self.options.validate()
