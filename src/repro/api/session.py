"""The request/handle front door over the matching engines
(DESIGN.md §4).

A :class:`MatchSession` owns one backend — the shared-wave scheduler
(``backend="engine"``) or the paper's sequential Algorithm 2 reference
(``backend="sequential"``) — and turns submissions into
:class:`~repro.api.handle.MatchHandle` futures:

* ``submit()`` is **non-blocking**: it enqueues through the bounded
  admission queue (raising :class:`QueueFull` for backpressure) and
  returns a handle immediately;
* progress is **cooperative**: the host thread advances the engine by
  calling ``session.step()`` / ``session.run()``, or implicitly by
  consuming any handle's ``result()`` / ``stream()`` — all resident
  queries share the same waves, so pumping one handle progresses all;
* embeddings are **streamed**: the scheduler delivers each query's
  newly found batches to its handle as the emitting wave's digest is
  processed, so ``stream()`` yields results long before retirement
  (TTFE ≪ completion on enumeration-heavy queries);
* ``cancel()`` rides the scheduler's existing eviction path — a
  cancelled query's neighbors are untouched.

The sequential backend serves the same lifecycle one query at a time
(FIFO): ``stream()`` runs the search on a worker thread and yields each
embedding as the recursion reports it, and ``cancel()`` aborts at the
next poll point. It remains the correctness oracle for the streamed
API: both backends yield unions identical to their blocking results.
"""
from __future__ import annotations

import collections
import queue as _queue
import threading
import time

import numpy as np

from ..core.backtrack import backtrack_deadend
from ..core.vectorized import QueueFull, WaveScheduler
from .handle import MatchError, MatchHandle, QueryResult, status_of
from .options import MatchOptions, MatchRequest

__all__ = ["MatchSession"]


class MatchSession:
    """Request/handle sessions over one data graph.

    ``options`` (plus keyword overrides) configures the engine *and*
    provides the default per-query options; an existing ``scheduler``
    may be passed to wrap it instead of constructing one.
    """

    def __init__(self, data, *, options: MatchOptions | None = None,
                 backend: str = "engine",
                 scheduler: WaveScheduler | None = None, **knobs):
        if backend not in ("engine", "sequential"):
            raise ValueError(f"unknown backend: {backend!r}")
        self.data = data
        self.backend = backend
        self.options = (scheduler.options if scheduler is not None
                        else MatchOptions.resolve(options, **knobs))
        self.scheduler = (
            (scheduler if scheduler is not None
             else WaveScheduler(data, options=self.options))
            if backend == "engine" else None)
        # completion hook: called with each finished QueryResult (the
        # serving layer records latency / TTFE / timeout tallies here)
        self.on_complete = None
        self._handles: dict[int, MatchHandle] = {}     # engine: sched qid
        self._pending: collections.deque[MatchHandle] = collections.deque()
        self._workers: set[threading.Thread] = set()   # sequential streams
        self._next_seq = 0

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def submit(self, query, *, options: MatchOptions | None = None,
               query_id: int | None = None, cand=None, order=None,
               **overrides) -> MatchHandle:
        """Non-blocking submit; returns a :class:`MatchHandle`.

        Raises :class:`QueueFull` when the bounded admission queue is at
        capacity (typed backpressure — callers shed load or drain via
        ``step()``). ``query_id`` sets the caller-visible id on the
        result (defaults to the engine-assigned id).
        """
        opts = MatchOptions.resolve(
            options if options is not None else self.options, **overrides)
        req = MatchRequest(query=query, options=opts, request_id=query_id,
                           cand=cand, order=order)
        h = MatchHandle(self, req)
        h._t_submit = time.perf_counter()
        if self.backend == "engine":
            sched_qid = self.scheduler.submit(
                query, options=opts, cand=cand, order=order,
                on_embeddings=h._push)
            h._sched_qid = sched_qid
            h.query_id = sched_qid if query_id is None else query_id
            self._handles[sched_qid] = h
            self._drain()          # trivial queries retire inside submit
        else:
            if len(self._pending) >= opts.max_queue:
                raise QueueFull(
                    f"admission queue at capacity ({opts.max_queue})")
            if query_id is None:
                h.query_id = self._next_seq
            self._next_seq += 1
            self._pending.append(h)
        return h

    # ------------------------------------------------------------------
    # driving
    # ------------------------------------------------------------------
    def step(self) -> bool:
        """Advance the backend by one unit of work (one scheduler wave /
        one sequential query); returns False when idle."""
        if self.backend == "engine":
            progressed = self.scheduler.step()
            self._drain()
            return progressed
        if not self._pending:
            return False
        self._run_sequential(self._pending.popleft())
        return True

    def run(self) -> None:
        """Drain every queued and in-flight query."""
        while self.step():
            pass

    @property
    def idle(self) -> bool:
        if self.backend == "engine":
            return self.scheduler.idle
        self._workers = {w for w in self._workers if w.is_alive()}
        return not self._pending and not self._workers

    # ------------------------------------------------------------------
    # handle-side plumbing
    # ------------------------------------------------------------------
    def _pump(self, h: MatchHandle) -> None:
        """Advance until *some* progress lands (used by handle.result /
        handle.stream); raises if the backend idles while ``h`` is
        still incomplete (a submit that never reached the queue)."""
        if h.done():
            return
        if h._worker is not None:
            # a sequential stream() moved this handle onto a worker
            # thread: completion comes from there, not from step()
            h._worker.join()
            return
        if not self.step() and not h.done():
            raise RuntimeError(
                f"session idle but handle {h.query_id!r} incomplete")

    def _cancel(self, h: MatchHandle) -> bool:
        if self.backend == "engine":
            ok = self.scheduler.cancel(h._sched_qid)
            if ok:
                self._drain()      # cancellation retires synchronously
            return ok
        if h in self._pending:     # never started: retire as cancelled
            self._pending.remove(h)
            from ..core.backtrack import SearchStats
            stats = SearchStats(aborted=True, abort_reason="cancelled")
            self._finish_handle(h, [], stats, 0.0)
            return True
        # running inside a stream() worker: h._cancel_requested is set;
        # the search aborts at its next poll point
        return not h.done()

    def _stream(self, h: MatchHandle):
        if self.backend == "engine":
            # delivered batches are consecutive slices of the query's
            # embedding list, so a yielded-row cursor is enough to
            # resume from result.embeddings once the handle completes —
            # which also makes a fresh post-completion stream() a full
            # replay (cursor 0) with no duplicate buffer held.
            n = 0
            while not h.done():
                while h._batches:
                    batch = h._batches.popleft()
                    n += len(batch)
                    yield batch
                if h.done():
                    break
                self._pump(h)
            emb = h._result.embeddings
            if n < len(emb):
                yield np.stack([np.asarray(e, np.int32)
                                for e in emb[n:]])
        else:
            yield from self._stream_sequential(h)

    # ------------------------------------------------------------------
    # completion
    # ------------------------------------------------------------------
    def _finish_handle(self, h: MatchHandle, embeddings, stats,
                       latency_s: float) -> None:
        status = status_of(stats, h.request.options.limit)
        if status == "error":
            h.error = MatchError(getattr(stats, "fault", None)
                                 or "query failed")
        qr = QueryResult(
            query_id=h.query_id, n_found=stats.found,
            embeddings=embeddings, latency_s=latency_s,
            recursions=stats.recursions, timed_out=status == "timeout",
            aborted=stats.aborted, status=status, stats=stats)
        h._complete(qr)
        if self.on_complete is not None:
            self.on_complete(qr)

    def _drain(self) -> None:
        """Retire finished scheduler queries into their handles. Only
        session-submitted query ids are popped — results of queries
        submitted directly on the scheduler stay in
        ``scheduler.finished`` for their owner."""
        for qid in self.scheduler.poll():
            h = self._handles.pop(qid, None)
            if h is None:
                continue
            res = self.scheduler.finished.pop(qid, None)
            if res is None:
                continue
            self._finish_handle(h, res.embeddings, res.stats,
                                time.perf_counter() - h._t_submit)

    # ------------------------------------------------------------------
    # sequential backend
    # ------------------------------------------------------------------
    def _run_sequential(self, h: MatchHandle,
                        stream_q: "_queue.Queue | None" = None) -> None:
        opts = h.request.options

        def on_emb(emb: np.ndarray) -> None:
            batch = np.asarray(emb, np.int32)[None, :].copy()
            h._push(batch)
            if stream_q is not None:
                stream_q.put(batch)

        res = backtrack_deadend(
            h.request.query, self.data, cand=h.request.cand,
            order=h.request.order, limit=opts.limit,
            max_recursions=opts.max_recursions,
            time_budget_s=opts.time_budget_s,
            use_pruning=(True if opts.use_pruning is None
                         else opts.use_pruning),
            on_embedding=on_emb,
            should_abort=lambda: h._cancel_requested)
        # latency = execution wall time (queueing is host-side FIFO)
        self._finish_handle(h, res.embeddings, res.stats,
                            res.stats.wall_time_s)
        if stream_q is not None:
            stream_q.put(None)

    def _stream_sequential(self, h: MatchHandle):
        if not h.done():
            # FIFO admission: run every query queued ahead of this one
            while self._pending and self._pending[0] is not h:
                self.step()
        if h.done():               # completed (or cancelled) already —
            emb = h._result.embeddings         # replay from the result
            if emb:
                yield np.stack([np.asarray(e, np.int32) for e in emb])
            return
        self._pending.remove(h)
        sq: _queue.Queue = _queue.Queue()
        worker = threading.Thread(
            target=self._run_sequential, args=(h, sq), daemon=True)
        # registered before start so result()/idle see the in-flight
        # worker even if this generator is abandoned mid-consumption
        h._worker = worker
        self._workers.add(worker)
        worker.start()
        while True:
            batch = sq.get()
            if batch is None:
                break
            yield batch
        worker.join()
