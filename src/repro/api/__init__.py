"""Public request/handle API for subgraph matching (DESIGN.md §4).

    from repro.api import MatchOptions, MatchSession

    session = MatchSession(data_graph, n_slots=16)
    handle = session.submit(query, limit=None)       # non-blocking
    for batch in handle.stream():                    # [k, n_query] int32
        ...                                          # before completion
    result = handle.result()                         # QueryResult
    handle.cancel()                                  # typed eviction

``MatchOptions`` is the single source of truth for every per-query and
per-engine knob; ``QueueFull`` is the typed backpressure signal from
the bounded admission queue.

Submodule note: ``options``/``handle`` are leaf modules imported
eagerly; ``MatchSession`` and ``QueueFull`` resolve lazily because the
core scheduler itself consumes ``api.options`` (PEP 562 keeps the
package importable from either direction).
"""
from .handle import (MatchError, MatchHandle, MatchTimeout, QueryResult,
                     Status, status_of)
from .options import MatchOptions, MatchRequest

__all__ = [
    "MatchError", "MatchHandle", "MatchOptions", "MatchRequest",
    "MatchSession", "MatchTimeout", "QueryResult", "QueueFull",
    "Status", "status_of",
]

_LAZY = {
    "MatchSession": ("repro.api.session", "MatchSession"),
    "QueueFull": ("repro.core.vectorized", "QueueFull"),
}


def __getattr__(name: str):
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    import importlib
    return getattr(importlib.import_module(mod_name), attr)
