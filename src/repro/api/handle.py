"""Futures for subgraph-matching queries: :class:`MatchHandle` and the
serving-level :class:`QueryResult` (DESIGN.md §4).

``submit()`` on a session/server returns a handle immediately; the
query runs when the session's scheduler steps. Because the engine is
host-driven (no background thread), the handle is *cooperative*:
``result()`` and ``stream()`` pump the owning session until this query
retires — other concurrent queries make progress on the same waves, so
consuming one handle never starves its neighbors.

Status taxonomy (one definition for every backend):

    "ok"        enumeration ran to completion
    "limit"     stopped at the per-query result cap
    "timeout"   recursion or wall-clock budget exhausted
    "cancelled" evicted by MatchHandle.cancel()
    "error"     quarantined past the failure budget (DESIGN.md §8);
                the typed failure is on ``MatchHandle.error``
    "shed"      dropped by the shed_lowest overload policy
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Iterator, Literal

import numpy as np

from .options import MatchRequest

__all__ = ["QueryResult", "MatchHandle", "Status", "status_of",
           "MatchError", "MatchTimeout"]

Status = Literal["ok", "limit", "timeout", "cancelled", "error", "shed"]
STATUSES: tuple[str, ...] = ("ok", "limit", "timeout", "cancelled",
                             "error", "shed")


class MatchError(RuntimeError):
    """A query was quarantined past its failure budget (or with
    fallback disabled): runtime fault, not a budget stop. Attached to
    ``MatchHandle.error`` when ``status == "error"``."""


class MatchTimeout(TimeoutError):
    """``MatchHandle.result(timeout=...)`` deadline expired before the
    query completed (the query keeps running; call ``result`` again)."""


def status_of(stats, limit: int | None) -> Status:
    """Map ``SearchStats`` abort bookkeeping to the serving status
    taxonomy (shared by the sequential oracle and the wave engine)."""
    if not stats.aborted:
        return "ok"
    reason = stats.abort_reason
    if reason in ("cancelled", "error", "shed"):
        return reason
    if reason == "limit" or (reason is None and limit is not None
                             and stats.found >= limit):
        return "limit"
    return "timeout"


@dataclasses.dataclass
class QueryResult:
    query_id: int
    n_found: int
    embeddings: list
    latency_s: float
    recursions: int
    timed_out: bool              # True iff status == "timeout"
    aborted: bool = False        # any early stop (limit/budget/cancel)
    status: Status = "ok"
    # full engine stats (EngineStats on the engine backend — includes
    # per-shard rows/items/steal counters for parallelism > 1, and
    # ttfe_s = time to first embedding)
    stats: object = None

    @property
    def ttfe_s(self) -> float | None:
        """Time from submission to the first emitted embedding (None if
        the query found nothing)."""
        return getattr(self.stats, "ttfe_s", None)

    def to_dict(self, include_embeddings: bool = False) -> dict:
        """JSON-safe summary payload: typed ``status``, builtin scalars
        only (no numpy types survive). ``include_embeddings`` adds the
        full embedding rows as lists of ints."""
        ttfe = self.ttfe_s
        d = {
            "query_id": int(self.query_id),
            "status": str(self.status),
            "n_found": int(self.n_found),
            "recursions": int(self.recursions),
            "latency_ms": float(self.latency_s) * 1e3,
            "ttfe_ms": None if ttfe is None else float(ttfe) * 1e3,
            "timed_out": bool(self.timed_out),
            "aborted": bool(self.aborted),
        }
        if include_embeddings:
            d["embeddings"] = [[int(v) for v in np.asarray(e).tolist()]
                               for e in self.embeddings]
        return d


class MatchHandle:
    """Future-like view of one submitted query.

    * :meth:`done` — non-blocking completion check;
    * :meth:`result` — pump the session until this query retires,
      return its :class:`QueryResult`;
    * :meth:`stream` — iterator yielding ``[k, n_query]`` int32
      embedding batches *as waves emit them* (before completion);
    * :meth:`cancel` — evict the query via the scheduler's existing
      eviction path; neighbors sharing its waves are untouched.
    """

    def __init__(self, session, request: MatchRequest):
        self._session = session
        self.request = request
        self.query_id: int | None = request.request_id  # set at submit
        # undelivered in-flight batches; cleared at completion (late /
        # repeat consumers replay from result().embeddings instead, so
        # blocking callers never hold a duplicate copy of their rows)
        self._batches: collections.deque[np.ndarray] = collections.deque()
        self._result: QueryResult | None = None
        self._cancel_requested = False
        self._worker = None        # sequential stream() worker thread
        # typed failure attached by the session when status == "error"
        self.error: MatchError | None = None

    # ------------------------------------------------------------------
    def done(self) -> bool:
        return self._result is not None

    @property
    def status(self) -> Status | Literal["pending"]:
        return self._result.status if self._result is not None \
            else "pending"

    def result(self, timeout: float | None = None) -> QueryResult:
        """Drive the session until this query completes (returns
        immediately when it already has).

        ``timeout`` bounds the wall-clock time spent pumping; past the
        deadline :class:`MatchTimeout` is raised instead of blocking on
        a stalled scheduler. The query itself keeps its state — calling
        ``result`` again resumes pumping."""
        if timeout is None:
            while self._result is None:
                self._session._pump(self)
            return self._result
        deadline = time.perf_counter() + timeout
        while self._result is None:
            if time.perf_counter() >= deadline:
                raise MatchTimeout(
                    f"query {self.query_id} did not complete within "
                    f"{timeout:g}s")
            self._session._pump(self)
        return self._result

    def stream(self) -> Iterator[np.ndarray]:
        """Yield embedding batches incrementally. The union of all
        yielded rows equals ``result().embeddings`` exactly — streaming
        changes delivery, never the answer. Safe to call after
        completion, and safe to call again: a finished handle replays
        its full embedding set from the result (one iterator at a
        time; concurrent iterators over one handle are not supported)."""
        return self._session._stream(self)

    def cancel(self) -> bool:
        """Request cancellation. Returns True if the query was still
        pending/running (its status becomes ``"cancelled"``; embeddings
        already emitted are kept), False if it had already finished."""
        if self._result is not None:
            return False
        self._cancel_requested = True
        return self._session._cancel(self)

    # ---- session-side plumbing ---------------------------------------
    def _push(self, batch: np.ndarray) -> None:
        """Embedding-delivery sink (called by the scheduler mid-wave)."""
        self._batches.append(np.asarray(batch, np.int32))

    def _complete(self, result: QueryResult) -> None:
        self._result = result
        # drop the in-flight buffer: an active stream iterator resumes
        # from result.embeddings at its yielded-row cursor, and late
        # consumers replay from there too — no duplicate copy survives
        self._batches.clear()

    def __repr__(self) -> str:            # pragma: no cover
        return (f"MatchHandle(query_id={self.query_id}, "
                f"status={self.status!r})")
