"""Knob resolution (DESIGN.md §9): explicit user value > MatchOptions >
tuning-cache record > built-in default.

:func:`resolve_engine_options` is the one funnel: ``WaveScheduler``
calls it at construction (via ``MatchOptions.resolved_engine``) and the
returned descriptor is what ``scheduler_stats()["tuning"]`` and the
serving-bench payload surface — the consumed record is always visible.

``REPRO_TUNING_DISABLE=1`` skips the cache entirely (the built-in
defaults win); ``scripts/ab_gate.py`` uses it for the tuned-vs-default
A/B and the tests use it to pin deterministic defaults.
"""
from __future__ import annotations

import os

from ..kernels import config as kconfig
from .cache import cache_key, device_kind, load_default_cache, \
    quantize_vertices
from .space import schema_hash

__all__ = ["resolve_engine_options", "tuning_enabled"]


def tuning_enabled() -> bool:
    return os.environ.get("REPRO_TUNING_DISABLE") != "1"


def resolve_engine_options(opts, *, backend: str | None = None,
                           n_vertices: int | None = None
                           ) -> tuple[dict, dict]:
    """Concrete engine knobs for ``opts`` plus the consumed-record
    descriptor.

    Every knob in ``ENGINE_TUNABLE_DEFAULTS`` the caller left ``None``
    on ``opts`` is filled from the tuning-cache record keyed by
    ``(backend, device_kind, quantized |V|)`` when one matches the
    current knob schema, else from the built-in default. Explicit
    values on ``opts`` always win. ``block_f`` (the refine-kernel
    row-block height, not a MatchOptions field) resolves kernel-scope
    override > record > built-in.
    """
    from ..api.options import ENGINE_TUNABLE_DEFAULTS

    backend = kconfig.resolve(backend)
    rec = None
    key = None
    if tuning_enabled() and n_vertices is not None:
        dev = device_kind()
        key = cache_key(backend, dev, n_vertices)
        rec = load_default_cache().lookup_key(key)
    rec_params = rec.get("params", {}) if rec else {}

    knobs = {}
    filled_from_cache = []
    for name, default in ENGINE_TUNABLE_DEFAULTS.items():
        explicit = getattr(opts, name, None)
        if explicit is not None:
            knobs[name] = int(explicit)
        elif name in rec_params:
            knobs[name] = int(rec_params[name])
            filled_from_cache.append(name)
        else:
            knobs[name] = int(default)
    block_f = kconfig.kernel_override("block_f")
    if block_f is None:
        block_f = rec_params.get("block_f")
        if block_f is not None:
            filled_from_cache.append("block_f")
    knobs["block_f"] = int(block_f) if block_f is not None \
        else kconfig.DEFAULT_BLOCK_F

    record = {
        "source": "tuning-cache" if rec else "builtin",
        "record": rec["name"] if rec else None,
        "key": key,
        "schema_hash": schema_hash(),
        "backend": backend,
        "v_bucket": (quantize_vertices(n_vertices)
                     if n_vertices is not None else None),
        "filled_from_cache": filled_from_cache,
        "params": dict(knobs),
    }
    return knobs, record
