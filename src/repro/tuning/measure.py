"""Measurement harness for the autotuner (DESIGN.md §9).

Two granularities, both jit-cache-aware (an untimed warmup run absorbs
compilation; the timed trials report the median so one GC pause or
container hiccup cannot crown a candidate):

* :func:`refine_microbench` — the ``bitmap_refine`` kernel alone at a
  given row-block height on synthetic operands of the target shape;
* :func:`run_smoke_workload` — the serving smoke uniform workload
  (identical graph/query construction to ``benchmarks.serving_bench
  --smoke``) end to end through a :class:`WaveScheduler` built with the
  candidate's knobs, returning qps, the per-slot store load factor,
  and a digest over the sorted embedding rows. The digest is the
  tuner's safety interlock: every candidate must produce bit-identical
  embeddings (configuration may move time, never results).

Heavy imports (core, data) stay inside the functions so the tuning
package is importable without pulling the engine in.
"""
from __future__ import annotations

import hashlib
import statistics
import time

__all__ = ["timed_trials", "refine_microbench", "run_smoke_workload",
           "SMOKE_SHAPE"]

# The serving smoke workload's construction parameters
# (benchmarks/serving_bench.py --smoke, uniform leg) — the tuner
# measures at the same shape the smoke bench serves, so the record it
# writes is the record the bench consumes.
SMOKE_SHAPE = {
    "n_vertices": 128, "extra_edges": 128, "n_labels": 24,
    "n_queries": 8, "query_size": 4, "kpr": 8,
    "limit": 1000, "time_budget_s": 10.0, "graph_seed": 0,
    "query_seed": 7,
}


def timed_trials(fn, warmup: int = 1, trials: int = 3) -> float:
    """Median wall seconds of ``trials`` calls after ``warmup`` untimed
    ones (the warmup absorbs jit compilation)."""
    for _ in range(max(0, warmup)):
        fn()
    samples = []
    for _ in range(max(1, trials)):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    return statistics.median(samples)


def refine_microbench(backend: str, block_f: int, n_vertices: int = 128,
                      f: int = 64, np_: int = 8, warmup: int = 1,
                      trials: int = 3, seed: int = 0) -> float:
    """Median seconds of one ``refine_bitmap_rows`` call at ``block_f``
    on synthetic operands shaped like the target workload."""
    import numpy as np

    import jax.numpy as jnp

    from ..core.graph import pack_bitmap
    from ..kernels.bitmap_refine import refine_bitmap_rows

    rng = np.random.default_rng(seed)
    dense = rng.random((n_vertices, n_vertices)) < 0.2
    dense |= dense.T
    adj = jnp.asarray(pack_bitmap(dense))
    cand = jnp.asarray(pack_bitmap(rng.random((f, n_vertices)) < 0.5))
    frontier = jnp.asarray(
        rng.integers(-1, n_vertices, size=(f, np_)).astype(np.int32))
    active = jnp.asarray((rng.random((f, np_)) < 0.6).astype(np.int32))
    interpret = backend == "pallas_interpret"

    def call():
        refine_bitmap_rows(adj, cand, frontier, active,
                           interpret=interpret,
                           block_f=block_f).block_until_ready()

    return timed_trials(call, warmup=warmup, trials=trials)


def _embeddings_digest(finished: dict) -> str:
    """sha256 over every query's sorted embedding rows — the
    bit-identity interlock across candidate configurations."""
    import numpy as np

    h = hashlib.sha256()
    for qid in sorted(finished):
        rows = sorted(
            np.asarray(e, np.int32).tobytes()
            for e in finished[qid].embeddings)
        h.update(str(qid).encode())
        for r in rows:
            h.update(r)
    return h.hexdigest()


def run_smoke_workload(params: dict, backend: str | None = None,
                       warmup: int = 1, trials: int = 2) -> dict:
    """End-to-end measurement of one candidate configuration on the
    serving smoke uniform workload.

    ``params`` is a ``CandidateConfig.as_params()`` dict — the engine
    knobs are passed *explicitly* to :class:`MatchOptions` (so the
    measurement is independent of whatever TUNING_CACHE.json currently
    holds) and ``block_f`` is pinned through
    ``kernels.config.kernel_param_scope``.
    """
    from ..api.options import MatchOptions
    from ..core.vectorized import WaveScheduler
    from ..data.graph_gen import ba_labeled_graph, query_set
    from ..kernels import config as kconfig

    s = SMOKE_SHAPE
    data = ba_labeled_graph(s["n_vertices"], 3, s["n_labels"],
                            extra_edges=s["extra_edges"],
                            seed=s["graph_seed"])
    queries = query_set(data, s["query_size"], s["n_queries"],
                        seed=s["query_seed"])
    opts = MatchOptions(
        limit=s["limit"], time_budget_s=s["time_budget_s"], kpr=s["kpr"],
        n_slots=params["n_slots"], wave_size=params["wave_size"],
        megastep_depth=params["megastep_depth"],
        stack_capacity=params["stack_capacity"],
        pattern_capacity=params["pattern_capacity"],
        store_flush_min=params["store_flush_min"])

    state: dict = {}
    walls: list[float] = []

    def one_run():
        sched = WaveScheduler(data, options=opts)
        for q in queries:
            sched.submit(q)
        t0 = time.perf_counter()
        finished = sched.run()
        walls.append(time.perf_counter() - t0)
        state["digest"] = _embeddings_digest(finished)
        state["n_embeddings"] = int(
            sum(len(r.embeddings) for r in finished.values()))
        stats = sched.scheduler_stats()
        state["store_load_factor"] = float(stats["store_load_factor"])
        state["prune_rate"] = float(stats["prune_rate"])

    # kernel-level knobs pin through kernel_param_scope (the engine
    # knobs above went through MatchOptions): block_f plus the
    # adjacency-layout knobs, so a measured point exercises exactly the
    # variant its record would later resolve to
    scope = {k: params[k] for k in ("block_f", "hbm_adjacency",
                                    "chunk_words", "dma_depth")
             if k in params}
    with kconfig.kernel_param_scope(**scope):
        if backend is None:
            timed_trials(one_run, warmup=warmup, trials=trials)
        else:
            with kconfig.backend_scope(backend):
                timed_trials(one_run, warmup=warmup, trials=trials)
    # construction and submit stay outside the timed window (matching
    # serving_bench, which times submit_batch on a prebuilt server) —
    # take the median of the *serving* walls, skipping the warmup runs
    wall = statistics.median(walls[max(0, warmup):])
    return {
        "qps": len(queries) / wall if wall > 0 else 0.0,
        "wall_s": wall,
        "digest": state["digest"],
        "n_embeddings": state["n_embeddings"],
        "store_load_factor": state["store_load_factor"],
        "prune_rate": state["prune_rate"],
    }
