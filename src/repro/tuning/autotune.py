"""Autotune CLI (DESIGN.md §9): sweep candidate configurations, verify
bit-identical embeddings across them, and persist the best record.

    PYTHONPATH=src python -m repro.tuning.autotune --smoke
    PYTHONPATH=src python -m repro.tuning.autotune --backend jnp

``--smoke`` narrows the knob domains to a handful of points around the
serving smoke shape (seconds-scale, what scripts/ci.sh runs) and always
includes the built-in-default point — so the recorded best is never
worse than the defaults in the container that measured it. The report
(JSON on stdout) lists every measured point, every rejected point with
its reason, and flags pattern-capacity points whose store load factor
stayed below ``LOAD_FACTOR_FLOOR`` (capacity paid for but unused — the
evidence behind the right-sized default, see api/options.py).

The tuner refuses to write a record if any candidate's embedding digest
deviates: a tuned configuration may move time, never results.
"""
from __future__ import annotations

import argparse
import json
import sys

from .cache import TuningCache, device_kind
from .measure import SMOKE_SHAPE, refine_microbench, run_smoke_workload
from .space import CandidateConfig, TunableSpace, WorkloadShape, \
    schema_hash

__all__ = ["autotune", "LOAD_FACTOR_FLOOR"]

# a capacity point whose max store load factor stays below this after
# the whole workload is oversized for that workload
LOAD_FACTOR_FLOOR = 0.05

# Smoke-mode knob domains: pinned to the serving smoke packing shape
# (wave 64 / 8 slots — what the CI bench passes explicitly) and sweeping
# the knobs the smoke bench leaves to the tuner. The built-in default
# point (megastep_depth=6, pattern_capacity=1024, ...) is in the cross
# product by construction.
SMOKE_DOMAINS = {
    "block_f": [8],
    "megastep_depth": [4, 6, 8],
    "wave_size": [64],
    "n_slots": [8],
    "stack_capacity": [1024],
    "pattern_capacity": [512, 1024],
    "store_flush_min": [16],
    # adjacency layout pinned dense at the smoke shape (512 vertices is
    # far below the HBM threshold); chunk_words/dma_depth only matter
    # when hbm_adjacency=1, so sweeping them here would only multiply
    # identical measurements
    "hbm_adjacency": [0],
    "chunk_words": [8],
    "dma_depth": [2],
}

# Full-mode domains: a bounded sweep around the serving defaults.
FULL_DOMAINS = {
    "block_f": [8],
    "megastep_depth": [2, 4, 6, 8],
    "wave_size": [256, 512],
    "n_slots": [8],
    "stack_capacity": [1024],
    "pattern_capacity": [512, 1024, 4096],
    "store_flush_min": [8, 16],
    "hbm_adjacency": [0],
    "chunk_words": [8],
    "dma_depth": [2],
}


def autotune(backend: str = "jnp", smoke: bool = True,
             trials: int = 2, cache_path=None,
             write: bool = True) -> dict:
    """Run the sweep; returns the JSON-safe report (and persists the
    best record unless ``write=False``)."""
    from ..kernels import config as kconfig

    backend = kconfig.resolve(backend)
    n_vertices = SMOKE_SHAPE["n_vertices"]
    shape = WorkloadShape.for_graph(n_vertices)
    space = TunableSpace(backend, shape)
    domains = dict(SMOKE_DOMAINS if smoke else FULL_DOMAINS)
    if backend != "jnp" and smoke:
        # kernel geometry only matters when the Pallas kernel lowers
        domains["block_f"] = [8, 16]
    candidates = space.candidates(overrides=domains)
    if not candidates:
        raise RuntimeError(
            "no valid candidate points — every point rejected: "
            + "; ".join(r for _, r in space.rejected))

    default_cfg = CandidateConfig()
    measured = []
    for cfg in candidates:
        res = run_smoke_workload(cfg.as_params(), backend=backend,
                                 warmup=1, trials=trials)
        measured.append({"params": cfg.as_params(), **res})
        print(f"autotune: {cfg.as_params()} -> "
              f"{res['qps']:.1f} qps "
              f"(load_factor={res['store_load_factor']:.3f})",
              file=sys.stderr)

    # bit-identity interlock: every configuration must enumerate the
    # exact same embedding sets
    digests = {m["digest"] for m in measured}
    if len(digests) != 1:
        raise RuntimeError(
            "embedding digests diverged across candidate configs — "
            "refusing to write a tuning record: "
            + json.dumps([{**{"params": m["params"]},
                           "digest": m["digest"]} for m in measured]))

    best = max(measured, key=lambda m: m["qps"])
    # the smoke default-equivalent point: built-in defaults for the
    # swept knobs at the pinned packing shape
    default_point = next(
        (m for m in measured if all(
            m["params"][k] == getattr(default_cfg, k)
            for k in ("megastep_depth", "pattern_capacity",
                      "stack_capacity", "store_flush_min", "block_f"))),
        None)

    capacity_flags = [
        {"pattern_capacity": m["params"]["pattern_capacity"],
         "store_load_factor": m["store_load_factor"],
         "oversized": m["store_load_factor"] < LOAD_FACTOR_FLOOR}
        for m in measured]

    micro = None
    if backend != "jnp":
        micro = {
            str(bf): refine_microbench(backend, bf,
                                       n_vertices=n_vertices,
                                       trials=trials) * 1e3
            for bf in sorted({m["params"]["block_f"] for m in measured})}

    dev = device_kind()
    report = {
        "backend": backend,
        "device_kind": dev,
        "n_vertices": n_vertices,
        "schema_hash": schema_hash(),
        "smoke": bool(smoke),
        "trials": trials,
        "n_candidates": len(candidates),
        "n_rejected": len(space.rejected),
        "rejected": [{"params": cfg.as_params(), "reason": reason}
                     for cfg, reason in space.rejected][:50],
        "measured": [{k: v for k, v in m.items() if k != "digest"}
                     for m in measured],
        "digest": next(iter(digests)),
        "capacity_flags": capacity_flags,
        "refine_microbench_ms": micro,
        "best": {"params": best["params"], "qps": best["qps"]},
        "default_qps": default_point["qps"] if default_point else None,
    }

    if write:
        cache = TuningCache(cache_path)
        rec = cache.put(
            backend, dev, n_vertices, best["params"],
            measured={
                "qps": best["qps"],
                "default_qps": report["default_qps"],
                "store_load_factor": best["store_load_factor"],
                "n_embeddings": best["n_embeddings"],
                "trials": trials,
                "workload": "uniform-smoke-v%d" % n_vertices,
            })
        report["record"] = rec["name"]
        report["cache_path"] = str(cache.path)
        print(f"autotune: wrote {rec['name']} -> {cache.path} "
              f"(best {best['qps']:.1f} qps, default "
              f"{report['default_qps']}, schema {schema_hash()})",
              file=sys.stderr)
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="kernel & schedule autotuner (DESIGN.md §9)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale sweep at the CI smoke shape")
    ap.add_argument("--backend", default="jnp",
                    help="kernel backend to tune (jnp, pallas_interpret,"
                         " pallas)")
    ap.add_argument("--trials", type=int, default=2,
                    help="timed trials per point (median)")
    ap.add_argument("--cache", default=None,
                    help="TUNING_CACHE.json path (default: repo root / "
                         "REPRO_TUNING_CACHE)")
    ap.add_argument("--dry-run", action="store_true",
                    help="measure and report without writing the cache")
    args = ap.parse_args(argv)
    report = autotune(backend=args.backend, smoke=args.smoke,
                      trials=args.trials, cache_path=args.cache,
                      write=not args.dry_run)
    print(json.dumps(report, indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
