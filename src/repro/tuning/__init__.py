"""Kernel & schedule autotuning (DESIGN.md §9).

Three layers:

* ``space``   — declarative :class:`TunableSpace` over the hot-path
  knobs, with validity constraints that reject invalid points before
  anything compiles;
* ``cache``   — the persistent versioned TUNING_CACHE.json keyed by
  ``(backend, device_kind, quantized graph size)`` with schema-hash
  staleness detection;
* ``resolve`` — the one resolution funnel (explicit arg > MatchOptions
  > tuning cache > built-in default) that ``WaveScheduler`` consults at
  construction.

``measure`` and ``autotune`` (the CLI: ``python -m
repro.tuning.autotune --smoke``) import the engine lazily so this
package stays importable without it.
"""
from .cache import (TuningCache, cache_key, default_cache_path,
                    device_kind, load_default_cache, quantize_vertices)
from .resolve import resolve_engine_options, tuning_enabled
from .space import (CandidateConfig, TunableSpace, WorkloadShape,
                    schema_hash)

__all__ = [
    "TunableSpace", "CandidateConfig", "WorkloadShape", "schema_hash",
    "TuningCache", "cache_key", "quantize_vertices", "device_kind",
    "default_cache_path", "load_default_cache",
    "resolve_engine_options", "tuning_enabled",
]
