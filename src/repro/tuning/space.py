"""Declarative search space for the kernel & schedule autotuner
(DESIGN.md §9).

A :class:`TunableSpace` enumerates candidate configurations over the
engine's hot-path knobs — the ``bitmap_refine`` row-block height
(``block_f``), megastep fusion depth, device stack capacity, pattern
store capacity (with its PROBE-window floor), and the scheduler packing
knobs (``wave_size``, ``n_slots``, ``store_flush_min``) — and rejects
invalid points *before* anything compiles:

* pow-2 constraints (``wave_size``, ``stack_capacity``,
  ``pattern_capacity``) — the store's open-addressing mask and the
  stack ring arithmetic require them;
* ``pattern_capacity >= PROBE`` (the linear probe window must fit);
* ``block_f`` must be a multiple of 8 on the compiled ``pallas``
  backend (int32 min tile is (8, 128) sublanes x lanes); interpret /
  jnp runs accept any height >= 1 (the oracle-equality tests exploit
  this with a deliberately odd block height);
* a VMEM budget at the given ``(V, W)`` shape: the dense refine kernel
  (``hbm_adjacency=0``) holds the whole padded adjacency bitmap plus
  one candidate/output row block in VMEM, so points whose working set
  exceeds the budget are rejected with a reason instead of failing at
  compile time. The hierarchical variant (``hbm_adjacency=1``) leaves
  the adjacency in HBM and only budgets its VMEM scratch — the chunk-id
  window plus ``dma_depth`` in-flight chunks — so large-V points stay
  admissible there and the dense rejection explains *why* the layout
  switches;
* hierarchical layout knobs: ``chunk_words`` must be a power of two in
  [1, 128] (the summary packs one bit per chunk into u32 words and the
  kernel slices chunk-aligned word windows), ``dma_depth >= 1``.

The schema hash over this definition is the staleness key for
TUNING_CACHE.json: a record written under a different knob schema is
ignored (see ``tuning/cache.py``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json

__all__ = ["CandidateConfig", "TunableSpace", "WorkloadShape",
           "schema_hash", "DEFAULT_VMEM_BUDGET_BYTES"]

# The store's linear probe window (patterns/store.py PROBE): capacity
# below it cannot hold one probe sequence. Kept as a literal here so the
# space is importable without the patterns package; pinned equal by
# tests/test_tuning.py.
PROBE = 8

# Conservative per-core VMEM budget for the refine kernel's resident
# working set (real TPUs have ~16 MB; leave headroom for the compiler's
# own buffers and the scalar-prefetch operands).
DEFAULT_VMEM_BUDGET_BYTES = 12 * 1024 * 1024

# The knob schema the cache's staleness hash covers: names, domains and
# the constraint version. Bump ``constraints`` whenever a validity rule
# changes meaning — every cached record becomes stale at once.
_SCHEMA = {
    "version": 1,
    "constraints": 2,
    "knobs": {
        "block_f": [4, 8, 16, 32],
        "megastep_depth": [1, 2, 4, 6, 8, 12],
        "wave_size": [32, 64, 128, 256, 512, 1024],
        "n_slots": [1, 2, 4, 8, 16, 32, 64],
        "stack_capacity": [256, 512, 1024, 2048, 4096],
        "pattern_capacity": [64, 128, 256, 512, 1024, 2048, 4096],
        "store_flush_min": [1, 8, 16, 32, 64],
        # hierarchical / HBM-resident adjacency (DESIGN.md §2)
        "hbm_adjacency": [0, 1],
        "chunk_words": [1, 2, 4, 8, 16, 32],
        "dma_depth": [1, 2, 4],
    },
}

KNOB_NAMES = tuple(sorted(_SCHEMA["knobs"]))


def schema_hash() -> str:
    """Digest of the knob schema — the cache staleness key."""
    blob = json.dumps(_SCHEMA, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:12]


def _is_pow2(v: int) -> bool:
    return v >= 1 and (v & (v - 1)) == 0


@dataclasses.dataclass(frozen=True)
class WorkloadShape:
    """The quantities the validity constraints need: data-graph vertex
    count (``v``), packed bitmap word width (``w``), and the widest
    query the engine pads to (``n_pad``)."""
    v: int
    w: int
    n_pad: int = 64

    @staticmethod
    def for_graph(n_vertices: int, n_pad: int = 64) -> "WorkloadShape":
        return WorkloadShape(v=int(n_vertices),
                             w=(int(n_vertices) + 31) // 32,
                             n_pad=n_pad)


@dataclasses.dataclass(frozen=True)
class CandidateConfig:
    """One point of the search space. ``as_params()`` is the dict shape
    the cache records and the resolution layer consume."""
    block_f: int = 8
    megastep_depth: int = 6
    wave_size: int = 512
    n_slots: int = 8
    stack_capacity: int = 1024
    pattern_capacity: int = 1024
    store_flush_min: int = 16
    hbm_adjacency: int = 0
    chunk_words: int = 8
    dma_depth: int = 2

    def as_params(self) -> dict:
        return {k: int(getattr(self, k)) for k in KNOB_NAMES}


def refine_vmem_bytes(shape: WorkloadShape, block_f: int) -> int:
    """Resident VMEM bytes of the dense refine kernel at ``shape``: the
    whole padded adjacency block plus the candidate and output row
    blocks (int32 words), mirroring ``bitmap_refine``'s padding rules."""
    w_pad = max(128, ((shape.w + 127) // 128) * 128)
    v_pad = ((shape.v + 7) // 8) * 8
    adj = v_pad * w_pad * 4
    row_blocks = 2 * block_f * w_pad * 4        # cand block + out block
    return adj + row_blocks


def refine_hier_vmem_bytes(shape: WorkloadShape, chunk_words: int,
                           dma_depth: int) -> int:
    """Resident VMEM bytes of the *hierarchical* refine kernel: the
    adjacency stays in HBM; VMEM holds one candidate + mask + output row
    (w_pad words each), the row's chunk-id window (worst case every
    chunk stored: ceil(W/C) ids) and ``dma_depth`` in-flight C-word
    chunk buffers — mirroring ``bitmap_refine``'s hier scratch shapes."""
    w_pad = max(128, ((shape.w + 127) // 128) * 128)
    n_chunks = (shape.w + chunk_words - 1) // chunk_words
    rows = 3 * w_pad * 4                 # cand + mask + out row
    ids = n_chunks * 4                   # chunk-id window (kmax ceiling)
    bufs = dma_depth * chunk_words * 4   # in-flight chunk slots
    return rows + ids + bufs


class TunableSpace:
    """Candidate enumeration + validity checking for one backend at one
    workload shape."""

    def __init__(self, backend: str, shape: WorkloadShape,
                 vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES):
        self.backend = backend
        self.shape = shape
        self.vmem_budget_bytes = int(vmem_budget_bytes)
        self.rejected: list[tuple[CandidateConfig, str]] = []

    # -- validity ------------------------------------------------------
    def validate(self, cfg: CandidateConfig) -> str | None:
        """``None`` when ``cfg`` is admissible, else the rejection
        reason. Pure shape arithmetic — nothing here compiles."""
        for name in ("block_f", "megastep_depth", "wave_size", "n_slots",
                     "stack_capacity", "pattern_capacity",
                     "store_flush_min", "chunk_words", "dma_depth"):
            if getattr(cfg, name) < 1:
                return f"{name} must be >= 1"
        if cfg.hbm_adjacency not in (0, 1):
            return f"hbm_adjacency={cfg.hbm_adjacency} must be 0 or 1"
        if cfg.chunk_words > 128 or not _is_pow2(cfg.chunk_words):
            return (f"chunk_words={cfg.chunk_words} must be a power of "
                    "two in [1, 128] (summary packs one bit per chunk "
                    "into u32 words)")
        for name in ("wave_size", "stack_capacity", "pattern_capacity"):
            if not _is_pow2(getattr(cfg, name)):
                return f"{name}={getattr(cfg, name)} is not a power of two"
        if cfg.pattern_capacity < PROBE:
            return (f"pattern_capacity={cfg.pattern_capacity} below the "
                    f"probe window ({PROBE})")
        if self.backend == "pallas" and cfg.block_f % 8:
            return (f"block_f={cfg.block_f} not a multiple of the int32 "
                    "sublane tile (8) on the compiled pallas backend")
        if cfg.stack_capacity < cfg.wave_size:
            return (f"stack_capacity={cfg.stack_capacity} below "
                    f"wave_size={cfg.wave_size} (a full wave of fresh "
                    "roots must fit one stack bank)")
        if cfg.hbm_adjacency:
            need = refine_hier_vmem_bytes(self.shape, cfg.chunk_words,
                                          cfg.dma_depth)
            if need > self.vmem_budget_bytes:
                return (f"hier refine scratch {need} B exceeds the VMEM "
                        f"budget {self.vmem_budget_bytes} B at "
                        f"V={self.shape.v}")
            return None
        need = refine_vmem_bytes(self.shape, cfg.block_f)
        if need > self.vmem_budget_bytes:
            return (f"refine working set {need} B exceeds the VMEM "
                    f"budget {self.vmem_budget_bytes} B at "
                    f"V={self.shape.v}")
        return None

    # -- enumeration ---------------------------------------------------
    def candidates(self, overrides: dict[str, list] | None = None
                   ) -> list[CandidateConfig]:
        """Valid candidates from the cross product of the knob domains
        (``overrides`` narrows any knob's domain — the smoke tuner uses
        this to keep CI runs to a handful of points). Invalid points
        land in ``self.rejected`` with their reason."""
        domains = {k: list(v) for k, v in _SCHEMA["knobs"].items()}
        for k, vals in (overrides or {}).items():
            if k not in domains:
                raise KeyError(f"unknown tunable knob {k!r}; "
                               f"known: {sorted(domains)}")
            domains[k] = list(vals)
        out = []
        names = KNOB_NAMES
        for values in itertools.product(*(domains[n] for n in names)):
            cfg = CandidateConfig(**dict(zip(names, values)))
            reason = self.validate(cfg)
            if reason is None:
                out.append(cfg)
            else:
                self.rejected.append((cfg, reason))
        return out
