"""Persistent tuning cache (DESIGN.md §9): best-config records keyed by
``(backend, device_kind, quantized graph size)`` in a versioned JSON
artifact (``TUNING_CACHE.json`` at the repo root, override with
``REPRO_TUNING_CACHE``).

Staleness: every record carries the knob-schema hash it was tuned
under (``tuning/space.py``). A lookup under a different schema returns
a miss — a schema change silently invalidates every stale record
instead of resolving knobs whose meaning moved.

Key quantization: the data-graph vertex count is bucketed to the next
power of two, so one tuned record covers the workload-shape
neighborhood it was measured in; the tiny graphs the unit tests build
land in different buckets and keep the deterministic built-in defaults.
"""
from __future__ import annotations

import json
import os
import pathlib
import threading

from .space import schema_hash

__all__ = ["TuningCache", "cache_key", "quantize_vertices",
           "device_kind", "default_cache_path", "load_default_cache"]

CACHE_VERSION = 1
_ENV_PATH = "REPRO_TUNING_CACHE"


def default_cache_path() -> pathlib.Path:
    env = os.environ.get(_ENV_PATH)
    if env:
        return pathlib.Path(env)
    # src/repro/tuning/cache.py -> repo root
    return pathlib.Path(__file__).resolve().parents[3] / \
        "TUNING_CACHE.json"


def quantize_vertices(n_vertices: int) -> int:
    """Bucket |V| to the next power of two (minimum 32)."""
    v = max(32, int(n_vertices))
    return 1 << (v - 1).bit_length()


def device_kind() -> str:
    """Normalized accelerator kind of the default jax device ("cpu",
    "tpu-v4", ...); "unknown" when jax is unavailable (the cache module
    stays importable without an accelerator runtime)."""
    try:
        import jax
        kind = jax.devices()[0].device_kind
    except Exception:                              # pragma: no cover
        return "unknown"
    return str(kind).strip().lower().replace(" ", "-")


def cache_key(backend: str, dev_kind: str, n_vertices: int) -> str:
    return f"{backend}/{dev_kind}/v{quantize_vertices(n_vertices)}"


class TuningCache:
    """Read/write view over one TUNING_CACHE.json file.

    File shape::

        {"version": 1,
         "schema_hash": "<knob-schema digest>",
         "records": {
           "jnp/cpu/v128": {"name": "jnp/cpu/v128",
                            "schema_hash": "...",
                            "params": {"block_f": 8, ...},
                            "measured": {"qps": ..., ...}}}}
    """

    def __init__(self, path: pathlib.Path | str | None = None):
        self.path = pathlib.Path(path) if path is not None \
            else default_cache_path()
        self._lock = threading.Lock()
        self._data = self._load()

    def _load(self) -> dict:
        try:
            data = json.loads(self.path.read_text())
        except (OSError, json.JSONDecodeError):
            data = {}
        if (not isinstance(data, dict)
                or data.get("version") != CACHE_VERSION
                or not isinstance(data.get("records"), dict)):
            data = {"version": CACHE_VERSION,
                    "schema_hash": schema_hash(), "records": {}}
        return data

    # -- reads ---------------------------------------------------------
    def records(self) -> dict:
        return dict(self._data["records"])

    def lookup_key(self, key: str) -> dict | None:
        """The record under ``key``, or None on a miss *or* a schema
        mismatch (stale record — tuned under a different knob schema)."""
        rec = self._data["records"].get(key)
        if not isinstance(rec, dict):
            return None
        if rec.get("schema_hash") != schema_hash():
            return None
        params = rec.get("params")
        if not isinstance(params, dict):
            return None
        return rec

    def lookup(self, backend: str, dev_kind: str,
               n_vertices: int) -> dict | None:
        return self.lookup_key(cache_key(backend, dev_kind, n_vertices))

    # -- writes --------------------------------------------------------
    def put(self, backend: str, dev_kind: str, n_vertices: int,
            params: dict, measured: dict | None = None) -> dict:
        """Insert/replace the best-config record for one key and persist
        the file. Returns the stored record."""
        key = cache_key(backend, dev_kind, n_vertices)
        rec = {"name": key, "schema_hash": schema_hash(),
               "params": {k: int(v) for k, v in params.items()},
               "measured": dict(measured or {})}
        with self._lock:
            self._data["schema_hash"] = schema_hash()
            self._data["records"][key] = rec
            self.path.write_text(
                json.dumps(self._data, indent=2, sort_keys=True) + "\n")
        return rec


# In-memory default-cache singleton, invalidated on file mtime change
# (WaveScheduler construction consults it — a JSON parse per scheduler
# would be noise, a parse per file change is free).
_default_cache: TuningCache | None = None
_default_mtime: float | None = None
_default_lock = threading.Lock()


def load_default_cache() -> TuningCache:
    global _default_cache, _default_mtime
    path = default_cache_path()
    try:
        mtime = path.stat().st_mtime
    except OSError:
        mtime = None
    with _default_lock:
        if (_default_cache is None or _default_mtime != mtime
                or _default_cache.path != path):
            _default_cache = TuningCache(path)
            _default_mtime = mtime
        return _default_cache
