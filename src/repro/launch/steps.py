"""Per-(arch × shape) step builders for the multi-pod dry-run and the
real drivers.

``build_cell(arch_id, shape_name, mesh)`` returns a :class:`Cell` with the
step function, ShapeDtypeStruct inputs (never allocated), input/output
shardings, and donation info — everything ``jax.jit(...).lower()`` needs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.common import ArchSpec, ShapeCell
from ..configs.registry import get_arch
from ..models import gnn, recsys, transformer
from ..models.equivariant import equiv_batched_loss, equiv_energy_loss, equiv_init
from ..models.gnn import gnn_init
from ..models.recsys import din_init
from ..models.transformer import (init_decode_state, lm_decode_step,
                                  lm_init, lm_logits, lm_loss)
from ..training.optimizer import AdamWConfig, adamw_init, adamw_update
from .sharding import dp, opt_specs, param_specs, _sanitize


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    fn: Callable
    args: tuple                  # ShapeDtypeStruct pytrees
    in_specs: tuple              # PartitionSpec pytrees
    out_specs: Any
    donate: tuple = ()
    static: dict | None = None

    def lower(self, mesh):
        to_sh = lambda t: jax.tree.map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
        jf = jax.jit(self.fn,
                     in_shardings=tuple(to_sh(s) for s in self.in_specs),
                     out_shardings=to_sh(self.out_specs),
                     donate_argnums=self.donate)
        with mesh:
            return jf.lower(*self.args)


def _opt_cfg(spec: ArchSpec) -> AdamWConfig:
    big = spec.family == "lm" and spec.config.n_params() > 1e11
    return AdamWConfig(state_dtype=jnp.bfloat16 if big else jnp.float32)


# ====================================================================== LM
def _lm_param_trees(spec: ArchSpec, mesh, batch_div: bool = True,
                    seq_axis: str | None = "model"):
    import dataclasses as dc
    cfg = spec.config
    if batch_div:
        dpa = dp(mesh)
        tp = "model" if seq_axis else None
        moe = dc.replace(
            cfg.moe, ep_axis="model", mesh=mesh, dp_axes=dpa,
            seq_axis=seq_axis) if cfg.moe else None
        mla = dc.replace(cfg.mla, dp_axis=dpa, tp_axis=tp) \
            if cfg.mla else None
        cfg = dc.replace(cfg, dp_axis=dpa, tp_axis=tp, moe=moe, mla=mla,
                         mesh=mesh)
    pshape = jax.eval_shape(lambda k: lm_init(k, cfg), jax.random.key(0))
    pspec = param_specs(pshape, mesh, "lm")
    ocfg = _opt_cfg(spec)
    oshape = jax.eval_shape(functools.partial(adamw_init, cfg=ocfg), pshape)
    ospec = opt_specs(oshape, pspec)
    return cfg, pshape, pspec, oshape, ospec, ocfg


def _lm_train_cell(spec: ArchSpec, cell: ShapeCell, mesh) -> Cell:
    cfg, pshape, pspec, oshape, ospec, ocfg = _lm_param_trees(spec, mesh)
    b = cell.dims["global_batch"]
    s = cell.dims["seq_len"]
    batch = {"tokens": sds((b, s), jnp.int32),
             "targets": sds((b, s), jnp.int32)}
    bspec = {"tokens": P(dp(mesh), None), "targets": P(dp(mesh), None)}

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch))(params)
        params, opt_state = adamw_update(params, grads, opt_state, ocfg)
        return params, opt_state, loss

    return Cell(spec.arch_id, cell.name, train_step,
                (pshape, oshape, batch), (pspec, ospec, bspec),
                (pspec, ospec, P()), donate=(0, 1))


def _lm_prefill_cell(spec: ArchSpec, cell: ShapeCell, mesh) -> Cell:
    cfg, pshape, pspec, *_ = _lm_param_trees(spec, mesh)
    b = cell.dims["global_batch"]
    s = cell.dims["seq_len"]
    tokens = sds((b, s), jnp.int32)

    def prefill(params, tokens):
        return lm_logits(params, cfg, tokens)

    return Cell(spec.arch_id, cell.name, prefill,
                (pshape, tokens), (pspec, P(dp(mesh), None)),
                P(dp(mesh), None, "model"))


def _lm_decode_cell(spec: ArchSpec, cell: ShapeCell, mesh) -> Cell:
    import dataclasses as dc
    b = cell.dims["global_batch"]
    kv = cell.dims["kv_len"]
    batch_div = b % _size(mesh, dp(mesh)) == 0
    cfg, pshape, pspec, *_ = _lm_param_trees(spec, mesh,
                                             batch_div=batch_div,
                                             seq_axis=None)
    # §Perf hillclimb A iter 2: flash-decoding for MLA archs — the latent
    # cache shards over the sequence; shards combine via log-sum-exp psum
    flash = (cfg.mla is not None and batch_div
             and kv % mesh.shape["model"] == 0)
    if flash:
        cfg = dc.replace(cfg, mla=dc.replace(
            cfg.mla, mesh=mesh, decode_flash=True,
            dp_axis=dp(mesh), tp_axis="model"))
    state_shape = jax.eval_shape(
        functools.partial(init_decode_state, cfg, b, kv))
    dpa = dp(mesh)
    b_div = b % _size(mesh, dpa) == 0

    def cache_spec(leaf):
        nd = len(leaf.shape)
        if nd >= 4:  # [L, B, S, ...] kv or latent cache
            if b_div:
                if flash and nd == 4:
                    # MLA flash-decoding: latent cache seq-sharded; the
                    # shard_map owns the DUS + log-sum-exp combine
                    return _sanitize(P(None, dpa, "model", None),
                                     leaf.shape, mesh)
                # GQA path: batch over data; the TRAILING head_dim over
                # model. Sharding the sequence instead puts the per-token
                # dynamic-update-slice astride shard boundaries and the
                # partitioner all-gathers the whole cache every layer.
                return _sanitize(
                    P(*((None, dpa) + (None,) * (nd - 3) + ("model",))),
                    leaf.shape, mesh)
            seq_axes = (dpa, "model") if isinstance(dpa, str) \
                else tuple(dpa) + ("model",)
            return _sanitize(
                P(*((None, None, seq_axes) + (None,) * (nd - 3))),
                leaf.shape, mesh)
        return P(*([None] * nd))

    sspec = jax.tree.map(cache_spec, state_shape)
    tokens = sds((b, 1), jnp.int32)
    tspec = P(dpa, None) if b_div else P(None, None)

    def serve_step(params, state, tokens):
        return lm_decode_step(params, cfg, tokens, state)

    return Cell(spec.arch_id, cell.name, serve_step,
                (pshape, state_shape, tokens), (pspec, sspec, tspec),
                (_sanitize(P(dpa, None, "model"),
                           (b, 1, cfg.vocab), mesh), sspec),
                donate=(1,))


def _size(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# ===================================================================== GNN
def _gnn_param_trees(spec: ArchSpec, mesh, d_in, n_classes):
    import dataclasses as dc
    cfg = dc.replace(spec.config, d_in=d_in, n_classes=n_classes)
    pshape = jax.eval_shape(lambda k: gnn_init(k, cfg), jax.random.key(0))
    pspec = param_specs(pshape, mesh, "gnn")
    ocfg = _opt_cfg(spec)
    oshape = jax.eval_shape(functools.partial(adamw_init, cfg=ocfg), pshape)
    return cfg, pshape, pspec, oshape, opt_specs(oshape, pspec), ocfg


def _gnn_full_cell(spec: ArchSpec, cell: ShapeCell, mesh) -> Cell:
    d = cell.dims
    cfg, pshape, pspec, oshape, ospec, ocfg = _gnn_param_trees(
        spec, mesh, d["d_feat"], d["n_classes"])
    n, e2 = d["n_nodes"], 2 * d["n_edges"]
    dpa = dp(mesh)
    batch = {"x": sds((n, d["d_feat"]), jnp.float32),
             "edge_index": sds((2, e2), jnp.int32),
             "labels": sds((n,), jnp.int32),
             "mask": sds((n,), jnp.float32)}
    bspec = {"x": _sanitize(P(dpa, None), (n, d["d_feat"]), mesh),
             "edge_index": _sanitize(P(None, dpa), (2, e2), mesh),
             "labels": _sanitize(P(dpa), (n,), mesh),
             "mask": _sanitize(P(dpa), (n,), mesh)}

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: gnn.gnn_loss(p, cfg, batch["x"], batch["edge_index"],
                                   batch["labels"], batch["mask"]))(params)
        params, opt_state = adamw_update(params, grads, opt_state, ocfg)
        return params, opt_state, loss

    return Cell(spec.arch_id, cell.name, train_step,
                (pshape, oshape, batch), (pspec, ospec, bspec),
                (pspec, ospec, P()), donate=(0, 1))


def _gnn_sampled_cell(spec: ArchSpec, cell: ShapeCell, mesh) -> Cell:
    d = cell.dims
    import dataclasses as dc
    cfg, pshape, pspec, oshape, ospec, ocfg = _gnn_param_trees(
        spec, mesh, d["d_feat"], d["n_classes"])
    cfg = dc.replace(cfg, n_layers=2)       # 2-hop fanout 15-10
    pshape = jax.eval_shape(lambda k: gnn_init(k, cfg), jax.random.key(0))
    pspec = param_specs(pshape, mesh, "gnn")
    oshape = jax.eval_shape(functools.partial(adamw_init, cfg=ocfg), pshape)
    ospec = opt_specs(oshape, pspec)
    b, f0, f1 = d["batch_nodes"], d["fanout0"], d["fanout1"]
    n1, n2 = b * f0, b * f0 * f1
    dpa = dp(mesh)
    batch = {
        "feats": [sds((m, d["d_feat"]), jnp.float32) for m in (b, n1, n2)],
        "nbr_idx": [sds((b, f0), jnp.int32), sds((n1, f1), jnp.int32)],
        "nbr_valid": [sds((b, f0), bool), sds((n1, f1), bool)],
        "labels": sds((b,), jnp.int32),
    }
    bspec = {
        "feats": [_sanitize(P(dpa, None), (m, d["d_feat"]), mesh)
                  for m in (b, n1, n2)],
        "nbr_idx": [_sanitize(P(dpa, None), (b, f0), mesh),
                    _sanitize(P(dpa, None), (n1, f1), mesh)],
        "nbr_valid": [_sanitize(P(dpa, None), (b, f0), mesh),
                      _sanitize(P(dpa, None), (n1, f1), mesh)],
        "labels": _sanitize(P(dpa), (b,), mesh),
    }

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            logits = gnn.gnn_forward_sampled(
                p, cfg, batch["feats"], batch["nbr_idx"],
                batch["nbr_valid"])
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            return -jnp.take_along_axis(
                logp, batch["labels"][:, None], axis=1).mean()
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = adamw_update(params, grads, opt_state, ocfg)
        return params, opt_state, loss

    return Cell(spec.arch_id, cell.name, train_step,
                (pshape, oshape, batch), (pspec, ospec, bspec),
                (pspec, ospec, P()), donate=(0, 1))


def _gnn_mol_cell(spec: ArchSpec, cell: ShapeCell, mesh) -> Cell:
    d = cell.dims
    nb = d["batch"]
    n_tot = nb * d["n_nodes"]
    e_tot = nb * d["n_edges"] * 2
    cfg, pshape, pspec, oshape, ospec, ocfg = _gnn_param_trees(
        spec, mesh, d["n_species"], 2)
    dpa = dp(mesh)
    batch = {"x": sds((n_tot, d["n_species"]), jnp.float32),
             "edge_index": sds((2, e_tot), jnp.int32),
             "graph_id": sds((n_tot,), jnp.int32),
             "labels": sds((nb,), jnp.int32)}
    bspec = {"x": _sanitize(P(dpa, None), (n_tot, d["n_species"]), mesh),
             "edge_index": _sanitize(P(None, dpa), (2, e_tot), mesh),
             "graph_id": _sanitize(P(dpa), (n_tot,), mesh),
             "labels": _sanitize(P(dpa), (nb,), mesh)}

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            logits = gnn.gnn_forward_batched(
                p, cfg, batch["x"], batch["edge_index"],
                batch["graph_id"], nb)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            return -jnp.take_along_axis(
                logp, batch["labels"][:, None], axis=1).mean()
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = adamw_update(params, grads, opt_state, ocfg)
        return params, opt_state, loss

    return Cell(spec.arch_id, cell.name, train_step,
                (pshape, oshape, batch), (pspec, ospec, bspec),
                (pspec, ospec, P()), donate=(0, 1))


# =================================================================== equiv
def _equiv_cells(spec: ArchSpec, cell: ShapeCell, mesh) -> Cell:
    import dataclasses as dc
    d = cell.dims
    cfg = spec.config
    # §Perf: edge-chunked message streaming for full-batch-large cells
    if d.get("n_edges", 0) > 4_000_000:
        cfg = dc.replace(cfg, edge_chunk=1 << 20)
    pshape = jax.eval_shape(lambda k: equiv_init(k, cfg),
                            jax.random.key(0))
    pspec = param_specs(pshape, mesh, "equiv")
    ocfg = _opt_cfg(spec)
    oshape = jax.eval_shape(functools.partial(adamw_init, cfg=ocfg), pshape)
    ospec = opt_specs(oshape, pspec)
    dpa = dp(mesh)

    if cell.kind == "batched_graphs":
        nb = d["batch"]
        n_tot, e_tot = nb * d["n_nodes"], nb * d["n_edges"] * 2
        batch = {"species": sds((n_tot,), jnp.int32),
                 "positions": sds((n_tot, 3), jnp.float32),
                 "edge_index": sds((2, e_tot), jnp.int32),
                 "graph_id": sds((n_tot,), jnp.int32),
                 "energy": sds((nb,), jnp.float32)}
        loss_of = lambda p, b: equiv_batched_loss(p, cfg, b, nb)
    else:
        if cell.kind == "sampled":
            n = d["batch_nodes"] * (1 + d["fanout0"]
                                    + d["fanout0"] * d["fanout1"])
            e2 = 2 * d["batch_nodes"] * (d["fanout0"]
                                         + d["fanout0"] * d["fanout1"])
        else:
            n, e2 = d["n_nodes"], 2 * d["n_edges"]
        batch = {"species": sds((n,), jnp.int32),
                 "positions": sds((n, 3), jnp.float32),
                 "edge_index": sds((2, e2), jnp.int32),
                 "energy": sds((), jnp.float32)}
        loss_of = lambda p, b: equiv_energy_loss(p, cfg, b)

    bspec = jax.tree.map(
        lambda s: _sanitize(
            P(*((dpa,) + (None,) * (len(s.shape) - 1)))
            if len(s.shape) >= 1 and s.shape[0] not in (2,)
            else P(*((None, dpa) + (None,) * (len(s.shape) - 2))),
            s.shape, mesh),
        batch)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_of(p, batch))(params)
        params, opt_state = adamw_update(params, grads, opt_state, ocfg)
        return params, opt_state, loss

    return Cell(spec.arch_id, cell.name, train_step,
                (pshape, oshape, batch), (pspec, ospec, bspec),
                (pspec, ospec, P()), donate=(0, 1))


# ================================================================== recsys
def _din_cells(spec: ArchSpec, cell: ShapeCell, mesh) -> Cell:
    cfg = spec.config
    pshape = jax.eval_shape(lambda k: din_init(k, cfg), jax.random.key(0))
    pspec = param_specs(pshape, mesh, "recsys")
    ocfg = _opt_cfg(spec)
    oshape = jax.eval_shape(functools.partial(adamw_init, cfg=ocfg), pshape)
    ospec = opt_specs(oshape, pspec)
    dpa = dp(mesh)
    L = cfg.seq_len

    def batch_of(b):
        return ({"target_item": sds((b,), jnp.int32),
                 "target_cat": sds((b,), jnp.int32),
                 "hist_items": sds((b, L), jnp.int32),
                 "hist_cats": sds((b, L), jnp.int32),
                 "hist_mask": sds((b, L), jnp.float32),
                 "dense_feats": sds((b, cfg.n_dense_feats), jnp.float32),
                 "labels": sds((b,), jnp.int32)})

    def spec_of(b):
        return jax.tree.map(
            lambda s: _sanitize(
                P(*((dpa,) + (None,) * (len(s.shape) - 1))), s.shape, mesh),
            batch_of(b))

    if cell.kind == "recsys_train":
        b = cell.dims["batch"]
        batch, bspec = batch_of(b), spec_of(b)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: recsys.din_loss(p, cfg, batch))(params)
            params, opt_state = adamw_update(params, grads, opt_state, ocfg)
            return params, opt_state, loss

        return Cell(spec.arch_id, cell.name, train_step,
                    (pshape, oshape, batch), (pspec, ospec, bspec),
                    (pspec, ospec, P()), donate=(0, 1))

    if cell.kind == "recsys_serve":
        b = cell.dims["batch"]
        batch, bspec = batch_of(b), spec_of(b)
        batch.pop("labels"); bspec.pop("labels")

        def serve(params, batch):
            return recsys.din_forward(params, cfg, batch)

        return Cell(spec.arch_id, cell.name, serve, (pshape, batch),
                    (pspec, bspec), _sanitize(P(dpa), (b,), mesh))

    # retrieval: 1 user x n_candidates
    n = cell.dims["n_candidates"]
    user = {"hist_items": sds((L,), jnp.int32),
            "hist_cats": sds((L,), jnp.int32),
            "hist_mask": sds((L,), jnp.float32),
            "dense_feats": sds((cfg.n_dense_feats,), jnp.float32)}
    uspec = jax.tree.map(lambda s: P(*([None] * len(s.shape))), user)
    cands = (sds((n,), jnp.int32), sds((n,), jnp.int32))
    cspec = (_sanitize(P(dpa), (n,), mesh), _sanitize(P(dpa), (n,), mesh))

    def retrieve(params, user, cand_items, cand_cats):
        return recsys.din_score_candidates(params, cfg, user, cand_items,
                                           cand_cats)

    return Cell(spec.arch_id, cell.name, retrieve,
                (pshape, user) + cands, (pspec, uspec) + cspec,
                _sanitize(P(dpa), (n,), mesh))


# ================================================================= matcher
def _hier_graph_structs(v: int, w: int, d: dict):
    """Shape structs + sharding for the hierarchical adjacency layout
    (DESIGN.md §2), gated on the cell's ``hier_adjacency`` dims flag.

    The summary shards its vertex axis over the model axis exactly like
    the dense ``adj_bitmap`` block did; ``chunk_ptr`` and the chunk
    store are indexed by global offsets, so they replicate — they are
    O(V) / O(E) words, which is the whole point of the layout next to
    the O(V²/32) dense block. ``n_stored`` / ``kmax`` / ``chunk_words``
    are dims knobs so the dry-run can describe a real graph's
    footprint.
    """
    from ..core.engine_step import GraphArrays
    cw = int(d.get("chunk_words", 8))
    n_chunks = (w + cw - 1) // cw
    swn = (n_chunks + 31) // 32
    kmax = int(d.get("kmax", min(64, max(1, n_chunks))))
    n_stored = int(d.get("n_stored", v * min(4, max(1, n_chunks)))) + kmax
    g = GraphArrays(
        adj_bitmap=None, n_vertices=sds((), jnp.int32),
        adj_summary=sds((v, swn), jnp.uint32),
        chunk_ptr=sds((v + 1,), jnp.int32),
        chunk_id=sds((n_stored,), jnp.int32),
        chunk_data=sds((n_stored, cw), jnp.uint32),
        chunk_pad=sds((kmax,), jnp.int32))
    gspec = GraphArrays(
        adj_bitmap=None, n_vertices=P(),
        adj_summary=P("model", None), chunk_ptr=P(None),
        chunk_id=P(None), chunk_data=P(None, None), chunk_pad=P(None))
    return g, gspec


def _matcher_cell(spec: ArchSpec, cell: ShapeCell, mesh) -> Cell:
    """Lower the *real* multi-query wave program (``expand_wave_mq``)
    that the shared-wave scheduler dispatches — slot-stacked query banks
    and hashed Δ store plus per-row slot/depth lanes — not the 1-slot
    single-query facade. The distributed shard-as-segments matcher rides
    exactly this program, so the dry-run/roofline numbers describe
    production waves with mixed-query (and mixed-shard) rows."""
    from ..core.engine_step import (MASK_WORDS, N_PAD, GraphArrays,
                                    QueryBank, expand_wave_mq)
    from ..patterns.store import PatternStoreBank
    d = cell.dims
    v = d["n_vertices"]
    w = (v + 31) // 32
    f = d["wave_size"]
    kpr = d["kpr"]
    s = d.get("n_slots", 16)
    cap = d.get("pattern_capacity", 65_536)
    dpa = dp(mesh)
    if d.get("hier_adjacency"):
        g, gspec = _hier_graph_structs(v, w, d)
    else:
        g = GraphArrays(adj_bitmap=sds((v, w), jnp.uint32),
                        n_vertices=sds((), jnp.int32))
        gspec = GraphArrays(adj_bitmap=P("model", None),
                            n_vertices=P())
    qb = QueryBank(cand_bitmap=sds((s, N_PAD, w), jnp.uint32),
                   nbr_mask=sds((s, N_PAD, N_PAD), bool),
                   n_query=sds((s,), jnp.int32),
                   learn=sds((s,), bool))
    tb = PatternStoreBank(key_pos=sds((s, cap), jnp.int32),
                          key_v=sds((s, cap), jnp.int32),
                          phi=sds((s, cap), jnp.int32),
                          mu=sds((s, cap), jnp.int32),
                          mask=sds((s, cap, MASK_WORDS), jnp.uint32),
                          valid=sds((s, cap), bool),
                          hits=sds((s, cap), jnp.int32))
    frontier = sds((f, N_PAD), jnp.int32)
    used = sds((f, w), jnp.uint32)
    phi = sds((f, N_PAD + 1), jnp.int32)
    row_valid = sds((f,), bool)
    query_slot = sds((f,), jnp.int32)
    depth = sds((f,), jnp.int32)

    # banks replicate the (small) slot axis; the hashed Δ store is
    # O(capacity) — data-graph independent and a few MB at web scale —
    # so it replicates too (the dense [S, N_PAD, V] bank it replaced had
    # to shard its vertex axis over the model axis)
    qbspec = QueryBank(cand_bitmap=P(None, None, None),
                       nbr_mask=P(None, None, None),
                       n_query=P(None), learn=P(None))
    tbspec = PatternStoreBank(key_pos=P(None, None), key_v=P(None, None),
                              phi=P(None, None), mu=P(None, None),
                              mask=P(None, None, None),
                              valid=P(None, None), hits=P(None, None))
    fspec = (_sanitize(P(dpa, None), (f, N_PAD), mesh),
             _sanitize(P(dpa, None), (f, w), mesh),
             _sanitize(P(dpa, None), (f, N_PAD + 1), mesh),
             _sanitize(P(dpa), (f,), mesh),
             _sanitize(P(dpa), (f,), mesh),
             _sanitize(P(dpa), (f,), mesh))

    def step(g, qb, tb, frontier, used, phi, row_valid, query_slot,
             depth):
        return expand_wave_mq(g, qb, tb, frontier, used, phi, row_valid,
                              query_slot, depth, kpr=kpr)

    res_spec, tb_out_spec = jax.tree.map(lambda _: P(), jax.eval_shape(
        step, g, qb, tb, frontier, used, phi, row_valid, query_slot,
        depth))
    # per-row result lanes follow the frontier's data sharding; the
    # returned store handle stays replicated like its input
    res_spec = res_spec._replace(
        child_v=_sanitize(P(dpa, None), (f, kpr), mesh),
        child_valid=_sanitize(P(dpa, None), (f, kpr), mesh),
        pruned_v=_sanitize(P(dpa, None), (f, kpr), mesh),
        leftover=_sanitize(P(dpa, None), (f, w), mesh),
        partial_mask=_sanitize(P(dpa, None), (f, MASK_WORDS), mesh),
        refined_empty=_sanitize(P(dpa), (f,), mesh),
        n_children=_sanitize(P(dpa), (f,), mesh),
        n_leftover=_sanitize(P(dpa), (f,), mesh),
        n_pruned=_sanitize(P(dpa), (f,), mesh),
        n_inj=_sanitize(P(dpa), (f,), mesh))

    return Cell(spec.arch_id, cell.name, step,
                (g, qb, tb, frontier, used, phi, row_valid, query_slot,
                 depth),
                (gspec, qbspec, tbspec) + fspec,
                (res_spec, tb_out_spec))


def _matcher_stack_cell(spec: ArchSpec, cell: ShapeCell, mesh) -> Cell:
    """Lower the device-resident scheduling step
    (``run_device_megastep``): per-slot frontier stacks (StackBank),
    on-device wave repacking and Lemma-4 resolution. Only root lanes
    cross the boundary in; only per-slot scalars + embedding rows come
    back — this is the program the serving scheduler dispatches when
    ``MatchOptions.device_stacks`` is on, so its dry-run/roofline
    numbers describe the steady-state serving step."""
    from ..core.engine_step import (MASK_WORDS, N_PAD, GraphArrays,
                                    QueryBank, StackBank,
                                    run_device_megastep)
    from ..patterns.store import PatternStoreBank
    d = cell.dims
    v = d["n_vertices"]
    w = (v + 31) // 32
    f = d["wave_size"]
    kpr = d["kpr"]
    s = d.get("n_slots", 16)
    cap = d.get("pattern_capacity", 65_536)
    depth_cap = d["stack_capacity"]
    t_max = d.get("megastep_depth", 6)
    emb_cap = d.get("emb_cap", max(512, f * kpr))
    dpa = dp(mesh)
    if d.get("hier_adjacency"):
        g, gspec = _hier_graph_structs(v, w, d)
    else:
        g = GraphArrays(adj_bitmap=sds((v, w), jnp.uint32),
                        n_vertices=sds((), jnp.int32))
        gspec = GraphArrays(adj_bitmap=P("model", None),
                            n_vertices=P())
    qb = QueryBank(cand_bitmap=sds((s, N_PAD, w), jnp.uint32),
                   nbr_mask=sds((s, N_PAD, N_PAD), bool),
                   n_query=sds((s,), jnp.int32),
                   learn=sds((s,), bool))
    tb = PatternStoreBank(key_pos=sds((s, cap), jnp.int32),
                          key_v=sds((s, cap), jnp.int32),
                          phi=sds((s, cap), jnp.int32),
                          mu=sds((s, cap), jnp.int32),
                          mask=sds((s, cap, MASK_WORDS), jnp.uint32),
                          valid=sds((s, cap), bool),
                          hits=sds((s, cap), jnp.int32))
    sb = StackBank(frontier=sds((s, depth_cap, N_PAD), jnp.int32),
                   used=sds((s, depth_cap, w), jnp.uint32),
                   phi=sds((s, depth_cap, N_PAD + 1), jnp.int32),
                   depth=sds((s, depth_cap), jnp.int32),
                   cand=sds((s, depth_cap, w), jnp.uint32),
                   state=sds((s, depth_cap), jnp.int8),
                   gamma=sds((s, depth_cap, MASK_WORDS), jnp.uint32),
                   outstanding=sds((s, depth_cap), jnp.int32),
                   reported=sds((s, depth_cap), bool),
                   parent=sds((s, depth_cap), jnp.int32),
                   pstack=sds((s, depth_cap), jnp.int32),
                   ptop=sds((s,), jnp.int32))
    in_root = sds((f,), jnp.int32)
    in_rid = sds((f,), jnp.int32)
    in_slot = sds((f,), jnp.int32)
    in_valid = sds((f,), bool)
    active = sds((s,), bool)

    # the stack is per-slot scheduler state — O(n_slots * depth_cap),
    # data-graph independent — so like the query/store banks it
    # replicates; only the (rare) root lanes are data-sharded
    qbspec = QueryBank(cand_bitmap=P(None, None, None),
                       nbr_mask=P(None, None, None),
                       n_query=P(None), learn=P(None))
    tbspec = PatternStoreBank(key_pos=P(None, None), key_v=P(None, None),
                              phi=P(None, None), mu=P(None, None),
                              mask=P(None, None, None),
                              valid=P(None, None), hits=P(None, None))
    sbspec = jax.tree.map(
        lambda x: P(*([None] * len(x.shape))), sb)
    rspec = _sanitize(P(dpa), (f,), mesh)

    def step(g, qb, tb, sb, in_root, in_rid, in_slot, in_valid, active):
        return run_device_megastep(
            g, qb, tb, sb, in_root, in_rid, in_slot, in_valid, active,
            jnp.int32(1), True, jnp.int32(t_max),
            kpr=kpr, emb_cap=emb_cap)

    out_spec = jax.tree.map(lambda _: P(), jax.eval_shape(
        step, g, qb, tb, sb, in_root, in_rid, in_slot, in_valid,
        active))
    return Cell(spec.arch_id, cell.name, step,
                (g, qb, tb, sb, in_root, in_rid, in_slot, in_valid,
                 active),
                (gspec, qbspec, tbspec, sbspec, rspec, rspec, rspec,
                 rspec, P(None)),
                out_spec)


# ================================================================ dispatch
def build_cell(arch_id: str, shape_name: str, mesh) -> Cell:
    spec = get_arch(arch_id)
    cell = spec.shape(shape_name)
    if spec.family == "lm":
        if cell.kind == "train":
            return _lm_train_cell(spec, cell, mesh)
        if cell.kind == "prefill":
            return _lm_prefill_cell(spec, cell, mesh)
        return _lm_decode_cell(spec, cell, mesh)
    if spec.family == "gnn":
        if cell.kind == "full_graph":
            return _gnn_full_cell(spec, cell, mesh)
        if cell.kind == "sampled":
            return _gnn_sampled_cell(spec, cell, mesh)
        return _gnn_mol_cell(spec, cell, mesh)
    if spec.family == "equiv":
        return _equiv_cells(spec, cell, mesh)
    if spec.family == "recsys":
        return _din_cells(spec, cell, mesh)
    if spec.family == "matcher":
        if "stack_capacity" in cell.dims:
            return _matcher_stack_cell(spec, cell, mesh)
        return _matcher_cell(spec, cell, mesh)
    raise ValueError(spec.family)
