"""End-to-end LM training driver with checkpoint/restart fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --steps 300 --batch 8 --seq 256 --ckpt-dir /tmp/run1 --resume auto

Runs on whatever devices exist (1 CPU here; the production mesh via the
same sharding rules when launched on real pods). Features exercised:
  * config-driven model/optimizer construction (--arch picks the smoke or
    full config; --scale smoke|full),
  * resumable deterministic data pipeline,
  * atomic checkpointing every --ckpt-every steps + auto-resume,
  * simulated failure injection (--fail-at-step) proving restart works,
  * MoE router-bias load balancing (aux-free) when the arch is MoE.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import get_arch
from ..data.lm_data import LMStreamConfig, TokenStream
from ..models import transformer
from ..training import checkpoint
from ..training.optimizer import AdamWConfig, adamw_init, adamw_update


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--scale", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", choices=["auto", "never"], default="auto")
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="inject a crash once (restart with --resume auto)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    assert spec.family == "lm", "train.py drives LM archs; see gnn example"
    cfg = spec.smoke_config if args.scale == "smoke" else spec.config
    ocfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(10, args.steps // 20))

    key = jax.random.key(0)
    params = transformer.lm_init(key, cfg)
    opt = adamw_init(params, ocfg)
    stream = TokenStream(LMStreamConfig(vocab=cfg.vocab, batch=args.batch,
                                        seq_len=args.seq))
    start = 0
    if args.ckpt_dir and args.resume == "auto":
        last = checkpoint.latest_step(args.ckpt_dir)
        if last is not None:
            (params, opt), start, extra = checkpoint.restore(
                args.ckpt_dir, (params, opt))
            stream = TokenStream.from_state(stream.cfg,
                                            extra["stream"])
            print(f"[resume] restored step {start}")

    @jax.jit
    def train_step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: transformer.lm_loss(p, cfg, batch))(params)
        params, opt = adamw_update(params, grads, opt, ocfg)
        return params, opt, loss

    t0 = time.time()
    losses = []
    for step in range(start, args.steps):
        if args.fail_at_step is not None and step == args.fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        batch = stream.next_batch()
        params, opt, loss = train_step(
            params, opt, {k: jnp.asarray(v) for k, v in batch.items()})
        losses.append(float(loss))
        if step % args.log_every == 0 or step == args.steps - 1:
            tok_s = (args.batch * args.seq * (step - start + 1)
                     / max(time.time() - t0, 1e-9))
            print(f"step {step:5d} loss {float(loss):.4f} "
                  f"tok/s {tok_s:,.0f}")
        if args.ckpt_dir and ((step + 1) % args.ckpt_every == 0
                              or step == args.steps - 1):
            checkpoint.save(args.ckpt_dir, step + 1, (params, opt),
                            extra={"stream": stream.state(),
                                   "loss": float(loss)})
    first = np.mean(losses[:10]) if len(losses) >= 10 else losses[0]
    last = np.mean(losses[-10:])
    print(f"[done] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
