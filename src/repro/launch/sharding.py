"""PartitionSpec rules: parameter and input sharding per family.

Scheme (DESIGN.md §5): Megatron-style tensor parallel over the mesh
``model`` axis + ZeRO-3-ish FSDP weight sharding over ``data``; batch
over (pod, data). Experts shard over ``model`` (EP); long-context KV
caches shard the sequence. Every rule passes through :func:`_sanitize`,
which drops assignments that do not divide the dimension — so one rule
set serves all ten architectures.
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def dp(mesh) -> Any:
    axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return axes if len(axes) > 1 else axes[0]


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _sanitize(spec: P, shape, mesh) -> P:
    """Drop axis assignments that don't divide the dimension."""
    out = []
    for i, ax in enumerate(spec):
        if ax is None or i >= len(shape):
            out.append(None if i >= len(shape) else ax)
            continue
        out.append(ax if shape[i] % _axis_size(mesh, ax) == 0 else None)
    return P(*out[:len(shape)])


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# (regex, spec builder taking ndim) — first match wins. ``L`` means the
# leading stacked-layer axis; rules are written for the stacked form and
# un-stacked leaves (mtp block) are handled by ndim.
def _lm_rules(fsdp, tp):
    def mat(*axes):
        return lambda nd: P(*( (None,) * (nd - len(axes)) + axes ))
    return [
        # vocab-sharded only: the shard_map vocab-parallel lookup owns it
        (r"embed$", lambda nd: P(tp, None)),
        (r"lm_head/w$", mat(fsdp, tp)),
        (r"(wq|wk|wv|wg|wu|wi)/w$", mat(fsdp, tp)),
        (r"(wo|wd)/w$", mat(tp, fsdp)),
        (r"(wq|wk|wv|wg|wu|wi)/b$", mat(tp)),
        (r"experts/(wg|wu)/w$",
         lambda nd: P(*((None,) * (nd - 3) + (tp, fsdp, None)))),
        (r"experts/wd/w$",
         lambda nd: P(*((None,) * (nd - 3) + (tp, None, fsdp)))),
        (r"router/w$", mat()),
        (r"(w_uq|w_uk|w_uv)/w$", mat(None, tp)),
        (r"(w_dq|w_dkv|w_kr)/w$", mat(fsdp, None)),
        (r"w_o/w$", mat(tp, fsdp)),
        (r"mtp/proj/w$", mat(fsdp, None)),
    ]


def param_specs(params_shape, mesh, family: str):
    """ShapeDtypeStruct tree -> PartitionSpec tree."""
    fsdp = "data"
    tp = "model"
    if family in ("lm",):
        rules = _lm_rules(fsdp, tp)
    elif family == "recsys":
        all_axes = tuple(a for a in mesh.axis_names)
        rules = [(r"(item_table|cat_table)$",
                  lambda nd: P(all_axes, None))]
    else:   # gnn / equiv / matcher: tiny params -> replicate
        rules = []

    def rule(path, leaf):
        ps = _path_str(path)
        nd = len(leaf.shape)
        for pat, builder in rules:
            if re.search(pat, ps):
                return _sanitize(builder(nd), leaf.shape, mesh)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def opt_specs(opt_shape, pspecs):
    """Optimizer state shards exactly like its parameters."""
    return {"m": pspecs, "v": pspecs,
            "step": P()}


def to_named(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))
