"""Production mesh construction.

A function, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 device; only the dry-run forces
512 host devices via XLA_FLAGS before any jax import).

Topology: TPU v5e pods of 16x16 = 256 chips; ``multi_pod`` adds a leading
pod axis (2 pods = 512 chips). Axis roles:
  * pod   — data-parallel replica sets with hierarchical cross-pod
            gradient reduction (DCI-aware ordering).
  * data  — batch / FSDP-weight sharding inside a pod (ICI-fast).
  * model — tensor/expert/sequence parallel dimension.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over however many devices the test process has."""
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (pod-aware)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
