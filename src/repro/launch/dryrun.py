import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512"
                           ).strip()
# The two lines above MUST run before any other import pulls in jax: the
# device count locks on first backend initialization. Everything below is
# the multi-pod dry-run driver (deliverable e).
"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and record memory/cost/collective analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import pathlib
import sys
import time
import traceback


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: pathlib.Path,
             verbose: bool = True) -> dict:
    import jax
    from ..configs.registry import get_arch
    from ..roofline.analysis import analyze
    from .mesh import make_production_mesh
    from .steps import build_cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh.devices.size
    t0 = time.time()
    cell = build_cell(arch, shape, mesh)
    lowered = cell.lower(mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    spec = get_arch(arch)
    model_flops = None
    if spec.family == "lm":
        cfg = spec.config
        c = spec.shape(shape)
        if c.kind == "train":
            tokens = c.dims["global_batch"] * c.dims["seq_len"]
            model_flops = 6.0 * cfg.n_active_params() * tokens
        elif c.kind == "prefill":
            tokens = c.dims["global_batch"] * c.dims["seq_len"]
            model_flops = 2.0 * cfg.n_active_params() * tokens
        else:
            tokens = c.dims["global_batch"]
            model_flops = 2.0 * cfg.n_active_params() * tokens

    roof = analyze(arch, shape, mesh_name, chips, compiled,
                   model_flops=model_flops)
    mem_txt = None
    try:
        mem_txt = str(compiled.memory_analysis())
    except Exception:
        pass
    rec = roof.to_dict()
    rec.update({"lower_s": round(t_lower, 2),
                "compile_s": round(t_compile, 2),
                "memory_analysis": mem_txt,
                "status": "ok"})
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{arch}__{shape}__{mesh_name}.json"
    path.write_text(json.dumps(rec, indent=2, default=str))
    if verbose:
        print(f"[ok] {arch} x {shape} x {mesh_name}: "
              f"compile={t_compile:.1f}s "
              f"flops/dev={rec['hlo_flops_per_device']:.3e} "
              f"coll/dev={rec['coll_bytes_per_device']:.3e} "
              f"bottleneck={rec['bottleneck']}")
        print(f"     memory_analysis: {mem_txt}")
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--include-matcher", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from ..configs.registry import all_cells
    out_dir = pathlib.Path(args.out)
    cells = (all_cells(include_matcher=args.include_matcher) if args.all
             else [(args.arch, args.shape)])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            name = f"{arch}__{shape}__{'pod2x16x16' if mp else 'pod16x16'}"
            if args.skip_existing and (out_dir / f"{name}.json").exists():
                print(f"[skip] {name}")
                continue
            try:
                run_cell(arch, shape, mp, out_dir)
            except Exception as e:  # noqa: BLE001 — record and continue
                traceback.print_exc()
                failures.append((name, repr(e)))
                (out_dir / f"{name}.json").write_text(json.dumps(
                    {"arch": arch, "shape": shape, "multi_pod": mp,
                     "status": "fail", "error": repr(e)}, indent=2))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for n, e in failures:
            print(" ", n, e[:200])
        return 1
    print("\nall cells compiled OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
