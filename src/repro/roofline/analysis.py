"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (EXPERIMENTS.md §Roofline):

    compute    = HLO_FLOPs / (chips × peak_FLOPs)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

``cost_analysis`` supplies FLOPs and bytes; collective bytes are parsed
from the optimized HLO text (result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op, summed;
ops inside loops/scans are counted once per trip via the enclosing
while-loop trip count when it is statically printed — otherwise once,
recorded as a lower bound).
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'bf16[256,7168]' or a tuple
    '(f32[8,128], u32[8])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes per collective kind from optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*((?:\([^)]*\))|(?:\w+\[[0-9,]*\]"
                     r"(?:\{[^}]*\})?))\s+([\w\-]+)", line)
        if not m:
            continue
        op = m.group(2)
        for kind in _COLLECTIVES:
            if op == kind or op.startswith(kind + "-start"):
                out[kind] += _shape_bytes(m.group(1))
                out["count"] += 1
                break
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_counts: dict
    model_flops: float | None = None
    mem_per_device: float | None = None
    # operand+result bytes of custom-call instructions (Pallas kernels —
    # for the HBM-paged refine variant this is the kernel's bytes-moved
    # attribution, an upper bound on its chunk DMA traffic)
    custom_call_bytes: float = 0.0
    custom_call_count: int = 0

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (LINK_BW)

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def useful_flops_frac(self) -> float | None:
        if not self.model_flops or not self.hlo_flops:
            return None
        return self.model_flops / (self.hlo_flops * self.chips)

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_device": self.hlo_flops,
            "hlo_bytes_per_device": self.hlo_bytes,
            "coll_bytes_per_device": self.coll_bytes,
            "coll_counts": self.coll_counts,
            "custom_call_bytes_per_device": self.custom_call_bytes,
            "custom_call_count": self.custom_call_count,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac,
            "mem_per_device_bytes": self.mem_per_device,
        }


def analyze(arch: str, shape: str, mesh_name: str, chips: int,
            compiled, model_flops: float | None = None) -> Roofline:
    from .hlo_cost import analyze_hlo_text
    hlo = compiled.as_text()
    hc = analyze_hlo_text(hlo)       # loop-aware (scan bodies x trip count)
    flops = float(hc.flops)
    byts = float(hc.bytes)
    total_coll = float(hc.coll_bytes)
    coll = {k: float(v) for k, v in hc.coll_by_kind.items()}
    coll["unresolved_loops"] = hc.unresolved_loops
    # XLA's own (loop-undercounting) numbers kept for reference
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        coll["xla_flops_ref"] = float(cost.get("flops", 0.0))
    except Exception:
        pass
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = (getattr(ma, "argument_size_in_bytes", 0)
                   + getattr(ma, "output_size_in_bytes", 0)
                   + getattr(ma, "temp_size_in_bytes", 0)
                   - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    hlo_flops=flops, hlo_bytes=byts,
                    coll_bytes=total_coll, coll_counts=coll,
                    model_flops=model_flops, mem_per_device=mem,
                    custom_call_bytes=float(hc.custom_call_bytes),
                    custom_call_count=int(hc.custom_call_count))
