"""Loop-aware cost extraction from optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers model is undercounted by the trip count (verified
empirically in EXPERIMENTS.md §Dry-run notes). This module re-derives the
three roofline inputs by walking the HLO text:

  * FLOPs       — 2·M·N·K per ``dot`` (shapes resolved via per-computation
                  symbol tables), plus 1 flop/element for arithmetic
                  fusions/reduces (documented approximation; dots dominate).
  * HBM bytes   — per *top-level kernel* (fusion/dot/copy/reduce/...):
                  operand bytes + result bytes. Fusion internals are
                  register/VMEM-resident and excluded, which is exactly the
                  roofline's HBM-traffic model.
  * collectives — result-shape bytes per all-gather/all-reduce/
                  reduce-scatter/all-to-all/collective-permute.
  * custom-call — operand + result bytes of ``custom-call`` instructions,
                  tracked both in the HBM total and separately as
                  ``custom_call_bytes``. Pallas kernels (the bitmap-refine
                  variants, including the HBM-paged hierarchical one)
                  lower to ``custom-call``, so this term is the
                  bytes-moved attribution for hand-written kernels. For
                  the HBM-resident adjacency the operand bytes are an
                  upper bound — the kernel DMAs only summary-live chunks —
                  so the split lets the report say which side of the
                  traffic XLA cannot see into.

``while`` instructions multiply their body cost by the trip count parsed
from the condition computation (jax scans lower to ``iv < const``); when
the trip count cannot be resolved the body is counted once and the result
is flagged as a lower bound.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops that move no HBM bytes themselves (custom-call is NOT free: Pallas
# kernels lower to it and their operand/result traffic is real — counted
# below into both `bytes` and the dedicated `custom_call_bytes` term)
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id",
             "opt-barrier"}

_SHAPE_TOKEN = re.compile(r"^(\w+)\[([0-9,]*)\]")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:\w+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*)$")
_COMP_HEADER = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_PARAM = re.compile(r"([\w.\-]+)\s*:\s*(\([^)]*\)|\w+\[[0-9,]*\](?:\{[^}]*\})?)")
_OPERAND = re.compile(r"%([\w.\-]+)")
_ATTR_CALL = re.compile(r"(?:calls|to_apply)=%([\w.\-]+)")
_ATTR_BODY = re.compile(r"body=%([\w.\-]+)")
_ATTR_COND = re.compile(r"condition=%([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    """(elements, bytes) of a shape or tuple-shape string."""
    elems = total = 0
    for m in re.finditer(r"(\w+)\[([0-9,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


def _dims(shape_str: str) -> list[int]:
    m = _SHAPE_TOKEN.match(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str
    operands: list[str]


@dataclasses.dataclass
class Computation:
    name: str
    shapes: dict         # symbol -> shape string (params + instr results)
    instrs: list         # [Instr]


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        hm = _COMP_HEADER.match(line)
        if hm and line.rstrip().endswith("{"):
            cur = Computation(hm.group(2), {}, [])
            comps[cur.name] = cur
            for pm in _PARAM.finditer(hm.group(3)):
                cur.shapes[pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        im = _INSTR.match(line)
        if not im:
            continue
        name, shape, op, rest = im.groups()
        # operand names: inside the first balanced paren region
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = i
                    break
                depth -= 1
        oper_str = rest[:end]
        operands = _OPERAND.findall(oper_str)
        cur.shapes[name] = shape
        cur.instrs.append(Instr(name, shape, op, rest, operands))
    return comps


def _trip_count(comps: dict, cond_name: str) -> int | None:
    cond = comps.get(cond_name)
    if cond is None:
        return None
    consts = []
    for ins in cond.instrs:
        if ins.op == "constant" and ins.shape.startswith("s32[]"):
            m = re.search(r"constant\((\-?\d+)\)", "constant(" + ins.rest)
            if m:
                consts.append(int(m.group(1)))
    if len(consts) == 1:
        return consts[0]
    if consts:
        return max(consts)      # jax scan: bound is the largest constant
    return None


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    custom_call_bytes: float = 0.0
    custom_call_count: int = 0
    coll_by_kind: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    unresolved_loops: int = 0

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        self.custom_call_bytes += other.custom_call_bytes * mult
        self.custom_call_count += other.custom_call_count
        for k in _COLLECTIVES:
            self.coll_by_kind[k] += other.coll_by_kind[k] * mult
        self.unresolved_loops += other.unresolved_loops


def _instr_bytes(comp: Computation, ins: Instr) -> float:
    _, out_b = _shape_elems_bytes(ins.shape)
    in_b = 0
    for o in ins.operands:
        s = comp.shapes.get(o)
        if s is not None:
            in_b += _shape_elems_bytes(s)[1]
    return out_b + in_b


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_elems, _ = _shape_elems_bytes(ins.shape)
    k = 1
    cm = _CONTRACT.search(ins.rest)
    if cm and ins.operands:
        lhs = comp.shapes.get(ins.operands[0])
        if lhs:
            d = _dims(lhs)
            for idx in cm.group(1).split(","):
                if idx and int(idx) < len(d):
                    k *= d[int(idx)]
    return 2.0 * out_elems * k


def cost_of(comps: dict, name: str, memo: dict,
            flops_only_comps: bool = False) -> HloCost:
    """Recursive cost of one computation (memoized)."""
    if name in memo:
        return memo[name]
    comp = comps.get(name)
    c = HloCost()
    memo[name] = c
    if comp is None:
        return c
    for ins in comp.instrs:
        if ins.op == "while":
            bm = _ATTR_BODY.search(ins.rest)
            cm = _ATTR_COND.search(ins.rest)
            if bm:
                body = cost_of(comps, bm.group(1), memo)
                trip = _trip_count(comps, cm.group(1)) if cm else None
                if trip is None:
                    trip = 1
                    c.unresolved_loops += 1
                c.add(body, trip)
            continue
        if ins.op in ("call", "conditional", "fusion", "map"):
            cm2 = _ATTR_CALL.search(ins.rest)
            if cm2:
                sub = cost_of(comps, cm2.group(1), memo)
                # fusion: internals contribute flops but not HBM bytes
                c.flops += sub.flops
                c.coll_bytes += sub.coll_bytes
                for k in _COLLECTIVES:
                    c.coll_by_kind[k] += sub.coll_by_kind[k]
                c.unresolved_loops += sub.unresolved_loops
            if ins.op == "fusion":
                c.bytes += _instr_bytes(comp, ins)
            continue
        if ins.op == "dot":
            c.flops += _dot_flops(comp, ins)
            c.bytes += _instr_bytes(comp, ins)
            continue
        if ins.op in _COLLECTIVES or any(
                ins.op == k + "-start" for k in _COLLECTIVES):
            kind = ins.op.replace("-start", "")
            _, b = _shape_elems_bytes(ins.shape)
            c.coll_bytes += b
            c.coll_by_kind[kind] += b
            c.bytes += _instr_bytes(comp, ins)
            continue
        if ins.op == "custom-call":
            # Pallas kernel launch: operand + result bytes is the HBM
            # traffic XLA sees at the call boundary (for the HBM-paged
            # hierarchical refine kernel this is an upper bound — the
            # kernel itself DMAs only summary-live chunks)
            b = _instr_bytes(comp, ins)
            c.custom_call_bytes += b
            c.custom_call_count += 1
            c.bytes += b
            continue
        if ins.op in _FREE_OPS or ins.op.endswith("-done"):
            continue
        # generic kernel: elementwise-ish flops + real traffic
        elems, _ = _shape_elems_bytes(ins.shape)
        if ins.op in ("add", "multiply", "subtract", "divide", "exponential",
                      "reduce", "reduce-window", "convert", "compare",
                      "maximum", "minimum", "select", "rsqrt", "tanh",
                      "log", "power", "negate", "and", "or", "xor",
                      "shift-left", "shift-right-logical"):
            c.flops += elems
        c.bytes += _instr_bytes(comp, ins)
    return c


def analyze_hlo_text(text: str) -> HloCost:
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        if line.strip().startswith("ENTRY"):
            m = _COMP_HEADER.match(line)
            if m:
                entry = m.group(2)
            break
    if entry is None:
        # fall back: computation named main-ish
        entry = next((n for n in comps if n.startswith("main")),
                     next(iter(comps), None))
    memo: dict = {}
    return cost_of(comps, entry, memo)
