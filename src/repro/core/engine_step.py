"""Device-side programs of the TPU wave engine.

This module contains the *pure JAX* (jit-able, shard_map-able) functions
executed per wave step. The host scheduler in ``vectorized.py`` owns the
segment stacks and resolution bookkeeping; every array-heavy operation —
Eq. 2 bitmap refinement, injectivity masking, O(1) dead-end lookups over a
whole wave, child extraction, pattern scatter — happens here on fixed
shapes so a single compiled program serves every query.

Multi-query waves (DESIGN.md §2): per-query state lives in *banks* stacked
along a leading slot axis — :class:`QueryBank` ``[S, ...]`` and the
bounded hashed Δ store :class:`~repro.patterns.store.PatternStoreBank`
``[S, capacity]`` — and every wave row carries a ``query_slot`` and a
``depth`` lane, so one jitted program expands a wave whose rows belong to
many concurrent queries at different depths (and, with shard-as-segments,
to many shards of the same query). Sequential-style callers go through
the 1-slot ``WaveEngine`` facade; the launch dry-run lowers the real
multi-query program.

Design notes (see DESIGN.md §2):
  * adjacency and candidate sets are packed uint32 bitmaps; Eq. 2 becomes
    a gather + AND-reduction over mapped-neighbor rows (the Pallas kernel
    ``kernels/bitmap_refine.py`` implements the same contraction with
    explicit VMEM tiling; this file keeps the jnp reference path which
    XLA fuses well on CPU and is what the dry-run lowers by default).
  * dead-end masks are bitmasks over query order positions, two uint32
    words (supports |V_Q| <= 64).
  * the numeric pattern check Φ[μ] == φ (paper Eq. 7) is a hashed probe
    (``patterns.store.hash_probe``: multiplicative hash + PROBE-slot
    linear window), a gather and a compare, evaluated for every
    (row, extracted-child) pair of the wave in one shot. The store is
    O(configured capacity) — the last data-graph-sized resident array
    is gone — and lookups bump per-entry hit counters that guide
    eviction when an insert finds its probe window full.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from ..patterns.store import (MASK_WORDS, PatternStore, PatternStoreBank,
                              StoreCounters, hash_insert, hash_probe)

N_PAD = 64              # padded query size
FULL = jnp.uint32(0xFFFFFFFF)


class GraphArrays(NamedTuple):
    """Device view of the data graph.

    Two mutually exclusive adjacency layouts (DESIGN.md §2):

      * dense  — ``adj_bitmap`` holds the whole packed [V, W] block and
        the hier fields are None; refinement gathers rows directly (the
        small-|V| fast path whose kernel keeps the block in VMEM).
      * hier   — ``adj_bitmap`` is None and the two-level layout rides
        in ``adj_summary``/``chunk_ptr``/``chunk_id``/``chunk_data``
        (see core.graph.HierBitmap); refinement intersects summaries
        first and touches only live chunks, so the store can stay in
        HBM past the VMEM ceiling. ``chunk_pad`` is a dummy int32
        [kmax] lane whose *shape* carries the layout's static
        max-stored-chunks-per-row through jit.

    Which layout a graph gets is decided once at scheduler construction
    (kernels.config.use_hbm_adjacency); every refinement call branches
    at trace time on ``chunk_data is not None``.
    """
    adj_bitmap: jax.Array | None   # uint32 [V, W] packed adjacency
    n_vertices: jax.Array          # int32 scalar
    adj_summary: jax.Array | None = None  # uint32 [V, SW] chunk summary
    chunk_ptr: jax.Array | None = None    # int32 [V + 1] CSR over chunks
    chunk_id: jax.Array | None = None     # int32 [n_stored + kmax]
    chunk_data: jax.Array | None = None   # uint32 [n_stored + kmax, C]
    chunk_pad: jax.Array | None = None    # int32 [kmax] (shape-only lane)


class QueryBank(NamedTuple):
    """Per-slot query arrays for multi-query waves (query axis first)."""
    cand_bitmap: jax.Array   # uint32 [S, N_PAD, W]
    nbr_mask: jax.Array      # bool [S, N_PAD, N_PAD]
    n_query: jax.Array       # int32 [S]
    learn: jax.Array         # bool [S] — slot stores patterns in-loop

    @staticmethod
    def empty(n_slots: int, w: int) -> "QueryBank":
        return QueryBank(
            cand_bitmap=jnp.zeros((n_slots, N_PAD, w), jnp.uint32),
            nbr_mask=jnp.zeros((n_slots, N_PAD, N_PAD), bool),
            n_query=jnp.zeros((n_slots,), jnp.int32),
            learn=jnp.zeros((n_slots,), bool))


class WaveResultMQ(NamedTuple):
    """Multi-query wave result — per-row counters so the host can
    attribute prune/injectivity statistics to the owning query."""
    refined_empty: jax.Array     # bool [F]
    n_children: jax.Array        # int32 [F]
    n_leftover: jax.Array        # int32 [F]
    partial_mask: jax.Array      # uint32 [F, MASK_WORDS]
    child_v: jax.Array           # int32 [F, KPR]
    child_valid: jax.Array       # bool [F, KPR]
    leftover: jax.Array          # uint32 [F, W]
    n_pruned: jax.Array          # int32 [F] dead-end prunes per row
    n_inj: jax.Array             # int32 [F] injectivity kills per row
    pruned_v: jax.Array          # int32 [F, KPR] Δ-pruned children (-1 pad)
    #   the host folds pruned_v into per-key hit counters, which rank
    #   the deterministic cross-host pattern exchange (DESIGN.md §3)


def _popcount_rows(words: jax.Array) -> jax.Array:
    """Sum of set bits per row of a uint32 [..., W] array -> int32 [...]."""
    return lax.population_count(words).astype(jnp.int32).sum(axis=-1)


def _unpack_bits(words: jax.Array, v: int) -> jax.Array:
    """uint32 [F, W] -> bool [F, v]."""
    f, w = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(f, w * 32)[:, :v].astype(bool)


def _pack_bits(bits: jax.Array, w: int) -> jax.Array:
    """bool [F, v] -> uint32 [F, W] (zero-padded)."""
    f, v = bits.shape
    padded = jnp.zeros((f, w * 32), bool).at[:, :v].set(bits)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (padded.reshape(f, w, 32).astype(jnp.uint32) * weights
            ).sum(axis=-1, dtype=jnp.uint32)


def _position_bit(p: jax.Array) -> jax.Array:
    """Order position (scalar) -> uint32 [MASK_WORDS] one-hot-bit mask."""
    word = p // 32
    bit = jnp.uint32(1) << (p % 32).astype(jnp.uint32)
    return jnp.where(jnp.arange(MASK_WORDS) == word, bit, jnp.uint32(0))


def _position_bits(p: jax.Array) -> jax.Array:
    """Order positions int32 [F] -> uint32 [F, MASK_WORDS] one-hot bits."""
    word = p // 32
    bit = jnp.uint32(1) << (p % 32).astype(jnp.uint32)
    return jnp.where(jnp.arange(MASK_WORDS)[None, :] == word[:, None],
                     bit[:, None], jnp.uint32(0))


def _below_bits(d: jax.Array) -> jax.Array:
    """Bitmask of all positions strictly below d, uint32 [MASK_WORDS]."""
    idx = jnp.arange(MASK_WORDS * 32)
    bits = idx < d
    return (bits.reshape(MASK_WORDS, 32).astype(jnp.uint32)
            * (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
            ).sum(axis=-1, dtype=jnp.uint32)


def _below_bits_rows(d: jax.Array) -> jax.Array:
    """Positions strictly below d, rowwise: int32 [F] -> uint32 [F, MW]."""
    idx = jnp.arange(MASK_WORDS * 32)
    bits = idx[None, :] < d[:, None]                        # [F, MW*32]
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return (bits.reshape(-1, MASK_WORDS, 32).astype(jnp.uint32)
            * weights).sum(axis=-1, dtype=jnp.uint32)


def _pack_mask_rows(bits: jax.Array) -> jax.Array:
    """bool [F, N_PAD] position sets -> packed uint32 [F, MASK_WORDS]."""
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return (bits.reshape(-1, MASK_WORDS, 32).astype(jnp.uint32)
            * weights).sum(axis=-1, dtype=jnp.uint32)


def _bitlen32(x: jax.Array) -> jax.Array:
    """Highest set bit + 1 of a uint32 (0 for 0): bit-smear + popcount."""
    x = x | (x >> 1)
    x = x | (x >> 2)
    x = x | (x >> 4)
    x = x | (x >> 8)
    x = x | (x >> 16)
    return lax.population_count(x).astype(jnp.int32)


def _mask_bitlen(words: jax.Array) -> jax.Array:
    """Bit length of packed 64-bit masks, uint32 [F, MASK_WORDS] -> int32
    [F] (the paper's μ: highest Γ position below the key + 1)."""
    hi, lo = words[:, 1], words[:, 0]
    return jnp.where(hi != 0, 32 + _bitlen32(hi), _bitlen32(lo))


def _extract_topk_packed(live: jax.Array, kpr: int
                         ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Extract the ``kpr`` lowest set bits per row of a packed bitmap.

    Word-level replacement for the old dense ``_unpack_bits`` + cumsum +
    vmapped-nonzero ranking, which materialized an O(F·V) boolean matrix
    per wave. Each of the ``kpr`` steps isolates the lowest set bit via
    first-nonzero-word + ``word & -word`` — O(kpr·F·W) word ops with no
    dense unpack, and the packed leftovers fall out for free.

    Returns (child_v int32 [F, kpr] ascending with -1 padding,
             leftover uint32 [F, W], n_leftover int32 [F]).
    """
    f, w = live.shape
    rows = jnp.arange(f)

    def step(cur, _):
        nz = cur != 0                                        # [F, W]
        any_row = nz.any(axis=1)
        first_w = jnp.argmax(nz, axis=1).astype(jnp.int32)   # [F]
        word = cur[rows, first_w]                            # [F]
        lsb = word & (jnp.uint32(0) - word)
        bit_idx = lax.population_count(
            lsb - jnp.uint32(1)).astype(jnp.int32)
        child = jnp.where(any_row, first_w * 32 + bit_idx, -1)
        cleared = word & (word - jnp.uint32(1))
        cur = cur.at[rows, first_w].set(
            jnp.where(any_row, cleared, word))
        return cur, child

    leftover, children = lax.scan(step, live, None, length=kpr)
    return children.T, leftover, _popcount_rows(leftover)


# ===================================================================
# slot management: load one query (+ its Δ store) into a bank slot
# ===================================================================
# Donation everywhere the store bank is threaded: the bank is the one
# large mutable device structure, and without donation every program
# that returns it copies all seven [S, C] lanes per dispatch (~4x the
# useful work on the single-step path). Callers always replace their
# handle with the returned one, so the old buffers are dead by
# construction.
@functools.partial(jax.jit, donate_argnums=(0, 1))
def load_slot(qb: QueryBank, tb: PatternStoreBank, slot: jax.Array,
              cand_bitmap: jax.Array, nbr_mask: jax.Array,
              n_query: jax.Array, store: PatternStore,
              learn: jax.Array = True
              ) -> tuple[QueryBank, PatternStoreBank]:
    """Install a query in bank slot ``slot`` (admission). ``store`` is
    the slot's initial hashed Δ store: empty, or seeded with transferable
    patterns (template-cache warm start, checkpoint restore, cross-host
    import — see patterns.cache / core.distributed). ``learn`` gates the
    megastep's in-loop pattern stores for this slot."""
    qb2 = QueryBank(
        cand_bitmap=qb.cand_bitmap.at[slot].set(cand_bitmap),
        nbr_mask=qb.nbr_mask.at[slot].set(nbr_mask),
        n_query=qb.n_query.at[slot].set(n_query),
        learn=qb.learn.at[slot].set(learn))
    tb2 = PatternStoreBank(
        key_pos=tb.key_pos.at[slot].set(store.key_pos),
        key_v=tb.key_v.at[slot].set(store.key_v),
        phi=tb.phi.at[slot].set(store.phi),
        mu=tb.mu.at[slot].set(store.mu),
        mask=tb.mask.at[slot].set(store.mask),
        valid=tb.valid.at[slot].set(store.valid),
        hits=tb.hits.at[slot].set(store.hits))
    return qb2, tb2


@functools.partial(jax.jit, donate_argnums=(0, 1))
def load_slots(qb: QueryBank, tb: PatternStoreBank, slots: jax.Array,
               cand_bitmap: jax.Array, nbr_mask: jax.Array,
               n_query: jax.Array, store: PatternStore,
               learn: jax.Array) -> tuple[QueryBank, PatternStoreBank]:
    """Batch variant of :func:`load_slot`: install ``k`` queries in one
    dispatch (all row arguments carry a leading [k] axis; a ``slots``
    value of S drops that row). An admission burst — fresh server, batch
    submit — used to pay one jit dispatch per query, which dominated
    tiny-batch admission latency."""
    qb2 = QueryBank(
        cand_bitmap=qb.cand_bitmap.at[slots].set(cand_bitmap,
                                                 mode="drop"),
        nbr_mask=qb.nbr_mask.at[slots].set(nbr_mask, mode="drop"),
        n_query=qb.n_query.at[slots].set(n_query, mode="drop"),
        learn=qb.learn.at[slots].set(learn, mode="drop"))
    tb2 = PatternStoreBank(
        key_pos=tb.key_pos.at[slots].set(store.key_pos, mode="drop"),
        key_v=tb.key_v.at[slots].set(store.key_v, mode="drop"),
        phi=tb.phi.at[slots].set(store.phi, mode="drop"),
        mu=tb.mu.at[slots].set(store.mu, mode="drop"),
        mask=tb.mask.at[slots].set(store.mask, mode="drop"),
        valid=tb.valid.at[slots].set(store.valid, mode="drop"),
        hits=tb.hits.at[slots].set(store.hits, mode="drop"))
    return qb2, tb2


@jax.jit
def read_store_slot(tb: PatternStoreBank, slot: jax.Array) -> PatternStore:
    """Read one slot's Δ store back out (pattern export on completion).

    Jitted so the export is ONE dispatch: seven separate ``tb.x[slot]``
    gathers cost ~1ms of host dispatch time per finished query, which
    dominated the tiny-workload serving smoke run."""
    return PatternStore(key_pos=tb.key_pos[slot], key_v=tb.key_v[slot],
                        phi=tb.phi[slot], mu=tb.mu[slot],
                        mask=tb.mask[slot], valid=tb.valid[slot],
                        hits=tb.hits[slot])


# ===================================================================
# multi-query wave programs
# ===================================================================
def _refine_hier_jnp(g: GraphArrays, acc0: jax.Array, frontier: jax.Array,
                     active: jax.Array) -> jax.Array:
    """Hierarchical Eq. 2 contraction in plain jnp.

    Each active position reconstructs its frontier rows from their
    stored chunks — an [F, kmax, C] gather proportional to the sparse
    layout, never the [F, NP, W] dense gather that costs W ∝ V per row
    (128 MB per wave at 64K vertices). The position loop runs to the
    deepest active position (traced bound), not N_PAD.
    """
    f, w = acc0.shape
    c = g.chunk_data.shape[1]
    kmax = g.chunk_pad.shape[0]
    ncp = g.adj_summary.shape[1] * 32
    acc = acc0.astype(jnp.uint32)
    hi = jnp.max(jnp.where(active.any(axis=0),
                           jnp.arange(N_PAD, dtype=jnp.int32) + 1, 0))

    def body(p, acc):
        vtx = frontier[:, p]
        act = (active[:, p] != 0) & (vtx >= 0)
        k0 = g.chunk_ptr[vtx.clip(0)]
        nk = g.chunk_ptr[vtx.clip(0) + 1] - k0
        ks = k0[:, None] + jnp.arange(kmax)[None, :]
        km = jnp.arange(kmax)[None, :] < nk[:, None]
        ids = jnp.where(km, g.chunk_id[ks], ncp)        # pad -> dropped
        data = jnp.where(km[:, :, None],
                         g.chunk_data[ks].astype(jnp.uint32),
                         jnp.uint32(0))
        rows = jnp.zeros((f, ncp, c), jnp.uint32).at[
            jnp.arange(f)[:, None], ids].set(data, mode="drop")
        rows = rows.reshape(f, ncp * c)[:, :w]
        return jnp.where(act[:, None], acc & rows, acc)

    return lax.fori_loop(0, hi, body, acc)


def refine_eq2_mq(g: GraphArrays, qb: QueryBank, query_slot: jax.Array,
                  frontier: jax.Array, depth: jax.Array,
                  backend: str = "jnp",
                  block_f: int | None = None,
                  dma_depth: int | None = None) -> jax.Array:
    """Eq. 2 candidate refinement for a mixed-query wave.

    C'(row) = cand[qid, depth] ∩ ⋂_{p < depth, p ~q depth} N(frontier[p]).
    ``query_slot`` and ``depth`` are int32 [F] lanes. Returns the packed
    candidate bitmap uint32 [F, W].

    ``backend`` (static, from ``kernels.config``): "jnp" keeps the inline
    gather + AND contraction that XLA fuses well on CPU; "pallas" /
    "pallas_interpret" lower to the multi-row ``bitmap_refine`` kernel,
    so one config switch moves the whole engine hot path onto the
    compiled kernel (no silent interpret-mode fallback).

    The adjacency layout picks the variant at trace time: a hierarchical
    ``g`` (``chunk_data`` set, ``adj_bitmap`` None) routes to the
    HBM-paged kernel / the sparse-gather jnp contraction; ``dma_depth``
    is its pipeline depth (None = tuned/config default).
    """
    acc0 = qb.cand_bitmap[query_slot, depth]                 # [F, W]
    pos = jnp.arange(N_PAD)
    active = (qb.nbr_mask[query_slot, depth]
              & (pos[None, :] < depth[:, None]))             # [F, NP]

    if g.chunk_data is not None:
        if backend != "jnp":
            from ..kernels.bitmap_refine import refine_bitmap_rows_hier
            w = acc0.shape[1]
            out = refine_bitmap_rows_hier(
                g.adj_summary, g.chunk_ptr, g.chunk_id, g.chunk_data,
                g.chunk_pad.shape[0], acc0, frontier, active,
                interpret=(backend == "pallas_interpret"),
                dma_depth=dma_depth)
            return out[:, :w].astype(jnp.uint32)
        return _refine_hier_jnp(g, acc0, frontier, active)

    if backend != "jnp":
        from ..kernels.bitmap_refine import refine_bitmap_rows
        w = acc0.shape[1]
        out = refine_bitmap_rows(g.adj_bitmap, acc0, frontier, active,
                                 interpret=(backend == "pallas_interpret"),
                                 block_f=block_f)
        return out[:, :w].astype(jnp.uint32)

    # one gather + reduce instead of a fori_loop over positions: 64
    # sequential [F, W] dispatches cost more than the [F, NP, W] gather
    rows = g.adj_bitmap[frontier.clip(0)]                    # [F, NP, W]
    rows = jnp.where(active[:, :, None], rows, FULL)
    return acc0 & lax.reduce(rows, FULL, lax.bitwise_and, (1,))


def deadend_lookup_children_mq(tb: PatternStoreBank, phi: jax.Array,
                               query_slot: jax.Array, depth: jax.Array,
                               child_v: jax.Array
                               ) -> tuple[jax.Array, jax.Array,
                                          PatternStoreBank]:
    """Paper-Eq.7 check for extracted children only (§Perf iteration 2:
    O(F·kpr·PROBE) hashed probes instead of the O(F·V) dense sweep),
    store rows keyed per query slot.

    child_v: int32 [F, KPR] candidate vertices (-1 = empty slot).
    Returns (prune bool [F, KPR], Γ* contribution uint32 [F, MASK_WORDS],
    the store bank with the matched entries' hit counters bumped — the
    counters feed eviction ranking and the host's exchange/cache
    ranking, so lookups thread the bank functionally).
    """
    f, kpr = child_v.shape
    cv = child_v.clip(0).reshape(-1)                        # [F*KPR]
    sl = jnp.broadcast_to(query_slot[:, None], (f, kpr)).reshape(-1)
    kp = jnp.broadcast_to(depth[:, None], (f, kpr)).reshape(-1)
    found, phi_g, mu_g, mask_g, idx = hash_probe(tb, sl, kp, cv)
    valid_g = found.reshape(f, kpr) & (child_v >= 0)
    my_phi = jnp.take_along_axis(phi, mu_g.reshape(f, kpr), axis=1)
    prune = valid_g & (my_phi == phi_g.reshape(f, kpr))
    masks = mask_g.reshape(f, kpr, MASK_WORDS)
    masks = jnp.where(prune[:, :, None],
                      masks | _position_bits(depth)[:, None, :],
                      jnp.uint32(0))
    # OR over the (small) child axis via unpack -> any -> repack
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((masks[:, :, :, None] >> shifts) & jnp.uint32(1)) > 0
    got = bits.any(axis=1)                   # [F, MASK_WORDS, 32]
    weights = jnp.uint32(1) << shifts
    contrib = (got.astype(jnp.uint32) * weights).sum(
        axis=-1, dtype=jnp.uint32)           # [F, MASK_WORDS]
    n_slots = tb.valid.shape[0]
    hit_slot = jnp.where(prune.reshape(-1), sl, n_slots)   # miss -> dropped
    tb2 = tb._replace(hits=tb.hits.at[hit_slot, idx].add(1, mode="drop"))
    return prune, contrib, tb2


def _expand_rows(g: GraphArrays, qb: QueryBank, tb: PatternStoreBank,
                 frontier: jax.Array, used: jax.Array, phi: jax.Array,
                 row_valid: jax.Array, query_slot: jax.Array,
                 depth: jax.Array, kpr: int,
                 backend: str = "jnp", block_f: int | None = None,
                 dma_depth: int | None = None
                 ) -> tuple[WaveResultMQ, PatternStoreBank]:
    """One expansion pass over F mixed-query rows (shared by
    :func:`expand_wave_mq` and the megastep loop body): Eq. 2 refinement,
    injectivity Γ* terms, packed top-kpr child extraction, and the
    Lemma 3 / Eq. 7 dead-end check on the extracted children. Returns
    the wave result plus the store bank with lookup hit counters
    bumped."""
    f = frontier.shape[0]

    refined = refine_eq2_mq(g, qb, query_slot, frontier, depth,
                            backend, block_f, dma_depth)     # [F, W]
    refined = jnp.where(row_valid[:, None], refined, jnp.uint32(0))
    refined_empty = (_popcount_rows(refined) == 0) & row_valid

    # ---- injectivity: candidates already used by the row ---------------
    inj_words = refined & used                               # [F, W]
    n_inj_per_row = _popcount_rows(inj_words)

    # injectivity Γ* contribution (Lemma 2): for every mapped position p
    # whose vertex is a refined candidate, add bit(p) | bit(depth).
    depth_bits = _position_bits(depth)                       # [F, MW]

    def inj_body(p, acc):
        vert = frontier[:, p].clip(0)                        # [F]
        word = jnp.take_along_axis(refined, (vert // 32)[:, None],
                                   axis=1)[:, 0]
        hit = ((word >> (vert % 32).astype(jnp.uint32)) & 1).astype(bool)
        hit &= (p < depth) & row_valid
        contrib = _position_bit(p)[None, :] | depth_bits
        return jnp.where(hit[:, None], acc | contrib, acc)

    inj_mask = lax.fori_loop(
        0, N_PAD, inj_body,
        jnp.zeros((f, MASK_WORDS), jnp.uint32))

    # ---- extract candidate children (per-row cap, packed ranking) -------
    live = refined & ~used                                   # [F, W]
    child_v, leftover, n_leftover = _extract_topk_packed(live, kpr)

    # ---- dead-end pruning on extracted children (Lemma 3 / Eq. 7) -------
    # Perf iteration 2 (see EXPERIMENTS.md): checking only extracted
    # children turns the O(F*V) dense sweep into O(F*kpr) gathers;
    # prunable candidates still in `leftover` are checked when a later
    # pass extracts them.
    prune, prune_mask, tb = deadend_lookup_children_mq(
        tb, phi, query_slot, depth, child_v)
    child_valid = (child_v >= 0) & ~prune
    n_children = child_valid.sum(axis=1).astype(jnp.int32)
    partial_mask = inj_mask | prune_mask

    return WaveResultMQ(
        refined_empty=refined_empty,
        n_children=n_children,
        n_leftover=n_leftover,
        partial_mask=partial_mask,
        child_v=jnp.where(child_valid, child_v, -1),
        child_valid=child_valid,
        leftover=leftover,
        n_pruned=jnp.where(row_valid, prune.sum(axis=1), 0),
        n_inj=jnp.where(row_valid, n_inj_per_row, 0),
        pruned_v=jnp.where(prune & row_valid[:, None], child_v, -1),
    ), tb


@functools.partial(jax.jit, donate_argnums=(2,),
                   static_argnames=("kpr", "backend", "block_f",
                                    "dma_depth"))
def expand_wave_mq(g: GraphArrays, qb: QueryBank, tb: PatternStoreBank,
                   frontier: jax.Array, used: jax.Array, phi: jax.Array,
                   row_valid: jax.Array, query_slot: jax.Array,
                   depth: jax.Array, kpr: int = 16,
                   backend: str = "jnp", block_f: int = 8,
                   dma_depth: int | None = None
                   ) -> tuple[WaveResultMQ, PatternStoreBank]:
    """Expand every row of a mixed-query wave by one query position.

    Args:
      frontier:   int32 [F, N_PAD] mapped data vertex per order position
                  (-1 where unmapped).
      used:       uint32 [F, W] bitmap of data vertices used by the row.
      phi:        int32 [F, N_PAD + 1] ancestor embedding ids (Φ array).
      row_valid:  bool [F] padding mask.
      query_slot: int32 [F] — owning query's bank slot, per row.
      depth:      int32 [F] — number of mapped positions, per row.
      kpr:        static per-row child cap for this pass (leftovers are
                  re-expanded by the host in later passes).
      backend:    static kernel backend for the Eq. 2 contraction.

    Returns (result, store bank with Δ lookup hit counters bumped).
    """
    return _expand_rows(g, qb, tb, frontier, used, phi, row_valid,
                        query_slot, depth, kpr, backend, block_f,
                        dma_depth)


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("kpr",))
def extract_more_mq(tb: PatternStoreBank, phi: jax.Array,
                    query_slot: jax.Array, depth: jax.Array,
                    leftover: jax.Array, kpr: int = 64
                    ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                               jax.Array, jax.Array, jax.Array,
                               PatternStoreBank]:
    """Extract up to ``kpr`` more children per row from leftover bitmaps
    of a mixed-query wave.

    Leftover bits already survived refinement and injectivity in their
    fresh pass; the dead-end check runs here at extraction time (and may
    see *newer* patterns than the fresh pass did — strictly more pruning).
    Returns (child_v, child_valid, new_leftover, n_leftover,
             partial_mask, n_pruned[F], pruned_v[F, KPR], tb).
    """
    child_v, new_leftover, n_leftover = _extract_topk_packed(leftover, kpr)
    prune, prune_mask, tb = deadend_lookup_children_mq(
        tb, phi, query_slot, depth, child_v)
    child_valid = (child_v >= 0) & ~prune
    return (jnp.where(child_valid, child_v, -1), child_valid,
            new_leftover, n_leftover, prune_mask, prune.sum(axis=1),
            jnp.where(prune, child_v, -1), tb)


@jax.jit
def assemble_children_mq(frontier: jax.Array, used: jax.Array,
                         phi: jax.Array, child_v: jax.Array,
                         child_valid: jax.Array, depth: jax.Array,
                         id_base: jax.Array
                         ) -> tuple[jax.Array, jax.Array, jax.Array,
                                    jax.Array, jax.Array]:
    """Materialize child rows [F*KPR, ...] from a mixed-query wave result.

    ``depth`` is the per-row int32 [F] lane. Returns (child_frontier,
    child_used, child_phi, parent_row, valid) — padded flat arrays; the
    host compacts them into new per-query segments. Fresh embedding ids
    are drawn from one shared counter (``id_base``): ids only need to be
    unique within a query, so global uniqueness is sufficient.
    """
    f, kpr = child_v.shape
    flat_v = child_v.reshape(-1)                              # [F*KPR]
    valid = child_valid.reshape(-1)
    parent = jnp.repeat(jnp.arange(f, dtype=jnp.int32), kpr)
    d_par = depth[parent]                                     # [F*KPR]
    cf = frontier[parent]                                     # [F*KPR, NP]
    cf = jnp.where(
        (jnp.arange(cf.shape[1])[None, :] == d_par[:, None]) & valid[:, None],
        flat_v[:, None], cf)
    vv = flat_v.clip(0)
    word = (vv // 32).astype(jnp.int32)
    bit = jnp.uint32(1) << (vv % 32).astype(jnp.uint32)
    cu = used[parent]
    add = jnp.zeros_like(cu).at[jnp.arange(cu.shape[0]), word].set(
        jnp.where(valid, bit, jnp.uint32(0)))
    cu = cu | add
    new_ids = id_base + jnp.cumsum(valid.astype(jnp.int32)) - 1
    cp = phi[parent]
    cp = jnp.where(
        (jnp.arange(cp.shape[1])[None, :] == d_par[:, None] + 1)
        & valid[:, None],
        new_ids[:, None], cp)
    return cf, cu, cp, parent, valid


@functools.partial(jax.jit, donate_argnums=(0,))
def store_patterns_mq(tb: PatternStoreBank, query_slot: jax.Array,
                      key_pos: jax.Array, key_v: jax.Array,
                      phis: jax.Array, mus: jax.Array, masks: jax.Array,
                      valid: jax.Array
                      ) -> tuple[PatternStoreBank, StoreCounters]:
    """Batched Δ[slot, (u_k, v)] <- (φ, μ, Γ) hashed insert (paper Eq. 6)
    across all slots at once.

    Invalid (padding) entries are routed out of bounds and dropped, so
    they can never clobber a real pattern. Returns the updated bank and
    per-slot insert counters (stored / overwrites / evictions / in-batch
    drops) — eviction is counter-guided and always sound (advisory-table
    invariant: losing a pattern only loses pruning, see patterns.store).
    """
    return hash_insert(tb, query_slot, key_pos, key_v, phis, mus, masks,
                       valid)


# ===================================================================
# fused multi-step megastep (DESIGN.md §2 "megastep & async pipeline")
# ===================================================================
class MegaResult(NamedTuple):
    """Digest of one K-depth megastep.

    The ring buffer rows [0, F) are the host's input wave; rows
    [F, tail) were created in-loop. Rows [0, head) were expanded
    in-loop; rows [head, tail) ran out of depth/capacity budget and are
    returned *pending* — the host re-packs them into fresh segments, so
    no work is ever lost to an overflow. All per-row lanes are indexed
    by ring position and are zero for rows never expanded.
    """
    tb: PatternStoreBank         # updated (host flush + in-loop stores)
    buf_frontier: jax.Array      # int32 [C, N_PAD]
    buf_used: jax.Array          # uint32 [C, W]
    buf_phi: jax.Array           # int32 [C, N_PAD + 1]
    buf_slot: jax.Array          # int32 [C]
    buf_depth: jax.Array         # int32 [C]
    buf_parent: jax.Array        # int32 [C] ring index of parent (-1: input)
    buf_valid: jax.Array         # bool [C]
    head: jax.Array              # int32 — rows [0, head) were expanded
    tail: jax.Array              # int32 — rows [head, tail) pending
    refined_empty: jax.Array     # bool [C] Lemma-1 dead (Eq. 2 empty)
    n_children: jax.Array        # int32 [C] surviving children appended
    n_leftover: jax.Array        # int32 [C]
    leftover: jax.Array          # uint32 [C, W]
    partial_mask: jax.Array      # uint32 [C, MASK_WORDS] inj+prune Γ* terms
    n_pruned: jax.Array          # int32 [C]
    n_inj: jax.Array             # int32 [C]
    n_emb_row: jax.Array         # int32 [C] embeddings emitted by the row
    dev_stored: jax.Array        # bool [C] Lemma-1 pattern stored in-loop
    pruned_v: jax.Array          # int32 [C, KPR] Δ-pruned children (-1 pad)
    # per-slot work-item accounting: how much of the dispatch each
    # resident query actually consumed (drives shard/occupancy reports)
    slot_rows: jax.Array         # int32 [S] rows expanded per slot
    slot_children: jax.Array     # int32 [S] rows+embeddings created per slot
    # per-slot Δ store insert accounting (host flush + in-loop stores of
    # this dispatch; occupancy is read off the live bank at report time)
    pat_stored: jax.Array        # int32 [S]
    pat_overwrites: jax.Array    # int32 [S]
    pat_evictions: jax.Array     # int32 [S]
    pat_dropped: jax.Array       # int32 [S]
    emb_frontier: jax.Array      # int32 [emb_cap, N_PAD] found embeddings
    emb_slot: jax.Array          # int32 [emb_cap]
    n_emb: jax.Array             # int32
    n_ids: jax.Array             # int32 fresh embedding ids consumed


@functools.partial(jax.jit, donate_argnums=(2,), static_argnames=(
    "kpr", "k_depth", "capacity", "emb_cap", "backend", "block_f",
    "dma_depth"))
def run_megastep_mq(g: GraphArrays, qb: QueryBank, tb: PatternStoreBank,
                    frontier: jax.Array, used: jax.Array, phi: jax.Array,
                    row_valid: jax.Array, query_slot: jax.Array,
                    depth: jax.Array,
                    st_slot: jax.Array, st_kpos: jax.Array,
                    st_kv: jax.Array, st_phi: jax.Array, st_mu: jax.Array,
                    st_mask: jax.Array, st_valid: jax.Array,
                    id_base: jax.Array, learn_enabled: jax.Array,
                    kpr: int = 8, k_depth: int = 4, capacity: int = 1024,
                    emb_cap: int = 512, backend: str = "jnp",
                    block_f: int = 8,
                    dma_depth: int | None = None) -> MegaResult:
    """Fused expand → assemble → pattern-store over up to ``k_depth``
    consecutive depth-steps, one host round-trip.

    A device-resident ring buffer holds the frontier/used/phi/slot/depth
    lanes of every live row. Each ``lax.while_loop`` iteration pops one
    F-row chunk off the head, expands it (`_expand_rows`), assembles the
    surviving non-last-level children directly at the tail, emits
    last-level children into an embedding buffer, and — for rows whose
    Eq. 2 candidate set came back empty — scatters their Lemma-1
    dead-end pattern ``(φ, μ, Γ = N(u_d) ∩ dom(M̂))`` straight into Δ,
    so later iterations of the *same* dispatch already prune on it.
    The host's batched pattern flush (``st_*``, fixed-length padded with
    a validity lane) is applied before the first iteration, replacing
    the separate ``store_patterns_mq`` dispatch of the single-step path.

    The loop stops when the queue drains, ``k_depth`` chunks were
    expanded, or a conservative worst-case bound (``F·kpr`` appends /
    embeddings per chunk) could overflow the ring or embedding buffer;
    everything still pending is returned in the digest. Fresh embedding
    ids are drawn from ``id_base``; the host reserves the worst case
    (``capacity - F``) so a later dispatch can be issued before this
    digest is read (async double-buffering).

    deep dive: Lemma-4 *aggregated* patterns still resolve on the host
    (they need the row's whole subtree), riding the next dispatch via
    the fused flush — only the immediate Lemma-1 stores move in-loop.
    """
    f_step, w = used.shape
    c = capacity
    assert c >= f_step * (kpr + 1), "ring cannot hold one chunk's children"
    assert emb_cap >= f_step * kpr, "emb buffer cannot hold one chunk"

    # ---- host-batched pattern stores ride the dispatch -----------------
    tb, pat0 = store_patterns_mq(tb, st_slot, st_kpos, st_kv, st_phi,
                                 st_mu, st_mask, st_valid)

    buf_frontier = jnp.full((c, N_PAD), -1, jnp.int32).at[:f_step].set(
        frontier)
    buf_used = jnp.zeros((c, w), jnp.uint32).at[:f_step].set(used)
    buf_phi = jnp.zeros((c, N_PAD + 1), jnp.int32).at[:f_step].set(phi)
    buf_slot = jnp.zeros((c,), jnp.int32).at[:f_step].set(query_slot)
    buf_depth = jnp.zeros((c,), jnp.int32).at[:f_step].set(depth)
    buf_parent = jnp.full((c,), -1, jnp.int32)
    buf_valid = jnp.zeros((c,), bool).at[:f_step].set(row_valid)

    zi = jnp.zeros((c,), jnp.int32)
    n_slots = qb.n_query.shape[0]
    lanes0 = dict(
        refined_empty=jnp.zeros((c,), bool), n_children=zi,
        n_leftover=zi, leftover=jnp.zeros((c, w), jnp.uint32),
        partial_mask=jnp.zeros((c, MASK_WORDS), jnp.uint32),
        n_pruned=zi, n_inj=zi, n_emb_row=zi,
        dev_stored=jnp.zeros((c,), bool),
        pruned_v=jnp.full((c, kpr), -1, jnp.int32),
        slot_rows=jnp.zeros((n_slots,), jnp.int32),
        slot_children=jnp.zeros((n_slots,), jnp.int32))

    state = dict(
        tb=tb, buf_frontier=buf_frontier, buf_used=buf_used,
        buf_phi=buf_phi, buf_slot=buf_slot, buf_depth=buf_depth,
        buf_parent=buf_parent, buf_valid=buf_valid,
        head=jnp.int32(0), tail=jnp.int32(f_step), it=jnp.int32(0),
        emb_frontier=jnp.full((emb_cap, N_PAD), -1, jnp.int32),
        emb_slot=jnp.zeros((emb_cap,), jnp.int32),
        n_emb=jnp.int32(0), id_ctr=jnp.asarray(id_base, jnp.int32),
        pat=pat0,
        **lanes0)

    def cond(s):
        return ((s["head"] < s["tail"]) & (s["it"] < k_depth)
                & (s["tail"] + f_step * kpr <= c)
                & (s["n_emb"] + f_step * kpr <= emb_cap))

    def body(s):
        head, tail = s["head"], s["tail"]
        cf = lax.dynamic_slice_in_dim(s["buf_frontier"], head, f_step)
        cu = lax.dynamic_slice_in_dim(s["buf_used"], head, f_step)
        cp = lax.dynamic_slice_in_dim(s["buf_phi"], head, f_step)
        slot_c = lax.dynamic_slice_in_dim(s["buf_slot"], head, f_step)
        depth_c = lax.dynamic_slice_in_dim(s["buf_depth"], head, f_step)
        in_chunk = (jnp.arange(f_step) + head) < tail
        valid_c = in_chunk & lax.dynamic_slice_in_dim(
            s["buf_valid"], head, f_step)

        res, tb_l = _expand_rows(g, qb, s["tb"], cf, cu, cp, valid_c,
                                 slot_c, depth_c, kpr, backend, block_f,
                                 dma_depth)

        is_last = depth_c + 1 == qb.n_query[slot_c]          # [F]

        # ---- materialize all surviving children (flat) -----------------
        parent_local = jnp.repeat(jnp.arange(f_step, dtype=jnp.int32), kpr)
        flat_v = res.child_v.reshape(-1)
        cvalid_flat = res.child_valid.reshape(-1)
        d_par = depth_c[parent_local]
        pos = jnp.arange(N_PAD)
        cf2 = cf[parent_local]
        cf2 = jnp.where((pos[None, :] == d_par[:, None])
                        & cvalid_flat[:, None], flat_v[:, None], cf2)
        vv = flat_v.clip(0)
        word = (vv // 32).astype(jnp.int32)
        bit = jnp.uint32(1) << (vv % 32).astype(jnp.uint32)
        cu2 = cu[parent_local]
        add = jnp.zeros_like(cu2).at[
            jnp.arange(cu2.shape[0]), word].set(
                jnp.where(cvalid_flat, bit, jnp.uint32(0)))
        cu2 = cu2 | add

        # ---- embeddings: last-level children go to the emb buffer ------
        emb_valid = cvalid_flat & is_last[parent_local]
        emb_off = jnp.cumsum(emb_valid.astype(jnp.int32)) - 1
        emb_idx = jnp.where(emb_valid, s["n_emb"] + emb_off, emb_cap)
        emb_frontier = s["emb_frontier"].at[emb_idx].set(cf2, mode="drop")
        emb_slot = s["emb_slot"].at[emb_idx].set(
            slot_c[parent_local], mode="drop")
        n_emb_new = emb_valid.sum().astype(jnp.int32)
        n_emb_row_c = (res.child_valid
                       & is_last[:, None]).sum(axis=1).astype(jnp.int32)

        # ---- append non-last children at the tail ----------------------
        app_valid = cvalid_flat & ~is_last[parent_local]
        app_off = jnp.cumsum(app_valid.astype(jnp.int32)) - 1
        app_idx = jnp.where(app_valid, tail + app_off, c)
        new_ids = s["id_ctr"] + app_off
        pos_phi = jnp.arange(N_PAD + 1)
        cp2 = cp[parent_local]
        cp2 = jnp.where((pos_phi[None, :] == d_par[:, None] + 1)
                        & app_valid[:, None], new_ids[:, None], cp2)
        n_new = app_valid.sum().astype(jnp.int32)
        bf = s["buf_frontier"].at[app_idx].set(cf2, mode="drop")
        bu = s["buf_used"].at[app_idx].set(cu2, mode="drop")
        bp = s["buf_phi"].at[app_idx].set(cp2, mode="drop")
        bs = s["buf_slot"].at[app_idx].set(
            slot_c[parent_local], mode="drop")
        bd = s["buf_depth"].at[app_idx].set(d_par + 1, mode="drop")
        bpar = s["buf_parent"].at[app_idx].set(
            head + parent_local, mode="drop")
        bv = s["buf_valid"].at[app_idx].set(True, mode="drop")
        n_child_c = (res.child_valid
                     & ~is_last[:, None]).sum(axis=1).astype(jnp.int32)

        # ---- in-loop Lemma-1 stores (Eq. 2 came back empty) ------------
        do_store = (res.refined_empty & (depth_c >= 1)
                    & qb.learn[slot_c] & learn_enabled)
        qnbr = _pack_mask_rows(qb.nbr_mask[slot_c, depth_c])
        gamma_w = qnbr & _below_bits_rows(depth_c)           # [F, MW]
        key_pos = (depth_c - 1).clip(0)
        key_v = jnp.take_along_axis(cf, key_pos[:, None], axis=1)[:, 0]
        mu = _mask_bitlen(gamma_w & _below_bits_rows(key_pos))
        phi_id = jnp.take_along_axis(cp, mu[:, None], axis=1)[:, 0]
        tb2, pat_c = store_patterns_mq(tb_l, slot_c, key_pos, key_v,
                                       phi_id, mu, gamma_w, do_store)

        # ---- digest lanes for this chunk -------------------------------
        def put(lane, vals):
            return lax.dynamic_update_slice_in_dim(lane, vals, head, 0)

        msk = valid_c

        def m1(x):
            return jnp.where(msk, x, jnp.zeros_like(x))

        def m2(x):
            return jnp.where(msk[:, None], x, jnp.zeros_like(x))

        return dict(
            tb=tb2, buf_frontier=bf, buf_used=bu, buf_phi=bp,
            buf_slot=bs, buf_depth=bd, buf_parent=bpar, buf_valid=bv,
            head=jnp.minimum(head + f_step, tail), tail=tail + n_new,
            it=s["it"] + 1, emb_frontier=emb_frontier, emb_slot=emb_slot,
            n_emb=s["n_emb"] + n_emb_new, id_ctr=s["id_ctr"] + n_new,
            pat=s["pat"].add(pat_c),
            refined_empty=put(s["refined_empty"], res.refined_empty),
            n_children=put(s["n_children"], m1(n_child_c)),
            n_leftover=put(s["n_leftover"], m1(res.n_leftover)),
            leftover=put(s["leftover"], m2(res.leftover)),
            partial_mask=put(s["partial_mask"], m2(res.partial_mask)),
            n_pruned=put(s["n_pruned"], m1(res.n_pruned)),
            n_inj=put(s["n_inj"], m1(res.n_inj)),
            n_emb_row=put(s["n_emb_row"], m1(n_emb_row_c)),
            dev_stored=put(s["dev_stored"], m1(do_store)),
            pruned_v=put(s["pruned_v"],
                         jnp.where(msk[:, None], res.pruned_v, -1)),
            slot_rows=s["slot_rows"].at[slot_c].add(
                valid_c.astype(jnp.int32)),
            slot_children=s["slot_children"].at[slot_c].add(
                m1(n_child_c + n_emb_row_c)))

    s = lax.while_loop(cond, body, state)
    return MegaResult(
        tb=s["tb"], buf_frontier=s["buf_frontier"], buf_used=s["buf_used"],
        buf_phi=s["buf_phi"], buf_slot=s["buf_slot"],
        buf_depth=s["buf_depth"], buf_parent=s["buf_parent"],
        buf_valid=s["buf_valid"], head=s["head"], tail=s["tail"],
        refined_empty=s["refined_empty"], n_children=s["n_children"],
        n_leftover=s["n_leftover"], leftover=s["leftover"],
        partial_mask=s["partial_mask"], n_pruned=s["n_pruned"],
        n_inj=s["n_inj"], n_emb_row=s["n_emb_row"],
        dev_stored=s["dev_stored"], pruned_v=s["pruned_v"],
        slot_rows=s["slot_rows"], slot_children=s["slot_children"],
        pat_stored=s["pat"].stored, pat_overwrites=s["pat"].overwrites,
        pat_evictions=s["pat"].evictions, pat_dropped=s["pat"].dropped,
        emb_frontier=s["emb_frontier"],
        emb_slot=s["emb_slot"], n_emb=s["n_emb"],
        n_ids=s["id_ctr"] - jnp.asarray(id_base, jnp.int32))


# ===================================================================
# device-resident frontier stacks (DESIGN.md §2 "device-resident state")
# ===================================================================
# Entry states of the per-slot stack. FREE entries are allocatable;
# FRESH/LEFT entries are pending work (each is on the slot's pending
# LIFO exactly once); WAIT entries were expanded and wait for their
# allocated children to resolve (Lemma 4 aggregation); RES entries hold
# a *converted* Γ ready to fold into their parent and be freed.
STK_FREE = 0
STK_FRESH = 1
STK_LEFT = 2
STK_WAIT = 3
STK_RES = 4


class StackBank(NamedTuple):
    """Per-slot DFS stacks held in device arrays ([S, D, ...]).

    This is the device-resident replacement for the host ``SegmentPool``
    row bookkeeping: one entry per live partial embedding, with the
    frontier/used/φ/depth lanes the wave programs consume plus the
    Lemma-4 resolution lanes (Γ accumulator, outstanding-children count,
    reported flag, parent entry index). ``pstack``/``ptop`` form the
    per-slot pending LIFO the expansion loop repacks waves from — the
    host never sees individual rows, only per-slot scalars.
    """
    frontier: jax.Array      # int32 [S, D, N_PAD]
    used: jax.Array          # uint32 [S, D, W]
    phi: jax.Array           # int32 [S, D, N_PAD + 1]
    depth: jax.Array         # int32 [S, D]
    cand: jax.Array          # uint32 [S, D, W] leftover bitmap (LEFT)
    state: jax.Array         # int8 [S, D] STK_* lifecycle
    gamma: jax.Array         # uint32 [S, D, MASK_WORDS] Γ* accumulator
    outstanding: jax.Array   # int32 [S, D] unresolved allocated children
    reported: jax.Array      # bool [S, D] subtree reached an embedding
    parent: jax.Array        # int32 [S, D] parent entry index (-1 = root)
    pstack: jax.Array        # int32 [S, D] pending LIFO of entry indices
    ptop: jax.Array          # int32 [S]

    @staticmethod
    def empty(n_slots: int, depth_cap: int, w: int) -> "StackBank":
        s, d = n_slots, depth_cap
        return StackBank(
            frontier=jnp.full((s, d, N_PAD), -1, jnp.int32),
            used=jnp.zeros((s, d, w), jnp.uint32),
            phi=jnp.zeros((s, d, N_PAD + 1), jnp.int32),
            depth=jnp.zeros((s, d), jnp.int32),
            cand=jnp.zeros((s, d, w), jnp.uint32),
            state=jnp.zeros((s, d), jnp.int8),
            gamma=jnp.zeros((s, d, MASK_WORDS), jnp.uint32),
            outstanding=jnp.zeros((s, d), jnp.int32),
            reported=jnp.zeros((s, d), bool),
            parent=jnp.full((s, d), -1, jnp.int32),
            pstack=jnp.zeros((s, d), jnp.int32),
            ptop=jnp.zeros((s,), jnp.int32))


@functools.partial(jax.jit, donate_argnums=(0,))
def clear_slot_stack(sb: StackBank, slot: jax.Array) -> StackBank:
    """Release every entry of one slot (query retired / evicted). Only
    the state and top-pointer lanes matter — FREE entries' payload lanes
    are rewritten on allocation."""
    return sb._replace(state=sb.state.at[slot].set(STK_FREE),
                       ptop=sb.ptop.at[slot].set(0))


@functools.partial(jax.jit, donate_argnums=(0,))
def clear_slot_stacks(sb: StackBank, slots: jax.Array) -> StackBank:
    """Batch variant of :func:`clear_slot_stack`: release several slots
    in one dispatch (``slots`` [k]; out-of-range values drop)."""
    return sb._replace(
        state=sb.state.at[slots].set(STK_FREE, mode="drop"),
        ptop=sb.ptop.at[slots].set(0, mode="drop"))


class DeviceResult(NamedTuple):
    """Per-slot scalar digest of one device-resident dispatch.

    This is everything that crosses the device→host boundary besides
    the embedding batch: counters for stats/budget accounting plus the
    stack's live/pending sizes for completion detection. No per-row
    lanes — the rows stayed on device.
    """
    tb: PatternStoreBank
    sb: StackBank
    d_accepted: jax.Array    # int32 [S] admitted root rows
    d_expanded: jax.Array    # int32 [S] rows expanded (selected)
    d_rows: jax.Array        # int32 [S] child rows allocated
    d_prunes: jax.Array      # int32 [S] Δ dead-end prunes
    d_inj: jax.Array         # int32 [S] injectivity kills
    d_stored: jax.Array      # int32 [S] patterns stored (L1 + L4)
    d_pending: jax.Array     # int32 [S] pending LIFO size after
    d_live: jax.Array        # int32 [S] non-FREE entries after
    d_outsum: jax.Array      # int32 [S] sum of live entries' outstanding
    d_childlive: jax.Array   # int32 [S] live entries with a parent
    pat_stored: jax.Array    # int32 [S] Δ insert counters
    pat_overwrites: jax.Array
    pat_evictions: jax.Array
    pat_dropped: jax.Array
    emb_frontier: jax.Array  # int32 [emb_cap, N_PAD]
    emb_slot: jax.Array      # int32 [emb_cap]
    n_emb: jax.Array         # int32
    n_ids: jax.Array         # int32 fresh embedding ids consumed


def _slot_counts(sel_slot: jax.Array, valid: jax.Array, n_slots: int,
                 weights: jax.Array | None = None) -> jax.Array:
    """Per-slot sum of ``weights`` (default 1) over valid rows."""
    tgt = jnp.where(valid, sel_slot, n_slots)
    w = (valid.astype(jnp.int32) if weights is None
         else jnp.where(valid, weights, 0))
    return jnp.zeros((n_slots + 1,), jnp.int32).at[tgt].add(w)[:n_slots]


def _group_rank(slot: jax.Array, valid: jax.Array, n_slots: int
                ) -> jax.Array:
    """Rank of each valid element within its slot group.

    Requires the valid elements to be grouped by slot in ascending
    order (wave rows and their flattened children are laid out that way
    by construction): rank = global running index minus the group's
    first global index, recovered with a scatter-min.
    """
    gidx = jnp.cumsum(valid.astype(jnp.int32)) - 1
    big = jnp.int32(2**30)
    start = jnp.full((n_slots + 1,), big, jnp.int32).at[
        jnp.where(valid, slot, n_slots)].min(gidx)
    return jnp.where(valid, gidx - start[slot], 0)


def _select_set_bits(mask: jax.Array, k: int) -> jax.Array:
    """Indices of the first ``k`` set bits of bool [n] ``mask``, in
    ascending order (``n`` where exhausted).

    Gather-based: binary search over the inclusive cumsum. The obvious
    scatter (``zeros(k).at[rank].set(iota)``) carries *n* updates, and
    XLA's CPU scatter executes updates serially — at stack-bank sizes
    (n = S·D ≈ 65k) one such compaction costs milliseconds, and the
    resolution sweep runs several per iteration.
    """
    csum = jnp.cumsum(mask.astype(jnp.int32))
    ks = jnp.arange(1, k + 1, dtype=jnp.int32)
    return jnp.searchsorted(csum, ks, side="left").astype(jnp.int32)


def _free_entry_order(isfree: jax.Array) -> jax.Array:
    """``eor[s, r]`` = entry id of the r-th free entry of slot ``s``
    (``d_cap`` when exhausted) — row-wise :func:`_select_set_bits`, for
    the same serial-scatter reason."""
    d_cap = isfree.shape[1]
    frank = jnp.cumsum(isfree.astype(jnp.int32), axis=1)
    ks = jnp.arange(1, d_cap + 1, dtype=jnp.int32)
    return jax.vmap(
        lambda row: jnp.searchsorted(row, ks, side="left"))(
            frank).astype(jnp.int32)


def _resolution_sweep(qb: QueryBank, tb: PatternStoreBank, lanes: dict,
                      learn_enabled: jax.Array, batch: int
                      ) -> tuple[PatternStoreBank, dict, jax.Array,
                                 StoreCounters]:
    """One Lemma-4 resolution pass over every slot's stack.

    Phase A folds *every* resolved (RES) child into its parent: Γ|=child
    Γ unless the child reported, outstanding -= child count, child
    entries freed (resolved roots are freed directly). outstanding and
    reported fold with conflict-free scatter add/max; the Γ OR-fold has
    no scatter-or primitive, so children are sorted by parent and
    OR-reduced with a segmented associative scan — a kpr-way fan-out
    folds in one sweep instead of kpr winner-per-parent sweeps, which
    kept the drain loop spinning for hundreds of iterations. Phase B
    finalizes up to ``batch`` subtree-exhausted WAIT entries
    (outstanding == 0, no pending leftover — LEFT is a distinct state):
    the μ==0-vs-μ>0 conversion of ``SegmentPool.finalize_row`` and the
    Δ store of ``queue_store`` become lanes, and the entry turns RES
    carrying the *converted* Γ for the next phase-A fold. One sweep per
    expansion iteration keeps resolution concurrent with the DFS;
    leftover unresolved state legally persists across dispatches.

    Returns (tb, lanes, per-slot stores int32 [S], insert counters).
    """
    state, gamma = lanes["state"], lanes["gamma"]
    outstanding, reported = lanes["outstanding"], lanes["reported"]
    s_dim, d_dim = state.shape
    s_grid = jnp.broadcast_to(jnp.arange(s_dim)[:, None], (s_dim, d_dim))

    # ---- phase A: fold resolved children into their parents ------------
    res_m = state == STK_RES
    par = lanes["parent"]
    res_child = res_m & (par >= 0)        # RES roots free directly
    n_flat = s_dim * d_dim
    # compact to the first 2*batch resolved children (one iteration
    # creates at most ``batch`` RES rows at expansion + ``batch`` at
    # phase-B finalize; stragglers legally wait a sweep) so the sort
    # below runs over O(batch), not the whole stack
    b_cap = 2 * batch
    child_i = _select_set_bits(res_child.reshape(-1), b_cap)
    valid_c = child_i < n_flat
    ci = child_i.clip(0, n_flat - 1)
    rep_flat = reported.reshape(-1)
    gam_flat = gamma.reshape(n_flat, -1)
    pgid_all = (s_grid * d_dim + par).reshape(-1)
    pg = jnp.where(valid_c, pgid_all[ci], n_flat)   # n_flat = dump row
    crep = rep_flat[ci]

    cnt = jnp.zeros((n_flat + 1,), jnp.int32).at[pg].add(1)[:n_flat]
    rep_fold = jnp.zeros((n_flat + 1,), bool).at[pg].max(crep)[:n_flat]

    # a RES parent never has RES children (it finalized with
    # outstanding == 0), so the per-parent OR below is race-free: sort
    # the taken children by parent and OR-reduce with a segmented scan —
    # there is no scatter-or primitive, and winner-per-parent sweeps
    # made a kpr-way fan-out take kpr drain iterations
    order = jnp.argsort(pg)
    ps = pg[order]
    gs = jnp.where((valid_c & ~crep)[order, None],
                   gam_flat[ci[order]], 0)  # a reported child folds no Γ

    def _seg_or(a, b):
        fa, va = a
        fb, vb = b
        return fa | fb, jnp.where(fb[..., None], vb, va | vb)

    seg_new = jnp.concatenate(
        [jnp.ones((1,), bool), ps[1:] != ps[:-1]])
    _, acc = lax.associative_scan(_seg_or, (seg_new, gs))
    is_end = jnp.concatenate(
        [ps[1:] != ps[:-1], jnp.ones((1,), bool)]) & (ps < n_flat)
    # segment ends carry the full OR and hit unique parents
    contrib = jnp.zeros((n_flat + 1, gamma.shape[-1]), gamma.dtype).at[
        jnp.where(is_end, ps, n_flat)].set(acc)[:n_flat]
    gamma = (gam_flat | contrib).reshape(gamma.shape)
    reported = rep_flat.reshape(s_dim, d_dim) | rep_fold.reshape(
        s_dim, d_dim)
    outstanding = outstanding - cnt.reshape(s_dim, d_dim)
    state_flat = state.reshape(-1).at[child_i].set(
        jnp.int8(STK_FREE), mode="drop")      # folded children freed
    state = jnp.where(res_m & (par < 0), jnp.int8(STK_FREE),
                      state_flat.reshape(s_dim, d_dim))

    # ---- phase B: finalize subtree-exhausted WAIT entries --------------
    fin = (state == STK_WAIT) & (outstanding == 0)
    bsel = _select_set_bits(fin.reshape(-1), batch)
    valid_b = bsel < n_flat
    bclip = bsel.clip(0, n_flat - 1)
    slot_b = jnp.where(valid_b, bclip // d_dim, 0)
    ent_b = jnp.where(valid_b, bclip % d_dim, 0)

    d_b = lanes["depth"][slot_b, ent_b]
    gm = gamma[slot_b, ent_b]                       # [B, MW]
    rep_b = reported[slot_b, ent_b]
    fr_b = lanes["frontier"][slot_b, ent_b]
    ph_b = lanes["phi"][slot_b, ent_b]
    # finalize_row's conversion: Γ mentions position d → the row's own
    # Eq. 2 neighbourhood joins and everything >= d is cut
    qnbr_b = _pack_mask_rows(qb.nbr_mask[slot_b, d_b])
    has_bit = ((gm & _position_bits(d_b)) != 0).any(axis=1)
    gconv = jnp.where(has_bit[:, None],
                      (gm | qnbr_b) & _below_bits_rows(d_b), gm)
    key_pos = (d_b - 1).clip(0)
    key_v = jnp.take_along_axis(fr_b, key_pos[:, None], axis=1)[:, 0]
    mu = _mask_bitlen(gconv & _below_bits_rows(key_pos))
    phi_v = jnp.take_along_axis(ph_b, mu[:, None], axis=1)[:, 0]
    do_store = (valid_b & ~rep_b & (d_b >= 1)
                & qb.learn[slot_b] & learn_enabled)
    tb, pat_c = store_patterns_mq(tb, slot_b, key_pos, key_v, phi_v, mu,
                                  gconv, do_store)

    state = state.reshape(-1).at[bsel].set(
        jnp.int8(STK_RES), mode="drop").reshape(s_dim, d_dim)
    sb_eff = jnp.where(valid_b, slot_b, s_dim)
    gamma = gamma.at[sb_eff, ent_b].set(gconv, mode="drop")

    stores = _slot_counts(slot_b, do_store, s_dim)
    lanes = dict(lanes, state=state, gamma=gamma, outstanding=outstanding,
                 reported=reported)
    return tb, lanes, stores, pat_c


@functools.partial(jax.jit, donate_argnums=(2, 3), static_argnames=(
    "kpr", "emb_cap", "backend", "wave", "block_f", "dma_depth"))
def run_device_megastep(g: GraphArrays, qb: QueryBank,
                        tb: PatternStoreBank, sb: StackBank,
                        in_root: jax.Array, in_rid: jax.Array,
                        in_slot: jax.Array, in_valid: jax.Array,
                        active: jax.Array, id_base: jax.Array,
                        learn_enabled: jax.Array, t_max: jax.Array,
                        kpr: int = 8, emb_cap: int = 512,
                        backend: str = "jnp",
                        wave: int | None = None,
                        block_f: int = 8,
                        dma_depth: int | None = None) -> DeviceResult:
    """One dispatch of the device-resident scheduler loop.

    Admits root rows into free stack entries, then runs up to ``t_max``
    repack→expand→resolve iterations entirely on device: each iteration
    pops a mixed wave of pending entries off the per-slot LIFOs (DFS
    order, waterfill quota across slots), expands it (Eq. 2 refinement,
    injectivity, top-kpr extraction, Eq. 7 dead-end probe — fresh
    entries — or re-extraction from the stored leftover bitmap — LEFT
    entries), allocates surviving non-last children as new stack
    entries, emits last-level children into the embedding buffer, stores
    Lemma-1 patterns in-loop, and runs one Lemma-4 resolution sweep.
    A final progress-bounded drain resolves what the iterations left.

    Children that find no free entry fold back into their parent's
    leftover bitmap (the entry re-queues as LEFT), so a full stack
    degrades to throttling, never to lost work. ``t_max`` is traced —
    the adaptive scheduler drops it to 1 under high prune rates without
    recompiling. Only this digest (per-slot scalars + the embedding
    batch) crosses back to the host.
    """
    # the root-intake width ``r`` is decoupled from the wave width
    # ``f``: a dispatch can land more roots than one wave expands, so a
    # fresh query batch reaches the device in one call instead of
    # trickling across several fixed-cost dispatches
    r = in_root.shape[0]
    f = wave if wave is not None else r
    n_slots, d_cap = sb.state.shape
    w = sb.used.shape[2]
    assert emb_cap >= f * kpr, "emb buffer cannot hold one iteration"
    f_rows = jnp.arange(f)
    # per-iteration allocation bound: overflow children fold back into
    # their parent's leftover bitmap, so this only throttles, and it
    # keeps the per-row lane-scatter cost off the f·kpr padding
    a_cap = min(8 * f, f * kpr)

    lanes = dict(frontier=sb.frontier, used=sb.used, phi=sb.phi,
                 depth=sb.depth, cand=sb.cand, state=sb.state,
                 gamma=sb.gamma, outstanding=sb.outstanding,
                 reported=sb.reported, parent=sb.parent,
                 pstack=sb.pstack, ptop=sb.ptop)

    # ---- root admission: place accepted inputs into free entries -------
    # Inputs are grouped by slot. Acceptance is throttled so a dispatch
    # leaves allocation headroom; unaccepted roots stay queued on the
    # host (the cursor only advances by d_accepted).
    isfree = lanes["state"] == STK_FREE
    free_n = isfree.sum(axis=1).astype(jnp.int32)
    n_in = _slot_counts(in_slot, in_valid, n_slots)
    accept_s = jnp.where(active, jnp.minimum(n_in, free_n // (kpr + 2)), 0)
    rank_in = _group_rank(in_slot, in_valid, n_slots)
    acc = in_valid & (rank_in < accept_s[in_slot])

    eor = _free_entry_order(isfree)
    ent_in = eor[in_slot, rank_in.clip(0, d_cap - 1)]
    ok_in = acc & (ent_in < d_cap)
    tgt_s = jnp.where(ok_in, in_slot, n_slots)
    tgt_e = jnp.where(ok_in, ent_in, 0)

    root_f = jnp.where(jnp.arange(N_PAD)[None, :] == 0,
                       in_root[:, None], -1).astype(jnp.int32)
    rv = in_root.clip(0)
    root_u = jnp.zeros((r, w), jnp.uint32).at[
        jnp.arange(r), (rv // 32)].set(jnp.uint32(1) << (rv % 32).astype(
            jnp.uint32))
    root_p = jnp.where(jnp.arange(N_PAD + 1)[None, :] == 1,
                       in_rid[:, None], 0).astype(jnp.int32)

    lanes["frontier"] = lanes["frontier"].at[tgt_s, tgt_e].set(
        root_f, mode="drop")
    lanes["used"] = lanes["used"].at[tgt_s, tgt_e].set(root_u, mode="drop")
    lanes["phi"] = lanes["phi"].at[tgt_s, tgt_e].set(root_p, mode="drop")
    lanes["depth"] = lanes["depth"].at[tgt_s, tgt_e].set(1, mode="drop")
    lanes["state"] = lanes["state"].at[tgt_s, tgt_e].set(
        jnp.int8(STK_FRESH), mode="drop")
    lanes["gamma"] = lanes["gamma"].at[tgt_s, tgt_e].set(
        jnp.uint32(0), mode="drop")
    lanes["outstanding"] = lanes["outstanding"].at[tgt_s, tgt_e].set(
        0, mode="drop")
    lanes["reported"] = lanes["reported"].at[tgt_s, tgt_e].set(
        False, mode="drop")
    lanes["parent"] = lanes["parent"].at[tgt_s, tgt_e].set(-1, mode="drop")
    lanes["cand"] = lanes["cand"].at[tgt_s, tgt_e].set(
        jnp.uint32(0), mode="drop")
    push_pos = jnp.where(ok_in, lanes["ptop"][in_slot] + rank_in, 0)
    lanes["pstack"] = lanes["pstack"].at[tgt_s, push_pos].set(
        ent_in, mode="drop")
    d_accepted = _slot_counts(in_slot, ok_in, n_slots)
    lanes["ptop"] = lanes["ptop"] + d_accepted

    zs = jnp.zeros((n_slots,), jnp.int32)
    carry = dict(
        tb=tb, it=jnp.int32(0),
        emb_frontier=jnp.full((emb_cap, N_PAD), -1, jnp.int32),
        emb_slot=jnp.zeros((emb_cap,), jnp.int32), n_emb=jnp.int32(0),
        id_ctr=jnp.asarray(id_base, jnp.int32),
        pat=StoreCounters.zeros(n_slots),
        d_expanded=zs, d_rows=zs, d_prunes=zs, d_inj=zs, d_stored=zs,
        **lanes)

    lane_keys = tuple(lanes.keys())

    def cond(s):
        return ((s["it"] < t_max)
                & (jnp.where(active, s["ptop"], 0) > 0).any()
                & (s["n_emb"] + f * kpr <= emb_cap))

    def body(s):
        st, ptop = s["state"], s["ptop"]

        # ---- wave selection: waterfill quota over pending slots --------
        pend = jnp.where(active, ptop, 0)
        free_now = (st == STK_FREE).sum(axis=1).astype(jnp.int32)
        quota_cap = jnp.maximum(free_now // (kpr + 1), 1)
        desire = jnp.minimum(pend, quota_cap)
        n_act = jnp.maximum((desire > 0).sum(), 1)
        base = jnp.int32(f) // n_act
        q1 = jnp.minimum(desire, base)
        want = desire - q1
        rem = jnp.int32(f) - q1.sum()
        extra = jnp.clip(jnp.minimum(
            want, rem - (jnp.cumsum(want) - want)), 0, None)
        q = q1 + extra                                       # [S]
        offs = jnp.cumsum(q) - q
        total = q.sum()
        s_of = jnp.searchsorted(jnp.cumsum(q), f_rows,
                                side="right").astype(jnp.int32)
        row_valid = f_rows < total
        s_of_c = jnp.where(row_valid, s_of, 0).clip(0, n_slots - 1)
        k_in = (f_rows - offs[s_of_c]).clip(0)
        ent_sel = s["pstack"][s_of_c, (ptop[s_of_c] - 1 - k_in).clip(0)]
        e_c = jnp.where(row_valid, ent_sel, 0)
        ptop2 = ptop - q

        wf = s["frontier"][s_of_c, e_c]
        wu = s["used"][s_of_c, e_c]
        wphi = s["phi"][s_of_c, e_c]
        wd = s["depth"][s_of_c, e_c]
        wcand = s["cand"][s_of_c, e_c]
        wg = s["gamma"][s_of_c, e_c]
        st_sel = st[s_of_c, e_c]
        is_left = (st_sel == STK_LEFT) & row_valid
        is_fresh = (st_sel == STK_FRESH) & row_valid

        # ---- expansion (fresh: full Eq.2 pass; LEFT: re-extraction) ----
        refined = refine_eq2_mq(g, qb, s_of_c, wf, wd, backend, block_f,
                                dma_depth)
        refined = jnp.where(is_fresh[:, None], refined, jnp.uint32(0))
        refined_empty = is_fresh & (_popcount_rows(refined) == 0)

        inj_words = refined & wu
        n_inj_row = jnp.where(is_fresh, _popcount_rows(inj_words), 0)
        depth_bits = _position_bits(wd)

        # vectorized over positions (no fori_loop): position bits are
        # disjoint across p, so the OR-fold is an exact integer sum
        verts = wf.clip(0)                                   # [F, NP]
        words = jnp.take_along_axis(refined, verts // 32, axis=1)
        hit = ((words >> (verts % 32).astype(jnp.uint32)) & 1) > 0
        hit &= (jnp.arange(N_PAD)[None, :] < wd[:, None]) \
            & is_fresh[:, None]
        posb = _position_bits(jnp.arange(N_PAD, dtype=jnp.int32))
        inj_mask = (hit[:, :, None].astype(jnp.uint32)
                    * posb[None, :, :]).sum(axis=1, dtype=jnp.uint32)
        inj_mask = inj_mask | jnp.where(hit.any(axis=1)[:, None],
                                        depth_bits, jnp.uint32(0))

        live = jnp.where(is_left[:, None], wcand, refined & ~wu)
        child_v, leftover, n_leftover = _extract_topk_packed(live, kpr)
        prune, prune_mask, tb_l = deadend_lookup_children_mq(
            s["tb"], wphi, s_of_c, wd, child_v)
        child_valid = (child_v >= 0) & ~prune & row_valid[:, None]
        partial = jnp.where(is_left[:, None], prune_mask,
                            inj_mask | prune_mask)
        n_pruned_row = jnp.where(row_valid, prune.sum(axis=1), 0)

        # ---- materialize children (flat [F*kpr], slot-grouped) ---------
        parent_local = jnp.repeat(jnp.arange(f, dtype=jnp.int32), kpr)
        flat_v = child_v.reshape(-1)
        cvalid_flat = child_valid.reshape(-1)
        d_par = wd[parent_local]
        slot_flat = s_of_c[parent_local]
        is_last = wd + 1 == qb.n_query[s_of_c]
        last_flat = is_last[parent_local]
        pos = jnp.arange(N_PAD)
        cf2 = wf[parent_local]
        cf2 = jnp.where((pos[None, :] == d_par[:, None])
                        & cvalid_flat[:, None], flat_v[:, None], cf2)
        vv = flat_v.clip(0)

        # ---- embeddings: last-level children, no allocation ------------
        emb_valid = cvalid_flat & last_flat
        emb_off = jnp.cumsum(emb_valid.astype(jnp.int32)) - 1
        emb_idx = jnp.where(emb_valid, s["n_emb"] + emb_off, emb_cap)
        emb_frontier = s["emb_frontier"].at[emb_idx].set(cf2, mode="drop")
        emb_slot = s["emb_slot"].at[emb_idx].set(slot_flat, mode="drop")
        n_emb_new = emb_valid.sum().astype(jnp.int32)
        n_emb_row = (child_valid & is_last[:, None]).sum(
            axis=1).astype(jnp.int32)

        # ---- allocate non-last children into free entries --------------
        # compacted to at most ``a_cap`` rows: the CPU backend executes
        # scatter updates serially, so every lane scatter below costs
        # per-row — and most of the F·kpr child rows are dead padding.
        # Children past the cap simply fold back into their parent's
        # leftover bitmap (LEFT requeue), the same sound degradation as
        # running out of free entries.
        eor_l = _free_entry_order(st == STK_FREE)
        app_valid = cvalid_flat & ~last_flat
        a_sel = _select_set_bits(app_valid, a_cap)           # [A]
        a_valid = a_sel < f * kpr
        a_i = a_sel.clip(0, f * kpr - 1)
        slot_a = slot_flat[a_i]
        par_a = parent_local[a_i]
        j = _group_rank(slot_a, a_valid, n_slots)
        ent_ch = eor_l[slot_a, j.clip(0, d_cap - 1)]
        ok = a_valid & (ent_ch < d_cap)
        alloc_flag = jnp.zeros((f * kpr,), bool).at[
            jnp.where(ok, a_sel, f * kpr)].set(True, mode="drop")
        fail = app_valid & ~alloc_flag

        # children that found no entry fold back into the parent row's
        # leftover bitmap (distinct vertices → add == or)
        fold = jnp.zeros((f, w), jnp.uint32).at[
            parent_local, (vv // 32)].add(
                jnp.where(fail,
                          jnp.uint32(1) << (vv % 32).astype(jnp.uint32),
                          jnp.uint32(0)))
        leftover = leftover | fold
        n_leftover = _popcount_rows(leftover)

        ok_s = jnp.where(ok, slot_a, n_slots)
        ok_e = jnp.where(ok, ent_ch, 0)
        child_ids = s["id_ctr"] + jnp.cumsum(ok.astype(jnp.int32)) - 1
        d_par_a = d_par[a_i]
        vv_a = vv[a_i]
        cf_a = cf2[a_i]
        cu_a = wu[par_a] | jnp.zeros((a_cap, w), jnp.uint32).at[
            jnp.arange(a_cap), (vv_a // 32)].set(
                jnp.uint32(1) << (vv_a % 32).astype(jnp.uint32))
        pos_phi = jnp.arange(N_PAD + 1)
        cp_a = wphi[par_a]
        cp_a = jnp.where((pos_phi[None, :] == d_par_a[:, None] + 1)
                         & ok[:, None], child_ids[:, None], cp_a)
        n_alloc = ok.sum().astype(jnp.int32)
        n_alloc_row = alloc_flag.reshape(f, kpr).sum(
            axis=1).astype(jnp.int32)
        alloc_s = _slot_counts(slot_a, ok, n_slots)

        fr_l = s["frontier"].at[ok_s, ok_e].set(cf_a, mode="drop")
        us_l = s["used"].at[ok_s, ok_e].set(cu_a, mode="drop")
        ph_l = s["phi"].at[ok_s, ok_e].set(cp_a, mode="drop")
        de_l = s["depth"].at[ok_s, ok_e].set(d_par_a + 1, mode="drop")
        st_l = st.at[ok_s, ok_e].set(jnp.int8(STK_FRESH), mode="drop")
        gm_l = s["gamma"].at[ok_s, ok_e].set(jnp.uint32(0), mode="drop")
        ou_l = s["outstanding"].at[ok_s, ok_e].set(0, mode="drop")
        re_l = s["reported"].at[ok_s, ok_e].set(False, mode="drop")
        pa_l = s["parent"].at[ok_s, ok_e].set(
            ent_sel[par_a], mode="drop")
        ca_l = s["cand"].at[ok_s, ok_e].set(jnp.uint32(0), mode="drop")

        # ---- in-loop Lemma-1 stores (Eq. 2 came back empty) ------------
        do_store = (refined_empty & (wd >= 1)
                    & qb.learn[s_of_c] & learn_enabled)
        qnbr = _pack_mask_rows(qb.nbr_mask[s_of_c, wd])
        gamma_w = qnbr & _below_bits_rows(wd)
        key_pos = (wd - 1).clip(0)
        key_v = jnp.take_along_axis(wf, key_pos[:, None], axis=1)[:, 0]
        mu = _mask_bitlen(gamma_w & _below_bits_rows(key_pos))
        phi_id = jnp.take_along_axis(wphi, mu[:, None], axis=1)[:, 0]
        tb2, pat_c = store_patterns_mq(tb_l, s_of_c, key_pos, key_v,
                                       phi_id, mu, gamma_w, do_store)

        # ---- update the selected entries -------------------------------
        has_left = (n_leftover > 0) & row_valid & ~refined_empty
        new_state = jnp.where(
            refined_empty, jnp.int8(STK_RES),
            jnp.where(has_left, jnp.int8(STK_LEFT), jnp.int8(STK_WAIT)))
        new_g = (wg | partial
                 | jnp.where(refined_empty[:, None], gamma_w,
                             jnp.uint32(0)))
        sel_s = jnp.where(row_valid, s_of_c, n_slots)
        st_l = st_l.at[sel_s, e_c].set(new_state, mode="drop")
        gm_l = gm_l.at[sel_s, e_c].set(new_g, mode="drop")
        ou_l = ou_l.at[sel_s, e_c].set(
            s["outstanding"][s_of_c, e_c] + n_alloc_row, mode="drop")
        re_l = re_l.at[sel_s, e_c].set(
            s["reported"][s_of_c, e_c] | (n_emb_row > 0), mode="drop")
        ca_l = ca_l.at[sel_s, e_c].set(
            jnp.where(has_left[:, None], leftover, jnp.uint32(0)),
            mode="drop")

        # ---- re-queue: LEFT entries below, fresh children on top -------
        lrank = _group_rank(s_of_c, has_left, n_slots)
        lpos = jnp.where(has_left, ptop2[s_of_c] + lrank, 0)
        ps_l = s["pstack"].at[
            jnp.where(has_left, s_of_c, n_slots), lpos].set(
                ent_sel, mode="drop")
        n_left_s = _slot_counts(s_of_c, has_left, n_slots)
        ptop3 = ptop2 + n_left_s
        cpos = jnp.where(ok, ptop3[slot_a] + j, 0)
        ps_l = ps_l.at[jnp.where(ok, slot_a, n_slots), cpos].set(
            ent_ch, mode="drop")
        ptop4 = ptop3 + alloc_s

        new_lanes = dict(
            frontier=fr_l, used=us_l, phi=ph_l, depth=de_l, cand=ca_l,
            state=st_l, gamma=gm_l, outstanding=ou_l, reported=re_l,
            parent=pa_l, pstack=ps_l, ptop=ptop4)

        # ---- one resolution sweep per iteration ------------------------
        tb3, new_lanes, n_stored_fin, pat_f = _resolution_sweep(
            qb, tb2, new_lanes, learn_enabled, f)

        return dict(
            tb=tb3, it=s["it"] + 1,
            emb_frontier=emb_frontier, emb_slot=emb_slot,
            n_emb=s["n_emb"] + n_emb_new, id_ctr=s["id_ctr"] + n_alloc,
            pat=s["pat"].add(pat_c).add(pat_f),
            d_expanded=s["d_expanded"] + _slot_counts(
                s_of_c, row_valid, n_slots),
            d_rows=s["d_rows"] + alloc_s,
            d_prunes=s["d_prunes"] + _slot_counts(
                s_of_c, row_valid, n_slots, n_pruned_row),
            d_inj=s["d_inj"] + _slot_counts(
                s_of_c, row_valid, n_slots, n_inj_row),
            d_stored=s["d_stored"] + n_stored_fin + _slot_counts(
                s_of_c, do_store, n_slots),
            **new_lanes)

    s = lax.while_loop(cond, body, carry)

    # ---- final drain: a few more resolution sweeps ---------------------
    # Bounded by a small constant, not run to quiescence: each sweep
    # costs real time even when nearly empty, and unresolved WAIT/RES
    # state legally persists across dispatches — the next dispatch's
    # in-loop sweeps (or its own drain) continue the fold, and trailing
    # resolution-only dispatches are cheap because the expansion loop
    # exits immediately with nothing pending.
    def drain_cond(d):
        can_fold = (d["state"] == STK_RES).any()
        can_fin = ((d["state"] == STK_WAIT)
                   & (d["outstanding"] == 0)).any()
        return (can_fold | can_fin) & (d["it"] < 12)

    def drain_body(d):
        lanes_d = {k: d[k] for k in lane_keys}
        tb_d, lanes_d, n_st, pat_d = _resolution_sweep(
            qb, d["tb"], lanes_d, learn_enabled, f)
        return dict(d, tb=tb_d, it=d["it"] + 1,
                    d_stored=d["d_stored"] + n_st,
                    pat=d["pat"].add(pat_d), **lanes_d)

    s = lax.while_loop(drain_cond, drain_body,
                       dict(s, it=jnp.int32(0)))

    sb_out = StackBank(**{k: s[k] for k in lane_keys})
    live_mask = s["state"] != STK_FREE
    live = live_mask.sum(axis=1).astype(jnp.int32)
    # Lemma-4 conservation lanes for the host-side digest validator:
    # every live non-root entry is counted exactly once in its parent's
    # outstanding counter, so per slot
    #   sum(outstanding over live) == count(live with parent >= 0)
    d_outsum = jnp.where(live_mask, s["outstanding"], 0) \
        .sum(axis=1).astype(jnp.int32)
    d_childlive = (live_mask & (s["parent"] >= 0)) \
        .sum(axis=1).astype(jnp.int32)
    return DeviceResult(
        tb=s["tb"], sb=sb_out,
        d_accepted=d_accepted, d_expanded=s["d_expanded"],
        d_rows=s["d_rows"], d_prunes=s["d_prunes"], d_inj=s["d_inj"],
        d_stored=s["d_stored"], d_pending=s["ptop"], d_live=live,
        d_outsum=d_outsum, d_childlive=d_childlive,
        pat_stored=s["pat"].stored, pat_overwrites=s["pat"].overwrites,
        pat_evictions=s["pat"].evictions, pat_dropped=s["pat"].dropped,
        emb_frontier=s["emb_frontier"], emb_slot=s["emb_slot"],
        n_emb=s["n_emb"],
        n_ids=s["id_ctr"] - jnp.asarray(id_base, jnp.int32))


# (the old single-query S == 1 wrappers — expand_wave &c. — are gone:
# nothing called them anymore, and every sequential-style caller goes
# through the 1-slot WaveEngine facade instead)
