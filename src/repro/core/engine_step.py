"""Device-side programs of the TPU wave engine.

This module contains the *pure JAX* (jit-able, shard_map-able) functions
executed per wave step. The host scheduler in ``vectorized.py`` owns the
segment stack and resolution bookkeeping; every array-heavy operation —
Eq. 2 bitmap refinement, injectivity masking, O(1) dead-end lookups over a
whole wave, child extraction, pattern scatter — happens here on fixed
shapes so a single compiled program serves every query.

Design notes (see DESIGN.md §2):
  * adjacency and candidate sets are packed uint32 bitmaps; Eq. 2 becomes
    a gather + AND-reduction over mapped-neighbor rows (the Pallas kernel
    ``kernels/bitmap_refine.py`` implements the same contraction with
    explicit VMEM tiling; this file keeps the jnp reference path which
    XLA fuses well on CPU and is what the dry-run lowers by default).
  * dead-end masks are bitmasks over query order positions, two uint32
    words (supports |V_Q| <= 64).
  * the numeric pattern check Φ[μ] == φ (paper Eq. 7) is a double gather
    and a compare, evaluated for every (row, candidate-vertex) pair of the
    wave in one shot.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

MASK_WORDS = 2          # dead-end masks cover up to 64 query positions
N_PAD = 64              # padded query size
FULL = jnp.uint32(0xFFFFFFFF)


class GraphArrays(NamedTuple):
    """Device view of the data graph."""
    adj_bitmap: jax.Array    # uint32 [V, W] packed adjacency
    n_vertices: jax.Array    # int32 scalar


class QueryArrays(NamedTuple):
    """Device view of one query (already permuted to matching order)."""
    cand_bitmap: jax.Array   # uint32 [N_PAD, W] candidates per position
    nbr_mask: jax.Array      # bool [N_PAD, N_PAD] query adjacency (by pos)
    n_query: jax.Array       # int32 scalar


class TableArrays(NamedTuple):
    """The dead-end pattern table Δ, keyed by (order position, vertex)."""
    phi: jax.Array           # int32 [N_PAD, V]  stored prefix id φ
    mu: jax.Array            # int32 [N_PAD, V]  prefix length μ
    mask: jax.Array          # uint32 [N_PAD, V, MASK_WORDS] mask Γ
    valid: jax.Array         # bool [N_PAD, V]

    @staticmethod
    def empty(n_vertices: int) -> "TableArrays":
        v = n_vertices
        return TableArrays(
            phi=jnp.zeros((N_PAD, v), jnp.int32),
            mu=jnp.zeros((N_PAD, v), jnp.int32),
            mask=jnp.zeros((N_PAD, v, MASK_WORDS), jnp.uint32),
            valid=jnp.zeros((N_PAD, v), bool),
        )


class WaveResult(NamedTuple):
    refined_empty: jax.Array     # bool [F]   Eq.2 candidate set empty
    n_children: jax.Array        # int32 [F]  surviving children this pass
    n_leftover: jax.Array        # int32 [F]  children beyond the per-row cap
    partial_mask: jax.Array      # uint32 [F, MASK_WORDS] inj+prune Γ* terms
    child_v: jax.Array           # int32 [F, KPR] child vertices (-1 pad)
    child_valid: jax.Array       # bool [F, KPR]
    leftover: jax.Array          # uint32 [F, W] unexpanded survivor bits
    n_pruned: jax.Array          # int32 [] dead-end prunes in this wave
    n_inj: jax.Array             # int32 [] injectivity kills in this wave


def _popcount_rows(words: jax.Array) -> jax.Array:
    """Sum of set bits per row of a uint32 [..., W] array -> int32 [...]."""
    return lax.population_count(words).astype(jnp.int32).sum(axis=-1)


def _unpack_bits(words: jax.Array, v: int) -> jax.Array:
    """uint32 [F, W] -> bool [F, v]."""
    f, w = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(f, w * 32)[:, :v].astype(bool)


def _pack_bits(bits: jax.Array, w: int) -> jax.Array:
    """bool [F, v] -> uint32 [F, W] (zero-padded)."""
    f, v = bits.shape
    padded = jnp.zeros((f, w * 32), bool).at[:, :v].set(bits)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (padded.reshape(f, w, 32).astype(jnp.uint32) * weights
            ).sum(axis=-1, dtype=jnp.uint32)


def _position_bit(p: jax.Array) -> jax.Array:
    """Order position -> uint32 [MASK_WORDS] one-hot-bit mask."""
    word = p // 32
    bit = jnp.uint32(1) << (p % 32).astype(jnp.uint32)
    return jnp.where(jnp.arange(MASK_WORDS) == word, bit, jnp.uint32(0))


def _below_bits(d: jax.Array) -> jax.Array:
    """Bitmask of all positions strictly below d, uint32 [MASK_WORDS]."""
    idx = jnp.arange(MASK_WORDS * 32)
    bits = idx < d
    return (bits.reshape(MASK_WORDS, 32).astype(jnp.uint32)
            * (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
            ).sum(axis=-1, dtype=jnp.uint32)


def refine_eq2(g: GraphArrays, q: QueryArrays, frontier: jax.Array,
               depth: jax.Array) -> jax.Array:
    """Eq. 2 candidate refinement for a whole wave.

    C'(row) = cand[depth] ∩ ⋂_{p < depth, p ~q depth} N(frontier[row, p]).
    Returns the packed candidate bitmap uint32 [F, W].
    """
    f = frontier.shape[0]
    w = g.adj_bitmap.shape[1]
    acc0 = jnp.broadcast_to(q.cand_bitmap[depth], (f, w))

    def body(p, acc):
        active = q.nbr_mask[depth, p] & (p < depth)
        rows = g.adj_bitmap[frontier[:, p].clip(0)]          # [F, W]
        return jnp.where(active, acc & rows, acc)

    return lax.fori_loop(0, N_PAD, body, acc0)


def deadend_lookup_children(t: TableArrays, phi: jax.Array,
                            depth: jax.Array, child_v: jax.Array
                            ) -> tuple[jax.Array, jax.Array]:
    """Paper-Eq.7 check for extracted children only (§Perf iteration 2:
    O(F·kpr) gathers instead of the O(F·V) dense sweep).

    child_v: int32 [F, KPR] candidate vertices (-1 = empty slot).
    Returns (prune bool [F, KPR], Γ* contribution uint32 [F, MASK_WORDS]).
    """
    f, kpr = child_v.shape
    cv = child_v.clip(0)
    mu_g = t.mu[depth][cv]                   # [F, KPR]
    phi_g = t.phi[depth][cv]
    valid_g = t.valid[depth][cv] & (child_v >= 0)
    my_phi = jnp.take_along_axis(phi, mu_g, axis=1)
    prune = valid_g & (my_phi == phi_g)
    masks = t.mask[depth][cv]                # [F, KPR, MASK_WORDS]
    masks = jnp.where(prune[:, :, None],
                      masks | _position_bit(depth)[None, None, :],
                      jnp.uint32(0))
    # OR over the (small) child axis via unpack -> any -> repack
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((masks[:, :, :, None] >> shifts) & jnp.uint32(1)) > 0
    got = bits.any(axis=1)                   # [F, MASK_WORDS, 32]
    weights = jnp.uint32(1) << shifts
    contrib = (got.astype(jnp.uint32) * weights).sum(
        axis=-1, dtype=jnp.uint32)           # [F, MASK_WORDS]
    return prune, contrib


@functools.partial(jax.jit, static_argnames=("kpr",))
def expand_wave(g: GraphArrays, q: QueryArrays, t: TableArrays,
                frontier: jax.Array, used: jax.Array, phi: jax.Array,
                row_valid: jax.Array, depth: jax.Array,
                kpr: int = 16) -> WaveResult:
    """Expand every row of a wave by one query position.

    Args:
      frontier:  int32 [F, N_PAD] mapped data vertex per order position
                 (-1 where unmapped); all rows share the same depth.
      used:      uint32 [F, W] bitmap of data vertices used by the row.
      phi:       int32 [F, N_PAD + 1] ancestor embedding ids (Φ array).
      row_valid: bool [F] padding mask.
      depth:     int32 scalar — number of mapped positions in each row.
      kpr:       static per-row child cap for this pass (leftovers are
                 re-expanded by the host in later passes).
    """
    f = frontier.shape[0]
    v = g.adj_bitmap.shape[0]
    w = g.adj_bitmap.shape[1]

    refined = refine_eq2(g, q, frontier, depth)              # [F, W]
    refined = jnp.where(row_valid[:, None], refined, jnp.uint32(0))
    refined_empty = (_popcount_rows(refined) == 0) & row_valid

    # ---- injectivity: candidates already used by the row ---------------
    inj_words = refined & used                               # [F, W]
    n_inj_per_row = _popcount_rows(inj_words)

    # injectivity Γ* contribution (Lemma 2): for every mapped position p
    # whose vertex is a refined candidate, add bit(p) | bit(depth).
    def inj_body(p, acc):
        vert = frontier[:, p].clip(0)                        # [F]
        word = jnp.take_along_axis(refined, (vert // 32)[:, None],
                                   axis=1)[:, 0]
        hit = ((word >> (vert % 32).astype(jnp.uint32)) & 1).astype(bool)
        hit &= (p < depth) & row_valid
        contrib = _position_bit(p)[None, :] | _position_bit(depth)[None, :]
        return jnp.where(hit[:, None], acc | contrib, acc)

    inj_mask = lax.fori_loop(
        0, N_PAD, inj_body,
        jnp.zeros((f, MASK_WORDS), jnp.uint32))

    # ---- extract candidate children (per-row cap) -----------------------
    live = refined & ~used                                   # [F, W]
    live_bits = _unpack_bits(live, v)                        # [F, V]
    rank = jnp.cumsum(live_bits, axis=1)                     # [F, V]
    take_bits = live_bits & (rank <= kpr)
    left_bits = live_bits & (rank > kpr)
    n_leftover = left_bits.sum(axis=1).astype(jnp.int32)

    def row_nonzero(row):
        return jnp.nonzero(row, size=kpr, fill_value=-1)[0]

    child_v = jax.vmap(row_nonzero)(take_bits).astype(jnp.int32)
    leftover = _pack_bits(left_bits, w)

    # ---- dead-end pruning on extracted children (Lemma 3 / Eq. 7) -------
    # Perf iteration 2 (see EXPERIMENTS.md): checking only extracted
    # children turns the O(F*V) dense sweep into O(F*kpr) gathers;
    # prunable candidates still in `leftover` are checked when a later
    # pass extracts them.
    prune, prune_mask = deadend_lookup_children(t, phi, depth, child_v)
    child_valid = (child_v >= 0) & ~prune
    n_children = child_valid.sum(axis=1).astype(jnp.int32)
    partial_mask = inj_mask | prune_mask

    return WaveResult(
        refined_empty=refined_empty,
        n_children=n_children,
        n_leftover=n_leftover,
        partial_mask=partial_mask,
        child_v=jnp.where(child_valid, child_v, -1),
        child_valid=child_valid,
        leftover=leftover,
        n_pruned=jnp.where(row_valid, prune.sum(axis=1), 0).sum(),
        n_inj=jnp.where(row_valid, n_inj_per_row, 0).sum(),
    )


@functools.partial(jax.jit, static_argnames=("kpr",))
def extract_more(t: TableArrays, phi: jax.Array, depth: jax.Array,
                 leftover: jax.Array, kpr: int = 64
                 ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                            jax.Array, jax.Array]:
    """Extract up to ``kpr`` more children per row from leftover bitmaps.

    Leftover bits already survived refinement and injectivity in their
    fresh pass; the dead-end check runs here at extraction time (and may
    see *newer* patterns than the fresh pass did — strictly more pruning).
    Returns (child_v, child_valid, new_leftover, n_leftover,
             partial_mask, n_pruned).
    """
    f, w = leftover.shape
    v_pad = w * 32
    bits = _unpack_bits(leftover, v_pad)
    rank = jnp.cumsum(bits, axis=1)
    take_bits = bits & (rank <= kpr)
    left_bits = bits & (rank > kpr)

    def row_nonzero(row):
        return jnp.nonzero(row, size=kpr, fill_value=-1)[0]

    child_v = jax.vmap(row_nonzero)(take_bits).astype(jnp.int32)
    prune, prune_mask = deadend_lookup_children(t, phi, depth, child_v)
    child_valid = (child_v >= 0) & ~prune
    return (jnp.where(child_valid, child_v, -1), child_valid,
            _pack_bits(left_bits, w),
            left_bits.sum(axis=1).astype(jnp.int32),
            prune_mask, prune.sum())


@jax.jit
def assemble_children(frontier: jax.Array, used: jax.Array, phi: jax.Array,
                      child_v: jax.Array, child_valid: jax.Array,
                      depth: jax.Array, id_base: jax.Array
                      ) -> tuple[jax.Array, jax.Array, jax.Array,
                                 jax.Array, jax.Array]:
    """Materialize child rows [F*KPR, ...] from an expand_wave result.

    Returns (child_frontier, child_used, child_phi, parent_row, valid) —
    padded flat arrays; the host compacts them into new segments.
    """
    f, kpr = child_v.shape
    flat_v = child_v.reshape(-1)                              # [F*KPR]
    valid = child_valid.reshape(-1)
    parent = jnp.repeat(jnp.arange(f, dtype=jnp.int32), kpr)
    cf = frontier[parent]                                     # [F*KPR, NP]
    cf = jnp.where(
        (jnp.arange(cf.shape[1])[None, :] == depth) & valid[:, None],
        flat_v[:, None], cf)
    vv = flat_v.clip(0)
    word = (vv // 32).astype(jnp.int32)
    bit = jnp.uint32(1) << (vv % 32).astype(jnp.uint32)
    cu = used[parent]
    add = jnp.zeros_like(cu).at[jnp.arange(cu.shape[0]), word].set(
        jnp.where(valid, bit, jnp.uint32(0)))
    cu = cu | add
    new_ids = id_base + jnp.cumsum(valid.astype(jnp.int32)) - 1
    cp = phi[parent]
    cp = jnp.where(
        (jnp.arange(cp.shape[1])[None, :] == depth + 1) & valid[:, None],
        new_ids[:, None], cp)
    return cf, cu, cp, parent, valid


@jax.jit
def store_patterns(t: TableArrays, key_pos: jax.Array, key_v: jax.Array,
                   phis: jax.Array, mus: jax.Array, masks: jax.Array,
                   valid: jax.Array) -> TableArrays:
    """Batched Δ[u_k, v] <- (φ, μ, Γ) scatter (paper Eq. 6).

    Invalid (padding) entries are routed out of bounds and dropped by the
    scatter, so they can never clobber a real pattern.
    """
    v_dim = t.phi.shape[1]
    kp = jnp.where(valid, key_pos, 0)
    kv = jnp.where(valid, key_v, v_dim)      # OOB -> dropped
    phi_new = t.phi.at[kp, kv].set(phis, mode="drop")
    mu_new = t.mu.at[kp, kv].set(mus, mode="drop")
    mask_new = t.mask.at[kp, kv].set(masks, mode="drop")
    valid_new = t.valid.at[kp, kv].set(True, mode="drop")
    return TableArrays(phi=phi_new, mu=mu_new, mask=mask_new,
                       valid=valid_new)
