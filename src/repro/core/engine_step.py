"""Device-side programs of the TPU wave engine.

This module contains the *pure JAX* (jit-able, shard_map-able) functions
executed per wave step. The host scheduler in ``vectorized.py`` owns the
segment stacks and resolution bookkeeping; every array-heavy operation —
Eq. 2 bitmap refinement, injectivity masking, O(1) dead-end lookups over a
whole wave, child extraction, pattern scatter — happens here on fixed
shapes so a single compiled program serves every query.

Multi-query waves (DESIGN.md §2): per-query state lives in *banks* stacked
along a leading slot axis — :class:`QueryBank` ``[S, ...]`` and
:class:`TableBank` ``[S, ...]`` — and every wave row carries a
``query_slot`` and a ``depth`` lane, so one jitted program expands a wave
whose rows belong to many concurrent queries at different depths. The
single-query entry points (``expand_wave`` &c., used by the launch dry-run
and the distributed pattern merge) are thin wrappers over the same
implementation with ``S == 1``.

Design notes (see DESIGN.md §2):
  * adjacency and candidate sets are packed uint32 bitmaps; Eq. 2 becomes
    a gather + AND-reduction over mapped-neighbor rows (the Pallas kernel
    ``kernels/bitmap_refine.py`` implements the same contraction with
    explicit VMEM tiling; this file keeps the jnp reference path which
    XLA fuses well on CPU and is what the dry-run lowers by default).
  * dead-end masks are bitmasks over query order positions, two uint32
    words (supports |V_Q| <= 64).
  * the numeric pattern check Φ[μ] == φ (paper Eq. 7) is a double gather
    and a compare, evaluated for every (row, candidate-vertex) pair of the
    wave in one shot.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

MASK_WORDS = 2          # dead-end masks cover up to 64 query positions
N_PAD = 64              # padded query size
FULL = jnp.uint32(0xFFFFFFFF)


class GraphArrays(NamedTuple):
    """Device view of the data graph."""
    adj_bitmap: jax.Array    # uint32 [V, W] packed adjacency
    n_vertices: jax.Array    # int32 scalar


class QueryArrays(NamedTuple):
    """Device view of one query (already permuted to matching order)."""
    cand_bitmap: jax.Array   # uint32 [N_PAD, W] candidates per position
    nbr_mask: jax.Array      # bool [N_PAD, N_PAD] query adjacency (by pos)
    n_query: jax.Array       # int32 scalar


class QueryBank(NamedTuple):
    """Per-slot query arrays for multi-query waves (query axis first)."""
    cand_bitmap: jax.Array   # uint32 [S, N_PAD, W]
    nbr_mask: jax.Array      # bool [S, N_PAD, N_PAD]
    n_query: jax.Array       # int32 [S]

    @staticmethod
    def empty(n_slots: int, w: int) -> "QueryBank":
        return QueryBank(
            cand_bitmap=jnp.zeros((n_slots, N_PAD, w), jnp.uint32),
            nbr_mask=jnp.zeros((n_slots, N_PAD, N_PAD), bool),
            n_query=jnp.zeros((n_slots,), jnp.int32))


class TableArrays(NamedTuple):
    """The dead-end pattern table Δ, keyed by (order position, vertex)."""
    phi: jax.Array           # int32 [N_PAD, V]  stored prefix id φ
    mu: jax.Array            # int32 [N_PAD, V]  prefix length μ
    mask: jax.Array          # uint32 [N_PAD, V, MASK_WORDS] mask Γ
    valid: jax.Array         # bool [N_PAD, V]

    @staticmethod
    def empty(n_vertices: int) -> "TableArrays":
        v = n_vertices
        return TableArrays(
            phi=jnp.zeros((N_PAD, v), jnp.int32),
            mu=jnp.zeros((N_PAD, v), jnp.int32),
            mask=jnp.zeros((N_PAD, v, MASK_WORDS), jnp.uint32),
            valid=jnp.zeros((N_PAD, v), bool),
        )


class TableBank(NamedTuple):
    """Per-slot dead-end tables, Δ[slot, order position, vertex]."""
    phi: jax.Array           # int32 [S, N_PAD, V]
    mu: jax.Array            # int32 [S, N_PAD, V]
    mask: jax.Array          # uint32 [S, N_PAD, V, MASK_WORDS]
    valid: jax.Array         # bool [S, N_PAD, V]

    @staticmethod
    def empty(n_slots: int, n_vertices: int) -> "TableBank":
        s, v = n_slots, n_vertices
        return TableBank(
            phi=jnp.zeros((s, N_PAD, v), jnp.int32),
            mu=jnp.zeros((s, N_PAD, v), jnp.int32),
            mask=jnp.zeros((s, N_PAD, v, MASK_WORDS), jnp.uint32),
            valid=jnp.zeros((s, N_PAD, v), bool),
        )


class WaveResult(NamedTuple):
    refined_empty: jax.Array     # bool [F]   Eq.2 candidate set empty
    n_children: jax.Array        # int32 [F]  surviving children this pass
    n_leftover: jax.Array        # int32 [F]  children beyond the per-row cap
    partial_mask: jax.Array      # uint32 [F, MASK_WORDS] inj+prune Γ* terms
    child_v: jax.Array           # int32 [F, KPR] child vertices (-1 pad)
    child_valid: jax.Array       # bool [F, KPR]
    leftover: jax.Array          # uint32 [F, W] unexpanded survivor bits
    n_pruned: jax.Array          # int32 [] dead-end prunes in this wave
    n_inj: jax.Array             # int32 [] injectivity kills in this wave


class WaveResultMQ(NamedTuple):
    """Multi-query wave result — per-row counters so the host can
    attribute prune/injectivity statistics to the owning query."""
    refined_empty: jax.Array     # bool [F]
    n_children: jax.Array        # int32 [F]
    n_leftover: jax.Array        # int32 [F]
    partial_mask: jax.Array      # uint32 [F, MASK_WORDS]
    child_v: jax.Array           # int32 [F, KPR]
    child_valid: jax.Array       # bool [F, KPR]
    leftover: jax.Array          # uint32 [F, W]
    n_pruned: jax.Array          # int32 [F] dead-end prunes per row
    n_inj: jax.Array             # int32 [F] injectivity kills per row


def _popcount_rows(words: jax.Array) -> jax.Array:
    """Sum of set bits per row of a uint32 [..., W] array -> int32 [...]."""
    return lax.population_count(words).astype(jnp.int32).sum(axis=-1)


def _unpack_bits(words: jax.Array, v: int) -> jax.Array:
    """uint32 [F, W] -> bool [F, v]."""
    f, w = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(f, w * 32)[:, :v].astype(bool)


def _pack_bits(bits: jax.Array, w: int) -> jax.Array:
    """bool [F, v] -> uint32 [F, W] (zero-padded)."""
    f, v = bits.shape
    padded = jnp.zeros((f, w * 32), bool).at[:, :v].set(bits)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (padded.reshape(f, w, 32).astype(jnp.uint32) * weights
            ).sum(axis=-1, dtype=jnp.uint32)


def _position_bit(p: jax.Array) -> jax.Array:
    """Order position (scalar) -> uint32 [MASK_WORDS] one-hot-bit mask."""
    word = p // 32
    bit = jnp.uint32(1) << (p % 32).astype(jnp.uint32)
    return jnp.where(jnp.arange(MASK_WORDS) == word, bit, jnp.uint32(0))


def _position_bits(p: jax.Array) -> jax.Array:
    """Order positions int32 [F] -> uint32 [F, MASK_WORDS] one-hot bits."""
    word = p // 32
    bit = jnp.uint32(1) << (p % 32).astype(jnp.uint32)
    return jnp.where(jnp.arange(MASK_WORDS)[None, :] == word[:, None],
                     bit[:, None], jnp.uint32(0))


def _below_bits(d: jax.Array) -> jax.Array:
    """Bitmask of all positions strictly below d, uint32 [MASK_WORDS]."""
    idx = jnp.arange(MASK_WORDS * 32)
    bits = idx < d
    return (bits.reshape(MASK_WORDS, 32).astype(jnp.uint32)
            * (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
            ).sum(axis=-1, dtype=jnp.uint32)


# ===================================================================
# slot management: load one query (+ its table) into a bank slot
# ===================================================================
@jax.jit
def load_slot(qb: QueryBank, tb: TableBank, slot: jax.Array,
              cand_bitmap: jax.Array, nbr_mask: jax.Array,
              n_query: jax.Array, table: TableArrays
              ) -> tuple[QueryBank, TableBank]:
    """Install a query in bank slot ``slot`` (admission). ``table`` is the
    slot's initial dead-end table: empty, or seeded with transferable
    patterns (see core.distributed)."""
    qb2 = QueryBank(
        cand_bitmap=qb.cand_bitmap.at[slot].set(cand_bitmap),
        nbr_mask=qb.nbr_mask.at[slot].set(nbr_mask),
        n_query=qb.n_query.at[slot].set(n_query))
    tb2 = TableBank(
        phi=tb.phi.at[slot].set(table.phi),
        mu=tb.mu.at[slot].set(table.mu),
        mask=tb.mask.at[slot].set(table.mask),
        valid=tb.valid.at[slot].set(table.valid))
    return qb2, tb2


def read_table_slot(tb: TableBank, slot: int) -> TableArrays:
    """Read one slot's table back out (pattern export on completion)."""
    return TableArrays(phi=tb.phi[slot], mu=tb.mu[slot],
                       mask=tb.mask[slot], valid=tb.valid[slot])


# ===================================================================
# multi-query wave programs
# ===================================================================
def refine_eq2_mq(g: GraphArrays, qb: QueryBank, query_slot: jax.Array,
                  frontier: jax.Array, depth: jax.Array) -> jax.Array:
    """Eq. 2 candidate refinement for a mixed-query wave.

    C'(row) = cand[qid, depth] ∩ ⋂_{p < depth, p ~q depth} N(frontier[p]).
    ``query_slot`` and ``depth`` are int32 [F] lanes. Returns the packed
    candidate bitmap uint32 [F, W].
    """
    f = frontier.shape[0]
    acc0 = qb.cand_bitmap[query_slot, depth]                 # [F, W]

    def body(p, acc):
        active = qb.nbr_mask[query_slot, depth, p] & (p < depth)  # [F]
        rows = g.adj_bitmap[frontier[:, p].clip(0)]               # [F, W]
        return jnp.where(active[:, None], acc & rows, acc)

    return lax.fori_loop(0, N_PAD, body, acc0)


def deadend_lookup_children_mq(tb: TableBank, phi: jax.Array,
                               query_slot: jax.Array, depth: jax.Array,
                               child_v: jax.Array
                               ) -> tuple[jax.Array, jax.Array]:
    """Paper-Eq.7 check for extracted children only (§Perf iteration 2:
    O(F·kpr) gathers instead of the O(F·V) dense sweep), table rows keyed
    per query slot.

    child_v: int32 [F, KPR] candidate vertices (-1 = empty slot).
    Returns (prune bool [F, KPR], Γ* contribution uint32 [F, MASK_WORDS]).
    """
    cv = child_v.clip(0)
    q2 = query_slot[:, None]
    d2 = depth[:, None]
    mu_g = tb.mu[q2, d2, cv]                 # [F, KPR]
    phi_g = tb.phi[q2, d2, cv]
    valid_g = tb.valid[q2, d2, cv] & (child_v >= 0)
    my_phi = jnp.take_along_axis(phi, mu_g, axis=1)
    prune = valid_g & (my_phi == phi_g)
    masks = tb.mask[q2, d2, cv]              # [F, KPR, MASK_WORDS]
    masks = jnp.where(prune[:, :, None],
                      masks | _position_bits(depth)[:, None, :],
                      jnp.uint32(0))
    # OR over the (small) child axis via unpack -> any -> repack
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((masks[:, :, :, None] >> shifts) & jnp.uint32(1)) > 0
    got = bits.any(axis=1)                   # [F, MASK_WORDS, 32]
    weights = jnp.uint32(1) << shifts
    contrib = (got.astype(jnp.uint32) * weights).sum(
        axis=-1, dtype=jnp.uint32)           # [F, MASK_WORDS]
    return prune, contrib


@functools.partial(jax.jit, static_argnames=("kpr",))
def expand_wave_mq(g: GraphArrays, qb: QueryBank, tb: TableBank,
                   frontier: jax.Array, used: jax.Array, phi: jax.Array,
                   row_valid: jax.Array, query_slot: jax.Array,
                   depth: jax.Array, kpr: int = 16) -> WaveResultMQ:
    """Expand every row of a mixed-query wave by one query position.

    Args:
      frontier:   int32 [F, N_PAD] mapped data vertex per order position
                  (-1 where unmapped).
      used:       uint32 [F, W] bitmap of data vertices used by the row.
      phi:        int32 [F, N_PAD + 1] ancestor embedding ids (Φ array).
      row_valid:  bool [F] padding mask.
      query_slot: int32 [F] — owning query's bank slot, per row.
      depth:      int32 [F] — number of mapped positions, per row.
      kpr:        static per-row child cap for this pass (leftovers are
                  re-expanded by the host in later passes).
    """
    f = frontier.shape[0]
    v = g.adj_bitmap.shape[0]
    w = g.adj_bitmap.shape[1]

    refined = refine_eq2_mq(g, qb, query_slot, frontier, depth)  # [F, W]
    refined = jnp.where(row_valid[:, None], refined, jnp.uint32(0))
    refined_empty = (_popcount_rows(refined) == 0) & row_valid

    # ---- injectivity: candidates already used by the row ---------------
    inj_words = refined & used                               # [F, W]
    n_inj_per_row = _popcount_rows(inj_words)

    # injectivity Γ* contribution (Lemma 2): for every mapped position p
    # whose vertex is a refined candidate, add bit(p) | bit(depth).
    depth_bits = _position_bits(depth)                       # [F, MW]

    def inj_body(p, acc):
        vert = frontier[:, p].clip(0)                        # [F]
        word = jnp.take_along_axis(refined, (vert // 32)[:, None],
                                   axis=1)[:, 0]
        hit = ((word >> (vert % 32).astype(jnp.uint32)) & 1).astype(bool)
        hit &= (p < depth) & row_valid
        contrib = _position_bit(p)[None, :] | depth_bits
        return jnp.where(hit[:, None], acc | contrib, acc)

    inj_mask = lax.fori_loop(
        0, N_PAD, inj_body,
        jnp.zeros((f, MASK_WORDS), jnp.uint32))

    # ---- extract candidate children (per-row cap) -----------------------
    live = refined & ~used                                   # [F, W]
    live_bits = _unpack_bits(live, v)                        # [F, V]
    rank = jnp.cumsum(live_bits, axis=1)                     # [F, V]
    take_bits = live_bits & (rank <= kpr)
    left_bits = live_bits & (rank > kpr)
    n_leftover = left_bits.sum(axis=1).astype(jnp.int32)

    def row_nonzero(row):
        return jnp.nonzero(row, size=kpr, fill_value=-1)[0]

    child_v = jax.vmap(row_nonzero)(take_bits).astype(jnp.int32)
    leftover = _pack_bits(left_bits, w)

    # ---- dead-end pruning on extracted children (Lemma 3 / Eq. 7) -------
    # Perf iteration 2 (see EXPERIMENTS.md): checking only extracted
    # children turns the O(F*V) dense sweep into O(F*kpr) gathers;
    # prunable candidates still in `leftover` are checked when a later
    # pass extracts them.
    prune, prune_mask = deadend_lookup_children_mq(
        tb, phi, query_slot, depth, child_v)
    child_valid = (child_v >= 0) & ~prune
    n_children = child_valid.sum(axis=1).astype(jnp.int32)
    partial_mask = inj_mask | prune_mask

    return WaveResultMQ(
        refined_empty=refined_empty,
        n_children=n_children,
        n_leftover=n_leftover,
        partial_mask=partial_mask,
        child_v=jnp.where(child_valid, child_v, -1),
        child_valid=child_valid,
        leftover=leftover,
        n_pruned=jnp.where(row_valid, prune.sum(axis=1), 0),
        n_inj=jnp.where(row_valid, n_inj_per_row, 0),
    )


@functools.partial(jax.jit, static_argnames=("kpr",))
def extract_more_mq(tb: TableBank, phi: jax.Array, query_slot: jax.Array,
                    depth: jax.Array, leftover: jax.Array, kpr: int = 64
                    ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                               jax.Array, jax.Array]:
    """Extract up to ``kpr`` more children per row from leftover bitmaps
    of a mixed-query wave.

    Leftover bits already survived refinement and injectivity in their
    fresh pass; the dead-end check runs here at extraction time (and may
    see *newer* patterns than the fresh pass did — strictly more pruning).
    Returns (child_v, child_valid, new_leftover, n_leftover,
             partial_mask, n_pruned[F]).
    """
    f, w = leftover.shape
    v_pad = w * 32
    bits = _unpack_bits(leftover, v_pad)
    rank = jnp.cumsum(bits, axis=1)
    take_bits = bits & (rank <= kpr)
    left_bits = bits & (rank > kpr)

    def row_nonzero(row):
        return jnp.nonzero(row, size=kpr, fill_value=-1)[0]

    child_v = jax.vmap(row_nonzero)(take_bits).astype(jnp.int32)
    prune, prune_mask = deadend_lookup_children_mq(
        tb, phi, query_slot, depth, child_v)
    child_valid = (child_v >= 0) & ~prune
    return (jnp.where(child_valid, child_v, -1), child_valid,
            _pack_bits(left_bits, w),
            left_bits.sum(axis=1).astype(jnp.int32),
            prune_mask, prune.sum(axis=1))


@jax.jit
def assemble_children_mq(frontier: jax.Array, used: jax.Array,
                         phi: jax.Array, child_v: jax.Array,
                         child_valid: jax.Array, depth: jax.Array,
                         id_base: jax.Array
                         ) -> tuple[jax.Array, jax.Array, jax.Array,
                                    jax.Array, jax.Array]:
    """Materialize child rows [F*KPR, ...] from a mixed-query wave result.

    ``depth`` is the per-row int32 [F] lane. Returns (child_frontier,
    child_used, child_phi, parent_row, valid) — padded flat arrays; the
    host compacts them into new per-query segments. Fresh embedding ids
    are drawn from one shared counter (``id_base``): ids only need to be
    unique within a query, so global uniqueness is sufficient.
    """
    f, kpr = child_v.shape
    flat_v = child_v.reshape(-1)                              # [F*KPR]
    valid = child_valid.reshape(-1)
    parent = jnp.repeat(jnp.arange(f, dtype=jnp.int32), kpr)
    d_par = depth[parent]                                     # [F*KPR]
    cf = frontier[parent]                                     # [F*KPR, NP]
    cf = jnp.where(
        (jnp.arange(cf.shape[1])[None, :] == d_par[:, None]) & valid[:, None],
        flat_v[:, None], cf)
    vv = flat_v.clip(0)
    word = (vv // 32).astype(jnp.int32)
    bit = jnp.uint32(1) << (vv % 32).astype(jnp.uint32)
    cu = used[parent]
    add = jnp.zeros_like(cu).at[jnp.arange(cu.shape[0]), word].set(
        jnp.where(valid, bit, jnp.uint32(0)))
    cu = cu | add
    new_ids = id_base + jnp.cumsum(valid.astype(jnp.int32)) - 1
    cp = phi[parent]
    cp = jnp.where(
        (jnp.arange(cp.shape[1])[None, :] == d_par[:, None] + 1)
        & valid[:, None],
        new_ids[:, None], cp)
    return cf, cu, cp, parent, valid


@jax.jit
def store_patterns_mq(tb: TableBank, query_slot: jax.Array,
                      key_pos: jax.Array, key_v: jax.Array,
                      phis: jax.Array, mus: jax.Array, masks: jax.Array,
                      valid: jax.Array) -> TableBank:
    """Batched Δ[slot, u_k, v] <- (φ, μ, Γ) scatter (paper Eq. 6) across
    all slots at once.

    Invalid (padding) entries are routed out of bounds and dropped by the
    scatter, so they can never clobber a real pattern.
    """
    v_dim = tb.phi.shape[2]
    qs = jnp.where(valid, query_slot, 0)
    kp = jnp.where(valid, key_pos, 0)
    kv = jnp.where(valid, key_v, v_dim)      # OOB -> dropped
    phi_new = tb.phi.at[qs, kp, kv].set(phis, mode="drop")
    mu_new = tb.mu.at[qs, kp, kv].set(mus, mode="drop")
    mask_new = tb.mask.at[qs, kp, kv].set(masks, mode="drop")
    valid_new = tb.valid.at[qs, kp, kv].set(True, mode="drop")
    return TableBank(phi=phi_new, mu=mu_new, mask=mask_new,
                     valid=valid_new)


# ===================================================================
# single-query wrappers (S == 1) — kept for the launch dry-run cells
# and the distributed pattern merge, which operate on one query
# ===================================================================
def _tbank_of(t: TableArrays) -> TableBank:
    return TableBank(phi=t.phi[None], mu=t.mu[None],
                     mask=t.mask[None], valid=t.valid[None])


def _bank_of(q: QueryArrays, t: TableArrays) -> tuple[QueryBank, TableBank]:
    qb = QueryBank(cand_bitmap=q.cand_bitmap[None],
                   nbr_mask=q.nbr_mask[None],
                   n_query=jnp.asarray(q.n_query)[None])
    return qb, _tbank_of(t)


@functools.partial(jax.jit, static_argnames=("kpr",))
def expand_wave(g: GraphArrays, q: QueryArrays, t: TableArrays,
                frontier: jax.Array, used: jax.Array, phi: jax.Array,
                row_valid: jax.Array, depth: jax.Array,
                kpr: int = 16) -> WaveResult:
    """Single-query :func:`expand_wave_mq` with a shared scalar depth."""
    f = frontier.shape[0]
    qb, tb = _bank_of(q, t)
    res = expand_wave_mq(
        g, qb, tb, frontier, used, phi, row_valid,
        jnp.zeros((f,), jnp.int32),
        jnp.full((f,), depth, jnp.int32), kpr=kpr)
    return WaveResult(
        refined_empty=res.refined_empty, n_children=res.n_children,
        n_leftover=res.n_leftover, partial_mask=res.partial_mask,
        child_v=res.child_v, child_valid=res.child_valid,
        leftover=res.leftover,
        n_pruned=res.n_pruned.sum(), n_inj=res.n_inj.sum())


@functools.partial(jax.jit, static_argnames=("kpr",))
def extract_more(t: TableArrays, phi: jax.Array, depth: jax.Array,
                 leftover: jax.Array, kpr: int = 64
                 ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                            jax.Array, jax.Array]:
    """Single-query :func:`extract_more_mq`; returns a scalar prune count."""
    f = leftover.shape[0]
    out = extract_more_mq(_tbank_of(t), phi, jnp.zeros((f,), jnp.int32),
                          jnp.full((f,), depth, jnp.int32), leftover,
                          kpr=kpr)
    return out[:5] + (out[5].sum(),)


@jax.jit
def assemble_children(frontier: jax.Array, used: jax.Array, phi: jax.Array,
                      child_v: jax.Array, child_valid: jax.Array,
                      depth: jax.Array, id_base: jax.Array
                      ) -> tuple[jax.Array, jax.Array, jax.Array,
                                 jax.Array, jax.Array]:
    """Single-query :func:`assemble_children_mq` with a scalar depth."""
    f = child_v.shape[0]
    return assemble_children_mq(frontier, used, phi, child_v, child_valid,
                                jnp.full((f,), depth, jnp.int32), id_base)


@jax.jit
def store_patterns(t: TableArrays, key_pos: jax.Array, key_v: jax.Array,
                   phis: jax.Array, mus: jax.Array, masks: jax.Array,
                   valid: jax.Array) -> TableArrays:
    """Single-query :func:`store_patterns_mq` (paper Eq. 6)."""
    tb2 = store_patterns_mq(_tbank_of(t), jnp.zeros_like(key_pos),
                            key_pos, key_v, phis, mus, masks, valid)
    return TableArrays(phi=tb2.phi[0], mu=tb2.mu[0],
                       mask=tb2.mask[0], valid=tb2.valid[0])
