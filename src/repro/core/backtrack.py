"""Faithful sequential subgraph matching (paper Algorithms 1 and 2).

Semantics: non-induced subgraph isomorphism (monomorphism) — Definition 1:
label constraint, edge constraint (query edges must map to data edges),
injection constraint.

Two entry points:

* :func:`backtrack_naive`   — Algorithm 1 (plain backtracking).
* :func:`backtrack_deadend` — Algorithm 2 (dead-end pattern pruning), with
  ``use_pruning=False`` reproducing the paper's "No pruning" ablation
  (identical code path minus the match/prune lines 14–15).

Candidate refinement (Eq. 2) is performed incrementally: mapping
``order[d] -> v`` intersects the candidate sets of unmapped query
neighbors with ``N(v)``; undone on backtrack. The child call performs the
empty-candidate check (line 7), so recursion counts match the paper's
accounting (refinement is conceptually inside the callee).

All indices inside the search are *order positions* (depth in the matching
order), not original query-vertex ids; reported embeddings are converted
back to query-vertex indexing.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from .candidates import build_candidates
from .deadend import NumericDeadEndTable, SetDeadEndTable
from .graph import Graph
from .ordering import connected_min_candidate_order

DEFAULT_LIMIT = 1000


@dataclasses.dataclass
class SearchStats:
    recursions: int = 0
    found: int = 0
    deadend_prunes: int = 0
    injectivity_fails: int = 0
    empty_candidate_fails: int = 0
    aborted: bool = False
    # why the search stopped early: None (ran to completion), "limit"
    # (result cap reached), "recursions"/"rows" (recursion budget),
    # "time" (wall-clock budget), or "cancelled" (evicted by
    # MatchHandle.cancel). Serving layers map this to a status.
    abort_reason: str | None = None
    wall_time_s: float = 0.0
    # time from search start to the first emitted embedding (None when
    # nothing was found) — the serving layer's TTFE metric
    ttfe_s: float | None = None
    table_stats: object | None = None


@dataclasses.dataclass
class MatchResult:
    embeddings: list[np.ndarray]  # each [n_query] data-vertex per query id
    stats: SearchStats


def _prepare(query: Graph, data: Graph, cand, order):
    if cand is None:
        cand = build_candidates(query, data)
    if order is None:
        order = connected_min_candidate_order(query, cand)
    order = np.asarray(order, dtype=np.int32)
    n = query.n
    # position-indexed views
    pos_of = np.empty(n, dtype=np.int32)
    pos_of[order] = np.arange(n, dtype=np.int32)
    # mapped-neighbor positions: for position d, positions p<d adjacent in Q
    nbr_pos: list[np.ndarray] = []
    for d in range(n):
        q = int(order[d])
        ps = np.sort(pos_of[query.neighbors(q)])
        nbr_pos.append(ps.astype(np.int32))
    cand_by_pos = [np.asarray(cand[int(order[d])], dtype=np.int32)
                   for d in range(n)]
    return cand_by_pos, order, pos_of, nbr_pos


def backtrack_naive(query: Graph, data: Graph,
                    cand: list[np.ndarray] | None = None,
                    order: np.ndarray | None = None,
                    limit: int | None = DEFAULT_LIMIT,
                    max_recursions: int | None = None,
                    time_budget_s: float | None = None) -> MatchResult:
    """Algorithm 1: plain backtracking with Eq. 2 refinement."""
    t0 = time.perf_counter()
    cand_by_pos, order, pos_of, nbr_pos = _prepare(query, data, cand, order)
    n = query.n
    nbr_sorted = data.neighbor_sorted
    stats = SearchStats()
    embeddings: list[np.ndarray] = []
    mapping = np.full(n, -1, dtype=np.int32)
    used = np.zeros(data.n, dtype=bool)
    cur = list(cand_by_pos)  # candidate arrays per position, refined in place

    def search(depth: int) -> None:
        stats.recursions += 1
        if stats.aborted:
            return
        if max_recursions is not None and stats.recursions > max_recursions:
            stats.aborted = True
            stats.abort_reason = "recursions"
            return
        if time_budget_s is not None and stats.recursions % 4096 == 0 \
                and time.perf_counter() - t0 > time_budget_s:
            stats.aborted = True
            stats.abort_reason = "time"
            return
        if depth == n:
            emb = np.empty(n, dtype=np.int32)
            emb[order] = mapping
            embeddings.append(emb)
            stats.found += 1
            if limit is not None and stats.found >= limit:
                stats.aborted = True
                stats.abort_reason = "limit"
            return
        # line 7 empty-candidate check over unmapped positions
        for d in range(depth, n):
            if len(cur[d]) == 0:
                stats.empty_candidate_fails += 1
                return
        for v in cur[depth]:
            v = int(v)
            if used[v]:
                stats.injectivity_fails += 1
                continue
            # Eq. 2 incremental refinement for unmapped neighbors of depth
            saved: list[tuple[int, np.ndarray]] = []
            nv = nbr_sorted[v]
            for p in nbr_pos[depth]:
                p = int(p)
                if p > depth:
                    saved.append((p, cur[p]))
                    cur[p] = np.intersect1d(cur[p], nv, assume_unique=True)
            mapping[depth] = v
            used[v] = True
            search(depth + 1)
            used[v] = False
            mapping[depth] = -1
            for p, arr in saved:
                cur[p] = arr
            if stats.aborted:
                return

    search(0)
    stats.wall_time_s = time.perf_counter() - t0
    return MatchResult(embeddings, stats)


def backtrack_deadend(query: Graph, data: Graph,
                      cand: list[np.ndarray] | None = None,
                      order: np.ndarray | None = None,
                      limit: int | None = DEFAULT_LIMIT,
                      max_recursions: int | None = None,
                      time_budget_s: float | None = None,
                      table_cls: Callable = NumericDeadEndTable,
                      use_pruning: bool = True,
                      on_embedding: Callable | None = None,
                      should_abort: Callable | None = None) -> MatchResult:
    """Algorithm 2: backtracking with dead-end pattern learning + pruning.

    ``use_pruning=False`` keeps pattern extraction/recording but skips the
    match/prune step (the paper's 'No pruning' comparison, §5.2).
    ``table_cls`` selects the numeric (paper, O(1)) or set-based
    (reference-semantics) table.

    ``on_embedding`` — called with each embedding (int32 [n_query]) as
    it is found, before the search continues: the sequential backend's
    incremental-delivery hook for ``MatchHandle.stream()``.
    ``should_abort`` — polled at every embedding and periodically
    between recursions; returning True stops the search with
    ``abort_reason == "cancelled"`` (partial results are kept).
    """
    t0 = time.perf_counter()
    cand_by_pos, order, pos_of, nbr_pos = _prepare(query, data, cand, order)
    n = query.n
    nbr_sorted = data.neighbor_sorted
    stats = SearchStats()
    table = table_cls(n)
    stats.table_stats = table.stats
    embeddings: list[np.ndarray] = []
    mapping_arr = np.full(n, -1, dtype=np.int32)
    mapping: list[int] = []          # data vertices by position (stack)
    used = np.zeros(data.n, dtype=bool)
    inv = np.full(data.n, -1, dtype=np.int32)  # data vertex -> position
    cur = list(cand_by_pos)
    phi = np.zeros(n + 1, dtype=np.int64)      # Φ[i] = id of length-i prefix

    def search(depth: int):
        """Returns None if the subtree reported (or was aborted); else the
        dead-end mask of the current partial embedding, as a frozenset of
        order positions < depth."""
        stats.recursions += 1
        phi[depth] = stats.recursions
        if max_recursions is not None and stats.recursions > max_recursions:
            stats.aborted = True
            stats.abort_reason = "recursions"
            return None
        if time_budget_s is not None and stats.recursions % 4096 == 0 \
                and time.perf_counter() - t0 > time_budget_s:
            stats.aborted = True
            stats.abort_reason = "time"
            return None
        if should_abort is not None and stats.recursions % 1024 == 0 \
                and should_abort():
            stats.aborted = True
            stats.abort_reason = "cancelled"
            return None
        if depth == n:
            emb = np.empty(n, dtype=np.int32)
            emb[order] = mapping_arr
            embeddings.append(emb)
            stats.found += 1
            if stats.ttfe_s is None:
                stats.ttfe_s = time.perf_counter() - t0
            if on_embedding is not None:
                on_embedding(emb)
            if limit is not None and stats.found >= limit:
                stats.aborted = True
                stats.abort_reason = "limit"
            elif should_abort is not None and should_abort():
                stats.aborted = True
                stats.abort_reason = "cancelled"
            return None
        # ---- Case 1: empty candidate set (Lemma 1) ----------------------
        for d in range(depth, n):
            if len(cur[d]) == 0:
                stats.empty_candidate_fails += 1
                gamma = frozenset(int(p) for p in nbr_pos[d] if p < depth)
                _record(depth, gamma)
                return gamma
        gamma_star: set[int] = set()
        reported = False
        for v in cur[depth]:
            v = int(v)
            if used[v]:
                # ---- Case 2: injectivity (Lemma 2) ----------------------
                stats.injectivity_fails += 1
                gamma_star.add(int(inv[v]))
                gamma_star.add(depth)
                continue
            if use_pruning:
                hit = table.match(depth, v, mapping, phi)
                if hit is not None:
                    # ---- Case 3: dead-end pattern (Lemma 3) -------------
                    stats.deadend_prunes += 1
                    gamma_star |= set(hit)
                    gamma_star.add(depth)
                    continue
            # ---- Case 4: recurse ----------------------------------------
            saved: list[tuple[int, np.ndarray]] = []
            nv = nbr_sorted[v]
            for p in nbr_pos[depth]:
                p = int(p)
                if p > depth:
                    saved.append((p, cur[p]))
                    cur[p] = np.intersect1d(cur[p], nv, assume_unique=True)
            mapping_arr[depth] = v
            mapping.append(v)
            used[v] = True
            inv[v] = depth
            child = search(depth + 1)
            used[v] = False
            inv[v] = -1
            mapping.pop()
            mapping_arr[depth] = -1
            for p, arr in saved:
                cur[p] = arr
            if stats.aborted:
                return None
            if child is None:
                reported = True
            else:
                gamma_star |= child
        if reported:
            return None
        # ---- Lemma 4 / Eq. 5 conversion ---------------------------------
        if depth in gamma_star:
            gamma = (gamma_star |
                     {int(p) for p in nbr_pos[depth]})
            gamma = frozenset(p for p in gamma if p < depth)
        else:
            gamma = frozenset(gamma_star)
        _record(depth, gamma)
        return gamma

    def _record(depth: int, gamma: frozenset[int]) -> None:
        # line 19-20: record the pattern keyed by the last mapping
        if depth == 0 or stats.aborted:
            return
        table.store(depth - 1, mapping[depth - 1], mapping, gamma, phi)

    search(0)
    stats.wall_time_s = time.perf_counter() - t0
    return MatchResult(embeddings, stats)
