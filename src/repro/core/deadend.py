"""Dead-end pattern management (paper §4.4) — re-export shim.

The table implementations are now owned by the first-class failure-
pattern subsystem in :mod:`repro.patterns` (``patterns.tables`` for the
host reference tables, ``patterns.store`` for the bounded hashed device
store). This module keeps the historical ``repro.core.deadend`` import
path alive for the sequential oracle and the tests.
"""
from __future__ import annotations

from ..patterns.tables import (DeadEndStats, NumericDeadEndTable,
                               SetDeadEndTable)

__all__ = ["DeadEndStats", "NumericDeadEndTable", "SetDeadEndTable"]
