"""Deterministic fault injection for the matching runtime (DESIGN.md §8).

A :class:`FaultPlan` is a seeded list of :class:`FaultSpec` triggers
aimed at the runtime's failure boundaries — dispatch, digest, flush,
admission, checkpoint, shard. The scheduler / distributed matcher poke
the plan at each boundary crossing (``plan.poke(site, ...)``); when a
spec's trigger count is reached the corresponding failure is injected
*on the host side*, so every chaos scenario is reproducible in CI
without touching the jitted kernels:

=============  =====================================================
site           kinds
=============  =====================================================
``dispatch``   ``exception`` (dispatch raises before the jitted
               call), ``hang`` (dispatch is marked hung; the
               watchdog treats the digest as untrusted)
``digest``     ``corrupt`` (bit-flip a digest lane past a validator
               invariant), ``overflow`` (forge a stack-capacity
               overflow for one slot)
``flush``      ``exception`` (a Δ pattern flush batch is dropped —
               sound: patterns only ever prune)
``admission``  ``exception`` (admission of one request fails)
``checkpoint`` ``exception`` (one checkpoint save fails)
``shard``      ``shard_loss`` (a distributed shard dies mid-run)
=============  =====================================================

Counters are 1-based and per-site: ``FaultSpec(site, kind, at=3)``
fires on the third crossing of ``site``; ``times=2`` keeps firing for
two consecutive crossings (e.g. ``times > dispatch_retries`` exhausts
the retry budget). Fired specs are appended to ``plan.fired`` so tests
and the chaos benchmark can assert exactly which faults landed.

All hooks are gated on ``plan is None`` in the callers, so the
disabled path costs one attribute load — zero-cost in the ab_gate
sense.
"""
from __future__ import annotations

import dataclasses
from typing import Any

__all__ = ["FaultSpec", "FaultPlan", "FaultInjected", "corrupt_digest",
           "DISPATCH_ERRORS", "DISPATCH_SITE", "DIGEST_SITE",
           "FLUSH_SITE", "ADMISSION_SITE", "CHECKPOINT_SITE",
           "SHARD_SITE"]

DISPATCH_SITE = "dispatch"
DIGEST_SITE = "digest"
FLUSH_SITE = "flush"
ADMISSION_SITE = "admission"
CHECKPOINT_SITE = "checkpoint"
SHARD_SITE = "shard"

_SITES = (DISPATCH_SITE, DIGEST_SITE, FLUSH_SITE, ADMISSION_SITE,
          CHECKPOINT_SITE, SHARD_SITE)
_KINDS = {
    DISPATCH_SITE: ("exception", "hang"),
    DIGEST_SITE: ("corrupt", "overflow"),
    FLUSH_SITE: ("exception",),
    ADMISSION_SITE: ("exception",),
    CHECKPOINT_SITE: ("exception",),
    SHARD_SITE: ("shard_loss",),
}


class FaultInjected(RuntimeError):
    """Raised (or recorded) when a planned fault fires."""


# exception types the dispatch retry loop treats as recoverable: the
# injected fault plus whatever runtime error the JAX backend surfaces
try:                                                 # pragma: no cover
    from jax.errors import JaxRuntimeError as _JaxRuntimeError
    DISPATCH_ERRORS: tuple = (FaultInjected, _JaxRuntimeError)
except Exception:                                    # pragma: no cover
    DISPATCH_ERRORS = (FaultInjected,)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One planned failure: fires on crossings ``at .. at+times-1`` of
    ``site`` (1-based). ``slot`` aims digest faults at a specific
    device slot (None = first slot in the digest's slot map)."""
    site: str
    kind: str
    at: int = 1
    slot: int | None = None
    times: int = 1

    def __post_init__(self):
        if self.site not in _SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"expected one of {_SITES}")
        if self.kind not in _KINDS[self.site]:
            raise ValueError(
                f"fault kind {self.kind!r} invalid for site "
                f"{self.site!r}; expected one of {_KINDS[self.site]}")
        if self.at < 1 or self.times < 1:
            raise ValueError("FaultSpec.at and .times must be >= 1")


class FaultPlan:
    """A deterministic, stateful schedule of :class:`FaultSpec`.

    ``poke(site, **ctx)`` advances the site's crossing counter and
    returns the matching spec if one fires (else None). ``fired``
    records ``(site, kind, crossing, ctx)`` tuples in firing order.
    """

    def __init__(self, specs: list[FaultSpec] | tuple[FaultSpec, ...] = (),
                 seed: int = 0):
        self.specs = tuple(specs)
        self.seed = int(seed)
        self.counts: dict[str, int] = {}
        self.fired: list[tuple[str, str, int, dict]] = []

    def poke(self, site: str, **ctx: Any) -> FaultSpec | None:
        """Advance ``site``'s crossing counter; return the firing spec
        (first match wins) or None."""
        n = self.counts.get(site, 0) + 1
        self.counts[site] = n
        for spec in self.specs:
            if spec.site == site and spec.at <= n < spec.at + spec.times:
                self.fired.append((site, spec.kind, n, dict(ctx)))
                return spec
        return None

    def peek(self, site: str) -> int:
        """Crossing count so far for ``site`` (no advance)."""
        return self.counts.get(site, 0)

    def reset(self) -> None:
        self.counts.clear()
        self.fired.clear()


def corrupt_digest(dig: dict, spec: FaultSpec, *, stack_capacity: int,
                   slots: list[int]) -> int:
    """Deterministically corrupt one slot's lanes in a materialized
    (host-side numpy) digest dict so a validator invariant is violated.

    ``kind="corrupt"`` breaks Lemma-4 outstanding-counter conservation
    and forges a negative counter; ``kind="overflow"`` forges a live
    count past ``stack_capacity``. Returns the corrupted slot."""
    slot = spec.slot if spec.slot is not None else slots[0]
    if spec.kind == "overflow":
        dig["d_live"][slot] = stack_capacity + 1 + (spec.at % 7)
        dig["d_pending"][slot] = stack_capacity + 1
    else:
        # flip a high bit in the conservation lane and go negative in a
        # counter lane — either alone trips the validator
        dig["d_outsum"][slot] = dig["d_outsum"][slot] ^ (1 << 20)
        dig["d_rows"][slot] = -1
    return slot
