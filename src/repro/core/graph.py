"""Graph representations for the subgraph-matching engine.

Four coupled views of one vertex-labeled undirected graph:

* CSR (``indptr``/``indices``)    — cache-friendly neighbor iteration and
  the layout every segment-op / SpMM kernel consumes.
* packed adjacency bitmaps        — ``[V, ceil(V/32)]`` uint32 words so the
  Eq. 2 candidate refinement becomes a vectorized bitwise-AND reduction
  (the Pallas ``bitmap_refine`` kernel operates on this view). Packed
  directly from CSR — the dense ``[V, V]`` boolean intermediate the old
  builder materialized is exactly the O(V²) blow-up the hierarchical
  layout exists to avoid.
* hierarchical (two-level) bitmaps — :class:`HierBitmap`: per row a
  *summary* word (one bit per C-word chunk) plus a CSR-of-chunks store
  holding only the nonzero chunks. Memory is O(E), not O(V²/32), so the
  refinement working set scales with edges touched and graphs past the
  dense bitmap's VMEM ceiling stay matchable (DESIGN.md §2).
* per-vertex neighbor sets        — Python ``set`` view used only by the
  faithful sequential reference (Algorithms 1 and 2).

The matching engine treats graphs as immutable once built.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, NamedTuple, Sequence

import numpy as np

WORD_BITS = 32


def pack_bitmap(dense: np.ndarray) -> np.ndarray:
    """Pack a boolean matrix [R, V] into uint32 words [R, ceil(V/32)].

    Bit ``j`` of word ``w`` of row ``r`` is ``dense[r, w*32 + j]``
    (little-endian bit order within each word).
    """
    dense = np.asarray(dense, dtype=bool)
    r, v = dense.shape
    n_words = (v + WORD_BITS - 1) // WORD_BITS
    padded = np.zeros((r, n_words * WORD_BITS), dtype=bool)
    padded[:, :v] = dense
    bits = padded.reshape(r, n_words, WORD_BITS)
    weights = (np.uint32(1) << np.arange(WORD_BITS, dtype=np.uint32))
    return (bits.astype(np.uint32) * weights).sum(axis=2, dtype=np.uint32)


def unpack_bitmap(words: np.ndarray, n_bits: int) -> np.ndarray:
    """Inverse of :func:`pack_bitmap` — returns a boolean matrix [R, n_bits]."""
    words = np.asarray(words, dtype=np.uint32)
    r, n_words = words.shape
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = (words[:, :, None] >> shifts) & np.uint32(1)
    return bits.reshape(r, n_words * WORD_BITS)[:, :n_bits].astype(bool)


def pack_bitmap_csr(n: int, indptr: np.ndarray,
                    indices: np.ndarray) -> np.ndarray:
    """Pack adjacency straight from CSR into uint32 [n, ceil(n/32)].

    O(E) time and O(n·W) output memory — no dense [n, n] boolean
    intermediate (that is 4 GB of bools at n=64K before packing even
    starts). Same bit order as :func:`pack_bitmap`.
    """
    n_words = (n + WORD_BITS - 1) // WORD_BITS
    words = np.zeros((n, max(n_words, 1)), dtype=np.uint32)
    cols = np.asarray(indices, dtype=np.int64)
    if cols.size:
        deg = np.asarray(indptr[1:], np.int64) - np.asarray(
            indptr[:-1], np.int64)
        rows = np.repeat(np.arange(n, dtype=np.int64), deg)
        np.bitwise_or.at(
            words, (rows, cols // WORD_BITS),
            np.uint32(1) << (cols % WORD_BITS).astype(np.uint32))
    return words


class HierBitmap(NamedTuple):
    """Two-level (hierarchical) packed adjacency: a per-row summary
    bitmap over C-word chunks plus a CSR-of-chunks store of the nonzero
    chunks only.

    Chunk ``c`` of row ``v`` covers words ``[c*C, (c+1)*C)`` of the flat
    packed row, i.e. vertices ``[c*32C, (c+1)*32C)``. ``summary[v]`` has
    bit ``c`` set iff that chunk holds at least one neighbor; the chunk's
    C words are stored at ``chunk_data[k]`` for the unique ``k`` in
    ``[chunk_ptr[v], chunk_ptr[v+1])`` with ``chunk_id[k] == c``
    (``chunk_id`` ascending within each row). ``chunk_id``/``chunk_data``
    carry ``kmax`` rows of zero padding past ``n_stored`` so a kernel may
    over-read a fixed ``kmax``-chunk window from any row start.
    """
    summary: np.ndarray     # uint32 [V, ceil(n_chunks/32)]
    chunk_ptr: np.ndarray   # int32 [V+1] CSR offsets into chunk_id/_data
    chunk_id: np.ndarray    # int32 [n_stored + kmax] chunk index per entry
    chunk_data: np.ndarray  # uint32 [n_stored + kmax, C] packed words
    chunk_words: int        # C — words per chunk (power of two)
    n_chunks: int           # ceil(W / C) addressable chunks per row
    kmax: int               # max stored chunks on any row (>= 1)

    @property
    def n_stored(self) -> int:
        return int(self.chunk_id.shape[0] - self.kmax)

    @property
    def nbytes(self) -> int:
        return int(self.summary.nbytes + self.chunk_ptr.nbytes
                   + self.chunk_id.nbytes + self.chunk_data.nbytes)


def build_hier_bitmap(n: int, indptr: np.ndarray, indices: np.ndarray,
                      chunk_words: int = 8) -> HierBitmap:
    """Build the two-level layout from CSR in O(E) — neither the dense
    bitmap nor any per-row dense chunk table is materialized.

    ``chunk_words`` must be a power of two in [1, 128] (the refine
    kernels rely on chunk boundaries dividing the 128-lane padded row —
    ``tuning/space.py`` rejects other values before anything compiles).
    """
    c = int(chunk_words)
    if c < 1 or (c & (c - 1)) or c > 128:
        raise ValueError(
            f"chunk_words={chunk_words!r} must be a power of two in "
            "[1, 128]")
    n_words = max((n + WORD_BITS - 1) // WORD_BITS, 1)
    n_chunks = (n_words + c - 1) // c
    sw = (n_chunks + WORD_BITS - 1) // WORD_BITS
    cols = np.asarray(indices, dtype=np.int64)
    deg = np.asarray(indptr[1:], np.int64) - np.asarray(indptr[:-1],
                                                        np.int64)
    rows = np.repeat(np.arange(n, dtype=np.int64), deg)
    chunk_of = cols // (WORD_BITS * c)
    # CSR rows are sorted, so (row, chunk) keys arrive sorted; unique
    # gives the stored-chunk list in row-major / ascending-chunk order.
    key = rows * n_chunks + chunk_of
    uniq, inv = np.unique(key, return_inverse=True)
    stored_row = (uniq // n_chunks).astype(np.int64)
    stored_chunk = (uniq % n_chunks).astype(np.int64)
    counts = np.bincount(stored_row, minlength=n)[:n]
    kmax = max(int(counts.max(initial=1)), 1)
    chunk_ptr = np.zeros(n + 1, dtype=np.int32)
    chunk_ptr[1:] = np.cumsum(counts)
    chunk_id = np.zeros(len(uniq) + kmax, dtype=np.int32)
    chunk_id[:len(uniq)] = stored_chunk
    chunk_data = np.zeros((len(uniq) + kmax, c), dtype=np.uint32)
    if cols.size:
        np.bitwise_or.at(
            chunk_data, (inv, (cols // WORD_BITS) % c),
            np.uint32(1) << (cols % WORD_BITS).astype(np.uint32))
    summary = np.zeros((n, sw), dtype=np.uint32)
    if len(uniq):
        np.bitwise_or.at(
            summary, (stored_row, stored_chunk // WORD_BITS),
            np.uint32(1) << (stored_chunk % WORD_BITS).astype(np.uint32))
    return HierBitmap(summary=summary, chunk_ptr=chunk_ptr,
                      chunk_id=chunk_id, chunk_data=chunk_data,
                      chunk_words=c, n_chunks=int(n_chunks), kmax=kmax)


@dataclasses.dataclass(frozen=True)
class Graph:
    """Immutable vertex-labeled undirected graph.

    Attributes:
      n:        number of vertices (ids are 0..n-1).
      labels:   int32 [n] vertex labels in 0..n_labels-1.
      indptr:   int32 [n+1] CSR row pointers.
      indices:  int32 [nnz] CSR column indices (sorted within each row).
      n_labels: size of the label alphabet.
    """

    n: int
    labels: np.ndarray
    indptr: np.ndarray
    indices: np.ndarray
    n_labels: int

    # ---- constructors -------------------------------------------------
    @staticmethod
    def from_edges(n: int, edges: Iterable[tuple[int, int]],
                   labels: Sequence[int], n_labels: int | None = None
                   ) -> "Graph":
        labels = np.asarray(labels, dtype=np.int32)
        assert labels.shape == (n,)
        src, dst = [], []
        seen = set()
        for a, b in edges:
            if a == b:
                continue  # no self loops in simple graphs
            key = (min(a, b), max(a, b))
            if key in seen:
                continue
            seen.add(key)
            src += [a, b]
            dst += [b, a]
        src_a = np.asarray(src, dtype=np.int32)
        dst_a = np.asarray(dst, dtype=np.int32)
        order = np.lexsort((dst_a, src_a))
        src_a, dst_a = src_a[order], dst_a[order]
        indptr = np.zeros(n + 1, dtype=np.int32)
        np.add.at(indptr, src_a + 1, 1)
        indptr = np.cumsum(indptr, dtype=np.int32)
        if n_labels is None:
            n_labels = int(labels.max(initial=-1)) + 1
        return Graph(n=n, labels=labels, indptr=indptr.astype(np.int32),
                     indices=dst_a, n_labels=int(n_labels))

    @staticmethod
    def from_networkx(g, label_attr: str = "label") -> "Graph":  # pragma: no cover
        import networkx as nx  # local import: optional dependency path
        mapping = {v: i for i, v in enumerate(sorted(g.nodes()))}
        labels = [0] * g.number_of_nodes()
        for v, data in g.nodes(data=True):
            labels[mapping[v]] = int(data.get(label_attr, 0))
        edges = [(mapping[a], mapping[b]) for a, b in g.edges()]
        return Graph.from_edges(g.number_of_nodes(), edges, labels)

    # ---- cached derived views -----------------------------------------
    def __post_init__(self):
        object.__setattr__(self, "_nbr_sets", None)
        object.__setattr__(self, "_nbr_sorted", None)
        object.__setattr__(self, "_bitmap", None)
        object.__setattr__(self, "_hier", {})
        object.__setattr__(self, "_label_index", None)

    @property
    def degrees(self) -> np.ndarray:
        return self.indptr[1:] - self.indptr[:-1]

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    @property
    def neighbor_sets(self) -> list[set[int]]:
        if self._nbr_sets is None:
            sets = [set(self.neighbors(v).tolist()) for v in range(self.n)]
            object.__setattr__(self, "_nbr_sets", sets)
        return self._nbr_sets

    @property
    def neighbor_sorted(self) -> list[np.ndarray]:
        """Sorted neighbor arrays (CSR rows are already sorted)."""
        if self._nbr_sorted is None:
            rows = [np.sort(self.neighbors(v)) for v in range(self.n)]
            object.__setattr__(self, "_nbr_sorted", rows)
        return self._nbr_sorted

    @property
    def adj_bitmap(self) -> np.ndarray:
        """Packed adjacency bitmap, uint32 [n, ceil(n/32)].

        Packed straight from CSR (O(E)); the old dense [n, n] boolean
        intermediate was O(V²) and alone exceeded host memory before
        the device copy at the scale bench's 64K-vertex point.
        """
        if self._bitmap is None:
            object.__setattr__(
                self, "_bitmap",
                pack_bitmap_csr(self.n, self.indptr, self.indices))
        return self._bitmap

    def hier_bitmap(self, chunk_words: int = 8) -> HierBitmap:
        """Two-level adjacency view (cached per chunk width) — the
        summary bitmap is built alongside the chunk store in one O(E)
        pass, see :func:`build_hier_bitmap`."""
        key = int(chunk_words)
        if key not in self._hier:
            self._hier[key] = build_hier_bitmap(
                self.n, self.indptr, self.indices, chunk_words=key)
        return self._hier[key]

    @property
    def label_index(self) -> dict[int, np.ndarray]:
        """label -> sorted array of vertices with that label."""
        if self._label_index is None:
            idx: dict[int, np.ndarray] = {}
            order = np.argsort(self.labels, kind="stable")
            sorted_labels = self.labels[order]
            bounds = np.searchsorted(sorted_labels,
                                     np.arange(self.n_labels + 1))
            for lab in range(self.n_labels):
                idx[lab] = np.sort(order[bounds[lab]:bounds[lab + 1]]
                                   ).astype(np.int32)
            object.__setattr__(self, "_label_index", idx)
        return self._label_index

    def has_edge(self, a: int, b: int) -> bool:
        row = self.neighbors(a)
        i = np.searchsorted(row, b)
        return bool(i < len(row) and row[i] == b)

    @property
    def n_edges(self) -> int:
        return int(len(self.indices) // 2)

    def degree(self, v: int) -> int:
        return int(self.indptr[v + 1] - self.indptr[v])

    # ---- neighbor label multiset signature (GraphQL-style filter) ------
    @property
    def neighbor_label_counts(self) -> np.ndarray:
        """[n, n_labels] int32 — count of each label among neighbors."""
        counts = np.zeros((self.n, self.n_labels), dtype=np.int32)
        src = np.repeat(np.arange(self.n, dtype=np.int32), self.degrees)
        np.add.at(counts, (src, self.labels[self.indices]), 1)
        return counts

    def relabel(self, order: np.ndarray) -> "Graph":
        """A copy with vertex ``order[i]`` renamed to ``i`` (``order``
        must be a permutation of 0..n-1)."""
        order = np.asarray(order, dtype=np.int64)
        inv = np.empty(self.n, dtype=np.int32)
        inv[order] = np.arange(self.n, dtype=np.int32)
        src = inv[np.repeat(np.arange(self.n, dtype=np.int64),
                            self.degrees.astype(np.int64))]
        dst = inv[self.indices]
        perm = np.lexsort((dst, src))
        indptr = np.zeros(self.n + 1, dtype=np.int32)
        indptr[1:] = np.cumsum(np.bincount(src, minlength=self.n))
        return Graph(n=self.n, labels=self.labels[order].copy(),
                     indptr=indptr, indices=dst[perm].astype(np.int32),
                     n_labels=self.n_labels)

    def to_networkx(self):  # pragma: no cover - debugging helper
        import networkx as nx
        g = nx.Graph()
        for v in range(self.n):
            g.add_node(v, label=int(self.labels[v]))
        for v in range(self.n):
            for w in self.neighbors(v):
                if v < w:
                    g.add_edge(v, int(w))
        return g


def degree_descending_order(g: Graph) -> np.ndarray:
    """Vertex order that concentrates the hierarchical layout: hubs get
    the low ids (stable degree-descending sort), so every row's neighbor
    bits cluster in the low chunks and the summary intersection marks
    fewer chunks live. Apply with ``g.relabel(order)``; ``order[new] ==
    old`` maps embeddings over the relabeled graph back."""
    return np.argsort(-g.degrees.astype(np.int64), kind="stable")
