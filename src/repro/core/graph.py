"""Graph representations for the subgraph-matching engine.

Three coupled views of one vertex-labeled undirected graph:

* CSR (``indptr``/``indices``)    — cache-friendly neighbor iteration and
  the layout every segment-op / SpMM kernel consumes.
* packed adjacency bitmaps        — ``[V, ceil(V/32)]`` uint32 words so the
  Eq. 2 candidate refinement becomes a vectorized bitwise-AND reduction
  (the Pallas ``bitmap_refine`` kernel operates on this view).
* per-vertex neighbor sets        — Python ``set`` view used only by the
  faithful sequential reference (Algorithms 1 and 2).

The matching engine treats graphs as immutable once built.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

WORD_BITS = 32


def pack_bitmap(dense: np.ndarray) -> np.ndarray:
    """Pack a boolean matrix [R, V] into uint32 words [R, ceil(V/32)].

    Bit ``j`` of word ``w`` of row ``r`` is ``dense[r, w*32 + j]``
    (little-endian bit order within each word).
    """
    dense = np.asarray(dense, dtype=bool)
    r, v = dense.shape
    n_words = (v + WORD_BITS - 1) // WORD_BITS
    padded = np.zeros((r, n_words * WORD_BITS), dtype=bool)
    padded[:, :v] = dense
    bits = padded.reshape(r, n_words, WORD_BITS)
    weights = (np.uint32(1) << np.arange(WORD_BITS, dtype=np.uint32))
    return (bits.astype(np.uint32) * weights).sum(axis=2, dtype=np.uint32)


def unpack_bitmap(words: np.ndarray, n_bits: int) -> np.ndarray:
    """Inverse of :func:`pack_bitmap` — returns a boolean matrix [R, n_bits]."""
    words = np.asarray(words, dtype=np.uint32)
    r, n_words = words.shape
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = (words[:, :, None] >> shifts) & np.uint32(1)
    return bits.reshape(r, n_words * WORD_BITS)[:, :n_bits].astype(bool)


@dataclasses.dataclass(frozen=True)
class Graph:
    """Immutable vertex-labeled undirected graph.

    Attributes:
      n:        number of vertices (ids are 0..n-1).
      labels:   int32 [n] vertex labels in 0..n_labels-1.
      indptr:   int32 [n+1] CSR row pointers.
      indices:  int32 [nnz] CSR column indices (sorted within each row).
      n_labels: size of the label alphabet.
    """

    n: int
    labels: np.ndarray
    indptr: np.ndarray
    indices: np.ndarray
    n_labels: int

    # ---- constructors -------------------------------------------------
    @staticmethod
    def from_edges(n: int, edges: Iterable[tuple[int, int]],
                   labels: Sequence[int], n_labels: int | None = None
                   ) -> "Graph":
        labels = np.asarray(labels, dtype=np.int32)
        assert labels.shape == (n,)
        src, dst = [], []
        seen = set()
        for a, b in edges:
            if a == b:
                continue  # no self loops in simple graphs
            key = (min(a, b), max(a, b))
            if key in seen:
                continue
            seen.add(key)
            src += [a, b]
            dst += [b, a]
        src_a = np.asarray(src, dtype=np.int32)
        dst_a = np.asarray(dst, dtype=np.int32)
        order = np.lexsort((dst_a, src_a))
        src_a, dst_a = src_a[order], dst_a[order]
        indptr = np.zeros(n + 1, dtype=np.int32)
        np.add.at(indptr, src_a + 1, 1)
        indptr = np.cumsum(indptr, dtype=np.int32)
        if n_labels is None:
            n_labels = int(labels.max(initial=-1)) + 1
        return Graph(n=n, labels=labels, indptr=indptr.astype(np.int32),
                     indices=dst_a, n_labels=int(n_labels))

    @staticmethod
    def from_networkx(g, label_attr: str = "label") -> "Graph":  # pragma: no cover
        import networkx as nx  # local import: optional dependency path
        mapping = {v: i for i, v in enumerate(sorted(g.nodes()))}
        labels = [0] * g.number_of_nodes()
        for v, data in g.nodes(data=True):
            labels[mapping[v]] = int(data.get(label_attr, 0))
        edges = [(mapping[a], mapping[b]) for a, b in g.edges()]
        return Graph.from_edges(g.number_of_nodes(), edges, labels)

    # ---- cached derived views -----------------------------------------
    def __post_init__(self):
        object.__setattr__(self, "_nbr_sets", None)
        object.__setattr__(self, "_nbr_sorted", None)
        object.__setattr__(self, "_bitmap", None)
        object.__setattr__(self, "_label_index", None)

    @property
    def degrees(self) -> np.ndarray:
        return self.indptr[1:] - self.indptr[:-1]

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    @property
    def neighbor_sets(self) -> list[set[int]]:
        if self._nbr_sets is None:
            sets = [set(self.neighbors(v).tolist()) for v in range(self.n)]
            object.__setattr__(self, "_nbr_sets", sets)
        return self._nbr_sets

    @property
    def neighbor_sorted(self) -> list[np.ndarray]:
        """Sorted neighbor arrays (CSR rows are already sorted)."""
        if self._nbr_sorted is None:
            rows = [np.sort(self.neighbors(v)) for v in range(self.n)]
            object.__setattr__(self, "_nbr_sorted", rows)
        return self._nbr_sorted

    @property
    def adj_bitmap(self) -> np.ndarray:
        """Packed adjacency bitmap, uint32 [n, ceil(n/32)]."""
        if self._bitmap is None:
            dense = np.zeros((self.n, self.n), dtype=bool)
            for v in range(self.n):
                dense[v, self.neighbors(v)] = True
            object.__setattr__(self, "_bitmap", pack_bitmap(dense))
        return self._bitmap

    @property
    def label_index(self) -> dict[int, np.ndarray]:
        """label -> sorted array of vertices with that label."""
        if self._label_index is None:
            idx: dict[int, np.ndarray] = {}
            order = np.argsort(self.labels, kind="stable")
            sorted_labels = self.labels[order]
            bounds = np.searchsorted(sorted_labels,
                                     np.arange(self.n_labels + 1))
            for lab in range(self.n_labels):
                idx[lab] = np.sort(order[bounds[lab]:bounds[lab + 1]]
                                   ).astype(np.int32)
            object.__setattr__(self, "_label_index", idx)
        return self._label_index

    def has_edge(self, a: int, b: int) -> bool:
        row = self.neighbors(a)
        i = np.searchsorted(row, b)
        return bool(i < len(row) and row[i] == b)

    @property
    def n_edges(self) -> int:
        return int(len(self.indices) // 2)

    def degree(self, v: int) -> int:
        return int(self.indptr[v + 1] - self.indptr[v])

    # ---- neighbor label multiset signature (GraphQL-style filter) ------
    @property
    def neighbor_label_counts(self) -> np.ndarray:
        """[n, n_labels] int32 — count of each label among neighbors."""
        counts = np.zeros((self.n, self.n_labels), dtype=np.int32)
        src = np.repeat(np.arange(self.n, dtype=np.int32), self.degrees)
        np.add.at(counts, (src, self.labels[self.indices]), 1)
        return counts

    def to_networkx(self):  # pragma: no cover - debugging helper
        import networkx as nx
        g = nx.Graph()
        for v in range(self.n):
            g.add_node(v, label=int(self.labels[v]))
        for v in range(self.n):
            for w in self.neighbors(v):
                if v < w:
                    g.add_edge(v, int(w))
        return g
