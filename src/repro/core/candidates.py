"""Candidate filtering for subgraph matching.

Produces, for every query vertex ``u``, the candidate set ``C[u]`` of data
vertices it may be mapped onto. Three filters of increasing strength, each
sound (never removes a vertex that participates in some embedding):

* LDF  — label + degree filter (Ullmann / Eq. 1 plus degree test).
* NLF  — neighbor-label-frequency filter (GraphQL/SPath style): ``v`` must
  have at least as many neighbors of each label as ``u`` does.
* CFL-lite — BFS-tree forward/backward refinement in the spirit of
  CFL-Match/TurboISO: a candidate survives only if every tree child/parent
  query vertex has at least one *adjacent* surviving candidate. Iterated to
  a fixpoint over the full query graph (stronger than tree-only).

The paper's method composes with these ("we can also combine our method and
structural analyses"); our default pipeline is LDF + NLF + CFL-lite, which
mirrors the paper's evaluation setup (they build on CFL-Match pruning).
"""
from __future__ import annotations

import numpy as np

from .graph import Graph


def ldf_filter(query: Graph, data: Graph) -> list[np.ndarray]:
    """Label + degree filter: C[u] = {v : l(v)=l(u), deg(v) >= deg(u)}."""
    out: list[np.ndarray] = []
    deg = data.degrees
    for u in range(query.n):
        lab = int(query.labels[u])
        cands = data.label_index.get(lab, np.empty(0, np.int32))
        cands = cands[deg[cands] >= query.degree(u)]
        out.append(np.sort(cands).astype(np.int32))
    return out


def nlf_filter(query: Graph, data: Graph,
               cand: list[np.ndarray]) -> list[np.ndarray]:
    """Neighbor-label-frequency refinement of an existing candidate list."""
    q_counts = query.neighbor_label_counts  # [nq, n_labels_q]
    d_counts = data.neighbor_label_counts   # [nd, n_labels_d]
    n_labels = min(q_counts.shape[1], d_counts.shape[1])
    out = []
    for u in range(query.n):
        need = q_counts[u]
        cands = cand[u]
        if len(cands) == 0:
            out.append(cands)
            continue
        have = d_counts[cands]
        ok = np.all(have[:, :n_labels] >= need[None, :n_labels], axis=1)
        # any query label beyond the data alphabet kills all candidates
        if need[n_labels:].any():
            ok &= False
        out.append(cands[ok])
    return out


def _refine_once(query: Graph, data: Graph,
                 cand_masks: list[np.ndarray]) -> bool:
    """One sweep of edge-consistency refinement (AC-ish / CFL passes).

    cand_masks[u] is a boolean mask over data vertices. A candidate v of u
    survives only if, for every query neighbor u', v has at least one data
    neighbor that is a candidate of u'. Returns True if anything changed.
    """
    changed = False
    nnz = data.indices.size
    # one reduceat over the data CSR per (u, u') pair instead of a
    # Python loop over candidates — the per-vertex generator dominated
    # submit latency on the serving path. The segment sum counts a
    # vertex's neighbors that are candidates of u'; empty rows read a
    # garbage segment and are masked via ``nonempty``.
    starts = np.minimum(data.indptr[:-1], max(nnz - 1, 0))
    nonempty = (data.indptr[1:] - data.indptr[:-1]) > 0
    for u in range(query.n):
        mask_u = cand_masks[u]
        if not mask_u.any():
            continue
        keep = mask_u.copy()
        for uq in query.neighbors(u):
            if nnz == 0:
                keep[:] = False
                break
            m_other = cand_masks[int(uq)]
            # v survives iff any neighbor of v is in m_other
            hit = np.add.reduceat(m_other[data.indices], starts) > 0
            keep &= nonempty & hit
            if not keep.any():
                break
        if not np.array_equal(keep, mask_u):
            changed = True
            cand_masks[u] = keep
    return changed


def cfl_refine(query: Graph, data: Graph, cand: list[np.ndarray],
               max_rounds: int = 3) -> list[np.ndarray]:
    """Fixpoint edge-consistency refinement (bounded rounds).

    Strictly sound: only candidates provably absent from every embedding
    are removed (they lack an adjacent candidate for some query neighbor).
    """
    masks = []
    for u in range(query.n):
        m = np.zeros(data.n, dtype=bool)
        m[cand[u]] = True
        masks.append(m)
    for _ in range(max_rounds):
        if not _refine_once(query, data, masks):
            break
    return [np.nonzero(m)[0].astype(np.int32) for m in masks]


def build_candidates(query: Graph, data: Graph,
                     use_nlf: bool = True,
                     use_cfl: bool = True) -> list[np.ndarray]:
    """Default filtering pipeline: LDF (+NLF) (+CFL-lite fixpoint)."""
    cand = ldf_filter(query, data)
    if use_nlf:
        cand = nlf_filter(query, data, cand)
    if use_cfl:
        cand = cfl_refine(query, data, cand)
    return cand
