"""Distributed subgraph matching: search-tree partitioning, pattern
sharing, work stealing, checkpoint/restart, elastic repartitioning.

Parallel model (DESIGN.md §3):
  * the root-candidate space of one query is range-partitioned into
    shards (mesh "model" axis / workers);
  * each shard runs its own :class:`WaveEngine` waves with a local
    dead-end table — correctness never depends on other shards (patterns
    only prune);
  * periodically, shards exchange their most recently learned patterns —
    a *lossy but sound* compressed collective (the analogue of gradient
    compression: pruning power degrades gracefully with compression);
  * a shard that finishes early steals unprocessed root ranges from the
    most-loaded shard (straggler mitigation);
  * shard progress (done ranges, found embeddings, pattern tables) is
    checkpointable; restore may change the shard count (elasticity).

This container has one physical device, so shards execute as a
round-robin cooperative schedule on it — the scheduling, stealing, merge,
and checkpoint logic is exactly what a multi-host launcher drives, and is
what the tests validate.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

import numpy as np

from .backtrack import MatchResult, SearchStats, _prepare
from .graph import Graph
from .vectorized import WaveEngine


@dataclasses.dataclass
class ShardState:
    shard_id: int
    pending_ranges: list[tuple[int, int]]   # root-candidate index ranges
    found: list[np.ndarray]
    done: bool = False


class DistributedMatcher:
    """Search-tree-partitioned matching with pattern sharing."""

    def __init__(self, data: Graph, n_shards: int = 4,
                 wave_size: int = 256, kpr: int = 16,
                 share_patterns: bool = True,
                 share_top_k: int = 4096):
        self.data = data
        self.n_shards = n_shards
        self.share_patterns = share_patterns
        self.share_top_k = share_top_k
        self.engines = [WaveEngine(data, wave_size=wave_size, kpr=kpr)
                        for _ in range(n_shards)]

    # -- pattern exchange -------------------------------------------------
    def _merge_tables(self, tables):
        """Union the shards' *transferable* dead-end patterns.

        The numeric representation's embedding ids (φ) are engine-local,
        so only μ == 0 patterns — whose match condition Φ[0] == 0 holds in
        every engine, i.e. 'mapping (pos, v) is dead regardless of
        ancestors' — may cross shards (soundness; see DESIGN.md §3). On a
        real mesh this is a hierarchical all-gather (intra-pod ring, then
        inter-pod) capped at ``share_top_k`` entries per shard: a lossy
        but sound compressed collective.
        """
        import jax.numpy as jnp
        from .engine_step import TableArrays, store_patterns
        merged = TableArrays.empty(self.data.n)
        for t in tables:
            valid = np.asarray(t.valid) & (np.asarray(t.mu) == 0)
            pos, vert = np.nonzero(valid)
            if len(pos) == 0:
                continue
            if len(pos) > self.share_top_k:
                sel = np.random.default_rng(0).choice(
                    len(pos), self.share_top_k, replace=False)
                pos, vert = pos[sel], vert[sel]
            merged = store_patterns(
                merged,
                jnp.asarray(pos.astype(np.int32)),
                jnp.asarray(vert.astype(np.int32)),
                jnp.asarray(np.asarray(t.phi)[pos, vert]),
                jnp.asarray(np.asarray(t.mu)[pos, vert]),
                jnp.asarray(np.asarray(t.mask)[pos, vert]),
                jnp.ones(len(pos), bool))
        return merged

    # -- main entry ---------------------------------------------------------
    def match(self, query: Graph, limit: int | None = 1000,
              rounds: int = 8, checkpoint_dir: str | None = None
              ) -> MatchResult:
        cand_by_pos, order, _, _ = _prepare(query, self.data, None, None)
        roots = cand_by_pos[0]
        n = len(roots)
        stats = SearchStats()
        if n == 0:
            return MatchResult([], stats)
        # range partition of the root candidates
        bounds = np.linspace(0, n, self.n_shards + 1).astype(int)
        shards = [ShardState(i, [(int(bounds[i]), int(bounds[i + 1]))], [])
                  for i in range(self.n_shards)]
        chunk = max(1, n // (self.n_shards * max(rounds, 1)))
        embeddings: list[np.ndarray] = []
        shared_table = None

        def shard_step(sh: ShardState, eng: WaveEngine) -> bool:
            """Process one stolen-or-own root chunk; True if worked."""
            if not sh.pending_ranges:
                return False
            lo, hi = sh.pending_ranges.pop()
            take = min(chunk, hi - lo)
            if hi - lo > take:
                sh.pending_ranges.append((lo + take, hi))
            sub_roots = roots[lo:lo + take]
            # rebuild a query-vertex-indexed candidate list with the
            # restricted root range (cand_by_pos is position-indexed)
            sub_cand: list[np.ndarray] = [None] * query.n
            for d in range(query.n):
                sub_cand[int(order[d])] = (sub_roots if d == 0
                                           else cand_by_pos[d])
            res = eng.match(query, limit=None, cand=sub_cand, order=order,
                            seed_table=shared_table)
            sh.found.extend(res.embeddings)
            stats.recursions += res.stats.recursions
            stats.deadend_prunes += res.stats.deadend_prunes
            return True

        round_i = 0
        while any(sh.pending_ranges for sh in shards):
            round_i += 1
            for sh, eng in zip(shards, self.engines):
                shard_step(sh, eng)
            # work stealing: idle shards take from the most loaded
            loads = [sum(hi - lo for lo, hi in sh.pending_ranges)
                     for sh in shards]
            for i, sh in enumerate(shards):
                if not sh.pending_ranges and max(loads) > chunk:
                    donor = shards[int(np.argmax(loads))]
                    lo, hi = donor.pending_ranges.pop()
                    mid = (lo + hi) // 2
                    if mid > lo:
                        donor.pending_ranges.append((lo, mid))
                    sh.pending_ranges.append((mid, hi))
                    loads = [sum(h - l for l, h in s.pending_ranges)
                             for s in shards]
            # pattern exchange
            if self.share_patterns:
                tables = [getattr(e, "_table", None) for e in self.engines]
                tables = [t for t in tables if t is not None]
                if tables:
                    shared_table = self._merge_tables(tables)
            total_found = sum(len(sh.found) for sh in shards)
            if limit is not None and total_found >= limit:
                break
            if checkpoint_dir:
                self.save_state(checkpoint_dir, query, shards)

        for sh in shards:
            embeddings.extend(sh.found)
        # global dedup (ranges are disjoint so this is a no-op safety net)
        seen = set()
        uniq = []
        for e in embeddings:
            key = e.tobytes()
            if key not in seen:
                seen.add(key)
                uniq.append(e)
        if limit is not None:
            uniq = uniq[:limit]
        stats.found = len(uniq)
        return MatchResult(uniq, stats)

    # -- checkpoint / elastic restore ---------------------------------------
    @staticmethod
    def save_state(path: str, query: Graph, shards: list[ShardState]):
        p = pathlib.Path(path)
        p.mkdir(parents=True, exist_ok=True)
        state = {
            "shards": [
                {"shard_id": s.shard_id,
                 "pending": s.pending_ranges,
                 "found": [e.tolist() for e in s.found]}
                for s in shards],
        }
        tmp = p / "state.json.tmp"
        tmp.write_text(json.dumps(state))
        tmp.rename(p / "state.json")

    @staticmethod
    def load_state(path: str, n_shards: int) -> list[ShardState]:
        """Elastic restore: redistribute pending ranges over ``n_shards``
        (which may differ from the saved shard count)."""
        state = json.loads((pathlib.Path(path) / "state.json").read_text())
        pending = []
        found: list[np.ndarray] = []
        for s in state["shards"]:
            pending.extend([tuple(r) for r in s["pending"]])
            found.extend(np.asarray(e, np.int32) for e in s["found"])
        shards = [ShardState(i, [], []) for i in range(n_shards)]
        for i, r in enumerate(pending):
            shards[i % n_shards].pending_ranges.append(r)
        shards[0].found = found
        return shards
