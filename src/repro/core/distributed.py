"""Distributed subgraph matching: shard-as-segments on the shared-wave
scheduler, with sound full-Δ sharing, work stealing, and elastic
checkpoint/restore (DESIGN.md §3).

Parallel model:
  * the root-candidate space of one query is range-partitioned into
    shards — but a shard is no longer an isolated engine: it is a *root
    segment* of one resident scheduler query (``parallelism = k``), so
    every shard rides the megastep, the double-buffered pipeline, and
    the adaptive-depth machinery of :class:`~repro.core.vectorized
    .WaveScheduler` for free;
  * all shards draw φ ids from the scheduler's single pool and write one
    slot-private dead-end table, so **every** pattern — μ > 0 included —
    learned by one shard prunes all the others with zero exchange step
    (the old per-engine architecture had to discard every μ > 0 pattern
    because φ embedding ids were engine-local);
  * an idle shard steals by splitting the largest pending work-item
    range of the most loaded shard (straggler mitigation on row ranges,
    see ``segments.QueryState.balance_shards``);
  * progress is checkpointable at segment granularity — unresolved root
    rows, found embeddings, and the learned Δ as a compact *entries*
    snapshot (``patterns.store``: pos/v/φ/μ/Γ/hits arrays over valid
    entries only, layout- and capacity-independent) in compressed
    ``.npz``; restore may change the shard count (elasticity) *and* the
    pattern-store capacity, and keeps the learned Δ;
  * *cross-host* replication (each host runs its own scheduler over a
    replica of the data graph) exchanges a capped pattern set selected
    deterministically by Δ hit counters (:func:`select_exchange_patterns`)
    — every host picks the same set from the same table state, unlike
    the fixed-seed random sample it replaces.

``share_patterns=False`` keeps the pre-unification ablation: each shard
runs as its *own* scheduler query in its own slot with a private table
and no sharing at all — the baseline the tests compare against.

This container has one physical device, so shards execute as segments of
one device-shared wave — the seeding, stealing, and checkpoint logic is
exactly what a multi-host launcher drives, and is what the tests
validate.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

import numpy as np

from ..api.options import MatchOptions
from ..patterns.store import ENTRY_KEYS, select_entries
from .backtrack import MatchResult, _prepare
from .graph import Graph
from .segments import EngineStats
from .vectorized import WaveScheduler

CHECKPOINT_VERSION = 3
# legacy v2 dense-table npz keys (one-release read compatibility)
_V2_TABLE_KEYS = ("phi", "mu", "mask", "valid")


class CheckpointCorrupt(RuntimeError):
    """A checkpoint failed structural validation (truncated archive,
    missing field, wrong shape/version). Raised by
    :meth:`DistributedMatcher.load_state` *before* any matcher state is
    mutated, naming the offending field — never a raw numpy traceback."""


def select_exchange_patterns(entries: dict, top_k: int,
                             transferable_only: bool = True) -> dict:
    """Deterministic top-k pattern selection for the cross-host exchange
    (DESIGN.md §3).

    Entries are ranked by Δ hit counter (descending — the patterns that
    actually pruned rows travel first), ties broken by (order position,
    vertex) ascending, so every host selects the identical set from the
    same table state. This replaces the old fixed-seed
    ``np.random.default_rng(0)`` sample, which was only accidentally
    deterministic and ignored pattern usefulness entirely.

    Within one host all shards already share the full table
    (shard-as-segments), so this export exists only for cross-host
    replication. μ > 0 patterns reference the sending host's φ
    numbering: they are sound to import only if the receiver raised its
    φ floor above the sender's ids (checkpoint restore does); otherwise
    keep ``transferable_only=True`` and ship μ == 0 patterns, whose
    match condition Φ[0] == 0 holds in every engine.

    ``entries`` is a pattern entries dict (``patterns.store``); the
    returned dict holds only the selected entries, still sorted by
    (pos, v).
    """
    return select_entries(entries, top_k,
                          transferable_only=transferable_only)


@dataclasses.dataclass
class Checkpoint:
    """Elastic snapshot of one distributed match (segment granularity).

    ``pending_roots`` are *data-vertex ids* of root candidates whose
    subtree was not fully resolved at snapshot time — restore re-seeds
    exactly those roots (onto any shard count) and deduplicates
    re-enumerated embeddings. ``entries`` carries the learned Δ in the
    layout-independent entries form (``patterns.store``, hit counters
    included) so restore works under any pattern-store capacity;
    ``phi_floor`` is the writer's φ ceiling, which the restoring
    scheduler reserves so μ > 0 patterns stay sound.
    """
    version: int
    pending_roots: np.ndarray | None          # int32 [P] (v2+)
    embeddings: list                          # list of int32 [n_query]
    entries: dict | None                      # Δ entries dict (v3)
    phi_floor: int = 1
    n_shards: int = 0
    # legacy (v1 JSON): root-candidate *index* ranges instead of ids
    pending_index_ranges: list | None = None


class DistributedMatcher:
    """Search-tree-partitioned matching as a thin front-end over the
    request/handle API (shard-as-segments): :meth:`submit` returns a
    non-blocking :class:`~repro.api.MatchHandle` whose ``stream()``
    yields embedding batches as the shards' waves emit them;
    :meth:`match` is the blocking wrapper that adds checkpointing."""

    def __init__(self, data: Graph, n_shards: int = 4,
                 share_patterns: bool = True,
                 share_top_k: int = 4096,
                 checkpoint_every_waves: int = 8,
                 options: MatchOptions | None = None, **knobs):
        """Engine knobs (``wave_size``, ``kpr``, ``megastep_depth``,
        ``adaptive_prune_threshold``, ``pattern_capacity``,
        ``pattern_cache``, …) resolve through
        :class:`repro.api.MatchOptions` — the shared surface with the
        scheduler and the server."""
        from ..api.session import MatchSession   # deferred: layering
        self.data = data
        self.n_shards = int(n_shards)
        self.share_patterns = share_patterns
        self.share_top_k = share_top_k
        # shared mode: ONE resident query whose n_shards root segments
        # share one slot-private Δ store. Ablation mode: one isolated
        # scheduler query (own slot, own store) per shard.
        opts = MatchOptions.resolve(options, **knobs).replace(
            n_slots=(1 if share_patterns else self.n_shards))
        # micro-checkpoint cadence (DESIGN.md §8): the MatchOptions knob
        # overrides the ctor arg so the serving surface can tune it
        self.checkpoint_every_waves = int(
            opts.micro_checkpoint_every
            if opts.micro_checkpoint_every is not None
            else checkpoint_every_waves)
        self._faults = opts.faults
        self._session = MatchSession(data, options=opts)
        self.scheduler = self._session.scheduler
        self._entries: dict | None = None     # last match's Δ snapshot

    # -- non-blocking entry -------------------------------------------------
    def submit(self, query: Graph, *,
               options: MatchOptions | None = None,
               cand: list | None = None, order=None, **overrides):
        """Submit one query as ``n_shards`` intra-query shards; returns
        a :class:`~repro.api.MatchHandle` immediately. The handle's
        ``stream()`` yields embedding batches as the shards find them
        (all shards share one slot-private Δ), ``cancel()`` evicts the
        whole sharded query. Requires ``share_patterns=True`` (the
        isolated-shard ablation has no single resident query to hand
        back)."""
        if not self.share_patterns:
            raise ValueError(
                "submit() requires share_patterns=True (the isolated-"
                "shard ablation runs one scheduler query per shard)")
        return self._session.submit(
            query, options=options, cand=cand, order=order,
            parallelism=self.n_shards, keep_table=True, **overrides)

    # -- main entry ---------------------------------------------------------
    def match(self, query: Graph, limit: int | None = 1000,
              checkpoint_dir: str | None = None, resume: bool = False,
              max_rows: int | None = None) -> MatchResult:
        """Match ``query`` across ``n_shards`` intra-query shards.

        ``checkpoint_dir``: snapshot progress every
        ``checkpoint_every_waves`` scheduler steps (and once at the
        end). ``resume=True`` restores the latest snapshot from that
        directory — possibly written under a different shard count —
        re-seeding only unresolved roots and keeping the learned Δ.
        ``max_rows`` bounds the row budget (mainly to exercise
        mid-flight aborts + restore in tests).
        """
        if checkpoint_dir is not None and not self.share_patterns:
            # fail fast, before load_state/reserve_phi_floor touch any
            # state: the isolated-shard ablation has no snapshot path,
            # and a silently ignored checkpoint_dir would lose progress
            # on abort (or resume stale state from an earlier run)
            raise ValueError(
                "checkpointing requires share_patterns=True "
                "(the isolated-shard ablation does not snapshot)")
        cand_by_pos, order, _, _ = _prepare(query, self.data, None, None)
        roots = np.asarray(cand_by_pos[0], np.int32)
        prior = None
        if resume and checkpoint_dir is not None:
            prior = self.load_state(checkpoint_dir)
        if prior is not None:
            pending = self._pending_roots(prior, roots)
            if prior.entries is not None:
                self.scheduler.reserve_phi_floor(prior.phi_floor)
        else:
            pending = roots
        prior_embs = list(prior.embeddings) if prior is not None else []

        if len(pending) == 0 or (
                limit is not None and len(prior_embs) >= limit):
            return self._merge_result(prior_embs, [], EngineStats(), limit)
        # the resumed run may re-enumerate duplicates of prior
        # embeddings (re-seeded pending roots), so its raw limit must
        # leave room for them: dedup happens on the merged union.
        run_limit = (None if limit is None
                     else limit + len(prior_embs))
        sub_cand = self._restrict_roots(cand_by_pos, order, pending,
                                        query.n)
        if not self.share_patterns:
            res = self._match_isolated(query, sub_cand, order, run_limit)
            return self._merge_result(prior_embs, res.embeddings,
                                      res.stats, limit)

        seed_patterns = (prior.entries if prior is not None else None)
        while True:
            h = self.submit(query, limit=run_limit, cand=sub_cand,
                            order=order, max_rows=max_rows,
                            seed_patterns=seed_patterns)
            waves = 0
            lost = False
            while self._session.step():
                waves += 1
                if (checkpoint_dir is not None
                        and waves % self.checkpoint_every_waves == 0):
                    ck = self._snapshot(h.query_id, prior_embs)
                    if ck is not None:
                        self._save_checkpoint(checkpoint_dir, ck)
                # injected shard loss (DESIGN.md §8): the lost shard is
                # a root segment of the one resident query, so its
                # frontier state dies with the query — recovery is
                # restore-from-micro-checkpoint on the survivors
                if (self._faults is not None and self.n_shards > 1
                        and not h.done()
                        and self._faults.poke("shard", wave=waves)
                        is not None):
                    h.cancel()
                    self._session.run()      # drain the teardown
                    self.n_shards -= 1
                    lost = True
                    break
            if not lost:
                break
            # re-seed the lost shard's unresolved roots onto the
            # survivors from the latest micro-checkpoint (or from
            # scratch when there is none — dedup makes that sound)
            recov = (self.load_state(checkpoint_dir)
                     if checkpoint_dir is not None else None)
            if recov is not None:
                pending = self._pending_roots(recov, roots)
                prior_embs = [np.asarray(e, np.int32)
                              for e in recov.embeddings]
                if recov.entries is not None:
                    self.scheduler.reserve_phi_floor(recov.phi_floor)
                seed_patterns = recov.entries
            else:
                pending = roots
            if len(pending) == 0 or (
                    limit is not None and len(prior_embs) >= limit):
                return self._merge_result(prior_embs, [], EngineStats(),
                                          limit)
            run_limit = (None if limit is None
                         else limit + len(prior_embs))
            sub_cand = self._restrict_roots(cand_by_pos, order, pending,
                                            query.n)
        qr = h.result()
        self._entries = self.scheduler.tables.pop(h.query_id, None)
        out = self._merge_result(prior_embs, qr.embeddings, qr.stats,
                                 limit)
        # final snapshot only on clean completion: an aborted run's
        # segments are already evicted, so the last periodic snapshot
        # (still on disk) is the correct restore point.
        if checkpoint_dir is not None and not qr.stats.aborted:
            self._save_checkpoint(checkpoint_dir, Checkpoint(
                version=CHECKPOINT_VERSION,
                pending_roots=np.zeros(0, np.int32),
                embeddings=[np.asarray(e, np.int32)
                            for e in out.embeddings],
                entries=self._entries,
                phi_floor=self.scheduler.pool.id_counter,
                n_shards=self.n_shards))
        return out

    def _save_checkpoint(self, path: str, ck: Checkpoint) -> None:
        """One save, with the ``checkpoint`` fault boundary: an injected
        save failure skips this snapshot (the previous one on disk stays
        the restore point) instead of killing the match."""
        if (self._faults is not None
                and self._faults.poke("checkpoint") is not None):
            return
        self.save_state(path, ck)

    # -- pattern export (cross-host exchange) -------------------------------
    def export_patterns(self, top_k: int | None = None,
                        transferable_only: bool = True) -> dict:
        """Export the last match's Δ for cross-host replication, capped
        at ``top_k`` (default ``share_top_k``) entries selected by
        :func:`select_exchange_patterns` (hit-counter ranked,
        deterministic). Returns a pattern entries dict ready for a
        receiving scheduler's ``seed_patterns``."""
        if self._entries is None:
            raise RuntimeError("no completed shared match to export")
        return select_exchange_patterns(
            self._entries,
            self.share_top_k if top_k is None else top_k,
            transferable_only=transferable_only)

    # -- internals ----------------------------------------------------------
    @staticmethod
    def _pending_roots(prior: Checkpoint, roots: np.ndarray) -> np.ndarray:
        if prior.pending_roots is not None:
            return np.asarray(prior.pending_roots, np.int32)
        # legacy v1: index ranges into the (deterministic) root order
        pend = []
        for lo, hi in prior.pending_index_ranges or []:
            pend.append(roots[int(lo):int(hi)])
        return (np.concatenate(pend).astype(np.int32) if pend
                else np.zeros(0, np.int32))

    @staticmethod
    def _restrict_roots(cand_by_pos, order, pending: np.ndarray,
                        n: int) -> list:
        """Query-vertex-indexed candidate list with the root position
        restricted to ``pending`` (cand_by_pos is position-indexed)."""
        sub_cand: list = [None] * n
        for d in range(n):
            sub_cand[int(order[d])] = (pending if d == 0
                                       else cand_by_pos[d])
        return sub_cand

    def _match_isolated(self, query: Graph, sub_cand: list,
                        order: np.ndarray, limit: int | None) -> MatchResult:
        """Ablation (``share_patterns=False``): one isolated scheduler
        query per shard — private slot, private table, no pattern flow
        between shards. Root ranges are disjoint so results just
        concatenate."""
        sched = self.scheduler
        roots = np.asarray(sub_cand[int(order[0])], np.int32)
        bounds = np.linspace(0, len(roots),
                             self.n_shards + 1).astype(int)
        qids = []
        for i in range(self.n_shards):
            lo, hi = int(bounds[i]), int(bounds[i + 1])
            if hi <= lo:
                continue
            shard_cand = list(sub_cand)
            shard_cand[int(order[0])] = roots[lo:hi]
            qids.append(sched.submit(query, limit=limit, cand=shard_cand,
                                     order=order))
        sched.run()
        stats = EngineStats()
        embeddings: list[np.ndarray] = []
        for qid in qids:
            r = sched.finished.pop(qid)
            embeddings.extend(r.embeddings)
            stats.recursions += r.stats.recursions
            stats.rows_created += r.stats.rows_created
            stats.deadend_prunes += r.stats.deadend_prunes
            stats.injectivity_fails += r.stats.injectivity_fails
            stats.patterns_stored += r.stats.patterns_stored
            stats.aborted |= r.stats.aborted
        sched.poll()
        return MatchResult(embeddings, stats)

    @staticmethod
    def _merge_result(prior_embs: list, new_embs: list, stats,
                      limit: int | None) -> MatchResult:
        """Union + dedup (restore re-enumerates roots that were mid-
        flight at snapshot time; ranges are otherwise disjoint)."""
        seen = set()
        uniq: list[np.ndarray] = []
        for e in list(prior_embs) + list(new_embs):
            e = np.asarray(e, np.int32)
            key = e.tobytes()
            if key not in seen:
                seen.add(key)
                uniq.append(e)
        if limit is not None:
            uniq = uniq[:limit]
        stats.found = len(uniq)
        return MatchResult(uniq, stats)

    def _snapshot(self, qid: int, prior_embs: list) -> Checkpoint | None:
        """Checkpoint a *running* shared match at segment granularity:
        root rows whose subtree is not fully resolved come back as
        pending (restore re-explores them and dedups)."""
        sched = self.scheduler
        q = next((s for s in sched.pool.slots
                  if s is not None and s.query_id == qid), None)
        if q is None or not q.active:
            return None
        pending = []
        for seg in q.segments.values():
            if seg.depth != 1 or seg.parent_seg[0] >= 0:
                continue
            rows = ~seg.resolved
            if rows.any():
                pending.append(seg.frontier[rows, 0])
        pending_roots = (np.concatenate(pending).astype(np.int32)
                         if pending else np.zeros(0, np.int32))
        from ..patterns.store import store_to_entries
        from .engine_step import read_store_slot
        q.materialize_hits()          # fold buffered digest hit batches
        entries = store_to_entries(read_store_slot(sched.tb, q.slot),
                                   q.hit_counts)
        return Checkpoint(
            version=CHECKPOINT_VERSION, pending_roots=pending_roots,
            embeddings=([np.asarray(e, np.int32) for e in prior_embs]
                        + [np.asarray(e, np.int32)
                           for e in q.embeddings]),
            entries=entries,
            phi_floor=sched.pool.id_counter, n_shards=self.n_shards)

    # -- checkpoint / elastic restore ---------------------------------------
    @staticmethod
    def save_state(path: str, ck: Checkpoint) -> None:
        """Write a compressed ``state.npz`` snapshot (atomic rename).

        Format v3: ``version``, ``n_shards``, ``phi_floor``,
        ``pending_roots`` (data-vertex ids), ``embeddings`` (int32
        [n_found, n_query]), and the Δ *entries* arrays
        (``delta_pos/v/phi/mu/mask/hits`` — valid entries only, so the
        snapshot is O(patterns), not O(positions × vertices), and
        restores under any store capacity). The shard count is
        informational — restore redistributes pending roots over
        whatever ``n_shards`` the restoring matcher uses.
        """
        p = pathlib.Path(path)
        p.mkdir(parents=True, exist_ok=True)
        embs = (np.stack(ck.embeddings).astype(np.int32)
                if ck.embeddings else np.zeros((0, 0), np.int32))
        payload = {
            "version": np.int64(ck.version),
            "n_shards": np.int64(ck.n_shards),
            "phi_floor": np.int64(ck.phi_floor),
            "pending_roots": np.asarray(
                ck.pending_roots if ck.pending_roots is not None else [],
                np.int32),
            "embeddings": embs,
        }
        if ck.entries is not None:
            for k in ENTRY_KEYS:
                payload[f"delta_{k}"] = np.asarray(ck.entries[k])
        tmp = p / "state.npz.tmp"
        with open(tmp, "wb") as f:
            np.savez_compressed(f, **payload)
        tmp.rename(p / "state.npz")

    @staticmethod
    def load_state(path: str) -> Checkpoint | None:
        """Load the latest snapshot. Prefers ``state.npz`` (v3 entries;
        v2 dense-table snapshots are converted on read); falls back to
        the legacy ``state.json`` (v1: root-index ranges, no Δ).

        The archive is structurally validated *before* any state is
        assembled: a truncated file, a missing/unreadable field, a
        wrong-shape array or an unsupported version raises
        :class:`CheckpointCorrupt` naming the bad field — callers never
        see a raw numpy/zipfile traceback, and a matcher resuming from
        a corrupt snapshot mutates nothing."""
        p = pathlib.Path(path)
        npz = p / "state.npz"
        if npz.exists():
            try:
                z = np.load(npz)
            except Exception as exc:
                raise CheckpointCorrupt(
                    f"checkpoint {npz} is unreadable (truncated or not "
                    f"an npz archive): {exc}") from exc
            with z:
                files = set(z.files)
                for k in ("version", "n_shards", "phi_floor",
                          "pending_roots", "embeddings"):
                    if k not in files:
                        raise CheckpointCorrupt(
                            f"checkpoint {npz} is missing required "
                            f"field {k!r}")

                def _arr(name: str, ndim: int | None = None):
                    try:
                        a = z[name]
                    except Exception as exc:
                        raise CheckpointCorrupt(
                            f"checkpoint {npz}: field {name!r} is "
                            f"unreadable (truncated member): {exc}"
                        ) from exc
                    if ndim is not None and a.ndim != ndim:
                        raise CheckpointCorrupt(
                            f"checkpoint {npz}: field {name!r} has "
                            f"shape {a.shape}, expected a {ndim}-D "
                            f"array")
                    return a

                def _scalar(name: str) -> int:
                    a = _arr(name)
                    if a.size != 1:
                        raise CheckpointCorrupt(
                            f"checkpoint {npz}: field {name!r} must be "
                            f"a scalar, got shape {a.shape}")
                    return int(a)

                version = _scalar("version")
                if not 1 <= version <= CHECKPOINT_VERSION:
                    raise CheckpointCorrupt(
                        f"checkpoint {npz}: field 'version' = "
                        f"{version} unsupported (expected 1.."
                        f"{CHECKPOINT_VERSION})")
                n_shards = _scalar("n_shards")
                phi_floor = _scalar("phi_floor")
                pending = _arr("pending_roots", ndim=1)
                embs = _arr("embeddings", ndim=2)
                entries = None
                if "delta_pos" in files:
                    for k in ENTRY_KEYS:
                        if f"delta_{k}" not in files:
                            raise CheckpointCorrupt(
                                f"checkpoint {npz} is missing Δ field "
                                f"'delta_{k}' (has delta_pos)")
                    entries = {k: _arr(f"delta_{k}", ndim=1)
                               for k in ENTRY_KEYS}
                    n_ent = len(entries["pos"])
                    for k in ENTRY_KEYS:
                        if len(entries[k]) != n_ent:
                            raise CheckpointCorrupt(
                                f"checkpoint {npz}: field 'delta_{k}' "
                                f"has {len(entries[k])} entries, "
                                f"expected {n_ent} (= len(delta_pos))")
                elif "table_valid" in files:
                    entries = _entries_from_dense_v2(
                        {k: _arr(f"table_{k}") for k in _V2_TABLE_KEYS},
                        _arr("table_hits") if "table_hits" in files
                        else None)
                return Checkpoint(
                    version=version,
                    pending_roots=pending.astype(np.int32),
                    embeddings=[e for e in embs.astype(np.int32)],
                    entries=entries,
                    phi_floor=phi_floor,
                    n_shards=n_shards)
        legacy = p / "state.json"
        if legacy.exists():
            state = json.loads(legacy.read_text())
            ranges = []
            found: list[np.ndarray] = []
            for s in state["shards"]:
                ranges.extend([tuple(r) for r in s["pending"]])
                found.extend(np.asarray(e, np.int32) for e in s["found"])
            return Checkpoint(version=1, pending_roots=None,
                              embeddings=found, entries=None,
                              pending_index_ranges=ranges,
                              n_shards=len(state["shards"]))
        return None


def _entries_from_dense_v2(table: dict, hits: np.ndarray | None) -> dict:
    """Convert a legacy v2 dense ``[N_PAD, V]`` table snapshot to the
    entries form (one-release read compatibility)."""
    valid = np.asarray(table["valid"])
    pos, vert = np.nonzero(valid)
    from ..patterns.store import mask64
    return {"pos": pos.astype(np.int32), "v": vert.astype(np.int32),
            "phi": np.asarray(table["phi"])[pos, vert].astype(np.int32),
            "mu": np.asarray(table["mu"])[pos, vert].astype(np.int32),
            "mask": mask64(np.asarray(table["mask"])[pos, vert]),
            "hits": (np.asarray(hits)[pos, vert].astype(np.int64)
                     if hits is not None
                     else np.zeros(len(pos), np.int64))}
