"""Host-side shared-wave scheduler for the TPU matching engine.

Continuous multi-query wave batching (DESIGN.md §2): many concurrent
queries are admitted into bank *slots*; every wave is packed with ready
segment rows from whichever queries have work, so one fixed-shape jitted
device program serves mixed traffic with no idle gaps between queries.
The per-query DFS stacks and Lemma-4 resolution bookkeeping live in
``segments.py``; all dense work — Eq. 2 refinement, injectivity,
dead-end lookup, child extraction, pattern scatter — runs in the jitted
device programs of ``engine_step``.

Megastep & async pipeline (DESIGN.md §2): with ``megastep_depth > 1``
each packed wave is dispatched as one fused ``run_megastep_mq`` program
that executes up to K consecutive depth-steps on a device-resident ring
buffer — child assembly, dead-end lookups, embedding emission, and the
batched pattern flush all happen in-loop, and only a compact digest
returns to the host. ``step()`` is double-buffered: megastep *i+1* is
dispatched (JAX async dispatch, nothing materialized) *before* megastep
*i*'s digest is read, so host bookkeeping overlaps device compute
instead of serializing on ~14 per-wave ``np.asarray`` syncs as the
single-step path did. ``megastep_depth == 1`` keeps the synchronous
single-step path (`expand_wave_mq` + host assembly) as the oracle
reference schedule.

Scheduling policy: admission fills free slots from a bounded FIFO queue;
wave packing round-robins over active queries, splitting segment slices
so waves stay full. The one-item-per-query rule is the fair-share
*floor*: on the fused megastep schedule a query may contribute up to
``max(1, wave_size / n_active)`` items per wave (occupancy-aware
packing — a lone heavy query fills the wave), while the single-step
schedule keeps the strict one-item store→lookup cadence. A query
submitted with ``parallelism = k`` runs as k intra-query shards
(shard-as-segments, DESIGN.md §3): k root segments with per-shard DFS
stacks, work stealing on work-item ranges, and one shared slot-private
table so every pattern (μ > 0 included) crosses shards for free.
Per-query ``limit`` / ``max_rows`` / ``time_budget_s`` abort a query
and evict its segments without touching its neighbors.

Learning happens *across* waves: patterns extracted from failures in
earlier-expanded subtrees prune later waves of the same query (stores are
slot-private, so live queries never see each other's patterns), and the
megastep additionally stores Lemma-1 patterns *inside* the loop, so they
prune later depth-steps of the same dispatch. Δ itself is the bounded
hashed store of :mod:`repro.patterns.store` — O(configured capacity)
device memory regardless of data-graph size, with counter-guided
eviction — and learning additionally crosses *queries* through the
template cache (:mod:`repro.patterns.cache`, DESIGN.md §6): a retiring
learner snapshots its hot transferable patterns and an admission of an
identical template warm-starts from them. Matching is exact for any
schedule, capacity, or seed because stored patterns are true dead-ends.

The public face of all of this is the request/handle API of
:mod:`repro.api` (DESIGN.md §4): a ``MatchSession`` wraps a scheduler,
``submit()`` is non-blocking and returns a ``MatchHandle`` whose
``stream()`` consumes the per-query embedding deliveries this module
pushes out of ``_retire_mega``/``_process_wave`` (``_deliver``), and
``cancel()`` rides :meth:`WaveScheduler.cancel` onto the existing
eviction path. Every knob resolves through ``repro.api.MatchOptions``
— the single default surface shared with the server and the
distributed matcher. :class:`WaveEngine` is the single-query blocking
facade (one slot) kept for the sequential-style API; the distributed
matcher fronts the same session machinery (shard-as-segments,
``core.distributed``).
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..api.options import MatchOptions
from ..kernels.config import (get_backend, kernel_chunk_words,
                              kernel_dma_depth, use_hbm_adjacency)

_log = logging.getLogger(__name__)
from ..patterns import (DeadEndStats, PatternCache, PatternStore,
                        PatternStoreBank, age_hits, empty_entries,
                        entries_to_store, store_to_entries)
from .backtrack import MatchResult, _prepare
from .faults import DISPATCH_ERRORS, FaultInjected, corrupt_digest
from .engine_step import (MASK_WORDS, N_PAD, STK_FREE, STK_FRESH,
                          STK_LEFT, STK_RES, STK_WAIT, DeviceResult,
                          GraphArrays, MegaResult, QueryBank, StackBank,
                          assemble_children_mq, clear_slot_stack,
                          clear_slot_stacks, expand_wave_mq,
                          extract_more_mq, load_slot, load_slots,
                          read_store_slot, run_device_megastep,
                          run_megastep_mq, store_patterns_mq)
from .graph import Graph, pack_bitmap
from .segments import (EngineStats, QueryState, Segment, SegmentPool,
                       WorkItem, below, bit_of, mask64, words_from64)

__all__ = ["WaveScheduler", "WaveEngine", "EngineStats", "QueueFull",
           "match_vectorized"]


class QueueFull(RuntimeError):
    """Raised when the bounded admission queue rejects a submission."""


# per-slot scalar lanes of a DeviceResult digest, materialized as one
# dict so the validator / fault injector can address them uniformly
_DEV_LANES = ("d_accepted", "d_expanded", "d_rows", "d_prunes", "d_inj",
              "d_stored", "d_pending", "d_live", "d_outsum",
              "d_childlive")


@dataclasses.dataclass
class _Request:
    """A prepared query waiting in the admission queue."""
    query_id: int
    n: int
    order: np.ndarray
    roots: np.ndarray
    cand_bitmap: np.ndarray        # uint32 [N_PAD, W]
    nbr_mask: np.ndarray           # bool [N_PAD, N_PAD]
    qnbr_bits: np.ndarray          # uint64 [N_PAD]
    limit: int | None
    learn: bool
    max_rows: int | None
    time_budget_s: float | None
    seed_patterns: dict | None     # entries dict (patterns.store)
    keep_table: bool
    t_submit: float
    # canonical template key (patterns.cache); None when the scheduler
    # runs cache-less — the SHA-1 over the packed candidate bitmap is
    # not free at web-scale V, so it is only computed when consumed
    fingerprint: bytes | None
    parallelism: int = 1
    # priority-aware admission: higher admitted first, FIFO within a tie
    priority: int = 0
    # streamed-embedding sink (MatchHandle._push); None = no streaming
    on_embeddings: object | None = None
    # ---- degraded-mode replay (DESIGN.md §8) --------------------------
    # a quarantined query is re-admitted as a fresh request on the host
    # single-step fallback path, carrying the embeddings it already
    # found (deduplicated on replay) and its failure count
    host_only: bool = False
    fail_count: int = 0
    prior_embeddings: list | None = None   # [n_query] int32 rows
    emb_seen: set | None = None            # tobytes() of every prior row
    prior_rows: int = 0                    # rows_created before demotion
    prior_ttfe: float | None = None


@dataclasses.dataclass
class _Inflight:
    """A dispatched-but-unread device wave (the pipeline's depth-1 slot).

    ``res`` holds unmaterialized device arrays; reading any of them
    blocks until the dispatch finishes, which the scheduler postpones
    until the *next* wave is already on its way.
    """
    kind: str                      # "mega" | "leftover"
    res: object                    # MegaResult | extract_more_mq tuple
    metas: list                    # [(q, seg, s, e, woff, k)]
    slot_map: dict                 # slot -> QueryState at dispatch time
    fr: np.ndarray | None = None   # leftover kind: packed inputs for
    us: np.ndarray | None = None   # host-side child assembly
    ph: np.ndarray | None = None
    depth_v: np.ndarray | None = None
    t_dispatch: float = 0.0        # watchdog reference point
    hung: bool = False             # injected hang: digest untrusted


@dataclasses.dataclass
class _InflightDev:
    """A dispatched-but-unread device-resident dispatch (stack path).

    The digest is per-slot scalars plus the embedding batch — no per-row
    lanes ever cross back; ``slot_map`` snapshots slot ownership at
    dispatch time so a slot recycled mid-flight drops the stale digest.
    """
    res: DeviceResult              # unmaterialized device digest
    slot_map: dict                 # slot -> QueryState at dispatch time
    root_slots: tuple              # slots whose root batch rode along
    t_max: int
    t_dispatch: float = 0.0        # watchdog reference point
    hung: bool = False             # injected hang: digest untrusted


class WaveScheduler:
    """Continuous multi-query matching over one data graph.

    Usage::

        sched = WaveScheduler(data_graph, n_slots=16)
        qid = sched.submit(query_graph, limit=1000)
        sched.run()
        res = sched.finished.pop(qid)          # MatchResult

    ``megastep_depth`` — K consecutive depth-steps fused into one device
    dispatch (1 = the synchronous single-step reference schedule).
    ``store_flush_min`` — single-step path only: host-queued pattern
    stores are batched across waves until this many are pending (the
    megastep path fuses the flush into every dispatch instead).

    Every knob lives on :class:`repro.api.MatchOptions` — pass a
    resolved ``options`` object or the equivalent keyword overrides;
    defaults come from ``MatchOptions`` alone (no local copies), and
    the instance's ``options`` doubles as the default per-query options
    for :meth:`submit`.
    """

    def __init__(self, data: Graph, *,
                 options: MatchOptions | None = None, **knobs):
        opts = MatchOptions.resolve(options, **knobs)
        self.options = opts
        self.data = data
        self._kernel_backend = get_backend()
        # tuning resolution (DESIGN.md §9): every tunable knob the
        # caller left None fills from the persistent tuning cache
        # (keyed by backend / device kind / quantized |V|), else the
        # built-in default. Explicit values — options or kwargs — win.
        tuned, self.tuning_record = opts.resolved_engine(
            backend=self._kernel_backend, n_vertices=data.n)
        _log.info(
            "WaveScheduler tuning: %s (%s) for backend=%s |V|=%d -> %s",
            self.tuning_record["source"],
            self.tuning_record["record"] or "built-in defaults",
            self._kernel_backend, data.n, tuned)
        self.n_slots = tuned["n_slots"]
        self.wave_size = tuned["wave_size"]
        self.kpr = int(opts.kpr)
        self.use_pruning = (True if opts.use_pruning is None
                            else opts.use_pruning)
        self.max_queue = int(opts.max_queue)
        self.megastep_depth = tuned["megastep_depth"]
        self.store_flush_min = tuned["store_flush_min"]
        self.store_pad = int(opts.store_pad)
        self._block_f = tuned["block_f"]
        # bounded hashed Δ store (patterns.store): per-slot capacity is a
        # power of two, independent of the data-graph vertex count.
        # Eviction is counter-guided and always sound; ``hit_decay_every``
        # waves the device hit counters are halved so eviction tracks
        # recent usefulness.
        self.pattern_capacity = tuned["pattern_capacity"]
        self.hit_decay_every = int(opts.hit_decay_every)
        # cross-query template cache (patterns.cache): retiring learners
        # snapshot their hot transferable (μ == 0) patterns; admissions
        # of an identical template warm-start from them.
        self.pattern_cache = (
            PatternCache(opts.pattern_cache_templates,
                         opts.pattern_cache_top_k)
            if opts.pattern_cache else None)
        # deferred cache snapshots: a retiring learner's slot store is
        # captured as async device slices (no host block on the in-
        # flight pipeline) and folded into the cache only if the same
        # template is admitted again — never-repeated templates pay
        # nothing. Bounded LRU alongside the cache itself.
        self._pending_snaps: collections.OrderedDict[bytes, tuple] = \
            collections.OrderedDict()
        self.warm_started = 0           # queries admitted with a warm Δ
        self.warm_patterns_seeded = 0
        # aggregate device store counters (megastep digests + flushes).
        # Flush counters accumulate as an unmaterialized device sum and
        # fold at ownership-change points (query finish) and stats reads
        # — materializing per flush would serialize the async pipeline.
        self.store_counters = {"stored": 0, "overwrites": 0,
                               "evictions": 0, "dropped": 0}
        self._flush_ctr_dev = None          # lazy StoreCounters sum
        self._last_aged_wave = 0
        # adaptive depth: a per-wave prune-rate EMA decides between the
        # fused K-deep megastep (cheap traffic: latency hiding wins) and
        # the synchronous single-step schedule (failure-heavy traffic:
        # the paper's tight store→lookup cadence wins — K-deep
        # speculation would expand rows that fresh patterns could have
        # pruned). Starts at 1.0 = assume prune-heavy until proven easy.
        self.adaptive_prune_threshold = float(
            opts.adaptive_prune_threshold)
        self._prune_ema = 1.0
        # the megastep extracts with a deeper per-row cap than the
        # single-step path: every child beyond the cap forces a
        # host-round-trip leftover pass, which is exactly what the fused
        # loop exists to avoid (hub vertices overflow kpr=8 routinely).
        self._mega_kpr = 2 * self.kpr
        # ring capacity: one chunk's worst-case fan-out (F·kpr) must fit
        # above the tail at every iteration (the megastep's conservative
        # overflow guard), with 2x slack so typical fan-outs get several
        # depth-steps before the guard trips.
        self._ring_capacity = 2 * self.wave_size * (self._mega_kpr + 1)
        self._emb_cap = 2 * self.wave_size * self._mega_kpr
        self.w = (data.n + 31) // 32
        # adjacency layout (DESIGN.md §2): options pin wins, else the
        # kernels.config size threshold / tuning record decides. The
        # hierarchical path never materializes the dense [V, W] block —
        # at 64K vertices that block alone is 512 MB, the thing the
        # layout exists to avoid.
        self._use_hier = (bool(opts.hier_adjacency)
                          if opts.hier_adjacency is not None
                          else use_hbm_adjacency(self._kernel_backend,
                                                 data.n))
        if self._use_hier:
            cw = (int(opts.chunk_words) if opts.chunk_words is not None
                  else kernel_chunk_words(self._kernel_backend, data.n))
            self._dma_depth = (
                int(opts.dma_depth) if opts.dma_depth is not None
                else kernel_dma_depth(self._kernel_backend, data.n))
            hb = data.hier_bitmap(chunk_words=cw)
            self._chunk_words = cw
            self.g = GraphArrays(
                adj_bitmap=None,
                n_vertices=jnp.int32(data.n),
                adj_summary=jnp.asarray(hb.summary),
                chunk_ptr=jnp.asarray(hb.chunk_ptr),
                chunk_id=jnp.asarray(hb.chunk_id),
                chunk_data=jnp.asarray(hb.chunk_data),
                chunk_pad=jnp.zeros((hb.kmax,), jnp.int32))
            self.adjacency_variant = "hier-hbm"
            self.adjacency_bytes = int(hb.nbytes)
        else:
            self._chunk_words = 0
            self._dma_depth = None
            self.g = GraphArrays(
                adj_bitmap=jnp.asarray(data.adj_bitmap),
                n_vertices=jnp.int32(data.n))
            self.adjacency_variant = "dense-vmem"
            self.adjacency_bytes = data.n * self.w * 4
        self.qb = QueryBank.empty(self.n_slots, self.w)
        self.tb = PatternStoreBank.empty(self.n_slots,
                                         self.pattern_capacity)
        self._empty_store = PatternStore.empty(
            self.pattern_capacity)                      # reused, immutable
        # cached [k]-stacked empty stores for burst admission (most
        # admissions carry no seed patterns — stacking on every burst
        # would cost seven dispatches per flush)
        self._empty_store_stacks: dict[int, PatternStore] = {}
        self.pool = SegmentPool(self.n_slots)
        self.queue: collections.deque[_Request] = collections.deque()
        self.finished: dict[int, MatchResult] = {}
        # per-query Δ snapshots (entries dicts, keep_table only)
        self.tables: dict[int, dict] = {}
        self._fresh_done: list[int] = []
        self._next_qid = 0
        self._rr = 0
        self._inflight: _Inflight | None = None
        # device-resident frontier stacks (DESIGN.md §2): plain
        # parallelism-1 queries keep their whole DFS stack in device
        # arrays and the host only sees per-slot scalar digests.
        # keep_table / parallelism>1 / single-step traffic stays on the
        # host SegmentPool path (it needs row-level introspection).
        self._use_device = (bool(opts.device_stacks)
                            and self.megastep_depth > 1)
        self.stack_capacity = tuned["stack_capacity"]
        # eager: the bank is a construction cost, not a first-query
        # latency cost (a fresh server's first batch used to pay it)
        self.sb: StackBank | None = (
            StackBank.empty(self.n_slots, self.stack_capacity, self.w)
            if self._use_device else None)
        self._inflight_dev: _InflightDev | None = None
        # aggregate wave statistics (for occupancy / SLO reporting)
        self.waves = 0
        self.rows_packed = 0
        self.occ_sum = 0.0
        self.waves_steady = 0
        self.occ_sum_steady = 0.0
        self.total_prunes = 0
        self.total_rows_created = 0
        self.total_steals = 0
        # per-slot work accounting (megastep digest lanes + host waves)
        self.slot_rows_expanded = np.zeros(self.n_slots, np.int64)
        self.slot_children_created = np.zeros(self.n_slots, np.int64)
        # host/device time split (serving_bench trajectory)
        self.t_dispatch_s = 0.0     # pack + async dispatch (host)
        self.t_sync_s = 0.0         # blocked materializing digests
        self.t_host_s = 0.0         # digest processing / bookkeeping
        # host-time breakdown (disjoint buckets inside the above):
        # admission / digest fold / query retirement / pattern flush
        self.t_admit_s = 0.0
        self.t_digest_s = 0.0
        self.t_retire_s = 0.0
        self.t_flush_s = 0.0
        # ---- fault tolerance (DESIGN.md §8) ---------------------------
        # every hook below is gated on its knob (or ``_faults is None``)
        # so the disabled path costs one attribute load per boundary
        self.dispatch_timeout_s = opts.dispatch_timeout_s
        self.dispatch_retries = int(opts.dispatch_retries)
        self.retry_backoff_s = float(opts.retry_backoff_s)
        self.validate_digests = bool(opts.validate_digests)
        self.fallback_on_failure = bool(opts.fallback_on_failure)
        self.max_query_failures = int(opts.max_query_failures)
        self.shed_policy = opts.shed_policy
        self._faults = opts.faults          # core.faults.FaultPlan | None
        self.fault_counters = {
            "dispatch_retries": 0, "hangs": 0, "digest_failures": 0,
            "quarantined": 0, "fallbacks": 0, "errors": 0,
            "flush_drops": 0, "shed": 0, "admission_failures": 0}

    # ------------------------------------------------------------------
    # submission / admission
    # ------------------------------------------------------------------
    def submit(self, query: Graph, *,
               options: MatchOptions | None = None,
               cand: list[np.ndarray] | None = None,
               order: np.ndarray | None = None,
               on_embeddings=None, **overrides) -> int:
        """Enqueue a query; returns its scheduler query id.

        Per-query knobs (``limit``, ``time_budget_s``,
        ``max_recursions``/``max_rows``, ``use_pruning``,
        ``seed_patterns``, ``keep_table``, ``parallelism``,
        ``priority``) resolve through :class:`repro.api.MatchOptions`
        with this scheduler's ``options`` as the defaults — pass a full
        ``options`` object or keyword overrides.

        Raises :class:`QueueFull` when the bounded admission queue is at
        capacity — callers apply backpressure or shed load.

        ``parallelism``: intra-query shard count (shard-as-segments,
        DESIGN.md §3). The root-candidate range is split into that many
        root segments with per-shard DFS stacks and work stealing; all
        shards share the query's slot-private Δ table, so every pattern
        (μ > 0 included) one shard learns prunes the others.

        ``priority``: admission order from the bounded queue — higher
        admitted first, FIFO within a tie.

        ``on_embeddings``: streamed-delivery sink, called with each
        newly found ``[k, n_query]`` int32 batch as the emitting wave's
        digest is processed (not at retirement) — the plumbing behind
        ``MatchHandle.stream()``.

        ``seed_patterns``: a pattern *entries* dict (patterns.store) to
        pre-load into the query's slot, hit counters included (cross-host
        pattern import or checkpoint restore — see core.distributed).
        μ > 0 seed patterns reference the *writer's* φ numbering: they
        are only sound if the ids cannot collide with this run's fresh
        ids — call :meth:`reserve_phi_floor` with the writer's φ ceiling
        first (checkpoint restore does), otherwise seed μ == 0 patterns
        only. Queries with no explicit seed may be warm-started from the
        cross-query template cache (μ == 0 entries only — sound without
        a floor).
        """
        opts = MatchOptions.resolve(
            options if options is not None else self.options, **overrides)
        if (len(self.queue) >= self.max_queue
                and self.shed_policy != "shed_lowest"):
            raise QueueFull(
                f"admission queue at capacity ({self.max_queue})")
        if query.n > N_PAD:
            raise ValueError(f"query too large for mask width: {query.n}")
        t_submit = time.perf_counter()
        qid = self._next_qid
        self._next_qid += 1
        cand_by_pos, order, _pos_of, nbr_pos = _prepare(
            query, self.data, cand, order)
        n = query.n
        v = self.data.n
        cand_dense = np.zeros((N_PAD, v), bool)
        for d in range(n):
            cand_dense[d, cand_by_pos[d]] = True
        nbr_mask = np.zeros((N_PAD, N_PAD), bool)
        qnbr_bits = np.zeros(N_PAD, np.uint64)
        for d in range(n):
            bits = np.uint64(0)
            for p in nbr_pos[d]:
                nbr_mask[d, int(p)] = True
                bits |= bit_of(int(p))
            qnbr_bits[d] = bits
        learn = (self.use_pruning if opts.use_pruning is None
                 else opts.use_pruning)
        cand_packed = pack_bitmap(cand_dense)
        req = _Request(
            query_id=qid, n=n, order=np.asarray(order, np.int32),
            roots=np.asarray(cand_by_pos[0], np.int32),
            cand_bitmap=cand_packed, nbr_mask=nbr_mask,
            qnbr_bits=qnbr_bits, limit=opts.limit, learn=learn,
            max_rows=opts.max_recursions,
            time_budget_s=opts.time_budget_s,
            seed_patterns=opts.seed_patterns, keep_table=opts.keep_table,
            t_submit=t_submit, fingerprint=None,
            parallelism=max(1, int(opts.parallelism)),
            priority=int(opts.priority), on_embeddings=on_embeddings)
        # trivial queries never need a slot (and never touch the cache)
        if len(req.roots) == 0 or n == 1:
            self._finish_trivial(req)
        else:
            # the fingerprint digests the packed candidate bitmap — not
            # free at web-scale V, so only queries that can actually
            # consume the cache (learning, cache enabled) pay for it
            if self.pattern_cache is not None and learn:
                req.fingerprint = PatternCache.fingerprint(
                    n, cand_packed, nbr_mask)
            if len(self.queue) >= self.max_queue:
                # shed_lowest overload policy: the overall lowest-
                # priority request — queued or the new arrival, newest
                # within a tie — completes immediately with
                # status="shed" instead of growing the queue (or
                # rejecting a high-priority arrival behind low traffic)
                victim = min(range(len(self.queue)),
                             key=lambda i: (self.queue[i].priority, -i))
                if req.priority <= self.queue[victim].priority:
                    self._shed_request(req)
                    return qid
                shed_req = self.queue[victim]
                del self.queue[victim]
                self._shed_request(shed_req)
            self.queue.append(req)
        return qid

    def _shed_request(self, req: _Request) -> None:
        """Finish a load-shed request: empty result, status "shed"."""
        stats = EngineStats()
        stats.aborted = True
        stats.abort_reason = "shed"
        stats.table_stats = None
        stats.wall_time_s = time.perf_counter() - req.t_submit
        self.finished[req.query_id] = MatchResult([], stats)
        self._fresh_done.append(req.query_id)
        self.fault_counters["shed"] += 1

    def _finish_trivial(self, req: _Request) -> None:
        stats = EngineStats()
        stats.table_stats = None
        embeddings: list[np.ndarray] = []
        if req.n == 1 and len(req.roots) > 0:
            stats.rows_created = len(req.roots)
            for v0 in req.roots:
                emb = np.empty(1, np.int32)
                emb[req.order[0]] = v0
                embeddings.append(emb)
            if req.limit is not None and len(embeddings) >= req.limit:
                embeddings = embeddings[:req.limit]
                stats.aborted = True
                stats.abort_reason = "limit"
            stats.found = len(embeddings)
            stats.recursions = stats.rows_created
        stats.wall_time_s = time.perf_counter() - req.t_submit
        if embeddings:
            stats.ttfe_s = stats.wall_time_s
            if req.on_embeddings is not None:
                req.on_embeddings(np.stack(embeddings).astype(np.int32))
        self.finished[req.query_id] = MatchResult(embeddings, stats)
        if req.keep_table:
            self.tables[req.query_id] = (req.seed_patterns
                                         if req.seed_patterns is not None
                                         else empty_entries())
        self._fresh_done.append(req.query_id)

    def reserve_phi_floor(self, floor: int) -> None:
        """Raise the pool's embedding-id counter to at least ``floor``.

        Makes seeding μ > 0 patterns sound: a seeded pattern fires only
        when a row's Φ[μ] equals its stored φ, and once every fresh id
        is above the writer's ceiling, a foreign φ can never collide
        with a live prefix id (it simply never matches again)."""
        self.pool.id_counter = max(self.pool.id_counter, int(floor))

    def _pop_admission(self) -> _Request:
        """Priority-aware pop from the bounded admission queue: the
        highest-priority request wins, FIFO within a tie (max over
        ``(priority, -index)``). O(queue) per admission — the queue is
        host-side and bounded by ``max_queue``."""
        best = max(range(len(self.queue)),
                   key=lambda i: (self.queue[i].priority, -i))
        req = self.queue[best]
        del self.queue[best]
        return req

    def _admit(self) -> None:
        # deferred slot installs: one fused load_slots / clear dispatch
        # for the whole admission burst instead of a per-query jit call
        # (a fresh batch of k queries used to pay k host dispatches of
        # ~0.3 ms each before the first wave could launch)
        loads: list[tuple] = []
        dev_clears: list[int] = []
        while self.queue:
            slot = self.pool.free_slot()
            if slot is None:
                break
            req = self._pop_admission()
            if self._faults is not None and self._faults.poke(
                    "admission", query_id=req.query_id) is not None:
                self.fault_counters["admission_failures"] += 1
                self._fail_request(req, "injected admission fault")
                continue
            learn = req.learn and self.pool.learning_enabled
            # Δ seed priority: explicit entries (restore / cross-host
            # import) > template-cache warm start (μ == 0 only, sound
            # without a φ floor) > empty store. Warm starts are gated on
            # ``learn`` so the no-pruning ablation stays pattern-free.
            entries = req.seed_patterns
            warm = False
            if entries is None and req.learn \
                    and self.pattern_cache is not None:
                pend = self._pending_snaps.pop(req.fingerprint, None)
                if pend is not None:
                    # the template recurred: materialize the deferred
                    # snapshot into its cache line now
                    snap_store, snap_hits = pend
                    self.pattern_cache.put(
                        req.fingerprint,
                        store_to_entries(snap_store, snap_hits))
                entries = self.pattern_cache.get(req.fingerprint)
                warm = entries is not None
            if entries is not None and len(entries["pos"]) > 0:
                store = entries_to_store(entries, self.pattern_capacity)
            else:
                store = self._empty_store
            loads.append((slot, req.cand_bitmap, req.nbr_mask,
                          req.n, store, learn))
            now = time.perf_counter()
            deadline = (None if req.time_budget_s is None
                        else now + req.time_budget_s)
            q = QueryState(slot, req.query_id, req.n, req.order,
                           req.qnbr_bits, self.w, limit=req.limit,
                           learn=learn, max_rows=req.max_rows,
                           deadline=deadline, keep_table=req.keep_table,
                           t_submit=req.t_submit,
                           parallelism=req.parallelism)
            q.fingerprint = req.fingerprint
            q.emb_sink = req.on_embeddings
            # stash the request so a quarantined query can be replayed
            # on the fallback path (DESIGN.md §8)
            q.request = req
            q.fail_count = req.fail_count
            q.force_single = req.host_only
            if req.prior_embeddings:
                # degraded-mode replay: carry the embeddings found
                # before demotion; the replay deduplicates against
                # ``emb_seen`` so re-enumeration cannot double-count
                q.embeddings.extend(req.prior_embeddings)
                q.emb_delivered = len(req.prior_embeddings)  # streamed
                q.stats.found = len(req.prior_embeddings)
                q.stats.ttfe_s = req.prior_ttfe
            if req.host_only:
                q.emb_seen = req.emb_seen if req.emb_seen is not None \
                    else set()
                q.stats.rows_created += req.prior_rows
                q.stats.fallback = True
            q.stats.table_stats = DeadEndStats(
                capacity=self.pattern_capacity)
            if warm:
                q.stats.cache_hit = True
                q.stats.warm_patterns = len(entries["pos"])
                self.warm_started += 1
                self.warm_patterns_seeded += len(entries["pos"])
            if req.keep_table:
                q.hit_counts = {}
                if entries is not None:
                    for p, v, h in zip(entries["pos"].tolist(),
                                       entries["v"].tolist(),
                                       entries["hits"].tolist()):
                        q.hit_counts[(int(p), int(v))] = int(h)
            r = len(req.roots)
            q.stats.rows_created += r
            if (self._use_device and q.parallelism == 1
                    and not req.keep_table and not req.host_only):
                # device-resident stack path: no host segments — roots
                # trickle onto the device stack as it has headroom (the
                # cursor advances by the digest's per-slot accept count)
                q.device = True
                q.pending_roots = req.roots
                q.root_cursor = 0
                q.dev_roots_inflight = False
                q.dev_wedge = 0
                q.dev_sig = None
                if self.sb is None:
                    self.sb = StackBank.empty(
                        self.n_slots, self.stack_capacity, self.w)
                else:
                    dev_clears.append(slot)
            else:
                self._admit_host_roots(q, req.roots)
            self.pool.attach(slot, q)
        self._flush_slot_loads(loads, dev_clears)

    def _flush_slot_loads(self, loads: list[tuple],
                          dev_clears: list[int]) -> None:
        """Install an admission burst's bank rows in O(1) dispatches.

        Bursts are padded to the next power of two (pad rows carry slot
        index ``n_slots`` and are dropped by the scatter) so the number
        of distinct compiled shapes stays ``log2(n_slots) + 1`` per
        function instead of one compilation — and one dispatch — per
        admitted query."""
        if dev_clears:
            if len(dev_clears) == 1:
                self.sb = clear_slot_stack(self.sb,
                                           np.int32(dev_clears[0]))
            else:
                k = 1 << (len(dev_clears) - 1).bit_length()
                slots = np.full((k,), self.n_slots, np.int32)
                slots[:len(dev_clears)] = dev_clears
                self.sb = clear_slot_stacks(self.sb, slots)
        if not loads:
            return
        if len(loads) == 1:
            slot, cb, nm, n, store, learn = loads[0]
            self.qb, self.tb = load_slot(
                self.qb, self.tb, np.int32(slot), cb, nm,
                np.int32(n), store, learn)
            return
        k = 1 << (len(loads) - 1).bit_length()
        rows = loads + [loads[-1]] * (k - len(loads))
        slots = np.full((k,), self.n_slots, np.int32)
        slots[:len(loads)] = [r[0] for r in loads]
        if all(r[4] is self._empty_store for r in rows):
            store = self._empty_store_stacks.get(k)
            if store is None:
                store = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (k,) + x.shape),
                    self._empty_store)
                self._empty_store_stacks[k] = store
        else:
            store = jax.tree.map(lambda *xs: jnp.stack(xs),
                                 *[r[4] for r in rows])
        self.qb, self.tb = load_slots(
            self.qb, self.tb, slots,
            np.stack([np.asarray(r[1]) for r in rows]),
            np.stack([np.asarray(r[2]) for r in rows]),
            np.array([r[3] for r in rows], np.int32), store,
            np.array([r[5] for r in rows], bool))

    def _admit_host_roots(self, q: QueryState, all_roots: np.ndarray
                          ) -> None:
        """Seed host root segments (SegmentPool path). Shard-as-segments:
        one root segment per contiguous slice of the root-candidate range
        (``parallelism == 1`` keeps the single root segment of the
        classic schedule)."""
        r = len(all_roots)
        bounds = np.linspace(0, r, q.parallelism + 1).astype(int)
        for shard in range(q.parallelism):
            lo, hi = int(bounds[shard]), int(bounds[shard + 1])
            if hi <= lo:
                continue
            roots = all_roots[lo:hi]
            k = hi - lo
            frontier = np.full((k, N_PAD), -1, np.int32)
            frontier[:, 0] = roots
            used = np.zeros((k, self.w), np.uint32)
            used[np.arange(k), roots // 32] = (
                np.uint32(1) << (roots.astype(np.uint32)
                                 % np.uint32(32)))
            phi = np.zeros((k, N_PAD + 1), np.int32)
            base = self.pool.alloc_ids(k)
            phi[:, 1] = np.arange(base, base + k)
            root_seg = q.new_segment(1, frontier, used, phi,
                                     np.full(k, -1, np.int32),
                                     np.zeros(k, np.int32),
                                     shard=shard)
            q.push(WorkItem(root_seg.seg_id, 0, k, "fresh", shard))

    # ------------------------------------------------------------------
    # streamed-embedding delivery
    # ------------------------------------------------------------------
    def _deliver(self, q: QueryState) -> None:
        """Push embeddings found since the last delivery to the query's
        stream sink (and stamp TTFE on the first batch). Called from
        the digest-processing paths — ``_retire_mega`` and
        ``_process_wave`` — so consumers see embeddings while the query
        is still running, and once more from ``_finish`` as a flush."""
        n = len(q.embeddings)
        if n == q.emb_delivered:
            return
        if q.stats.ttfe_s is None:
            q.stats.ttfe_s = time.perf_counter() - q.t_submit
        if q.emb_sink is not None:
            batch = np.stack(q.embeddings[q.emb_delivered:]).astype(
                np.int32)
            q.emb_sink(batch)
        q.emb_delivered = n

    # ------------------------------------------------------------------
    # completion / abort / cancellation
    # ------------------------------------------------------------------
    def _finish(self, q: QueryState) -> None:
        t0 = time.perf_counter()
        f0 = self.t_flush_s
        self._deliver(q)
        q.materialize_hits()
        want_cache = (self.pattern_cache is not None and q.learn
                      and q.fingerprint is not None)
        if (q.keep_table or want_cache) and q.store_buf:
            # make patterns from the final resolutions visible in the
            # snapshot (distributed sharing / template cache)
            self._flush_stores(force=True)
        # materialize AFTER the final flush: the retiring query's last
        # insert counters must fold while it still owns its slot
        self._materialize_flush_counters()
        q.status = "done"
        q.evict()
        q.stats.recursions = q.stats.rows_created
        q.stats.wall_time_s = time.perf_counter() - q.t_submit
        if q.parallelism > 1:
            q.stats.shard_rows = q.shard_rows.tolist()
            q.stats.shard_items = q.shard_items.tolist()
        self.total_prunes += q.stats.deadend_prunes
        self.total_rows_created += q.stats.rows_created
        self.total_steals += q.stats.steals
        ts = q.stats.table_stats
        if isinstance(ts, DeadEndStats):
            # hits = Δ prunes; lookups stays 0 on the engine path
            # (see DeadEndStats — the digest has no lookup count)
            ts.hits = q.stats.deadend_prunes
        if q.keep_table:
            entries = store_to_entries(read_store_slot(self.tb, q.slot),
                                       q.hit_counts)
            if isinstance(ts, DeadEndStats):
                ts.occupancy = len(entries["pos"])
            self.tables[q.query_id] = entries
            if want_cache:
                # already materialized for the table export — fold the
                # retiring learner's hot transferable patterns into the
                # template's cache line right away
                self.pattern_cache.put(q.fingerprint, entries)
        elif want_cache:
            # defer: capture the slot store as async device slices (no
            # pipeline stall here) — materialized into a cache line
            # only if the same template is admitted again
            snap = read_store_slot(self.tb, q.slot)
            hits = dict(q.hit_counts) if q.hit_counts is not None else None
            prev = self._pending_snaps.pop(q.fingerprint, None)
            if prev is not None:
                # same template already has a pending snapshot (e.g. a
                # richer earlier run): fold it into the cache line —
                # put() merges by key — instead of discarding it
                self.pattern_cache.put(q.fingerprint,
                                       store_to_entries(*prev))
            self._pending_snaps[q.fingerprint] = (snap, hits)
            # tight bound: each pending snapshot pins a full-capacity
            # slice set on device (unlike the top_k-capped cache lines),
            # so size to the slot count, not to max_templates. An
            # LRU-evicted snapshot is materialized into its (compact)
            # cache line rather than discarded — otherwise interleaved
            # traffic over more templates than the pending bound would
            # never populate the cache at all.
            while len(self._pending_snaps) > max(8, 2 * self.n_slots):
                old_fp, (old_snap, old_hits) = \
                    self._pending_snaps.popitem(last=False)
                self.pattern_cache.put(
                    old_fp, store_to_entries(old_snap, old_hits))
        self.finished[q.query_id] = MatchResult(q.embeddings, q.stats)
        self._fresh_done.append(q.query_id)
        if getattr(q, "device", False) and self.sb is not None:
            # release the slot's device stack; the clear chains in
            # program order after any in-flight dispatch (the handle is
            # that dispatch's output), so live entries cannot revive
            self.sb = clear_slot_stack(self.sb, np.int32(q.slot))
        self.pool.release(q.slot)
        self.t_retire_s += (time.perf_counter() - t0
                            - (self.t_flush_s - f0))

    def _abort(self, q: QueryState, reason: str) -> None:
        """Abort a query (budget exhausted or limit reached) and evict
        its segments; partial embeddings are kept. Rows of the query
        still in flight on the device are dropped at digest time."""
        q.stats.aborted = True
        q.stats.abort_reason = reason
        q.abort_reason = reason
        self._finish(q)

    def cancel(self, qid: int) -> bool:
        """Cancel a submitted query. A queued request is removed before
        it ever takes a slot; a resident query rides the existing
        abort/eviction path — its in-flight device rows are dropped at
        digest time and neighbors sharing its waves are untouched.
        Partial embeddings are kept (``abort_reason == "cancelled"``).
        Returns False when the query already finished."""
        if qid in self.finished:
            return False
        for i, req in enumerate(self.queue):
            if req.query_id == qid:
                del self.queue[i]
                stats = EngineStats()
                stats.aborted = True
                stats.abort_reason = "cancelled"
                stats.table_stats = None
                stats.wall_time_s = time.perf_counter() - req.t_submit
                self.finished[qid] = MatchResult([], stats)
                self._fresh_done.append(qid)
                return True
        for q in self.pool.active_queries():
            if q.query_id == qid:
                self._abort(q, "cancelled")
                return True
        return False

    # ------------------------------------------------------------------
    # fault tolerance: retry, quarantine, degraded-mode fallback
    # (DESIGN.md §8)
    # ------------------------------------------------------------------
    def _fail_request(self, req: _Request, msg: str) -> None:
        """Finish a request that failed before (or at) admission with
        ``status="error"``; any embeddings carried from a prior
        incarnation are kept."""
        stats = EngineStats()
        stats.aborted = True
        stats.abort_reason = "error"
        stats.fault = msg
        stats.table_stats = None
        stats.found = len(req.prior_embeddings or ())
        stats.wall_time_s = time.perf_counter() - req.t_submit
        self.finished[req.query_id] = MatchResult(
            list(req.prior_embeddings or ()), stats)
        self._fresh_done.append(req.query_id)
        self.fault_counters["errors"] += 1

    def _run_dispatch(self, call, queries: list, stacks: bool):
        """Run one device dispatch with bounded retry + exponential
        backoff. Returns ``(result, hung)``; ``result is None`` means
        the retry budget is exhausted — the involved ``queries`` have
        been quarantined and the device banks rebuilt (``stacks=True``
        additionally rebuilds the frontier StackBank). An injected hang
        runs the dispatch but flags its digest untrusted for the
        retire-side watchdog."""
        attempt = 0
        while True:
            hung = False
            try:
                if self._faults is not None:
                    spec = self._faults.poke("dispatch")
                    if spec is not None:
                        if spec.kind == "hang":
                            self.fault_counters["hangs"] += 1
                            hung = True
                        else:
                            raise FaultInjected(
                                "injected dispatch exception")
                return call(), hung
            except DISPATCH_ERRORS as exc:
                attempt += 1
                if attempt > self.dispatch_retries:
                    self._dispatch_failed(queries, exc, stacks)
                    return None, False
                self.fault_counters["dispatch_retries"] += 1
                time.sleep(self.retry_backoff_s * (2 ** (attempt - 1)))

    def _dispatch_failed(self, queries: list, exc: BaseException,
                         stacks: bool) -> None:
        msg = (f"dispatch failed after {self.dispatch_retries + 1} "
               f"attempts: {exc}")
        self._invalidate_device_state(stacks)
        for q in list(queries):
            if q.active:
                self._quarantine(q, msg)

    def _invalidate_device_state(self, stacks: bool) -> None:
        """Rebuild the device banks after a hang / failed dispatch /
        globally-invalid digest. Always sound: Δ patterns only ever
        prune (losing them costs pruning, never correctness) and every
        query whose frontier stack lived in the bank is quarantined by
        the caller before the rebuild, so no live state is dropped."""
        self.tb = PatternStoreBank.empty(self.n_slots,
                                         self.pattern_capacity)
        self._flush_ctr_dev = None
        self._pending_snaps.clear()
        if stacks and self._use_device:
            self.sb = StackBank.empty(self.n_slots, self.stack_capacity,
                                      self.w)

    def _quarantine(self, q: QueryState, reason: str) -> None:
        """Quarantine state machine: resident → quarantined →
        fallback re-admission on the host/single-step path, or — past
        the per-query failure budget (or with fallback disabled) —
        errored through the existing abort/eviction path."""
        self.fault_counters["quarantined"] += 1
        q.fail_count += 1
        req = q.request
        if (self.fallback_on_failure and req is not None
                and q.fail_count <= self.max_query_failures):
            self.fault_counters["fallbacks"] += 1
            self._demote_to_host(q, req, reason)
        else:
            self.fault_counters["errors"] += 1
            q.stats.fault = reason
            self._abort(q, "error")

    def _demote_to_host(self, q: QueryState, req: _Request,
                        reason: str) -> None:
        """Tear the query down *without* publishing a result and
        re-enqueue its original request on the host single-step
        fallback path (``host_only``: no device stack, one item per
        wave). Embeddings found so far ride along and the replay
        deduplicates against them, so the final set is exact; neighbors
        are untouched — their rows never leave their own slots."""
        seen = set()
        prior = []
        for e in q.embeddings:
            b = np.asarray(e, np.int32)
            key = b.tobytes()
            if key not in seen:
                seen.add(key)
                prior.append(b)
        req2 = dataclasses.replace(
            req, host_only=True, fail_count=q.fail_count,
            prior_embeddings=prior, emb_seen=seen,
            prior_rows=q.stats.rows_created, prior_ttfe=q.stats.ttfe_s,
            seed_patterns=None, on_embeddings=q.emb_sink)
        q.status = "quarantined"    # in-flight digests for this slot drop
        q.evict()
        if q.device and self.sb is not None:
            self.sb = clear_slot_stack(self.sb, np.int32(q.slot))
        self.pool.release(q.slot)
        # internal re-admission: jumps the max_queue bound (the query
        # already held a slot) and front-runs its priority tie
        self.queue.appendleft(req2)

    def _validate_device_digest(self, dig: dict, n_emb: int,
                                embS: np.ndarray, embF: np.ndarray,
                                slot_map: dict) -> tuple[dict, bool]:
        """Check every invariant a sound digest must satisfy (see
        DESIGN.md §8 for why each is implied by Lemma 1/4 soundness).
        Returns ``(bad, global_bad)`` — ``bad`` maps a failing slot to
        the violated invariant; ``global_bad`` flags corruption that
        cannot be blamed on one slot (the whole digest is dropped)."""
        cap = self.stack_capacity
        v = self.data.n
        if n_emb < 0 or n_emb > self._emb_cap:
            return {}, True
        if n_emb and ((embS < 0) | (embS >= self.n_slots)).any():
            return {}, True
        bad: dict[int, str] = {}
        for slot, q in slot_map.items():
            if not q.active or not q.device:
                continue
            pend, live = int(dig["d_pending"][slot]), \
                int(dig["d_live"][slot])
            if not (0 <= pend <= live <= cap):
                bad[slot] = (f"stack occupancy out of bounds: "
                             f"pending={pend} live={live} capacity={cap}")
                continue
            neg = [k for k in ("d_accepted", "d_expanded", "d_rows",
                               "d_prunes", "d_inj", "d_stored")
                   if int(dig[k][slot]) < 0]
            if neg:
                bad[slot] = f"negative counter lane {neg[0]}"
                continue
            if int(dig["d_outsum"][slot]) != int(dig["d_childlive"][slot]):
                bad[slot] = (
                    "Lemma-4 outstanding-counter conservation violated: "
                    f"sum(outstanding)={int(dig['d_outsum'][slot])} != "
                    f"live children={int(dig['d_childlive'][slot])}")
                continue
            if n_emb:
                rows = embF[embS == slot][:, :q.n]
                if len(rows) and ((rows < 0) | (rows >= v)).any():
                    bad[slot] = "embedding row vertex out of range"
        return bad, False

    def _fold_embeddings(self, q: QueryState, rows: np.ndarray
                         ) -> np.ndarray:
        """Fold a ``[k, >= q.n]`` batch of found embedding rows into the
        query: permute to query-vertex order, deduplicate against a
        fallback replay's carried set, apply the limit, stream. Returns
        a bool mask marking rows that must count as *reported* (they
        produced a valid embedding — duplicates included, so Lemma-1/4
        resolution can never learn a failure pattern from a successful
        row). Rows clipped by the limit stay unmarked: the caller
        aborts on the limit immediately after, so they are never
        resolved as failures."""
        k = len(rows)
        out = np.empty((k, q.n), np.int32)
        out[:, q.order[:q.n]] = rows[:, :q.n]
        if q.emb_seen is None:
            accept = np.ones(k, bool)
        else:
            accept = np.fromiter(
                (r.tobytes() not in q.emb_seen for r in out),
                bool, count=k)
        take = int(accept.sum())
        if q.limit is not None:
            take = min(take, q.limit - q.stats.found)
        report = np.ones(k, bool)
        idx = np.nonzero(accept)[0]
        report[idx[max(0, take):]] = False
        if take > 0:
            idx = idx[:take]
            if q.emb_seen is not None:
                for i in idx:
                    q.emb_seen.add(out[i].tobytes())
            q.embeddings.extend(out[idx])
            q.stats.found += take
            self._deliver(q)           # stream before retirement
        return report

    def _reset_learning_on_overflow(self) -> None:
        """Embedding-id overflow: clear all stores and pause learning
        (sound — only pruning is lost); the pool re-enables learning
        once it drains. Shared by both schedule paths."""
        if self.pool.id_overflow and self.pool.learning_enabled:
            self.tb = PatternStoreBank.empty(self.n_slots,
                                             self.pattern_capacity)
            self.pool.learning_enabled = False
            for qq in self.pool.active_queries():
                qq.learn = False

    def _check_budgets(self, now: float | None = None) -> None:
        for q in self.pool.active_queries():
            if q.deadline is not None:
                if now is None:
                    now = time.perf_counter()
                if now > q.deadline:
                    self._abort(q, "time")
                    continue
            if q.max_rows is not None and q.stats.rows_created > q.max_rows:
                self._abort(q, "rows")

    # ------------------------------------------------------------------
    # wave packing
    # ------------------------------------------------------------------
    def _pack_wave(self
                   ) -> list[tuple[QueryState, Segment, int, int, int]] | None:
        """Fill one wave with ready rows, round-robin across queries.

        All picks share one kind ("fresh" or "leftover") because the two
        run different device programs; a query whose ready items are all
        of the other kind simply waits for a later wave.

        Occupancy-aware packing: the classic one-work-item-per-query
        round-robin is the *fair-share floor*, not a ceiling. On the
        fused megastep schedule a query may contribute up to
        ``max(1, wave_size / n_active)`` items per wave, so a lone heavy
        query fills the wave instead of idling rows. The synchronous
        single-step schedule (``megastep_depth == 1`` or the prune-EMA
        fallback) keeps the strict one-item cadence — in failure-heavy
        regimes patterns learned from one slice must prune the next
        slice of the same query, which multi-item packing would defeat.

        Within a query, items are drawn round-robin across its shard
        stacks (shard-as-segments), after rebalancing idle shards via
        work stealing. Returns [(query, segment, start, stop, shard)] or
        None when no work exists.
        """
        active = self.pool.active_queries()
        if not active:
            return None
        for q in active:
            if q.parallelism > 1:
                q.balance_shards()
        start = self._rr % len(active)
        order = active[start:] + active[:start]
        self._rr += 1
        if (self.megastep_depth <= 1
                or self._prune_ema > self.adaptive_prune_threshold):
            item_cap = 1
        else:
            item_cap = max(1, self.wave_size // len(active))
        kind = None
        picks: list[tuple[QueryState, Segment, int, int, int]] = []
        remaining = self.wave_size
        taken = dict.fromkeys(range(len(order)), 0)
        progress = True
        while remaining > 0 and progress:
            progress = False
            for qi, q in enumerate(order):
                if remaining == 0:
                    break
                # fallback queries keep the strict single-item cadence
                # regardless of the engine-wide packing mode
                if taken[qi] >= (1 if q.force_single else item_cap):
                    continue
                if kind is None:
                    kind = q.peek_kind()
                    if kind is None:
                        continue
                item = q.pop_ready(kind)
                if item is None:
                    taken[qi] = item_cap     # nothing of this kind now
                    continue
                take = min(remaining, item.stop - item.start)
                if take < item.stop - item.start:
                    q.push(WorkItem(item.seg_id, item.start + take,
                                    item.stop, item.kind, item.shard))
                picks.append((q, q.segments[item.seg_id], item.start,
                              item.start + take, item.shard))
                remaining -= take
                taken[qi] += 1
                progress = True
        if not picks:
            return None
        self._wave_kind = kind
        return picks

    def _build_wave(self, picks: list, kind: str):
        """Pack picked segment slices into fixed-shape wave arrays."""
        f_pad = self.wave_size
        fr = np.full((f_pad, N_PAD), -1, np.int32)
        us = np.zeros((f_pad, self.w), np.uint32)
        ph = np.zeros((f_pad, N_PAD + 1), np.int32)
        lo = np.zeros((f_pad, self.w), np.uint32)
        valid = np.zeros(f_pad, bool)
        slot_v = np.zeros(f_pad, np.int32)
        depth_v = np.zeros(f_pad, np.int32)
        metas: list[tuple[QueryState, Segment, int, int, int, int, int]] = []
        off = 0
        for q, seg, s, e, shard in picks:
            k = e - s
            fr[off:off + k] = seg.frontier[s:e]
            us[off:off + k] = seg.used[s:e]
            ph[off:off + k] = seg.phi[s:e]
            valid[off:off + k] = ~seg.resolved[s:e]
            slot_v[off:off + k] = q.slot
            depth_v[off:off + k] = seg.depth
            if kind == "leftover":
                lo[off:off + k] = seg.pending_leftover[s:e]
            metas.append((q, seg, s, e, off, k, shard))
            off += k
        self.waves += 1
        self.rows_packed += off
        occ = off / f_pad
        self.occ_sum += occ
        if self.pool.n_active == self.n_slots:
            self.waves_steady += 1
            self.occ_sum_steady += occ
        return fr, us, ph, lo, valid, slot_v, depth_v, metas

    def _note_prunes(self, prunes: int, rows: int) -> None:
        """Feed one wave's prune/row counts into the adaptive-depth EMA
        (decay 0.5: ~5 easy waves flip a cold scheduler to deep mode, a
        single prune-heavy wave flips it back)."""
        rate = prunes / max(1, prunes + rows)
        self._prune_ema = 0.5 * self._prune_ema + 0.5 * rate

    # ------------------------------------------------------------------
    # pattern store flushing
    # ------------------------------------------------------------------
    def _pending_stores(self) -> list[tuple[QueryState, list]]:
        return [(q, q.store_buf) for q in self.pool.active_queries()
                if q.store_buf]

    @staticmethod
    def _drain_dedup(bufs: list, max_take: int | None) -> dict:
        """Drain up to ``max_take`` queued (key_pos, key_v, φ, μ, Γ)
        tuples from per-query buffers, deduplicated by (slot, key): the
        device insert is last-write-wins per key anyway, and one wave of
        a failure-heavy query queues the same key many times — host
        dedup shrinks the device batch ~4x on the trap workload.
        Consumed entries are removed from the buffers."""
        dedup: dict = {}
        i = 0
        for q, buf in bufs:
            take = (len(buf) if max_take is None
                    else min(len(buf), max_take - i))
            for key_pos, key_v, phi_id, mu_len, gamma in buf[:take]:
                dedup[(q.slot, key_pos, key_v)] = (phi_id, mu_len, gamma)
            i += take
            del buf[:take]
            if max_take is not None and i == max_take:
                break
        return dedup

    @staticmethod
    def _pack_store_batch(dedup: dict, n_pad: int):
        """Pack deduplicated entries into padded insert arrays (the
        validity lane marks padding; the device insert drops invalid
        rows)."""
        slots = np.zeros(n_pad, np.int32)
        kpos = np.zeros(n_pad, np.int32)
        kv = np.zeros(n_pad, np.int32)
        phis = np.zeros(n_pad, np.int32)
        mus = np.zeros(n_pad, np.int32)
        masks = np.zeros(n_pad, np.uint64)
        valid = np.zeros(n_pad, bool)
        for i, ((slot, key_pos, key_v), (phi_id, mu_len, gamma)) \
                in enumerate(dedup.items()):
            slots[i] = slot
            kpos[i] = key_pos
            kv[i] = key_v
            phis[i] = phi_id
            mus[i] = mu_len
            masks[i] = gamma
            valid[i] = True
        return slots, kpos, kv, phis, mus, words_from64(masks), valid

    def _fold_store_counters(self, counters, slot_map: dict | None) -> None:
        """Fold per-slot device insert counters (int32 [S] lanes) into
        the scheduler totals and the owning queries' DeadEndStats."""
        lanes = {"stored": np.asarray(counters[0], np.int64),
                 "overwrites": np.asarray(counters[1], np.int64),
                 "evictions": np.asarray(counters[2], np.int64),
                 "dropped": np.asarray(counters[3], np.int64)}
        for k, v in lanes.items():
            self.store_counters[k] += int(v.sum())
        if slot_map is None:
            slot_map = {q.slot: q for q in self.pool.active_queries()}
        for slot, q in slot_map.items():
            ts = q.stats.table_stats
            if not isinstance(ts, DeadEndStats):
                continue
            ts.stores += int(lanes["stored"][slot])
            ts.overwrites += int(lanes["overwrites"][slot])
            ts.evictions += int(lanes["evictions"][slot])
            ts.dropped += int(lanes["dropped"][slot])

    def _flush_stores(self, force: bool = False) -> None:
        """Standalone batched Δ insert (single-step path and forced
        flushes). Skips the dispatch entirely when nothing is pending,
        and below ``store_flush_min`` unless forced; arrays are padded
        to power-of-two buckets so the jitted insert compiles O(log)
        variants instead of one per distinct batch length."""
        bufs = self._pending_stores()
        if not bufs:
            return
        t0 = time.perf_counter()
        if not self.pool.learning_enabled:
            for q, buf in bufs:
                buf.clear()
            self.t_flush_s += time.perf_counter() - t0
            return
        total = sum(len(buf) for _, buf in bufs)
        if not force and total < self.store_flush_min:
            self.t_flush_s += time.perf_counter() - t0
            return
        dedup = self._drain_dedup(bufs, None)
        if self._faults is not None and dedup and self._faults.poke(
                "flush", n=len(dedup)) is not None:
            # injected flush failure: drop the batch — sound, patterns
            # only ever prune
            self.fault_counters["flush_drops"] += 1
            self.t_flush_s += time.perf_counter() - t0
            return
        n_pad = 16
        while n_pad < len(dedup):
            n_pad *= 2
        self.tb, counters = store_patterns_mq(
            self.tb, *self._pack_store_batch(dedup, n_pad))
        self._flush_ctr_dev = (counters if self._flush_ctr_dev is None
                               else self._flush_ctr_dev.add(counters))
        self.t_flush_s += time.perf_counter() - t0

    def _materialize_flush_counters(self) -> None:
        """Fold the accumulated flush counters into stats. Correct
        per-query attribution holds because this runs at every
        ownership-change point (each query finish), so between two folds
        every slot has a single owner."""
        if self._flush_ctr_dev is None:
            return
        ctr, self._flush_ctr_dev = self._flush_ctr_dev, None
        self._fold_store_counters(ctr, None)

    def _drain_store_batch(self):
        """Drain up to ``store_pad`` host-queued pattern stores into the
        fixed-length arrays that ride the next megastep dispatch.
        Leftover entries stay queued for the next wave."""
        t0 = time.perf_counter()
        bufs = self._pending_stores()
        if not self.pool.learning_enabled:
            for q, buf in bufs:
                buf.clear()
            bufs = []
        dedup = self._drain_dedup(bufs, self.store_pad)
        if self._faults is not None and dedup and self._faults.poke(
                "flush", n=len(dedup)) is not None:
            # injected flush failure: drop the pattern batch (sound)
            self.fault_counters["flush_drops"] += 1
            dedup = {}
        out = self._pack_store_batch(dedup, self.store_pad)
        self.t_flush_s += time.perf_counter() - t0
        return out

    # ------------------------------------------------------------------
    # one scheduling step (double-buffered pipeline)
    # ------------------------------------------------------------------
    def step(self) -> bool:
        """Admit, pack, and execute one wave. Returns False when idle.

        With ``megastep_depth > 1`` the wave is dispatched as a fused
        megastep and the *previous* dispatch's digest is processed only
        after the new one is in flight — host bookkeeping overlaps
        device compute (double buffering).
        """
        self._check_budgets()
        t_a = time.perf_counter()
        self._admit()
        self.t_admit_s += time.perf_counter() - t_a
        if self.waves - self._last_aged_wave >= self.hit_decay_every:
            # age the device hit counters so eviction ranks *recent*
            # usefulness (stale hot entries decay back into candidates);
            # runs on every schedule path, single-step included
            self.tb = age_hits(self.tb)
            self._last_aged_wave = self.waves
        if self.megastep_depth <= 1:
            return self._step_single()
        ema_high = self._prune_ema > self.adaptive_prune_threshold
        # device-resident pipeline: dispatched before any host-side
        # digest processing so device compute overlaps it. Under a high
        # prune EMA the dispatch runs with t_max=1 (traced, no
        # recompile) — the paper's tight store→lookup cadence.
        retired_dev = False
        if self._inflight_dev is not None and self._device_tail():
            # tail regime (every root already on device): retire the
            # in-flight call *before* dispatching, so a pool that just
            # completed skips the speculative trailing dispatch — at
            # tail the lost dispatch/retire overlap is worth less than
            # a wasted fixed-cost device call
            sync_dev, self._inflight_dev = self._inflight_dev, None
            self._retire_device(sync_dev)
            retired_dev = True
        t0 = time.perf_counter()
        rec_dev = self._dispatch_device(
            1 if ema_high else self.megastep_depth)
        self.t_dispatch_s += time.perf_counter() - t0
        prev_dev, self._inflight_dev = self._inflight_dev, rec_dev
        if ema_high:
            # failure-heavy regime: drain the pipeline and fall back to
            # the synchronous single-step schedule so every wave sees
            # the patterns learned from the one before it.
            prev, self._inflight = self._inflight, None
            if prev is not None:
                if prev.kind == "mega":
                    self._retire_mega(prev)
                else:
                    self._retire_leftover(prev)
            progressed = self._step_single() or prev is not None
        else:
            t0 = time.perf_counter()
            picks = self._pack_wave()
            rec: _Inflight | None = None
            if picks is not None:
                if self._wave_kind == "fresh":
                    rec = self._dispatch_mega(picks)
                else:
                    rec = self._dispatch_leftover(picks)
            self.t_dispatch_s += time.perf_counter() - t0
            prev, self._inflight = self._inflight, rec
            if prev is not None:
                if prev.kind == "mega":
                    self._retire_mega(prev)
                else:
                    self._retire_leftover(prev)
            progressed = prev is not None or rec is not None
        if prev_dev is not None:
            self._retire_device(prev_dev)
        return (progressed or retired_dev or prev_dev is not None
                or rec_dev is not None)

    # ------------------------------------------------------------------
    # device-resident stack dispatch / retire (DESIGN.md §2)
    # ------------------------------------------------------------------
    def _device_tail(self) -> bool:
        """True when every device query's roots are already on device —
        there is nothing left to feed, so dispatches only continue the
        device-resident expansion/resolution."""
        if self.queue:
            return False             # queued admissions bring new roots
        devq = [q for q in self.pool.active_queries()
                if getattr(q, "device", False)]
        return bool(devq) and not any(
            len(q.pending_roots) > q.root_cursor for q in devq)

    def _dispatch_device(self, t_max: int) -> _InflightDev | None:
        """Dispatch one device-resident scheduling step: feed pending
        roots into slots with headroom and let the device repack, expand
        and resolve up to ``t_max`` waves from its per-slot stacks. The
        host never sees rows — only the per-slot scalar digest."""
        devq = [q for q in self.pool.active_queries()
                if getattr(q, "device", False)]
        if not devq or self.sb is None:
            return None
        devq.sort(key=lambda q: q.slot)      # _group_rank wants slot order
        # root intake is wider than the wave: a fresh batch's roots land
        # in one dispatch instead of trickling across several
        f = 2 * self.wave_size
        in_root = np.full(f, -1, np.int32)
        in_rid = np.zeros(f, np.int32)
        in_slot = np.zeros(f, np.int32)
        in_valid = np.zeros(f, bool)
        active = np.zeros(self.n_slots, bool)
        root_slots = []
        off = 0
        for q in devq:
            active[q.slot] = True
            if q.dev_roots_inflight:
                continue                     # previous batch unacked
            rest = len(q.pending_roots) - q.root_cursor
            if rest <= 0 or off >= f:
                continue
            k = min(rest, f - off)
            roots = q.pending_roots[q.root_cursor:q.root_cursor + k]
            base = self.pool.alloc_ids(k)
            in_root[off:off + k] = roots
            in_rid[off:off + k] = np.arange(base, base + k,
                                            dtype=np.int32)
            in_slot[off:off + k] = q.slot
            in_valid[off:off + k] = True
            q.dev_roots_inflight = True
            root_slots.append(q.slot)
            off += k
        if t_max > 1 and off == 0 and not any(
                len(q.pending_roots) > q.root_cursor for q in devq):
            # tail regime: every root is already on device, so there is
            # no admission granularity left to preserve — deepen the
            # call to amortize its fixed dispatch cost (t_max is traced,
            # so this changes no compilation)
            t_max = 2 * t_max
        # worst-case fresh-id reservation for the in-loop allocations —
        # reserving up front keeps the dispatch fully async
        id_base = self.pool.alloc_ids(t_max * f * self._mega_kpr)
        self._reset_learning_on_overflow()
        res, hung = self._run_dispatch(
            lambda: run_device_megastep(
                self.g, self.qb, self.tb, self.sb, in_root, in_rid,
                in_slot, in_valid, active, np.int32(id_base),
                bool(self.pool.learning_enabled), np.int32(t_max),
                kpr=self._mega_kpr, emb_cap=self._emb_cap,
                backend=self._kernel_backend, wave=self.wave_size,
                block_f=self._block_f, dma_depth=self._dma_depth),
            devq, stacks=True)
        if res is None:
            return None                      # retries exhausted: the
        self.tb = res.tb                     # queries were quarantined
        self.sb = res.sb                     # handles only — not
        # wave/occupancy/EMA accounting happens at retire time, where
        # the digest says whether the wave actually carried work — the
        # trailing empty dispatches that detect completion must not
        # dilute occupancy or decay the adaptive-depth EMA
        return _InflightDev(res, {q.slot: q for q in devq},
                            tuple(root_slots), t_max,
                            t_dispatch=time.perf_counter(), hung=hung)

    def _watchdog_fire(self, slot_map: dict, msg: str,
                       stacks: bool) -> None:
        """A hung or untrusted dispatch retires cleanly instead of
        blocking all slots: rebuild the device banks and quarantine
        every involved query (each restarts on the fallback path or
        errors out past its failure budget)."""
        self._invalidate_device_state(stacks)
        for q in list(slot_map.values()):
            if q.active:
                self._quarantine(q, msg)

    def _retire_device(self, rec: _InflightDev) -> None:
        """Fold one device-resident digest: per-slot scalars into query
        stats (no per-row lanes exist), the embedding batch out to the
        owning queries, then completion / budget / wedge checks."""
        if rec.hung:
            # injected hang: neither the digest nor the banks it chains
            # from are trusted — don't even materialize it
            self._watchdog_fire(rec.slot_map, "injected dispatch hang",
                                stacks=True)
            return
        res = rec.res
        t0 = time.perf_counter()
        dig = {k: np.asarray(getattr(res, k)) for k in _DEV_LANES}
        n_emb = max(0, min(int(res.n_emb), self._emb_cap))
        embF = np.asarray(res.emb_frontier)[:n_emb]
        embS = np.asarray(res.emb_slot)[:n_emb]
        t1 = time.perf_counter()
        self.t_sync_s += t1 - t0
        if (self.dispatch_timeout_s is not None
                and t1 - rec.t_dispatch > self.dispatch_timeout_s):
            # per-dispatch watchdog: the call blocked past its deadline
            # — whatever it returned is not worth trusting over a clean
            # restart of the involved queries
            self.fault_counters["hangs"] += 1
            self._watchdog_fire(
                rec.slot_map, "dispatch exceeded watchdog deadline "
                f"({self.dispatch_timeout_s:g}s)", stacks=True)
            return
        if self._faults is not None:
            slots = sorted(s for s, q in rec.slot_map.items()
                           if q.active and q.device)
            spec = (self._faults.poke("digest", slots=slots)
                    if slots else None)
            if spec is not None:
                dig = {k: np.array(v) for k, v in dig.items()}
                corrupt_digest(dig, spec,
                               stack_capacity=self.stack_capacity,
                               slots=slots)
        if self.validate_digests:
            bad, global_bad = self._validate_device_digest(
                dig, int(res.n_emb), embS, embF, rec.slot_map)
            if global_bad:
                self.fault_counters["digest_failures"] += 1
                self._watchdog_fire(rec.slot_map,
                                    "device digest globally invalid",
                                    stacks=True)
                return
            if bad:
                # quarantine each failing slot's query and zero its
                # lanes/rows so the aggregate folds below stay clean —
                # neighbors' digests (and embedding rows) are untouched
                dig = {k: (v if v.flags.writeable else v.copy())
                       for k, v in dig.items()}
                for slot, why in bad.items():
                    self.fault_counters["digest_failures"] += 1
                    q = rec.slot_map[slot]
                    for k in _DEV_LANES:
                        dig[k][slot] = 0
                    if q.active:
                        self._quarantine(
                            q, f"digest validation failed: {why}")
                if len(embS):
                    keep = ~np.isin(embS, list(bad))
                    embF, embS = embF[keep], embS[keep]
        n_emb = len(embS)
        d_accepted = dig["d_accepted"]
        d_expanded = dig["d_expanded"]
        d_rows = dig["d_rows"]
        d_prunes = dig["d_prunes"]
        d_inj = dig["d_inj"]
        d_stored = dig["d_stored"]
        d_pending = dig["d_pending"]
        d_live = dig["d_live"]
        r0, f0 = self.t_retire_s, self.t_flush_s

        self._fold_store_counters(
            (res.pat_stored, res.pat_overwrites, res.pat_evictions,
             res.pat_dropped), rec.slot_map)
        self.slot_rows_expanded += d_expanded.astype(np.int64)
        self.slot_children_created += d_rows.astype(np.int64)
        expanded_total = int(d_expanded.sum())
        worked = bool(expanded_total or n_emb or d_accepted.sum())
        if worked:
            self.rows_packed += expanded_total
            occ = min(1.0, expanded_total / (self.wave_size * rec.t_max))
            self.occ_sum += occ
            self.waves += 1
            for q in rec.slot_map.values():
                if q.active:
                    q.stats.waves += 1
            if self.pool.n_active == self.n_slots:
                self.waves_steady += 1
                self.occ_sum_steady += occ

        emb_per_slot = (np.bincount(embS, minlength=self.n_slots)
                        if n_emb else np.zeros(self.n_slots, np.int64))

        # ---- per-query scalar digest fold ------------------------------
        for slot, q in rec.slot_map.items():
            if not q.active or not getattr(q, "device", False):
                continue
            q.stats.rows_created += int(d_rows[slot])
            q.stats.deadend_prunes += int(d_prunes[slot])
            q.stats.injectivity_fails += int(d_inj[slot])
            q.stats.patterns_stored += int(d_stored[slot])
            if q.dev_roots_inflight and slot in rec.root_slots:
                q.root_cursor += int(d_accepted[slot])
                q.dev_roots_inflight = False

        # ---- embeddings found on device (+ limit aborts) ---------------
        if n_emb:
            for sl_v in np.unique(embS):
                q = rec.slot_map.get(int(sl_v))
                if q is None or not q.active:
                    continue
                self._fold_embeddings(q, embF[embS == sl_v])
                if q.limit is not None and q.stats.found >= q.limit:
                    self._abort(q, "limit")

        # ---- completion / budget / wedge checks ------------------------
        for slot, q in rec.slot_map.items():
            if not q.active or not getattr(q, "device", False):
                continue
            if (q.max_rows is not None
                    and q.stats.rows_created > q.max_rows):
                self._abort(q, "rows")
                continue
            roots_done = (q.root_cursor >= len(q.pending_roots)
                          and not q.dev_roots_inflight)
            if (roots_done and d_pending[slot] == 0
                    and d_live[slot] == 0):
                # done — any embedding batch that landed this retire was
                # already streamed above (the embedding fold runs before
                # this loop), so consumers observe delivery-then-done
                # within the same retire and no trailing empty dispatch
                # is needed to finish the query
                self._finish(q)
                continue
            # wedge detection: a full stack can throttle to a state
            # where iterations select rows but nothing allocates,
            # resolves, embeds or stores. After 3 observably identical
            # digests, export the stack back to host segments.
            moved = (int(d_accepted[slot]) or int(d_rows[slot])
                     or int(emb_per_slot[slot]) or int(d_stored[slot])
                     or int(d_prunes[slot]))
            sig = (int(d_pending[slot]), int(d_live[slot]))
            if moved or sig != q.dev_sig:
                q.dev_wedge = 0
            else:
                q.dev_wedge += 1
            q.dev_sig = sig
            if q.dev_wedge >= 3:
                self._export_device_query(q)
        if worked:
            self._note_prunes(int(d_prunes.sum()), int(d_rows.sum()))
        dt = time.perf_counter() - t1
        self.t_host_s += dt
        self.t_digest_s += max(0.0, dt - (self.t_retire_s - r0)
                               - (self.t_flush_s - f0))

    def _export_device_query(self, q: QueryState) -> None:
        """Wedge fallback: materialize one slot's device stack back into
        host segments (one 1-row segment per live entry, parent links
        preserved) and route the query through the SegmentPool path from
        here on. Rare — only when the bounded stack throttles into a
        no-progress state — and exact: entry lanes carry the identical
        Lemma-4 bookkeeping the host keeps."""
        slot = q.slot
        if self._inflight_dev is not None:
            # the in-flight dispatch's mutations are already in the
            # materialized stack (program order): ack its root batch now
            # and drop its digest for this query at retire time
            if (q.dev_roots_inflight
                    and slot in self._inflight_dev.root_slots):
                q.root_cursor += int(np.asarray(
                    self._inflight_dev.res.d_accepted)[slot])
        q.dev_roots_inflight = False
        q.device = False
        sb = self.sb
        st = np.asarray(sb.state[slot])
        frontier = np.asarray(sb.frontier[slot])
        used = np.asarray(sb.used[slot])
        phi = np.asarray(sb.phi[slot])
        depth = np.asarray(sb.depth[slot])
        cand = np.asarray(sb.cand[slot])
        gamma64 = mask64(np.asarray(sb.gamma[slot]))
        outstanding = np.asarray(sb.outstanding[slot])
        reported = np.asarray(sb.reported[slot])
        parent = np.asarray(sb.parent[slot])
        live = np.nonzero(st != STK_FREE)[0]
        seg_of: dict[int, Segment] = {}
        for e in live.tolist():
            seg = q.new_segment(
                int(depth[e]), frontier[e:e + 1].copy(),
                used[e:e + 1].copy(), phi[e:e + 1].copy(),
                np.full(1, -1, np.int32), np.zeros(1, np.int32))
            seg_of[e] = seg
        res_items: list = []
        for e in live.tolist():
            seg = seg_of[e]
            p = int(parent[e])
            if p >= 0 and p in seg_of:
                seg.parent_seg[0] = seg_of[p].seg_id
                seg.parent_row[0] = 0
            state = int(st[e])
            if state == STK_FRESH:
                q.push(WorkItem(seg.seg_id, 0, 1, "fresh", 0))
                continue
            seg.expanded[0] = True
            seg.gamma[0] = gamma64[e]
            seg.outstanding[0] = int(outstanding[e])
            seg.reported[0] = bool(reported[e])
            if state == STK_LEFT:
                seg.pending_leftover[0] = cand[e]
                q.push(WorkItem(seg.seg_id, 0, 1, "leftover", 0))
            elif state == STK_RES:
                # already finalized on device (pattern stored there)
                seg.stored[0] = True
                res_items.append((seg.seg_id, 0, bool(reported[e]),
                                  gamma64[e]))
            elif state == STK_WAIT and int(outstanding[e]) == 0:
                res_items.append(q.finalize_row(seg, 0))
        q.resolve_rows(res_items)
        rest = q.pending_roots[q.root_cursor:]
        if len(rest):
            self._admit_host_roots(q, rest)
            q.stats.rows_created -= len(rest)   # counted at admission
        q.root_cursor = len(q.pending_roots)
        self.sb = clear_slot_stack(self.sb, np.int32(slot))
        if not q.segments:
            self._finish(q)

    # ------------------------------------------------------------------
    # megastep dispatch / retire
    # ------------------------------------------------------------------
    def _dispatch_mega(self, picks: list) -> _Inflight:
        fr, us, ph, _lo, valid, slot_v, depth_v, metas = \
            self._build_wave(picks, "fresh")
        st = self._drain_store_batch()
        # worst-case id reservation: every ring position beyond the
        # input wave is a fresh row. Reserving up front lets the next
        # dispatch go out before this digest is read.
        id_base = self.pool.alloc_ids(self._ring_capacity - self.wave_size)
        self._reset_learning_on_overflow()
        res, hung = self._run_dispatch(
            lambda: run_megastep_mq(
                self.g, self.qb, self.tb, fr, us, ph, valid, slot_v,
                depth_v, *st, np.int32(id_base),
                bool(self.pool.learning_enabled),
                kpr=self._mega_kpr, k_depth=self.megastep_depth,
                capacity=self._ring_capacity, emb_cap=self._emb_cap,
                backend=self._kernel_backend, block_f=self._block_f,
                dma_depth=self._dma_depth),
            list({q.slot: q for q, *_ in metas}.values()), stacks=False)
        if res is None:
            return None             # retries exhausted: queries demoted
        self.tb = res.tb            # handle only — not materialized
        for q in {q.slot: q for q, *_ in metas}.values():
            q.stats.waves += 1
        # slot map over ALL dispatch-time owners, not just the wave's
        # picks: the drained store batch carries buffered patterns from
        # every active query, so digest counter attribution must too
        slot_map = {q.slot: q for q in self.pool.active_queries()}
        return _Inflight("mega", res, metas, slot_map,
                         t_dispatch=time.perf_counter(), hung=hung)

    def _retire_mega(self, rec: _Inflight) -> None:
        if rec.hung:
            self._watchdog_fire(
                {q.slot: q for q, *_ in rec.metas},
                "injected dispatch hang", stacks=False)
            return
        res: MegaResult = rec.res
        t0 = time.perf_counter()
        head = int(res.head)
        tail = int(res.tail)
        bufF = np.asarray(res.buf_frontier)
        bufU = np.asarray(res.buf_used)
        bufP = np.asarray(res.buf_phi)
        slot_a = np.asarray(res.buf_slot)
        depth_a = np.asarray(res.buf_depth)
        parent_a = np.asarray(res.buf_parent)
        valid_a = np.asarray(res.buf_valid)
        rempty = np.asarray(res.refined_empty)
        nchild = np.asarray(res.n_children)
        nleft = np.asarray(res.n_leftover)
        leftover = np.asarray(res.leftover)
        pmask = mask64(np.asarray(res.partial_mask))
        nprun = np.asarray(res.n_pruned)
        ninj = np.asarray(res.n_inj)
        nembr = np.asarray(res.n_emb_row)
        dstored = np.asarray(res.dev_stored)
        pruned_v = np.asarray(res.pruned_v)
        n_emb = int(res.n_emb)
        embF = np.asarray(res.emb_frontier)[:max(0, n_emb)]
        embS = np.asarray(res.emb_slot)[:max(0, n_emb)]
        t1 = time.perf_counter()
        self.t_sync_s += t1 - t0
        if (self.dispatch_timeout_s is not None
                and t1 - rec.t_dispatch > self.dispatch_timeout_s):
            self.fault_counters["hangs"] += 1
            self._watchdog_fire({q.slot: q for q, *_ in rec.metas},
                                "dispatch exceeded watchdog deadline "
                                f"({self.dispatch_timeout_s:g}s)",
                                stacks=False)
            return
        if self.validate_digests and not (
                0 <= head <= tail <= self._ring_capacity
                and 0 <= n_emb <= self._emb_cap):
            # the ring digest has no per-slot blame: an out-of-bounds
            # head/tail invalidates the whole dispatch
            self.fault_counters["digest_failures"] += 1
            self._watchdog_fire(
                {q.slot: q for q, *_ in rec.metas},
                f"megastep digest globally invalid (head={head} "
                f"tail={tail} n_emb={n_emb})", stacks=False)
            return
        r0, f0 = self.t_retire_s, self.t_flush_s

        # ---- Δ store accounting (digest counter lanes) -----------------
        self._fold_store_counters(
            (res.pat_stored, res.pat_overwrites, res.pat_evictions,
             res.pat_dropped), rec.slot_map)

        f_in = self.wave_size
        slot_map = rec.slot_map
        involved: dict[int, QueryState] = {}
        sweeps: dict[int, list] = {}
        # per-slot work accounting surfaced by the digest
        self.slot_rows_expanded += np.asarray(res.slot_rows, np.int64)
        self.slot_children_created += np.asarray(res.slot_children,
                                                 np.int64)
        # shard of every ring row: input rows from their pick's work
        # item, in-loop rows inherit their parent's shard (parents
        # always precede children, so K passes reach every chain)
        shard_of = np.zeros(tail, np.int32)

        # ---- 1) input-row bookkeeping (rows [0, f_in) of the ring) -----
        for q, seg, s, e, woff, k, shard in rec.metas:
            shard_of[woff:woff + k] = shard
            if not q.active:
                continue
            involved[q.query_id] = q
            sl = slice(woff, woff + k)
            rows = slice(s, e)
            seg.gamma[rows] |= pmask[sl]
            seg.pending_leftover[rows] = leftover[sl]
            seg.expanded[rows] = True
            seg.stored[rows] |= dstored[sl]
            seg.outstanding[rows] += nchild[sl]
            seg.reported[rows] |= nembr[sl] > 0
            q.stats.deadend_prunes += int(nprun[sl].sum())
            q.stats.injectivity_fails += int(ninj[sl].sum())
            q.stats.patterns_stored += int(dstored[sl].sum())
            if (nleft[sl] > 0).any():
                q.push(WorkItem(seg.seg_id, s, e, "leftover", shard))
            sweeps.setdefault(q.query_id, []).append(
                (seg, np.arange(s, e), rempty[sl]))

        # ---- Δ hit counters (pruned-child lanes, any ring row) ---------
        if any(q.hit_counts is not None for q in slot_map.values()):
            for sl_v, q in slot_map.items():
                if q.hit_counts is None:
                    continue
                rows = np.nonzero(slot_a[:tail] == sl_v)[0]
                if len(rows):
                    q.note_hits(depth_a[rows], pruned_v[rows])

        # ---- 2) embeddings found in-loop (+ limit aborts) --------------
        if n_emb:
            for sl_v in np.unique(embS):
                q = slot_map.get(int(sl_v))
                if q is None or not q.active:
                    continue
                self._fold_embeddings(q, embF[embS == sl_v])
                if q.limit is not None and q.stats.found >= q.limit:
                    self._abort(q, "limit")

        # ---- 3) rows created in-loop -> new segments -------------------
        if tail > f_in:
            # ring index -> (q-local segment id, row) for parent links;
            # parents always precede children in the ring.
            seg_of = np.full(tail, -1, np.int64)
            row_of = np.full(tail, -1, np.int64)
            for q, seg, s, e, woff, k, shard in rec.metas:
                seg_of[woff:woff + k] = seg.seg_id
                row_of[woff:woff + k] = np.arange(s, e)
            new_idx = np.arange(f_in, tail)
            new_idx = new_idx[valid_a[f_in:tail]]
            # propagate shards down parent chains (≤ K links deep) —
            # skipped on the default path where every shard id is 0
            if any(q.parallelism > 1 for q in slot_map.values()):
                for _ in range(self.megastep_depth):
                    shard_of[new_idx] = shard_of[parent_a[new_idx]]
            sl_arr = slot_a[new_idx]
            for sl_v in np.unique(sl_arr):
                q = slot_map.get(int(sl_v))
                qsel = new_idx[sl_arr == sl_v]
                if q is None or not q.active:
                    continue
                involved[q.query_id] = q
                qd = depth_a[qsel]
                qsh = shard_of[qsel]
                for d_v in np.unique(qd):          # ascending: parents
                    dsel = qsel[qd == d_v]         # precede children
                    dsh = qsh[qd == d_v]
                    for sh_v in np.unique(dsh):    # segments stay
                        sel = dsel[dsh == sh_v]    # shard-pure
                        exp_sel = sel[sel < head]
                        sel2 = np.concatenate([exp_sel, sel[sel >= head]])
                        r = len(sel2)
                        n_exp = len(exp_sel)
                        q.stats.rows_created += r
                        cseg = q.new_segment(
                            int(d_v), bufF[sel2], bufU[sel2], bufP[sel2],
                            seg_of[parent_a[sel2]].astype(np.int32),
                            row_of[parent_a[sel2]].astype(np.int32),
                            shard=int(sh_v))
                        cseg.expanded[:n_exp] = True
                        cseg.gamma[:n_exp] = pmask[exp_sel]
                        cseg.pending_leftover[:] = leftover[sel2]
                        cseg.outstanding[:] = nchild[sel2]
                        cseg.reported[:] = nembr[sel2] > 0
                        cseg.stored[:] = dstored[sel2]
                        q.stats.deadend_prunes += int(nprun[exp_sel].sum())
                        q.stats.injectivity_fails += int(ninj[exp_sel].sum())
                        q.stats.patterns_stored += int(dstored[sel2].sum())
                        seg_of[sel2] = cseg.seg_id
                        row_of[sel2] = np.arange(r)
                        if n_exp < r:
                            q.push(WorkItem(cseg.seg_id, n_exp, r, "fresh",
                                            int(sh_v)))
                        if n_exp and (nleft[exp_sel] > 0).any():
                            q.push(WorkItem(cseg.seg_id, 0, n_exp,
                                            "leftover", int(sh_v)))
                        sweeps.setdefault(q.query_id, []).append(
                            (cseg, np.arange(n_exp), rempty[exp_sel]))

        # ---- 4) Lemma-4 resolution sweep over every expanded row -------
        for qid, q in involved.items():
            if not q.active:
                continue
            items: list = []
            for seg, srows, remask in sweeps.get(qid, []):
                if seg.seg_id not in q.segments:
                    continue
                unres = ~seg.resolved[srows]
                for row in srows[remask & unres]:
                    # Lemma 1: Γ = N(u_d) ∩ dom(M̂)
                    gam = q.qnbr_bits[seg.depth] & below(seg.depth)
                    items.append((seg.seg_id, int(row), False, gam))
                cand = srows[~remask & unres]
                if len(cand):
                    done = cand[(seg.outstanding[cand] == 0)
                                & seg.expanded[cand]
                                & ~seg.pending_leftover[cand].any(axis=1)]
                    for row in done:
                        if seg.reported[row]:
                            items.append((seg.seg_id, int(row), True,
                                          np.uint64(0)))
                        else:
                            items.append(q.finalize_row(seg, int(row)))
            q.resolve_rows(items)
            if q.max_rows is not None and q.stats.rows_created > q.max_rows:
                self._abort(q, "rows")
            elif not q.segments:
                self._finish(q)
        self._note_prunes(int(nprun[:tail].sum()), max(0, tail - f_in))
        dt = time.perf_counter() - t1
        self.t_host_s += dt
        self.t_digest_s += max(0.0, dt - (self.t_retire_s - r0)
                               - (self.t_flush_s - f0))

    # ------------------------------------------------------------------
    # leftover extraction dispatch / retire (single-step program)
    # ------------------------------------------------------------------
    def _dispatch_leftover(self, picks: list) -> _Inflight:
        fr, us, ph, lo, valid, slot_v, depth_v, metas = \
            self._build_wave(picks, "leftover")
        res = extract_more_mq(self.tb, ph, slot_v, depth_v, lo,
                              kpr=4 * self.kpr)
        self.tb = res[7]            # handle with hit counters bumped
        slot_map = {q.slot: q for q, *_ in metas}
        for q in slot_map.values():
            q.stats.waves += 1
        return _Inflight("leftover", res, metas, slot_map,
                         fr=fr, us=us, ph=ph, depth_v=depth_v)

    def _retire_leftover(self, rec: _Inflight) -> None:
        res = rec.res
        t0 = time.perf_counter()
        child_v = np.asarray(res[0])
        child_valid = np.asarray(res[1])
        leftover = np.asarray(res[2])
        n_leftover = np.asarray(res[3])
        partial = mask64(np.asarray(res[4]))
        n_pruned = np.asarray(res[5])
        pruned_v = np.asarray(res[6])
        t1 = time.perf_counter()
        self.t_sync_s += t1 - t0
        r0, f0 = self.t_retire_s, self.t_flush_s
        f_pad = self.wave_size
        digest = dict(
            refined_empty=np.zeros(f_pad, bool),
            n_children=child_valid.sum(axis=1).astype(np.int32),
            n_leftover=n_leftover, partial=partial, child_v=child_v,
            child_valid=child_valid, leftover=leftover,
            n_pruned=n_pruned, n_inj=np.zeros(f_pad, np.int32),
            pruned_v=pruned_v)
        self._process_wave("leftover", rec.metas, rec.fr, rec.us, rec.ph,
                           rec.depth_v, digest)
        dt = time.perf_counter() - t1
        self.t_host_s += dt
        self.t_digest_s += max(0.0, dt - (self.t_retire_s - r0)
                               - (self.t_flush_s - f0))

    # ------------------------------------------------------------------
    # single-step wave processing (megastep_depth == 1 reference path,
    # and the leftover-extraction retire)
    # ------------------------------------------------------------------
    def _step_single(self) -> bool:
        picks = self._pack_wave()
        if picks is None:
            return False
        kind = self._wave_kind
        t0 = time.perf_counter()
        fr, us, ph, lo, valid, slot_v, depth_v, metas = \
            self._build_wave(picks, kind)
        self._flush_stores()
        for q in {q.slot: q for q, *_ in metas}.values():
            q.stats.waves += 1

        if kind == "fresh":
            self.slot_rows_expanded += np.bincount(
                slot_v[valid], minlength=self.n_slots).astype(np.int64)
            res, self.tb = expand_wave_mq(
                self.g, self.qb, self.tb, fr, us, ph, valid, slot_v,
                depth_v, kpr=self.kpr, backend=self._kernel_backend,
                block_f=self._block_f, dma_depth=self._dma_depth)
            self.t_dispatch_s += time.perf_counter() - t0
            t1 = time.perf_counter()
            digest = dict(
                refined_empty=np.asarray(res.refined_empty),
                n_children=np.asarray(res.n_children),
                n_leftover=np.asarray(res.n_leftover),
                partial=mask64(np.asarray(res.partial_mask)),
                child_v=np.asarray(res.child_v),
                child_valid=np.asarray(res.child_valid),
                leftover=np.asarray(res.leftover),
                n_pruned=np.asarray(res.n_pruned),
                n_inj=np.asarray(res.n_inj),
                pruned_v=np.asarray(res.pruned_v))
        else:
            res = extract_more_mq(self.tb, ph, slot_v, depth_v, lo,
                                  kpr=4 * self.kpr)
            self.tb = res[7]        # handle with hit counters bumped
            self.t_dispatch_s += time.perf_counter() - t0
            t1 = time.perf_counter()
            child_valid = np.asarray(res[1])
            digest = dict(
                refined_empty=np.zeros(self.wave_size, bool),
                n_children=child_valid.sum(axis=1).astype(np.int32),
                n_leftover=np.asarray(res[3]),
                partial=mask64(np.asarray(res[4])),
                child_v=np.asarray(res[0]), child_valid=child_valid,
                leftover=np.asarray(res[2]),
                n_pruned=np.asarray(res[5]),
                n_inj=np.zeros(self.wave_size, np.int32),
                pruned_v=np.asarray(res[6]))
        t2 = time.perf_counter()
        self.t_sync_s += t2 - t1
        r0, f0 = self.t_retire_s, self.t_flush_s
        self._process_wave(kind, metas, fr, us, ph, depth_v, digest)
        dt = time.perf_counter() - t2
        self.t_host_s += dt
        self.t_digest_s += max(0.0, dt - (self.t_retire_s - r0)
                               - (self.t_flush_s - f0))
        return True

    def _process_wave(self, kind: str, metas: list, fr, us, ph, depth_v,
                      digest: dict) -> None:
        """Host bookkeeping for one single-step wave digest: child
        assembly, embedding extraction, Lemma-4 resolution."""
        f_pad = self.wave_size
        refined_empty = digest["refined_empty"]
        n_children = digest["n_children"]
        n_leftover = digest["n_leftover"]
        partial = digest["partial"]
        child_v = digest["child_v"]
        child_valid = digest["child_valid"]
        leftover = digest["leftover"]
        n_pruned = digest["n_pruned"]
        n_inj = digest["n_inj"]
        pruned_v = digest["pruned_v"]

        # mask out rows of evicted queries (aborted while this wave was
        # in flight) and last-level rows — their children are
        # embeddings, not rows.
        last_level = np.zeros(f_pad, bool)
        dead_rows = np.zeros(f_pad, bool)
        for q, seg, s, e, woff, k, shard in metas:
            if seg.depth + 1 == q.n:
                last_level[woff:woff + k] = True
            if not q.active:
                dead_rows[woff:woff + k] = True
        child_valid_eff = child_valid & ~last_level[:, None] \
            & ~dead_rows[:, None]

        cf = cu = cp = par = cvalid = None
        if child_valid_eff.any():
            id_base = self.pool.alloc_ids(int(child_valid_eff.sum()))
            cf, cu, cp, par, cvalid = assemble_children_mq(
                fr, us, ph, np.where(child_valid_eff, child_v, -1),
                child_valid_eff, depth_v, np.int32(id_base))
            cf = np.asarray(cf)
            cu = np.asarray(cu)
            cp = np.asarray(cp)
            par = np.asarray(par)
            cvalid = np.asarray(cvalid)
            self._reset_learning_on_overflow()

        # ---- per-item host bookkeeping ---------------------------------
        wave_rows_created = 0
        for q, seg, s, e, woff, k, shard in metas:
            if not q.active:
                continue
            sl = slice(woff, woff + k)
            rows = slice(s, e)
            seg.gamma[rows] |= partial[sl]
            seg.pending_leftover[rows] = leftover[sl]
            q.stats.deadend_prunes += int(n_pruned[sl].sum())
            if q.hit_counts is not None:
                q.note_hits(depth_v[sl], pruned_v[sl])
            if kind == "fresh":
                seg.expanded[rows] = True
                q.stats.injectivity_fails += int(n_inj[sl].sum())

            # re-queue leftover before children (LIFO: children first)
            if (n_leftover[sl] > 0).any():
                q.push(WorkItem(seg.seg_id, s, e, "leftover", shard))

            item_last = seg.depth + 1 == q.n
            if item_last:
                # complete embeddings (vectorized gather + permute)
                emb_rows, emb_cols = np.nonzero(child_valid[sl])
                if len(emb_rows):
                    mrows = seg.frontier[s + emb_rows].copy()
                    mrows[:, seg.depth] = \
                        child_v[woff + emb_rows, emb_cols]
                    report = self._fold_embeddings(q, mrows)
                    seg.reported[s + emb_rows[report]] = True
                if q.limit is not None and q.stats.found >= q.limit:
                    self._abort(q, "limit")
                    continue
            else:
                seg.outstanding[rows] += n_children[sl]
                # compact this item's children into a new segment
                if (n_children[sl] > 0).any():
                    lo_f, hi_f = woff * child_v.shape[1], \
                        (woff + k) * child_v.shape[1]
                    sel = np.nonzero(cvalid[lo_f:hi_f])[0] + lo_f
                    n_new = len(sel)
                    q.stats.rows_created += n_new
                    wave_rows_created += n_new
                    self.slot_children_created[q.slot] += n_new
                    cseg = q.new_segment(
                        seg.depth + 1, cf[sel], cu[sel], cp[sel],
                        np.full(n_new, seg.seg_id, np.int32),
                        (par[sel] - woff + s).astype(np.int32),
                        shard=shard)
                    q.push(WorkItem(cseg.seg_id, 0, n_new, "fresh", shard))

            # immediate resolutions
            items = []
            for i in range(k):
                row = s + i
                if seg.resolved[row]:
                    continue
                if refined_empty[woff + i]:
                    # Lemma 1: Γ = N(u_d) ∩ dom(M̂)
                    gam = q.qnbr_bits[seg.depth] & below(seg.depth)
                    items.append((seg.seg_id, row, False, gam))
                elif (seg.outstanding[row] == 0 and seg.expanded[row]
                      and not seg.pending_leftover[row].any()):
                    if seg.reported[row]:
                        items.append((seg.seg_id, row, True, np.uint64(0)))
                    else:
                        items.append(q.finalize_row(seg, row))
            q.resolve_rows(items)

            if q.max_rows is not None and q.stats.rows_created > q.max_rows:
                self._abort(q, "rows")
            elif not q.segments:
                self._finish(q)
        self._note_prunes(int(n_pruned.sum()), wave_rows_created)

    # ------------------------------------------------------------------
    # driving
    # ------------------------------------------------------------------
    def poll(self) -> list[int]:
        """Query ids completed since the last poll."""
        done, self._fresh_done = self._fresh_done, []
        return done

    @property
    def idle(self) -> bool:
        return (not self.queue and self.pool.n_active == 0
                and self._inflight is None
                and self._inflight_dev is None)

    def run(self) -> dict[int, MatchResult]:
        """Drain all queued and in-flight queries; returns the finished
        map (also available as ``self.finished``)."""
        while self.step():
            pass
        return self.finished

    def scheduler_stats(self) -> dict:
        """Aggregate wave statistics for SLO / occupancy reporting.
        Prune/row totals include still-active queries, so mid-run polling
        sees live numbers."""
        self._materialize_flush_counters()
        occupancy = np.asarray(self.tb.valid.sum(axis=1), np.int64)
        prunes = self.total_prunes + sum(
            q.stats.deadend_prunes for q in self.pool.active_queries())
        rows = self.total_rows_created + sum(
            q.stats.rows_created for q in self.pool.active_queries())
        steals = self.total_steals + sum(
            q.stats.steals for q in self.pool.active_queries())
        return {
            "steals": steals,
            "slot_rows_expanded": self.slot_rows_expanded.tolist(),
            "slot_children_created": self.slot_children_created.tolist(),
            "waves": self.waves,
            "rows_packed": self.rows_packed,
            "wave_size": self.wave_size,
            "n_slots": self.n_slots,
            "megastep_depth": self.megastep_depth,
            "mean_occupancy": (self.occ_sum / self.waves
                               if self.waves else 0.0),
            "steady_occupancy": (self.occ_sum_steady / self.waves_steady
                                 if self.waves_steady else 0.0),
            "steady_waves": self.waves_steady,
            "peak_active": self.pool.peak_active,
            "queued": len(self.queue),
            "active": self.pool.n_active,
            "deadend_prunes": prunes,
            "rows_created": rows,
            "prune_rate": prunes / max(1, prunes + rows),
            "dispatch_time_s": self.t_dispatch_s,
            "device_sync_time_s": self.t_sync_s,
            "host_time_s": self.t_host_s,
            # disjoint host-time breakdown (ISSUE 6): where host wall
            # actually goes — digest folding, admission, retirement
            # (_finish), Δ pattern flushing
            "host_admission_time_s": self.t_admit_s,
            "host_digest_time_s": self.t_digest_s,
            "host_retirement_time_s": self.t_retire_s,
            "host_flush_time_s": self.t_flush_s,
            "device_stacks": self._use_device,
            # adjacency layout (DESIGN.md §2): which refine variant this
            # engine compiled ("dense-vmem" | "hier-hbm") and what the
            # resident adjacency costs — the scale bench's headline
            "adjacency_variant": self.adjacency_variant,
            "adjacency_bytes": self.adjacency_bytes,
            "chunk_words": self._chunk_words,
            # bounded hashed Δ store + cross-query template cache
            # (occupancy reads the live bank so every schedule path —
            # single-step included — reports real store pressure)
            "pattern_capacity": self.pattern_capacity,
            "store_stored": self.store_counters["stored"],
            "store_overwrites": self.store_counters["overwrites"],
            "store_evictions": self.store_counters["evictions"],
            "store_dropped": self.store_counters["dropped"],
            "store_occupancy": occupancy.tolist(),
            "store_load_factor": float(
                occupancy.max() / self.pattern_capacity
                if self.n_slots else 0.0),
            "warm_started": self.warm_started,
            "warm_patterns_seeded": self.warm_patterns_seeded,
            # fault-tolerance counters (DESIGN.md §8): retries, hangs,
            # digest validation failures, quarantines and their
            # outcomes (fallback vs error), flush drops, load shedding
            "faults": dict(self.fault_counters),
            # the tuning record this scheduler resolved at construction
            # (DESIGN.md §9) — "tuning-cache" names the consumed
            # TUNING_CACHE.json record, "builtin" means defaults
            "tuning": dict(self.tuning_record),
            "pattern_cache": (self.pattern_cache.report()
                              if self.pattern_cache is not None else None),
        }


class WaveEngine:
    """Single-query facade over the request/handle API (one slot).

    A thin compatibility wrapper (DESIGN.md §4): ``match`` submits a
    :class:`repro.api.MatchRequest` through a one-slot
    :class:`repro.api.MatchSession` and blocks on the handle. Use the
    session/handle API directly for async submit, streaming, and
    cancellation.

    Usage::

        eng = WaveEngine(data_graph)
        res = eng.match(query_graph, limit=1000)
    """

    def __init__(self, data: Graph, *,
                 options: MatchOptions | None = None, **knobs):
        from ..api.session import MatchSession   # deferred: layering
        knobs["n_slots"] = 1                     # the single-query facade
        self._session = MatchSession(
            data, options=MatchOptions.resolve(options, **knobs))
        self.scheduler = self._session.scheduler

    def match(self, query: Graph, *,
              options: MatchOptions | None = None,
              cand: list[np.ndarray] | None = None,
              order: np.ndarray | None = None,
              **overrides) -> MatchResult:
        """Blocking single-query match; knobs resolve through
        :class:`repro.api.MatchOptions` (``seed_patterns`` follows
        :meth:`WaveScheduler.submit`'s μ > 0 soundness rule;
        ``parallelism`` is the intra-query shard count)."""
        h = self._session.submit(query, options=options, cand=cand,
                                 order=order, keep_table=True,
                                 **overrides)
        qr = h.result()
        self._entries = self.scheduler.tables.pop(h.query_id, None)
        return MatchResult(qr.embeddings, qr.stats)


def match_vectorized(query: Graph, data: Graph,
                     **knobs) -> MatchResult:
    """One-shot convenience wrapper around :class:`WaveEngine`: every
    per-query and per-engine knob is a :class:`repro.api.MatchOptions`
    field (``limit``, ``use_pruning``, ``wave_size``, ``kpr``,
    ``megastep_depth``, ``pattern_capacity``, …)."""
    opts = MatchOptions.resolve(None, **knobs)
    return WaveEngine(data, options=opts).match(query, options=opts)
