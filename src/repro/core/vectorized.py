"""Host-side wave scheduler for the TPU matching engine.

The scheduler owns a DFS stack of *segments* (fixed-shape batches of
partial embeddings, all at one depth) and the resolution bookkeeping that
implements the paper's Lemma-4 mask aggregation across waves. All dense
work — Eq. 2 refinement, injectivity, dead-end lookup, child extraction,
pattern scatter — runs in the jitted device programs of ``engine_step``.

Learning happens *across* waves: patterns extracted from failures in
earlier-expanded subtrees prune later waves (DESIGN.md §2). Matching is
exact for any schedule because stored patterns are true dead-ends.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .backtrack import MatchResult, SearchStats, _prepare
from .candidates import build_candidates
from .engine_step import (MASK_WORDS, N_PAD, GraphArrays, QueryArrays,
                          TableArrays, assemble_children, expand_wave,
                          extract_more, store_patterns)
from .graph import Graph, pack_bitmap
from .ordering import connected_min_candidate_order

_ID_LIMIT = 2**31 - 2**22


def _mask64(words: np.ndarray) -> np.ndarray:
    """uint32 [..., 2] -> uint64 [...]."""
    w = words.astype(np.uint64)
    return w[..., 0] | (w[..., 1] << np.uint64(32))


def _words_from64(m: np.ndarray) -> np.ndarray:
    out = np.zeros(m.shape + (MASK_WORDS,), np.uint32)
    out[..., 0] = (m & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    out[..., 1] = (m >> np.uint64(32)).astype(np.uint32)
    return out


def _bit(p) -> np.uint64:
    return np.uint64(1) << np.uint64(p)


def _below(d: int) -> np.uint64:
    return (np.uint64(1) << np.uint64(d)) - np.uint64(1) if d < 64 \
        else np.uint64(0xFFFFFFFFFFFFFFFF)


@dataclasses.dataclass
class _Segment:
    seg_id: int
    depth: int                      # mapped positions per row
    frontier: np.ndarray            # int32 [R, N_PAD]
    used: np.ndarray                # uint32 [R, W]
    phi: np.ndarray                 # int32 [R, N_PAD + 1]
    parent_seg: np.ndarray          # int32 [R] (-1 for roots)
    parent_row: np.ndarray          # int32 [R]
    # resolution state (filled lazily at expansion time)
    outstanding: np.ndarray | None = None   # int64 [R]
    gamma: np.ndarray | None = None         # uint64 [R] accumulated Γ*
    reported: np.ndarray | None = None      # bool [R]
    expanded: np.ndarray | None = None      # bool [R] first pass done
    pending_leftover: np.ndarray | None = None  # uint32 [R, W]
    resolved: np.ndarray | None = None      # bool [R]
    n_unresolved: int = 0

    def init_state(self, w: int) -> None:
        r = len(self.frontier)
        self.outstanding = np.zeros(r, np.int64)
        self.gamma = np.zeros(r, np.uint64)
        self.reported = np.zeros(r, bool)
        self.expanded = np.zeros(r, bool)
        self.pending_leftover = np.zeros((r, w), np.uint32)
        self.resolved = np.zeros(r, bool)
        self.n_unresolved = r


@dataclasses.dataclass
class EngineStats(SearchStats):
    waves: int = 0
    rows_created: int = 0
    patterns_stored: int = 0


class WaveEngine:
    """Vectorized subgraph matching over one data graph.

    Usage::

        eng = WaveEngine(data_graph)
        res = eng.match(query_graph, limit=1000)
    """

    def __init__(self, data: Graph, wave_size: int = 512, kpr: int = 16,
                 use_pruning: bool = True):
        self.data = data
        self.wave_size = int(wave_size)
        self.kpr = int(kpr)
        self.use_pruning = use_pruning
        self.w = (data.n + 31) // 32
        self.g = GraphArrays(
            adj_bitmap=jnp.asarray(data.adj_bitmap),
            n_vertices=jnp.int32(data.n))

    # ------------------------------------------------------------------
    def match(self, query: Graph, limit: int | None = 1000,
              cand: list[np.ndarray] | None = None,
              order: np.ndarray | None = None,
              max_rows: int | None = None,
              seed_table=None) -> MatchResult:
        """``seed_table``: a TableArrays of *transferable* (mu == 0)
        patterns from other shards — see core.distributed. Patterns with
        mu > 0 reference foreign embedding-id numbering and MUST NOT be
        seeded (soundness)."""
        import time as _time
        _t0 = _time.perf_counter()
        if query.n > N_PAD:
            raise ValueError(f"query too large for mask width: {query.n}")
        cand_by_pos, order, pos_of, nbr_pos = _prepare(
            query, self.data, cand, order)
        n = query.n
        v, w = self.data.n, self.w

        # --- device query arrays -------------------------------------
        cand_dense = np.zeros((N_PAD, v), bool)
        for d in range(n):
            cand_dense[d, cand_by_pos[d]] = True
        nbr_mask = np.zeros((N_PAD, N_PAD), bool)
        for d in range(n):
            for p in nbr_pos[d]:
                nbr_mask[d, int(p)] = True
        q = QueryArrays(cand_bitmap=jnp.asarray(pack_bitmap(cand_dense)),
                        nbr_mask=jnp.asarray(nbr_mask),
                        n_query=jnp.int32(n))
        qnbr_bits = np.zeros(N_PAD, np.uint64)
        for d in range(n):
            bits = np.uint64(0)
            for p in nbr_pos[d]:
                bits |= _bit(int(p))
            qnbr_bits[d] = bits

        table = seed_table if seed_table is not None \
            else TableArrays.empty(v)
        no_table = TableArrays.empty(v) if not self.use_pruning else None
        stats = EngineStats()
        stats.table_stats = None
        embeddings: list[np.ndarray] = []
        segments: dict[int, _Segment] = {}
        store_buf: list[tuple[int, int, int, int, np.uint64]] = []
        id_counter = 1
        learning = self.use_pruning
        next_seg = 0

        # --- helpers ---------------------------------------------------
        def new_segment(depth, frontier, used, phi, pseg, prow) -> _Segment:
            nonlocal next_seg
            seg = _Segment(next_seg, depth, frontier, used, phi, pseg, prow)
            seg.init_state(w)
            segments[next_seg] = seg
            next_seg += 1
            return seg

        def flush_stores():
            nonlocal table
            if not store_buf or not learning:
                store_buf.clear()
                return
            kpos = np.array([s[0] for s in store_buf], np.int32)
            kv = np.array([s[1] for s in store_buf], np.int32)
            phis = np.array([s[2] for s in store_buf], np.int32)
            mus = np.array([s[3] for s in store_buf], np.int32)
            masks = _words_from64(np.array([s[4] for s in store_buf],
                                           np.uint64))
            table = store_patterns(table, jnp.asarray(kpos), jnp.asarray(kv),
                                   jnp.asarray(phis), jnp.asarray(mus),
                                   jnp.asarray(masks),
                                   jnp.ones(len(kpos), bool))
            stats.patterns_stored += len(store_buf)
            store_buf.clear()

        def queue_store(seg: _Segment, row: int, gamma: np.uint64):
            """Record the dead-end pattern of a resolved-dead row."""
            if not learning or stats.aborted:
                return
            d = seg.depth
            if d == 0:
                return
            key_pos = d - 1
            key_v = int(seg.frontier[row, key_pos])
            below = gamma & _below(key_pos)
            if below:
                mu_len = int(below).bit_length()   # highest set bit + 1
            else:
                mu_len = 0
            phi_id = int(seg.phi[row, mu_len])
            store_buf.append((key_pos, key_v, phi_id, mu_len, gamma))

        # worklist of (seg_id, row, reported, gamma) resolutions
        def resolve_rows(items: list[tuple[int, int, bool, np.uint64]]):
            while items:
                sid, row, reported, gamma = items.pop()
                seg = segments[sid]
                if seg.resolved[row]:
                    continue
                seg.resolved[row] = True
                seg.n_unresolved -= 1
                if not reported:
                    queue_store(seg, row, gamma)
                ps, pr = int(seg.parent_seg[row]), int(seg.parent_row[row])
                if ps >= 0:
                    pseg = segments[ps]
                    if reported:
                        pseg.reported[pr] = True
                    else:
                        pseg.gamma[pr] |= gamma
                    pseg.outstanding[pr] -= 1
                    if (pseg.outstanding[pr] == 0 and pseg.expanded[pr]
                            and not _has_leftover(pseg, pr)):
                        items.append(_finalize_row(pseg, pr))
                if seg.n_unresolved == 0:
                    del segments[sid]

        def _has_leftover(seg: _Segment, row: int) -> bool:
            return bool(seg.pending_leftover[row].any())

        def _finalize_row(seg: _Segment, row: int
                          ) -> tuple[int, int, bool, np.uint64]:
            """All children of this row are resolved: Lemma 4 conversion."""
            if seg.reported[row]:
                return (seg.seg_id, row, True, np.uint64(0))
            d = seg.depth
            gamma = seg.gamma[row]
            if gamma & _bit(d):
                gamma = (gamma | qnbr_bits[d]) & _below(d)
            return (seg.seg_id, row, False, gamma)

        # --- root segment ----------------------------------------------
        roots = cand_by_pos[0]
        if len(roots) == 0:
            stats.wall_time_s = 0.0
            return MatchResult([], stats)
        r = len(roots)
        frontier = np.full((r, N_PAD), -1, np.int32)
        frontier[:, 0] = roots
        used = np.zeros((r, w), np.uint32)
        used[np.arange(r), roots // 32] = (
            np.uint32(1) << (roots.astype(np.uint32) % np.uint32(32)))
        phi = np.zeros((r, N_PAD + 1), np.int32)
        phi[:, 1] = np.arange(id_counter, id_counter + r)
        id_counter += r
        stats.rows_created += r
        if n == 1:
            for v0 in roots:
                emb = np.empty(1, np.int32)
                emb[order[0]] = v0
                embeddings.append(emb)
            if limit is not None:
                embeddings = embeddings[:limit]
            stats.found = len(embeddings)
            stats.recursions = stats.rows_created
            return MatchResult(embeddings, stats)
        root_seg = new_segment(1, frontier, used, phi,
                               np.full(r, -1, np.int32),
                               np.zeros(r, np.int32))

        # stack items: (seg_id, row_start, 'fresh' | 'leftover')
        stack: list[tuple[int, int, str]] = []
        for s in range(0, r, self.wave_size):
            stack.append((root_seg.seg_id, s, "fresh"))
        stack.reverse()

        # --- main loop ---------------------------------------------------
        while stack and not stats.aborted:
            sid, start, kind = stack.pop()
            if sid not in segments:
                continue
            seg = segments[sid]
            rows = slice(start, min(start + self.wave_size,
                                    len(seg.frontier)))
            nrows = rows.stop - rows.start
            if kind == "leftover":
                active = seg.pending_leftover[rows].any(axis=1)
                if not active.any():
                    continue
            flush_stores()
            stats.waves += 1
            f_pad = self.wave_size
            fr = _pad(seg.frontier[rows], f_pad, -1)
            us = _pad(seg.used[rows], f_pad, 0)
            ph = _pad(seg.phi[rows], f_pad, 0)
            valid = np.zeros(f_pad, bool)
            valid[:nrows] = ~seg.resolved[rows]
            depth = seg.depth
            last_level = depth + 1 == n

            if kind == "fresh":
                res = expand_wave(
                    self.g, q, table if no_table is None else no_table,
                    jnp.asarray(fr), jnp.asarray(us), jnp.asarray(ph),
                    jnp.asarray(valid), jnp.int32(depth), kpr=self.kpr)
                refined_empty = np.asarray(res.refined_empty)[:nrows]
                n_children = np.asarray(res.n_children)[:nrows]
                n_leftover = np.asarray(res.n_leftover)[:nrows]
                partial = _mask64(np.asarray(res.partial_mask))[:nrows]
                child_v = np.asarray(res.child_v)[:nrows]
                child_valid = np.asarray(res.child_valid)[:nrows]
                leftover = np.asarray(res.leftover)[:nrows]
                stats.deadend_prunes += int(np.asarray(res.n_pruned))
                stats.injectivity_fails += int(np.asarray(res.n_inj))
                seg.expanded[rows] = True
                seg.gamma[rows] |= partial
                seg.pending_leftover[rows] = leftover
            else:
                lo = _pad(seg.pending_leftover[rows], f_pad, 0)
                res = extract_more(
                    table if no_table is None else no_table,
                    jnp.asarray(ph), jnp.int32(depth), jnp.asarray(lo),
                    kpr=4 * self.kpr)
                child_v = np.asarray(res[0])[:nrows]
                child_valid = np.asarray(res[1])[:nrows]
                leftover = np.asarray(res[2])[:nrows]
                n_children = child_valid.sum(axis=1)
                n_leftover = np.asarray(res[3])[:nrows]
                seg.gamma[rows] |= _mask64(np.asarray(res[4]))[:nrows]
                stats.deadend_prunes += int(np.asarray(res[5]))
                refined_empty = np.zeros(nrows, bool)
                seg.pending_leftover[rows] = leftover

            # re-queue leftover before children (LIFO: children first)
            if (n_leftover > 0).any():
                stack.append((sid, start, "leftover"))

            # ---- complete embeddings at the last level -------------------
            if last_level:
                emb_rows, emb_cols = np.nonzero(child_valid)
                for i, j in zip(emb_rows.tolist(), emb_cols.tolist()):
                    if limit is not None and stats.found >= limit:
                        stats.aborted = True
                        break
                    mrow = seg.frontier[rows.start + i].copy()
                    mrow[depth] = child_v[i, j]
                    emb = np.empty(n, np.int32)
                    emb[order] = mrow[:n]
                    embeddings.append(emb)
                    stats.found += 1
                    seg.reported[rows.start + i] = True
                if stats.aborted:
                    break
                n_children_eff = np.zeros_like(n_children)
            else:
                n_children_eff = n_children

            seg.outstanding[rows] += n_children_eff

            # ---- push child segment --------------------------------------
            if not last_level and (n_children > 0).any():
                cf, cu, cp, par, cvalid = assemble_children(
                    jnp.asarray(fr), jnp.asarray(us), jnp.asarray(ph),
                    jnp.asarray(_pad(child_v, f_pad, -1)),
                    jnp.asarray(_pad(child_valid, f_pad, False)),
                    jnp.int32(depth), jnp.int32(id_counter))
                cvalid = np.asarray(cvalid)
                sel = np.nonzero(cvalid)[0]
                n_new = len(sel)
                id_counter += n_new
                stats.rows_created += n_new
                if id_counter > _ID_LIMIT and learning:
                    # id overflow: clear the table, stop learning (sound)
                    table = TableArrays.empty(v)
                    learning = False
                cseg = new_segment(
                    depth + 1,
                    np.asarray(cf)[sel], np.asarray(cu)[sel],
                    np.asarray(cp)[sel],
                    np.full(n_new, sid, np.int32),
                    (np.asarray(par)[sel] + rows.start).astype(np.int32))
                for s in range(0, n_new, self.wave_size):
                    stack.append((cseg.seg_id, s, "fresh"))

            # ---- immediate resolutions -----------------------------------
            items = []
            for i in range(nrows):
                row = rows.start + i
                if seg.resolved[row]:
                    continue
                if refined_empty[i]:
                    # Lemma 1: Γ = N(u_d) ∩ dom(M̂)
                    gam = qnbr_bits[depth] & _below(depth)
                    items.append((sid, row, False, gam))
                elif (seg.outstanding[row] == 0 and seg.expanded[row]
                      and not seg.pending_leftover[row].any()):
                    if seg.reported[row]:
                        items.append((sid, row, True, np.uint64(0)))
                    else:
                        items.append(_finalize_row(seg, row))
            resolve_rows(items)
            if max_rows is not None and stats.rows_created > max_rows:
                stats.aborted = True

        stats.recursions = stats.rows_created
        stats.wall_time_s = _time.perf_counter() - _t0
        self._table = table  # expose for distributed pattern merging
        return MatchResult(embeddings, stats)


def _pad(arr: np.ndarray, rows: int, fill) -> np.ndarray:
    if len(arr) == rows:
        return arr
    out = np.full((rows,) + arr.shape[1:], fill, arr.dtype)
    out[:len(arr)] = arr
    return out


def match_vectorized(query: Graph, data: Graph, limit: int | None = 1000,
                     use_pruning: bool = True, wave_size: int = 512,
                     kpr: int = 16, **kw) -> MatchResult:
    """One-shot convenience wrapper around :class:`WaveEngine`."""
    return WaveEngine(data, wave_size=wave_size, kpr=kpr,
                      use_pruning=use_pruning).match(query, limit=limit,
                                                     **kw)
