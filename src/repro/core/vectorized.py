"""Host-side shared-wave scheduler for the TPU matching engine.

Continuous multi-query wave batching (DESIGN.md §2): many concurrent
queries are admitted into bank *slots*; every wave is packed with ready
segment rows from whichever queries have work, so one fixed-shape jitted
device program (``engine_step.expand_wave_mq``) serves mixed traffic with
no idle gaps between queries. The per-query DFS stacks and Lemma-4
resolution bookkeeping live in ``segments.py``; all dense work — Eq. 2
refinement, injectivity, dead-end lookup, child extraction, pattern
scatter — runs in the jitted device programs of ``engine_step``.

Scheduling policy: admission fills free slots from a bounded FIFO queue;
wave packing round-robins over active queries, splitting segment slices
so waves stay full; per-query ``limit`` / ``max_rows`` / ``time_budget_s``
abort a query and evict its segments without touching its neighbors.

Learning happens *across* waves: patterns extracted from failures in
earlier-expanded subtrees prune later waves of the same query (tables are
slot-private, so queries never see each other's patterns). Matching is
exact for any schedule because stored patterns are true dead-ends.

:class:`WaveEngine` is the single-query facade (one slot) kept for the
sequential-style API and the distributed matcher.
"""
from __future__ import annotations

import collections
import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from .backtrack import MatchResult, _prepare
from .engine_step import (MASK_WORDS, N_PAD, GraphArrays, QueryBank,
                          TableArrays, TableBank, assemble_children_mq,
                          expand_wave_mq, extract_more_mq, load_slot,
                          read_table_slot, store_patterns_mq)
from .graph import Graph, pack_bitmap
from .segments import (EngineStats, QueryState, Segment, SegmentPool,
                       WorkItem, below, bit_of, mask64, words_from64)

__all__ = ["WaveScheduler", "WaveEngine", "EngineStats", "QueueFull",
           "match_vectorized"]


class QueueFull(RuntimeError):
    """Raised when the bounded admission queue rejects a submission."""


@dataclasses.dataclass
class _Request:
    """A prepared query waiting in the admission queue."""
    query_id: int
    n: int
    order: np.ndarray
    roots: np.ndarray
    cand_bitmap: np.ndarray        # uint32 [N_PAD, W]
    nbr_mask: np.ndarray           # bool [N_PAD, N_PAD]
    qnbr_bits: np.ndarray          # uint64 [N_PAD]
    limit: int | None
    learn: bool
    max_rows: int | None
    time_budget_s: float | None
    seed_table: TableArrays | None
    keep_table: bool
    t_submit: float


class WaveScheduler:
    """Continuous multi-query matching over one data graph.

    Usage::

        sched = WaveScheduler(data_graph, n_slots=16)
        qid = sched.submit(query_graph, limit=1000)
        sched.run()
        res = sched.finished.pop(qid)          # MatchResult
    """

    def __init__(self, data: Graph, n_slots: int = 8, wave_size: int = 512,
                 kpr: int = 16, use_pruning: bool = True,
                 max_queue: int = 4096):
        self.data = data
        self.n_slots = int(n_slots)
        self.wave_size = int(wave_size)
        self.kpr = int(kpr)
        self.use_pruning = use_pruning
        self.max_queue = int(max_queue)
        self.w = (data.n + 31) // 32
        self.g = GraphArrays(
            adj_bitmap=jnp.asarray(data.adj_bitmap),
            n_vertices=jnp.int32(data.n))
        self.qb = QueryBank.empty(self.n_slots, self.w)
        self.tb = TableBank.empty(self.n_slots, data.n)
        self.pool = SegmentPool(self.n_slots)
        self.queue: collections.deque[_Request] = collections.deque()
        self.finished: dict[int, MatchResult] = {}
        self.tables: dict[int, TableArrays] = {}
        self._fresh_done: list[int] = []
        self._next_qid = 0
        self._rr = 0
        # aggregate wave statistics (for occupancy / SLO reporting)
        self.waves = 0
        self.rows_packed = 0
        self.occ_sum = 0.0
        self.waves_steady = 0
        self.occ_sum_steady = 0.0
        self.total_prunes = 0
        self.total_rows_created = 0

    # ------------------------------------------------------------------
    # submission / admission
    # ------------------------------------------------------------------
    def submit(self, query: Graph, *, limit: int | None = 1000,
               cand: list[np.ndarray] | None = None,
               order: np.ndarray | None = None,
               max_rows: int | None = None,
               time_budget_s: float | None = None,
               use_pruning: bool | None = None,
               seed_table: TableArrays | None = None,
               keep_table: bool = False) -> int:
        """Enqueue a query; returns its scheduler query id.

        Raises :class:`QueueFull` when the bounded admission queue is at
        capacity — callers apply backpressure or shed load.

        ``seed_table``: a TableArrays of *transferable* (mu == 0)
        patterns from other shards — see core.distributed. Patterns with
        mu > 0 reference foreign embedding-id numbering and MUST NOT be
        seeded (soundness).
        """
        if len(self.queue) >= self.max_queue:
            raise QueueFull(
                f"admission queue at capacity ({self.max_queue})")
        if query.n > N_PAD:
            raise ValueError(f"query too large for mask width: {query.n}")
        t_submit = time.perf_counter()
        qid = self._next_qid
        self._next_qid += 1
        cand_by_pos, order, _pos_of, nbr_pos = _prepare(
            query, self.data, cand, order)
        n = query.n
        v = self.data.n
        cand_dense = np.zeros((N_PAD, v), bool)
        for d in range(n):
            cand_dense[d, cand_by_pos[d]] = True
        nbr_mask = np.zeros((N_PAD, N_PAD), bool)
        qnbr_bits = np.zeros(N_PAD, np.uint64)
        for d in range(n):
            bits = np.uint64(0)
            for p in nbr_pos[d]:
                nbr_mask[d, int(p)] = True
                bits |= bit_of(int(p))
            qnbr_bits[d] = bits
        learn = self.use_pruning if use_pruning is None else use_pruning
        req = _Request(
            query_id=qid, n=n, order=np.asarray(order, np.int32),
            roots=np.asarray(cand_by_pos[0], np.int32),
            cand_bitmap=pack_bitmap(cand_dense), nbr_mask=nbr_mask,
            qnbr_bits=qnbr_bits, limit=limit, learn=learn,
            max_rows=max_rows, time_budget_s=time_budget_s,
            seed_table=seed_table, keep_table=keep_table,
            t_submit=t_submit)
        # trivial queries never need a slot
        if len(req.roots) == 0 or n == 1:
            self._finish_trivial(req)
        else:
            self.queue.append(req)
        return qid

    def _finish_trivial(self, req: _Request) -> None:
        stats = EngineStats()
        stats.table_stats = None
        embeddings: list[np.ndarray] = []
        if req.n == 1 and len(req.roots) > 0:
            stats.rows_created = len(req.roots)
            for v0 in req.roots:
                emb = np.empty(1, np.int32)
                emb[req.order[0]] = v0
                embeddings.append(emb)
            if req.limit is not None and len(embeddings) >= req.limit:
                embeddings = embeddings[:req.limit]
                stats.aborted = True
                stats.abort_reason = "limit"
            stats.found = len(embeddings)
            stats.recursions = stats.rows_created
        stats.wall_time_s = time.perf_counter() - req.t_submit
        self.finished[req.query_id] = MatchResult(embeddings, stats)
        if req.keep_table:
            self.tables[req.query_id] = (req.seed_table
                                         if req.seed_table is not None
                                         else TableArrays.empty(self.data.n))
        self._fresh_done.append(req.query_id)

    def _admit(self) -> None:
        while self.queue:
            slot = self.pool.free_slot()
            if slot is None:
                return
            req = self.queue.popleft()
            table = (req.seed_table if req.seed_table is not None
                     else TableArrays.empty(self.data.n))
            self.qb, self.tb = load_slot(
                self.qb, self.tb, jnp.int32(slot),
                jnp.asarray(req.cand_bitmap), jnp.asarray(req.nbr_mask),
                jnp.int32(req.n), table)
            now = time.perf_counter()
            deadline = (None if req.time_budget_s is None
                        else now + req.time_budget_s)
            q = QueryState(slot, req.query_id, req.n, req.order,
                           req.qnbr_bits, self.w, limit=req.limit,
                           learn=req.learn and self.pool.learning_enabled,
                           max_rows=req.max_rows, deadline=deadline,
                           keep_table=req.keep_table,
                           t_submit=req.t_submit)
            q.stats.table_stats = None
            r = len(req.roots)
            frontier = np.full((r, N_PAD), -1, np.int32)
            frontier[:, 0] = req.roots
            used = np.zeros((r, self.w), np.uint32)
            used[np.arange(r), req.roots // 32] = (
                np.uint32(1) << (req.roots.astype(np.uint32)
                                 % np.uint32(32)))
            phi = np.zeros((r, N_PAD + 1), np.int32)
            base = self.pool.alloc_ids(r)
            phi[:, 1] = np.arange(base, base + r)
            q.stats.rows_created += r
            root_seg = q.new_segment(1, frontier, used, phi,
                                     np.full(r, -1, np.int32),
                                     np.zeros(r, np.int32))
            q.push(WorkItem(root_seg.seg_id, 0, r, "fresh"))
            self.pool.attach(slot, q)

    # ------------------------------------------------------------------
    # completion / abort
    # ------------------------------------------------------------------
    def _finish(self, q: QueryState) -> None:
        if q.keep_table and q.store_buf:
            # make patterns from the final resolutions visible in the
            # exported table (distributed pattern sharing)
            self._flush_stores()
        q.status = "done"
        q.evict()
        q.stats.recursions = q.stats.rows_created
        q.stats.wall_time_s = time.perf_counter() - q.t_submit
        self.total_prunes += q.stats.deadend_prunes
        self.total_rows_created += q.stats.rows_created
        if q.keep_table:
            self.tables[q.query_id] = read_table_slot(self.tb, q.slot)
        self.finished[q.query_id] = MatchResult(q.embeddings, q.stats)
        self._fresh_done.append(q.query_id)
        self.pool.release(q.slot)

    def _abort(self, q: QueryState, reason: str) -> None:
        """Abort a query (budget exhausted or limit reached) and evict
        its segments; partial embeddings are kept."""
        q.stats.aborted = True
        q.stats.abort_reason = reason
        q.abort_reason = reason
        self._finish(q)

    def _check_budgets(self, now: float | None = None) -> None:
        for q in self.pool.active_queries():
            if q.deadline is not None:
                if now is None:
                    now = time.perf_counter()
                if now > q.deadline:
                    self._abort(q, "time")
                    continue
            if q.max_rows is not None and q.stats.rows_created > q.max_rows:
                self._abort(q, "rows")

    # ------------------------------------------------------------------
    # wave packing
    # ------------------------------------------------------------------
    def _pack_wave(self) -> list[tuple[QueryState, Segment, int, int]] | None:
        """Fill one wave with ready rows, round-robin across queries.

        All picks share one kind ("fresh" or "leftover") because the two
        run different device programs; a query whose stack top is the
        other kind simply waits for a later wave. Each query contributes
        at most one work item per wave: waves fill *across* queries, not
        by draining one query's stack — that keeps the per-query
        store→lookup cadence of depth-first search (patterns learned from
        one segment slice prune the next slice) while mixed traffic keeps
        the wave full. Returns [(query, segment, start, stop)] or None
        when no work exists.
        """
        active = self.pool.active_queries()
        if not active:
            return None
        order = active[self._rr % len(active):] + \
            active[:self._rr % len(active)]
        self._rr += 1
        kind = None
        picks: list[tuple[QueryState, Segment, int, int]] = []
        remaining = self.wave_size
        for q in order:
            if remaining == 0:
                break
            top = q.peek_kind()
            if top is None:
                continue
            if kind is None:
                kind = top
            if top != kind:
                continue
            item = q.pop_ready()
            take = min(remaining, item.stop - item.start)
            if take < item.stop - item.start:
                q.push(WorkItem(item.seg_id, item.start + take,
                                item.stop, item.kind))
            picks.append((q, q.segments[item.seg_id], item.start,
                          item.start + take))
            remaining -= take
        if not picks:
            return None
        self._wave_kind = kind
        return picks

    # ------------------------------------------------------------------
    # pattern store flushing
    # ------------------------------------------------------------------
    def _flush_stores(self) -> None:
        bufs = [(q, q.store_buf) for q in self.pool.active_queries()
                if q.store_buf]
        if not bufs or not self.pool.learning_enabled:
            for q, buf in bufs:
                buf.clear()
            return
        slots, kpos, kv, phis, mus, masks = [], [], [], [], [], []
        for q, buf in bufs:
            for key_pos, key_v, phi_id, mu_len, gamma in buf:
                slots.append(q.slot)
                kpos.append(key_pos)
                kv.append(key_v)
                phis.append(phi_id)
                mus.append(mu_len)
                masks.append(gamma)
            q.stats.patterns_stored += len(buf)
            buf.clear()
        self.tb = store_patterns_mq(
            self.tb,
            jnp.asarray(np.array(slots, np.int32)),
            jnp.asarray(np.array(kpos, np.int32)),
            jnp.asarray(np.array(kv, np.int32)),
            jnp.asarray(np.array(phis, np.int32)),
            jnp.asarray(np.array(mus, np.int32)),
            jnp.asarray(words_from64(np.array(masks, np.uint64))),
            jnp.ones(len(slots), bool))

    # ------------------------------------------------------------------
    # one wave
    # ------------------------------------------------------------------
    def step(self) -> bool:
        """Admit, pack, and execute one wave. Returns False when idle."""
        self._check_budgets()
        self._admit()
        picks = self._pack_wave()
        if picks is None:
            return False
        kind = self._wave_kind
        f_pad = self.wave_size
        fr = np.full((f_pad, N_PAD), -1, np.int32)
        us = np.zeros((f_pad, self.w), np.uint32)
        ph = np.zeros((f_pad, N_PAD + 1), np.int32)
        lo = np.zeros((f_pad, self.w), np.uint32)
        valid = np.zeros(f_pad, bool)
        slot_v = np.zeros(f_pad, np.int32)
        depth_v = np.zeros(f_pad, np.int32)
        metas: list[tuple[QueryState, Segment, int, int, int, int]] = []
        off = 0
        for q, seg, s, e in picks:
            k = e - s
            fr[off:off + k] = seg.frontier[s:e]
            us[off:off + k] = seg.used[s:e]
            ph[off:off + k] = seg.phi[s:e]
            valid[off:off + k] = ~seg.resolved[s:e]
            slot_v[off:off + k] = q.slot
            depth_v[off:off + k] = seg.depth
            if kind == "leftover":
                lo[off:off + k] = seg.pending_leftover[s:e]
            metas.append((q, seg, s, e, off, k))
            off += k

        self._flush_stores()
        self.waves += 1
        self.rows_packed += off
        occ = off / f_pad
        self.occ_sum += occ
        if self.pool.n_active == self.n_slots:
            self.waves_steady += 1
            self.occ_sum_steady += occ
        for q, *_ in metas:     # one item per query per wave (_pack_wave)
            q.stats.waves += 1

        if kind == "fresh":
            res = expand_wave_mq(
                self.g, self.qb, self.tb, jnp.asarray(fr), jnp.asarray(us),
                jnp.asarray(ph), jnp.asarray(valid), jnp.asarray(slot_v),
                jnp.asarray(depth_v), kpr=self.kpr)
            refined_empty = np.asarray(res.refined_empty)
            n_children = np.asarray(res.n_children)
            n_leftover = np.asarray(res.n_leftover)
            partial = mask64(np.asarray(res.partial_mask))
            child_v = np.asarray(res.child_v)
            child_valid = np.asarray(res.child_valid)
            leftover = np.asarray(res.leftover)
            n_pruned = np.asarray(res.n_pruned)
            n_inj = np.asarray(res.n_inj)
        else:
            res = extract_more_mq(
                self.tb, jnp.asarray(ph), jnp.asarray(slot_v),
                jnp.asarray(depth_v), jnp.asarray(lo), kpr=4 * self.kpr)
            child_v = np.asarray(res[0])
            child_valid = np.asarray(res[1])
            leftover = np.asarray(res[2])
            n_leftover = np.asarray(res[3])
            partial = mask64(np.asarray(res[4]))
            n_pruned = np.asarray(res[5])
            n_children = child_valid.sum(axis=1).astype(np.int32)
            refined_empty = np.zeros(f_pad, bool)
            n_inj = np.zeros(f_pad, np.int32)

        # mask out rows of evicted queries (aborted between pack and now:
        # cannot happen today, but keeps the invariant explicit) and
        # last-level rows — their children are embeddings, not rows.
        last_level = np.zeros(f_pad, bool)
        for q, seg, s, e, woff, k in metas:
            if seg.depth + 1 == q.n:
                last_level[woff:woff + k] = True
        child_valid_eff = child_valid & ~last_level[:, None]

        cf = cu = cp = par = cvalid = None
        if child_valid_eff.any():
            id_base = self.pool.alloc_ids(int(child_valid_eff.sum()))
            cf, cu, cp, par, cvalid = assemble_children_mq(
                jnp.asarray(fr), jnp.asarray(us), jnp.asarray(ph),
                jnp.asarray(np.where(child_valid_eff, child_v, -1)),
                jnp.asarray(child_valid_eff), jnp.asarray(depth_v),
                jnp.int32(id_base))
            cf = np.asarray(cf)
            cu = np.asarray(cu)
            cp = np.asarray(cp)
            par = np.asarray(par)
            cvalid = np.asarray(cvalid)
            if self.pool.id_overflow and self.pool.learning_enabled:
                # id overflow: clear all tables, pause learning (sound);
                # the pool re-enables learning once it drains.
                self.tb = TableBank.empty(self.n_slots, self.data.n)
                self.pool.learning_enabled = False
                for qq in self.pool.active_queries():
                    qq.learn = False

        # ---- per-item host bookkeeping ---------------------------------
        for q, seg, s, e, woff, k in metas:
            if not q.active:
                continue
            sl = slice(woff, woff + k)
            rows = slice(s, e)
            seg.gamma[rows] |= partial[sl]
            seg.pending_leftover[rows] = leftover[sl]
            q.stats.deadend_prunes += int(n_pruned[sl].sum())
            if kind == "fresh":
                seg.expanded[rows] = True
                q.stats.injectivity_fails += int(n_inj[sl].sum())

            # re-queue leftover before children (LIFO: children first)
            if (n_leftover[sl] > 0).any():
                q.push(WorkItem(seg.seg_id, s, e, "leftover"))

            item_last = seg.depth + 1 == q.n
            if item_last:
                # complete embeddings
                emb_rows, emb_cols = np.nonzero(child_valid[sl])
                for i, j in zip(emb_rows.tolist(), emb_cols.tolist()):
                    if (q.limit is not None
                            and q.stats.found >= q.limit):
                        break
                    mrow = seg.frontier[s + i].copy()
                    mrow[seg.depth] = child_v[woff + i, j]
                    emb = np.empty(q.n, np.int32)
                    emb[q.order] = mrow[:q.n]
                    q.embeddings.append(emb)
                    q.stats.found += 1
                    seg.reported[s + i] = True
                if q.limit is not None and q.stats.found >= q.limit:
                    self._abort(q, "limit")
                    continue
            else:
                seg.outstanding[rows] += n_children[sl]
                # compact this item's children into a new segment
                if (n_children[sl] > 0).any():
                    lo_f, hi_f = woff * child_v.shape[1], \
                        (woff + k) * child_v.shape[1]
                    sel = np.nonzero(cvalid[lo_f:hi_f])[0] + lo_f
                    n_new = len(sel)
                    q.stats.rows_created += n_new
                    cseg = q.new_segment(
                        seg.depth + 1, cf[sel], cu[sel], cp[sel],
                        np.full(n_new, seg.seg_id, np.int32),
                        (par[sel] - woff + s).astype(np.int32))
                    q.push(WorkItem(cseg.seg_id, 0, n_new, "fresh"))

            # immediate resolutions
            items = []
            for i in range(k):
                row = s + i
                if seg.resolved[row]:
                    continue
                if refined_empty[woff + i]:
                    # Lemma 1: Γ = N(u_d) ∩ dom(M̂)
                    gam = q.qnbr_bits[seg.depth] & below(seg.depth)
                    items.append((seg.seg_id, row, False, gam))
                elif (seg.outstanding[row] == 0 and seg.expanded[row]
                      and not seg.pending_leftover[row].any()):
                    if seg.reported[row]:
                        items.append((seg.seg_id, row, True, np.uint64(0)))
                    else:
                        items.append(q.finalize_row(seg, row))
            q.resolve_rows(items)

            if q.max_rows is not None and q.stats.rows_created > q.max_rows:
                self._abort(q, "rows")
            elif not q.segments:
                self._finish(q)
        return True

    # ------------------------------------------------------------------
    # driving
    # ------------------------------------------------------------------
    def poll(self) -> list[int]:
        """Query ids completed since the last poll."""
        done, self._fresh_done = self._fresh_done, []
        return done

    @property
    def idle(self) -> bool:
        return not self.queue and self.pool.n_active == 0

    def run(self) -> dict[int, MatchResult]:
        """Drain all queued and in-flight queries; returns the finished
        map (also available as ``self.finished``)."""
        while self.step():
            pass
        return self.finished

    def scheduler_stats(self) -> dict:
        """Aggregate wave statistics for SLO / occupancy reporting.
        Prune/row totals include still-active queries, so mid-run polling
        sees live numbers."""
        prunes = self.total_prunes + sum(
            q.stats.deadend_prunes for q in self.pool.active_queries())
        rows = self.total_rows_created + sum(
            q.stats.rows_created for q in self.pool.active_queries())
        return {
            "waves": self.waves,
            "rows_packed": self.rows_packed,
            "wave_size": self.wave_size,
            "n_slots": self.n_slots,
            "mean_occupancy": (self.occ_sum / self.waves
                               if self.waves else 0.0),
            "steady_occupancy": (self.occ_sum_steady / self.waves_steady
                                 if self.waves_steady else 0.0),
            "steady_waves": self.waves_steady,
            "peak_active": self.pool.peak_active,
            "queued": len(self.queue),
            "active": self.pool.n_active,
            "deadend_prunes": prunes,
            "rows_created": rows,
            "prune_rate": prunes / max(1, prunes + rows),
        }


class WaveEngine:
    """Single-query facade over :class:`WaveScheduler` (one slot).

    Usage::

        eng = WaveEngine(data_graph)
        res = eng.match(query_graph, limit=1000)
    """

    def __init__(self, data: Graph, wave_size: int = 512, kpr: int = 16,
                 use_pruning: bool = True):
        self.scheduler = WaveScheduler(
            data, n_slots=1, wave_size=wave_size, kpr=kpr,
            use_pruning=use_pruning)

    def match(self, query: Graph, limit: int | None = 1000,
              cand: list[np.ndarray] | None = None,
              order: np.ndarray | None = None,
              max_rows: int | None = None,
              time_budget_s: float | None = None,
              seed_table: TableArrays | None = None) -> MatchResult:
        """``seed_table``: a TableArrays of *transferable* (mu == 0)
        patterns from other shards — see core.distributed."""
        qid = self.scheduler.submit(
            query, limit=limit, cand=cand, order=order, max_rows=max_rows,
            time_budget_s=time_budget_s, seed_table=seed_table,
            keep_table=True)
        self.scheduler.run()
        res = self.scheduler.finished.pop(qid)
        self.scheduler.poll()
        self._table = self.scheduler.tables.pop(qid, None)
        return res


def match_vectorized(query: Graph, data: Graph, limit: int | None = 1000,
                     use_pruning: bool = True, wave_size: int = 512,
                     kpr: int = 16, **kw) -> MatchResult:
    """One-shot convenience wrapper around :class:`WaveEngine`."""
    return WaveEngine(data, wave_size=wave_size, kpr=kpr,
                      use_pruning=use_pruning).match(query, limit=limit,
                                                     **kw)
