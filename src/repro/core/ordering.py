"""Matching-order selection.

The backtracking maps query vertices in a fixed order ``u_1, ..., u_n``.
Requirements and heuristics (mirrors the QuickSI / CFL-Match lineage the
paper builds on):

* connectivity — every prefix must induce a connected subgraph of the
  query (VF2 invariant), so Eq. 2 always constrains the next vertex;
* rarity first — start from the query vertex with the fewest candidates
  (QuickSI's rare-label heuristic, generalized to candidate counts);
* greedy min-candidate expansion — among vertices adjacent to the chosen
  prefix, pick the one with the smallest candidate set, tie-broken by
  higher query degree (more constraints earlier).
"""
from __future__ import annotations

import numpy as np

from .graph import Graph


def connected_min_candidate_order(query: Graph,
                                  cand: list[np.ndarray]) -> np.ndarray:
    """Return a permutation of query vertices (the matching order)."""
    n = query.n
    sizes = np.array([len(c) for c in cand], dtype=np.int64)
    degrees = query.degrees
    # start: fewest candidates; tie-break by high degree then id
    start = min(range(n), key=lambda u: (sizes[u], -degrees[u], u))
    order = [start]
    in_order = np.zeros(n, dtype=bool)
    in_order[start] = True
    frontier = set(int(w) for w in query.neighbors(start))
    for _ in range(n - 1):
        frontier = {u for u in frontier if not in_order[u]}
        if frontier:
            # prefer many already-ordered neighbors (tighter Eq. 2), then
            # fewer candidates, then higher degree
            def key(u: int):
                back = sum(1 for w in query.neighbors(u) if in_order[w])
                return (-back, sizes[u], -degrees[u], u)
            nxt = min(frontier, key=key)
        else:  # disconnected query: jump to rarest unvisited vertex
            nxt = min((u for u in range(n) if not in_order[u]),
                      key=lambda u: (sizes[u], -degrees[u], u))
        order.append(nxt)
        in_order[nxt] = True
        frontier |= {int(w) for w in query.neighbors(nxt)}
    return np.asarray(order, dtype=np.int32)


def rarity_order(query: Graph, data: Graph) -> np.ndarray:
    """QuickSI-style order using label frequency only (no candidate sets)."""
    freq = np.zeros(query.n_labels, dtype=np.int64)
    labs, counts = np.unique(data.labels, return_counts=True)
    freq[labs[labs < query.n_labels]] = counts[labs < query.n_labels]
    fake_cand = [np.empty(int(freq[query.labels[u]]) if
                          query.labels[u] < query.n_labels else 0,
                          dtype=np.int32)
                 for u in range(query.n)]
    return connected_min_candidate_order(query, fake_cand)
