"""Per-query search state for the shared-wave scheduler.

A *segment* is a fixed-shape batch of partial embeddings of one query,
all at one depth. Each concurrent query owns a DFS stack of
:class:`WorkItem` slices over its segments plus the resolution
bookkeeping that implements the paper's Lemma-4 mask aggregation across
waves (DESIGN.md §2): a row resolves when its subtree is exhausted, its
Γ* terms (empty-candidate, injectivity, dead-end, child masks) are
combined, and the resulting dead-end pattern is queued for the batched
device scatter.

:class:`SegmentPool` maps bank slots to live :class:`QueryState` objects
and owns the shared embedding-id counter — the scheduler in
``vectorized.py`` packs waves from whichever queries have ready segments.

Shard-as-segments (DESIGN.md §3): a query submitted with
``parallelism = k`` seeds *k* root segments, one per contiguous slice of
its root-candidate range, and keeps one DFS stack per shard. All shards
live in one bank slot, draw φ ids from the shared pool counter, and
write one slot-private dead-end table — so every pattern (μ > 0
included) learned by one shard prunes every other shard with no
exchange step. An idle shard steals by splitting the largest pending
work-item range of the most loaded shard (``balance_shards``);
per-shard rows/items/steal counters feed the serving reports.

Learning happens *across* waves and across queries' interleavings:
patterns extracted from failures in earlier-expanded subtrees prune later
waves. Matching is exact for any schedule because stored patterns are
true dead-ends.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..patterns.store import mask64, words_from64  # noqa: F401 (re-export)
from .backtrack import SearchStats

_ID_LIMIT = 2**31 - 2**22


def bit_of(p) -> np.uint64:
    return np.uint64(1) << np.uint64(p)


def below(d: int) -> np.uint64:
    return (np.uint64(1) << np.uint64(d)) - np.uint64(1) if d < 64 \
        else np.uint64(0xFFFFFFFFFFFFFFFF)


@dataclasses.dataclass
class Segment:
    seg_id: int
    depth: int                      # mapped positions per row
    frontier: np.ndarray            # int32 [R, N_PAD]
    used: np.ndarray                # uint32 [R, W]
    phi: np.ndarray                 # int32 [R, N_PAD + 1]
    parent_seg: np.ndarray          # int32 [R] (-1 for roots)
    parent_row: np.ndarray          # int32 [R]
    shard: int = 0                  # owning shard (parallelism > 1)
    # resolution state
    outstanding: np.ndarray | None = None   # int64 [R]
    gamma: np.ndarray | None = None         # uint64 [R] accumulated Γ*
    reported: np.ndarray | None = None      # bool [R]
    expanded: np.ndarray | None = None      # bool [R] first pass done
    pending_leftover: np.ndarray | None = None  # uint32 [R, W]
    resolved: np.ndarray | None = None      # bool [R]
    stored: np.ndarray | None = None        # bool [R] pattern already in Δ
    n_unresolved: int = 0

    def init_state(self, w: int) -> None:
        r = len(self.frontier)
        self.outstanding = np.zeros(r, np.int64)
        self.gamma = np.zeros(r, np.uint64)
        self.reported = np.zeros(r, bool)
        self.expanded = np.zeros(r, bool)
        self.pending_leftover = np.zeros((r, w), np.uint32)
        self.resolved = np.zeros(r, bool)
        # True for rows whose Lemma-1 pattern the megastep already
        # scattered into the device table in-loop — the host resolution
        # must not queue a duplicate store for them.
        self.stored = np.zeros(r, bool)
        self.n_unresolved = r


@dataclasses.dataclass
class EngineStats(SearchStats):
    waves: int = 0
    rows_created: int = 0
    patterns_stored: int = 0
    # shard-as-segments accounting (parallelism > 1, DESIGN.md §3)
    steals: int = 0
    shard_rows: list | None = None   # rows created per shard
    shard_items: list | None = None  # work items dispatched per shard
    # cross-query template cache (patterns.cache, DESIGN.md §6)
    cache_hit: bool = False          # Δ was warm-started from the cache
    warm_patterns: int = 0           # entries seeded at admission
    # fault tolerance (DESIGN.md §8)
    fault: str | None = None         # what failed (status == "error")
    fallback: bool = False           # completed on the degraded path


@dataclasses.dataclass
class WorkItem:
    """A ready slice of one segment: rows [start, stop) awaiting a fresh
    expansion or a leftover extraction pass. ``shard`` routes the item to
    one of the query's per-shard DFS stacks (always 0 for
    ``parallelism == 1``); stolen ranges carry the thief's shard id."""
    seg_id: int
    start: int
    stop: int
    kind: str                       # "fresh" | "leftover"
    shard: int = 0


class QueryState:
    """One concurrent query: DFS stack, segments, Lemma-4 resolution."""

    def __init__(self, slot: int, query_id: int, n: int, order: np.ndarray,
                 qnbr_bits: np.ndarray, w: int, *, limit: int | None,
                 learn: bool, max_rows: int | None,
                 deadline: float | None, keep_table: bool,
                 t_submit: float, parallelism: int = 1):
        self.slot = slot
        self.query_id = query_id
        self.n = n
        self.order = order
        self.qnbr_bits = qnbr_bits      # uint64 [N_PAD] query-adjacency bits
        self.w = w
        self.limit = limit
        self.learn = learn
        self.max_rows = max_rows
        self.deadline = deadline        # absolute perf_counter deadline
        self.keep_table = keep_table
        self.t_submit = t_submit
        self.parallelism = max(1, int(parallelism))
        self.stats = EngineStats()
        self.embeddings: list[np.ndarray] = []
        self.segments: dict[int, Segment] = {}
        # one DFS stack per shard (shard-as-segments, DESIGN.md §3)
        self.stacks: list[list[WorkItem]] = [
            [] for _ in range(self.parallelism)]
        self._shard_rr = 0
        self.shard_rows = np.zeros(self.parallelism, np.int64)
        self.shard_items = np.zeros(self.parallelism, np.int64)
        # Δ hit counters per (order position, vertex) key, accumulated
        # from the digests' pruned-child lanes into a sparse dict (the
        # old dense [N_PAD, V] array scaled with the data graph); drives
        # the deterministic cross-host pattern exchange and survives
        # device-side eviction/aging (allocated by the scheduler when
        # the table is exported).
        self.hit_counts: dict[tuple[int, int], int] | None = None
        # packed (depth << 32 | v) int64 hit keys buffered per digest;
        # folded into hit_counts by materialize_hits() at export time so
        # the per-wave hot path never touches the Python dict
        self._hit_buf: list[np.ndarray] = []
        # canonical template fingerprint (patterns.cache) — set at
        # admission so retirement can snapshot under the same key
        self.fingerprint: bytes | None = None
        # streamed-embedding delivery (DESIGN.md §4): the scheduler
        # pushes each newly found batch to ``emb_sink`` as the emitting
        # wave's digest is processed — not at retirement —
        # ``emb_delivered`` is the cursor into ``self.embeddings``.
        self.emb_sink = None
        self.emb_delivered = 0
        self.store_buf: list[tuple[int, int, int, int, np.uint64]] = []
        # "running" | "done" | "quarantined" (torn down for fallback
        # re-admission, no result published — DESIGN.md §8). Only
        # "running" is ``active``; in-flight digests for any other
        # status drop at retire time.
        self.status = "running"
        self.abort_reason: str | None = None  # "limit"|"rows"|"time"|...
        self._next_seg = 0
        # -- device-resident stack path (set by the scheduler at
        # admission when the query runs with no host segments) ----------
        self.device = False
        self.pending_roots: np.ndarray | None = None
        self.root_cursor = 0
        self.dev_roots_inflight = False
        self.dev_wedge = 0
        self.dev_sig = None
        # -- fault tolerance (DESIGN.md §8) -----------------------------
        self.request = None             # originating _Request (replay)
        self.fail_count = 0             # quarantines across incarnations
        self.force_single = False       # fallback: one item per wave
        self.emb_seen: set | None = None  # replay dedup (tobytes keys)

    # -- segment / stack management ------------------------------------
    def new_segment(self, depth: int, frontier: np.ndarray,
                    used: np.ndarray, phi: np.ndarray,
                    parent_seg: np.ndarray, parent_row: np.ndarray,
                    shard: int = 0) -> Segment:
        seg = Segment(self._next_seg, depth, frontier, used, phi,
                      parent_seg, parent_row, shard)
        seg.init_state(self.w)
        self.segments[self._next_seg] = seg
        self._next_seg += 1
        self.shard_rows[shard] += len(frontier)
        return seg

    def push(self, item: WorkItem) -> None:
        self.stacks[item.shard].append(item)

    def _live_top(self, shard: int) -> WorkItem | None:
        """Top live work item of one shard stack (discarding stale ones)."""
        st = self.stacks[shard]
        while st:
            item = st[-1]
            if item.seg_id not in self.segments:
                st.pop()
                continue
            return item
        return None

    def pop_ready(self, kind: str | None = None) -> WorkItem | None:
        """Pop a live work item, round-robin across shard stacks. With
        ``kind`` set, only an item of that kind is taken (the wave's
        picks all share one device program)."""
        for off in range(self.parallelism):
            shard = (self._shard_rr + off) % self.parallelism
            item = self._live_top(shard)
            if item is not None and (kind is None or item.kind == kind):
                self.stacks[shard].pop()
                self._shard_rr = (shard + 1) % self.parallelism
                self.shard_items[shard] += 1
                return item
        return None

    def peek_kind(self) -> str | None:
        """Kind of the next item pop_ready would take (round-robin)."""
        for off in range(self.parallelism):
            item = self._live_top((self._shard_rr + off) % self.parallelism)
            if item is not None:
                return item.kind
        return None

    def balance_shards(self) -> int:
        """Work stealing on work-item ranges (DESIGN.md §3): every idle
        shard splits the largest pending range of the most loaded shard
        and takes the upper half. Sound for any split because items are
        just row ranges of shared segments — the thief's children simply
        carry its shard id. Returns the number of steals."""
        if self.parallelism <= 1:
            return 0
        loads = [sum(it.stop - it.start for it in st
                     if it.seg_id in self.segments)
                 for st in self.stacks]
        steals = 0
        for shard in range(self.parallelism):
            if self._live_top(shard) is not None:
                continue
            donor = int(np.argmax(loads))
            if donor == shard or loads[donor] <= 1:
                continue
            best_i, best_len = -1, 1
            for i, it in enumerate(self.stacks[donor]):
                if (it.seg_id in self.segments
                        and it.stop - it.start > best_len):
                    best_i, best_len = i, it.stop - it.start
            if best_i < 0:
                continue
            it = self.stacks[donor][best_i]
            mid = (it.start + it.stop) // 2
            self.stacks[donor][best_i] = WorkItem(
                it.seg_id, it.start, mid, it.kind, it.shard)
            self.stacks[shard].append(WorkItem(
                it.seg_id, mid, it.stop, it.kind, shard))
            loads[donor] -= it.stop - mid
            loads[shard] += it.stop - mid
            steals += 1
        self.stats.steals += steals
        return steals

    def note_hits(self, depth, pruned_v) -> None:
        """Accumulate Δ hit counters from a digest's pruned-child lane
        (``pruned_v`` int32 [..., KPR], -1 padding; a prune at row depth
        d on vertex v is one hit on table key (d, v))."""
        if self.hit_counts is None:
            return
        pv = np.asarray(pruned_v)
        dd = np.broadcast_to(np.asarray(depth)[..., None], pv.shape)
        sel = pv >= 0
        if sel.any():
            # buffer packed int64 keys; the dict fold happens once in
            # materialize_hits(), not on every digest
            self._hit_buf.append(
                (dd[sel].astype(np.int64) << np.int64(32)) | pv[sel])

    def materialize_hits(self) -> None:
        """Fold every buffered ``note_hits`` batch into ``hit_counts``
        with a single ``np.unique``/``bincount`` pass (the old per-key
        Python loop walked each digest separately)."""
        if self.hit_counts is None or not self._hit_buf:
            return
        buf = self._hit_buf
        self._hit_buf = []
        if self.hit_counts:
            old = np.fromiter(
                ((np.int64(d) << np.int64(32)) | np.int64(v)
                 for d, v in self.hit_counts), np.int64,
                count=len(self.hit_counts))
            weights = np.concatenate(
                [np.fromiter(self.hit_counts.values(), np.float64,
                             count=len(self.hit_counts))]
                + [np.ones(len(b)) for b in buf])
            flat = np.concatenate([old] + buf)
        else:
            flat = np.concatenate(buf)
            weights = np.ones(len(flat))
        uniq, inv = np.unique(flat, return_inverse=True)
        counts = np.bincount(inv, weights=weights).astype(np.int64)
        self.hit_counts = {
            (int(f >> 32), int(f & 0xFFFFFFFF)): int(c)
            for f, c in zip(uniq.tolist(), counts.tolist())}

    def evict(self) -> None:
        """Drop all in-flight work (abort / completion)."""
        self.segments.clear()
        for st in self.stacks:
            st.clear()
        self.store_buf.clear()

    # -- Lemma-4 resolution bookkeeping --------------------------------
    def queue_store(self, seg: Segment, row: int, gamma: np.uint64) -> None:
        """Record the dead-end pattern of a resolved-dead row.

        ``stats.patterns_stored`` counts at queue time (patterns
        *learned*): the actual device scatter is batched across waves
        and fused into the megastep dispatch, so flush time no longer
        maps 1:1 to a wave. Rows the megastep already stored in-loop
        (``seg.stored``) are skipped — their pattern is in Δ.
        """
        if not self.learn or self.stats.aborted:
            return
        if seg.stored[row]:
            return
        d = seg.depth
        if d == 0:
            return
        key_pos = d - 1
        key_v = int(seg.frontier[row, key_pos])
        below_mask = gamma & below(key_pos)
        if below_mask:
            mu_len = int(below_mask).bit_length()   # highest set bit + 1
        else:
            mu_len = 0
        phi_id = int(seg.phi[row, mu_len])
        self.store_buf.append((key_pos, key_v, phi_id, mu_len, gamma))
        self.stats.patterns_stored += 1

    def has_leftover(self, seg: Segment, row: int) -> bool:
        return bool(seg.pending_leftover[row].any())

    def finalize_row(self, seg: Segment, row: int
                     ) -> tuple[int, int, bool, np.uint64]:
        """All children of this row are resolved: Lemma 4 conversion."""
        if seg.reported[row]:
            return (seg.seg_id, row, True, np.uint64(0))
        d = seg.depth
        gamma = seg.gamma[row]
        if gamma & bit_of(d):
            gamma = (gamma | self.qnbr_bits[d]) & below(d)
        return (seg.seg_id, row, False, gamma)

    def resolve_rows(self, items: list[tuple[int, int, bool, np.uint64]]
                     ) -> None:
        """Worklist of (seg_id, row, reported, gamma) resolutions,
        propagating up through parent segments."""
        while items:
            sid, row, reported, gamma = items.pop()
            seg = self.segments.get(sid)
            if seg is None or seg.resolved[row]:
                continue
            seg.resolved[row] = True
            seg.n_unresolved -= 1
            if not reported:
                self.queue_store(seg, row, gamma)
            ps, pr = int(seg.parent_seg[row]), int(seg.parent_row[row])
            if ps >= 0:
                pseg = self.segments[ps]
                if reported:
                    pseg.reported[pr] = True
                else:
                    pseg.gamma[pr] |= gamma
                pseg.outstanding[pr] -= 1
                if (pseg.outstanding[pr] == 0 and pseg.expanded[pr]
                        and not self.has_leftover(pseg, pr)):
                    items.append(self.finalize_row(pseg, pr))
            if seg.n_unresolved == 0:
                del self.segments[sid]

    @property
    def active(self) -> bool:
        return self.status == "running"


class SegmentPool:
    """Slot table of live queries plus the shared embedding-id counter."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.slots: list[QueryState | None] = [None] * n_slots
        self.id_counter = 1
        self.learning_enabled = True
        self.peak_active = 0

    def free_slot(self) -> int | None:
        for i, q in enumerate(self.slots):
            if q is None:
                return i
        return None

    def attach(self, slot: int, q: QueryState) -> None:
        assert self.slots[slot] is None
        self.slots[slot] = q
        self.peak_active = max(self.peak_active, self.n_active)

    def release(self, slot: int) -> None:
        self.slots[slot] = None
        if self.n_active == 0 and not self.learning_enabled:
            # id-space overflow recovery: once the pool drains, no live
            # phi value can collide with fresh ids, so learning restarts.
            self.id_counter = 1
            self.learning_enabled = True

    @property
    def n_active(self) -> int:
        return sum(q is not None for q in self.slots)

    def active_queries(self) -> list[QueryState]:
        return [q for q in self.slots if q is not None and q.active]

    def alloc_ids(self, n: int) -> int:
        """Reserve ``n`` fresh embedding ids; returns the base id. On
        overflow, learning pauses (tables are cleared by the scheduler)
        until the pool drains — matching stays exact throughout."""
        base = self.id_counter
        self.id_counter += n
        return base

    @property
    def id_overflow(self) -> bool:
        return self.id_counter > _ID_LIMIT
