"""First-class failure-pattern subsystem (paper §4.4 grown up).

The paper's speedup lives in the dead-end pattern table Δ; this package
makes Δ a subsystem instead of an engine detail:

* ``store``  — the bounded hashed device store (O(capacity) memory,
  in-kernel probe/insert lanes, counter-guided eviction) plus the
  layout-independent host *entries* form used by exchange, checkpoints
  and the cache.
* ``cache``  — the cross-query template cache: retiring queries snapshot
  their hot transferable patterns, recurring templates warm-start.
* ``tables`` — the sequential host reference tables (set-semantic and
  numeric) that anchor the soundness arguments and the oracle tests.
"""
from .cache import CacheStats, PatternCache
from .store import (ENTRY_KEYS, MASK_WORDS, PROBE, PatternStore,
                    PatternStoreBank, StoreCounters, age_hits,
                    empty_entries, entries_to_store, hash_insert,
                    hash_probe, mask64, probe_slots, select_entries,
                    store_to_entries, words_from64)
from .tables import DeadEndStats, NumericDeadEndTable, SetDeadEndTable

__all__ = [
    "CacheStats", "PatternCache",
    "ENTRY_KEYS", "MASK_WORDS", "PROBE", "PatternStore",
    "PatternStoreBank", "StoreCounters", "age_hits", "empty_entries",
    "entries_to_store", "hash_insert", "hash_probe", "mask64",
    "probe_slots", "select_entries", "store_to_entries", "words_from64",
    "DeadEndStats", "NumericDeadEndTable", "SetDeadEndTable",
]
