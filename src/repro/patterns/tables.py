"""Host reference implementations of the dead-end pattern table Δ
(paper §4.4) plus the shared stats record.

Two interchangeable table implementations:

* :class:`SetDeadEndTable` — stores patterns as explicit mapping sets and
  matches with real ``D ⊆ M̂`` containment. O(|D|) per check. Used by tests
  as the semantic reference for the numeric representation.

* :class:`NumericDeadEndTable` — the paper's O(1) scheme (§4.4.2): each
  pattern is the triplet ``(φ, μ, Γ)`` where ``φ`` is the embedding ID of
  the storing embedding's length-``μ`` prefix and ``Γ`` is the dead-end
  mask (kept for Lemma-3 propagation). A partial embedding with ancestor
  ID array ``Φ`` matches iff ``Φ[μ] == φ``. This matches *fewer* embeddings
  than true containment (prefix-identity is stronger than subset), hence
  remains sound; in exchange both lookup and match are O(1).

Keys: the paper keys the hash table by the last mapping ``(u_k, v)``.
Since the matching order fixes which query vertex sits at each depth, we
key by ``(depth_position, data_vertex)``.

Both tables are *advisory*: overwrites or capacity evictions can only lose
pruning opportunities, never correctness (Theorem 1 relies only on every
stored pattern being a true dead-end). The device-side bounded hashed
store (``patterns.store``) leans on exactly this invariant for its
counter-guided eviction; :class:`DeadEndStats` is the shared accounting
record for both — the engine fills the eviction/occupancy fields from the
megastep digest counters.

With device-resident stacks (``engine_step.run_device_megastep``) the
in-loop Δ stores are fed from rows that never exist on the host: Lemma-1
patterns at expansion time and Lemma-4 patterns at on-device finalize
(``_resolution_sweep``), both through ``store_patterns_mq`` against the
same advisory invariant. The host tables here stay the oracle the
device-path equality tests pin against.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class DeadEndStats:
    stores: int = 0
    # per-probe lookup count: maintained by the host reference tables
    # only — the engine's device digest carries no lookup count, so on
    # the engine path this stays 0 while ``hits`` counts Δ prunes
    lookups: int = 0
    hits: int = 0
    overwrites: int = 0
    # bounded-store accounting (device hashed Δ; always 0 for the
    # unbounded host reference tables)
    evictions: int = 0
    dropped: int = 0
    occupancy: int = 0          # valid entries at read-out time
    capacity: int = 0           # 0 = unbounded


class SetDeadEndTable:
    """Reference implementation with exact subset matching."""

    def __init__(self, n_query: int):
        self.n_query = n_query
        self.table: dict[tuple[int, int], frozenset[tuple[int, int]]] = {}
        self.stats = DeadEndStats()

    def store(self, pos: int, v: int, mapping: list[int],
              mask_positions: frozenset[int], phi: np.ndarray) -> None:
        """Record pattern {(p, mapping[p]) : p in mask} at key (pos, v).

        ``mapping`` is the current partial embedding as a list of data
        vertices indexed by order position; ``pos`` is the position of the
        last mapping (== len(mapping) - 1) and ``v == mapping[pos]``.
        """
        del phi  # unused in the set representation
        pattern = frozenset((p, mapping[p]) for p in mask_positions)
        if (pos, v) in self.table:
            self.stats.overwrites += 1
        self.table[(pos, v)] = pattern
        self.stats.stores += 1

    def match(self, pos: int, v: int, mapping: list[int],
              phi: np.ndarray) -> frozenset[int] | None:
        """If extending with position ``pos`` -> ``v`` hits a pattern,
        return the pattern's mask positions (for Lemma 3); else None."""
        del phi
        self.stats.lookups += 1
        pat = self.table.get((pos, v))
        if pat is None:
            return None
        for (p, pv) in pat:
            if p >= len(mapping) or mapping[p] != pv:
                return None
        self.stats.hits += 1
        return frozenset(p for p, _ in pat)


class NumericDeadEndTable:
    """The paper's O(1) numeric representation (§4.4.2)."""

    def __init__(self, n_query: int):
        self.n_query = n_query
        # key (pos, v) -> (phi_id, mu_len, mask_positions)
        self.table: dict[tuple[int, int], tuple[int, int, frozenset[int]]] = {}
        self.stats = DeadEndStats()

    def store(self, pos: int, v: int, mapping: list[int],
              mask_positions: frozenset[int], phi: np.ndarray) -> None:
        # ignore the key's own position (the key encodes it, §4.4.2)
        below = [p for p in mask_positions if p < pos]
        mu_len = (max(below) + 1) if below else 0
        phi_id = int(phi[mu_len])
        if (pos, v) in self.table:
            self.stats.overwrites += 1
        self.table[(pos, v)] = (phi_id, mu_len, frozenset(mask_positions))
        self.stats.stores += 1

    def match(self, pos: int, v: int, mapping: list[int],
              phi: np.ndarray) -> frozenset[int] | None:
        self.stats.lookups += 1
        entry = self.table.get((pos, v))
        if entry is None:
            return None
        phi_id, mu_len, mask = entry
        if int(phi[mu_len]) != phi_id:
            return None
        self.stats.hits += 1
        return mask
