"""Bounded hashed device store for failure patterns (Δ, paper §4.4).

The dead-end table used to be a dense ``[S, N_PAD, V]`` bank — resident
memory grew with the data-graph vertex count and most of it sat empty
(patterns are sparse: one per *discovered* dead-end key, not one per
possible key). This module replaces it with a bounded open-addressing
hash store:

* :class:`PatternStoreBank` — per-slot arrays ``[S, C]`` where ``C`` is
  the configured capacity (a power of two). Each entry holds the key
  ``(order position, data vertex)`` explicitly plus the paper's numeric
  pattern ``(φ, μ, Γ)`` and a device-side hit counter.
* :func:`hash_probe` / :func:`hash_insert` — the in-kernel probe and
  insert lanes: multiplicative hash of the key, linear probing over a
  fixed ``PROBE``-slot window. Inserts reuse a matching-key slot
  (overwrite), else the first empty slot, else **evict** the
  lowest-hit-counter slot of the window (counter-guided eviction).
  Batched inserts resolve in-batch conflicts deterministically
  (last-write-wins per target slot, all lanes consistent) and return
  per-slot counters (stored / overwrites / evictions / drops) so the
  digest can surface them.

Soundness: the table is *advisory* (see ``core.deadend``) — a lost,
evicted, or dropped pattern only loses pruning opportunity, never
correctness, because every stored pattern is a true dead-end and lookups
only ever *skip* work. Capacity and probe-window pressure therefore
trade memory for prune rate, not for exactness; the
tiny-capacity oracle-equality tests pin this.

Host helpers convert between the device layout and a compact *entries*
dict (``pos/v/phi/mu/mask/hits`` arrays over valid entries only) used by
the cross-host exchange, checkpoints, and the template cache — the
entries form is layout-independent, so a snapshot written under one
capacity restores under any other.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

MASK_WORDS = 2          # dead-end masks cover up to 64 query positions
PROBE = 8               # linear-probe window length (static)

ENTRY_KEYS = ("pos", "v", "phi", "mu", "mask", "hits")


class PatternStore(NamedTuple):
    """One query slot's hashed Δ store (capacity C entries)."""
    key_pos: jax.Array       # int32 [C] order position of the key (-1 empty)
    key_v: jax.Array         # int32 [C] data vertex of the key
    phi: jax.Array           # int32 [C] stored prefix id φ
    mu: jax.Array            # int32 [C] prefix length μ
    mask: jax.Array          # uint32 [C, MASK_WORDS] dead-end mask Γ
    valid: jax.Array         # bool [C]
    hits: jax.Array          # int32 [C] device hit counter (aged)

    @staticmethod
    def empty(capacity: int) -> "PatternStore":
        c = _check_capacity(capacity)
        return PatternStore(
            key_pos=jnp.full((c,), -1, jnp.int32),
            key_v=jnp.full((c,), -1, jnp.int32),
            phi=jnp.zeros((c,), jnp.int32),
            mu=jnp.zeros((c,), jnp.int32),
            mask=jnp.zeros((c, MASK_WORDS), jnp.uint32),
            valid=jnp.zeros((c,), bool),
            hits=jnp.zeros((c,), jnp.int32))


class PatternStoreBank(NamedTuple):
    """Per-slot hashed Δ stores, stacked along the query-slot axis."""
    key_pos: jax.Array       # int32 [S, C]
    key_v: jax.Array         # int32 [S, C]
    phi: jax.Array           # int32 [S, C]
    mu: jax.Array            # int32 [S, C]
    mask: jax.Array          # uint32 [S, C, MASK_WORDS]
    valid: jax.Array         # bool [S, C]
    hits: jax.Array          # int32 [S, C]

    @property
    def capacity(self) -> int:
        return self.phi.shape[1]

    @staticmethod
    def empty(n_slots: int, capacity: int) -> "PatternStoreBank":
        c = _check_capacity(capacity)
        s = n_slots
        return PatternStoreBank(
            key_pos=jnp.full((s, c), -1, jnp.int32),
            key_v=jnp.full((s, c), -1, jnp.int32),
            phi=jnp.zeros((s, c), jnp.int32),
            mu=jnp.zeros((s, c), jnp.int32),
            mask=jnp.zeros((s, c, MASK_WORDS), jnp.uint32),
            valid=jnp.zeros((s, c), bool),
            hits=jnp.zeros((s, c), jnp.int32))


class StoreCounters(NamedTuple):
    """Per-slot insert accounting of one batched scatter (int32 [S])."""
    stored: jax.Array        # entries written (new + overwrites + evicting)
    overwrites: jax.Array    # matching key re-stored in place
    evictions: jax.Array     # lowest-hit entry displaced (window full)
    dropped: jax.Array       # lost to an in-batch target conflict

    @staticmethod
    def zeros(n_slots: int) -> "StoreCounters":
        z = jnp.zeros((n_slots,), jnp.int32)
        return StoreCounters(z, z, z, z)

    def add(self, other: "StoreCounters") -> "StoreCounters":
        return StoreCounters(*(a + b for a, b in zip(self, other)))


def _check_capacity(capacity: int) -> int:
    c = int(capacity)
    if c < PROBE or (c & (c - 1)) != 0:
        raise ValueError(
            f"pattern store capacity must be a power of two >= {PROBE}, "
            f"got {capacity}")
    return c


def _hash0(key_pos: jax.Array, key_v: jax.Array, capacity: int) -> jax.Array:
    """Multiplicative hash of (pos, v) onto [0, capacity)."""
    h = (key_v.astype(jnp.uint32) * jnp.uint32(2654435761)
         ^ key_pos.astype(jnp.uint32) * jnp.uint32(0x9E3779B9))
    h ^= h >> 15
    return (h & jnp.uint32(capacity - 1)).astype(jnp.int32)


def probe_slots(key_pos: jax.Array, key_v: jax.Array,
                capacity: int) -> jax.Array:
    """Linear-probe window: int32 [..., PROBE] store indices per key."""
    h0 = _hash0(key_pos, key_v, capacity)
    offs = jnp.arange(PROBE, dtype=jnp.int32)
    return (h0[..., None] + offs) & jnp.int32(capacity - 1)


def hash_probe(bank: PatternStoreBank, slot: jax.Array, key_pos: jax.Array,
               key_v: jax.Array
               ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                          jax.Array]:
    """Probe flat key arrays [M] against the bank.

    Returns (found bool [M], phi int32 [M], mu int32 [M],
    mask uint32 [M, MASK_WORDS], idx int32 [M]) where ``idx`` is the
    matched store index (0 when not found — gate on ``found``).
    """
    c = bank.capacity
    ps = probe_slots(key_pos, key_v, c)                      # [M, P]
    s2 = slot[:, None]
    match = (bank.valid[s2, ps]
             & (bank.key_pos[s2, ps] == key_pos[:, None])
             & (bank.key_v[s2, ps] == key_v[:, None]))      # [M, P]
    found = match.any(axis=1)
    j = jnp.argmax(match, axis=1)
    idx = jnp.take_along_axis(ps, j[:, None], axis=1)[:, 0]
    idx = jnp.where(found, idx, 0)
    sl = jnp.where(found, slot, 0)
    return (found, bank.phi[sl, idx], bank.mu[sl, idx],
            bank.mask[sl, idx], idx)


INSERT_ROUNDS = 3       # in-batch conflict retries (static unroll)


def hash_insert(bank: PatternStoreBank, slot: jax.Array, key_pos: jax.Array,
                key_v: jax.Array, phis: jax.Array, mus: jax.Array,
                masks: jax.Array, valid: jax.Array
                ) -> tuple[PatternStoreBank, StoreCounters]:
    """Batched Δ insert with counter-guided eviction (flat arrays [N]).

    Target selection per entry: a matching-key slot in the probe window
    (overwrite, hit counter preserved), else the first empty slot, else
    the window's lowest-hit slot (eviction, hit counter reset). In-batch
    conflicts on one (slot, target) pair keep the *last* entry — chosen
    per target index, so all lanes of the surviving entry are written
    consistently (a mixed-lane write could fabricate a pattern that is
    not a true dead-end; a dropped one merely loses pruning). Entries
    that lose to a *different-key* winner retry against the updated bank
    for up to ``INSERT_ROUNDS`` rounds (one wave's batch shares probe
    windows heavily — a single pre-state pass would drop most of a
    congested batch); entries superseded by a later same-key store do
    not retry (last write wins, as the dense scatter behaved).
    """
    n_slots = bank.valid.shape[0]

    def cond(state):
        _, _, remaining, it = state
        return remaining.any() & (it < INSERT_ROUNDS)

    def body(state):
        bank, counters, remaining, it = state
        bank, round_counters, remaining = _insert_round(
            bank, slot, key_pos, key_v, phis, mus, masks, remaining)
        return bank, counters.add(round_counters), remaining, it + 1

    # while_loop, not an unrolled scan: the typical batch resolves in
    # one round (same-key duplicates don't retry), so later rounds
    # usually never execute at all
    bank, counters, remaining, _ = lax.while_loop(
        cond, body,
        (bank, StoreCounters.zeros(n_slots), valid, jnp.int32(0)))
    return bank, counters._replace(
        dropped=counters.dropped + _count_per_slot(remaining, slot,
                                                   n_slots))


def _count_per_slot(sel: jax.Array, slot: jax.Array,
                    n_slots: int) -> jax.Array:
    return jnp.zeros((n_slots,), jnp.int32).at[
        jnp.where(sel, slot, n_slots)].add(1, mode="drop")


def _insert_round(bank: PatternStoreBank, slot: jax.Array,
                  key_pos: jax.Array, key_v: jax.Array, phis: jax.Array,
                  mus: jax.Array, masks: jax.Array, valid: jax.Array
                  ) -> tuple[PatternStoreBank, StoreCounters, jax.Array]:
    """One conflict-resolution round of :func:`hash_insert`. Returns the
    updated bank, this round's counters (``dropped`` always 0 — losers
    either retry or are superseded), and the entries still to insert."""
    n = slot.shape[0]
    n_slots, c = bank.valid.shape
    ps = probe_slots(key_pos, key_v, c)                      # [N, P]
    s2 = jnp.where(valid, slot, 0)[:, None]
    wvalid = bank.valid[s2, ps]
    match = (wvalid & (bank.key_pos[s2, ps] == key_pos[:, None])
             & (bank.key_v[s2, ps] == key_v[:, None]))
    whits = bank.hits[s2, ps]
    has_match = match.any(axis=1)
    empty = ~wvalid
    has_empty = empty.any(axis=1)
    arange = jnp.arange(n, dtype=jnp.int32)
    # decorrelated empty-slot pick: an entry takes the (spread(key) mod
    # n_empty)-th empty slot of its window, not the first — distinct
    # keys whose windows overlap (one congested wave batch) then mostly
    # land on distinct slots instead of all racing for one (lookups scan
    # the whole window, so any in-window slot is equivalent). The spread
    # is a second hash of the KEY, not the batch position: same-key
    # entries must pick the same target so the (slot, target) dedup
    # below collapses them to one write (last wins), as the dense
    # scatter behaved — a position-based spread would store duplicates.
    spread = (key_v.astype(jnp.uint32) * jnp.uint32(0x85EBCA6B)
              ^ key_pos.astype(jnp.uint32) * jnp.uint32(0xC2B2AE35))
    spread ^= spread >> 13
    n_empty = empty.sum(axis=1).astype(jnp.int32)
    want = (spread % jnp.maximum(n_empty, 1).astype(jnp.uint32)
            ).astype(jnp.int32)[:, None]
    ranks = jnp.cumsum(empty, axis=1).astype(jnp.int32) - 1
    j_empty = jnp.argmax(empty & (ranks == want), axis=1)
    j = jnp.where(has_match, jnp.argmax(match, axis=1),
                  jnp.where(has_empty, j_empty,
                            jnp.argmin(whits, axis=1)))
    target = jnp.take_along_axis(ps, j[:, None], axis=1)[:, 0]  # [N]

    # in-batch dedup: exactly one winner per (slot, target) pair
    flat = slot * c + target
    winner = jnp.full((n_slots * c,), -1, jnp.int32).at[
        jnp.where(valid, flat, n_slots * c)].max(
            jnp.where(valid, arange, -1), mode="drop")
    keep = valid & (winner[flat] == arange)

    qs = jnp.where(keep, slot, n_slots)          # OOB row -> dropped
    # a dropped entry whose *winner* carries the same key was simply
    # superseded in-batch (the dense scatter's last-write-wins) — count
    # it as an overwrite; only a different-key winner means real loss
    widx = winner[flat].clip(0)
    same_key = (key_pos == key_pos[widx]) & (key_v == key_v[widx])
    kept_hits = jnp.where(
        has_match, jnp.take_along_axis(whits, j[:, None], axis=1)[:, 0], 0)
    bank2 = PatternStoreBank(
        key_pos=bank.key_pos.at[qs, target].set(key_pos, mode="drop"),
        key_v=bank.key_v.at[qs, target].set(key_v, mode="drop"),
        phi=bank.phi.at[qs, target].set(phis, mode="drop"),
        mu=bank.mu.at[qs, target].set(mus, mode="drop"),
        mask=bank.mask.at[qs, target].set(masks, mode="drop"),
        valid=bank.valid.at[qs, target].set(True, mode="drop"),
        hits=bank.hits.at[qs, target].set(kept_hits, mode="drop"))

    superseded = valid & ~keep & same_key
    retry = valid & ~keep & ~same_key
    counters = StoreCounters(
        stored=_count_per_slot(keep, slot, n_slots),
        overwrites=_count_per_slot((keep & has_match) | superseded,
                                   slot, n_slots),
        evictions=_count_per_slot(keep & ~has_match & ~has_empty,
                                  slot, n_slots),
        dropped=jnp.zeros((n_slots,), jnp.int32))
    return bank2, counters, retry


def age_hits(bank: PatternStoreBank) -> PatternStoreBank:
    """Halve every hit counter (periodic aging so eviction tracks
    *recent* usefulness instead of all-time history)."""
    return bank._replace(hits=bank.hits >> 1)


# ===================================================================
# host-side entries form (numpy) — layout-independent snapshot
# ===================================================================
def mask64(words: np.ndarray) -> np.ndarray:
    """uint32 [..., 2] -> uint64 [...]."""
    w = np.asarray(words).astype(np.uint64)
    return w[..., 0] | (w[..., 1] << np.uint64(32))


def words_from64(m: np.ndarray) -> np.ndarray:
    out = np.zeros(np.shape(m) + (MASK_WORDS,), np.uint32)
    out[..., 0] = (m & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    out[..., 1] = (m >> np.uint64(32)).astype(np.uint32)
    return out


def empty_entries() -> dict:
    return {"pos": np.zeros(0, np.int32), "v": np.zeros(0, np.int32),
            "phi": np.zeros(0, np.int32), "mu": np.zeros(0, np.int32),
            "mask": np.zeros(0, np.uint64), "hits": np.zeros(0, np.int64)}


def store_to_entries(store: PatternStore,
                     hit_counts: dict | None = None) -> dict:
    """Snapshot a slot's store into the compact entries dict.

    Entries are sorted by (pos, v) so snapshots of identical table state
    are byte-identical (deterministic exchange/checkpoint). ``hit_counts``
    (host-cumulative ``{(pos, v): n}``) overrides the device hit lane
    when given — the device counter is aged and reset on eviction, the
    host one survives both.
    """
    valid = np.asarray(store.valid)
    sel = np.nonzero(valid)[0]
    pos = np.asarray(store.key_pos)[sel]
    v = np.asarray(store.key_v)[sel]
    order = np.lexsort((v, pos))
    pos, v, sel = pos[order], v[order], sel[order]
    hits = np.asarray(store.hits)[sel].astype(np.int64)
    if hit_counts:
        # one vectorized searchsorted pass over packed (pos, v) keys —
        # this runs on the periodic checkpoint path, where a per-entry
        # Python loop over a near-full store would stall the host
        hk = np.fromiter(((p << 32) | vv for p, vv in hit_counts),
                         np.int64, len(hit_counts))
        hv = np.fromiter(hit_counts.values(), np.int64, len(hit_counts))
        ho = np.argsort(hk)
        hk, hv = hk[ho], hv[ho]
        ek = (pos.astype(np.int64) << 32) | v
        idx = np.clip(np.searchsorted(hk, ek), 0, len(hk) - 1)
        matched = hk[idx] == ek
        hits = np.where(matched, np.maximum(hits, hv[idx]), hits)
    return {"pos": pos.astype(np.int32), "v": v.astype(np.int32),
            "phi": np.asarray(store.phi)[sel].astype(np.int32),
            "mu": np.asarray(store.mu)[sel].astype(np.int32),
            "mask": mask64(np.asarray(store.mask)[sel]),
            "hits": hits}


def entries_to_store(entries: dict, capacity: int) -> PatternStore:
    """Rebuild a device-layout store from an entries dict (any capacity).

    Entries are placed hottest-first with the same hash/probe layout the
    device uses; when a probe window is full the (colder) newcomer is
    dropped — sound, and consistent with the device eviction policy.
    Placement is vectorized (PROBE offset rounds; within a round the
    hottest contender wins each free slot, losers try the next offset)
    so restoring a full web-scale store costs numpy passes, not ~n·PROBE
    interpreted iterations on the admission path.
    """
    c = _check_capacity(capacity)
    key_pos = np.full(c, -1, np.int32)
    key_v = np.full(c, -1, np.int32)
    phi = np.zeros(c, np.int32)
    mu = np.zeros(c, np.int32)
    mask = np.zeros((c, MASK_WORDS), np.uint32)
    valid = np.zeros(c, bool)
    hits = np.zeros(c, np.int32)
    pos_a = np.asarray(entries["pos"], np.int32)
    v_a = np.asarray(entries["v"], np.int32)
    h_a = np.asarray(entries["hits"], np.int64)
    # hottest first; (pos, v) tie-break keeps placement deterministic
    order = np.lexsort((v_a, pos_a, -h_a))
    pos_a, v_a, h_a = pos_a[order], v_a[order], h_a[order]
    phi_a = np.asarray(entries["phi"], np.int32)[order]
    mu_a = np.asarray(entries["mu"], np.int32)[order]
    mask_words = words_from64(np.asarray(entries["mask"], np.uint64))[order]
    h0 = np.asarray(_hash0(jnp.asarray(pos_a), jnp.asarray(v_a), c))
    placed = np.zeros(len(pos_a), bool)
    for off in range(PROBE):
        rem = np.nonzero(~placed)[0]            # still in hotness order
        if len(rem) == 0:
            break
        t = (h0[rem] + off) & (c - 1)
        # hottest contender wins each free slot; losers retry next off
        _, first = np.unique(t, return_index=True)
        winner = np.zeros(len(rem), bool)
        winner[first] = True
        ok = winner & ~valid[t]
        sel, ts = rem[ok], t[ok]
        key_pos[ts] = pos_a[sel]
        key_v[ts] = v_a[sel]
        phi[ts] = phi_a[sel]
        mu[ts] = mu_a[sel]
        mask[ts] = mask_words[sel]
        valid[ts] = True
        hits[ts] = np.minimum(h_a[sel], 2**31 - 1).astype(np.int32)
        placed[sel] = True
    return PatternStore(key_pos=jnp.asarray(key_pos),
                        key_v=jnp.asarray(key_v), phi=jnp.asarray(phi),
                        mu=jnp.asarray(mu), mask=jnp.asarray(mask),
                        valid=jnp.asarray(valid), hits=jnp.asarray(hits))


def select_entries(entries: dict, top_k: int | None,
                   transferable_only: bool = True) -> dict:
    """Deterministic top-k selection over an entries dict.

    Ranked by hit counter descending (the patterns that actually pruned
    travel first), ties broken by (pos, v) ascending — every host
    selects the identical set from identical state. With
    ``transferable_only`` only μ == 0 entries are kept: their match
    condition Φ[0] == 0 holds in every engine, so they are sound without
    a φ floor (μ > 0 entries reference the writer's φ numbering and need
    :meth:`WaveScheduler.reserve_phi_floor` on import).
    """
    sel = np.ones(len(entries["pos"]), bool)
    if transferable_only:
        sel &= np.asarray(entries["mu"]) == 0
    idx = np.nonzero(sel)[0]
    if top_k is not None and len(idx) > top_k:
        pos = np.asarray(entries["pos"])[idx]
        v = np.asarray(entries["v"])[idx]
        h = np.asarray(entries["hits"])[idx]
        rank = np.lexsort((v, pos, -h))
        idx = np.sort(idx[rank[:top_k]])
    return {k: np.asarray(entries[k])[idx] for k in ENTRY_KEYS}
