"""Cross-query pattern cache: warm-start Δ for recurring query templates.

A serving system with millions of users sees the same query *templates*
over and over (the same shape/labels, often literally the same query).
The paper's table Δ dies with its query slot, so every resubmission
relearns the same dead-ends from scratch. :class:`PatternCache` closes
that loop on the host: when a learning query retires, its hot patterns
are snapshotted under a canonical template fingerprint; when an
equivalent template is admitted later, the snapshot warm-starts the new
slot's store so known dead-ends prune from the very first wave.

Template canonicalization — *exact device-array identity*. The engine's
behavior for a query is fully determined by the order-permuted device
arrays it is loaded with: ``(n_query, cand_bitmap, nbr_mask)``. The
fingerprint is a digest of exactly those bytes, so two queries share a
cache line iff the engine literally cannot tell them apart (isomorphic
queries normalize to the same arrays whenever the candidate filters and
ordering heuristic map them the same way — no graph-isomorphism solve
is needed, and there are no false positives by construction).

Soundness — *μ == 0 entries only*. A μ == 0 pattern's set form is
``{(key_pos, key_v)}`` ⊆ the key itself, and its numeric condition
``Φ[0] == 0`` holds for every row of every query (root prefixes all
share id 0): it asserts "mapping this order position to this data vertex
is dead regardless of the prefix", which transfers verbatim to any query
with identical device arrays. μ > 0 entries reference the writer's φ
numbering and would never fire for a fresh query anyway (its prefix ids
are all newer), so the cache does not spend capacity on them.

The cache itself is bounded: ``max_templates`` LRU template lines of at
most ``top_k`` entries each (hit-counter ranked) — O(configured size)
resident memory, independent of data-graph or traffic scale.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib

import numpy as np

from .store import ENTRY_KEYS, select_entries


@dataclasses.dataclass
class CacheStats:
    lookups: int = 0
    hits: int = 0
    inserts: int = 0
    evictions: int = 0
    warm_patterns: int = 0      # total entries handed out on hits


class PatternCache:
    """LRU map: template fingerprint -> hot μ == 0 pattern entries."""

    def __init__(self, max_templates: int = 64, top_k: int = 512):
        self.max_templates = int(max_templates)
        self.top_k = int(top_k)
        self._lines: collections.OrderedDict[bytes, dict] = \
            collections.OrderedDict()
        self.stats = CacheStats()

    @staticmethod
    def fingerprint(n_query: int, cand_bitmap: np.ndarray,
                    nbr_mask: np.ndarray) -> bytes:
        """Canonical template key: digest of the exact device arrays."""
        h = hashlib.sha1()
        h.update(int(n_query).to_bytes(4, "little"))
        h.update(np.ascontiguousarray(cand_bitmap).tobytes())
        h.update(np.ascontiguousarray(nbr_mask).tobytes())
        return h.digest()

    def __len__(self) -> int:
        return len(self._lines)

    def get(self, fp: bytes) -> dict | None:
        """Entries for a template (or None). Counts as one lookup."""
        self.stats.lookups += 1
        line = self._lines.get(fp)
        if line is None or len(line["pos"]) == 0:
            return None
        self._lines.move_to_end(fp)
        self.stats.hits += 1
        self.stats.warm_patterns += len(line["pos"])
        return {k: line[k].copy() for k in ENTRY_KEYS}

    def put(self, fp: bytes, entries: dict) -> int:
        """Fold a retiring query's entries into the template's line.

        Only μ == 0 entries are kept (see module docstring). An existing
        line is merged by key with hit counters summed (recurring
        dead-ends accumulate weight), then re-ranked and capped at
        ``top_k``. Returns the number of entries now cached for the
        template (0 = nothing transferable, no line written).
        """
        # pre-cap at top_k so the merge loop below is bounded by
        # 2·top_k, not by the retiring store's full occupancy
        new = select_entries(entries, self.top_k, transferable_only=True)
        old = self._lines.get(fp)
        if old is not None:
            merged: dict[tuple[int, int], list] = {}
            for src in (old, new):
                for i in range(len(src["pos"])):
                    key = (int(src["pos"][i]), int(src["v"][i]))
                    if key in merged:
                        merged[key][5] += int(src["hits"][i])
                    else:
                        merged[key] = [src[k][i] for k in ENTRY_KEYS]
            keys = sorted(merged)
            new = {k: np.asarray([merged[key][i] for key in keys],
                                 dtype=new[k].dtype)
                   for i, k in enumerate(ENTRY_KEYS)}
        new = select_entries(new, self.top_k, transferable_only=True)
        if len(new["pos"]) == 0:
            return 0
        if old is None and len(self._lines) >= self.max_templates:
            self._lines.popitem(last=False)
            self.stats.evictions += 1
        self._lines[fp] = new
        self._lines.move_to_end(fp)
        self.stats.inserts += 1
        return len(new["pos"])

    def report(self) -> dict:
        s = self.stats
        return {"templates": len(self._lines),
                "lookups": s.lookups, "hits": s.hits,
                "inserts": s.inserts, "evictions": s.evictions,
                "warm_patterns": s.warm_patterns}
