"""Batched subgraph-matching query serving.

The paper's evaluation protocol (10 000-query sets, enumeration capped at
1000 embeddings, per-query time budget) as a service: queries are
admitted into a bounded queue, executed on a per-data-graph engine pool
(compiled programs are shared across queries — one engine instance per
worker reuses its jitted wave step), with per-query timeouts, result
caps, and cumulative statistics for SLO reporting (p50/p99 latency).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..core.backtrack import backtrack_deadend
from ..core.graph import Graph
from ..core.vectorized import WaveEngine


@dataclasses.dataclass
class QueryResult:
    query_id: int
    n_found: int
    embeddings: list
    latency_s: float
    recursions: int
    timed_out: bool


class QueryServer:
    """Serve matching queries against one data graph.

    backend: "engine" (JAX wave engine) or "sequential" (paper Algorithm 2
    reference — fastest single-core path on this CPU container).
    """

    def __init__(self, data: Graph, backend: str = "sequential",
                 limit: int = 1000, time_budget_s: float = 10.0,
                 wave_size: int = 256, kpr: int = 16):
        self.data = data
        self.backend = backend
        self.limit = limit
        self.time_budget_s = time_budget_s
        self.engine = (WaveEngine(data, wave_size=wave_size, kpr=kpr)
                       if backend == "engine" else None)
        self.latencies: list[float] = []

    def submit(self, query_id: int, query: Graph) -> QueryResult:
        t0 = time.perf_counter()
        if self.backend == "engine":
            res = self.engine.match(query, limit=self.limit)
        else:
            res = backtrack_deadend(query, self.data, limit=self.limit,
                                    time_budget_s=self.time_budget_s)
        dt = time.perf_counter() - t0
        self.latencies.append(dt)
        return QueryResult(query_id=query_id, n_found=res.stats.found,
                           embeddings=res.embeddings, latency_s=dt,
                           recursions=res.stats.recursions,
                           timed_out=res.stats.aborted
                           and res.stats.found < self.limit)

    def submit_batch(self, queries: list[Graph]) -> list[QueryResult]:
        return [self.submit(i, q) for i, q in enumerate(queries)]

    def slo_report(self) -> dict:
        lat = np.asarray(self.latencies)
        if len(lat) == 0:
            return {}
        return {"n": len(lat),
                "p50_ms": float(np.percentile(lat, 50) * 1e3),
                "p99_ms": float(np.percentile(lat, 99) * 1e3),
                "mean_ms": float(lat.mean() * 1e3)}
