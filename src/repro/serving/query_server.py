"""Batched + streamed subgraph-matching query serving (DESIGN.md §4).

:class:`QueryServer` is a thin *session* over the request/handle API
(:mod:`repro.api`): the paper's evaluation protocol (10 000-query sets,
enumeration capped at 1000 embeddings, per-query time budget) as a
service, plus the interactive scenarios the batch API cannot express —

* :meth:`submit_async` — non-blocking; returns a
  :class:`~repro.api.MatchHandle` with ``done()/result()/cancel()`` and
  ``stream()`` (embedding batches delivered as waves emit them, so time
  to first embedding — TTFE — beats completion latency);
* :meth:`submit` / :meth:`submit_batch` — the legacy blocking
  interfaces, now compatibility wrappers over request/handle;
* priority-aware admission from the bounded queue
  (``MatchOptions.priority``; :class:`~repro.api.QueueFull` is the
  typed backpressure signal);
* :meth:`slo_report` — p50/p99/mean latency, TTFE percentiles, timeout
  tally, and the scheduler's wave/occupancy statistics.

Every knob — per-query (``limit``, ``time_budget_s``,
``max_recursions``, ``parallelism``, ``priority``, …) and per-engine
(``n_slots``, ``wave_size``, ``megastep_depth``, ``pattern_*``, …) —
resolves through :class:`repro.api.MatchOptions`, the single source of
truth; the server adds none of its own defaults.

backend: "engine" (shared-wave JAX scheduler) or "sequential" (paper
Algorithm 2 reference, one query at a time — the correctness oracle;
it supports the same handle lifecycle including streaming and
cancellation).
"""
from __future__ import annotations

import numpy as np

from ..api.handle import MatchHandle, QueryResult  # noqa: F401 (re-export)
from ..api.options import MatchOptions
from ..api.session import MatchSession
from ..core.graph import Graph

__all__ = ["QueryServer", "QueryResult"]


class QueryServer:
    """Serve matching queries against one data graph."""

    def __init__(self, data: Graph, backend: str = "sequential",
                 options: MatchOptions | None = None, **knobs):
        """``options`` / ``knobs`` resolve through
        :class:`repro.api.MatchOptions` and configure both the engine
        (``n_slots``, ``wave_size``, ``kpr``, ``megastep_depth``,
        ``max_queue``, ``pattern_capacity``, ``pattern_cache*``, …) and
        the default per-query budget (``limit``, ``time_budget_s``,
        ``max_recursions``) applied to every submission that does not
        override them. The pattern-cache knobs control the cross-query
        template cache: recurring query templates warm-start their Δ
        from the previous run's hot transferable patterns (DESIGN.md
        §6); cache hit/warm-start metrics surface in
        :meth:`slo_report` and per-query in ``QueryResult.stats``."""
        self.data = data
        self.backend = backend
        self.options = MatchOptions.resolve(options, **knobs)
        self.session = MatchSession(
            data, options=self.options,
            backend="engine" if backend == "engine" else "sequential")
        self.scheduler = self.session.scheduler   # None on sequential
        self.latencies: list[float] = []
        self.ttfes: list[float] = []
        self.n_timeouts = 0
        self.n_cancelled = 0
        self.n_errors = 0
        self.n_shed = 0
        # QueueFull events absorbed by submit_batch's drain-and-retry
        # loop. Backpressure is *not* shedding — the query still runs —
        # but the serving tier needs the count to distinguish "dropped"
        # from "retried later" when sizing admission queues.
        self.n_backpressure = 0
        self.session.on_complete = self._record

    # convenience views of the resolved per-query defaults
    @property
    def limit(self):
        return self.options.limit

    @property
    def time_budget_s(self):
        return self.options.time_budget_s

    @property
    def max_recursions(self):
        return self.options.max_recursions

    # ------------------------------------------------------------------
    def _record(self, qr: QueryResult) -> None:
        """Session completion hook: SLO bookkeeping for every finished
        query, whether consumed via handles or the blocking wrappers."""
        self.latencies.append(qr.latency_s)
        if qr.ttfe_s is not None:
            self.ttfes.append(qr.ttfe_s)
        self.n_timeouts += qr.timed_out
        self.n_cancelled += qr.status == "cancelled"
        self.n_errors += qr.status == "error"
        self.n_shed += qr.status == "shed"

    # ------------------------------------------------------------------
    # request/handle API
    # ------------------------------------------------------------------
    def submit_async(self, query: Graph, *, query_id: int | None = None,
                     options: MatchOptions | None = None,
                     **overrides) -> MatchHandle:
        """Non-blocking submit; returns a :class:`MatchHandle`
        (``done()``, ``result()``, ``stream()``, ``cancel()``).

        Raises :class:`repro.api.QueueFull` when the bounded admission
        queue is at capacity — apply backpressure (``step()`` /
        consume a handle) or shed load. Admission from the queue is
        priority-aware (``priority=`` override, higher first)."""
        return self.session.submit(query, query_id=query_id,
                                   options=options, **overrides)

    def step(self) -> bool:
        """Advance the backend by one unit of work; False when idle."""
        return self.session.step()

    # ------------------------------------------------------------------
    # legacy blocking wrappers
    # ------------------------------------------------------------------
    def submit(self, query_id: int, query: Graph,
               parallelism: int = 1) -> QueryResult:
        """Synchronous single-query submit (runs the query to
        completion). Compatibility wrapper over :meth:`submit_async`."""
        return self.submit_async(query, query_id=query_id,
                                 parallelism=parallelism).result()

    def submit_batch(self, queries: list[Graph],
                     ids: list[int] | None = None,
                     parallelism: int | list[int] | None = None
                     ) -> list[QueryResult]:
        """Run a batch of queries; on the engine backend all of them
        share the scheduler's waves concurrently (continuous batching:
        as queries finish, queued ones are admitted into their slots).
        Compatibility wrapper: submits handles with bounded-queue
        backpressure, then drains them.

        ``parallelism``: intra-query shard count (shard-as-segments,
        DESIGN.md §3) — an int applied to every query or a per-query
        list. A heavy query submitted with ``parallelism=k`` seeds k
        root segments with work stealing between them, so it fills
        waves instead of idling rows next to light traffic. Ignored by
        the sequential backend (one recursion, nothing to shard).
        """
        from ..core.vectorized import QueueFull
        if ids is None:
            ids = list(range(len(queries)))
        if parallelism is None:
            par = [1] * len(queries)
        elif isinstance(parallelism, int):
            par = [parallelism] * len(queries)
        else:
            par = list(parallelism)
            if len(par) != len(queries):
                raise ValueError(
                    f"parallelism list length {len(par)} != "
                    f"{len(queries)} queries")
        handles: list[MatchHandle] = []
        for eid, q, k in zip(ids, queries, par):
            while True:
                try:
                    handles.append(self.submit_async(
                        q, query_id=eid, parallelism=k))
                    break
                except QueueFull:
                    # bounded-queue backpressure: drain one unit of
                    # work, freeing queue space, then retry — counted,
                    # never silent (surfaced as slo_report's
                    # backpressure_absorbed)
                    self.n_backpressure += 1
                    if not self.step():
                        raise
        return [h.result() for h in handles]

    # ------------------------------------------------------------------
    def slo_report(self) -> dict:
        # instantaneous-load gauges (always present, even before the
        # first completion — the serving tier's /slo endpoint reports
        # live state, not just terminal-state tallies): queue_depth =
        # requests admitted but not yet resident, resident_queries =
        # queries currently occupying engine slots (sequential: the
        # in-flight worker count).
        if self.scheduler is not None:
            gauges = {"queue_depth": len(self.scheduler.queue),
                      "resident_queries": int(self.scheduler.pool.n_active)}
        else:
            self.session._workers = {w for w in self.session._workers
                                     if w.is_alive()}
            gauges = {"queue_depth": len(self.session._pending),
                      "resident_queries": len(self.session._workers)}
        lat = np.asarray(self.latencies)
        if len(lat) == 0:
            return {"n": 0, **gauges,
                    "backpressure_absorbed": int(self.n_backpressure)}
        rep = {"n": len(lat),
               **gauges,
               "p50_ms": float(np.percentile(lat, 50) * 1e3),
               "p99_ms": float(np.percentile(lat, 99) * 1e3),
               "mean_ms": float(lat.mean() * 1e3),
               "timeouts": int(self.n_timeouts),
               "cancelled": int(self.n_cancelled),
               "errors": int(self.n_errors),
               "shed": int(self.n_shed),
               "backpressure_absorbed": int(self.n_backpressure)}
        # time-to-first-embedding percentiles (queries that found >= 1
        # embedding): the streaming SLO — how long until a consumer of
        # MatchHandle.stream() sees its first batch
        ttfe = np.asarray(self.ttfes)
        rep["ttfe_n"] = len(ttfe)
        if len(ttfe):
            rep["ttfe_p50_ms"] = float(np.percentile(ttfe, 50) * 1e3)
            rep["ttfe_p99_ms"] = float(np.percentile(ttfe, 99) * 1e3)
        if self.scheduler is not None:
            rep.update(self.scheduler.scheduler_stats())
        return rep
