"""Batched subgraph-matching query serving on the shared-wave scheduler.

The paper's evaluation protocol (10 000-query sets, enumeration capped at
1000 embeddings, per-query time budget) as a service: queries are
admitted into the :class:`~repro.core.vectorized.WaveScheduler`'s bounded
queue and executed *concurrently* — partial embeddings from many queries
are packed into each fixed-shape wave, so one jitted device program
serves the whole mixed batch with no idle gaps between queries
(DESIGN.md §4). Per-query limits, recursion and time budgets evict
aborted queries without disturbing their neighbors, and cumulative
statistics feed SLO reporting (p50/p99 latency, wave occupancy).

backend: "engine" (shared-wave JAX scheduler) or "sequential" (paper
Algorithm 2 reference, one query at a time — the correctness oracle).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..core.backtrack import backtrack_deadend
from ..core.graph import Graph
from ..core.vectorized import WaveScheduler


@dataclasses.dataclass
class QueryResult:
    query_id: int
    n_found: int
    embeddings: list
    latency_s: float
    recursions: int
    # status taxonomy (identical for both backends):
    #   "ok"      — enumeration ran to completion
    #   "limit"   — stopped because the result cap was reached
    #   "timeout" — aborted by the recursion or wall-clock budget
    timed_out: bool              # True iff status == "timeout"
    aborted: bool = False        # any early stop (limit OR budget)
    status: str = "ok"
    # full engine stats (EngineStats on the engine backend — includes
    # per-shard rows/items/steal counters for parallelism > 1)
    stats: object = None


def _status_of(stats, limit: int | None) -> str:
    """Map SearchStats abort bookkeeping to the serving status taxonomy."""
    if not stats.aborted:
        return "ok"
    reason = stats.abort_reason
    if reason == "limit" or (reason is None and limit is not None
                             and stats.found >= limit):
        return "limit"
    return "timeout"


class QueryServer:
    """Serve matching queries against one data graph."""

    def __init__(self, data: Graph, backend: str = "sequential",
                 limit: int | None = 1000, time_budget_s: float = 10.0,
                 wave_size: int = 256, kpr: int = 16, n_slots: int = 16,
                 max_recursions: int | None = None, max_queue: int = 4096,
                 megastep_depth: int = 6,
                 pattern_capacity: int = 4096,
                 pattern_cache: bool = True,
                 pattern_cache_templates: int = 64,
                 pattern_cache_top_k: int = 512):
        """``pattern_capacity`` bounds the per-slot hashed Δ store
        (O(capacity) device memory, independent of the data graph;
        eviction only loses pruning, never exactness). The pattern-cache
        knobs control the cross-query template cache: recurring query
        templates warm-start their Δ from the previous run's hot
        transferable patterns — the serving win for traffic with
        repeated templates (DESIGN.md §6). Cache hit/warm-start metrics
        surface in :meth:`slo_report` and per-query in
        ``QueryResult.stats`` (``cache_hit``, ``warm_patterns``,
        ``table_stats``)."""
        self.data = data
        self.backend = backend
        self.limit = limit
        self.time_budget_s = time_budget_s
        self.max_recursions = max_recursions
        self.scheduler = (WaveScheduler(
            data, n_slots=n_slots, wave_size=wave_size, kpr=kpr,
            max_queue=max_queue, megastep_depth=megastep_depth,
            pattern_capacity=pattern_capacity,
            pattern_cache=pattern_cache,
            pattern_cache_templates=pattern_cache_templates,
            pattern_cache_top_k=pattern_cache_top_k)
            if backend == "engine" else None)
        self.latencies: list[float] = []
        self.n_timeouts = 0

    # ------------------------------------------------------------------
    def _wrap(self, query_id: int, res, latency_s: float) -> QueryResult:
        status = _status_of(res.stats, self.limit)
        qr = QueryResult(query_id=query_id, n_found=res.stats.found,
                         embeddings=res.embeddings, latency_s=latency_s,
                         recursions=res.stats.recursions,
                         timed_out=status == "timeout",
                         aborted=res.stats.aborted, status=status,
                         stats=res.stats)
        self.latencies.append(latency_s)
        self.n_timeouts += qr.timed_out
        return qr

    def submit(self, query_id: int, query: Graph,
               parallelism: int = 1) -> QueryResult:
        """Synchronous single-query submit (runs the query to completion)."""
        return self.submit_batch([query], ids=[query_id],
                                 parallelism=parallelism)[0]

    def submit_batch(self, queries: list[Graph],
                     ids: list[int] | None = None,
                     parallelism: int | list[int] | None = None
                     ) -> list[QueryResult]:
        """Run a batch of queries; on the engine backend all of them share
        the scheduler's waves concurrently (continuous batching: as
        queries finish, queued ones are admitted into their slots).

        ``parallelism``: intra-query shard count (shard-as-segments,
        DESIGN.md §3) — an int applied to every query or a per-query
        list. A heavy query submitted with ``parallelism=k`` seeds k
        root segments with work stealing between them, so it fills
        waves instead of idling rows next to light traffic. Ignored by
        the sequential backend (one recursion, nothing to shard).
        """
        if ids is None:
            ids = list(range(len(queries)))
        if parallelism is None:
            par = [1] * len(queries)
        elif isinstance(parallelism, int):
            par = [parallelism] * len(queries)
        else:
            par = list(parallelism)
            if len(par) != len(queries):
                raise ValueError(
                    f"parallelism list length {len(par)} != "
                    f"{len(queries)} queries")
        if self.backend != "engine":
            out = []
            for qid, q in zip(ids, queries):
                t0 = time.perf_counter()
                res = backtrack_deadend(
                    q, self.data, limit=self.limit,
                    max_recursions=self.max_recursions,
                    time_budget_s=self.time_budget_s)
                out.append(self._wrap(qid, res, time.perf_counter() - t0))
            return out

        sched = self.scheduler
        pending = list(zip(ids, queries, par))
        t_submit: dict[int, float] = {}
        ext_id: dict[int, int] = {}          # scheduler id -> external id
        results: dict[int, QueryResult] = {}
        next_i = 0

        def drain_finished():
            for sqid in sched.poll():
                eid = ext_id.get(sqid)
                if eid is None or sqid not in sched.finished:
                    continue
                res = sched.finished.pop(sqid)
                results[eid] = self._wrap(
                    eid, res, time.perf_counter() - t_submit[eid])

        while len(results) < len(pending):
            # bounded-queue backpressure: top the queue up, then step
            while next_i < len(pending) and len(sched.queue) < sched.max_queue:
                eid, q, k = pending[next_i]
                t_submit[eid] = time.perf_counter()
                ext_id[sched.submit(
                    q, limit=self.limit,
                    max_rows=self.max_recursions,
                    time_budget_s=self.time_budget_s,
                    parallelism=k)] = eid
                next_i += 1
            if not sched.step() and next_i >= len(pending):
                drain_finished()
                break
            drain_finished()
        drain_finished()
        return [results[eid] for eid, *_ in pending]

    # ------------------------------------------------------------------
    def slo_report(self) -> dict:
        lat = np.asarray(self.latencies)
        if len(lat) == 0:
            return {}
        rep = {"n": len(lat),
               "p50_ms": float(np.percentile(lat, 50) * 1e3),
               "p99_ms": float(np.percentile(lat, 99) * 1e3),
               "mean_ms": float(lat.mean() * 1e3),
               "timeouts": int(self.n_timeouts)}
        if self.scheduler is not None:
            rep.update(self.scheduler.scheduler_stats())
        return rep
