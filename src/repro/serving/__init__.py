from .query_server import QueryResult, QueryServer

__all__ = ["QueryResult", "QueryServer"]
