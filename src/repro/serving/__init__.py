from ..api.handle import MatchHandle, QueryResult
from ..api.options import MatchOptions
from .query_server import QueryServer

__all__ = ["MatchHandle", "MatchOptions", "QueryResult", "QueryServer"]
