"""Atomic, resumable, mesh-elastic checkpointing.

Layout per step::

    <dir>/step_000123.tmp-<nonce>/   (written, fsynced)
        manifest.json                (tree structure, shapes, dtypes,
                                      logical PartitionSpecs, step, extra)
        arrays.npz                   (flattened leaves by index)
    <dir>/step_000123/               (atomic rename when complete)

Guarantees:
  * crash-safe — a checkpoint is visible only after the atomic rename;
    stale ``.tmp-*`` directories are garbage-collected on save.
  * elastic — arrays are stored unsharded with their *logical*
    PartitionSpec recorded; ``restore`` re-shards onto whatever mesh the
    restarted job has (different device count included).
  * bounded — keeps the newest ``keep`` checkpoints.

For multi-pod scale the same protocol runs per-host on the host-local
shard of each array (manifest records the global shape); this container
exercises the single-host path.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import time
import uuid

import jax
import numpy as np

MANIFEST = "manifest.json"
ARRAYS = "arrays.npz"


def _tree_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str | pathlib.Path, step: int, tree,
         specs=None, extra: dict | None = None, keep: int = 3) -> pathlib.Path:
    """Write a checkpoint atomically; returns the final directory."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:09d}"
    tmp = ckpt_dir / f"step_{step:09d}.tmp-{uuid.uuid4().hex[:8]}"
    tmp.mkdir(parents=True)
    leaves, treedef = _tree_paths(tree)
    arrays = {}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            arr = arr.view(np.uint16)    # npz-safe; dtype in manifest
        arrays[f"a{i}"] = arr
    np.savez(tmp / ARRAYS, **arrays)
    spec_leaves = None
    if specs is not None:
        spec_leaves = [str(s) for s in
                       treedef.flatten_up_to(specs)]
    manifest = {
        "step": int(step),
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
        "specs": spec_leaves,
        "extra": extra or {},
        "time": time.time(),
    }
    with open(tmp / MANIFEST, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    # GC: stale tmp dirs + old checkpoints beyond ``keep``
    for p in ckpt_dir.glob("step_*.tmp-*"):
        shutil.rmtree(p, ignore_errors=True)
    done = sorted(p for p in ckpt_dir.glob("step_*") if p.is_dir()
                  and ".tmp-" not in p.name)
    for p in done[:-keep]:
        shutil.rmtree(p, ignore_errors=True)
    return final


def latest_step(ckpt_dir: str | pathlib.Path) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
             if p.is_dir() and ".tmp-" not in p.name
             and (p / MANIFEST).exists()]
    return max(steps) if steps else None


def restore(ckpt_dir: str | pathlib.Path, template, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``template``.

    ``shardings``: optional pytree of jax.sharding.Sharding matching
    template — arrays are device_put with them (elastic re-shard).
    Returns (tree, step, extra).
    """
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:09d}"
    manifest = json.loads((d / MANIFEST).read_text())
    data = np.load(d / ARRAYS)
    leaves, treedef = _tree_paths(template)
    assert manifest["n_leaves"] == len(leaves), \
        f"leaf count mismatch: ckpt {manifest['n_leaves']} vs {len(leaves)}"
    out = []
    sh_leaves = (treedef.flatten_up_to(shardings)
                 if shardings is not None else [None] * len(leaves))
    for i, (tmpl, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = data[f"a{i}"]
        if manifest["dtypes"][i] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        want_shape = tuple(getattr(tmpl, "shape", arr.shape))
        assert tuple(arr.shape) == want_shape, \
            f"leaf {i}: shape {arr.shape} != template {want_shape}"
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out), manifest["step"], manifest["extra"]
