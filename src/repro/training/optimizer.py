"""AdamW with global-norm clipping, cosine schedule, and a reduced-
precision state mode for >=100B-parameter models.

State dtype policy (DESIGN.md): fp32 moments for <100B models; bf16
moments for the 671B/1T MoE configs so params+m+v fit the 16 GB/chip HBM
budget at 512 chips (the dry-run's memory_analysis verifies). Moment
updates are computed in fp32 and rounded once per step.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac * lr."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params: Params, cfg: AdamWConfig) -> Params:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params: Params, grads: Params, state: Params,
                 cfg: AdamWConfig) -> tuple[Params, Params]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    c1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mhat = mf / c1
        vhat = vf / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, mf.astype(cfg.state_dtype), vf.astype(cfg.state_dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
