"""deepseek-v3-671b — MLA + 256-expert top-8 MoE + MTP.
[arXiv:2412.19437; hf]

Deviation from the HF config (recorded in DESIGN.md): all 61 layers are
MoE (the release keeps the first 3 dense); total params land at ~692B vs
671B, activated ~37B matches the paper.
"""
from ..models.mla import MLAConfig
from ..models.moe import MoEConfig
from ..models.transformer import LMConfig
from .common import ArchSpec, lm_shapes

FULL = LMConfig(
    name="deepseek-v3-671b", n_layers=61, d_model=7168, n_heads=128,
    n_kv_heads=128, d_ff=2048, vocab=129280, rope_theta=1e4,
    mla=MLAConfig(d_model=7168, n_heads=128, d_c=512, d_cq=1536,
                  d_nope=128, d_rope=64, d_v=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1,
                  d_ff_shared=2048),
    mtp=True)

SMOKE = LMConfig(
    name="deepseek-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256,
    mla=MLAConfig(d_model=64, n_heads=4, d_c=32, d_cq=48, d_nope=16,
                  d_rope=8, d_v=16),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1,
                  d_ff_shared=32, capacity_factor=8.0),
    mtp=True, remat=False)


def spec() -> ArchSpec:
    return ArchSpec(arch_id="deepseek-v3-671b", family="lm", config=FULL,
                    smoke_config=SMOKE, shapes=lm_shapes(),
                    notes="MLA latent KV cache, 1 shared + 256 routed "
                          "top-8, MTP head")
