"""Architecture spec plumbing shared by all config files.

Each ``configs/<arch>.py`` exposes ``spec() -> ArchSpec`` with
  * ``config``  — the exact published configuration (full scale),
  * ``shapes``  — the arch's assigned input-shape cells,
  * ``smoke_config`` — a reduced same-family config for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str            # train | prefill | decode | full_graph |
    #                      sampled | batched_graphs | recsys_train |
    #                      recsys_serve | recsys_retrieval
    dims: dict[str, int]


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str          # "lm" | "gnn" | "equiv" | "recsys"
    config: Any
    smoke_config: Any
    shapes: tuple[ShapeCell, ...]
    notes: str = ""

    def shape(self, name: str) -> ShapeCell:
        for c in self.shapes:
            if c.name == name:
                return c
        raise KeyError(f"{self.arch_id} has no shape {name!r}: "
                       f"{[c.name for c in self.shapes]}")


def lm_shapes() -> tuple[ShapeCell, ...]:
    return (
        ShapeCell("train_4k", "train",
                  dict(seq_len=4096, global_batch=256)),
        ShapeCell("prefill_32k", "prefill",
                  dict(seq_len=32768, global_batch=32)),
        ShapeCell("decode_32k", "decode",
                  dict(kv_len=32768, global_batch=128)),
        ShapeCell("long_500k", "decode",
                  dict(kv_len=524288, global_batch=1)),
    )


def gnn_shapes() -> tuple[ShapeCell, ...]:
    return (
        ShapeCell("full_graph_sm", "full_graph",
                  dict(n_nodes=2708, n_edges=10556, d_feat=1433,
                       n_classes=7)),
        ShapeCell("minibatch_lg", "sampled",
                  dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
                       fanout0=15, fanout1=10, d_feat=602, n_classes=41)),
        ShapeCell("ogb_products", "full_graph",
                  dict(n_nodes=2449029, n_edges=61859140, d_feat=100,
                       n_classes=47)),
        ShapeCell("molecule", "batched_graphs",
                  dict(n_nodes=30, n_edges=64, batch=128, n_species=10)),
    )


def recsys_shapes() -> tuple[ShapeCell, ...]:
    return (
        ShapeCell("train_batch", "recsys_train", dict(batch=65536)),
        ShapeCell("serve_p99", "recsys_serve", dict(batch=512)),
        ShapeCell("serve_bulk", "recsys_serve", dict(batch=262144)),
        ShapeCell("retrieval_cand", "recsys_retrieval",
                  dict(batch=1, n_candidates=1000000)),
    )
