"""The paper's own workload as an 'architecture': the wave-engine device
program over production-scale matching instances.

Shape cells size the device arrays of ``core.engine_step.expand_wave``:
the data-graph bitmap, wave width, and dead-end table. These are the
dry-run/roofline cells for the paper's technique itself.
"""
import dataclasses

from .common import ArchSpec, ShapeCell


@dataclasses.dataclass(frozen=True)
class MatcherConfig:
    name: str
    n_vertices: int          # data graph |V|
    wave_size: int
    kpr: int
    n_query_max: int = 64


FULL = MatcherConfig(name="paper-matcher", n_vertices=1_048_576,
                     wave_size=8192, kpr=16)

SMOKE = MatcherConfig(name="matcher-smoke", n_vertices=512,
                      wave_size=64, kpr=4)


def spec() -> ArchSpec:
    shapes = (
        ShapeCell("yeast_scale", "matcher",
                  dict(n_vertices=4096, wave_size=4096, kpr=16)),
        ShapeCell("web_scale", "matcher",
                  dict(n_vertices=1_048_576, wave_size=8192, kpr=16)),
    )
    return ArchSpec(arch_id="paper-matcher", family="matcher", config=FULL,
                    smoke_config=SMOKE, shapes=shapes,
                    notes="expand_wave lowered on the production mesh; "
                          "frontier sharded over data axis, graph bitmap "
                          "+ dead-end table sharded over model axis")
