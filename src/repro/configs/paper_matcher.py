"""The paper's own workload as an 'architecture': the wave-engine device
program over production-scale matching instances.

Shape cells size the device arrays of the *real* serving program,
``core.engine_step.expand_wave_mq``: the data-graph bitmap, the
slot-stacked query/table banks, wave width, and the slot/depth lanes —
the same multi-query wave the shared-wave scheduler (and the distributed
shard-as-segments matcher on top of it) dispatches, not the 1-slot
facade. These are the dry-run/roofline cells for the paper's technique
itself.
"""
import dataclasses

from .common import ArchSpec, ShapeCell


@dataclasses.dataclass(frozen=True)
class MatcherConfig:
    name: str
    n_vertices: int          # data graph |V|
    wave_size: int
    kpr: int
    n_slots: int = 16        # concurrent resident queries (bank slots)
    n_query_max: int = 64
    # bounded hashed Δ store (patterns.store): per-slot capacity, a
    # power of two. Resident pattern memory is S * capacity * ~29 B —
    # independent of n_vertices (the dense [S, N_PAD, V] bank the store
    # replaced was ~0.8 GB/slot at web scale; 64 Ki entries is ~2 MB).
    pattern_capacity: int = 65_536


FULL = MatcherConfig(name="paper-matcher", n_vertices=1_048_576,
                     wave_size=8192, kpr=16)

SMOKE = MatcherConfig(name="matcher-smoke", n_vertices=512,
                      wave_size=64, kpr=4, n_slots=4,
                      pattern_capacity=1024)


def spec() -> ArchSpec:
    shapes = (
        ShapeCell("yeast_scale", "matcher",
                  dict(n_vertices=4096, wave_size=4096, kpr=16,
                       n_slots=16, pattern_capacity=16_384)),
        ShapeCell("web_scale", "matcher",
                  dict(n_vertices=1_048_576, wave_size=8192, kpr=16,
                       n_slots=16, pattern_capacity=65_536)),
        # device-resident scheduling step (run_device_megastep): adds
        # the per-slot StackBank dims — presence of stack_capacity
        # routes build_cell to the stack lowering
        ShapeCell("yeast_scale_stacks", "matcher",
                  dict(n_vertices=4096, wave_size=4096, kpr=16,
                       n_slots=16, pattern_capacity=16_384,
                       stack_capacity=1024, megastep_depth=6)),
    )
    return ArchSpec(arch_id="paper-matcher", family="matcher", config=FULL,
                    smoke_config=SMOKE, shapes=shapes,
                    notes="expand_wave_mq lowered on the production mesh; "
                          "frontier + slot/depth lanes sharded over data "
                          "axis, graph bitmap sharded over model axis, "
                          "hashed pattern store replicated (O(capacity), "
                          "data-graph independent)")
