"""The paper's own workload as an 'architecture': the wave-engine device
program over production-scale matching instances.

Shape cells size the device arrays of the *real* serving program,
``core.engine_step.expand_wave_mq``: the data-graph bitmap, the
slot-stacked query/table banks, wave width, and the slot/depth lanes —
the same multi-query wave the shared-wave scheduler (and the distributed
shard-as-segments matcher on top of it) dispatches, not the 1-slot
facade. These are the dry-run/roofline cells for the paper's technique
itself.
"""
import dataclasses

from .common import ArchSpec, ShapeCell


@dataclasses.dataclass(frozen=True)
class MatcherConfig:
    name: str
    n_vertices: int          # data graph |V|
    wave_size: int
    kpr: int
    n_slots: int = 16        # concurrent resident queries (bank slots)
    n_query_max: int = 64


FULL = MatcherConfig(name="paper-matcher", n_vertices=1_048_576,
                     wave_size=8192, kpr=16)

SMOKE = MatcherConfig(name="matcher-smoke", n_vertices=512,
                      wave_size=64, kpr=4, n_slots=4)


def spec() -> ArchSpec:
    shapes = (
        ShapeCell("yeast_scale", "matcher",
                  dict(n_vertices=4096, wave_size=4096, kpr=16,
                       n_slots=16)),
        ShapeCell("web_scale", "matcher",
                  dict(n_vertices=1_048_576, wave_size=8192, kpr=16,
                       n_slots=16)),
    )
    return ArchSpec(arch_id="paper-matcher", family="matcher", config=FULL,
                    smoke_config=SMOKE, shapes=shapes,
                    notes="expand_wave_mq lowered on the production mesh; "
                          "frontier + slot/depth lanes sharded over data "
                          "axis, graph bitmap + dead-end table bank "
                          "sharded over model axis")
