"""mace — higher-order equivariant message passing (ACE), 2 layers,
128 channels, correlation order 3. [arXiv:2206.07697; paper]"""
from ..models.equivariant import EquivConfig
from .common import ArchSpec, gnn_shapes

FULL = EquivConfig(name="mace", kind="mace", n_layers=2, channels=128,
                   n_species=64, n_rbf=8, cutoff=5.0, l_max=2,
                   correlation=3)

SMOKE = EquivConfig(name="mace-smoke", kind="mace", n_layers=2,
                    channels=8, n_species=8, n_rbf=4, cutoff=5.0,
                    correlation=3)


def spec() -> ArchSpec:
    return ArchSpec(arch_id="mace", family="equiv", config=FULL,
                    smoke_config=SMOKE, shapes=gnn_shapes(),
                    notes="correlation-3 products of aggregated features "
                          "(many-body terms from one sweep)")
