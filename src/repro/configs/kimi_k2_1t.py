"""kimi-k2-1t-a32b — trillion-param MoE (384 experts top-8, MLA, 64 heads).
[arXiv:2501.kimi2; unverified — paper-table config]"""
from ..models.mla import MLAConfig
from ..models.moe import MoEConfig
from ..models.transformer import LMConfig
from .common import ArchSpec, lm_shapes

FULL = LMConfig(
    name="kimi-k2-1t-a32b", n_layers=61, d_model=7168, n_heads=64,
    n_kv_heads=8, d_ff=2048, vocab=163840, rope_theta=5e4,
    mla=MLAConfig(d_model=7168, n_heads=64, d_c=512, d_cq=1536,
                  d_nope=128, d_rope=64, d_v=128),
    moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048, n_shared=1,
                  d_ff_shared=2048))

SMOKE = LMConfig(
    name="kimi-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256,
    mla=MLAConfig(d_model=64, n_heads=4, d_c=32, d_cq=48, d_nope=16,
                  d_rope=8, d_v=16),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1,
                  d_ff_shared=32, capacity_factor=8.0),
    remat=False)


def spec() -> ArchSpec:
    return ArchSpec(arch_id="kimi-k2-1t-a32b", family="lm", config=FULL,
                    smoke_config=SMOKE, shapes=lm_shapes(),
                    notes="1T total / 32B active, 384 routed experts")
