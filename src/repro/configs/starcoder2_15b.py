"""starcoder2-15b — dense GQA code LM, GELU MLP. [arXiv:2402.19173; hf]"""
from ..models.transformer import LMConfig
from .common import ArchSpec, lm_shapes

FULL = LMConfig(
    name="starcoder2-15b", n_layers=40, d_model=6144, n_heads=48,
    n_kv_heads=4, head_dim=128, d_ff=24576, vocab=49152,
    qkv_bias=True, rope_theta=1e5, mlp="gelu")

SMOKE = LMConfig(
    name="starcoder2-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=256, vocab=256,
    qkv_bias=True, mlp="gelu", remat=False)


def spec() -> ArchSpec:
    return ArchSpec(arch_id="starcoder2-15b", family="lm", config=FULL,
                    smoke_config=SMOKE, shapes=lm_shapes(),
                    notes="GQA kv=4, RoPE, GELU MLP")
