"""qwen3-0.6b — dense GQA LM with qk_norm. [hf:Qwen/Qwen3-0.6B; hf]"""
from ..models.transformer import LMConfig
from .common import ArchSpec, lm_shapes

FULL = LMConfig(
    name="qwen3-0.6b", n_layers=28, d_model=1024, n_heads=16,
    n_kv_heads=8, head_dim=128, d_ff=3072, vocab=151936,
    qkv_bias=False, qk_norm=True, rope_theta=1e6, mlp="swiglu")

SMOKE = LMConfig(
    name="qwen3-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
    qk_norm=True, mlp="swiglu", remat=False)


def spec() -> ArchSpec:
    return ArchSpec(arch_id="qwen3-0.6b", family="lm", config=FULL,
                    smoke_config=SMOKE, shapes=lm_shapes(),
                    notes="qk_norm, GQA kv=8")
