"""gin-tu — 5-layer GIN, sum aggregator, learnable eps.
[arXiv:1810.00826; paper]"""
from ..models.gnn import GNNConfig
from .common import ArchSpec, gnn_shapes

FULL = GNNConfig(name="gin-tu", kind="gin", n_layers=5, d_in=1433,
                 d_hidden=64, n_classes=7, aggregator="sum",
                 learnable_eps=True, sym_norm=False)

SMOKE = GNNConfig(name="gin-smoke", kind="gin", n_layers=3, d_in=16,
                  d_hidden=16, n_classes=3, aggregator="sum",
                  sym_norm=False)


def spec() -> ArchSpec:
    return ArchSpec(arch_id="gin-tu", family="gnn", config=FULL,
                    smoke_config=SMOKE, shapes=gnn_shapes(),
                    notes="sum aggregation + 2-layer MLP per hop; "
                          "d_in/n_classes follow each shape cell")
