"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from . import (deepseek_v3_671b, din, gcn_cora, gin_tu, kimi_k2_1t, mace,
               nequip, paper_matcher, qwen2_5_14b, qwen3_0_6b,
               starcoder2_15b)
from .common import ArchSpec

_MODULES = (qwen2_5_14b, qwen3_0_6b, starcoder2_15b, deepseek_v3_671b,
            kimi_k2_1t, gcn_cora, nequip, mace, gin_tu, din,
            paper_matcher)

ARCHS: dict[str, ArchSpec] = {m.spec().arch_id: m.spec() for m in _MODULES}

ASSIGNED = [a for a in ARCHS if a != "paper-matcher"]


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(ARCHS)}")
    return ARCHS[arch_id]


def all_cells(include_matcher: bool = False) -> list[tuple[str, str]]:
    """Every (arch, shape) dry-run cell."""
    out = []
    for aid, spec in ARCHS.items():
        if aid == "paper-matcher" and not include_matcher:
            continue
        out += [(aid, c.name) for c in spec.shapes]
    return out
