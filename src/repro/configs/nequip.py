"""nequip — O(3)-equivariant potential, 5 layers, 32 channels, l_max=2.
[arXiv:2101.03164; paper]

Adaptation note (DESIGN.md): irreps are carried in Cartesian form
(scalars / vectors / traceless-sym rank-2) — exact for l_max=2; e3nn is
unavailable offline. Citation-graph shape cells get synthetic 3D
positions (those datasets carry no coordinates).
"""
from ..models.equivariant import EquivConfig
from .common import ArchSpec, gnn_shapes

FULL = EquivConfig(name="nequip", kind="nequip", n_layers=5, channels=32,
                   n_species=64, n_rbf=8, cutoff=5.0, l_max=2,
                   correlation=1)

SMOKE = EquivConfig(name="nequip-smoke", kind="nequip", n_layers=2,
                    channels=8, n_species=8, n_rbf=4, cutoff=5.0,
                    correlation=1)


def spec() -> ArchSpec:
    return ArchSpec(arch_id="nequip", family="equiv", config=FULL,
                    smoke_config=SMOKE, shapes=gnn_shapes(),
                    notes="E(3) tensor-product messages, energy+forces")
