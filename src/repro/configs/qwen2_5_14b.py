"""qwen2.5-14b — dense GQA LM with QKV bias. [hf:Qwen/Qwen2.5-14B; hf]"""
from ..models.transformer import LMConfig
from .common import ArchSpec, lm_shapes

FULL = LMConfig(
    name="qwen2.5-14b", n_layers=48, d_model=5120, n_heads=40,
    n_kv_heads=8, head_dim=128, d_ff=13824, vocab=152064,
    qkv_bias=True, qk_norm=False, rope_theta=1e6, mlp="swiglu")

SMOKE = LMConfig(
    name="qwen2.5-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
    qkv_bias=True, qk_norm=False, mlp="swiglu", remat=False)


def spec() -> ArchSpec:
    return ArchSpec(arch_id="qwen2.5-14b", family="lm", config=FULL,
                    smoke_config=SMOKE, shapes=lm_shapes(),
                    notes="GQA kv=8, QKV bias")
