"""gcn-cora — 2-layer GCN, sym-norm. [arXiv:1609.02907; paper]"""
from ..models.gnn import GNNConfig
from .common import ArchSpec, gnn_shapes

FULL = GNNConfig(name="gcn-cora", kind="gcn", n_layers=2, d_in=1433,
                 d_hidden=16, n_classes=7, aggregator="mean",
                 sym_norm=True)

SMOKE = GNNConfig(name="gcn-smoke", kind="gcn", n_layers=2, d_in=32,
                  d_hidden=8, n_classes=4, sym_norm=True)


def spec() -> ArchSpec:
    return ArchSpec(arch_id="gcn-cora", family="gnn", config=FULL,
                    smoke_config=SMOKE, shapes=gnn_shapes(),
                    notes="SpMM regime; d_in/n_classes follow each shape "
                          "cell (config dims are the Cora cell)")
