"""din — Deep Interest Network, target attention over user history.
[arXiv:1706.06978; paper]

Table sizes follow the production regime the taxonomy prescribes
(10^6–10^9 rows): 100M items / 100k categories, dim 18.
"""
from ..models.recsys import DINConfig
from .common import ArchSpec, recsys_shapes

FULL = DINConfig(name="din", n_items=100_000_000, n_cats=100_000,
                 embed_dim=18, seq_len=100, attn_hidden=(80, 40),
                 mlp_hidden=(200, 80), n_dense_feats=8)

SMOKE = DINConfig(name="din-smoke", n_items=1000, n_cats=50,
                  embed_dim=8, seq_len=10, attn_hidden=(16, 8),
                  mlp_hidden=(32, 16), n_dense_feats=4)


def spec() -> ArchSpec:
    return ArchSpec(arch_id="din", family="recsys", config=FULL,
                    smoke_config=SMOKE, shapes=recsys_shapes(),
                    notes="embedding-bag = take + segment_sum; "
                          "retrieval cell scores 1e6 candidates batched")
