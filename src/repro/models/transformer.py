"""Unified decoder-only transformer LM: dense / GQA / qk-norm / MLA / MoE.

One config covers all five assigned LM architectures. Layer parameters are
stacked on a leading axis and the forward pass ``lax.scan``s over them
(with optional per-layer remat), keeping the HLO O(1) in depth — essential
for 61-layer 671B-parameter dry-runs to compile quickly.

Entry points:
  * ``lm_init``          — parameter pytree (stacked layers).
  * ``lm_logits``        — training / prefill forward -> [B, S, V].
  * ``lm_loss``          — next-token CE loss (+ optional MTP loss).
  * ``init_decode_state``/``lm_decode_step`` — KV-cached decoding
    (latent cache when MLA is enabled).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as _P

from .layers import (AttnConfig, attn_apply, attn_init, dense, dense_init,
                     gelu_mlp_apply, gelu_mlp_init, rms_norm, swiglu_apply,
                     swiglu_init)
from .mla import (MLAConfig, mla_decode_apply, mla_init, mla_init_cache,
                  mla_train_apply)
from .moe import MoEConfig, moe_apply, moe_init

Params = Any


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    mlp: str = "swiglu"                  # "swiglu" | "gelu"
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    mtp: bool = False                    # DeepSeek-V3 multi-token predict
    mtp_weight: float = 0.3
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    # activation-sharding constraint axes (set by launch/steps.py when
    # running under a mesh; None = no constraints, e.g. CPU smoke tests)
    dp_axis: Any = None
    tp_axis: Any = None
    mesh: Any = None          # Mesh => vocab-parallel embedding lookup

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(d_model=self.d_model, n_heads=self.n_heads,
                          n_kv_heads=self.n_kv_heads, head_dim=self.hd,
                          qkv_bias=self.qkv_bias, qk_norm=self.qk_norm,
                          rope_theta=self.rope_theta)

    def n_params(self) -> int:
        """Total parameter count (used for MODEL_FLOPS in §Roofline)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        if self.mla is not None:
            m = self.mla
            attn = (d * m.d_cq + m.d_cq * m.n_heads * (m.d_nope + m.d_rope)
                    + d * m.d_c + d * m.d_rope
                    + m.d_c * m.n_heads * (m.d_nope + m.d_v)
                    + m.n_heads * m.d_v * d)
        else:
            attn = d * self.hd * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * self.hd * d
        if self.moe is not None:
            e = self.moe
            ffp = e.n_experts * 3 * d * e.d_ff_expert
            if e.n_shared:
                ffp += 3 * d * (e.d_ff_shared or e.d_ff_expert * e.n_shared)
            ffp += d * e.n_experts
        else:
            ffp = 3 * d * ff if self.mlp == "swiglu" else 2 * d * ff
        return self.n_layers * (attn + ffp) + 2 * v * d

    def n_active_params(self) -> int:
        """Activated parameters per token (MoE top-k)."""
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        e = self.moe
        full = self.n_params()
        routed_all = self.n_layers * e.n_experts * 3 * d * e.d_ff_expert
        routed_act = self.n_layers * e.top_k * 3 * d * e.d_ff_expert
        return full - routed_all + routed_act


# ------------------------------------------------------------------ init
def _layer_init(key, cfg: LMConfig) -> Params:
    k_attn, k_ffn = jax.random.split(key)
    dt = cfg.param_dtype
    p = {"ln_attn": jnp.ones((cfg.d_model,), dt),
         "ln_ffn": jnp.ones((cfg.d_model,), dt)}
    if cfg.mla is not None:
        p["attn"] = mla_init(k_attn, cfg.mla, dt)
    else:
        p["attn"] = attn_init(k_attn, cfg.attn_cfg(), dt)
    if cfg.moe is not None:
        p["ffn"] = moe_init(k_ffn, cfg.d_model, cfg.moe, dt)
    elif cfg.mlp == "swiglu":
        p["ffn"] = swiglu_init(k_ffn, cfg.d_model, cfg.d_ff, dt)
    else:
        p["ffn"] = gelu_mlp_init(k_ffn, cfg.d_model, cfg.d_ff, dt)
    return p


def lm_init(key, cfg: LMConfig) -> Params:
    k_e, k_l, k_h, k_m = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_l, cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    p = {
        "embed": (jax.random.normal(k_e, (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02
                  ).astype(cfg.param_dtype),
        "layers": layers,
        "ln_final": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k_h, cfg.d_model, cfg.vocab,
                                  cfg.param_dtype)
    if cfg.mtp:
        km1, km2 = jax.random.split(k_m)
        p["mtp"] = {"proj": dense_init(km1, 2 * cfg.d_model, cfg.d_model,
                                       cfg.param_dtype),
                    "block": _layer_init(km2, cfg),
                    "ln": jnp.ones((cfg.d_model,), cfg.param_dtype)}
    return p


def _cst(x: jax.Array, cfg: LMConfig, *axes) -> jax.Array:
    """Batch-sharding constraint (ZeRO-3 style: keep activations sharded
    over data; let GSPMD all-gather FSDP weights instead)."""
    if cfg.dp_axis is None:
        return x
    return lax.with_sharding_constraint(x, _P(*axes))


def _embed_lookup(params: Params, cfg: LMConfig,
                  tokens: jax.Array) -> jax.Array:
    """Vocab-parallel embedding lookup.

    Plain ``embed[tokens]`` backward is a scatter-add that the SPMD
    partitioner materializes as a full fp32 [V, d] per device. Under a
    mesh we shard_map the lookup instead: each model shard resolves its
    own vocab range and a psum(+scatter over the sequence) assembles the
    activations — the backward is then a *local* scatter per shard.
    """
    emb = params["embed"]
    v = emb.shape[0]
    mesh = cfg.mesh
    tp = cfg.tp_axis if cfg.tp_axis is not None else None
    if (mesh is None or tp is None or v % mesh.shape[tp] != 0
            or tokens.shape[1] % mesh.shape[tp] != 0):
        x = emb.astype(cfg.compute_dtype)[tokens]
        return _cst(x, cfg, cfg.dp_axis, cfg.tp_axis, None)
    n_tp = mesh.shape[tp]

    def inner(emb_l, tok_l):
        vsh = emb_l.shape[0]
        lo = lax.axis_index(tp) * vsh
        sel = tok_l - lo
        ok = (sel >= 0) & (sel < vsh)
        out = jnp.where(ok[..., None],
                        emb_l[sel.clip(0, vsh - 1)].astype(
                            cfg.compute_dtype), 0)
        return lax.psum_scatter(out, tp, scatter_dimension=1, tiled=True)

    return jax.shard_map(
        inner, mesh=mesh,
        in_specs=(_P(tp, None), _P(cfg.dp_axis, None)),
        out_specs=_P(cfg.dp_axis, tp, None), check_vma=False,
    )(emb, tokens)


# --------------------------------------------------------------- forward
def _block_apply(layer_p: Params, cfg: LMConfig, x: jax.Array,
                 positions: jax.Array) -> jax.Array:
    h = rms_norm(x, layer_p["ln_attn"])
    if cfg.mla is not None:
        a = mla_train_apply(layer_p["attn"], cfg.mla, h, positions)
    else:
        a, _ = attn_apply(layer_p["attn"], cfg.attn_cfg(), h, positions)
    x = x + a
    h = rms_norm(x, layer_p["ln_ffn"])
    if cfg.moe is not None:
        f = moe_apply(layer_p["ffn"], cfg.moe, h)
    elif cfg.mlp == "swiglu":
        f = swiglu_apply(layer_p["ffn"], h)
    else:
        f = gelu_mlp_apply(layer_p["ffn"], h)
    return x + f


def _backbone(params: Params, cfg: LMConfig, tokens: jax.Array
              ) -> jax.Array:
    b, s = tokens.shape
    # sequence-parallel activation sharding (Megatron-SP): the remat
    # boundary (= what backward saves per layer) is sharded over BOTH the
    # data axis (batch) and the model axis (sequence), so the saved stack
    # is [L, B/dp, S/tp, d] instead of [L, B/dp, S, d]. Norms and matmuls
    # are token-local; GSPMD all-gathers K/V inside attention only.
    x = _embed_lookup(params, cfg, tokens)
    x = _cst(x, cfg, cfg.dp_axis, cfg.tp_axis, None)
    positions = jnp.arange(s)

    def body(x, layer_p):
        y = _block_apply(layer_p, cfg, x, positions)
        return _cst(y, cfg, cfg.dp_axis, cfg.tp_axis, None), None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = lax.scan(fn, x, params["layers"])
    return rms_norm(x, params["ln_final"])


def _head(params: Params, cfg: LMConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = x @ params["embed"].astype(x.dtype).T
    else:
        logits = dense(params["lm_head"], x)
    return _cst(logits, cfg, cfg.dp_axis, cfg.tp_axis, None)


def lm_logits(params: Params, cfg: LMConfig, tokens: jax.Array
              ) -> jax.Array:
    return _head(params, cfg, _backbone(params, cfg, tokens))


def _xent(logits: jax.Array, targets: jax.Array,
          mask: jax.Array | None = None) -> jax.Array:
    """Cross entropy in a GSPMD-friendly form: the gold-logit term is a
    masked reduction over the (model-sharded) vocab axis instead of a
    take_along_axis gather, so no vocab all-gather is ever inserted."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    vocab_iota = lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    gold = jnp.where(vocab_iota == targets[..., None], lf, 0.0).sum(-1)
    nll = lse - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


def _vocab_parallel_nll(params: Params, cfg: LMConfig, h: jax.Array,
                        targets: jax.Array) -> jax.Array:
    """Megatron-style vocab-parallel head + cross entropy under shard_map.

    Each model shard holds a [d, V/tp] slice of the head; the sequence is
    all-gathered once inside the shard, logits/loss are computed in
    seq-chunks (rematerialized), and only psums of scalars-per-token cross
    shards. The head gradient stays a *local* [d, V/tp] — without this the
    partitioner materializes a full fp32 [V, d] per device.
    """
    mesh, tp = cfg.mesh, cfg.tp_axis
    w = params["lm_head"]["w"] if not cfg.tie_embeddings else None
    v = cfg.vocab
    if (w is None or mesh is None or tp is None
            or v % mesh.shape[tp] != 0
            or h.shape[1] % (mesh.shape[tp] ** 2) != 0):
        lf = _head(params, cfg, h).astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        viota = lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
        gold = jnp.where(viota == targets[..., None], lf, 0.0).sum(-1)
        return lse - gold                                    # [B, S]

    def inner(hl, wl, tl):
        # hl: [B_l, S/tp, d] -> gather the full local sequence once
        hfull = lax.all_gather(hl, tp, axis=1, tiled=True)   # [B_l, S, d]
        vsh = wl.shape[1]
        lo = lax.axis_index(tp) * vsh
        n_chunks = mesh.shape[tp]
        bl, s, d = hfull.shape
        hc = hfull.reshape(bl, n_chunks, s // n_chunks, d).transpose(
            1, 0, 2, 3)
        tc = tl.reshape(bl, n_chunks, s // n_chunks).transpose(1, 0, 2)

        def chunk_nll(_, xs):
            hx, tx = xs
            logits = (hx @ wl.astype(hx.dtype)).astype(jnp.float32)
            m_loc = logits.max(axis=-1)
            # the running max is a numerical-stability shift only
            m = lax.pmax(lax.stop_gradient(m_loc), tp)
            se = jnp.exp(logits - m[..., None]).sum(axis=-1)
            se = lax.psum(se, tp)
            viota = lax.broadcasted_iota(jnp.int32, logits.shape, 2) + lo
            gold = jnp.where(viota == tx[..., None], logits, 0.0).sum(-1)
            gold = lax.psum(gold, tp)
            return None, jnp.log(se) + m - gold

        _, nll = lax.scan(jax.checkpoint(chunk_nll), None, (hc, tc))
        return nll.transpose(1, 0, 2).reshape(bl, s)

    nll = jax.shard_map(
        inner, mesh=mesh,
        in_specs=(_P(cfg.dp_axis, tp, None), _P(None, tp),
                  _P(cfg.dp_axis, None)),
        out_specs=_P(cfg.dp_axis, None), check_vma=False,
    )(h, w, targets)
    return nll                                               # [B, S]


def lm_loss(params: Params, cfg: LMConfig, batch: dict) -> jax.Array:
    """batch: {"tokens": [B, S], "targets": [B, S]} (targets = next ids).

    With ``cfg.mtp`` adds the DeepSeek-style one-step-ahead MTP loss.
    """
    h = _backbone(params, cfg, batch["tokens"])
    loss = _vocab_parallel_nll(params, cfg, h, batch["targets"]).mean()
    if cfg.mtp:
        # predict t+2: combine h_t with the embedding of target t+1
        def mtp_loss(h):
            emb_next = _embed_lookup(params, cfg, batch["targets"])
            z = jnp.concatenate([rms_norm(h, params["mtp"]["ln"]),
                                 emb_next], axis=-1)
            z = dense(params["mtp"]["proj"], z)
            z = _cst(z, cfg, cfg.dp_axis, cfg.tp_axis, None)
            s = z.shape[1]
            z = _block_apply(params["mtp"]["block"], cfg, z,
                             jnp.arange(s))
            # predict targets shifted one more step; mask the last column
            t2 = jnp.concatenate([batch["targets"][:, 1:],
                                  batch["targets"][:, -1:]], axis=1)
            nll = _vocab_parallel_nll(params, cfg, z, t2)
            return nll[:, :-1].mean()
        fn = jax.checkpoint(mtp_loss) if cfg.remat else mtp_loss
        loss = loss + cfg.mtp_weight * fn(h)
    return loss


# ---------------------------------------------------------------- decode
def init_decode_state(cfg: LMConfig, batch: int, s_max: int) -> Params:
    dt = cfg.compute_dtype
    if cfg.mla is not None:
        def one(_):
            return mla_init_cache(cfg.mla, batch, s_max, dt)
        caches = jax.vmap(one)(jnp.arange(cfg.n_layers))
    else:
        hk, hd = cfg.n_kv_heads, cfg.hd
        caches = (jnp.zeros((cfg.n_layers, batch, s_max, hk, hd), dt),
                  jnp.zeros((cfg.n_layers, batch, s_max, hk, hd), dt),
                  jnp.zeros((cfg.n_layers,), jnp.int32))
    return {"cache": caches, "length": jnp.zeros((), jnp.int32)}


def lm_decode_step(params: Params, cfg: LMConfig, tokens: jax.Array,
                   state: Params) -> tuple[jax.Array, Params]:
    """One decode step. tokens: [B, S_step] (S_step typically 1)."""
    b, s = tokens.shape
    x = _embed_lookup(params, cfg, tokens)
    length = state["length"]
    positions = length + jnp.arange(s)

    def body(x, scanned):
        layer_p, cache = scanned
        h = rms_norm(x, layer_p["ln_attn"])
        if cfg.mla is not None:
            c, r, _ = cache
            a, (c2, r2, _) = mla_decode_apply(
                layer_p["attn"], cfg.mla, h, (c, r, length))
            new_cache = (c2, r2, jnp.zeros((), jnp.int32))
        else:
            ck, cv, _ = cache
            a, (ck2, cv2, _) = attn_apply(
                layer_p["attn"], cfg.attn_cfg(), h, positions,
                kv_cache=(ck, cv, length))
            new_cache = (ck2, cv2, jnp.zeros((), jnp.int32))
        x = x + a
        h = rms_norm(x, layer_p["ln_ffn"])
        if cfg.moe is not None:
            f = moe_apply(layer_p["ffn"], cfg.moe, h)
        elif cfg.mlp == "swiglu":
            f = swiglu_apply(layer_p["ffn"], h)
        else:
            f = gelu_mlp_apply(layer_p["ffn"], h)
        return x + f, new_cache

    x, new_caches = lax.scan(body, x, (params["layers"], state["cache"]))
    x = rms_norm(x, params["ln_final"])
    logits = _head(params, cfg, x)
    return logits, {"cache": new_caches, "length": length + s}
