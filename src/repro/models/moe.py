"""Mixture-of-Experts FFN (DeepSeek-V3 / Kimi-K2 style).

Shared expert(s) + fine-grained routed experts with sigmoid top-k routing
(aux-loss-free bias option).

Dispatch is *group-local*: tokens are reshaped to [G, T/G, d] where G is
the number of token shards (dp × tp on the production mesh), and the
whole sort-based dispatch (argsort by expert, capacity clipping, scatter
into per-expert slots) is vmapped over the group axis. Every sort/cumsum
is therefore shard-local — nothing about routing crosses devices. The
only cross-device movement is the expert-major regroup

    [G, E, C, d]  --transpose-->  [E, G·C, d]

whose input is sharded over G (token shards) and output over E (expert
parallelism): GSPMD lowers exactly this into the MoE all-to-all. With
G=1 (CPU tests) the same code runs unsharded.

Capacity is per (group, expert): C = T_local·k/E · capacity_factor;
overflow tokens are dropped (drop-and-scale policy, GShard-style),
counted by ``router_load`` for the aux-free bias update.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as _P

from .layers import dense_init, swiglu_apply, swiglu_init

Params = Any


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int            # routed experts
    top_k: int
    d_ff_expert: int          # per-expert hidden dim
    n_shared: int = 1         # shared experts (always-on)
    d_ff_shared: int | None = None   # defaults to d_ff_expert * n_shared
    capacity_factor: float = 1.25
    router_dtype: Any = jnp.float32
    bias_update_rate: float = 1e-3   # aux-free load-balance bias (DSv3)
    # distribution (set by launch/steps.py; None = single-device smoke)
    ep_axis: Any = None              # expert-parallel mesh axis ("model")
    token_axes: Any = None           # token-shard axes, e.g. ("data","model")
    cap_axes: Any = None             # axes for the G*C slot dim (dp)
    dispatch_groups: int = 1         # G = product of token_axes sizes
    mesh: Any = None                 # Mesh => use the shard_map EP path
    dp_axes: Any = None              # data axes of the mesh (shard_map)
    seq_axis: Any = None             # sequence-parallel axis of activations


def moe_init(key, d_model: int, cfg: MoEConfig, dtype) -> Params:
    k_r, k_e, k_s = jax.random.split(key, 3)
    expert_keys = jax.random.split(k_e, cfg.n_experts)
    experts = jax.vmap(
        lambda k: swiglu_init(k, d_model, cfg.d_ff_expert, dtype))(
            expert_keys)
    p = {
        "router": dense_init(k_r, d_model, cfg.n_experts, jnp.float32),
        "router_bias": jnp.zeros((cfg.n_experts,), jnp.float32),
        "experts": experts,
    }
    if cfg.n_shared > 0:
        d_sh = cfg.d_ff_shared or cfg.d_ff_expert * cfg.n_shared
        p["shared"] = swiglu_init(k_s, d_model, d_sh, dtype)
    return p


def _cst(x, spec):
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def _route(p: Params, cfg: MoEConfig, flat: jax.Array):
    """flat: [T, d] -> (top_idx [T, K], top_w [T, K]); sigmoid + aux-free
    bias selection, weights from unbiased scores (DSv3 §2.1.2)."""
    scores = jax.nn.sigmoid(
        flat.astype(cfg.router_dtype) @ p["router"]["w"])
    biased = scores + p["router_bias"][None, :]
    _, top_idx = jax.lax.top_k(biased, cfg.top_k)
    top_w = jnp.take_along_axis(scores, top_idx, axis=1)
    top_w = top_w / (top_w.sum(axis=1, keepdims=True) + 1e-9)
    return top_idx, top_w


def _local_sort_dispatch(flat, keys, n_buckets: int, cap: int,
                         payload_dtype=None):
    """Sort-based bucketing of [T, d] rows by key into [n_buckets*cap, d].

    Returns (buf, order, slot) where ``slot[i]`` is the destination of the
    i-th *sorted* row (== n_buckets*cap when dropped) and ``order`` is the
    sort permutation. All ops are local (intended for shard_map bodies).
    """
    t = keys.shape[0]
    # negative keys mark empty slots: remap past the last bucket so they
    # sort to the end and never shift real buckets' positions
    ks_remap = jnp.where(keys < 0, n_buckets, keys)
    order = jnp.argsort(ks_remap)
    ks = ks_remap[order]
    counts = jnp.bincount(ks, length=n_buckets + 1)[:n_buckets]
    starts = jnp.cumsum(counts) - counts
    idx = jnp.arange(t) - starts[ks.clip(0, n_buckets - 1)]
    slot = jnp.where((ks < n_buckets) & (idx < cap),
                     ks.clip(0, n_buckets - 1) * cap + idx, n_buckets * cap)
    buf = jnp.zeros((n_buckets * cap, flat.shape[1]), flat.dtype
                    ).at[slot].set(flat[order], mode="drop")
    return buf, order, slot


def _moe_shard_map(p: Params, cfg: MoEConfig, x: jax.Array) -> jax.Array:
    """Expert-parallel MoE with explicit all-to-alls under shard_map.

    Layout: x is [B, S, d] sharded (dp_axes, seq_axis, None); routed
    experts are sharded over ``ep_axis`` (E_l = E / n_ep per shard). Each
    shard routes its local tokens, packs per-destination send buffers,
    all-to-alls tokens + expert ids to the owning shards, groups received
    tokens by local expert, runs the expert MLPs, and reverses the path.
    Two capacity stages (send and expert) drop overflow tokens
    (drop-and-scale, GShard-style), both local — the SPMD partitioner
    never sees the sorts/scatters that it would otherwise replicate.
    """
    from jax.sharding import PartitionSpec as P
    mesh = cfg.mesh
    ep = cfg.ep_axis
    n_ep = int(mesh.shape[ep])
    e, k = cfg.n_experts, cfg.top_k
    e_l = e // n_ep
    b, s, d = x.shape
    x_spec = P(cfg.dp_axes, cfg.seq_axis, None)
    exp_specs = jax.tree.map(lambda _: P(ep), p["experts"])

    def inner(xl, experts, router_w, router_bias):
        bl, sl, _ = xl.shape
        tl = bl * sl
        flat = xl.reshape(tl, d)
        scores = jax.nn.sigmoid(flat.astype(cfg.router_dtype) @ router_w)
        biased = scores + router_bias[None, :]
        _, top_idx = jax.lax.top_k(biased, k)
        top_w = jnp.take_along_axis(scores, top_idx, axis=1)
        top_w = (top_w / (top_w.sum(1, keepdims=True) + 1e-9)
                 ).astype(flat.dtype)
        pair_e = top_idx.reshape(-1).astype(jnp.int32)
        pair_t = jnp.repeat(jnp.arange(tl, dtype=jnp.int32), k)
        pair_w = top_w.reshape(-1)
        dest = pair_e // e_l
        c_send = int(max(1, round(tl * k / n_ep * cfg.capacity_factor)))
        send_x, order, slot = _local_sort_dispatch(flat[pair_t], dest,
                                                   n_ep, c_send)
        send_le = jnp.full((n_ep * c_send,), -1, jnp.int32).at[slot].set(
            (pair_e % e_l)[order], mode="drop")
        recv_x = jax.lax.all_to_all(send_x.reshape(n_ep, c_send, d), ep,
                                    0, 0, tiled=True)
        recv_le = jax.lax.all_to_all(send_le.reshape(n_ep, c_send), ep,
                                     0, 0, tiled=True)
        rx = recv_x.reshape(n_ep * c_send, d)
        rle = recv_le.reshape(-1)
        r = n_ep * c_send
        cap_e = int(max(1, round(r / e_l * cfg.capacity_factor)))
        buf, order2, slot2 = _local_sort_dispatch(rx, rle, e_l, cap_e)
        out = jax.vmap(swiglu_apply)(experts, buf.reshape(e_l, cap_e, d))
        out = out.reshape(e_l * cap_e, d)
        back = jnp.zeros((r, d), flat.dtype).at[order2].set(
            jnp.where((slot2 < e_l * cap_e)[:, None],
                      out[slot2.clip(0, e_l * cap_e - 1)], 0.0),
            mode="drop")
        ret = jax.lax.all_to_all(back.reshape(n_ep, c_send, d), ep,
                                 0, 0, tiled=True).reshape(r, d)
        got = jnp.where((slot < r)[:, None], ret[slot.clip(0, r - 1)], 0.0)
        y = jnp.zeros((tl, d), flat.dtype).at[pair_t[order]].add(
            got * pair_w[order][:, None])
        return y.reshape(bl, sl, d)

    y = jax.shard_map(
        inner, mesh=mesh,
        in_specs=(x_spec, exp_specs, P(), P()),
        out_specs=x_spec, check_vma=False,
    )(x, p["experts"], p["router"]["w"], p["router_bias"])
    if "shared" in p:
        # token-local: operate on [B, S, d] directly (no reshape — a
        # (dp, tp)-sharded dim merge would force a sequence all-gather)
        y = y + swiglu_apply(p["shared"], x)
    return y


def moe_apply(p: Params, cfg: MoEConfig, x: jax.Array) -> jax.Array:
    """x: [B, S, d] -> [B, S, d]."""
    if cfg.mesh is not None:
        return _moe_shard_map(p, cfg, x)
    b, s, d = x.shape
    t = b * s
    g = max(1, cfg.dispatch_groups)
    if t % g != 0:   # ragged fallback (smoke shapes): single group
        g = 1
    tl = t // g
    e, k = cfg.n_experts, cfg.top_k
    cap = int(max(k, round(tl * k / e * cfg.capacity_factor)))

    sharded = g > 1 and cfg.token_axes is not None
    tok_spec = _P(cfg.token_axes, None, None) if sharded else None
    # [E, G, C, d]: experts over EP axis, groups over the dp axes —
    # pure dim-permutation away from the dispatch layout (GSPMD lowers
    # the permutation to the MoE all-to-all; no dim merging, which the
    # SPMD partitioner cannot re-shard without replicating).
    ep_spec = (_P(cfg.ep_axis, cfg.cap_axes if sharded else None,
                  None, None)
               if cfg.ep_axis is not None else None)

    xs = _cst(x.reshape(g, tl, d), tok_spec)

    def dispatch(xg):
        """[tl, d] -> (buf [E, C, d], pt, pw, slot)."""
        top_idx, top_w = _route(p, cfg, xg)
        pair_e = top_idx.reshape(-1)                       # [tl*k]
        pair_t = jnp.repeat(jnp.arange(tl, dtype=jnp.int32), k)
        pair_w = top_w.reshape(-1)
        order = jnp.argsort(pair_e)
        pe, pt, pw = pair_e[order], pair_t[order], pair_w[order]
        counts = jnp.bincount(pe, length=e)
        starts = jnp.cumsum(counts) - counts
        idx_in_e = jnp.arange(tl * k) - starts[pe]
        slot = jnp.where(idx_in_e < cap, pe * cap + idx_in_e, e * cap)
        buf = jnp.zeros((e * cap, d), x.dtype).at[slot].set(
            xg[pt], mode="drop")
        return buf.reshape(e, cap, d), pt, pw, slot

    buf_g, pt_g, pw_g, slot_g = jax.vmap(dispatch)(xs)     # [G, E, C, d]

    # ---- expert-major regroup (the all-to-all) --------------------------
    buf = _cst(buf_g.transpose(1, 0, 2, 3), ep_spec)       # [E, G, C, d]
    out = jax.vmap(swiglu_apply)(p["experts"], buf)        # [E, G, C, d]
    out = _cst(out, ep_spec)
    out_g = _cst(out.transpose(1, 0, 2, 3),
                 _P(cfg.token_axes, None, None, None) if sharded else None)

    def combine(out_buf, pt, pw, slot):
        flat_buf = out_buf.reshape(e * cap, d)
        got = jnp.where((slot < e * cap)[:, None],
                        flat_buf[slot.clip(0, e * cap - 1)], 0.0)
        return jnp.zeros((tl, d), x.dtype).at[pt].add(
            got * pw[:, None].astype(x.dtype))

    comb = jax.vmap(combine)(out_g, pt_g, pw_g, slot_g)    # [G, tl, d]
    comb = _cst(comb, tok_spec)
    y = comb.reshape(b, s, d)

    if "shared" in p:
        y = y + swiglu_apply(p["shared"], x.reshape(t, d)).reshape(b, s, d)
    return y


def router_load(p: Params, cfg: MoEConfig, x: jax.Array) -> jax.Array:
    """Expert load fractions for the aux-free bias update (train loop)."""
    b, s, d = x.shape
    flat = x.reshape(-1, d)
    top_idx, _ = _route(p, cfg, flat)
    counts = jnp.bincount(top_idx.reshape(-1), length=cfg.n_experts)
    return counts / counts.sum()


def update_router_bias(p: Params, cfg: MoEConfig,
                       load: jax.Array) -> Params:
    """Aux-loss-free balancing: nudge bias against over/under-loaded
    experts (DeepSeek-V3 eq. 16-17 style sign update)."""
    target = 1.0 / cfg.n_experts
    delta = cfg.bias_update_rate * jnp.sign(target - load)
    return {**p, "router_bias": p["router_bias"] + delta}
