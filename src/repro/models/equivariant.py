"""E(3)-equivariant interatomic potentials: NequIP-lite and MACE-lite.

Hardware/software adaptation (recorded per DESIGN.md): e3nn is not
available offline, so irreps are carried in *Cartesian* form rather than
spherical-harmonic bases — mathematically equivalent for l <= 2:

    l=0  scalars            s  [N, C]
    l=1  vectors            v  [N, C, 3]
    l=2  traceless symmetric T  [N, C, 3, 3]

The tensor-product message paths below are exact Cartesian forms of the
Clebsch-Gordan contractions for (l_in ⊗ l_f -> l_out) with l <= 2, each
gated by a learned radial function of the edge length (Bessel basis x
cutoff envelope). Channel mixing happens per tensor order (equivariant),
nonlinearities act on scalars and on invariant norms (gates) only — so
the network is E(3)-equivariant by construction; tests rotate inputs and
assert energy invariance / force covariance to 1e-5.

MACE-lite adds the paper's key idea — higher body-order via *products of
aggregated one-hop features* (correlation order 3): invariant and
equivariant contractions of (A ⊗ A) and (A ⊗ A ⊗ A) enter the update,
giving many-body terms with only one aggregation sweep per layer.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .layers import dense, dense_init

Params = Any
EYE3 = jnp.eye(3)


@dataclasses.dataclass(frozen=True)
class EquivConfig:
    name: str
    kind: str                  # "nequip" | "mace"
    n_layers: int
    channels: int
    n_species: int = 8
    n_rbf: int = 8
    cutoff: float = 5.0
    l_max: int = 2             # fixed 2 in this implementation
    correlation: int = 1       # MACE: 3
    param_dtype: Any = jnp.float32
    # §Perf iteration 1 (mace × ogb_products): edge-chunked messages.
    # 0 = materialize all edge messages at once (fine to ~1e6 edges);
    # >0 = lax.scan over edge chunks with rematerialized bodies, so the
    # peak message footprint is O(chunk · C · 13) instead of O(E · C · 13).
    edge_chunk: int = 0


# ----------------------------------------------------------- radial basis
def bessel_basis(r: jax.Array, n: int, cutoff: float) -> jax.Array:
    """Sinc-like Bessel radial basis with smooth polynomial cutoff."""
    rs = jnp.maximum(r, 1e-9)[..., None]
    k = jnp.arange(1, n + 1, dtype=jnp.float32) * jnp.pi / cutoff
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(k * rs) / rs
    x = jnp.clip(r / cutoff, 0.0, 1.0)[..., None]
    env = 1.0 - 10.0 * x**3 + 15.0 * x**4 - 6.0 * x**5  # C^2 envelope
    return basis * env


def _traceless_sym(m: jax.Array) -> jax.Array:
    """Project [..., 3, 3] onto traceless-symmetric (the l=2 rep)."""
    sym = 0.5 * (m + jnp.swapaxes(m, -1, -2))
    tr = jnp.trace(sym, axis1=-2, axis2=-1)[..., None, None]
    return sym - tr * EYE3 / 3.0


# ----------------------------------------------------------------- layers
_N_PATHS = 9   # tensor-product paths below


def _layer_init(key, cfg: EquivConfig, first: bool) -> Params:
    c = cfg.channels
    ks = jax.random.split(key, 6)
    return {
        # radial MLP: rbf -> per-(path, channel) weights
        "rad1": dense_init(ks[0], cfg.n_rbf, 32, cfg.param_dtype, True),
        "rad2": dense_init(ks[1], 32, _N_PATHS * c, cfg.param_dtype, True),
        # per-order channel mixers
        "mix_s": dense_init(ks[2], c * (3 if cfg.correlation >= 2 else 1)
                            + (3 * c if cfg.correlation >= 3 else 0),
                            c, cfg.param_dtype, True),
        "mix_v": dense_init(ks[3], c * (2 if cfg.correlation >= 2 else 1),
                            c, cfg.param_dtype),
        "mix_t": dense_init(ks[4], c * (2 if cfg.correlation >= 2 else 1),
                            c, cfg.param_dtype),
        "gate": dense_init(ks[5], c, 2 * c, cfg.param_dtype, True),
    }


def equiv_init(key, cfg: EquivConfig) -> Params:
    k_e, k_l, k_r = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_l, cfg.n_layers)
    return {
        "species_embed": (jax.random.normal(
            k_e, (cfg.n_species, cfg.channels), jnp.float32) * 0.5
            ).astype(cfg.param_dtype),
        "layers": [_layer_init(layer_keys[i], cfg, i == 0)
                   for i in range(cfg.n_layers)],
        "readout1": dense_init(jax.random.fold_in(k_r, 0), cfg.channels,
                               cfg.channels, cfg.param_dtype, True),
        "readout2": dense_init(jax.random.fold_in(k_r, 1), cfg.channels,
                               1, cfg.param_dtype, True),
    }


def _messages(layer: Params, cfg: EquivConfig, s, v, T, src, dst, rvec, n):
    """One tensor-product message sweep + aggregation.

    rvec: [E, 3] displacement of each edge (dst <- src).
    Returns aggregated (As, Av, AT), each [N, C, ...]. With
    ``cfg.edge_chunk`` set, edges stream through a rematerialized scan —
    the message tensors for one chunk are the only live edge-sized
    buffers (the ogb_products-scale memory fix, EXPERIMENTS.md §Perf).
    """
    e_total = src.shape[0]
    ck = cfg.edge_chunk
    if ck and e_total > ck:
        n_chunks = -(-e_total // ck)
        pad = n_chunks * ck - e_total
        srcp = jnp.concatenate([src, jnp.zeros(pad, src.dtype)])
        dstp = jnp.concatenate([dst, jnp.zeros(pad, dst.dtype)])
        validp = jnp.concatenate([jnp.ones(e_total, bool),
                                  jnp.zeros(pad, bool)])
        rvecp = jnp.concatenate([rvec, jnp.ones((pad, 3), rvec.dtype)])

        def body(carry, xs):
            As, Av, AT = carry
            sc, dc, rv, va = xs
            ms, mv, mT = _edge_messages(layer, cfg, s, v, T, sc, rv)
            w = va.astype(ms.dtype)
            As = As + jax.ops.segment_sum(ms * w[:, None], dc,
                                          num_segments=n)
            Av = Av + jax.ops.segment_sum(mv * w[:, None, None], dc,
                                          num_segments=n)
            AT = AT + jax.ops.segment_sum(mT * w[:, None, None, None],
                                          dc, num_segments=n)
            return (As, Av, AT), None

        init = (jnp.zeros((n, cfg.channels), s.dtype),
                jnp.zeros((n, cfg.channels, 3), s.dtype),
                jnp.zeros((n, cfg.channels, 3, 3), s.dtype))
        xs = (srcp.reshape(n_chunks, ck), dstp.reshape(n_chunks, ck),
              rvecp.reshape(n_chunks, ck, 3),
              validp.reshape(n_chunks, ck))
        (As, Av, AT), _ = jax.lax.scan(jax.checkpoint(body), init, xs)
        return As, Av, AT

    m_s, m_v, m_T = _edge_messages(layer, cfg, s, v, T, src, rvec)
    As = jax.ops.segment_sum(m_s, dst, num_segments=n)
    Av = jax.ops.segment_sum(m_v, dst, num_segments=n)
    AT = jax.ops.segment_sum(m_T, dst, num_segments=n)
    return As, Av, AT


def _edge_messages(layer: Params, cfg: EquivConfig, s, v, T, src, rvec):
    """Per-edge tensor-product messages (no aggregation)."""
    c = cfg.channels
    r = jnp.linalg.norm(rvec, axis=-1)                       # [E]
    rhat = rvec / jnp.maximum(r, 1e-9)[:, None]              # [E, 3]
    rbf = bessel_basis(r, cfg.n_rbf, cfg.cutoff)             # [E, nrbf]
    w = dense(layer["rad2"], jax.nn.silu(dense(layer["rad1"], rbf)))
    w = w.reshape(-1, _N_PATHS, c)                           # [E, P, C]

    s_j = s[src]                                             # [E, C]
    v_j = v[src]                                             # [E, C, 3]
    T_j = T[src]                                             # [E, C, 3, 3]
    Y2 = _traceless_sym(rhat[:, None, :] * rhat[:, :, None])  # [E, 3, 3]

    # --- scalar messages: (0⊗0→0), (1⊗1→0), (2⊗2→0) -------------------
    m_s = (w[:, 0] * s_j
           + w[:, 1] * jnp.einsum("eci,ei->ec", v_j, rhat)
           + w[:, 2] * jnp.einsum("ecij,eij->ec", T_j, Y2))
    # --- vector messages: (0⊗1→1), (1⊗0→1), (2⊗1→1) -------------------
    m_v = (w[:, 3, :, None] * s_j[:, :, None] * rhat[:, None, :]
           + w[:, 4, :, None] * v_j
           + w[:, 5, :, None] * jnp.einsum("ecij,ej->eci", T_j, rhat))
    # --- tensor messages: (0⊗2→2), (1⊗1→2), (2⊗0→2) -------------------
    outer_vr = _traceless_sym(v_j[..., :, None] * rhat[:, None, None, :])
    m_T = (w[:, 6, :, None, None] * s_j[:, :, None, None] * Y2[:, None]
           + w[:, 7, :, None, None] * outer_vr
           + w[:, 8, :, None, None] * T_j)
    return m_s, m_v, m_T


def _update(layer: Params, cfg: EquivConfig, s, v, T, As, Av, AT):
    """Equivariant update with optional MACE higher-order products."""
    s_feats = [As]
    v_feats = [Av]
    t_feats = [AT]
    if cfg.correlation >= 2:      # two-body products of aggregates
        s_feats += [jnp.einsum("nci,nci->nc", Av, Av),
                    jnp.einsum("ncij,ncij->nc", AT, AT)]
        v_feats += [jnp.einsum("ncij,ncj->nci", AT, Av)]
        t_feats += [_traceless_sym(Av[..., :, None] * Av[..., None, :])]
    if cfg.correlation >= 3:      # three-body invariants
        s_feats += [As * As,
                    As * jnp.einsum("nci,nci->nc", Av, Av),
                    jnp.einsum("nci,ncij,ncj->nc", Av, AT, Av)]
    s_new = dense(layer["mix_s"], jnp.concatenate(s_feats, axis=-1))
    v_cat = jnp.concatenate(v_feats, axis=1)              # [N, kC, 3]
    t_cat = jnp.concatenate(t_feats, axis=1)
    # channel mixing via einsum against [kC, C] weight (equivariant)
    v_new = jnp.einsum("nki,kc->nci", v_cat, layer["mix_v"]["w"])
    T_new = jnp.einsum("nkij,kc->ncij", t_cat, layer["mix_t"]["w"])
    # gated nonlinearity: scalars gate higher orders
    gates = jax.nn.sigmoid(dense(layer["gate"], jax.nn.silu(s_new)))
    gv, gt = gates[..., :cfg.channels], gates[..., cfg.channels:]
    return (s + jax.nn.silu(s_new),
            v + v_new * gv[..., None],
            T + T_new * gt[..., None, None])


def equiv_energy(params: Params, cfg: EquivConfig, species: jax.Array,
                 positions: jax.Array, edge_index: jax.Array) -> jax.Array:
    """Total energy. species: int [N]; positions: [N, 3];
    edge_index: [2, E] (both directions for undirected neighbor lists)."""
    n = species.shape[0]
    src, dst = edge_index[0], edge_index[1]
    rvec = positions[src] - positions[dst]
    s = params["species_embed"][species]
    v = jnp.zeros((n, cfg.channels, 3), s.dtype)
    T = jnp.zeros((n, cfg.channels, 3, 3), s.dtype)
    for layer in params["layers"]:
        As, Av, AT = _messages(layer, cfg, s, v, T, src, dst, rvec, n)
        s, v, T = _update(layer, cfg, s, v, T, As, Av, AT)
    e_node = dense(params["readout2"],
                   jax.nn.silu(dense(params["readout1"], s)))
    return e_node.sum()


def equiv_forces(params: Params, cfg: EquivConfig, species, positions,
                 edge_index) -> tuple[jax.Array, jax.Array]:
    """(energy, forces = -dE/dpos) — the standard potential interface."""
    e, grad = jax.value_and_grad(
        lambda pos: equiv_energy(params, cfg, species, pos, edge_index)
    )(positions)
    return e, -grad


def equiv_node_energies(params: Params, cfg: EquivConfig, species,
                        positions, edge_index) -> jax.Array:
    """Per-node energy contributions [N] (for batched graphs)."""
    n = species.shape[0]
    src, dst = edge_index[0], edge_index[1]
    rvec = positions[src] - positions[dst]
    s = params["species_embed"][species]
    v = jnp.zeros((n, cfg.channels, 3), s.dtype)
    T = jnp.zeros((n, cfg.channels, 3, 3), s.dtype)
    for layer in params["layers"]:
        As, Av, AT = _messages(layer, cfg, s, v, T, src, dst, rvec, n)
        s, v, T = _update(layer, cfg, s, v, T, As, Av, AT)
    return dense(params["readout2"],
                 jax.nn.silu(dense(params["readout1"], s)))[:, 0]


def equiv_batched_loss(params: Params, cfg: EquivConfig, batch,
                       n_graphs: int) -> jax.Array:
    """Disjoint-union molecular batch: per-graph energy MSE (+forces)."""
    def total_by_graph(pos):
        e_node = equiv_node_energies(params, cfg, batch["species"], pos,
                                     batch["edge_index"])
        return jax.ops.segment_sum(e_node, batch["graph_id"],
                                   num_segments=n_graphs)
    e_graphs = total_by_graph(batch["positions"])
    loss = ((e_graphs - batch["energy"]) ** 2).mean()
    if "forces" in batch:
        forces = -jax.grad(lambda p: total_by_graph(p).sum())(
            batch["positions"])
        loss = loss + ((forces - batch["forces"]) ** 2).mean()
    return loss


def equiv_energy_loss(params: Params, cfg: EquivConfig, batch) -> jax.Array:
    """MSE on per-graph energies for batched molecular training."""
    e, f = equiv_forces(params, cfg, batch["species"], batch["positions"],
                        batch["edge_index"])
    loss = (e - batch["energy"]) ** 2
    if "forces" in batch:
        loss = loss + ((f - batch["forces"]) ** 2).mean()
    return loss
