"""Shared neural-network layers (pure-function style, pytree params).

No framework dependency: a layer is an ``init(key, cfg) -> params`` plus an
``apply(params, x, ...) -> y`` pair. All big models stack layer params on a
leading layer axis and scan, keeping HLO size O(1) in depth.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

Params = Any


# --------------------------------------------------------------- norms
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------- rope
def rope_freqs(head_dim: int, max_pos: int, theta: float = 1e4) -> jax.Array:
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                      dtype=jnp.float32) / head_dim))
    pos = jnp.arange(max_pos, dtype=jnp.float32)
    return jnp.outer(pos, inv)                       # [max_pos, head_dim/2]


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 1e4) -> jax.Array:
    """Rotary embedding. x: [B, S, H, D] or [B, S, D]; positions: [S]
    absolute positions shared across the batch. Rotates (even, odd) pairs.
    """
    d = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions.astype(jnp.float32)[:, None] * inv      # [S, D/2]
    if x.ndim == 4:
        ang = ang[None, :, None, :]                          # [1,S,1,D/2]
    elif x.ndim == 3:
        ang = ang[None, :, :]                                # [1,S,D/2]
    else:
        raise ValueError(f"unsupported rope input rank {x.ndim}")
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# --------------------------------------------------------------- linear
def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False,
               scale: float | None = None) -> Params:
    std = scale if scale is not None else d_in ** -0.5
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * std
         ).astype(dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# --------------------------------------------------------------- attention
@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    attn_chunk: int = 1024      # kv-chunk size of the online-softmax scan


def attn_init(key, cfg: AttnConfig, dtype) -> Params:
    ks = jax.random.split(key, 6)
    h, hk, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(ks[0], cfg.d_model, h * d, dtype, cfg.qkv_bias),
        "wk": dense_init(ks[1], cfg.d_model, hk * d, dtype, cfg.qkv_bias),
        "wv": dense_init(ks[2], cfg.d_model, hk * d, dtype, cfg.qkv_bias),
        "wo": dense_init(ks[3], h * d, cfg.d_model, dtype,
                         scale=(h * d) ** -0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((d,), dtype)
        p["k_norm"] = jnp.ones((d,), dtype)
    return p


def chunked_sdpa(q, k, v, *, causal: bool = True, q_offset: int | jax.Array = 0,
                 chunk: int = 1024, valid_len=None) -> jax.Array:
    """Memory-efficient attention: lax.scan over key/value chunks with an
    online softmax (the pure-JAX counterpart of kernels/flash_attention).

    q: [B, S, H, D]; k/v: [B, T, Hkv, D]. Never materializes [S, T];
    per-step temp is [B, S, H, chunk]. The chunk body is rematerialized in
    the backward pass, so training memory is O(S·D), not O(S·T).

    ``q_offset``: absolute position of q[0] (causal masking for chunked
    prefill); ``valid_len``: mask key positions >= valid_len (KV caches).
    """
    b, s, h, d = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = h // hk
    scale = d ** -0.5
    n_chunks = -(-t // chunk)
    t_pad = n_chunks * chunk
    if t_pad != t:
        pad = [(0, 0), (0, t_pad - t), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    t_valid = valid_len if valid_len is not None else t
    qf = q.reshape(b, s, hk, g, d).astype(jnp.float32)
    kc = k.reshape(b, n_chunks, chunk, hk, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, hk, d).transpose(1, 0, 2, 3, 4)
    bases = jnp.arange(n_chunks) * chunk
    qpos = jnp.arange(s) + q_offset

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, base = xs
        logits = jnp.einsum("bshgd,bchd->bshgc", qf,
                            kblk.astype(jnp.float32)) * scale
        kpos = base + jnp.arange(chunk)
        mask = kpos[None, :] < t_valid
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        logits = jnp.where(mask[None, :, None, None, :], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bshgc,bchd->bshgd", p, vblk.astype(jnp.float32))
        return (m_new, l, acc), None

    init = (jnp.full((b, s, hk, g), -1e30, jnp.float32),
            jnp.zeros((b, s, hk, g), jnp.float32),
            jnp.zeros((b, s, hk, g, d), jnp.float32))
    (m, l, acc), _ = lax.scan(jax.checkpoint(body), init, (kc, vc, bases))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, s, h, d).astype(q.dtype)


def _sdpa(q, k, v, causal: bool, q_offset=None):
    """q [B,S,H,D], k/v [B,T,Hkv,D] -> [B,S,H,D]; f32 softmax math.

    ``q_offset``: absolute position of the first query (for causal masking
    of decode/chunked-prefill where S != T).
    """
    b, s, h, d = q.shape
    t, hk = k.shape[1], k.shape[2]
    group = h // hk
    qf = q.reshape(b, s, hk, group, d).astype(jnp.float32)
    logits = jnp.einsum("bshgd,bthd->bhgst", qf,
                        k.astype(jnp.float32)) * (d ** -0.5)
    if causal:
        off = q_offset if q_offset is not None else t - s
        qpos = jnp.arange(s)[:, None] + off
        kpos = jnp.arange(t)[None, :]
        mask = kpos <= qpos
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)


def attn_apply(p: Params, cfg: AttnConfig, x: jax.Array,
               positions: jax.Array, kv_cache=None, causal: bool = True):
    """Returns (y, new_kv_cache). kv_cache = (k, v, length) with k/v
    [B, S_max, Hkv, D] or None for plain training forward."""
    b, s, _ = x.shape
    h, hk, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense(p["wq"], x).reshape(b, s, h, d)
    k = dense(p["wk"], x).reshape(b, s, hk, d)
    v = dense(p["wv"], x).reshape(b, s, hk, d)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if kv_cache is None:
        if s > cfg.attn_chunk:
            y = chunked_sdpa(q, k, v, causal=causal,
                             chunk=min(cfg.attn_chunk, s))
        else:
            y = _sdpa(q, k, v, causal=causal)
        new_cache = None
    else:
        ck, cv, length = kv_cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, length, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, length, 0, 0))
        t = ck.shape[1]
        kpos = jnp.arange(t)
        valid = kpos < (length + s)
        qpos = positions[:s]
        mask = valid[None, :] & (kpos[None, :] <= qpos[:, None])
        y = _masked_sdpa(q, ck, cv, mask)
        new_cache = (ck, cv, length + s)
    y = y.reshape(b, s, h * d)
    return dense(p["wo"], y), new_cache


def _masked_sdpa(q, k, v, mask):
    b, s, h, d = q.shape
    t, hk = k.shape[1], k.shape[2]
    group = h // hk
    qf = q.reshape(b, s, hk, group, d).astype(jnp.float32)
    logits = jnp.einsum("bshgd,bthd->bhgst", qf,
                        k.astype(jnp.float32)) * (d ** -0.5)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)


# --------------------------------------------------------------- mlp
def swiglu_init(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wg": dense_init(k1, d_model, d_ff, dtype),
            "wu": dense_init(k2, d_model, d_ff, dtype),
            "wd": dense_init(k3, d_ff, d_model, dtype,
                             scale=d_ff ** -0.5)}


def swiglu_apply(p: Params, x: jax.Array) -> jax.Array:
    return dense(p["wd"], jax.nn.silu(dense(p["wg"], x)) * dense(p["wu"], x))


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype,
                  bias: bool = True) -> Params:
    k1, k2 = jax.random.split(key)
    return {"wi": dense_init(k1, d_model, d_ff, dtype, bias),
            "wo": dense_init(k2, d_ff, d_model, dtype, bias,
                             scale=d_ff ** -0.5)}


def gelu_mlp_apply(p: Params, x: jax.Array) -> jax.Array:
    return dense(p["wo"], jax.nn.gelu(dense(p["wi"], x)))
