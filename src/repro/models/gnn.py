"""GCN and GIN message-passing layers (SpMM regime).

JAX has no CSR SpMM — message passing is built from ``jnp.take`` +
``jax.ops.segment_sum`` over an edge index, exactly as the assignment
requires. Three execution modes:

  * full-batch  — one segment-sum over all edges (Cora, ogb_products);
    node/edge arrays shard over the mesh data axis, GSPMD turns the
    boundary gathers into all-to-alls (§Dry-run).
  * sampled     — fanout-bounded neighbor blocks [B, fanout] from
    ``data.sampler`` (Reddit-scale minibatch training).
  * batched-small-graphs — molecules packed into one disjoint union graph
    with a graph-id segment vector.

The packed-bitmap Pallas SpMM (``kernels/bitmap_spmm``) is a drop-in for
the full-batch path on graphs whose bitmap fits HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .layers import dense, dense_init

Params = Any


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str                  # "gcn" | "gin"
    n_layers: int
    d_in: int
    d_hidden: int
    n_classes: int
    aggregator: str = "mean"   # gcn: sym-norm handled separately
    sym_norm: bool = True      # GCN D^-1/2 A D^-1/2
    learnable_eps: bool = True  # GIN
    dropout: float = 0.0
    param_dtype: Any = jnp.float32


def gnn_init(key, cfg: GNNConfig) -> Params:
    dims = ([cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1)
            + [cfg.n_classes])
    keys = jax.random.split(key, cfg.n_layers)
    layers = []
    for i in range(cfg.n_layers):
        if cfg.kind == "gcn":
            layers.append({"lin": dense_init(keys[i], dims[i], dims[i + 1],
                                             cfg.param_dtype, bias=True)})
        else:  # GIN: 2-layer MLP per layer
            k1, k2 = jax.random.split(keys[i])
            layers.append({
                "mlp1": dense_init(k1, dims[i], dims[i + 1],
                                   cfg.param_dtype, bias=True),
                "mlp2": dense_init(k2, dims[i + 1], dims[i + 1],
                                   cfg.param_dtype, bias=True),
                "eps": jnp.zeros((), cfg.param_dtype),
            })
    return {"layers": layers}


def _aggregate(x: jax.Array, src: jax.Array, dst: jax.Array, n: int,
               deg: jax.Array, cfg: GNNConfig) -> jax.Array:
    """Segment-sum message passing: out[i] = reduce_{j->i} x[j] * coef."""
    msgs = jnp.take(x, src, axis=0)
    if cfg.kind == "gcn" and cfg.sym_norm:
        coef = jax.lax.rsqrt(jnp.maximum(deg[src], 1.0)) \
            * jax.lax.rsqrt(jnp.maximum(deg[dst], 1.0))
        msgs = msgs * coef[:, None]
    agg = jax.ops.segment_sum(msgs, dst, num_segments=n)
    if cfg.kind == "gcn" and not cfg.sym_norm and cfg.aggregator == "mean":
        agg = agg / jnp.maximum(deg[:, None], 1.0)
    return agg


def _layer_apply(layer: Params, cfg: GNNConfig, h: jax.Array,
                 agg: jax.Array, last: bool) -> jax.Array:
    if cfg.kind == "gcn":
        # self loop folded in: (agg + h/deg-normish) @ W — standard GCN
        # uses A+I; we add the normalized self term explicitly
        out = dense(layer["lin"], agg)
    else:
        out = dense(layer["mlp2"],
                    jax.nn.relu(dense(layer["mlp1"],
                                      (1.0 + layer["eps"]) * h + agg)))
    return out if last else jax.nn.relu(out)


def gnn_forward_full(params: Params, cfg: GNNConfig, x: jax.Array,
                     edge_index: jax.Array) -> jax.Array:
    """Full-batch forward. x: [N, d_in]; edge_index: int32 [2, E]
    (directed pairs; undirected graphs list both directions).
    Self-loops are added internally for GCN."""
    n = x.shape[0]
    src, dst = edge_index[0], edge_index[1]
    if cfg.kind == "gcn":
        loops = jnp.arange(n, dtype=src.dtype)
        src = jnp.concatenate([src, loops])
        dst = jnp.concatenate([dst, loops])
    deg = jax.ops.segment_sum(jnp.ones_like(src, jnp.float32), dst,
                              num_segments=n)
    h = x
    for i, layer in enumerate(params["layers"]):
        agg = _aggregate(h, src, dst, n, deg, cfg)
        h = _layer_apply(layer, cfg, h, agg, last=(i == cfg.n_layers - 1))
    return h


def gnn_forward_sampled(params: Params, cfg: GNNConfig,
                        feats: list[jax.Array],
                        nbr_idx: list[jax.Array],
                        nbr_valid: list[jax.Array]) -> jax.Array:
    """Fanout-sampled forward (GraphSAGE-style blocks).

    feats[k]:     [N_k, d_in] features of layer-k nodes (N_0 = seeds).
    nbr_idx[k]:   int32 [N_k, fanout_k] indices into feats[k+1].
    nbr_valid[k]: bool  [N_k, fanout_k].
    """
    h = [f for f in feats]
    for i, layer in enumerate(params["layers"]):
        new_h = []
        depth = cfg.n_layers - i  # layers of h still needed
        for kk in range(depth):
            nbrs = jnp.take(h[kk + 1], nbr_idx[kk], axis=0)  # [N,f,d]
            valid = nbr_valid[kk][..., None]
            if cfg.kind == "gcn":
                # include self in the normalized mean (A+I semantics)
                agg = ((nbrs * valid).sum(axis=1) + h[kk]) / \
                    (valid.sum(axis=1) + 1.0)
            elif cfg.aggregator == "mean":
                agg = (nbrs * valid).sum(axis=1) / \
                    jnp.maximum(valid.sum(axis=1), 1.0)
            else:
                agg = (nbrs * valid).sum(axis=1)
            new_h.append(_layer_apply(layer, cfg, h[kk], agg,
                                      last=(i == cfg.n_layers - 1)))
        h = new_h
    return h[0]


def gnn_forward_batched(params: Params, cfg: GNNConfig, x: jax.Array,
                        edge_index: jax.Array, graph_id: jax.Array,
                        n_graphs: int) -> jax.Array:
    """Disjoint-union batched small graphs -> per-graph logits via
    sum-pool readout (GIN-style)."""
    node_logits = gnn_forward_full(params, cfg, x, edge_index)
    return jax.ops.segment_sum(node_logits, graph_id,
                               num_segments=n_graphs)


def gnn_loss(params: Params, cfg: GNNConfig, x, edge_index, labels,
             mask=None) -> jax.Array:
    logits = gnn_forward_full(params, cfg, x, edge_index)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
