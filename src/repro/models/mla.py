"""Multi-head Latent Attention (DeepSeek-V2/V3).

K/V are compressed into a small latent ``c_kv`` (plus a shared RoPE key
channel); the KV cache stores only ``[B, S, d_c + d_rope]`` — the memory
win that makes the 500k-token decode cell feasible. Decode uses the
*absorbed* formulation: ``W_uk`` folds into the query and ``W_uv`` into
the output projection, so per-step attention works directly on the latent
cache without rematerializing per-head K/V.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as _P

from .layers import apply_rope, dense, dense_init, rms_norm


def _cst(x, cfg: "MLAConfig", *axes):
    if cfg.dp_axis is None:
        return x
    return lax.with_sharding_constraint(x, _P(*axes))

Params = Any


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    d_c: int = 512            # kv compression dim
    d_cq: int = 1536          # q compression dim
    d_nope: int = 128         # per-head non-rope dim
    d_rope: int = 64          # per-head rope dim (shared k channel)
    d_v: int = 128            # per-head value dim
    rope_theta: float = 1e4
    dp_axis: Any = None       # activation sharding (set by launch/steps)
    tp_axis: Any = None
    mesh: Any = None          # Mesh + decode_flash => flash-decoding path
    decode_flash: bool = False


def mla_init(key, cfg: MLAConfig, dtype) -> Params:
    ks = jax.random.split(key, 8)
    h = cfg.n_heads
    return {
        "w_dq": dense_init(ks[0], cfg.d_model, cfg.d_cq, dtype),
        "q_norm": jnp.ones((cfg.d_cq,), dtype),
        "w_uq": dense_init(ks[1], cfg.d_cq,
                           h * (cfg.d_nope + cfg.d_rope), dtype),
        "w_dkv": dense_init(ks[2], cfg.d_model, cfg.d_c, dtype),
        "kv_norm": jnp.ones((cfg.d_c,), dtype),
        "w_kr": dense_init(ks[3], cfg.d_model, cfg.d_rope, dtype),
        "w_uk": dense_init(ks[4], cfg.d_c, h * cfg.d_nope, dtype),
        "w_uv": dense_init(ks[5], cfg.d_c, h * cfg.d_v, dtype),
        "w_o": dense_init(ks[6], h * cfg.d_v, cfg.d_model, dtype,
                          scale=(h * cfg.d_v) ** -0.5),
    }


def _q_proj(p, cfg: MLAConfig, x, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    cq = rms_norm(dense(p["w_dq"], x), p["q_norm"])
    q = dense(p["w_uq"], cq).reshape(b, s, h, cfg.d_nope + cfg.d_rope)
    q_nope, q_rope = q[..., :cfg.d_nope], q[..., cfg.d_nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_train_apply(p: Params, cfg: MLAConfig, x: jax.Array,
                    positions: jax.Array, chunk: int = 1024) -> jax.Array:
    """Training / prefill forward (no cache), causal. x: [B, S, d].

    Flash-MLA: the online-softmax scan walks *latent* chunks and expands
    per-head K/V per chunk inside the (rematerialized) body, so neither
    the [S, S] score matrix nor the full per-head K/V [B, S, H, d] ever
    materializes — the training-memory analogue of the latent KV cache.
    """
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _q_proj(p, cfg, x, positions)            # [B,S,H,*]
    # queries (and the softmax state) stay sequence-sharded; only the
    # small latent K-side is gathered chunk-by-chunk
    q_nope = _cst(q_nope, cfg, cfg.dp_axis, cfg.tp_axis, None, None)
    q_rope = _cst(q_rope, cfg, cfg.dp_axis, cfg.tp_axis, None, None)
    c_kv = rms_norm(dense(p["w_dkv"], x), p["kv_norm"])       # [B, S, d_c]
    k_rope = apply_rope(dense(p["w_kr"], x), positions,
                        cfg.rope_theta)                        # [B, S, d_r]
    scale = (cfg.d_nope + cfg.d_rope) ** -0.5
    ck = min(chunk, s)
    n_chunks = -(-s // ck)
    s_pad = n_chunks * ck
    if s_pad != s:
        c_kv = jnp.pad(c_kv, [(0, 0), (0, s_pad - s), (0, 0)])
        k_rope = jnp.pad(k_rope, [(0, 0), (0, s_pad - s), (0, 0)])
    cc = c_kv.reshape(b, n_chunks, ck, cfg.d_c).transpose(1, 0, 2, 3)
    rc = k_rope.reshape(b, n_chunks, ck, cfg.d_rope).transpose(1, 0, 2, 3)
    bases = jnp.arange(n_chunks) * ck
    qf_n = q_nope.astype(jnp.float32)
    qf_r = q_rope.astype(jnp.float32)
    qpos = positions.astype(jnp.int32)

    def body(carry, xs):
        m, l, acc = carry
        c_blk, r_blk, base = xs
        k_nope = dense(p["w_uk"], c_blk).reshape(b, ck, h, cfg.d_nope)
        v_blk = dense(p["w_uv"], c_blk).reshape(b, ck, h, cfg.d_v)
        logits = (jnp.einsum("bshd,bchd->bshc", qf_n,
                             k_nope.astype(jnp.float32))
                  + jnp.einsum("bshd,bcd->bshc", qf_r,
                               r_blk.astype(jnp.float32))
                  ) * scale                                   # [B,S,H,ck]
        kpos = base + jnp.arange(ck)
        mask = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] < s)
        logits = jnp.where(mask[None, :, None, :], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        pr = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + pr.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bshc,bchd->bshd", pr, v_blk.astype(jnp.float32))
        m_new = _cst(m_new, cfg, cfg.dp_axis, cfg.tp_axis, None)
        l_new = _cst(l_new, cfg, cfg.dp_axis, cfg.tp_axis, None)
        acc_new = _cst(acc_new, cfg, cfg.dp_axis, cfg.tp_axis, None, None)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, s, h), -1e30, jnp.float32),
            jnp.zeros((b, s, h), jnp.float32),
            jnp.zeros((b, s, h, cfg.d_v), jnp.float32))
    (m, l, acc), _ = lax.scan(jax.checkpoint(body), init, (cc, rc, bases))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.reshape(b, s, h * cfg.d_v).astype(x.dtype)
    out = _cst(out, cfg, cfg.dp_axis, cfg.tp_axis, None)
    return dense(p["w_o"], out)


def mla_init_cache(cfg: MLAConfig, batch: int, s_max: int, dtype
                   ) -> tuple[jax.Array, jax.Array, jax.Array]:
    return (jnp.zeros((batch, s_max, cfg.d_c), dtype),
            jnp.zeros((batch, s_max, cfg.d_rope), dtype),
            jnp.zeros((), jnp.int32))


def mla_decode_flash(p: Params, cfg: MLAConfig, x: jax.Array,
                     cache) -> tuple[jax.Array, tuple]:
    """Flash-decoding MLA step under shard_map (§Perf hillclimb A iter 2).

    The latent cache is *sequence-sharded* over the model axis; each
    shard updates only the cache slice it owns (masked DUS — no
    cross-shard resharding), computes its partial online-softmax state
    against its local keys, and the shards combine with a max/psum
    log-sum-exp merge. Collective payload per layer = the [B_l, H, d_c]
    partial accumulator (~MBs) instead of the all-gathered cache (~GBs).
    """
    mesh, dpa, tp = cfg.mesh, cfg.dp_axis, cfg.tp_axis
    b, s, _ = x.shape
    h = cfg.n_heads
    c_cache, r_cache, length = cache
    s_max = c_cache.shape[1]
    n_tp = int(mesh.shape[tp])
    s_shard = s_max // n_tp
    scale = (cfg.d_nope + cfg.d_rope) ** -0.5
    w_uk = p["w_uk"]["w"].reshape(cfg.d_c, h, cfg.d_nope)

    def inner(xl, c_l, r_l, length):
        bl = xl.shape[0]
        positions = length + jnp.arange(s)
        q_nope, q_rope = _q_proj(p, cfg, xl, positions)    # [B_l,1,H,*]
        c_new = rms_norm(dense(p["w_dkv"], xl), p["kv_norm"])
        r_new = apply_rope(dense(p["w_kr"], xl), positions, cfg.rope_theta)
        lo = jax.lax.axis_index(tp) * s_shard
        pos_local = (length - lo).clip(0, s_shard - 1)
        in_range = (length >= lo) & (length < lo + s_shard)
        c_upd = jax.lax.dynamic_update_slice(
            c_l, c_new.astype(c_l.dtype), (0, pos_local, 0))
        r_upd = jax.lax.dynamic_update_slice(
            r_l, r_new.astype(r_l.dtype), (0, pos_local, 0))
        c_l = jnp.where(in_range, c_upd, c_l)
        r_l = jnp.where(in_range, r_upd, r_l)
        q_abs = jnp.einsum("bshd,chd->bshc", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))       # [B_l,1,H,d_c]
        logits = (jnp.einsum("bshc,btc->bhst", q_abs,
                             c_l.astype(jnp.float32))
                  + jnp.einsum("bshd,btd->bhst",
                               q_rope.astype(jnp.float32),
                               r_l.astype(jnp.float32))) * scale
        kpos = lo + jnp.arange(s_shard)
        mask = kpos[None, :] <= positions[:, None]
        logits = jnp.where(mask[None, None], logits, -1e30)
        m_l = logits.max(axis=-1)                          # [B_l,H,1]
        m = jax.lax.pmax(m_l, tp)
        pr = jnp.exp(logits - m[..., None])
        l_sum = jax.lax.psum(pr.sum(axis=-1), tp)          # [B_l,H,1]
        acc = jax.lax.psum(
            jnp.einsum("bhst,btc->bshc", pr, c_l.astype(jnp.float32)),
            tp)                                            # [B_l,1,H,d_c]
        lat = acc / jnp.maximum(l_sum, 1e-30).transpose(0, 2, 1)[..., None]
        return lat, c_l, r_l

    from jax.sharding import PartitionSpec as P
    lat, c2, r2 = jax.shard_map(
        inner, mesh=mesh,
        in_specs=(P(dpa, None, None), P(dpa, tp, None), P(dpa, tp, None),
                  P()),
        out_specs=(P(dpa, None, None, None), P(dpa, tp, None),
                   P(dpa, tp, None)),
        check_vma=False,
    )(x, c_cache, r_cache, length)
    w_uv = p["w_uv"]["w"].reshape(cfg.d_c, h, cfg.d_v)
    out = jnp.einsum("bshc,chd->bshd", lat,
                     w_uv.astype(jnp.float32))
    out = out.reshape(b, s, h * cfg.d_v).astype(x.dtype)
    return dense(p["w_o"], out), (c2, r2, length + s)


def mla_decode_apply(p: Params, cfg: MLAConfig, x: jax.Array,
                     cache) -> tuple[jax.Array, tuple]:
    """Absorbed-form decode step. x: [B, 1, d]; cache latent-only."""
    if cfg.decode_flash and cfg.mesh is not None:
        return mla_decode_flash(p, cfg, x, cache)
    b, s, _ = x.shape
    h = cfg.n_heads
    c_cache, r_cache, length = cache
    positions = length + jnp.arange(s)
    q_nope, q_rope = _q_proj(p, cfg, x, positions)             # [B,1,H,*]
    c_kv = rms_norm(dense(p["w_dkv"], x), p["kv_norm"])
    k_rope = apply_rope(dense(p["w_kr"], x), positions, cfg.rope_theta)
    c_cache = jax.lax.dynamic_update_slice(
        c_cache, c_kv.astype(c_cache.dtype), (0, length, 0))
    r_cache = jax.lax.dynamic_update_slice(
        r_cache, k_rope.astype(r_cache.dtype), (0, length, 0))
    t = c_cache.shape[1]
    # absorb W_uk into q: q_abs[b,s,h,c] = sum_d q_nope[...,d] W_uk[c, h*d]
    w_uk = p["w_uk"]["w"].reshape(cfg.d_c, h, cfg.d_nope)
    q_abs = jnp.einsum("bshd,chd->bshc", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))               # [B,1,H,d_c]
    scale = (cfg.d_nope + cfg.d_rope) ** -0.5
    logits = (jnp.einsum("bshc,btc->bhst", q_abs,
                         c_cache.astype(jnp.float32))
              + jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                           r_cache.astype(jnp.float32))) * scale
    kpos = jnp.arange(t)
    mask = kpos[None, :] <= positions[:, None]
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    # attend over the latent, then absorb W_uv into the output proj
    lat = jnp.einsum("bhst,btc->bshc", probs,
                     c_cache.astype(jnp.float32))              # [B,1,H,d_c]
    w_uv = p["w_uv"]["w"].reshape(cfg.d_c, h, cfg.d_v)
    out = jnp.einsum("bshc,chd->bshd", lat, w_uv.astype(jnp.float32))
    out = out.reshape(b, s, h * cfg.d_v).astype(x.dtype)
    return dense(p["w_o"], out), (c_cache, r_cache, length + s)
