"""DIN (Deep Interest Network) — target attention over user history.

The huge-sparse-embedding regime: item/category tables are the hot path.
JAX has no ``nn.EmbeddingBag``; multi-hot bag lookups are built from
``jnp.take`` + ``jax.ops.segment_sum`` (the assignment's explicit
requirement) in :func:`embedding_bag`.

Four serving/training shapes are supported by the same parameters:
  * train/serve  — [B] targets × [B, L] histories -> [B] logits,
  * retrieval    — 1 user × 1e6 candidates: the target-attention MLP runs
    over the candidate axis in MXU-friendly batched form (no host loop).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .layers import dense, dense_init

Params = Any


@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str
    n_items: int
    n_cats: int
    embed_dim: int = 18
    seq_len: int = 100
    attn_hidden: tuple = (80, 40)
    mlp_hidden: tuple = (200, 80)
    n_dense_feats: int = 8
    param_dtype: Any = jnp.float32


def din_init(key, cfg: DINConfig) -> Params:
    ks = jax.random.split(key, 10)
    d = cfg.embed_dim
    feat = 2 * d                 # item + category embedding per position
    p = {
        "item_table": (jax.random.normal(ks[0], (cfg.n_items, d),
                                         jnp.float32) * 0.01
                       ).astype(cfg.param_dtype),
        "cat_table": (jax.random.normal(ks[1], (cfg.n_cats, d),
                                        jnp.float32) * 0.01
                      ).astype(cfg.param_dtype),
    }
    a_in = 4 * feat              # [hist, target, hist-target, hist*target]
    dims_a = (a_in,) + cfg.attn_hidden + (1,)
    p["attn"] = [dense_init(ks[2 + i], dims_a[i], dims_a[i + 1],
                            cfg.param_dtype, bias=True)
                 for i in range(len(dims_a) - 1)]
    m_in = 2 * feat + cfg.n_dense_feats   # pooled + target + profile
    dims_m = (m_in,) + cfg.mlp_hidden + (1,)
    p["mlp"] = [dense_init(ks[6 + i], dims_m[i], dims_m[i + 1],
                           cfg.param_dtype, bias=True)
                for i in range(len(dims_m) - 1)]
    return p


def embedding_bag(table: jax.Array, indices: jax.Array,
                  segment_ids: jax.Array, n_bags: int,
                  mode: str = "sum") -> jax.Array:
    """EmbeddingBag built from take + segment_sum.

    indices: int32 [NNZ] rows of ``table``; segment_ids: int32 [NNZ]
    bag id per index (sorted not required). Returns [n_bags, d].
    """
    rows = jnp.take(table, indices, axis=0)
    s = jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(indices, jnp.float32),
                                  segment_ids, num_segments=n_bags)
        s = s / jnp.maximum(cnt[:, None], 1.0)
    return s


def _mlp(layers: list[Params], x: jax.Array,
         act=jax.nn.relu) -> jax.Array:
    for i, p in enumerate(layers):
        x = dense(p, x)
        if i < len(layers) - 1:
            x = act(x)
    return x


def _embed(p: Params, item_ids: jax.Array, cat_ids: jax.Array) -> jax.Array:
    return jnp.concatenate([jnp.take(p["item_table"], item_ids, axis=0),
                            jnp.take(p["cat_table"], cat_ids, axis=0)],
                           axis=-1)


def din_attention_pool(p: Params, hist: jax.Array, target: jax.Array,
                       mask: jax.Array) -> jax.Array:
    """hist: [..., L, F], target: [..., F] -> pooled [..., F].

    DIN activation-unit attention: per-position MLP on
    [hist, target, hist - target, hist * target] -> scalar weight; the
    weighted sum (no softmax, per the paper) pools the history.
    """
    t = jnp.broadcast_to(target[..., None, :], hist.shape)
    z = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)
    w = _mlp(p["attn"], z, act=jax.nn.sigmoid)[..., 0]      # [..., L]
    w = w * mask
    return (hist * w[..., None]).sum(axis=-2)


def din_forward(p: Params, cfg: DINConfig, batch: dict) -> jax.Array:
    """Pointwise CTR scoring.

    batch: target_item/target_cat [B], hist_items/hist_cats [B, L],
           hist_mask [B, L], dense_feats [B, n_dense]. Returns [B] logits.
    """
    target = _embed(p, batch["target_item"], batch["target_cat"])  # [B,F]
    hist = _embed(p, batch["hist_items"], batch["hist_cats"])      # [B,L,F]
    pooled = din_attention_pool(p, hist, target, batch["hist_mask"])
    z = jnp.concatenate([pooled, target, batch["dense_feats"]], axis=-1)
    return _mlp(p["mlp"], z)[..., 0]


def din_score_candidates(p: Params, cfg: DINConfig, user: dict,
                         cand_items: jax.Array, cand_cats: jax.Array
                         ) -> jax.Array:
    """Retrieval scoring: one user against N candidates -> [N] logits.

    user: hist_items/hist_cats [L], hist_mask [L], dense_feats [n_dense].
    The history embedding is computed once; the attention pool runs
    batched over the candidate axis.
    """
    hist = _embed(p, user["hist_items"], user["hist_cats"])   # [L, F]
    n = cand_items.shape[0]
    target = _embed(p, cand_items, cand_cats)                 # [N, F]
    hist_b = jnp.broadcast_to(hist[None], (n,) + hist.shape)  # [N, L, F]
    pooled = din_attention_pool(p, hist_b, target,
                                jnp.broadcast_to(user["hist_mask"][None],
                                                 (n, hist.shape[0])))
    dense_b = jnp.broadcast_to(user["dense_feats"][None],
                               (n, user["dense_feats"].shape[0]))
    z = jnp.concatenate([pooled, target, dense_b], axis=-1)
    return _mlp(p["mlp"], z)[..., 0]


def din_loss(p: Params, cfg: DINConfig, batch: dict) -> jax.Array:
    logits = din_forward(p, cfg, batch)
    labels = batch["labels"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits))))
