"""Single kernel-backend configuration shared by every kernel call site.

Before this module existed, ``bitmap_refine.refine_bitmap`` defaulted to
``interpret=True`` while ``ops.py`` owned its own ``DEFAULT_BACKEND`` —
a TPU run that called the kernel directly (or through ``engine_step``)
could silently fall into interpret mode. Now *one* process-wide setting
decides how every op lowers:

  * ``"jnp"``              — pure-jnp oracle path (``ref.py``); fastest on
                             CPU and what the dry-run lowers by default.
  * ``"pallas_interpret"`` — Pallas kernel bodies interpreted on CPU (the
                             kernel-validation mode used by the tests).
  * ``"pallas"``           — compiled TPU kernels (target hardware).

Resolution order: explicit ``backend=`` argument > ``set_backend()`` >
``REPRO_KERNEL_BACKEND`` environment variable > ``"jnp"``.

Kernel wrappers translate the backend to their ``interpret`` flag with
:func:`interpret_mode` — so ``interpret=True`` can only happen when the
configuration explicitly asks for it.
"""
from __future__ import annotations

import os

BACKENDS = ("jnp", "pallas_interpret", "pallas")

_backend = os.environ.get("REPRO_KERNEL_BACKEND", "jnp")
if _backend not in BACKENDS:
    raise ValueError(
        f"REPRO_KERNEL_BACKEND={_backend!r} not in {BACKENDS}")


def get_backend() -> str:
    """The process-wide kernel backend."""
    return _backend


def set_backend(name: str) -> None:
    """Set the process-wide kernel backend (e.g. once at TPU startup)."""
    global _backend
    if name not in BACKENDS:
        raise ValueError(f"unknown kernel backend {name!r}; "
                         f"choose one of {BACKENDS}")
    _backend = name


def resolve(backend: str | None) -> str:
    """An explicit per-call backend wins; None means the global config."""
    if backend is None:
        return get_backend()
    if backend not in BACKENDS:
        raise ValueError(f"unknown kernel backend {backend!r}; "
                         f"choose one of {BACKENDS}")
    return backend


def interpret_mode(backend: str | None) -> bool:
    """Interpret flag for a Pallas call under ``backend`` (None = global).

    Only ``"pallas_interpret"`` interprets; ``"pallas"`` compiles for the
    accelerator. (``"jnp"`` never reaches a pallas_call.)
    """
    return resolve(backend) == "pallas_interpret"
