"""Single kernel-backend configuration shared by every kernel call site.

Before this module existed, ``bitmap_refine.refine_bitmap`` defaulted to
``interpret=True`` while ``ops.py`` owned its own ``DEFAULT_BACKEND`` —
a TPU run that called the kernel directly (or through ``engine_step``)
could silently fall into interpret mode. Now *one* process-wide setting
decides how every op lowers:

  * ``"jnp"``              — pure-jnp oracle path (``ref.py``); fastest on
                             CPU and what the dry-run lowers by default.
  * ``"pallas_interpret"`` — Pallas kernel bodies interpreted on CPU (the
                             kernel-validation mode used by the tests).
  * ``"pallas"``           — compiled TPU kernels (target hardware).

Resolution order: explicit ``backend=`` argument > ``set_backend()`` >
``REPRO_KERNEL_BACKEND`` environment variable > ``"jnp"``.

Kernel wrappers translate the backend to their ``interpret`` flag with
:func:`interpret_mode` — so ``interpret=True`` can only happen when the
configuration explicitly asks for it.

Since the autotuner (DESIGN.md §9) this module is also the resolution
point for tuned *kernel* parameters: :func:`kernel_block_f` resolves the
``bitmap_refine`` row-block height as explicit scope override >
tuning-cache record (for the call's backend and graph size) > built-in
``DEFAULT_BLOCK_F``. :func:`backend_scope` / :func:`kernel_param_scope`
give tests and the tuner leak-free save/restore around the
process-global state.
"""
from __future__ import annotations

import contextlib
import os

BACKENDS = ("jnp", "pallas_interpret", "pallas")

DEFAULT_BLOCK_F = 8     # refine kernel sublanes per grid step
                        # (int32 min tile height; see bitmap_refine.py)

DEFAULT_CHUNK_WORDS = 8  # hierarchical layout: packed words per chunk
                         # (C) — 256 vertices of coverage per summary bit
DEFAULT_DMA_DEPTH = 2    # in-flight chunk copies in the HBM refine
                         # kernel's double-buffered pipeline

# Dense/hierarchical threshold: below this many data-graph vertices the
# whole-VMEM dense kernel is the fast path (the padded adjacency block
# fits comfortably — 8K vertices is 8 MB); at or above it the adjacency
# stays in HBM and the hierarchical kernel pages live chunks into VMEM
# scratch (DESIGN.md §2). A tuning record or kernel_param_scope override
# ("hbm_adjacency") wins over the threshold.
HBM_ADJACENCY_MIN_VERTICES = 16384

# scope-local kernel parameter overrides (kernel_param_scope) — the
# "explicit arg" level of the tuning resolution order
_kernel_overrides: dict[str, int] = {}

_backend = os.environ.get("REPRO_KERNEL_BACKEND", "jnp")
if _backend not in BACKENDS:
    raise ValueError(
        f"REPRO_KERNEL_BACKEND={_backend!r} not in {BACKENDS}")


def get_backend() -> str:
    """The process-wide kernel backend."""
    return _backend


def set_backend(name: str) -> None:
    """Set the process-wide kernel backend (e.g. once at TPU startup)."""
    global _backend
    if name not in BACKENDS:
        raise ValueError(f"unknown kernel backend {name!r}; "
                         f"choose one of {BACKENDS}")
    _backend = name


@contextlib.contextmanager
def backend_scope(name: str):
    """Temporarily switch the process-wide backend — save/restore around
    :func:`set_backend`, exception-safe, so tests and the tuner can
    sweep backends without leaking process-global state."""
    prev = get_backend()
    set_backend(name)
    try:
        yield name
    finally:
        set_backend(prev)


@contextlib.contextmanager
def kernel_param_scope(**params: int):
    """Temporarily pin tuned kernel parameters (e.g. ``block_f=16``) —
    the explicit-override level of the resolution order, used by the
    tuner to measure candidate points and by tests to pin geometry."""
    global _kernel_overrides
    prev = dict(_kernel_overrides)
    _kernel_overrides.update({k: int(v) for k, v in params.items()})
    try:
        yield dict(_kernel_overrides)
    finally:
        _kernel_overrides = prev


def kernel_override(name: str) -> int | None:
    """The active :func:`kernel_param_scope` override for ``name``."""
    return _kernel_overrides.get(name)


def _tuned_param(name: str, backend: str | None,
                 n_vertices: int | None) -> int | None:
    """Shared knob lookup: scope override > tuning-cache record for
    (backend, device kind, |V| bucket) > None (caller's built-in)."""
    v = _kernel_overrides.get(name)
    if v is not None:
        return int(v)
    if n_vertices is not None \
            and os.environ.get("REPRO_TUNING_DISABLE") != "1":
        from ..tuning.cache import device_kind, load_default_cache
        rec = load_default_cache().lookup(
            resolve(backend), device_kind(), n_vertices)
        if rec and name in rec.get("params", {}):
            return int(rec["params"][name])
    return None


def kernel_block_f(backend: str | None = None,
                   n_vertices: int | None = None) -> int:
    """Resolved ``bitmap_refine`` row-block height: scope override >
    tuning-cache record (needs ``n_vertices`` for the shape bucket) >
    ``DEFAULT_BLOCK_F``. Called at trace time by the kernel wrapper
    when no explicit ``block_f`` argument was passed."""
    v = _tuned_param("block_f", backend, n_vertices)
    return DEFAULT_BLOCK_F if v is None else v


def kernel_chunk_words(backend: str | None = None,
                       n_vertices: int | None = None) -> int:
    """Resolved hierarchical chunk width C (words per chunk), same
    resolution order as :func:`kernel_block_f`."""
    v = _tuned_param("chunk_words", backend, n_vertices)
    return DEFAULT_CHUNK_WORDS if v is None else v


def kernel_dma_depth(backend: str | None = None,
                     n_vertices: int | None = None) -> int:
    """Resolved DMA pipeline depth of the HBM-resident refine kernel
    (in-flight chunk copies), same resolution order as
    :func:`kernel_block_f`."""
    v = _tuned_param("dma_depth", backend, n_vertices)
    return DEFAULT_DMA_DEPTH if v is None else max(1, v)


def use_hbm_adjacency(backend: str | None = None,
                      n_vertices: int | None = None) -> bool:
    """Whether refinement should use the hierarchical / HBM-resident
    layout at this graph size: scope override ("hbm_adjacency", 0/1) >
    tuning-cache record > the ``HBM_ADJACENCY_MIN_VERTICES``
    threshold."""
    v = _tuned_param("hbm_adjacency", backend, n_vertices)
    if v is not None:
        return bool(v)
    return (n_vertices is not None
            and int(n_vertices) >= HBM_ADJACENCY_MIN_VERTICES)


def resolve(backend: str | None) -> str:
    """An explicit per-call backend wins; None means the global config."""
    if backend is None:
        return get_backend()
    if backend not in BACKENDS:
        raise ValueError(f"unknown kernel backend {backend!r}; "
                         f"choose one of {BACKENDS}")
    return backend


def interpret_mode(backend: str | None) -> bool:
    """Interpret flag for a Pallas call under ``backend`` (None = global).

    Only ``"pallas_interpret"`` interprets; ``"pallas"`` compiles for the
    accelerator. (``"jnp"`` never reaches a pallas_call.)
    """
    return resolve(backend) == "pallas_interpret"
