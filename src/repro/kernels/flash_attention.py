"""Pallas TPU kernel: FlashAttention-style fused attention forward.

Beyond-paper kernel for the LM architectures' hot spot. Classic online-
softmax tiling adapted to TPU: the query tile stays resident in VMEM
while key/value tiles stream in along the innermost grid dimension; the
running (max, sum, accumulator) state lives in VMEM scratch, so the
[S, S] score matrix never materializes in HBM.

Supports causal masking and GQA is handled by the wrapper (K/V heads are
repeated logically via indexing, never materialized).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, bq: int, bk: int,
                  kv_steps: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = True
    if causal:
        # skip key blocks strictly above the causal diagonal
        run = kj * bk <= qi * bq + (bq - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32)            # [bq, d]
        k = k_ref[0].astype(jnp.float32)            # [bk, d]
        v = v_ref[0].astype(jnp.float32)            # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bk]
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]                          # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                       # [bq, bk]
        corr = jnp.exp(m_prev - m_new)               # [bq, 1]
        l_scr[...] = corr * l_scr[...] + p.sum(axis=1, keepdims=True)
        acc_scr[...] = corr * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kj == kv_steps - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True) -> jax.Array:
    """Fused attention forward, [B, H, S, D] layout.

    K/V may have fewer heads than Q (GQA): H_kv must divide H, and the
    wrapper maps query head h to kv head h // (H // H_kv) via an index
    transform (no repetition in HBM).
    """
    b, h, s, d = q.shape
    _, h_kv, s_kv, _ = k.shape
    assert h % h_kv == 0
    group = h // h_kv
    scale = d ** -0.5
    bq = min(block_q, s)
    bk = min(block_k, s_kv)
    assert s % bq == 0 and s_kv % bk == 0, (s, bq, s_kv, bk)
    d_pad = max(128, ((d + 127) // 128) * 128)
    if d_pad != d:
        pad = [(0, 0)] * 3 + [(0, d_pad - d)]
        q, k, v = (jnp.pad(t, pad) for t in (q, k, v))

    qr = q.reshape(b * h, s, d_pad)
    kr = k.reshape(b * h_kv, s_kv, d_pad)
    vr = v.reshape(b * h_kv, s_kv, d_pad)
    kv_steps = s_kv // bk

    grid = (b * h, s // bq, kv_steps)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, kv_steps=kv_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d_pad), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d_pad),
                         lambda bh, qi, kj, g=group: (bh // g, kj, 0)),
            pl.BlockSpec((1, bk, d_pad),
                         lambda bh, qi, kj, g=group: (bh // g, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d_pad),
                               lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d_pad), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d_pad), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s, d_pad)[..., :d]
