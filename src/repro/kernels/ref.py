"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

FULL_U32 = jnp.uint32(0xFFFFFFFF)


def refine_bitmap_ref(adj_bitmap: jax.Array, cand_row: jax.Array,
                      frontier: jax.Array, active: jax.Array) -> jax.Array:
    """Eq. 2 refinement oracle: cand ∧ ⋀_{p active} adj[frontier[:, p]].

    Same signature/semantics as kernels.bitmap_refine.refine_bitmap but
    returns uint32 [F, W] (unpadded).
    """
    f, np_ = frontier.shape
    adj = adj_bitmap.astype(jnp.uint32)
    acc = jnp.broadcast_to(cand_row.astype(jnp.uint32)[None, :],
                           (f, adj.shape[1]))

    def body(p, acc):
        act = (active[p] != 0)
        rows = adj[frontier[:, p].clip(0)]
        rows = jnp.where((frontier[:, p] >= 0)[:, None], rows, FULL_U32)
        return jnp.where(act, acc & rows, acc)

    return jax.lax.fori_loop(0, np_, body, acc)


def refine_bitmap_rows_ref(adj_bitmap: jax.Array, cand_rows: jax.Array,
                           frontier: jax.Array, active: jax.Array
                           ) -> jax.Array:
    """Per-row Eq. 2 oracle (multi-query layout): candidates and active
    positions vary per row. Same semantics as
    ``kernels.bitmap_refine.refine_bitmap_rows``; returns uint32 [F, W].
    """
    f, np_ = frontier.shape
    adj = adj_bitmap.astype(jnp.uint32)
    acc = cand_rows.astype(jnp.uint32)

    def body(p, acc):
        act = (active[:, p] != 0) & (frontier[:, p] >= 0)
        rows = adj[frontier[:, p].clip(0)]
        return jnp.where(act[:, None], acc & rows, acc)

    return jax.lax.fori_loop(0, np_, body, acc)


def refine_bitmap_rows_hier_ref(summary: jax.Array, chunk_ptr: jax.Array,
                                chunk_id: jax.Array,
                                chunk_data: jax.Array, kmax: int,
                                cand_rows: jax.Array, frontier: jax.Array,
                                active: jax.Array) -> jax.Array:
    """Eq. 2 oracle over the two-level (hierarchical) adjacency layout
    (core.graph.HierBitmap) — bit-identical to
    :func:`refine_bitmap_rows_ref` on the dense bitmap of the same
    graph.

    Exercises both levels the way the HBM kernel does: the summary
    intersection ``sacc = cand_summary ∧ ⋀_p summary[frontier_p]``
    pre-zeroes dead chunks (sound: a dead chunk is zero in the dense
    result — either the candidate chunk was empty or some active row
    misses it entirely), then each active position's row is
    reconstructed from its stored chunks and AND-folded. ``kmax`` is
    the layout's static max stored-chunks-per-row.

    Returns uint32 [F, W] where W = cand_rows.shape[1].
    """
    f, np_ = frontier.shape
    w = cand_rows.shape[1]
    c = chunk_data.shape[1]
    sw = summary.shape[1]
    ncp = sw * 32                       # padded chunk count (>= ceil(W/C))
    acc = cand_rows.astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)

    cpad = jnp.zeros((f, ncp * c), jnp.uint32).at[:, :w].set(acc)
    nonzero = (cpad.reshape(f, ncp, c) != 0).any(axis=2)
    cand_sum = (nonzero.reshape(f, sw, 32).astype(jnp.uint32)
                << shifts).sum(axis=2, dtype=jnp.uint32).astype(jnp.uint32)

    def sbody(p, s):
        act = (active[:, p] != 0) & (frontier[:, p] >= 0)
        rows = summary.astype(jnp.uint32)[frontier[:, p].clip(0)]
        return jnp.where(act[:, None], s & rows, s)

    sacc = jax.lax.fori_loop(0, np_, sbody, cand_sum)
    livebit = ((sacc[:, :, None] >> shifts) & jnp.uint32(1))
    mask = jnp.repeat(livebit.reshape(f, ncp), c,
                      axis=1)[:, :w] * FULL_U32
    acc = acc & mask

    def body(p, acc):
        vtx = frontier[:, p]
        act = (active[:, p] != 0) & (vtx >= 0)
        k0 = chunk_ptr[vtx.clip(0)]
        nk = chunk_ptr[vtx.clip(0) + 1] - k0
        ks = k0[:, None] + jnp.arange(kmax)[None, :]
        km = jnp.arange(kmax)[None, :] < nk[:, None]
        ids = jnp.where(km, chunk_id[ks], ncp)          # pad -> dropped
        data = jnp.where(km[:, :, None],
                         chunk_data[ks].astype(jnp.uint32), jnp.uint32(0))
        rows = jnp.zeros((f, ncp, c), jnp.uint32).at[
            jnp.arange(f)[:, None], ids].set(data, mode="drop")
        rows = rows.reshape(f, ncp * c)[:, :w]
        return jnp.where(act[:, None], acc & rows, acc)

    return jax.lax.fori_loop(0, np_, body, acc)


def bitmap_spmm_ref(adj_words: jax.Array, x: jax.Array) -> jax.Array:
    """Unpack the bitmap densely and matmul in f32."""
    n, w = adj_words.shape
    words = adj_words.astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    dense = bits.reshape(n, w * 32).astype(jnp.float32)
    return (dense @ x.astype(jnp.float32)).astype(x.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        scale: float | None = None) -> jax.Array:
    """Plain softmax attention oracle, [B, H, S, D] layout, f32 math."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    logits = jnp.einsum("bhsd,bhtd->bhst", qf, kf) * scale
    if causal:
        s, t = logits.shape[-2:]
        mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs, vf).astype(q.dtype)
