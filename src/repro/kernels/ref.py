"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

FULL_U32 = jnp.uint32(0xFFFFFFFF)


def refine_bitmap_ref(adj_bitmap: jax.Array, cand_row: jax.Array,
                      frontier: jax.Array, active: jax.Array) -> jax.Array:
    """Eq. 2 refinement oracle: cand ∧ ⋀_{p active} adj[frontier[:, p]].

    Same signature/semantics as kernels.bitmap_refine.refine_bitmap but
    returns uint32 [F, W] (unpadded).
    """
    f, np_ = frontier.shape
    adj = adj_bitmap.astype(jnp.uint32)
    acc = jnp.broadcast_to(cand_row.astype(jnp.uint32)[None, :],
                           (f, adj.shape[1]))

    def body(p, acc):
        act = (active[p] != 0)
        rows = adj[frontier[:, p].clip(0)]
        rows = jnp.where((frontier[:, p] >= 0)[:, None], rows, FULL_U32)
        return jnp.where(act, acc & rows, acc)

    return jax.lax.fori_loop(0, np_, body, acc)


def refine_bitmap_rows_ref(adj_bitmap: jax.Array, cand_rows: jax.Array,
                           frontier: jax.Array, active: jax.Array
                           ) -> jax.Array:
    """Per-row Eq. 2 oracle (multi-query layout): candidates and active
    positions vary per row. Same semantics as
    ``kernels.bitmap_refine.refine_bitmap_rows``; returns uint32 [F, W].
    """
    f, np_ = frontier.shape
    adj = adj_bitmap.astype(jnp.uint32)
    acc = cand_rows.astype(jnp.uint32)

    def body(p, acc):
        act = (active[:, p] != 0) & (frontier[:, p] >= 0)
        rows = adj[frontier[:, p].clip(0)]
        return jnp.where(act[:, None], acc & rows, acc)

    return jax.lax.fori_loop(0, np_, body, acc)


def bitmap_spmm_ref(adj_words: jax.Array, x: jax.Array) -> jax.Array:
    """Unpack the bitmap densely and matmul in f32."""
    n, w = adj_words.shape
    words = adj_words.astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    dense = bits.reshape(n, w * 32).astype(jnp.float32)
    return (dense @ x.astype(jnp.float32)).astype(x.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        scale: float | None = None) -> jax.Array:
    """Plain softmax attention oracle, [B, H, S, D] layout, f32 math."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    logits = jnp.einsum("bhsd,bhtd->bhst", qf, kf) * scale
    if causal:
        s, t = logits.shape[-2:]
        mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs, vf).astype(q.dtype)
