"""Jit'd public wrappers around the Pallas kernels.

Every op takes ``backend`` in {"pallas", "pallas_interpret", "jnp"}:
  * ``pallas``           — compiled TPU kernel (target hardware),
  * ``pallas_interpret`` — kernel body interpreted on CPU (what tests and
                           this container use to validate the kernels),
  * ``jnp``              — the pure-jnp oracle from ``ref.py`` (fastest on
                           CPU; also the lowering used by the dry-run).

``backend=None`` resolves from the single process-wide configuration in
``kernels/config.py`` (``set_backend`` / ``REPRO_KERNEL_BACKEND``) — the
same config the engine's device programs consult, so one switch moves
the whole hot path between lowerings and a TPU run cannot silently fall
into interpret mode. ``DEFAULT_BACKEND`` is kept as a module attribute
for backward compatibility and reflects the config default.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import ref
from .bitmap_refine import refine_bitmap as _refine_pallas
from .bitmap_refine import refine_bitmap_rows as _refine_rows_pallas
from .bitmap_refine import \
    refine_bitmap_rows_hier as _refine_rows_hier_pallas
from .bitmap_spmm import bitmap_spmm as _spmm_pallas
from .config import (backend_scope, get_backend, interpret_mode, resolve,
                     set_backend)
from .flash_attention import flash_attention as _flash_pallas

__all__ = ["refine_bitmap_op", "refine_bitmap_rows_op",
           "refine_bitmap_rows_hier_op", "bitmap_spmm_op",
           "flash_attention_op", "get_backend", "set_backend",
           "backend_scope", "DEFAULT_BACKEND"]


def __getattr__(name):
    # DEFAULT_BACKEND tracks the live config (a frozen import-time
    # snapshot would override set_backend() when passed explicitly).
    if name == "DEFAULT_BACKEND":
        return get_backend()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def refine_bitmap_rows_op(adj_bitmap, cand_rows, frontier, active,
                          backend: str | None = None,
                          block_f: int | None = None):
    """Eq. 2 packed-bitmap refinement with per-row candidate/active sets
    (the multi-query wave layout). Returns uint32 [F, W]. ``block_f``
    None resolves through the tuning layer (kernels.config)."""
    w = adj_bitmap.shape[1]
    if resolve(backend) == "jnp":
        return ref.refine_bitmap_rows_ref(adj_bitmap, cand_rows, frontier,
                                          active)
    out = _refine_rows_pallas(adj_bitmap, cand_rows, frontier, active,
                              interpret=interpret_mode(backend),
                              block_f=block_f)
    return out[:, :w].astype(jnp.uint32)


def refine_bitmap_rows_hier_op(summary, chunk_ptr, chunk_id, chunk_data,
                               kmax, cand_rows, frontier, active,
                               backend: str | None = None,
                               dma_depth: int | None = None):
    """Eq. 2 refinement over the two-level (hierarchical) adjacency
    layout — the HBM-resident variant for graphs past the dense
    kernel's VMEM ceiling (kernels.config.use_hbm_adjacency picks the
    variant; core.graph.HierBitmap builds the operands). Bit-identical
    to :func:`refine_bitmap_rows_op` on the same graph. Returns uint32
    [F, W]."""
    w = cand_rows.shape[1]
    if resolve(backend) == "jnp":
        return ref.refine_bitmap_rows_hier_ref(
            summary, chunk_ptr, chunk_id, chunk_data, int(kmax),
            cand_rows, frontier, active)
    out = _refine_rows_hier_pallas(summary, chunk_ptr, chunk_id,
                                   chunk_data, int(kmax), cand_rows,
                                   frontier, active,
                                   interpret=interpret_mode(backend),
                                   dma_depth=dma_depth)
    return out[:, :w].astype(jnp.uint32)


def refine_bitmap_op(adj_bitmap, cand_row, frontier, active,
                     backend: str | None = None,
                     block_f: int | None = None):
    """Eq. 2 packed-bitmap refinement, one shared candidate row (the
    single-query layout). Returns uint32 [F, W]."""
    if resolve(backend) == "jnp":
        return ref.refine_bitmap_ref(adj_bitmap, cand_row, frontier, active)
    w = adj_bitmap.shape[1]
    out = _refine_pallas(adj_bitmap, cand_row, frontier, active,
                         interpret=interpret_mode(backend),
                         block_f=block_f)
    return out[:, :w].astype(jnp.uint32)


def bitmap_spmm_op(adj_words, x, backend: str | None = None,
                   block_i: int = 256, block_j: int = 256):
    """Packed-bitmap SpMM ``A @ x``. Returns [N, D] in x.dtype."""
    if resolve(backend) == "jnp":
        return ref.bitmap_spmm_ref(adj_words, x)
    return _spmm_pallas(adj_words, x, block_i=block_i, block_j=block_j,
                        interpret=interpret_mode(backend))


def flash_attention_op(q, k, v, causal: bool = True,
                       backend: str | None = None,
                       block_q: int = 128, block_k: int = 128):
    """Fused attention forward [B, H, S, D] (GQA-aware)."""
    if resolve(backend) == "jnp":
        # oracle handles equal-head layout; expand kv heads for GQA
        h, h_kv = q.shape[1], k.shape[1]
        if h != h_kv:
            rep = h // h_kv
            k = jnp.repeat(k, rep, axis=1)
            v = jnp.repeat(v, rep, axis=1)
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return _flash_pallas(q, k, v, causal=causal, block_q=block_q,
                         block_k=block_k,
                         interpret=interpret_mode(backend))
