"""Jit'd public wrappers around the Pallas kernels.

Every op takes ``backend`` in {"pallas", "pallas_interpret", "jnp"}:
  * ``pallas``           — compiled TPU kernel (target hardware),
  * ``pallas_interpret`` — kernel body interpreted on CPU (what tests and
                           this container use to validate the kernels),
  * ``jnp``              — the pure-jnp oracle from ``ref.py`` (fastest on
                           CPU; also the lowering used by the dry-run).
"""
from __future__ import annotations

import jax.numpy as jnp

from . import ref
from .bitmap_refine import refine_bitmap as _refine_pallas
from .bitmap_spmm import bitmap_spmm as _spmm_pallas
from .flash_attention import flash_attention as _flash_pallas

DEFAULT_BACKEND = "jnp"


def refine_bitmap_op(adj_bitmap, cand_row, frontier, active,
                     backend: str = DEFAULT_BACKEND):
    """Eq. 2 packed-bitmap refinement. Returns uint32 [F, W]."""
    w = adj_bitmap.shape[1]
    if backend == "jnp":
        return ref.refine_bitmap_ref(adj_bitmap, cand_row, frontier, active)
    out = _refine_pallas(adj_bitmap, cand_row, frontier, active,
                         interpret=(backend == "pallas_interpret"))
    return out[:, :w].astype(jnp.uint32)


def bitmap_spmm_op(adj_words, x, backend: str = DEFAULT_BACKEND,
                   block_i: int = 256, block_j: int = 256):
    """Packed-bitmap SpMM ``A @ x``. Returns [N, D] in x.dtype."""
    if backend == "jnp":
        return ref.bitmap_spmm_ref(adj_words, x)
    return _spmm_pallas(adj_words, x, block_i=block_i, block_j=block_j,
                        interpret=(backend == "pallas_interpret"))


def flash_attention_op(q, k, v, causal: bool = True,
                       backend: str = DEFAULT_BACKEND,
                       block_q: int = 128, block_k: int = 128):
    """Fused attention forward [B, H, S, D] (GQA-aware)."""
    if backend == "jnp":
        # oracle handles equal-head layout; expand kv heads for GQA
        h, h_kv = q.shape[1], k.shape[1]
        if h != h_kv:
            rep = h // h_kv
            k = jnp.repeat(k, rep, axis=1)
            v = jnp.repeat(v, rep, axis=1)
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return _flash_pallas(q, k, v, causal=causal, block_q=block_q,
                         block_k=block_k,
                         interpret=(backend == "pallas_interpret"))
