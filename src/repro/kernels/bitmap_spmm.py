"""Pallas TPU kernel: dense-blocked SpMM over a packed adjacency bitmap.

Computes ``out = A @ X`` where ``A`` is a {0,1} adjacency matrix stored as
packed uint32 words (32x smaller HBM footprint than f32 and 8x smaller
than int8). Each grid step unpacks one ``(Bi, Bj)`` bitmap tile to an MXU
mask and contracts it with an ``(Bj, D)`` feature tile, accumulating into
the ``(Bi, D)`` output tile resident in VMEM.

This is the shared substrate between the matcher (whose adjacency already
lives in packed-bitmap form) and full-batch GNN layers on small/medium
graphs (GCN sym-norm is applied as D^-1/2 scaling outside). For graphs
whose bitmap exceeds HBM (ogb_products) the framework falls back to the
segment-sum path in ``repro.models.gnn``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmm_kernel(words_ref, x_ref, out_ref, *, bj: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    words = words_ref[...]                      # [Bi, Bj // 32] int32
    bi = words.shape[0]
    shifts = jnp.arange(32, dtype=jnp.int32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & 1
    mask = bits.reshape(bi, bj).astype(x_ref.dtype)      # [Bi, Bj]
    out_ref[...] += jnp.dot(mask, x_ref[...],
                            preferred_element_type=out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_i", "block_j", "interpret"))
def bitmap_spmm(adj_words: jax.Array, x: jax.Array,
                block_i: int = 256, block_j: int = 256,
                interpret: bool = True) -> jax.Array:
    """``A_packed @ x`` with VMEM tiling.

    Args:
      adj_words: int32/uint32 [N, W] packed rows of an [N, M] 0/1 matrix,
                 M = W * 32 (padding bits must be zero).
      x:         [M, D] dense features (f32/bf16).
      block_i / block_j: output-row / contraction tile sizes (block_j
                 must be a multiple of 32).
    Returns [N, D] in x.dtype (f32 accumulation).
    """
    n, w = adj_words.shape
    m, d = x.shape
    assert m == w * 32, (m, w)
    assert block_j % 32 == 0
    n_pad = ((n + block_i - 1) // block_i) * block_i
    m_pad = ((m + block_j - 1) // block_j) * block_j
    d_pad = max(128, ((d + 127) // 128) * 128)
    words = jnp.zeros((n_pad, m_pad // 32), jnp.int32).at[:n, :w].set(
        adj_words.astype(jnp.int32))
    xp = jnp.zeros((m_pad, d_pad), jnp.float32).at[:m, :d].set(
        x.astype(jnp.float32))

    grid = (n_pad // block_i, m_pad // block_j)
    out = pl.pallas_call(
        functools.partial(_spmm_kernel, bj=block_j),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i, block_j // 32), lambda i, j: (i, j)),
            pl.BlockSpec((block_j, d_pad), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_i, d_pad), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d_pad), jnp.float32),
        interpret=interpret,
    )(words, xp)
    return out[:n, :d].astype(x.dtype)
