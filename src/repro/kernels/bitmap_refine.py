"""Pallas TPU kernel: Eq. 2 candidate refinement over packed bitmaps.

The matcher's hot loop. For every partial embedding (frontier row) the
refined candidate set of the next query position is

    refined[i] = cand[i] ∧ ⋀_{p active for row i} adj[frontier[i, p]]

an AND-reduction over dynamically gathered adjacency bitmap rows. Since
the multi-query engine refactor the candidate row and the active-position
set are *per row* (each wave row may belong to a different query at a
different depth), so the kernel takes ``cand [F, W]`` and
``active [F, NP]`` — the single-query entry point broadcasts.

Block geometry (this file's §Perf iteration 3): the grid is one step per
``(BLOCK_F, W_pad)`` row block and the position loop is folded *inside*
the kernel body — the old kernel used single-sublane ``(1, W_pad)``
blocks with a ``(F, NP)`` grid, wasting 7/8 sublanes and paying one grid
step per (row, position) pair. Per grid step the body now runs
``fori_loop`` over positions and gathers one adjacency row per sublane
with a dynamic ``pl.ds`` load. The frontier and active matrices are
scalar-prefetched (SMEM) because their values index the adjacency
operand; the adjacency bitmap itself is a single whole-array VMEM block
(packed bitmaps are tiny: V=8192, W_pad=256 is 8 MB — graphs beyond
VMEM capacity need an HBM + manual-DMA variant, see DESIGN.md §2).
``W_pad`` is padded to a multiple of 128 lanes, ``F`` to a multiple of
``BLOCK_F`` sublanes. All words are int32 (bitwise ops are
sign-agnostic; uint32<->int32 is a bitcast at the wrapper).

Backend selection lives in ``kernels/config.py`` — ``interpret=None``
resolves from the process-wide config, so TPU runs cannot silently fall
into interpret mode (the old default was ``interpret=True``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .config import interpret_mode, kernel_block_f

BLOCK_F = 8     # default sublanes per grid step (int32 min tile height)
                # — the tuned value resolves through kernels.config


def _make_refine_kernel(block_f: int):
    """Kernel body closure over the (tuned) row-block height — the
    sublane loop is a static unroll, so the height is a trace-time
    constant, not a ref shape."""

    def _refine_kernel(frontier_ref, active_ref, adj_ref, cand_ref,
                       out_ref):
        b = pl.program_id(0)
        np_ = frontier_ref.shape[1]

        def body(p, acc):
            rows = []
            for i in range(block_f):        # static unroll over sublanes
                r = b * block_f + i
                vtx = frontier_ref[r, p]
                act = (active_ref[r, p] != 0) & (vtx >= 0)
                idx = jnp.where(act, vtx, 0).clip(0, adj_ref.shape[0] - 1)
                row = adj_ref[pl.ds(idx, 1), :]         # (1, W_pad)
                rows.append(jnp.where(act, row, jnp.int32(-1)))
            return acc & jnp.concatenate(rows, axis=0)

        out_ref[...] = lax.fori_loop(0, np_, body, cand_ref[...])

    return _refine_kernel


@functools.partial(jax.jit, static_argnames=("interpret", "block_f"))
def _refine_rows_call(adj, cand, frontier, active, interpret: bool,
                      block_f: int):
    v_pad, w_pad = adj.shape
    f_pad = frontier.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(f_pad // block_f,),
        in_specs=[
            pl.BlockSpec((v_pad, w_pad), lambda i, *_: (0, 0)),
            pl.BlockSpec((block_f, w_pad), lambda i, *_: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_f, w_pad), lambda i, *_: (i, 0)),
    )
    return pl.pallas_call(
        _make_refine_kernel(block_f),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((f_pad, w_pad), jnp.int32),
        interpret=interpret,
    )(frontier, active, adj, cand)


def refine_bitmap_rows(adj_bitmap: jax.Array, cand_rows: jax.Array,
                       frontier: jax.Array, active: jax.Array,
                       interpret: bool | None = None,
                       block_f: int | None = None) -> jax.Array:
    """Pallas-backed Eq. 2 refinement with per-row candidates.

    Args:
      adj_bitmap: int32/uint32 [V, W] packed adjacency rows.
      cand_rows:  int32/uint32 [F, W] packed candidates, one per row.
      frontier:   int32 [F, NP] mapped vertex per position (-1 unmapped).
      active:     bool/int32 [F, NP] mapped-neighbor positions, per row.
      interpret:  None resolves from ``kernels.config`` (the process-wide
                  backend); pass a bool to force.
      block_f:    rows per grid step. None resolves through the tuning
                  layer (scope override > tuning cache > default 8,
                  DESIGN.md §9). The compiled backend needs a multiple
                  of 8 (int32 sublane tile); interpret mode takes any
                  height >= 1.

    Returns int32 [F, W_pad >= W] refined packed bitmaps (caller slices
    the first W words).
    """
    if interpret is None:
        interpret = interpret_mode(None)
    v, w = adj_bitmap.shape
    if block_f is None:
        block_f = kernel_block_f(n_vertices=v)
    block_f = max(1, int(block_f))
    f, np_ = frontier.shape
    w_pad = max(128, ((w + 127) // 128) * 128)
    v_pad = ((v + 7) // 8) * 8
    f_pad = ((max(f, 1) + block_f - 1) // block_f) * block_f
    adj = jnp.zeros((v_pad, w_pad), jnp.int32).at[:v, :w].set(
        adj_bitmap.astype(jnp.int32))
    cand = jnp.zeros((f_pad, w_pad), jnp.int32).at[:f, :w].set(
        cand_rows.astype(jnp.int32))
    fr = jnp.full((f_pad, np_), -1, jnp.int32).at[:f].set(
        frontier.astype(jnp.int32))
    act = jnp.zeros((f_pad, np_), jnp.int32).at[:f].set(
        active.astype(jnp.int32))
    return _refine_rows_call(adj, cand, fr, act, interpret,
                             block_f)[:f]


def refine_bitmap(adj_bitmap: jax.Array, cand_row: jax.Array,
                  frontier: jax.Array, active: jax.Array,
                  interpret: bool | None = None,
                  block_f: int | None = None) -> jax.Array:
    """Single-query entry point: one shared candidate row and one shared
    active-position vector, broadcast over all F rows (the historical
    signature, kept for ``ops.refine_bitmap_op`` and the dry-run)."""
    f = frontier.shape[0]
    cand_rows = jnp.broadcast_to(
        cand_row.astype(jnp.int32)[None, :], (f, cand_row.shape[0]))
    act = jnp.broadcast_to(
        active.astype(jnp.int32)[None, :], (f, active.shape[0]))
    return refine_bitmap_rows(adj_bitmap, cand_rows, frontier, act,
                              interpret=interpret, block_f=block_f)
