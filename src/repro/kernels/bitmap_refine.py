"""Pallas TPU kernel: Eq. 2 candidate refinement over packed bitmaps.

The matcher's hot loop. For every partial embedding (frontier row) the
refined candidate set of the next query position is

    refined[i] = cand[i] ∧ ⋀_{p active for row i} adj[frontier[i, p]]

an AND-reduction over dynamically gathered adjacency bitmap rows. Since
the multi-query engine refactor the candidate row and the active-position
set are *per row* (each wave row may belong to a different query at a
different depth), so the kernel takes ``cand [F, W]`` and
``active [F, NP]`` — the single-query entry point broadcasts.

Block geometry (this file's §Perf iteration 3): the grid is one step per
``(BLOCK_F, W_pad)`` row block and the position loop is folded *inside*
the kernel body — the old kernel used single-sublane ``(1, W_pad)``
blocks with a ``(F, NP)`` grid, wasting 7/8 sublanes and paying one grid
step per (row, position) pair. Per grid step the body now runs
``fori_loop`` over positions and gathers one adjacency row per sublane
with a dynamic ``pl.ds`` load. The frontier and active matrices are
scalar-prefetched (SMEM) because their values index the adjacency
operand; the adjacency bitmap itself is a single whole-array VMEM block
(packed bitmaps are tiny: V=8192, W_pad=256 is 8 MB — graphs beyond
VMEM capacity need an HBM + manual-DMA variant, see DESIGN.md §2).
``W_pad`` is padded to a multiple of 128 lanes, ``F`` to a multiple of
``BLOCK_F`` sublanes. All words are int32 (bitwise ops are
sign-agnostic; uint32<->int32 is a bitcast at the wrapper).

Backend selection lives in ``kernels/config.py`` — ``interpret=None``
resolves from the process-wide config, so TPU runs cannot silently fall
into interpret mode (the old default was ``interpret=True``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .config import interpret_mode

BLOCK_F = 8     # sublanes per grid step (f32/int32 min tile height)


def _refine_kernel(frontier_ref, active_ref, adj_ref, cand_ref, out_ref):
    """One grid step refines BLOCK_F rows, looping positions in-body."""
    b = pl.program_id(0)
    np_ = frontier_ref.shape[1]

    def body(p, acc):
        rows = []
        for i in range(BLOCK_F):            # static unroll over sublanes
            r = b * BLOCK_F + i
            vtx = frontier_ref[r, p]
            act = (active_ref[r, p] != 0) & (vtx >= 0)
            idx = jnp.where(act, vtx, 0).clip(0, adj_ref.shape[0] - 1)
            row = adj_ref[pl.ds(idx, 1), :]             # (1, W_pad)
            rows.append(jnp.where(act, row, jnp.int32(-1)))
        return acc & jnp.concatenate(rows, axis=0)

    out_ref[...] = lax.fori_loop(0, np_, body, cand_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def _refine_rows_call(adj, cand, frontier, active, interpret: bool):
    v_pad, w_pad = adj.shape
    f_pad = frontier.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(f_pad // BLOCK_F,),
        in_specs=[
            pl.BlockSpec((v_pad, w_pad), lambda i, *_: (0, 0)),
            pl.BlockSpec((BLOCK_F, w_pad), lambda i, *_: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_F, w_pad), lambda i, *_: (i, 0)),
    )
    return pl.pallas_call(
        _refine_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((f_pad, w_pad), jnp.int32),
        interpret=interpret,
    )(frontier, active, adj, cand)


def refine_bitmap_rows(adj_bitmap: jax.Array, cand_rows: jax.Array,
                       frontier: jax.Array, active: jax.Array,
                       interpret: bool | None = None) -> jax.Array:
    """Pallas-backed Eq. 2 refinement with per-row candidates.

    Args:
      adj_bitmap: int32/uint32 [V, W] packed adjacency rows.
      cand_rows:  int32/uint32 [F, W] packed candidates, one per row.
      frontier:   int32 [F, NP] mapped vertex per position (-1 unmapped).
      active:     bool/int32 [F, NP] mapped-neighbor positions, per row.
      interpret:  None resolves from ``kernels.config`` (the process-wide
                  backend); pass a bool to force.

    Returns int32 [F, W_pad >= W] refined packed bitmaps (caller slices
    the first W words).
    """
    if interpret is None:
        interpret = interpret_mode(None)
    v, w = adj_bitmap.shape
    f, np_ = frontier.shape
    w_pad = max(128, ((w + 127) // 128) * 128)
    v_pad = ((v + BLOCK_F - 1) // BLOCK_F) * BLOCK_F
    f_pad = ((max(f, 1) + BLOCK_F - 1) // BLOCK_F) * BLOCK_F
    adj = jnp.zeros((v_pad, w_pad), jnp.int32).at[:v, :w].set(
        adj_bitmap.astype(jnp.int32))
    cand = jnp.zeros((f_pad, w_pad), jnp.int32).at[:f, :w].set(
        cand_rows.astype(jnp.int32))
    fr = jnp.full((f_pad, np_), -1, jnp.int32).at[:f].set(
        frontier.astype(jnp.int32))
    act = jnp.zeros((f_pad, np_), jnp.int32).at[:f].set(
        active.astype(jnp.int32))
    return _refine_rows_call(adj, cand, fr, act, interpret)[:f]


def refine_bitmap(adj_bitmap: jax.Array, cand_row: jax.Array,
                  frontier: jax.Array, active: jax.Array,
                  interpret: bool | None = None) -> jax.Array:
    """Single-query entry point: one shared candidate row and one shared
    active-position vector, broadcast over all F rows (the historical
    signature, kept for ``ops.refine_bitmap_op`` and the dry-run)."""
    f = frontier.shape[0]
    cand_rows = jnp.broadcast_to(
        cand_row.astype(jnp.int32)[None, :], (f, cand_row.shape[0]))
    act = jnp.broadcast_to(
        active.astype(jnp.int32)[None, :], (f, active.shape[0]))
    return refine_bitmap_rows(adj_bitmap, cand_rows, frontier, act,
                              interpret=interpret)
