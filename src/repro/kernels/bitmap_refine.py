"""Pallas TPU kernel: Eq. 2 candidate refinement over packed bitmaps.

The matcher's hot loop. For every partial embedding (frontier row) the
refined candidate set of the next query position is

    refined[i] = cand ∧ ⋀_{p active} adj[frontier[i, p]]

an AND-reduction over dynamically gathered adjacency bitmap rows. On TPU
the dynamic row gather is expressed with *scalar prefetch*: the frontier
matrix and the active-position vector are prefetched into SMEM, and the
``index_map`` of the adjacency operand picks the HBM block to stream into
VMEM for each (row, position) grid step. The output block is revisited
across the position dimension and accumulated in place (VMEM), so each
refined row is written to HBM once.

Block geometry: one grid step loads one adjacency row block of
``(1, W_pad)`` words. ``W_pad`` is padded to a multiple of 128 lanes; the
single-sublane block wastes sublanes on real hardware — measured as
acceptable because the kernel is gather-bound, see EXPERIMENTS.md §Perf.
All words are int32 (bitwise ops are sign-agnostic; uint32<->int32 is a
bitcast at the wrapper).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

def _refine_kernel(frontier_ref, active_ref, adj_ref, cand_ref, out_ref):
    """Grid (F, NP): AND-accumulate adjacency rows into the output row."""
    p = pl.program_id(1)
    i = pl.program_id(0)

    @pl.when(p == 0)
    def _init():
        out_ref[...] = cand_ref[...]

    act = (active_ref[p] != 0) & (frontier_ref[i, p] >= 0)
    row = jnp.where(act, adj_ref[...], -1)   # -1 == all bits set
    out_ref[...] &= row


@functools.partial(jax.jit, static_argnames=("interpret",))
def refine_bitmap(adj_bitmap: jax.Array, cand_row: jax.Array,
                  frontier: jax.Array, active: jax.Array,
                  interpret: bool = True) -> jax.Array:
    """Pallas-backed Eq. 2 refinement.

    Args:
      adj_bitmap: int32/uint32 [V, W] packed adjacency rows.
      cand_row:   int32/uint32 [W] packed candidates of the position.
      frontier:   int32 [F, NP] mapped vertex per position (-1 unmapped).
      active:     int32 [NP] nonzero for mapped neighbor positions.
      interpret:  run the kernel body in interpret mode (CPU container);
                  on real TPU pass False.

    Returns int32 [F, W_pad>=W] refined packed bitmaps (caller slices W).
    """
    v, w = adj_bitmap.shape
    f, np_ = frontier.shape
    w_pad = max(128, ((w + 127) // 128) * 128)
    adj = jnp.zeros((v, w_pad), jnp.int32).at[:, :w].set(
        adj_bitmap.astype(jnp.int32))
    cand = jnp.zeros((1, w_pad), jnp.int32).at[0, :w].set(
        cand_row.astype(jnp.int32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(f, np_),
        in_specs=[
            pl.BlockSpec(
                (1, w_pad),
                lambda i, p, frontier_ref, active_ref: (
                    jnp.where(active_ref[p] != 0,
                              frontier_ref[i, p], 0).clip(0, v - 1),
                    0)),
            pl.BlockSpec((1, w_pad), lambda i, p, *_: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w_pad), lambda i, p, *_: (i, 0)),
    )
    return pl.pallas_call(
        _refine_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((f, w_pad), jnp.int32),
        interpret=interpret,
    )(frontier, active.astype(jnp.int32), adj, cand)
