"""Pallas TPU kernel: Eq. 2 candidate refinement over packed bitmaps.

The matcher's hot loop. For every partial embedding (frontier row) the
refined candidate set of the next query position is

    refined[i] = cand[i] ∧ ⋀_{p active for row i} adj[frontier[i, p]]

an AND-reduction over dynamically gathered adjacency bitmap rows. Since
the multi-query engine refactor the candidate row and the active-position
set are *per row* (each wave row may belong to a different query at a
different depth), so the kernel takes ``cand [F, W]`` and
``active [F, NP]`` — the single-query entry point broadcasts.

Block geometry (this file's §Perf iteration 3): the grid is one step per
``(BLOCK_F, W_pad)`` row block and the position loop is folded *inside*
the kernel body — the old kernel used single-sublane ``(1, W_pad)``
blocks with a ``(F, NP)`` grid, wasting 7/8 sublanes and paying one grid
step per (row, position) pair. Per grid step the body now runs
``fori_loop`` over positions and gathers one adjacency row per sublane
with a dynamic ``pl.ds`` load. The frontier and active matrices are
scalar-prefetched (SMEM) because their values index the adjacency
operand; the adjacency bitmap itself is a single whole-array VMEM block
(packed bitmaps are tiny: V=8192, W_pad=256 is 8 MB). ``W_pad`` is
padded to a multiple of 128 lanes, ``F`` to a multiple of ``BLOCK_F``
sublanes. All words are int32 (bitwise ops are sign-agnostic;
uint32<->int32 is a bitcast at the wrapper).

Past ~8K vertices the whole-VMEM block stops fitting, so this file also
carries the HBM-resident variant :func:`refine_bitmap_rows_hier` over
the two-level layout (core.graph.HierBitmap, DESIGN.md §2): the chunk
store stays in ``pltpu.ANY`` (compiler-placed, HBM at scale), the
wrapper intersects per-row chunk summaries into a live mask, and the
kernel walks only live chunks, double-buffering each one into VMEM
scratch with ``make_async_copy`` before AND-folding it into the output
row. VMEM residency is O(kmax + dma_depth·C) per grid step —
independent of V. ``kernels/config.py`` owns the dense/hier threshold
(``use_hbm_adjacency``) plus the ``chunk_words``/``dma_depth`` knob
resolution.

Backend selection lives in ``kernels/config.py`` — ``interpret=None``
resolves from the process-wide config, so TPU runs cannot silently fall
into interpret mode (the old default was ``interpret=True``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .config import interpret_mode, kernel_block_f, kernel_dma_depth

BLOCK_F = 8     # default sublanes per grid step (int32 min tile height)
                # — the tuned value resolves through kernels.config


def _make_refine_kernel(block_f: int):
    """Kernel body closure over the (tuned) row-block height — the
    sublane loop is a static unroll, so the height is a trace-time
    constant, not a ref shape."""

    def _refine_kernel(frontier_ref, active_ref, adj_ref, cand_ref,
                       out_ref):
        b = pl.program_id(0)
        np_ = frontier_ref.shape[1]

        def body(p, acc):
            rows = []
            for i in range(block_f):        # static unroll over sublanes
                r = b * block_f + i
                vtx = frontier_ref[r, p]
                act = (active_ref[r, p] != 0) & (vtx >= 0)
                idx = jnp.where(act, vtx, 0).clip(0, adj_ref.shape[0] - 1)
                row = adj_ref[pl.ds(idx, 1), :]         # (1, W_pad)
                rows.append(jnp.where(act, row, jnp.int32(-1)))
            return acc & jnp.concatenate(rows, axis=0)

        out_ref[...] = lax.fori_loop(0, np_, body, cand_ref[...])

    return _refine_kernel


@functools.partial(jax.jit, static_argnames=("interpret", "block_f"))
def _refine_rows_call(adj, cand, frontier, active, interpret: bool,
                      block_f: int):
    v_pad, w_pad = adj.shape
    f_pad = frontier.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(f_pad // block_f,),
        in_specs=[
            pl.BlockSpec((v_pad, w_pad), lambda i, *_: (0, 0)),
            pl.BlockSpec((block_f, w_pad), lambda i, *_: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_f, w_pad), lambda i, *_: (i, 0)),
    )
    return pl.pallas_call(
        _make_refine_kernel(block_f),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((f_pad, w_pad), jnp.int32),
        interpret=interpret,
    )(frontier, active, adj, cand)


def refine_bitmap_rows(adj_bitmap: jax.Array, cand_rows: jax.Array,
                       frontier: jax.Array, active: jax.Array,
                       interpret: bool | None = None,
                       block_f: int | None = None) -> jax.Array:
    """Pallas-backed Eq. 2 refinement with per-row candidates.

    Args:
      adj_bitmap: int32/uint32 [V, W] packed adjacency rows.
      cand_rows:  int32/uint32 [F, W] packed candidates, one per row.
      frontier:   int32 [F, NP] mapped vertex per position (-1 unmapped).
      active:     bool/int32 [F, NP] mapped-neighbor positions, per row.
      interpret:  None resolves from ``kernels.config`` (the process-wide
                  backend); pass a bool to force.
      block_f:    rows per grid step. None resolves through the tuning
                  layer (scope override > tuning cache > default 8,
                  DESIGN.md §9). The compiled backend needs a multiple
                  of 8 (int32 sublane tile); interpret mode takes any
                  height >= 1.

    Returns int32 [F, W_pad >= W] refined packed bitmaps (caller slices
    the first W words).
    """
    if interpret is None:
        interpret = interpret_mode(None)
    v, w = adj_bitmap.shape
    if block_f is None:
        block_f = kernel_block_f(n_vertices=v)
    block_f = max(1, int(block_f))
    f, np_ = frontier.shape
    w_pad = max(128, ((w + 127) // 128) * 128)
    v_pad = ((v + 7) // 8) * 8
    f_pad = ((max(f, 1) + block_f - 1) // block_f) * block_f
    adj = jnp.zeros((v_pad, w_pad), jnp.int32).at[:v, :w].set(
        adj_bitmap.astype(jnp.int32))
    cand = jnp.zeros((f_pad, w_pad), jnp.int32).at[:f, :w].set(
        cand_rows.astype(jnp.int32))
    fr = jnp.full((f_pad, np_), -1, jnp.int32).at[:f].set(
        frontier.astype(jnp.int32))
    act = jnp.zeros((f_pad, np_), jnp.int32).at[:f].set(
        active.astype(jnp.int32))
    return _refine_rows_call(adj, cand, fr, act, interpret,
                             block_f)[:f]


def refine_bitmap(adj_bitmap: jax.Array, cand_row: jax.Array,
                  frontier: jax.Array, active: jax.Array,
                  interpret: bool | None = None,
                  block_f: int | None = None) -> jax.Array:
    """Single-query entry point: one shared candidate row and one shared
    active-position vector, broadcast over all F rows (the historical
    signature, kept for ``ops.refine_bitmap_op`` and the dry-run)."""
    f = frontier.shape[0]
    cand_rows = jnp.broadcast_to(
        cand_row.astype(jnp.int32)[None, :], (f, cand_row.shape[0]))
    act = jnp.broadcast_to(
        active.astype(jnp.int32)[None, :], (f, active.shape[0]))
    return refine_bitmap_rows(adj_bitmap, cand_rows, frontier, act,
                              interpret=interpret, block_f=block_f)


# --------------------------------------------------------------------------
# HBM-resident hierarchical variant (two-level layout, DESIGN.md §2)
# --------------------------------------------------------------------------

def summary_intersect(summary: jax.Array, cand_rows: jax.Array,
                      frontier: jax.Array, active: jax.Array,
                      chunk_words: int, w_pad: int
                      ) -> tuple[jax.Array, jax.Array]:
    """The first level of the hierarchical refinement, in plain jnp:
    ``sacc[i] = cand_summary[i] ∧ ⋀_{p active} summary[frontier[i, p]]``
    plus its expansion to a ``[F, w_pad]`` word mask.

    Summaries are O(V/32C) words per row, so this stays cheap enough to
    fold outside the kernel; a chunk dead in ``sacc`` is provably zero
    in the dense result (the candidate chunk was empty, or some active
    row misses it), which is what licenses the kernel to never read it.
    Returns ``(sacc int32 [F, SW], mask int32 [F, w_pad])``.
    """
    f, np_ = frontier.shape
    w = cand_rows.shape[1]
    c = int(chunk_words)
    sw = summary.shape[1]
    ncp = sw * 32
    shifts = jnp.arange(32, dtype=jnp.uint32)
    cand = cand_rows.astype(jnp.uint32)
    cpad = jnp.zeros((f, ncp * c), jnp.uint32).at[:, :w].set(cand)
    nonzero = (cpad.reshape(f, ncp, c) != 0).any(axis=2)
    cand_sum = (nonzero.reshape(f, sw, 32).astype(jnp.uint32)
                << shifts).sum(axis=2, dtype=jnp.uint32)

    def sbody(p, s):
        act = (active[:, p] != 0) & (frontier[:, p] >= 0)
        rows = summary.astype(jnp.uint32)[frontier[:, p].clip(0)]
        return jnp.where(act[:, None], s & rows, s)

    sacc = lax.fori_loop(0, np_, sbody, cand_sum)
    livebit = ((sacc[:, :, None] >> shifts) & jnp.uint32(1))
    mask = jnp.repeat(livebit.reshape(f, ncp), c, axis=1)
    mask = jnp.zeros((f, w_pad), jnp.uint32).at[:, :min(ncp * c, w_pad)] \
        .set(mask[:, :w_pad] * jnp.uint32(0xFFFFFFFF))
    return sacc.astype(jnp.int32), mask.astype(jnp.int32)


def _make_refine_hier_kernel(kmax: int, chunk_words: int, depth: int):
    """Kernel body closure over the layout's static geometry: ``kmax``
    (stored-chunk window per row), ``chunk_words`` (C) and the DMA
    pipeline ``depth``."""
    c = int(chunk_words)

    def _kernel(frontier_ref, active_ref, seg_start_ref, seg_len_ref,
                sacc_ref, chunk_id_ref, chunk_data_ref, cand_ref,
                mask_ref, out_ref, ids_buf, data_buf, ring_ref,
                ids_sem, data_sem):
        r = pl.program_id(0)
        np_ = frontier_ref.shape[1]
        sw = sacc_ref.shape[1]
        # dead chunks of the candidate row are pre-zeroed so skipping
        # them below cannot leave stale bits
        out_ref[...] = cand_ref[...] & mask_ref[...]
        row_live = sacc_ref[r, 0]
        for s in range(1, sw):              # static unroll, SW is tiny
            row_live = row_live | sacc_ref[r, s]

        def drain(slot):
            """Wait the copy in ``slot`` and AND its chunk into the
            output row (same-shape descriptor, same semaphore)."""
            pltpu.make_async_copy(
                chunk_data_ref.at[pl.ds(0, 1)],
                data_buf.at[pl.ds(slot, 1)],
                data_sem.at[slot]).wait()
            cid = ring_ref[slot, 0]
            cur = out_ref[0, pl.ds(cid * c, c)]
            out_ref[0, pl.ds(cid * c, c)] = cur & data_buf[slot, :]

        def pos_body(p, _):
            vtx = frontier_ref[r, p]
            act = (active_ref[r, p] != 0) & (vtx >= 0)
            k0 = seg_start_ref[r, p]
            nk = seg_len_ref[r, p]

            @pl.when(act & (nk > 0))
            def _():
                # stage this row's stored-chunk ids (one contiguous
                # copy; the store pads kmax rows so the fixed window
                # never over-runs)
                pltpu.make_async_copy(
                    chunk_id_ref.at[pl.ds(k0, kmax)], ids_buf,
                    ids_sem).start()
                pltpu.make_async_copy(
                    chunk_id_ref.at[pl.ds(k0, kmax)], ids_buf,
                    ids_sem).wait()

                def walk(j, lc):
                    cid = ids_buf[j, 0]
                    live = (j < nk) & (
                        ((sacc_ref[r, cid // 32]
                          >> lax.rem(cid, 32)) & 1) != 0)

                    def issue(lc):
                        slot = lax.rem(lc, depth)
                        # free the slot first: its previous chunk is
                        # consumed while this one's copy is in flight
                        @pl.when(lc >= depth)
                        def _():
                            drain(slot)
                        ring_ref[slot, 0] = cid
                        pltpu.make_async_copy(
                            chunk_data_ref.at[pl.ds(k0 + j, 1)],
                            data_buf.at[pl.ds(slot, 1)],
                            data_sem.at[slot]).start()
                        return lc + 1

                    return lax.cond(live, issue, lambda lc: lc, lc)

                lc = lax.fori_loop(0, kmax, walk, 0)

                def tail(s, _):
                    @pl.when(s < jnp.minimum(lc, depth))
                    def _():
                        drain(s)
                    return 0

                lax.fori_loop(0, depth, tail, 0)
            return 0

        @pl.when(row_live != 0)
        def _():
            lax.fori_loop(0, np_, pos_body, 0)

    return _kernel


@functools.partial(jax.jit,
                   static_argnames=("interpret", "kmax", "depth"))
def _refine_rows_hier_call(chunk_id, chunk_data, cand, mask, frontier,
                           active, seg_start, seg_len, sacc,
                           interpret: bool, kmax: int, depth: int):
    f_pad, w_pad = cand.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(f_pad,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),     # chunk_id  (HBM)
            pl.BlockSpec(memory_space=pltpu.ANY),     # chunk_data (HBM)
            pl.BlockSpec((1, w_pad), lambda i, *_: (i, 0)),
            pl.BlockSpec((1, w_pad), lambda i, *_: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, w_pad), lambda i, *_: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((kmax, 1), jnp.int32),         # staged chunk ids
            pltpu.VMEM((depth, chunk_data.shape[1]), jnp.int32),
            pltpu.SMEM((depth, 1), jnp.int32),        # in-flight ids ring
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA((depth,)),
        ])
    return pl.pallas_call(
        _make_refine_hier_kernel(kmax, chunk_data.shape[1], depth),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((f_pad, w_pad), jnp.int32),
        interpret=interpret,
    )(frontier, active, seg_start, seg_len, sacc, chunk_id, chunk_data,
      cand, mask)


def refine_bitmap_rows_hier(summary: jax.Array, chunk_ptr: jax.Array,
                            chunk_id: jax.Array, chunk_data: jax.Array,
                            kmax: int, cand_rows: jax.Array,
                            frontier: jax.Array, active: jax.Array,
                            interpret: bool | None = None,
                            dma_depth: int | None = None) -> jax.Array:
    """HBM-paged Eq. 2 refinement over the two-level layout.

    Args:
      summary:    uint32/int32 [V, SW] per-row chunk summary bitmaps.
      chunk_ptr:  int32 [V+1] CSR offsets into the chunk store.
      chunk_id:   int32 [P] stored chunk index per entry (kmax-padded).
      chunk_data: uint32/int32 [P, C] the stored chunks (kmax-padded).
      kmax:       static max stored chunks on any row (>= 1).
      cand_rows / frontier / active: as :func:`refine_bitmap_rows`.
      dma_depth:  in-flight chunk copies. None resolves through the
                  tuning layer (kernels.config, DESIGN.md §9).

    The adjacency operands ride in ``pltpu.ANY`` — nothing O(V·W) is
    staged into VMEM, so the only V-dependent device residency is the
    O(E)-proportional chunk store itself. Returns int32 [F, W_pad]
    (caller slices the first W words).
    """
    if interpret is None:
        interpret = interpret_mode(None)
    v = chunk_ptr.shape[0] - 1
    if dma_depth is None:
        dma_depth = kernel_dma_depth(n_vertices=v)
    dma_depth = max(1, int(dma_depth))
    kmax = max(1, int(kmax))
    c = chunk_data.shape[1]
    f, np_ = frontier.shape
    w = cand_rows.shape[1]
    w_pad = max(128, ((w + 127) // 128) * 128)
    f_pad = max(f, 1)
    sacc, mask = summary_intersect(summary, cand_rows, frontier, active,
                                   c, w_pad)
    fr = jnp.full((f_pad, np_), -1, jnp.int32).at[:f].set(
        frontier.astype(jnp.int32))
    act = jnp.zeros((f_pad, np_), jnp.int32).at[:f].set(
        active.astype(jnp.int32))
    seg_start = chunk_ptr[fr.clip(0)].astype(jnp.int32)
    seg_len = (chunk_ptr[fr.clip(0) + 1] - chunk_ptr[fr.clip(0)]) \
        .astype(jnp.int32)
    cand = jnp.zeros((f_pad, w_pad), jnp.int32).at[:f, :w].set(
        cand_rows.astype(jnp.int32))
    maskp = jnp.zeros((f_pad, w_pad), jnp.int32).at[:f].set(mask)
    saccp = jnp.zeros((f_pad, sacc.shape[1]), jnp.int32).at[:f].set(sacc)
    return _refine_rows_hier_call(
        chunk_id.astype(jnp.int32).reshape(-1, 1),
        chunk_data.astype(jnp.int32), cand, maskp, fr, act,
        seg_start, seg_len, saccp, bool(interpret), kmax, dma_depth)
