"""Stdlib HTTP client for the serving tier (DESIGN.md §10) — used by
tests, ``examples/serve_queries.py`` and ``benchmarks/load_bench.py``.

Blocking and streaming flavors over the NDJSON wire protocol
(:mod:`repro.server.protocol`):

    client = ServeClient(host, port)
    rows, result = client.match(query, tenant="alpha")   # blocking
    for ev in client.stream(query):                      # streaming
        if ev["event"] == "chunk":
            ...ev["rows"]...

``stream`` decodes strictly (every malformed line raises
:class:`~repro.server.protocol.ProtocolError`) and yields events until
the terminal ``done``/``error`` event inclusive. The embedding union
across ``chunk`` events equals the in-process blocking API's embedding
set exactly — streamed delivery never changes the answer.
"""
from __future__ import annotations

import http.client
import json
import socket
from typing import Iterator

import numpy as np

from ..core.graph import Graph
from .protocol import (MatchRequestWire, ProtocolError, decode_event)

__all__ = ["ServeClient", "ServerError"]


class ServerError(RuntimeError):
    """The server answered with a terminal ``error`` event (or a
    non-200 HTTP status). Carries the wire ``code``."""

    def __init__(self, message: str, code: str = "error"):
        super().__init__(message)
        self.code = code


class ServeClient:
    def __init__(self, host: str = "127.0.0.1", port: int = 8421,
                 timeout: float = 300.0):
        self.host = host
        self.port = port
        self.timeout = timeout

    # ------------------------------------------------------------------
    def _conn(self) -> http.client.HTTPConnection:
        conn = http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout)
        conn.connect()
        # request bodies and NDJSON reads are small; Nagle against
        # delayed ACKs costs tens of ms per round trip
        conn.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return conn

    def _get_json(self, path: str) -> dict:
        conn = self._conn()
        try:
            conn.request("GET", path)
            resp = conn.getresponse()
            body = resp.read()
            if resp.status != 200:
                raise ServerError(
                    f"GET {path} -> {resp.status}: {body[:200]!r}",
                    code=str(resp.status))
            return json.loads(body)
        finally:
            conn.close()

    def health(self) -> dict:
        return self._get_json("/healthz")

    def slo(self) -> dict:
        return self._get_json("/slo")

    def metrics(self) -> dict:
        return self._get_json("/metrics")

    # ------------------------------------------------------------------
    def stream(self, query: Graph, *, tenant: str = "default",
               options: dict | None = None,
               request_id: int | str | None = None) -> Iterator[dict]:
        """Send one match request; yield decoded wire events through
        the terminal event. Closing the generator mid-stream closes the
        connection — the server cancels the query via the eviction
        path."""
        wire = MatchRequestWire(query=query, tenant=tenant,
                                options=dict(options or {}),
                                request_id=request_id)
        body = wire.to_json()
        conn = self._conn()
        try:
            conn.request("POST", "/v1/match", body=body, headers={
                "Content-Type": "application/json",
                "Content-Length": str(len(body))})
            resp = conn.getresponse()
            if resp.status not in (200, 400, 503):
                raise ServerError(
                    f"POST /v1/match -> {resp.status}",
                    code=str(resp.status))
            while True:
                line = resp.readline()
                if not line:
                    raise ProtocolError(
                        "stream ended without a terminal event")
                if not line.strip():
                    continue
                ev = decode_event(line)
                yield ev
                if ev["event"] in ("done", "error"):
                    return
        finally:
            conn.close()

    def match(self, query: Graph, *, tenant: str = "default",
              options: dict | None = None,
              request_id: int | str | None = None
              ) -> tuple[list[np.ndarray], dict]:
        """Blocking convenience: consume the stream, return
        ``(rows, result)`` where ``rows`` is the streamed embedding
        union in arrival order ([n_query]-int32 arrays) and ``result``
        the terminal summary (any of the six statuses). Raises
        :class:`ServerError` on a terminal ``error`` event."""
        rows: list[np.ndarray] = []
        for ev in self.stream(query, tenant=tenant, options=options,
                              request_id=request_id):
            if ev["event"] == "chunk":
                rows.extend(np.asarray(r, np.int32) for r in ev["rows"])
            elif ev["event"] == "done":
                return rows, ev["result"]
            elif ev["event"] == "error":
                raise ServerError(ev["message"], code=ev["code"])
        raise ProtocolError("stream ended without a terminal event")
