"""Standalone server process entry point (DESIGN.md §10).

    python -m repro.server.launch --graph ba --graph-n 512 --port 8421

Builds the data graph, constructs the engine (knobs resolved through
``MatchOptions`` > tuning cache > built-in, DESIGN.md §9), warms the
jit cache, then announces readiness on stdout with one machine-parseable
line:

    REPRO_SERVER_READY {"host": "127.0.0.1", "port": 8421, ...}

(scripts and the load benchmark wait for that line before sending
traffic). SIGTERM/SIGINT trigger a graceful drain: new requests are
refused with a typed ``draining`` event, queued + resident queries run
to their terminal status (bounded by ``--drain-timeout-s``, then
cancelled through the eviction path), the final SLO report is flushed
to stderr, and the process exits 0.
"""
from __future__ import annotations

import argparse
import json
import signal
import sys
import time

from .server import MatchServer, _jsonify
from .server_args import ServerArgs

__all__ = ["main"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.server.launch",
        description="Subgraph-matching serving tier (DESIGN.md §10)")
    ServerArgs.add_cli_args(ap)
    ap.add_argument("--quiet", action="store_true",
                    help="suppress progress logging on stderr")
    ns = ap.parse_args(argv)
    args = ServerArgs.from_cli_args(ns)

    def log(msg: str) -> None:
        if not ns.quiet:
            print(f"[repro-server] {msg}", file=sys.stderr, flush=True)

    t0 = time.perf_counter()
    log(f"building data graph: {args.graph} "
        f"(n={args.graph_n}, seed={args.graph_seed})")
    data = args.build_graph()
    log(f"data graph ready: |V|={data.n} |E|={data.n_edges} "
        f"labels={data.n_labels} ({time.perf_counter() - t0:.1f}s)")

    server = MatchServer(data, args, log=log)
    if args.backend == "engine":
        sch = server.qserver.scheduler
        tun = sch.tuning_record
        log(f"engine config: {tun['source']}"
            f"{' ' + tun['record'] if tun.get('record') else ''} -> "
            f"n_slots={sch.n_slots} wave_size={sch.wave_size} "
            f"megastep_depth={sch.megastep_depth}")
    server.warmup()

    # graceful drain on SIGTERM/SIGINT: stop admitting, finish
    # residents, flush the SLO report (handler only flips events — the
    # engine thread owns the actual teardown)
    def _drain(signum, frame):
        log(f"signal {signum}: draining "
            f"(timeout {args.drain_timeout_s:g}s)")
        server.begin_drain()

    signal.signal(signal.SIGTERM, _drain)
    signal.signal(signal.SIGINT, _drain)

    ready = {"host": server.host, "port": server.port,
             "graph": args.graph, "n_vertices": data.n,
             "backend": args.backend,
             "tenants": sorted(server.admission.snapshot()),
             "warmup_s": round(time.perf_counter() - t0, 2),
             "baseline_qps": server.baseline_qps}
    print("REPRO_SERVER_READY " + json.dumps(ready), flush=True)
    log(f"listening on http://{server.host}:{server.port}")

    server.serve_forever()             # returns once the drain finishes

    rep = _jsonify(server.qserver.slo_report())
    rep["wire"] = server.metrics.snapshot(server.admission)["wire"]
    rep["tenants"] = server.admission.snapshot()
    print("REPRO_SERVER_SLO " + json.dumps(rep), file=sys.stderr,
          flush=True)
    log("drained; bye")
    return 0


if __name__ == "__main__":
    sys.exit(main())
