"""CLI-parseable server configuration (DESIGN.md §10) — the sglang
``ServerArgs`` idiom: one dataclass that owns every launch knob, with
``add_cli_args``/``from_cli_args`` so ``python -m repro.server.launch
--help`` is the single source of truth.

Engine knobs deliberately mirror :class:`repro.api.MatchOptions` names
and default to ``None`` = "resolve through MatchOptions > tuning cache
> built-in" (DESIGN.md §9) — a launched server picks up the same tuned
configuration the benchmarks were measured with unless the operator
pins a knob explicitly.

Tenant admission config is JSON (inline or ``@file.json``):

    --tenants '{"alpha": {"rate": 50, "burst": 8, "weight": 2},
                "beta":  {"rate": 10}}'

Unknown tenants get the ``--default-*`` policy (their own bucket and
queue). The data graph is built in-process from a named generator —
the serving tier serves one resident graph, like the engine below it.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
from typing import Any

from ..api.options import MatchOptions
from .admission import TenantConfig

__all__ = ["ServerArgs", "GRAPH_KINDS"]

GRAPH_KINDS = ("ba", "er", "powerlaw", "yeast", "trap", "corridor")

# ServerArgs fields forwarded verbatim into MatchOptions.resolve()
_ENGINE_KNOBS = ("n_slots", "wave_size", "kpr", "megastep_depth",
                 "max_queue", "limit", "time_budget_s", "max_recursions",
                 "pattern_capacity", "shed_policy", "stack_capacity")


@dataclasses.dataclass
class ServerArgs:
    # ---- network ------------------------------------------------------
    host: str = "127.0.0.1"
    port: int = 8421               # 0 = pick a free port (announced)

    # ---- data graph ---------------------------------------------------
    graph: str = "ba"
    graph_n: int = 512
    graph_seed: int = 0
    graph_labels: int = 24
    graph_m: int = 3               # BA/powerlaw attachment degree
    graph_extra_edges: int = 512   # ba generator densification

    # ---- engine (None = MatchOptions > tuning cache > built-in) -------
    backend: str = "engine"        # "engine" | "sequential"
    n_slots: int | None = None
    wave_size: int | None = None
    kpr: int | None = None
    megastep_depth: int | None = None
    max_queue: int | None = None
    limit: int | None = 1000
    time_budget_s: float | None = 10.0
    max_recursions: int | None = None
    pattern_capacity: int | None = None
    stack_capacity: int | None = None
    shed_policy: str | None = None   # engine-level QueueFull policy

    # ---- tenants ------------------------------------------------------
    tenants: str | None = None     # JSON object or @path
    default_rate: float | None = None   # None = unlimited
    default_burst: float = 8.0
    default_weight: float = 1.0
    default_max_pending: int = 256

    # ---- lifecycle ----------------------------------------------------
    warmup_queries: int = 4        # jit-cache warmup before listening
    warmup_query_size: int = 4
    drain_timeout_s: float = 60.0  # SIGTERM: max wait for residents
    idle_poll_s: float = 0.002     # engine-thread sleep when idle
    metrics_refresh_s: float = 0.25

    # ------------------------------------------------------------------
    @staticmethod
    def add_cli_args(ap: argparse.ArgumentParser) -> None:
        d = ServerArgs()
        net = ap.add_argument_group("network")
        net.add_argument("--host", default=d.host)
        net.add_argument("--port", type=int, default=d.port,
                         help="0 picks a free port (announced on the "
                              "READY line)")
        g = ap.add_argument_group("data graph")
        g.add_argument("--graph", choices=GRAPH_KINDS, default=d.graph)
        g.add_argument("--graph-n", type=int, default=d.graph_n)
        g.add_argument("--graph-seed", type=int, default=d.graph_seed)
        g.add_argument("--graph-labels", type=int, default=d.graph_labels)
        g.add_argument("--graph-m", type=int, default=d.graph_m)
        g.add_argument("--graph-extra-edges", type=int,
                       default=d.graph_extra_edges)
        e = ap.add_argument_group(
            "engine (unset = MatchOptions > tuning cache > built-in)")
        e.add_argument("--backend", choices=("engine", "sequential"),
                       default=d.backend)
        for knob, typ in (("n_slots", int), ("wave_size", int),
                          ("kpr", int), ("megastep_depth", int),
                          ("max_queue", int), ("limit", int),
                          ("time_budget_s", float),
                          ("max_recursions", int),
                          ("pattern_capacity", int),
                          ("stack_capacity", int)):
            e.add_argument(f"--{knob.replace('_', '-')}", type=typ,
                           default=getattr(d, knob))
        e.add_argument("--shed-policy", choices=("reject", "shed_lowest"),
                       default=d.shed_policy)
        t = ap.add_argument_group("tenants")
        t.add_argument("--tenants", default=d.tenants,
                       help="JSON object name -> {rate, burst, weight, "
                            "max_pending}, or @path/to/file.json")
        t.add_argument("--default-rate", type=float, default=d.default_rate)
        t.add_argument("--default-burst", type=float,
                       default=d.default_burst)
        t.add_argument("--default-weight", type=float,
                       default=d.default_weight)
        t.add_argument("--default-max-pending", type=int,
                       default=d.default_max_pending)
        lc = ap.add_argument_group("lifecycle")
        lc.add_argument("--warmup-queries", type=int,
                        default=d.warmup_queries)
        lc.add_argument("--warmup-query-size", type=int,
                        default=d.warmup_query_size)
        lc.add_argument("--drain-timeout-s", type=float,
                        default=d.drain_timeout_s)

    @staticmethod
    def from_cli_args(ns: argparse.Namespace) -> "ServerArgs":
        fields = {f.name for f in dataclasses.fields(ServerArgs)}
        return ServerArgs(**{k: v for k, v in vars(ns).items()
                             if k in fields})

    # ------------------------------------------------------------------
    def build_graph(self):
        """Build the resident data graph from the named generator —
        deterministic in (kind, n, seed), so a client-side oracle can
        reconstruct the identical graph."""
        from ..data import graph_gen as gg
        k = self.graph
        if k == "ba":
            return gg.ba_labeled_graph(
                self.graph_n, self.graph_m, self.graph_labels,
                extra_edges=self.graph_extra_edges, seed=self.graph_seed)
        if k == "er":
            return gg.er_labeled_graph(
                self.graph_n, self.graph_extra_edges, self.graph_labels,
                seed=self.graph_seed)
        if k == "powerlaw":
            return gg.powerlaw_graph(self.graph_n, self.graph_m,
                                     self.graph_labels,
                                     seed=self.graph_seed)
        if k == "yeast":
            return gg.yeast_like_graph(self.graph_seed)
        if k == "trap":
            _, g = gg.trap_graph(seed=self.graph_seed)
            return g
        if k == "corridor":
            _, g = gg.corridor_graph(seed=self.graph_seed)
            return g
        raise ValueError(f"unknown graph kind {self.graph!r}")

    def build_options(self) -> MatchOptions:
        knobs: dict[str, Any] = {}
        for k in _ENGINE_KNOBS:
            v = getattr(self, k)
            if v is not None:
                knobs[k] = v
        return MatchOptions.resolve(None, **knobs)

    def build_tenants(self) -> tuple[dict[str, TenantConfig],
                                     TenantConfig]:
        """Parse ``--tenants`` into per-tenant configs + the default
        policy applied to tenants not named there."""
        default = TenantConfig(
            name="default", rate=self.default_rate,
            burst=self.default_burst, weight=self.default_weight,
            max_pending=self.default_max_pending).validate()
        if not self.tenants:
            return {}, default
        raw = self.tenants
        if raw.startswith("@"):
            raw = pathlib.Path(raw[1:]).read_text()
        try:
            spec = json.loads(raw)
        except json.JSONDecodeError as e:
            raise ValueError(f"--tenants is not valid JSON: {e}") from e
        if not isinstance(spec, dict):
            raise ValueError("--tenants must be a JSON object "
                             "{name: {rate, burst, weight, max_pending}}")
        tenants = {}
        for name, cfg in spec.items():
            if not isinstance(cfg, dict):
                raise ValueError(f"tenant {name!r} config must be an "
                                 "object")
            bad = set(cfg) - {"rate", "burst", "weight", "max_pending"}
            if bad:
                raise ValueError(f"tenant {name!r}: unknown keys {bad}")
            tenants[name] = TenantConfig(
                name=name, rate=cfg.get("rate", default.rate),
                burst=cfg.get("burst", default.burst),
                weight=cfg.get("weight", default.weight),
                max_pending=cfg.get("max_pending",
                                    default.max_pending)).validate()
        return tenants, default
