"""Serving-tier metrics (DESIGN.md §10): the ``/metrics`` + ``/slo``
payloads.

One :class:`ServerMetrics` instance aggregates three layers into a
JSON-safe snapshot:

* **wire-level counters** owned here (requests, protocol rejects,
  streamed chunks/rows, client disconnects, absorbed engine
  backpressure, drain state) — bumped from HTTP handler threads and the
  engine thread under one lock;
* **admission counters** — per-tenant offered/admitted/shed/
  backpressure tallies and instantaneous queue depths, read from the
  :class:`~repro.server.admission.AdmissionController`;
* **engine SLO + scheduler stats** — ``QueryServer.slo_report()``
  (latency/TTFE percentiles, terminal-status tallies, and the
  ``queue_depth``/``resident_queries`` gauges) and
  ``scheduler_stats()`` (fault counters, tuning record, occupancy).

The engine-side report is refreshed *by the engine thread* (the
scheduler is single-threaded state; ``scheduler_stats`` mutates flush
counters) and cached here, so ``/metrics`` served from an HTTP thread
never races the wave loop.
"""
from __future__ import annotations

import threading
import time

__all__ = ["ServerMetrics"]

# wire-level counter names (all start at 0; JSON ints)
_COUNTERS = (
    "requests_total",          # POST /v1/match bodies received
    "protocol_errors",         # rejected before becoming a query
    "accepted",                # admitted into a tenant queue
    "admission_shed",          # dropped by the bounded-queue policy
    "submitted",               # handed to MatchSession.submit
    "completed",               # terminal done events emitted
    "chunks_streamed",         # chunk events emitted
    "rows_streamed",           # embedding rows across all chunks
    "client_disconnects",      # mid-stream EPIPE -> cancellation
    "backpressure_absorbed",   # QueueFull absorbed + retried
    "draining_rejects",        # requests refused during drain
)


class ServerMetrics:
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters = {k: 0 for k in _COUNTERS}
        self._engine_report: dict = {}
        self._engine_report_t = 0.0
        self.t_start = time.time()
        self.draining = False

    # ------------------------------------------------------------------
    def bump(self, name: str, by: int = 1) -> None:
        with self._lock:
            self._counters[name] += by

    def get(self, name: str) -> int:
        with self._lock:
            return self._counters[name]

    # ------------------------------------------------------------------
    def set_engine_report(self, report: dict) -> None:
        """Engine-thread-only: cache the latest slo_report/stats merge
        so HTTP threads never touch live scheduler state."""
        with self._lock:
            self._engine_report = report
            self._engine_report_t = time.time()

    # ------------------------------------------------------------------
    def slo(self) -> dict:
        """The ``/slo`` payload: the engine's own SLO report (latency /
        TTFE percentiles, terminal tallies, queue_depth +
        resident_queries gauges) stamped with its snapshot age."""
        with self._lock:
            rep = dict(self._engine_report)
            rep["snapshot_age_s"] = (time.time() - self._engine_report_t
                                     if self._engine_report_t else None)
            rep["draining"] = self.draining
        return rep

    def snapshot(self, admission=None) -> dict:
        """The ``/metrics`` payload: wire counters + per-tenant
        admission state + the cached engine report."""
        with self._lock:
            out = {
                "uptime_s": time.time() - self.t_start,
                "draining": self.draining,
                "wire": dict(self._counters),
                "engine": dict(self._engine_report),
            }
        if admission is not None:
            out["tenants"] = admission.snapshot()
            out["admission_depth"] = admission.depth
        return out
