"""Multi-tenant admission for the serving tier (DESIGN.md §10).

Sits between the HTTP handler threads and the engine thread's
``MatchSession.submit``. Three mechanisms compose, outermost first:

* **bounded per-tenant queues** — a tenant whose pending queue is full
  has its *lowest-priority* pending request shed immediately (terminal
  ``status="shed"``, same taxonomy as the scheduler's ``shed_lowest``
  policy) rather than growing without bound; a new arrival that is
  itself the lowest loses the comparison and is shed on arrival;
* **per-tenant token buckets** — ``rate`` admissions/second with
  ``burst`` headroom gate *dispatch into the engine*, not arrival: an
  over-rate tenant's requests wait in its own queue and never delay
  other tenants;
* **weighted fair queueing** — among tenants that currently hold a
  token, the engine admits in virtual-finish-time order (classic WFQ:
  each request's finish tag is assigned *at enqueue* as
  ``max(vtime, tenant.vfinish) + 1 / weight``), so a tenant with
  weight 2 gets twice the admission share of a weight-1 tenant under
  contention, an idle tenant's unused share redistributes, and a
  backlogged light tenant keeps its early tag instead of being
  re-priced every pop (which would starve it behind a heavier queue).

Engine backpressure (the scheduler's bounded queue raising
``QueueFull``) is *not* shedding: the controller re-queues the request
at the head of its tenant queue and counts an absorbed-backpressure
event — the distinction the SLO report needs between "dropped" and
"retry later".

Thread-safety: ``offer``/counters are called from HTTP threads,
``next_ready``/``requeue_front`` from the engine thread; one lock
guards the queues.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Callable

__all__ = ["TenantConfig", "TokenBucket", "AdmissionController"]


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """Admission policy for one tenant (see ``ServerArgs.tenants``)."""
    name: str = "default"
    rate: float | None = None      # admissions/sec (None = unlimited)
    burst: float = 8.0             # token-bucket capacity
    weight: float = 1.0            # WFQ share under contention
    max_pending: int = 256         # bounded queue; overflow sheds

    def validate(self) -> "TenantConfig":
        if self.rate is not None and self.rate <= 0:
            raise ValueError(f"tenant {self.name}: rate must be > 0 or "
                             f"None, got {self.rate!r}")
        if self.burst < 1:
            raise ValueError(f"tenant {self.name}: burst must be >= 1")
        if self.weight <= 0:
            raise ValueError(f"tenant {self.name}: weight must be > 0")
        if self.max_pending < 1:
            raise ValueError(f"tenant {self.name}: max_pending >= 1")
        return self


class TokenBucket:
    """Continuous-refill token bucket; ``rate=None`` always has a
    token. Not thread-safe on its own — the controller's lock guards
    it."""

    def __init__(self, rate: float | None, burst: float,
                 now: float | None = None):
        self.rate = rate
        self.burst = float(burst)
        self.tokens = float(burst)
        self._t = time.monotonic() if now is None else now

    def _refill(self, now: float) -> None:
        if self.rate is None:
            return
        self.tokens = min(self.burst,
                          self.tokens + (now - self._t) * self.rate)
        self._t = now

    def peek(self, now: float) -> bool:
        self._refill(now)
        return self.rate is None or self.tokens >= 1.0

    def take(self, now: float) -> bool:
        self._refill(now)
        if self.rate is None:
            return True
        if self.tokens < 1.0:
            return False
        self.tokens -= 1.0
        return True


class _TenantState:
    __slots__ = ("cfg", "bucket", "pending", "vfinish", "counters")

    def __init__(self, cfg: TenantConfig, now: float):
        self.cfg = cfg
        self.bucket = TokenBucket(cfg.rate, cfg.burst, now)
        self.pending: deque = deque()   # (finish_tag, item) pairs
        self.vfinish = 0.0         # WFQ finish tag of the last enqueue
        self.counters = {"offered": 0, "admitted": 0, "shed": 0,
                         "completed": 0, "backpressure": 0}


class AdmissionController:
    """Tenant-aware admission queue in front of the engine.

    ``on_shed(item)`` is invoked (outside the lock) for every request
    dropped by the bounded-queue policy so the caller can deliver its
    terminal ``status="shed"`` event. Items must expose ``priority``
    (int, higher = keep) and are otherwise opaque.
    """

    def __init__(self, tenants: dict[str, TenantConfig] | None = None,
                 default: TenantConfig | None = None,
                 on_shed: Callable[[Any], None] | None = None):
        self.default = (default or TenantConfig()).validate()
        self._tenants: dict[str, _TenantState] = {}
        self._lock = threading.Lock()
        self._vtime = 0.0                       # WFQ virtual clock
        self.on_shed = on_shed
        now = time.monotonic()
        for name, cfg in (tenants or {}).items():
            cfg = dataclasses.replace(cfg, name=name).validate()
            self._tenants[name] = _TenantState(cfg, now)

    # ------------------------------------------------------------------
    def _state(self, tenant: str, now: float) -> _TenantState:
        st = self._tenants.get(tenant)
        if st is None:
            # unknown tenants serve under the default policy (their own
            # bucket/queue — "default" is a template, not a shared lane)
            cfg = dataclasses.replace(self.default, name=tenant)
            st = self._tenants[tenant] = _TenantState(cfg, now)
        return st

    # ---- HTTP-thread side --------------------------------------------
    def offer(self, item: Any, tenant: str) -> bool:
        """Queue a request. Returns False (after calling ``on_shed``)
        when the bounded-queue policy dropped one — the new arrival if
        it is the lowest-priority pending request, else the current
        lowest, making room. True means *some* request was shed only if
        it was not ``item`` itself."""
        now = time.monotonic()
        shed = None
        with self._lock:
            st = self._state(tenant, now)
            st.counters["offered"] += 1
            # finish tag assigned at enqueue (classic WFQ): frozen for
            # the request's queue lifetime, so a backlogged light
            # tenant's head keeps its early tag and gets its
            # proportional turn instead of being outbid every pop
            tag = max(self._vtime, st.vfinish) + 1.0 / st.cfg.weight
            if len(st.pending) >= st.cfg.max_pending:
                victim_i = min(
                    range(len(st.pending)),
                    key=lambda i: (getattr(st.pending[i][1],
                                           "priority", 0), -i))
                victim = st.pending[victim_i][1]
                if getattr(item, "priority", 0) <= getattr(
                        victim, "priority", 0):
                    shed = item
                else:
                    del st.pending[victim_i]
                    st.pending.append((tag, item))
                    st.vfinish = tag
                    shed = victim
                st.counters["shed"] += 1
            else:
                st.pending.append((tag, item))
                st.vfinish = tag
        if shed is not None:
            if self.on_shed is not None:
                self.on_shed(shed)
            return shed is not item
        return True

    # ---- engine-thread side ------------------------------------------
    def next_ready(self) -> Any | None:
        """Pop the next admissible request: among tenants with pending
        work *and* an available token, the smallest WFQ virtual finish
        tag wins. Returns None when nothing is admissible right now
        (empty, or every backlogged tenant is over its rate)."""
        now = time.monotonic()
        with self._lock:
            best: _TenantState | None = None
            best_tag = 0.0
            for st in self._tenants.values():
                if not st.pending or not st.bucket.peek(now):
                    continue
                tag = st.pending[0][0]
                if best is None or tag < best_tag:
                    best, best_tag = st, tag
            if best is None:
                return None
            best.bucket.take(now)
            self._vtime = max(self._vtime, best_tag)
            best.counters["admitted"] += 1
            return best.pending.popleft()[1]

    def requeue_front(self, item: Any, tenant: str) -> None:
        """Engine backpressure (``QueueFull``): put the request back at
        the head of its tenant queue (at the current virtual time, so
        it is first in line next pass) and count the absorbed event.
        The spent token is intentionally not refunded — a saturated
        engine must not let retries defeat the rate limit."""
        with self._lock:
            st = self._state(tenant, time.monotonic())
            st.pending.appendleft((self._vtime, item))
            st.counters["admitted"] -= 1
            st.counters["backpressure"] += 1

    def note_completed(self, tenant: str) -> None:
        with self._lock:
            st = self._tenants.get(tenant)
            if st is not None:
                st.counters["completed"] += 1

    # ------------------------------------------------------------------
    @property
    def depth(self) -> int:
        with self._lock:
            return sum(len(st.pending) for st in self._tenants.values())

    def pending_items(self) -> list:
        """Snapshot of every queued item (drain bookkeeping)."""
        with self._lock:
            return [it for st in self._tenants.values()
                    for _tag, it in st.pending]

    def snapshot(self) -> dict:
        """JSON-safe per-tenant counters + queue depths for /metrics."""
        with self._lock:
            return {
                name: {**st.counters, "pending": len(st.pending),
                       "rate": st.cfg.rate, "weight": st.cfg.weight,
                       "max_pending": st.cfg.max_pending}
                for name, st in sorted(self._tenants.items())
            }
