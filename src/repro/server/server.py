"""The serving-tier HTTP request loop (DESIGN.md §10).

Process layout — one :class:`MatchServer` owns three kinds of thread:

* the **engine thread** is the only thread that ever touches the
  :class:`~repro.serving.query_server.QueryServer` / scheduler (the
  wave loop is host-driven, single-threaded state). It admits requests
  from the :class:`~repro.server.admission.AdmissionController` in WFQ
  order, absorbs ``QueueFull`` backpressure (requeue-at-head + counter,
  never a drop), steps the session, and forwards each query's freshly
  emitted embedding batches to its response queue — the wire stream is
  fed by the same incremental delivery that feeds
  ``MatchHandle.stream()`` in-process;
* **HTTP handler threads** (stdlib ``ThreadingHTTPServer``) decode one
  request each, then block on the request's event queue, writing each
  event as one NDJSON line and flushing — chunked streaming with zero
  buffering between the engine and the socket. A write failure
  (client went away mid-stream) cancels the query through the
  scheduler's existing eviction path; co-resident queries are
  untouched;
* the **drain waiter** (SIGTERM): stop admitting new wire requests
  (typed ``draining`` error event + HTTP 503), let queued + resident
  queries finish (bounded by ``drain_timeout_s``, then cancelled
  through the eviction path), flush the final SLO report, stop the
  listener.

Endpoints:

    POST /v1/match            NDJSON event stream (protocol.py)
    POST /v1/match?stream=0   single JSON {"events": [...]} (blocking)
    GET  /slo                 engine SLO report (+ live gauges)
    GET  /metrics             wire + admission + engine counters
    GET  /healthz             {"ok": true, "draining": ..., "graph": ...}
"""
from __future__ import annotations

import json
import queue as _queue
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np

from ..api.handle import MatchHandle
from ..core.vectorized import QueueFull
from ..serving.query_server import QueryServer
from .admission import AdmissionController
from .metrics import ServerMetrics
from . import protocol
from .protocol import ProtocolError
from .server_args import ServerArgs

__all__ = ["MatchServer"]


def _jsonify(obj):
    """Recursively convert numpy scalars/arrays so every metrics
    payload survives ``json.dumps``."""
    if isinstance(obj, dict):
        return {str(k): _jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonify(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


class _ServeRequest:
    """One wire request's server-side state. The event queue is the
    engine-thread -> handler-thread seam; everything else is touched by
    one thread at a time (handle only by the engine thread)."""

    __slots__ = ("wire", "query_id", "priority", "events", "handle",
                 "n_sent", "seq", "cancel_requested", "t_accept",
                 "options")

    def __init__(self, wire: protocol.MatchRequestWire, query_id: int):
        self.wire = wire
        self.query_id = query_id
        self.priority = int(wire.options.get("priority") or 0)
        self.events: _queue.Queue = _queue.Queue()
        self.handle: MatchHandle | None = None
        self.n_sent = 0            # embedding rows already streamed
        self.seq = 0               # chunk sequence number
        self.cancel_requested = False
        self.t_accept = time.perf_counter()
        self.options: dict = dict(wire.options)

    # terminal results for requests that never reached the engine ------
    def _terminal(self, status: str, **extra) -> dict:
        res = {"query_id": self.query_id, "status": status, "n_found": 0,
               "recursions": 0,
               "latency_ms": (time.perf_counter() - self.t_accept) * 1e3,
               "ttfe_ms": None, "timed_out": status == "timeout",
               "aborted": True, "request_id": self.wire.request_id}
        res.update(extra)
        return res

    def push_done(self, result: dict) -> None:
        self.events.put(protocol.done_event(self.query_id, result))


class MatchServer:
    """The serving tier: engine thread + admission + HTTP listener over
    one data graph. Construct, then :meth:`serve_forever` (blocking) or
    :meth:`start`/:meth:`shutdown` (tests)."""

    def __init__(self, data, args: ServerArgs | None = None,
                 log=None):
        self.args = args = args or ServerArgs()
        self.data = data
        self.log = log or (lambda *a, **k: None)
        self.options = args.build_options()
        self.qserver = QueryServer(data, backend=args.backend,
                                   options=self.options)
        self.metrics = ServerMetrics()
        tenants, default = args.build_tenants()
        self.admission = AdmissionController(
            tenants, default, on_shed=self._on_admission_shed)
        self._live: dict[int, _ServeRequest] = {}
        self.baseline_qps: float | None = None   # set by warmup()
        # generator recipe for the resident graph: build_graph is
        # deterministic in these, so a remote client can reconstruct
        # the identical graph and generate valid queries against it
        # (examples/serve_queries.py --server does)
        self.graph_info = {
            "kind": args.graph, "n": args.graph_n, "m": args.graph_m,
            "labels": args.graph_labels,
            "extra_edges": args.graph_extra_edges,
            "seed": args.graph_seed, "n_vertices": int(data.n),
            "n_edges": int(data.n_edges),
            "n_labels": int(data.n_labels)}
        self._next_id = 0
        self._id_lock = threading.Lock()
        self._work = threading.Event()     # engine wake signal
        self._draining = threading.Event()
        self._drained = threading.Event()
        self._engine_thread: threading.Thread | None = None
        self._t_report = 0.0
        srv = self

        class _BoundHandler(_Handler):
            server_ref = srv

        class _Listener(ThreadingHTTPServer):
            daemon_threads = True
            # the stdlib default listen backlog (5) drops SYNs under a
            # connection burst — the kernel's 1s retransmit then shows
            # up as a spurious p99 latency cliff
            request_queue_size = 128
            # NDJSON streaming writes one small line per event; Nagle
            # batching against delayed ACKs turns that into tens of ms
            # of added TTFE per request
            disable_nagle_algorithm = True

        self.httpd = _Listener((args.host, args.port), _BoundHandler)
        self.host, self.port = self.httpd.server_address[:2]

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def warmup(self) -> None:
        """Warm the jit cache before taking traffic, through the
        *serving* engine instance: one full batch compiles the wave
        programs, then a descending ladder of batch sizes compiles every
        power-of-two admission-burst variant (``_flush_slot_loads`` pads
        bursts to the next power of two — under live traffic requests
        arrive in bursts of every size, and each uncompiled variant
        would cost its tenant a ~100ms stall). The warmup queries'
        latencies are scrubbed from the SLO tallies afterwards."""
        if self.args.warmup_queries <= 0:
            return
        from ..data.graph_gen import query_set
        qs = query_set(self.data, self.args.warmup_query_size,
                       max(self.args.warmup_queries, 1), seed=1)
        t0 = time.perf_counter()
        sch = self.qserver.scheduler
        if sch is None:
            self.qserver.submit_batch(qs)
        else:
            # [n, n, n/2, ..., 2, 1]: the first full batch compiles the
            # wave programs + the widest load burst, the second adds the
            # widest slot-clear burst, the rest cover the narrower
            # power-of-two load/clear variants
            sizes = [sch.n_slots, sch.n_slots]
            k = sch.n_slots // 2
            while k >= 1:
                sizes.append(k)
                k //= 2
            for size in sizes:
                self.qserver.submit_batch(
                    [qs[i % len(qs)] for i in range(size)])
            # in-process baseline on the *serving* engine (best of 2
            # warm full batches): the denominator for the serving
            # tier's wire-overhead ratio (scripts/ab_gate.py) — same
            # process, same compiled programs, same query shapes as the
            # wire burst that load_bench --rate 0 drives
            for _ in range(2):
                batch = [qs[i % len(qs)] for i in range(sch.n_slots)]
                tb = time.perf_counter()
                self.qserver.submit_batch(batch)
                qps = len(batch) / (time.perf_counter() - tb)
                self.baseline_qps = max(self.baseline_qps or 0.0, qps)
        # warmup traffic must not pollute the serving SLO percentiles
        q = self.qserver
        q.latencies.clear()
        q.ttfes.clear()
        q.n_timeouts = q.n_cancelled = q.n_errors = 0
        q.n_shed = q.n_backpressure = 0
        self.log(f"warmup: wave programs + admission burst variants "
                 f"compiled ({time.perf_counter() - t0:.1f}s); "
                 f"in-process baseline "
                 f"{self.baseline_qps or float('nan'):.1f} qps")

    def start(self) -> None:
        self._engine_thread = threading.Thread(
            target=self._engine_loop, name="repro-engine", daemon=True)
        self._engine_thread.start()
        self._http_thread = threading.Thread(
            target=self.httpd.serve_forever, name="repro-http",
            daemon=True)
        self._http_thread.start()

    def serve_forever(self) -> None:
        """Blocking run: returns after a drain completes."""
        self.start()
        self._drained.wait()
        self.httpd.shutdown()
        self._http_thread.join(timeout=10)

    def begin_drain(self) -> None:
        """Graceful shutdown: stop admitting new wire requests, finish
        queued + resident queries (bounded by ``drain_timeout_s``),
        then release :meth:`serve_forever`."""
        self.metrics.draining = True
        self._draining.set()
        self._work.set()

    def shutdown(self, drain: bool = True) -> None:
        """Test/embedding teardown: optionally drain, then stop the
        listener and join the engine thread."""
        if drain:
            self.begin_drain()
            self._drained.wait(timeout=self.args.drain_timeout_s + 30)
        else:
            self._draining.set()
            self._drained.set()
            self._work.set()
        self.httpd.shutdown()
        if self._engine_thread is not None:
            self._engine_thread.join(timeout=10)

    # ------------------------------------------------------------------
    # handler-thread side
    # ------------------------------------------------------------------
    def submit_wire(self, wire: protocol.MatchRequestWire
                    ) -> _ServeRequest | dict:
        """Validate + admit one decoded request (handler thread).
        Returns the live :class:`_ServeRequest`, or a terminal error
        event dict when the request never became a query."""
        self.metrics.bump("requests_total")
        if self._draining.is_set():
            self.metrics.bump("draining_rejects")
            return protocol.error_event(
                "server is draining; retry against another replica",
                code="draining")
        try:    # validate option values with the engine defaults folded
            self.options.replace(**{
                k: v for k, v in wire.options.items()
                if k in protocol.REQUEST_OPTION_KEYS})
        except (ValueError, TypeError) as e:
            self.metrics.bump("protocol_errors")
            return protocol.error_event(f"invalid options: {e}",
                                        code="bad-options")
        with self._id_lock:
            qid = self._next_id
            self._next_id += 1
        req = _ServeRequest(wire, qid)
        self.metrics.bump("accepted")
        self.admission.offer(req, wire.tenant)
        self._work.set()
        return req

    def _on_admission_shed(self, req: _ServeRequest) -> None:
        """Bounded-queue drop: terminal ``status="shed"`` over the wire
        (the same taxonomy as the engine's shed_lowest policy)."""
        self.metrics.bump("admission_shed")
        req.push_done(req._terminal("shed", shed_by="admission"))

    def request_cancel(self, req: _ServeRequest,
                       disconnect: bool = False) -> None:
        """Handler thread: client disconnected (or asked to stop) —
        ride the scheduler's eviction path at the engine thread's next
        deliver pass."""
        req.cancel_requested = True
        if disconnect:
            self.metrics.bump("client_disconnects")
        self._work.set()

    # ------------------------------------------------------------------
    # engine thread
    # ------------------------------------------------------------------
    def _engine_loop(self) -> None:
        session = self.qserver.session
        t_drain_start = None
        while True:
            did = self._admit_ready()
            if not session.idle:
                try:
                    did = session.step() or did
                except Exception as e:      # pragma: no cover - belt
                    self.log(f"engine step failed: {e!r}")
            did = self._deliver() or did
            now = time.perf_counter()
            if now - self._t_report >= self.args.metrics_refresh_s:
                self._refresh_report()
            if self._draining.is_set():
                if t_drain_start is None:
                    t_drain_start = now
                busy = (self.admission.depth or self._live
                        or not session.idle)
                if busy and (now - t_drain_start
                             > self.args.drain_timeout_s):
                    self._force_cancel_all()
                    busy = False
                if not busy:
                    self._refresh_report()
                    self._drained.set()
                    return
            if not did:
                self._work.wait(timeout=self.args.idle_poll_s)
                self._work.clear()

    def _admit_ready(self) -> bool:
        """Pull WFQ-ordered admissible requests into the engine until it
        pushes back. ``QueueFull`` is absorbed (requeue at head +
        counter), never surfaced to the tenant — the admission queue is
        the retry buffer."""
        did = False
        while True:
            req = self.admission.next_ready()
            if req is None:
                return did
            if req.cancel_requested:   # died waiting in the queue
                req.push_done(req._terminal("cancelled"))
                self.admission.note_completed(req.wire.tenant)
                self.metrics.bump("completed")
                continue
            try:
                opts = {k: v for k, v in req.options.items()
                        if k in protocol.REQUEST_OPTION_KEYS}
                req.handle = self.qserver.submit_async(
                    req.wire.query, query_id=req.query_id, **opts)
            except QueueFull:
                self.admission.requeue_front(req, req.wire.tenant)
                self.metrics.bump("backpressure_absorbed")
                return did
            except Exception as e:     # unexpected submit failure:
                # terminal error status — never leave a handler thread
                # blocked on an event queue nobody will feed
                req.push_done(req._terminal(
                    "error", timed_out=False, error=f"{e!r}"))
                self.admission.note_completed(req.wire.tenant)
                self.metrics.bump("completed")
                continue
            self.metrics.bump("submitted")
            req.events.put(protocol.accepted_event(
                req.query_id, req.wire.tenant, req.wire.request_id))
            self._live[req.query_id] = req
            did = True

    def _deliver(self) -> bool:
        """Forward freshly emitted embedding batches to each live
        request's wire stream; retire completed handles with their
        terminal event. Mirrors ``MatchSession._stream``'s cursor
        logic: on completion any rows not yet streamed are flushed from
        ``result().embeddings[n_sent:]``."""
        did = False
        for qid in list(self._live):
            req = self._live[qid]
            h = req.handle
            if req.cancel_requested and not h.done():
                h.cancel()             # scheduler eviction path
            while h._batches:
                batch = h._batches.popleft()
                req.events.put(protocol.chunk_event(
                    qid, req.seq, np.asarray(batch).tolist()))
                req.seq += 1
                req.n_sent += len(batch)
                self.metrics.bump("chunks_streamed")
                self.metrics.bump("rows_streamed", len(batch))
                did = True
            if h.done():
                res = h._result
                emb = res.embeddings
                if req.n_sent < len(emb):
                    rows = [np.asarray(e).tolist()
                            for e in emb[req.n_sent:]]
                    req.events.put(protocol.chunk_event(
                        qid, req.seq, rows))
                    req.seq += 1
                    req.n_sent += len(rows)
                    self.metrics.bump("chunks_streamed")
                    self.metrics.bump("rows_streamed", len(rows))
                d = res.to_dict()
                d["tenant"] = req.wire.tenant
                d["request_id"] = req.wire.request_id
                if res.status == "error" and h.error is not None:
                    d["error"] = str(h.error)
                req.push_done(d)
                del self._live[qid]
                self.admission.note_completed(req.wire.tenant)
                self.metrics.bump("completed")
                did = True
        return did

    def _force_cancel_all(self) -> None:
        """Drain deadline expired: evict every resident query and shed
        everything still queued (all reach a terminal status)."""
        self.log("drain timeout: cancelling resident queries")
        for req in self.admission.pending_items():
            req.cancel_requested = True
        self._admit_ready()            # flush queue -> cancelled events
        for req in self._live.values():
            if req.handle is not None and not req.handle.done():
                req.handle.cancel()
        self._deliver()

    def _refresh_report(self) -> None:
        """Engine-thread-only: snapshot the SLO report for /slo and
        /metrics (``scheduler_stats`` mutates scheduler state, so HTTP
        threads must never call it live)."""
        self.metrics.set_engine_report(_jsonify(self.qserver.slo_report()))
        self._t_report = time.perf_counter()


# ----------------------------------------------------------------------
# HTTP layer
# ----------------------------------------------------------------------
class _Handler(BaseHTTPRequestHandler):
    server_ref: MatchServer = None      # bound per-server subclass
    server_version = "repro-serve/1"
    protocol_version = "HTTP/1.0"       # Connection: close; EOF-delimited

    def log_message(self, fmt, *args):  # quiet by default
        self.server_ref.log(f"http: {fmt % args}")

    # ------------------------------------------------------------------
    def _send_json(self, payload: dict, code: int = 200) -> None:
        body = json.dumps(_jsonify(payload), indent=2).encode() + b"\n"
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self) -> None:
        srv = self.server_ref
        path = self.path.split("?", 1)[0]
        if path == "/healthz":
            self._send_json({"ok": True,
                             "draining": srv.metrics.draining,
                             "graph": srv.graph_info})
        elif path == "/slo":
            self._send_json(srv.metrics.slo())
        elif path == "/metrics":
            self._send_json(srv.metrics.snapshot(srv.admission))
        else:
            self._send_json({"error": f"unknown path {path!r}"}, 404)

    # ------------------------------------------------------------------
    def do_POST(self) -> None:
        srv = self.server_ref
        path, _, query_str = self.path.partition("?")
        if path != "/v1/match":
            self._send_json({"error": f"unknown path {path!r}"}, 404)
            return
        stream = "stream=0" not in query_str
        try:
            length = int(self.headers.get("Content-Length", "0"))
            raw = self.rfile.read(length)
            wire = protocol.MatchRequestWire.from_json(raw)
        except ProtocolError as e:
            srv.metrics.bump("protocol_errors")
            self._send_events([protocol.error_event(str(e))], code=400)
            return
        out = srv.submit_wire(wire)
        if isinstance(out, dict):       # terminal error pre-admission
            code = 503 if out.get("code") == "draining" else 400
            self._send_events([out], code=code)
            return
        if stream:
            self._stream_events(out)
        else:
            self._blocking_events(out)

    # ------------------------------------------------------------------
    def _send_events(self, events: list, code: int = 200) -> None:
        body = b"".join(protocol.encode_event(e) for e in events)
        self.send_response(code)
        self.send_header("Content-Type", "application/x-ndjson")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _stream_events(self, req: _ServeRequest) -> None:
        """NDJSON streaming: one event per line, flushed as the engine
        emits it. A failed write = the client went away -> cancel the
        query via the eviction path and stop consuming."""
        srv = self.server_ref
        try:
            self.send_response(200)
            self.send_header("Content-Type", "application/x-ndjson")
            self.end_headers()
            self.wfile.flush()
            while True:
                try:
                    ev = req.events.get(
                        timeout=srv.args.drain_timeout_s + 300.0)
                except _queue.Empty:
                    self.wfile.write(protocol.encode_event(
                        protocol.error_event(
                            "server stalled delivering events",
                            code="stalled", query_id=req.query_id)))
                    return
                self.wfile.write(protocol.encode_event(ev))
                self.wfile.flush()
                if ev["event"] in ("done", "error"):
                    return
        except (BrokenPipeError, ConnectionResetError, OSError):
            srv.request_cancel(req, disconnect=True)

    def _blocking_events(self, req: _ServeRequest) -> None:
        """?stream=0 — collect the whole event stream, answer once."""
        srv = self.server_ref
        events = []
        while True:
            try:
                ev = req.events.get(
                    timeout=srv.args.drain_timeout_s + 300.0)
            except _queue.Empty:
                events.append(protocol.error_event(
                    "server stalled delivering events", code="stalled",
                    query_id=req.query_id))
                break
            events.append(ev)
            if ev["event"] in ("done", "error"):
                break
        try:
            self._send_events(events)
        except (BrokenPipeError, ConnectionResetError, OSError):
            srv.metrics.bump("client_disconnects")
