"""Versioned JSON wire encoding for the serving tier (DESIGN.md §10).

Everything that crosses the socket is a JSON object with an explicit
``"v"`` (wire version) — the request envelope, and each NDJSON response
event. The paper's streamed-enumeration semantics (embeddings arrive
incrementally as backtracking progresses, PAPER.md Alg. 2) map onto the
event stream directly:

    {"v": 1, "event": "accepted", "query_id": 7, "tenant": "a"}
    {"v": 1, "event": "chunk", "query_id": 7, "seq": 0, "rows": [[...]]}
    {"v": 1, "event": "chunk", "query_id": 7, "seq": 1, "rows": [[...]]}
    {"v": 1, "event": "done", "query_id": 7, "result": {"status": "ok", ...}}

The union of all ``chunk`` rows equals the blocking API's embedding
set exactly — streaming changes delivery, never the answer. A stream
ends with exactly one terminal event: ``done`` (carrying one of the six
:data:`repro.api.handle.Status` values — ``error`` and ``shed``
included) or ``error`` (the request never became a query: malformed
payload, draining server, unknown tenant action).

Decoding is strict: unknown versions, missing fields, out-of-range
vertex ids and non-whitelisted option knobs all raise
:class:`ProtocolError` — a server must never construct a Graph from a
payload it only half understood.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Iterable

import numpy as np

from ..api.handle import STATUSES
from ..core.graph import Graph

__all__ = [
    "WIRE_VERSION", "ProtocolError", "MatchRequestWire",
    "encode_query", "decode_query", "encode_event", "decode_event",
    "accepted_event", "chunk_event", "done_event", "error_event",
    "REQUEST_OPTION_KEYS",
]

WIRE_VERSION = 1

# per-query knobs a remote caller may set. Engine-level knobs
# (n_slots, wave_size, faults, ...) are the operator's, resolved once at
# server construction — a tenant must not re-shape the shared engine.
REQUEST_OPTION_KEYS = ("limit", "time_budget_s", "max_recursions",
                       "use_pruning", "parallelism", "priority")

_EVENTS = ("accepted", "chunk", "done", "error")


class ProtocolError(ValueError):
    """Malformed or version-incompatible wire payload."""


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ProtocolError(msg)


def _check_version(obj: dict) -> None:
    _require(isinstance(obj, dict), f"payload must be an object, got "
             f"{type(obj).__name__}")
    v = obj.get("v")
    _require(v == WIRE_VERSION,
             f"unsupported wire version {v!r} (speak v{WIRE_VERSION})")


# ----------------------------------------------------------------------
# query graphs
# ----------------------------------------------------------------------
def encode_query(g: Graph) -> dict:
    """JSON-safe query-graph payload: vertex labels + undirected edge
    list (each edge once, ``a < b``)."""
    src = np.repeat(np.arange(g.n), g.degrees)
    dst = np.asarray(g.indices)
    keep = src < dst                    # CSR holds both directions
    return {
        "n": int(g.n),
        "labels": [int(x) for x in g.labels],
        "edges": [[int(a), int(b)] for a, b in
                  zip(src[keep], dst[keep])],
        "n_labels": int(g.n_labels),
    }


def decode_query(d: Any) -> Graph:
    _require(isinstance(d, dict), "query must be an object")
    for k in ("n", "labels", "edges"):
        _require(k in d, f"query missing {k!r}")
    n = d["n"]
    _require(isinstance(n, int) and 1 <= n <= 64,
             f"query n must be an int in [1, 64], got {n!r}")
    labels = d["labels"]
    _require(isinstance(labels, list) and len(labels) == n,
             f"query labels must be a list of length {n}")
    _require(all(isinstance(x, int) and x >= 0 for x in labels),
             "query labels must be non-negative ints")
    edges = d["edges"]
    _require(isinstance(edges, list), "query edges must be a list")
    for e in edges:
        _require(isinstance(e, list) and len(e) == 2
                 and all(isinstance(x, int) for x in e),
                 f"query edge {e!r} must be [int, int]")
        a, b = e
        _require(0 <= a < n and 0 <= b < n and a != b,
                 f"query edge {e!r} out of range for n={n}")
    n_labels = d.get("n_labels")
    if n_labels is not None:
        _require(isinstance(n_labels, int)
                 and n_labels > max(labels, default=-1),
                 f"n_labels {n_labels!r} inconsistent with labels")
    return Graph.from_edges(n, [(a, b) for a, b in edges], labels,
                            n_labels=n_labels)


# ----------------------------------------------------------------------
# request envelope
# ----------------------------------------------------------------------
@dataclasses.dataclass
class MatchRequestWire:
    """One match request as it crosses the wire: the query graph, the
    tenant it bills to, and the whitelisted per-query option overrides.
    ``request_id`` is the caller's correlation id, echoed verbatim on
    every response event."""
    query: Graph
    tenant: str = "default"
    options: dict = dataclasses.field(default_factory=dict)
    request_id: int | str | None = None

    def to_wire(self) -> dict:
        return {"v": WIRE_VERSION, "query": encode_query(self.query),
                "tenant": self.tenant, "options": dict(self.options),
                "request_id": self.request_id}

    @staticmethod
    def from_wire(obj: Any) -> "MatchRequestWire":
        _check_version(obj)
        _require("query" in obj, "request missing 'query'")
        query = decode_query(obj["query"])
        tenant = obj.get("tenant", "default")
        _require(isinstance(tenant, str) and 0 < len(tenant) <= 128,
                 f"tenant must be a short string, got {tenant!r}")
        options = obj.get("options") or {}
        _require(isinstance(options, dict), "options must be an object")
        for k, val in options.items():
            _require(k in REQUEST_OPTION_KEYS,
                     f"option {k!r} not settable over the wire "
                     f"(allowed: {', '.join(REQUEST_OPTION_KEYS)})")
            _require(val is None or isinstance(val, (int, float, bool)),
                     f"option {k}={val!r} must be a JSON scalar")
        rid = obj.get("request_id")
        _require(rid is None or isinstance(rid, (int, str)),
                 f"request_id must be an int or string, got {rid!r}")
        return MatchRequestWire(query=query, tenant=tenant,
                                options=dict(options), request_id=rid)

    def to_json(self) -> bytes:
        return json.dumps(self.to_wire()).encode()

    @staticmethod
    def from_json(raw: bytes | str) -> "MatchRequestWire":
        try:
            obj = json.loads(raw)
        except json.JSONDecodeError as e:
            raise ProtocolError(f"request is not valid JSON: {e}") from e
        return MatchRequestWire.from_wire(obj)


# ----------------------------------------------------------------------
# response events
# ----------------------------------------------------------------------
def accepted_event(query_id, tenant: str,
                   request_id=None) -> dict:
    return {"v": WIRE_VERSION, "event": "accepted",
            "query_id": query_id, "tenant": tenant,
            "request_id": request_id}


def chunk_event(query_id, seq: int, rows: Iterable) -> dict:
    """One streamed embedding batch: ``rows`` is ``[k, n_query]`` ints
    (row ``i`` maps query position ``j`` -> data vertex ``rows[i][j]``,
    in matching order)."""
    return {"v": WIRE_VERSION, "event": "chunk", "query_id": query_id,
            "seq": int(seq),
            "rows": [[int(x) for x in r] for r in rows]}


def done_event(query_id, result: dict) -> dict:
    """Terminal event. ``result`` is a ``QueryResult.to_dict()``-shaped
    summary; its ``status`` must be one of the six terminal statuses —
    ``error`` and ``shed`` ride the same event so no outcome is
    expressible in-process but not on the wire."""
    st = result.get("status")
    if st not in STATUSES:
        raise ProtocolError(f"done event with non-terminal status {st!r}")
    return {"v": WIRE_VERSION, "event": "done", "query_id": query_id,
            "result": result}


def error_event(message: str, code: str = "bad-request",
                query_id=None) -> dict:
    """The request failed before becoming a query (malformed payload,
    draining server). Queries that *ran* and failed terminate with a
    ``done`` event carrying ``status="error"`` instead."""
    return {"v": WIRE_VERSION, "event": "error", "query_id": query_id,
            "code": str(code), "message": str(message)}


def encode_event(ev: dict) -> bytes:
    """One NDJSON line (the chunked-stream unit)."""
    return (json.dumps(ev, separators=(",", ":")) + "\n").encode()


def decode_event(line: bytes | str) -> dict:
    """Strict inverse of :func:`encode_event` — shape-checks every
    event kind so a client never consumes a half-valid stream."""
    try:
        ev = json.loads(line)
    except json.JSONDecodeError as e:
        raise ProtocolError(f"event is not valid JSON: {e}") from e
    _check_version(ev)
    kind = ev.get("event")
    _require(kind in _EVENTS, f"unknown event kind {kind!r}")
    if kind == "chunk":
        rows = ev.get("rows")
        _require(isinstance(rows, list) and all(
            isinstance(r, list) and all(isinstance(x, int) for x in r)
            for r in rows), "chunk rows must be a list of int lists")
        _require(isinstance(ev.get("seq"), int) and ev["seq"] >= 0,
                 "chunk seq must be a non-negative int")
    elif kind == "done":
        res = ev.get("result")
        _require(isinstance(res, dict), "done event missing result")
        _require(res.get("status") in STATUSES,
                 f"done status {res.get('status')!r} not terminal")
    elif kind == "error":
        _require(isinstance(ev.get("message"), str),
                 "error event missing message")
        _require(isinstance(ev.get("code"), str),
                 "error event missing code")
    return ev
