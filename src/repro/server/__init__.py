"""Network serving tier (DESIGN.md §10): a standalone server process in
front of the in-process engine.

* :mod:`repro.server.server_args` — CLI-parseable :class:`ServerArgs`
  (host/port, data-graph spec, engine knobs resolved through
  ``MatchOptions``/the tuning cache, tenant admission config);
* :mod:`repro.server.protocol` — the versioned JSON wire encoding
  (query graphs, per-query options, streamed embedding chunks, terminal
  results carrying every ``Status``, typed errors);
* :mod:`repro.server.server` — the HTTP request loop over
  ``MatchSession``: one engine thread owns the scheduler, handler
  threads stream NDJSON events, client disconnects ride the eviction
  path, SIGTERM drains gracefully;
* :mod:`repro.server.admission` — multi-tenant admission: per-tenant
  token buckets, weighted fair queueing, bounded-queue load shedding;
* :mod:`repro.server.metrics` — the ``/metrics`` + ``/slo`` exporter;
* :mod:`repro.server.client` — the stdlib blocking/streaming client
  used by tests, examples and ``benchmarks/load_bench.py``.

Launch:  ``python -m repro.server.launch --graph ba --port 8421``
"""
from .admission import AdmissionController, TenantConfig
from .client import ServeClient
from .protocol import (ProtocolError, WIRE_VERSION, decode_event,
                       decode_query, encode_event, encode_query)
from .server import MatchServer
from .server_args import ServerArgs

__all__ = [
    "AdmissionController", "TenantConfig", "ServeClient",
    "ProtocolError", "WIRE_VERSION", "decode_event", "decode_query",
    "encode_event", "encode_query", "MatchServer", "ServerArgs",
]
